//! `cargo bench` — component benches (hand-rolled harness; no criterion in
//! the vendor set). One bench per hot path, plus per-table aliases mapping
//! to the paper's evaluation (DESIGN.md §3):
//!
//!   tab8_*   — training-phase step latency/throughput (Table 8)
//!   fig3_*   — eval/perplexity path that produces the convergence curves
//!   tab3_*   — generation/decode path behind pass@k
//!   serve    — continuous-batching scheduler; emits BENCH_serve.json
//!              (steady-state tokens/sec, mean TTFT, batch occupancy;
//!              speculative scenarios keyed by draft length K and
//!              acceptance rate, sim fallback without artifacts)
//!   substrate benches: NF4 quant, pruning plans, recovery, tokenizer, JSON
//!
//! Requires `make artifacts` (tiny suite) for the runtime benches.

use loram::bench::{bench, bench_throughput};
use loram::chaos::ChaosEngine;
use loram::coordinator::adapters::AdapterId;
use loram::coordinator::evaluate::{test_sequences, Evaluator};
use loram::coordinator::generate::{DecodePath, Generator, SampleCfg};
use loram::coordinator::train::TrainSession;
use loram::data::instruct::{Dataset, InstructGen};
use loram::data::{corpus::Corpus, make_batch};
use loram::params::{init_lora, init_params};
use loram::pruning;
use loram::quant;
use loram::runtime::Runtime;
use loram::serve::{DecodeEngine, Server, ServerStats, SimEngine};
use loram::tensor::Tensor;
use loram::tokenizer::Tokenizer;
use loram::util::json::Json;
use loram::util::rng::Rng;

/// Drive `n` mixed-config requests through the continuous-batching server
/// and return its stats (tokens/sec, TTFT, occupancy). `adapters` routes
/// request i through `adapters[i % len]` (empty = adapter-less requests).
fn serve_workload<E: DecodeEngine>(
    engine: E,
    n: usize,
    adapters: &[AdapterId],
) -> anyhow::Result<ServerStats> {
    serve_workload_t(engine, n, adapters, false)
}

/// `greedy` pins every request to temperature 0 — the speculative
/// scenarios measure acceptance, which is a greedy-path concept.
fn serve_workload_t<E: DecodeEngine>(
    engine: E,
    n: usize,
    adapters: &[AdapterId],
    greedy: bool,
) -> anyhow::Result<ServerStats> {
    let mut srv = Server::new(engine, 7);
    let mut ig = InstructGen::new(Dataset::Hermes, 3, 1);
    for i in 0..n {
        let (ex, _) = ig.next();
        srv.enqueue_adapter(
            ex.instruction,
            SampleCfg {
                temperature: if greedy { 0.0 } else { 0.2 * (i % 3) as f64 },
                top_p: [1.0, 0.95, 0.9][i % 3],
                max_new: 8 + 4 * (i % 2),
            },
            if adapters.is_empty() {
                None
            } else {
                Some(adapters[i % adapters.len()])
            },
        );
    }
    srv.drain()?;
    Ok(srv.stats)
}

/// Bursty mixed-length workload through the token-budget scheduler
/// (ISSUE 5): bursts of prompts — every third near-grid-long, the rest
/// short — arrive mid-decode, with `budget` prefill window tokens per
/// tick. Paired monolithic/chunked engines measure the admission stall
/// and its removal in sim ticks (TTFT/ITL percentiles).
fn serve_bursty_workload<E: DecodeEngine>(
    engine: E,
    n: usize,
    budget: usize,
) -> anyhow::Result<ServerStats> {
    let mut srv = Server::new(engine, 7);
    srv.set_prefill_budget(Some(budget));
    let mut sent = 0;
    while sent < n {
        for _ in 0..6.min(n - sent) {
            let prompt = if sent % 3 == 0 {
                "long prompt ".repeat(5)
            } else {
                format!("q{sent}")
            };
            srv.enqueue(prompt, SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 6 });
            sent += 1;
        }
        for _ in 0..6 {
            srv.step()?;
        }
    }
    srv.drain()?;
    Ok(srv.stats)
}

/// Shared-system-prompt workload (DESIGN.md §2f): bursts of requests that
/// share one long system prefix (suffix differs per user), through either
/// the dense-grid engine (4 rows × 64 slots) or the paged block-pool
/// engine (32 × 8-slot blocks — identical cache bytes). The paged entry
/// must show prefix hits, more concurrent rows, and zero copy-on-write
/// forks; the dense entry re-prefills the shared prefix every admission.
fn serve_shared_prefix_workload(
    paged: bool,
    sys: &str,
    n: usize,
    budget: usize,
) -> anyhow::Result<ServerStats> {
    let engine = if paged {
        SimEngine::with_paged(32, 8, 32, vec![16, 64])?
    } else {
        SimEngine::with_prefill(4, vec![16, 64], false)
    };
    let mut srv = Server::new(engine, 7);
    srv.set_prefill_budget(Some(budget));
    let mut sent = 0;
    while sent < n {
        for u in 0..8.min(n - sent) {
            srv.enqueue(
                format!("{sys}user {u}"),
                SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 4 },
            );
            sent += 1;
        }
        for _ in 0..6 {
            srv.step()?;
        }
    }
    srv.drain()?;
    Ok(srv.stats)
}

/// The SLO-vs-FIFO A/B (DESIGN.md §2i): one bursty heavy-tail stream
/// with a high-priority deadline-carrying slice
/// (`workload::generate("bursty-heavytail")`), replayed through the
/// same engine under plain FIFO admission vs the SLO-aware scheduler.
/// The SLO row must win on goodput-under-SLO (misses and cancellations
/// subtract) — the serve.rs scenario tests additionally pin the
/// high-priority TTFT p95 win.
fn serve_slo_workload(slo: bool, n: usize, seed: u64) -> anyhow::Result<ServerStats> {
    let mut srv = Server::new(SimEngine::new(4), 7);
    srv.set_slo(slo);
    let reqs = loram::workload::generate("bursty-heavytail", n, seed)?;
    loram::workload::run(&mut srv, &reqs)?;
    Ok(srv.stats)
}

/// The fault-storm A/B (DESIGN.md §2j): the identical deterministic
/// storm (`ChaosEngine`, scenario "fault-storm") over the `faults`
/// workload stream, replayed under bounded retry + failure-domain
/// isolation vs the pre-§2j abort-on-error contract. The retry row must
/// resolve every request — served / failed / rejected, nothing lost
/// silently — and carries the failed/retries/degraded_ticks columns;
/// the abort row's drain error is the measurement (partial stats, zero
/// graceful failures).
fn serve_chaos_workload(retry: bool, n: usize, seed: u64) -> anyhow::Result<ServerStats> {
    let chaos = ChaosEngine::new(SimEngine::new(4), "fault-storm", 64, seed)?;
    let mut srv = Server::new(chaos, 7);
    if retry {
        srv.set_retry_policy(Some(2), 1);
    }
    let reqs = loram::workload::generate("faults", n, seed)?;
    if let Err(e) = loram::workload::run(&mut srv, &reqs) {
        // the abort arm dies at the first unabsorbed fault — expected;
        // the retry arm surviving the storm is an acceptance criterion
        anyhow::ensure!(!retry, "retry+isolation arm must survive the storm: {e}");
    }
    Ok(srv.stats)
}

/// One serving measurement: which decode path it exercised (`reforward` /
/// `kvcache` / `speculative`) and through which engine (`pjrt`, or `sim`
/// when the scheduler ran without artifacts).
struct ServeEntry {
    path: &'static str,
    engine: &'static str,
    requests: usize,
    /// speculative scenario knobs: (draft length K, sim acceptance prob)
    spec_cfg: Option<(usize, f64)>,
    stats: ServerStats,
}

/// `git rev-parse --short HEAD`, or "unknown" outside a git checkout —
/// every emitted measurement names the code that produced it.
fn git_rev() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// Emit the serving bench trajectory: one distinct entry per decode path.
/// Every scalar is read back out of the unified metrics registry
/// ([`ServerStats::to_metrics`], DESIGN.md §2g) — the registry is the
/// single export path, so a renamed or dropped counter breaks this bench
/// instead of silently forking the schema. The file is stamped with the
/// schema version, git revision, and the run's wall clock.
fn emit_bench_serve(entries: &[ServeEntry], run_wall_s: f64) -> anyhow::Result<()> {
    let rows: Vec<Json> = entries
        .iter()
        .map(|e| {
            let st = &e.stats;
            let m = st.to_metrics();
            let c = |k: &str| Json::num(m.counter(k));
            let g = |k: &str| Json::num(m.gauge(k));
            let lanes: Vec<Json> = st
                .per_adapter
                .keys()
                .map(|adapter| {
                    let label = loram::serve::adapter_label(*adapter);
                    let k = |field: &str| format!("adapter.{label}.{field}");
                    Json::obj(vec![
                        ("adapter", Json::str(&label)),
                        ("requests", c(&k("requests"))),
                        ("tokens", c(&k("tokens"))),
                        ("tokens_per_sec", g(&k("tokens_per_sec"))),
                        ("mean_ttft_ms", g(&k("mean_ttft_ms"))),
                    ])
                })
                .collect();
            let mut fields = vec![
                ("path", Json::str(e.path)),
                ("engine", Json::str(e.engine)),
                ("requests", Json::num(e.requests as f64)),
                ("tokens_per_sec", g("serve.tokens_per_sec")),
                ("mean_ttft_ms", g("serve.mean_ttft_ms")),
                ("mean_latency_ms", g("serve.mean_latency_ms")),
                ("mean_batch_occupancy", g("serve.mean_occupancy")),
                ("mean_queue_wait_ms", g("serve.mean_queue_wait_ms")),
                ("peak_queue_depth", g("serve.peak_queue_depth")),
                ("decode_steps", c("serve.decode_steps")),
                ("total_tokens", c("serve.total_tokens")),
                // sim-time latency distributions + the §2e waste counter
                ("ticks", c("serve.ticks")),
                ("ttft_p50_ticks", g("serve.ttft_tick_p50")),
                ("ttft_p95_ticks", g("serve.ttft_tick_p95")),
                ("itl_p50_ticks", g("serve.itl_tick_p50")),
                ("itl_p95_ticks", g("serve.itl_tick_p95")),
                ("prefill_tokens", c("prefill.tokens")),
                ("padded_prefill_tokens", c("prefill.padded_tokens")),
                ("peak_in_flight", g("serve.peak_in_flight")),
                // §2i SLO columns: zero on plain-FIFO entries
                ("preempted", c("serve.preempted")),
                ("cancelled", c("serve.cancelled")),
                ("deadline_misses", c("serve.deadline_misses")),
                ("goodput", g("serve.goodput")),
                // §2j fault columns: zero everywhere but the chaos rows
                ("failed", c("serve.failed")),
                ("retries", c("serve.retries")),
                ("degraded_ticks", c("serve.degraded_ticks")),
            ];
            // §2f block-pool counters, present only on the paged path
            if m.has_gauge("paged.prefix_hit_rate") {
                fields.push(("prefix_hit_rate", g("paged.prefix_hit_rate")));
                fields.push(("prefix_hit_tokens", c("paged.prefix_hit_tokens")));
                fields.push(("blocks_in_use", g("paged.blocks_in_use")));
                fields.push(("pool_blocks", g("paged.pool_blocks")));
                fields.push(("cow_copies", c("paged.cow_copies")));
            }
            if let Some((k, p)) = e.spec_cfg {
                fields.push(("draft_k", Json::num(k as f64)));
                if p.is_finite() {
                    // sim scenarios only; pjrt entries carry the *real*
                    // acceptance_rate below instead
                    fields.push(("sim_accept_prob", Json::num(p)));
                }
            }
            if m.has_counter("spec.rounds") {
                fields.push(("acceptance_rate", g("spec.acceptance_rate")));
                fields.push(("tokens_per_verify", g("spec.tokens_per_verify")));
                fields.push(("draft_steps", c("spec.draft_steps")));
                fields.push(("verify_steps", c("spec.verify_steps")));
            }
            fields.push(("adapters", Json::Arr(lanes)));
            Json::obj(fields)
        })
        .collect();
    let now_unix = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0);
    let j = Json::obj(vec![
        ("bench", Json::str("serve")),
        ("schema_version", Json::num(loram::obs::export::TRACE_SCHEMA_VERSION as f64)),
        ("git_rev", Json::str(&git_rev())),
        ("generated_unix", Json::num(now_unix)),
        ("run_wall_s", Json::num(run_wall_s)),
        ("entries", Json::Arr(rows)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json");
    std::fs::write(path, j.to_string())?;
    for e in entries {
        println!(
            "BENCH_serve.json [{}/{}]: {:.1} tok/s, mean ttft {:.2} ms, occupancy {:.2}, \
             queue wait {:.2} ms (peak depth {})",
            e.path,
            e.engine,
            e.stats.tokens_per_sec(),
            e.stats.mean_ttft_ms(),
            e.stats.mean_occupancy(),
            e.stats.mean_queue_wait_ms(),
            e.stats.peak_queue_depth
        );
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    // cargo passes harness flags like `--bench`; only bare words filter
    let filter = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with('-'))
        .unwrap_or_default();
    let run = |name: &str| filter.is_empty() || name.contains(&filter);
    let t_run = std::time::Instant::now();
    println!("loram bench suite (filter: {:?})", filter);

    // ---------------- pure-substrate benches -----------------------------
    let mut rng = Rng::new(0);
    let w = Tensor::from_f32(&[256, 512], rng.normal_vec(256 * 512, 1.0));
    if run("nf4_quantize") {
        bench_throughput("nf4_quantize_256x512", 2, 10, (256 * 512) as f64, "elem/s", || {
            std::hint::black_box(quant::quantize(&w, 16));
        })
        .report();
    }
    if run("nf4_dequantize") {
        let q = quant::quantize(&w, 16);
        bench_throughput("nf4_dequantize_256x512", 2, 10, (256 * 512) as f64, "elem/s", || {
            std::hint::black_box(quant::dequantize(&q));
        })
        .report();
    }
    if run("semi_mask") {
        bench("pruning_semi_mask_4of8_256x512", 2, 10, || {
            std::hint::black_box(pruning::semi_mask_4of8(&w));
        })
        .report();
    }
    if run("unst_mask") {
        bench("pruning_unst_mask_256x512", 2, 10, || {
            std::hint::black_box(pruning::unstructured_mask(&w, 0.55));
        })
        .report();
    }
    if run("tokenizer") {
        let tk = Tokenizer::new();
        let text = "Q: 12+34= A: 46 ".repeat(64);
        bench_throughput("tokenizer_encode_1KiB", 5, 50, text.len() as f64, "B/s", || {
            std::hint::black_box(tk.encode(&text));
        })
        .report();
    }
    if run("json") {
        let doc = Json::obj(vec![
            ("xs", Json::arr_f64(&(0..256).map(|x| x as f64).collect::<Vec<_>>())),
            ("name", Json::str("bench")),
        ])
        .to_string();
        bench("json_parse_2KiB", 5, 50, || {
            std::hint::black_box(Json::parse(&doc).unwrap());
        })
        .report();
    }
    if run("corpus") {
        let mut c = Corpus::new(0, 0.5);
        bench_throughput("corpus_gen_seq64", 3, 30, 65.0, "tok/s", || {
            std::hint::black_box(c.next_seq(64));
        })
        .report();
    }
    if run("serve") {
        // scheduler-only serving bench on the simulated engine (runs with
        // no artifacts); overwritten by the PJRT-backed numbers below when
        // the tiny artifact suite is present. The sim engine has no decode
        // cost model, so one measured workload stands in for both path
        // labels (engine "sim" marks the entries as scheduler-only). The
        // mixed-adapter scenario routes requests across three adapters;
        // the speculative scenarios sweep draft length K x acceptance
        // probability through the SimEngine drafter mode.
        let st = serve_workload(SimEngine::new(4), 64, &[])?;
        let ids: Vec<AdapterId> = (0..3).map(AdapterId::for_slot).collect();
        let mixed = serve_workload(SimEngine::new(4), 64, &ids)?;
        let mut entries = vec![
            ServeEntry { path: "reforward", engine: "sim", requests: 64, spec_cfg: None, stats: st.clone() },
            ServeEntry { path: "kvcache", engine: "sim", requests: 64, spec_cfg: None, stats: st },
            ServeEntry { path: "mixed-adapter", engine: "sim", requests: 64, spec_cfg: None, stats: mixed },
        ];
        for (k, p) in [(2, 0.5), (4, 0.0), (4, 0.5), (4, 0.9), (8, 0.9)] {
            let st = serve_workload_t(SimEngine::with_spec(4, k, p, 7), 64, &[], true)?;
            entries.push(ServeEntry {
                path: "speculative",
                engine: "sim",
                requests: 64,
                spec_cfg: Some((k, p)),
                stats: st,
            });
        }
        // the admission-stall A/B (ISSUE 5): the same bursty mixed-length
        // load and per-tick token capacity through the monolithic
        // pad-to-S baseline (decode stalls while admissions drain) vs the
        // chunked bucket ladder (prefill interleaves with decode); the
        // chunked row must show lower sim TTFT p95 and bounded ITL
        for (path, ladder, stall) in [
            ("prefill-monolithic", vec![64], true),
            ("prefill-chunked", vec![16, 64], false),
        ] {
            let st = serve_bursty_workload(SimEngine::with_prefill(4, ladder, stall), 48, 16)?;
            entries.push(ServeEntry { path, engine: "sim", requests: 48, spec_cfg: None, stats: st });
        }
        // the shared-prefix A/B (§2f): N users × one system prompt, dense
        // grid vs paged block pool at identical cache bytes — the paged
        // entry carries the prefix_hit_rate / blocks_in_use / cow_copies
        // counters and a higher peak_in_flight
        let sysp = "system: you are a terse helpful assistant. ";
        for (path, paged) in [("prefix-dense", false), ("prefix-paged", true)] {
            let st = serve_shared_prefix_workload(paged, sysp, 32, 16)?;
            entries.push(ServeEntry { path, engine: "sim", requests: 32, spec_cfg: None, stats: st });
        }
        // the SLO A/B (§2i): the identical adversarial stream, FIFO vs
        // SLO-aware — the slo-sched row carries the goodput win and the
        // preempted/cancelled/deadline_misses accounting
        for (path, slo) in [("slo-fifo", false), ("slo-sched", true)] {
            let st = serve_slo_workload(slo, 48, 9)?;
            entries.push(ServeEntry { path, engine: "sim", requests: 48, spec_cfg: None, stats: st });
        }
        // the fault-storm A/B (§2j): the same deterministic storm,
        // abort-on-error (the drain dies at the first fault — partial
        // stats, zero graceful failures) vs bounded retry + isolation
        // (every request resolves; failed/retries/degraded_ticks filled)
        for (path, retry) in [("chaos-abort", false), ("chaos-retry", true)] {
            let st = serve_chaos_workload(retry, 48, 9)?;
            entries.push(ServeEntry { path, engine: "sim", requests: 48, spec_cfg: None, stats: st });
        }
        emit_bench_serve(&entries, t_run.elapsed().as_secs_f64())?;
    }

    // ---------------- runtime benches (need artifacts) --------------------
    let rt = match Runtime::new(loram::default_artifact_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            println!("(skipping runtime benches: {e})");
            return Ok(());
        }
    };
    if rt.load("eval_tiny").is_err() {
        println!("(skipping runtime benches: tiny artifacts missing — run `make artifacts`)");
        return Ok(());
    }
    let cfg = rt.load("eval_tiny")?.meta.config.clone();
    let params = init_params(&cfg, 0);
    let lora = init_lora(&cfg, 0);

    if run("plan") || run("recovery") {
        let pruned_cfg = rt.load("eval_tiny_p50")?.meta.config.clone();
        let plan = pruning::StructuredPlan::random(&cfg, &pruned_cfg, 0)?;
        if run("plan") {
            bench("pruning_slice_params_tiny", 2, 10, || {
                std::hint::black_box(pruning::slice_params(&params, &cfg, &plan).unwrap());
            })
            .report();
        }
        if run("recovery") {
            let pruned_lora = init_lora(&pruned_cfg, 0);
            bench("recovery_scatter_tiny", 2, 10, || {
                std::hint::black_box(
                    pruning::recover_lora(&pruned_lora, &cfg, &plan).unwrap(),
                );
            })
            .report();
        }
    }

    if run("fig3") || run("eval") {
        let ev = Evaluator::new(&rt, "eval_tiny", &[&params, &lora])?;
        let seqs = test_sequences(Dataset::Alpaca, 0, 8);
        bench_throughput("fig3_eval_ppl_8seq", 1, 8, 8.0, "seq/s", || {
            std::hint::black_box(ev.perplexity(&seqs, true).unwrap());
        })
        .report();
    }

    if run("tab8") || run("sft") {
        let mut sess = TrainSession::new(&rt, "sft_tiny", &[&params, &lora])?;
        let (b, s) = (sess.batch_size(), sess.seq_len());
        let mut corpus = Corpus::new(1, 0.5);
        bench_throughput("tab8_sft_step_tiny", 2, 12, b as f64, "samples/s", || {
            let seqs = corpus.next_seqs(b, s);
            let batch = make_batch(&seqs, b, s, true);
            sess.train_step(&batch, 1e-3).unwrap();
        })
        .report();
        let mut pre = TrainSession::new(&rt, "pretrain_tiny", &[&params])?;
        bench_throughput("tab8_pretrain_step_tiny", 2, 12, b as f64, "samples/s", || {
            let seqs = corpus.next_seqs(b, s);
            let batch = make_batch(&seqs, b, s, false);
            pre.train_step(&batch, 1e-3).unwrap();
        })
        .report();
    }

    if run("tab3") || run("decode") {
        let gen = Generator::new(&rt, "logits_tiny", &[&params, &lora])?;
        let mut grng = Rng::new(2);
        let prompts = vec!["Q: 2+3=".to_string(), "Q: 4+4=".to_string()];
        bench_throughput("tab3_decode_8tok_b2", 1, 6, 16.0, "tok/s", || {
            std::hint::black_box(
                gen.generate_batch(
                    &prompts,
                    SampleCfg {
                        temperature: 0.0,
                        top_p: 1.0,
                        max_new: 8,
                    },
                    &mut grng,
                )
                .unwrap(),
            );
        })
        .report();
    }

    if run("serve") {
        // both decode paths through the real scheduler: the full-reforward
        // baseline vs the (B, 1) kv-cache path (DESIGN.md §Perf), plus the
        // mixed-adapter scenario over the stacked artifact (§2c)
        let n = 16;
        let gen = Generator::with_path(
            &rt,
            "logits_tiny",
            &[&params, &lora],
            Some(DecodePath::Reforward),
        )?;
        let mut entries = vec![ServeEntry {
            path: "reforward",
            engine: "pjrt",
            requests: n,
            spec_cfg: None,
            stats: serve_workload(gen, n, &[])?,
        }];
        match Generator::with_path(&rt, "logits_tiny", &[&params, &lora], Some(DecodePath::KvCache))
        {
            Ok(gen) => {
                // the historical baseline row stays monolithic so the
                // chunked row below is a like-for-like A/B
                let had_ladder = gen.chunked_prefill();
                if had_ladder {
                    gen.set_chunked_prefill(false)?;
                }
                entries.push(ServeEntry {
                    path: "kvcache",
                    engine: "pjrt",
                    requests: n,
                    spec_cfg: None,
                    stats: serve_workload(gen, n, &[])?,
                });
                if had_ladder {
                    let gen = Generator::with_path(
                        &rt,
                        "logits_tiny",
                        &[&params, &lora],
                        Some(DecodePath::KvCache),
                    )?;
                    entries.push(ServeEntry {
                        path: "kvcache-chunked",
                        engine: "pjrt",
                        requests: n,
                        spec_cfg: None,
                        stats: serve_workload(gen, n, &[])?,
                    });
                }
            }
            Err(e) => {
                println!("(kvcache serve bench falling back to sim: {e})");
                entries.push(ServeEntry {
                    path: "kvcache",
                    engine: "sim",
                    requests: 64,
                    spec_cfg: None,
                    stats: serve_workload(SimEngine::new(4), 64, &[])?,
                });
            }
        }
        // pooled block caches through the real scheduler (§2f), when the
        // decode_*_paged_tiny family is in the artifact dir
        match Generator::with_path_paged(
            &rt,
            "logits_tiny",
            &[&params, &lora],
            Some(DecodePath::KvCache),
            true,
        ) {
            Ok(gen) => entries.push(ServeEntry {
                path: "kvcache-paged",
                engine: "pjrt",
                requests: n,
                spec_cfg: None,
                stats: serve_workload(gen, n, &[])?,
            }),
            Err(e) => println!("(paged serve bench skipped: {e})"),
        }
        // draft small, verify large through the real scheduler: the
        // pruned proxy (sliced base, zero factors) drafts for the target;
        // sim K-sweep fallback when the trio/drafter artifacts are absent
        let spec = (|| -> anyhow::Result<(usize, ServerStats)> {
            let (dparams, dlora) = loram::coordinator::speculative::sliced_drafter_standin(
                &rt, &cfg, &params, "tiny_p50", 0,
            )?;
            let gen = Generator::with_speculative(
                &rt,
                "logits_tiny",
                &[&params, &lora],
                "tiny_p50",
                &[&dparams, &dlora],
            )?;
            let k = gen.draft_k().expect("speculative generator has a window");
            Ok((k, serve_workload_t(gen, n, &[], true)?))
        })();
        match spec {
            Ok((k, stats)) => entries.push(ServeEntry {
                path: "speculative",
                engine: "pjrt",
                requests: n,
                spec_cfg: Some((k, f64::NAN)),
                stats,
            }),
            Err(e) => {
                println!("(speculative serve bench falling back to sim: {e})");
                for (k, p) in [(4, 0.5), (4, 0.9)] {
                    entries.push(ServeEntry {
                        path: "speculative",
                        engine: "sim",
                        requests: 64,
                        spec_cfg: Some((k, p)),
                        stats: serve_workload_t(SimEngine::with_spec(4, k, p, 7), 64, &[], true)?,
                    });
                }
            }
        }
        let mixed = Generator::with_adapters(&rt, "logits_tiny_a3", &[&params], None, None)
            .and_then(|gen| {
                let cap = gen.adapter_capacity().unwrap_or(1);
                let ids: Vec<AdapterId> = (0..cap)
                    .map(|i| {
                        gen.register_adapter(&format!("task{i}"), init_lora(&cfg, i as u64 + 1))
                    })
                    .collect::<anyhow::Result<_>>()?;
                serve_workload(gen, n, &ids)
            });
        match mixed {
            Ok(stats) => entries.push(ServeEntry {
                path: "mixed-adapter",
                engine: "pjrt",
                requests: n,
                spec_cfg: None,
                stats,
            }),
            Err(e) => {
                println!("(mixed-adapter serve bench falling back to sim: {e})");
                let ids: Vec<AdapterId> = (0..3).map(AdapterId::for_slot).collect();
                entries.push(ServeEntry {
                    path: "mixed-adapter",
                    engine: "sim",
                    requests: 64,
                    spec_cfg: None,
                    stats: serve_workload(SimEngine::new(4), 64, &ids)?,
                });
            }
        }
        emit_bench_serve(&entries, t_run.elapsed().as_secs_f64())?;
    }

    if run("pallas") {
        // L1 kernel-path vs jnp-path logits artifacts (numerical parity is
        // asserted by the integration tests; here we compare latency)
        for name in ["logits_tiny_jnp", "logits_tiny_pallas"] {
            if let Ok(art) = rt.load(name) {
                let mut store = loram::tensor::TensorStore::new();
                for (k, v) in &params.map {
                    store.insert(k.clone(), v.clone());
                }
                for (k, v) in &lora.map {
                    store.insert(k.clone(), v.clone());
                }
                store.insert(
                    "tokens",
                    Tensor::from_i32(&[2, 32], vec![65; 64]),
                );
                bench(&format!("l1_{name}"), 1, 6, || {
                    std::hint::black_box(rt.run(&art, &store).unwrap());
                })
                .report();
            }
        }
    }

    let m = rt.metrics.borrow();
    println!(
        "\nruntime totals: {} compiles ({:.0} ms), {} executions ({:.0} ms), h2d {} MiB, d2h {} MiB",
        m.compiles,
        m.compile_ms,
        m.executions,
        m.execute_ms,
        m.h2d_bytes >> 20,
        m.d2h_bytes >> 20
    );
    Ok(())
}

//! Integration tests over the real artifacts + PJRT runtime.
//!
//! These need `make artifacts` (the smoke subset is enough). They share one
//! Runtime (PJRT client) via a thread-local because the client is neither
//! Send nor cheap; `cargo test` runs this binary's cases in parallel
//! threads, so each test opens its own runtime.

use loram::coordinator::adapters::{AdapterId, AdapterStore};
use loram::coordinator::evaluate::{test_sequences, Evaluator};
use loram::coordinator::generate::{DecodePath, Generator, SampleCfg};
use loram::coordinator::pipeline::{Pipeline, PipelineConfig, Variant};
use loram::coordinator::train::TrainSession;
use loram::data::instruct::Dataset;
use loram::data::{corpus::Corpus, make_batch};
use loram::params::{init_lora, init_params};
use loram::pruning;
use loram::runtime::{BackendKind, Runtime, Session};
use loram::chaos::ChaosEngine;
use loram::serve::{Outcome, Priority, Server};
use loram::tensor::{Tensor, TensorStore};
use loram::util::rng::Rng;

fn runtime() -> Runtime {
    let dir = std::env::var("LORAM_ARTIFACTS").unwrap_or_else(|_| {
        // tests run from the crate root
        "artifacts".to_string()
    });
    Runtime::new(dir).expect("PJRT runtime (did you run `make artifacts`?)")
}

/// Like [`runtime`] but for tests that *skip* (rather than fail) when the
/// runtime or the artifacts they need are unavailable.
fn try_runtime(needed: &[&str]) -> Option<Runtime> {
    let dir = std::env::var("LORAM_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    let rt = match Runtime::new(dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping: no PJRT runtime ({e})");
            return None;
        }
    };
    for name in needed {
        if let Err(e) = rt.load(name) {
            eprintln!("skipping: artifact '{name}' unavailable ({e})");
            return None;
        }
    }
    Some(rt)
}

fn tmp_runs() -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("loram_it_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn artifact_meta_matches_rust_shape_mirror() {
    let rt = runtime();
    let art = rt.load("eval_tiny").unwrap();
    let cfg = &art.meta.config;
    // every base-param input of the artifact matches ModelCfg::param_shapes
    for (name, shape) in cfg.param_shapes() {
        let spec = art.meta.input_spec(&name).unwrap();
        assert_eq!(spec.shape, shape, "{name}");
    }
    for (name, shape) in cfg.lora_shapes() {
        let spec = art.meta.input_spec(&name).unwrap();
        assert_eq!(spec.shape, shape, "{name}");
    }
}

#[test]
fn pretrain_step_decreases_loss_on_fixed_batch() {
    let rt = runtime();
    let art = rt.load("pretrain_tiny").unwrap();
    let cfg = art.meta.config.clone();
    let params = init_params(&cfg, 0);
    let mut sess = TrainSession::new(&rt, "pretrain_tiny", &[&params]).unwrap();
    let (b, s) = (sess.batch_size(), sess.seq_len());
    let mut corpus = Corpus::new(0, 0.5);
    let seqs = corpus.next_seqs(b, s);
    let batch = make_batch(&seqs, b, s, false);
    let first = sess.train_step(&batch, 1e-2).unwrap();
    for _ in 0..4 {
        sess.train_step(&batch, 1e-2).unwrap();
    }
    let last = *sess.losses.last().unwrap();
    assert!(last < first, "loss {first} -> {last}");
}

#[test]
fn fresh_lora_is_identity_through_artifacts() {
    // eval with zero-b LoRA must equal eval of the bare model: the nll of
    // any batch must be identical whether lora is fresh or absent-by-zero.
    let rt = runtime();
    let cfg = rt.load("eval_tiny").unwrap().meta.config.clone();
    let params = init_params(&cfg, 1);
    let lora = init_lora(&cfg, 2);
    let mut lora_zero = lora.clone();
    for (k, t) in lora_zero.map.iter_mut() {
        if k.ends_with("lora_a") {
            *t = Tensor::zeros(&t.shape); // zero a as well: both zero
        }
    }
    let ev1 = Evaluator::new(&rt, "eval_tiny", &[&params, &lora]).unwrap();
    let ev2 = Evaluator::new(&rt, "eval_tiny", &[&params, &lora_zero]).unwrap();
    let seqs = test_sequences(Dataset::Alpaca, 0, 4);
    let p1 = ev1.perplexity(&seqs, true).unwrap();
    let p2 = ev2.perplexity(&seqs, true).unwrap();
    assert!((p1 - p2).abs() < 1e-3, "{p1} vs {p2}");
}

#[test]
fn pallas_and_jnp_logits_artifacts_agree() {
    // the L1 kernel path (fused lora_matmul Pallas kernels, interpret mode)
    // lowered into HLO must match the jnp path numerically
    let rt = runtime();
    let art_p = rt.load("logits_tiny_pallas").unwrap();
    let art_j = rt.load("logits_tiny_jnp").unwrap();
    let cfg = art_p.meta.config.clone();
    let params = init_params(&cfg, 3);
    let lora = init_lora(&cfg, 4);
    // non-trivial lora_b so the fused path actually contributes
    let mut store = TensorStore::new();
    for (k, v) in params.map.iter().chain(lora.map.iter()) {
        store.insert(k.clone(), v.clone());
    }
    let mut rng = Rng::new(5);
    for (k, t) in store.map.iter_mut() {
        if k.ends_with("lora_b") {
            *t = Tensor::from_f32(&t.shape, rng.normal_vec(t.len(), 0.05));
        }
    }
    let toks: Vec<i32> = (0..64).map(|i| (i * 7) % 256).collect();
    store.insert("tokens", Tensor::from_i32(&[2, 32], toks));
    let out_p = rt.run(&art_p, &store).unwrap();
    let out_j = rt.run(&art_j, &store).unwrap();
    let lp = out_p.get("logits").unwrap();
    let lj = out_j.get("logits").unwrap();
    let diff = lp.max_abs_diff(lj);
    assert!(diff < 2e-3, "pallas vs jnp max diff {diff}");
}

#[test]
fn sft_masked_keeps_pruned_positions_zero() {
    let rt = runtime();
    let cfg = rt.load("sft_tiny_m").unwrap().meta.config.clone();
    let params = init_params(&cfg, 6);
    let (masks, masked) = pruning::build_masks(&params, &cfg, "unst", 0.5).unwrap();
    let lora = init_lora(&cfg, 7);
    let mut sess = TrainSession::new(&rt, "sft_tiny_m", &[&masked, &masks, &lora]).unwrap();
    let (b, s) = (sess.batch_size(), sess.seq_len());
    let mut gen = loram::data::instruct::InstructGen::new(Dataset::Hermes, 0, 0);
    let tk = loram::tokenizer::Tokenizer::new();
    for _ in 0..3 {
        let seqs: Vec<Vec<i32>> = gen.batch_examples(b).iter().map(|e| e.tokens(&tk)).collect();
        let batch = make_batch(&seqs, b, s, true);
        sess.train_step(&batch, 1e-2).unwrap();
    }
    // C2 invariant: the masked low-rank product (a@b)∘M only updates kept
    // coordinates — equivalently a fully-masked projection's lora gets no
    // gradient. Verify via the delta of a projection whose mask we zero.
    // Here we check the weaker artifact-level invariant: loss is finite and
    // lora_b moved.
    let lnames = sess.art.meta.name_list("lora_names");
    let state = sess.extract(&lnames).unwrap();
    let moved = lnames
        .iter()
        .filter(|n| n.ends_with("lora_b"))
        .any(|n| state.get(n).unwrap().l2_norm() > 0.0);
    assert!(moved);
    assert!(sess.losses.iter().all(|l| l.is_finite()));
}

#[test]
fn full_loram_pipeline_recovers_and_beats_nothing() {
    let runs = tmp_runs();
    let rt = runtime();
    let plc = PipelineConfig {
        base: "tiny".into(),
        pruned: Some("tiny_p50".into()),
        variant: Variant::Stru,
        pretrain_steps: 30,
        align_steps: 6,
        sft_steps: 10,
        dataset: Dataset::Hermes,
        seed: 1,
        eval_every: 0,
        eval_seqs: 8,
        run_dir: runs,
        ..Default::default()
    };
    let res = Pipeline::new(&rt, plc).run().unwrap();
    // recovered factors must have full-config shapes
    let full_cfg = rt.load("eval_tiny").unwrap().meta.config.clone();
    for (name, shape) in full_cfg.lora_shapes() {
        assert_eq!(res.lora_recovered.get(&name).unwrap().shape, shape);
    }
    // the final eval point exists and is finite
    let last = res.eval_points.last().unwrap();
    assert!(last.ood_ppl.is_finite() && last.ood_ppl > 1.0);
    // sft made progress on the training loss
    assert!(res.sft_losses.last().unwrap() < res.sft_losses.first().unwrap());
}

#[test]
fn quantized_sft_step_runs_and_matches_dense_loss_roughly() {
    let rt = runtime();
    let art = rt.load("sft_tiny_p50_q").unwrap();
    let cfg = art.meta.config.clone();
    let params = init_params(&cfg, 8);
    let qnames = art.meta.name_list("quant_names");
    let quant = loram::quant::quantize_projections(&params, &qnames, loram::quant::NF4_BLOCK)
        .unwrap();
    let lora = init_lora(&cfg, 9);
    let mut qsess =
        TrainSession::new(&rt, "sft_tiny_p50_q", &[&params, &quant, &lora]).unwrap();
    let mut dsess = TrainSession::new(&rt, "sft_tiny_p50", &[&params, &lora]).unwrap();
    let (b, s) = (qsess.batch_size(), qsess.seq_len());
    let mut gen = loram::data::instruct::InstructGen::new(Dataset::Hermes, 1, 0);
    let tk = loram::tokenizer::Tokenizer::new();
    let seqs: Vec<Vec<i32>> = gen.batch_examples(b).iter().map(|e| e.tokens(&tk)).collect();
    let batch = make_batch(&seqs, b, s, true);
    let lq = qsess.train_step(&batch, 1e-3).unwrap();
    let ld = dsess.train_step(&batch, 1e-3).unwrap();
    assert!((lq - ld).abs() < 0.5, "quantized {lq} vs dense {ld}");
}

#[test]
fn generation_decodes_tokens() {
    let rt = runtime();
    let cfg = rt.load("logits_tiny").unwrap().meta.config.clone();
    let params = init_params(&cfg, 10);
    let lora = init_lora(&cfg, 11);
    let gen = Generator::new(&rt, "logits_tiny", &[&params, &lora]).unwrap();
    let mut rng = Rng::new(0);
    let outs = gen
        .generate_batch(
            &["Q: 1+1=".to_string()],
            SampleCfg {
                temperature: 0.0,
                top_p: 1.0,
                max_new: 4,
            },
            &mut rng,
        )
        .unwrap();
    assert_eq!(outs.len(), 1);
    assert!(outs[0].len() <= 4);
}

#[test]
fn gradimp_importance_drives_structured_plan() {
    let rt = runtime();
    let art = rt.load("gradimp_tiny").unwrap();
    let cfg = art.meta.config.clone();
    let params = init_params(&cfg, 12);
    let mut store = params.clone();
    let b = art.meta.batch();
    let s = art.meta.seq();
    let mut corpus = Corpus::new(3, 0.5);
    let seqs = corpus.next_seqs(b, s);
    let batch = make_batch(&seqs, b, s, false);
    store.insert("tokens", batch.tokens);
    store.insert("loss_mask", batch.loss_mask);
    let out = rt.run(&art, &store).unwrap();
    let head_imp = out.get("head_imp").unwrap();
    let ff_imp = out.get("ff_imp").unwrap();
    assert_eq!(head_imp.shape, vec![cfg.n_layers, cfg.n_heads]);
    assert!(head_imp.f32s().iter().all(|&x| x >= 0.0));
    assert!(head_imp.f32s().iter().any(|&x| x > 0.0));
    let pruned_cfg = rt.load("eval_tiny_p50").unwrap().meta.config.clone();
    let plan =
        pruning::StructuredPlan::from_importance(&cfg, &pruned_cfg, head_imp, ff_imp).unwrap();
    // kept sets have the right sizes
    for (i, l) in plan.layers.iter().enumerate() {
        let (h, kv, ff) = pruned_cfg.layer_shapes(i);
        assert_eq!(l.heads.len(), h);
        assert_eq!(l.kv_heads.len(), kv);
        assert_eq!(l.ff.len(), ff);
    }
}

#[test]
fn session_host_and_device_backends_are_equivalent() {
    // The same Session abstraction over both backends: identical losses
    // over a 5-step SFT run, identical stepped state afterwards.
    let rt = runtime();
    let art = rt.load("sft_tiny").unwrap();
    let cfg = art.meta.config.clone();
    let params = init_params(&cfg, 20);
    let lora = init_lora(&cfg, 21);
    let mut host =
        Session::with_backend(&rt, art.clone(), &[&params, &lora], BackendKind::Host).unwrap();
    let mut dev =
        Session::with_backend(&rt, art.clone(), &[&params, &lora], BackendKind::Device).unwrap();
    let (b, s) = (art.meta.batch(), art.meta.seq());
    let mut gen = loram::data::instruct::InstructGen::new(Dataset::Hermes, 5, 0);
    let tk = loram::tokenizer::Tokenizer::new();
    for step in 1..=5 {
        let seqs: Vec<Vec<i32>> = gen.batch_examples(b).iter().map(|e| e.tokens(&tk)).collect();
        let batch = make_batch(&seqs, b, s, true);
        let mut losses = vec![];
        for sess in [&mut host, &mut dev] {
            sess.set(&rt, "step", &Tensor::scalar_f32(step as f32)).unwrap();
            sess.set(&rt, "lr", &Tensor::scalar_f32(1e-3)).unwrap();
            sess.set(&rt, "tokens", &batch.tokens).unwrap();
            sess.set(&rt, "loss_mask", &batch.loss_mask).unwrap();
            let out = sess.run(&rt).unwrap();
            losses.push(out.get("loss").unwrap().f32s()[0]);
        }
        assert!(
            (losses[0] - losses[1]).abs() < 1e-5,
            "step {step}: host {} vs device {}",
            losses[0],
            losses[1]
        );
    }
    let lnames = art.meta.name_list("lora_names");
    let sh = host.fetch_all(&rt, &lnames).unwrap();
    let sd = dev.fetch_all(&rt, &lnames).unwrap();
    for n in &lnames {
        let d = sh.get(n).unwrap().max_abs_diff(sd.get(n).unwrap());
        assert!(d < 1e-5, "{n}: host/device state diverged by {d}");
    }
}

#[test]
fn session_fetch_returns_stepped_not_initial_state() {
    // After N steps the session's slots hold the *threaded* state: the
    // trained factors and the adam moments every new.* / new_m.* output
    // rebinds onto — not the tensors uploaded at construction.
    let rt = runtime();
    let cfg = rt.load("sft_tiny").unwrap().meta.config.clone();
    let params = init_params(&cfg, 22);
    let lora = init_lora(&cfg, 23);
    let mut sess = TrainSession::new(&rt, "sft_tiny", &[&params, &lora]).unwrap();
    let (b, s) = (sess.batch_size(), sess.seq_len());
    let mut gen = loram::data::instruct::InstructGen::new(Dataset::Hermes, 6, 0);
    let tk = loram::tokenizer::Tokenizer::new();
    for _ in 0..3 {
        let seqs: Vec<Vec<i32>> = gen.batch_examples(b).iter().map(|e| e.tokens(&tk)).collect();
        let batch = make_batch(&seqs, b, s, true);
        sess.train_step(&batch, 1e-2).unwrap();
    }
    let lnames = sess.art.meta.name_list("lora_names");
    let state = sess.extract(&lnames).unwrap();
    // lora_b is initialised to zero; only stepped state can be non-zero
    let b_moved = lnames
        .iter()
        .filter(|n| n.ends_with("lora_b"))
        .any(|n| state.get(n).unwrap().l2_norm() > 0.0);
    assert!(b_moved, "extract returned the initial upload, not stepped state");
    // adam moments start zero-filled and only move via the new_m.* binding
    let mnames: Vec<String> = lnames.iter().map(|n| format!("adam_m.{n}")).collect();
    let moments = sess.extract(&mnames).unwrap();
    assert!(
        mnames.iter().any(|n| moments.get(n).unwrap().l2_norm() > 0.0),
        "optimiser moments never rebound onto their slots"
    );
}

#[test]
fn server_admits_new_request_mid_decode() {
    // Continuous batching with the real generator: a request enqueued
    // behind a full batch starts decoding as soon as any row frees, while
    // earlier requests are still in flight — and mixed sampling configs
    // share one batch.
    let rt = runtime();
    let cfg = rt.load("logits_tiny").unwrap().meta.config.clone();
    let params = init_params(&cfg, 24);
    let lora = init_lora(&cfg, 25);
    let gen = Generator::new(&rt, "logits_tiny", &[&params, &lora]).unwrap();
    let b = gen.batch_size();
    let mut srv = Server::new(gen, 3);
    for i in 0..b {
        // staggered budgets so rows free up one at a time
        srv.enqueue(
            format!("Q: {i}+{i}="),
            SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 3 * (i + 1) },
        );
    }
    let late = srv.enqueue(
        "Q: 1+1=",
        SampleCfg { temperature: 0.8, top_p: 0.9, max_new: 2 },
    );
    let mut responses = vec![];
    let mut admitted_mid_decode = false;
    while srv.pending() > 0 || srv.in_flight() > 0 {
        responses.extend(srv.step().unwrap());
        let late_done = responses.iter().any(|r| r.id == late);
        if srv.pending() == 0 && !late_done && srv.in_flight() > 1 {
            // the late request is decoding alongside still-running
            // earlier requests
            admitted_mid_decode = true;
        }
    }
    assert_eq!(responses.len(), b + 1);
    assert_eq!(srv.stats.served, b + 1);
    let late_pos = responses.iter().position(|r| r.id == late).unwrap();
    assert!(
        admitted_mid_decode || late_pos < responses.len() - 1,
        "late request waited for the whole previous batch (head-of-line blocking)"
    );
    assert!(srv.stats.mean_ttft_ms() >= 0.0);
    assert!(srv.stats.tokens_per_sec() > 0.0);
}

const DECODE_ARTS: &[&str] = &["logits_tiny", "decode_prefill_tiny", "decode_step_tiny"];

#[test]
fn kvcache_and_reforward_greedy_streams_match() {
    // The acceptance contract of the kv decode subsystem: greedy decode
    // over the same prompts yields the *identical* token stream whether
    // each step reforwards the full (B, S) grid or runs the (B, 1)
    // incremental step over donated caches.
    let Some(rt) = try_runtime(DECODE_ARTS) else { return };
    let cfg = rt.load("logits_tiny").unwrap().meta.config.clone();
    let params = init_params(&cfg, 30);
    let lora = init_lora(&cfg, 31);
    let greedy = SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 6 };
    let prompts = vec!["Q: 2+3=".to_string(), "The quick brown fox".to_string()];
    let mut outs = vec![];
    for path in [DecodePath::Reforward, DecodePath::KvCache] {
        let gen =
            Generator::with_path(&rt, "logits_tiny", &[&params, &lora], Some(path)).unwrap();
        assert_eq!(gen.decode_path(), path);
        let mut rng = Rng::new(0);
        outs.push(gen.generate_batch(&prompts, greedy, &mut rng).unwrap());
    }
    assert_eq!(
        outs[0], outs[1],
        "kv-cache decode diverged from the full-reforward stream"
    );
}

#[test]
fn kvcache_row_recycling_does_not_leak_stale_cache() {
    // `take` then `prefill` reuses the same batch row; the recycled row's
    // output must match a fresh generator's output for the same prompt —
    // i.e. no K/V from the previous occupant may survive admission.
    let Some(rt) = try_runtime(DECODE_ARTS) else { return };
    let cfg = rt.load("logits_tiny").unwrap().meta.config.clone();
    let params = init_params(&cfg, 32);
    let lora = init_lora(&cfg, 33);
    let greedy = SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 5 };
    let kv = Some(DecodePath::KvCache);
    let gen = Generator::with_path(&rt, "logits_tiny", &[&params, &lora], kv).unwrap();
    let mut rng = Rng::new(1);
    // first occupant of row 0: a long, distinctive prompt
    let first = gen
        .generate_batch(&["AAAAAAAA BBBB CCCC DDDD".to_string()], greedy, &mut rng)
        .unwrap();
    // recycle row 0 for a different prompt
    let reused = gen
        .generate_batch(&["Q: 2+3=".to_string()], greedy, &mut rng)
        .unwrap();
    // reference: the same prompt through a never-used generator
    let fresh_gen = Generator::with_path(&rt, "logits_tiny", &[&params, &lora], kv).unwrap();
    let fresh = fresh_gen
        .generate_batch(&["Q: 2+3=".to_string()], greedy, &mut rng)
        .unwrap();
    assert_eq!(reused, fresh, "stale cache leaked into the recycled row");
    let _ = first;
}

#[test]
fn kvcache_serves_mixed_configs_through_scheduler() {
    // continuous batching over the kv path: mid-decode admission triggers
    // a prefill into a freed row while other rows keep their caches
    let Some(rt) = try_runtime(DECODE_ARTS) else { return };
    let cfg = rt.load("logits_tiny").unwrap().meta.config.clone();
    let params = init_params(&cfg, 34);
    let lora = init_lora(&cfg, 35);
    let gen = Generator::with_path(
        &rt,
        "logits_tiny",
        &[&params, &lora],
        Some(DecodePath::KvCache),
    )
    .unwrap();
    let b = gen.batch_size();
    let mut srv = Server::new(gen, 3);
    for i in 0..b + 2 {
        srv.enqueue(
            format!("Q: {i}+{i}="),
            SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 2 + i % 3 },
        );
    }
    let rs = srv.drain().unwrap();
    assert_eq!(rs.len(), b + 2);
    assert_eq!(srv.stats.served, b + 2);
    assert!(srv.stats.peak_queue_depth >= 2, "overflow requests queued");
    assert!(srv.stats.mean_queue_wait_ms() >= 0.0);
}

const CHUNK_ARTS: &[&str] = &[
    "logits_tiny",
    "decode_prefill_tiny",
    "decode_step_tiny",
    "decode_prefill_chunk_tiny_c16",
    "decode_prefill_chunk_tiny_c32",
];

/// The §2e acceptance contract, end to end: admission through the chunk
/// ladder produces greedy streams byte-identical to the monolithic
/// pad-to-S prefill — across short (sub-bucket), bucket-exact and
/// near-grid prompts — while processing fewer padded window tokens.
#[test]
fn chunked_and_monolithic_admission_greedy_streams_match() {
    let Some(rt) = try_runtime(CHUNK_ARTS) else { return };
    let cfg = rt.load("logits_tiny").unwrap().meta.config.clone();
    let params = init_params(&cfg, 50);
    let lora = init_lora(&cfg, 51);
    let greedy = SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 5 };
    let prompts = vec![
        "Q: 2+3=".to_string(),                       // sub-bucket
        "ABCDEFGHIJKLMN".to_string(),                // bucket-exact (16 ids)
        "The quick brown fox jumps over".to_string(), // near-grid
    ];
    let mut outs = vec![];
    let mut padded = vec![];
    for chunked in [false, true] {
        let gen = Generator::with_path(
            &rt,
            "logits_tiny",
            &[&params, &lora],
            Some(DecodePath::KvCache),
        )
        .unwrap();
        gen.set_chunked_prefill(chunked).unwrap();
        assert_eq!(gen.chunked_prefill(), chunked);
        let mut rng = Rng::new(0);
        // one prompt per call so each admission exercises its own shape
        let mut streams = vec![];
        for p in &prompts {
            streams.push(
                gen.generate_batch(&[p.clone()], greedy, &mut rng).unwrap().remove(0),
            );
        }
        outs.push(streams);
        padded.push(gen.prefill_stats().padded_prefill_tokens);
    }
    assert_eq!(outs[0], outs[1], "chunked admission diverged from pad-to-S");
    assert!(
        padded[1] < padded[0],
        "chunked admission padded {} tokens, monolithic {}",
        padded[1],
        padded[0]
    );
}

/// Recycling a row under chunked admission: only prompt positions are
/// rewritten (unlike the monolithic full-row scatter), so stale K/V
/// beyond the new prompt must be provably masked out.
#[test]
fn chunked_admission_recycled_row_leaks_nothing() {
    let Some(rt) = try_runtime(CHUNK_ARTS) else { return };
    let cfg = rt.load("logits_tiny").unwrap().meta.config.clone();
    let params = init_params(&cfg, 52);
    let lora = init_lora(&cfg, 53);
    let greedy = SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 5 };
    let kv = Some(DecodePath::KvCache);
    let gen = Generator::with_path(&rt, "logits_tiny", &[&params, &lora], kv).unwrap();
    gen.set_chunked_prefill(true).unwrap();
    let mut rng = Rng::new(1);
    // first occupant: a long prompt filling most of the row
    let _long = gen
        .generate_batch(&["AAAAAAAA BBBB CCCC DDDD".to_string()], greedy, &mut rng)
        .unwrap();
    // recycle with a *short* prompt: positions past it keep stale K/V
    let reused = gen
        .generate_batch(&["Q: 2+3=".to_string()], greedy, &mut rng)
        .unwrap();
    let fresh_gen = Generator::with_path(&rt, "logits_tiny", &[&params, &lora], kv).unwrap();
    fresh_gen.set_chunked_prefill(true).unwrap();
    let fresh = fresh_gen
        .generate_batch(&["Q: 2+3=".to_string()], greedy, &mut rng)
        .unwrap();
    assert_eq!(reused, fresh, "stale cache leaked into the chunk-admitted row");
}

/// Token-budget pacing through the real scheduler: budgeted chunked
/// admission serves the same greedy responses as instant admission, and
/// the accounting stays consistent.
#[test]
fn token_budget_scheduler_matches_unpaced_serving_on_kv_path() {
    let Some(rt) = try_runtime(CHUNK_ARTS) else { return };
    let cfg = rt.load("logits_tiny").unwrap().meta.config.clone();
    let params = init_params(&cfg, 54);
    let lora = init_lora(&cfg, 55);
    let mut texts = vec![];
    for budget in [None, Some(8)] {
        let gen = Generator::with_path(
            &rt,
            "logits_tiny",
            &[&params, &lora],
            Some(DecodePath::KvCache),
        )
        .unwrap();
        gen.set_chunked_prefill(true).unwrap();
        let b = gen.batch_size();
        let mut srv = Server::new(gen, 3);
        srv.set_prefill_budget(budget);
        for i in 0..b + 2 {
            srv.enqueue(
                format!("Q: {i}+{i}="),
                SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 2 + i % 3 },
            );
        }
        let mut rs = srv.drain().unwrap();
        assert_eq!(rs.len(), b + 2);
        assert_eq!(srv.stats.served, b + 2);
        assert_eq!(srv.stats.admitted, b + 2);
        assert_eq!(srv.in_flight(), 0);
        assert!(srv.stats.ticks >= srv.stats.decode_steps);
        rs.sort_by_key(|r| r.id);
        texts.push(rs.into_iter().map(|r| r.text).collect::<Vec<_>>());
    }
    assert_eq!(texts[0], texts[1], "budget pacing changed a served stream");
}

const PAGED_ARTS: &[&str] = &[
    "logits_tiny",
    "decode_prefill_paged_tiny",
    "decode_step_paged_tiny",
    "decode_prefill_chunk_paged_tiny_c16",
    "decode_prefill_chunk_paged_tiny_c32",
];

/// The §2f acceptance contract, end to end: greedy decode over the real
/// PJRT runtime is byte-identical whether the caches are the dense
/// (B, S) grid or the pooled block tensors behind per-row block tables —
/// under both monolithic and chunk-ladder admission.
#[test]
fn paged_and_dense_greedy_streams_match_on_kv_path() {
    let mut needed: Vec<&str> = DECODE_ARTS.to_vec();
    needed.extend_from_slice(&PAGED_ARTS[1..]);
    needed.extend_from_slice(&["decode_prefill_chunk_tiny_c16", "decode_prefill_chunk_tiny_c32"]);
    let Some(rt) = try_runtime(&needed) else { return };
    let cfg = rt.load("logits_tiny").unwrap().meta.config.clone();
    let params = init_params(&cfg, 60);
    let lora = init_lora(&cfg, 61);
    let greedy = SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 6 };
    let prompts = vec!["Q: 2+3=".to_string(), "The quick brown fox".to_string()];
    let mut outs = vec![];
    for paged in [false, true] {
        for chunked in [false, true] {
            let gen = Generator::with_path_paged(
                &rt,
                "logits_tiny",
                &[&params, &lora],
                Some(DecodePath::KvCache),
                paged,
            )
            .unwrap();
            assert_eq!(gen.paged(), paged);
            gen.set_chunked_prefill(chunked).unwrap();
            let mut rng = Rng::new(0);
            outs.push((paged, chunked, gen.generate_batch(&prompts, greedy, &mut rng).unwrap()));
        }
    }
    for (paged, chunked, out) in &outs[1..] {
        assert_eq!(
            out, &outs[0].2,
            "paged={paged} chunked={chunked} diverged from the dense monolithic stream"
        );
    }
}

/// Shared-prefix reuse through the real artifacts: the second admission
/// of a prompt sharing a long prefix maps the resident blocks in by
/// reference (prefix-index hit, fewer prefill window tokens) and still
/// emits the same greedy stream as a dense decoder.
#[test]
fn paged_shared_prefix_reuse_skips_prefill_and_matches_dense() {
    let mut needed: Vec<&str> = DECODE_ARTS.to_vec();
    needed.extend_from_slice(&PAGED_ARTS[1..]);
    needed.extend_from_slice(&["decode_prefill_chunk_tiny_c16", "decode_prefill_chunk_tiny_c32"]);
    let Some(rt) = try_runtime(&needed) else { return };
    let cfg = rt.load("logits_tiny").unwrap().meta.config.clone();
    let params = init_params(&cfg, 62);
    let lora = init_lora(&cfg, 63);
    let greedy = SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 5 };
    // >= 2 full 8-token blocks of shared prefix, distinct tails
    let prompts =
        vec!["The quick brown fox jumps".to_string(), "The quick brown fox sleeps".to_string()];
    let mut streams = vec![];
    let mut window_tokens = vec![];
    for paged in [false, true] {
        let gen = Generator::with_path_paged(
            &rt,
            "logits_tiny",
            &[&params, &lora],
            Some(DecodePath::KvCache),
            paged,
        )
        .unwrap();
        gen.set_chunked_prefill(true).unwrap();
        let mut rng = Rng::new(0);
        // sequential single-row admissions, so the second can hit the
        // prefix the first registered
        let mut out = vec![];
        for p in &prompts {
            out.push(gen.generate_batch(&[p.clone()], greedy, &mut rng).unwrap().remove(0));
        }
        streams.push(out);
        window_tokens.push(gen.prefill_stats().prefill_tokens);
        if paged {
            let pg = gen.paged_stats().expect("paged generator exposes stats");
            assert!(pg.prefix_hits >= 1, "second admission missed the resident prefix");
            assert!(pg.prefix_hit_tokens >= 16, "hit reused {} tokens", pg.prefix_hit_tokens);
            assert_eq!(pg.cow_copies, 0, "serving flow must never fork a shared block");
        } else {
            assert!(gen.paged_stats().is_none());
        }
    }
    assert_eq!(streams[0], streams[1], "prefix reuse changed a greedy stream");
    assert!(
        window_tokens[1] < window_tokens[0],
        "paged prefill fed {} window tokens, dense {}",
        window_tokens[1],
        window_tokens[0]
    );
}

const SPEC_ARTS: &[&str] = &[
    "logits_tiny",
    "decode_prefill_tiny",
    "decode_step_tiny",
    "decode_verify_tiny",
    "eval_tiny_p50",
    "decode_prefill_tiny_p50",
    "decode_step_tiny_p50",
];

const SPEC_PRUNED_ARTS: &[&str] = &[
    "logits_tiny_p50",
    "decode_prefill_tiny_p50",
    "decode_step_tiny_p50",
    "decode_verify_tiny_p50",
];

/// Drafter weights for speculative tests: the shared stand-in (base
/// params sliced under a random plan + fresh factors) — close enough to
/// the target for some drafts to be accepted, different enough for
/// rejections.
fn sliced_drafter(
    rt: &Runtime,
    full_cfg: &loram::runtime::ModelCfg,
    params: &TensorStore,
) -> (TensorStore, TensorStore) {
    loram::coordinator::speculative::sliced_drafter_standin(
        rt, full_cfg, params, "tiny_p50", 0,
    )
    .unwrap()
}

/// The headline equivalence matrix (ISSUE 4): greedy decoding emits
/// byte-identical token streams on ALL THREE paths — full reforward,
/// kv-cache, and speculative with the pruned proxy drafting.
#[test]
fn reforward_kvcache_and_speculative_greedy_streams_match() {
    let Some(rt) = try_runtime(SPEC_ARTS) else { return };
    let cfg = rt.load("logits_tiny").unwrap().meta.config.clone();
    let params = init_params(&cfg, 36);
    let lora = init_lora(&cfg, 37);
    let (dparams, dlora) = sliced_drafter(&rt, &cfg, &params);
    let greedy = SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 8 };
    let prompts = vec!["Q: 2+3=".to_string(), "The quick brown fox".to_string()];
    let mut outs = vec![];
    for path in [DecodePath::Reforward, DecodePath::KvCache, DecodePath::Speculative] {
        let gen = match path {
            DecodePath::Speculative => Generator::with_speculative(
                &rt,
                "logits_tiny",
                &[&params, &lora],
                "tiny_p50",
                &[&dparams, &dlora],
            )
            .unwrap(),
            other => {
                Generator::with_path(&rt, "logits_tiny", &[&params, &lora], Some(other)).unwrap()
            }
        };
        assert_eq!(gen.decode_path(), path);
        let mut rng = Rng::new(0);
        outs.push((path, gen.generate_batch(&prompts, greedy, &mut rng).unwrap()));
    }
    for (path, out) in &outs[1..] {
        assert_eq!(
            out, &outs[0].1,
            "{path:?} greedy stream diverged from the reforward stream"
        );
    }
}

/// The chunked-admission equivalence matrix (ISSUE 5): with admissions
/// routed through the bucket ladder, greedy streams stay byte-identical
/// across ALL THREE decode paths — reforward (no caches, the reference),
/// kv-cache and speculative (target *and* drafter admit chunked).
#[test]
fn chunked_admission_matches_across_reforward_kvcache_and_speculative() {
    let mut needed: Vec<&str> = SPEC_ARTS.to_vec();
    needed.extend_from_slice(&[
        "decode_prefill_chunk_tiny_c16",
        "decode_prefill_chunk_tiny_c32",
        "decode_prefill_chunk_tiny_p50_c16",
        "decode_prefill_chunk_tiny_p50_c32",
    ]);
    let Some(rt) = try_runtime(&needed) else { return };
    let cfg = rt.load("logits_tiny").unwrap().meta.config.clone();
    let params = init_params(&cfg, 56);
    let lora = init_lora(&cfg, 57);
    let (dparams, dlora) = sliced_drafter(&rt, &cfg, &params);
    let greedy = SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 8 };
    let prompts = vec!["Q: 2+3=".to_string(), "The quick brown fox".to_string()];
    let mut outs = vec![];
    for path in [DecodePath::Reforward, DecodePath::KvCache, DecodePath::Speculative] {
        let gen = match path {
            DecodePath::Speculative => Generator::with_speculative(
                &rt,
                "logits_tiny",
                &[&params, &lora],
                "tiny_p50",
                &[&dparams, &dlora],
            )
            .unwrap(),
            other => {
                Generator::with_path(&rt, "logits_tiny", &[&params, &lora], Some(other)).unwrap()
            }
        };
        if path != DecodePath::Reforward {
            gen.set_chunked_prefill(true).unwrap();
            assert!(gen.chunked_prefill());
        }
        let mut rng = Rng::new(0);
        outs.push((path, gen.generate_batch(&prompts, greedy, &mut rng).unwrap()));
    }
    for (path, out) in &outs[1..] {
        assert_eq!(
            out, &outs[0].1,
            "{path:?} with chunked admission diverged from the reforward stream"
        );
    }
}

/// Speculative decoding over pooled block caches: the target verifies
/// through the paged trio (rejection rewinds stay logical — block tables
/// untouched), the drafter falls back to its dense pair (no paged family
/// is emitted for tiny_p50), and the greedy stream still matches the
/// dense speculative stream byte-for-byte.
#[test]
fn paged_speculative_greedy_stream_matches_dense() {
    let mut needed: Vec<&str> = SPEC_ARTS.to_vec();
    needed.extend_from_slice(&PAGED_ARTS[1..]);
    needed.push("decode_verify_paged_tiny");
    let Some(rt) = try_runtime(&needed) else { return };
    let cfg = rt.load("logits_tiny").unwrap().meta.config.clone();
    let params = init_params(&cfg, 64);
    let lora = init_lora(&cfg, 65);
    let (dparams, dlora) = sliced_drafter(&rt, &cfg, &params);
    let greedy = SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 8 };
    let prompts = vec!["Q: 2+3=".to_string(), "The quick brown fox".to_string()];
    let mut outs = vec![];
    for paged in [false, true] {
        let gen = Generator::with_speculative_paged(
            &rt,
            "logits_tiny",
            &[&params, &lora],
            "tiny_p50",
            &[&dparams, &dlora],
            paged,
        )
        .unwrap();
        assert_eq!(gen.decode_path(), DecodePath::Speculative);
        assert_eq!(gen.paged(), paged);
        let mut rng = Rng::new(0);
        outs.push(gen.generate_batch(&prompts, greedy, &mut rng).unwrap());
        if paged {
            let pg = gen.paged_stats().expect("paged target exposes stats");
            assert_eq!(pg.cow_copies, 0, "rewinds must stay logical, never fork");
        }
    }
    assert_eq!(outs[0], outs[1], "paged speculative diverged from the dense stream");
}

/// The pruned-tiny pair as *target*: the pruned proxy self-drafts, and
/// all three paths again agree byte-for-byte.
#[test]
fn speculative_self_drafting_on_pruned_target_matches_other_paths() {
    let Some(rt) = try_runtime(SPEC_PRUNED_ARTS) else { return };
    let cfg = rt.load("logits_tiny_p50").unwrap().meta.config.clone();
    let params = init_params(&cfg, 38);
    let lora = init_lora(&cfg, 39);
    let greedy = SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 7 };
    let prompts = vec!["Once upon a time".to_string(), "Q: 4+4=".to_string()];
    let mut outs = vec![];
    for spec in [false, true] {
        let gen = if spec {
            // self-speculative: the same weights draft and verify, so
            // every draft is accepted — the maximal-acceptance corner
            Generator::with_speculative(
                &rt,
                "logits_tiny_p50",
                &[&params, &lora],
                "tiny_p50",
                &[&params, &lora],
            )
            .unwrap()
        } else {
            Generator::with_path(&rt, "logits_tiny_p50", &[&params, &lora], Some(DecodePath::KvCache))
                .unwrap()
        };
        let mut rng = Rng::new(0);
        outs.push(gen.generate_batch(&prompts, greedy, &mut rng).unwrap());
        if spec {
            let st = gen.spec_stats().unwrap();
            assert!(st.drafted_tokens > 0, "self-drafting proposed nothing");
            assert!(
                st.accepted_tokens > 0,
                "self-drafting must accept its own drafts"
            );
        }
    }
    assert_eq!(outs[0], outs[1], "self-speculative stream diverged");
}

/// Row recycling on the speculative path: rejected drafts leave garbage
/// K/V beyond the frontier; a recycled row must decode exactly like a
/// fresh generator's row (the e2e rewind-safety test).
#[test]
fn speculative_row_recycling_after_rejections_leaks_nothing() {
    let Some(rt) = try_runtime(SPEC_ARTS) else { return };
    let cfg = rt.load("logits_tiny").unwrap().meta.config.clone();
    let params = init_params(&cfg, 40);
    let lora = init_lora(&cfg, 41);
    let (dparams, dlora) = sliced_drafter(&rt, &cfg, &params);
    let greedy = SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 6 };
    let mk = || {
        Generator::with_speculative(
            &rt,
            "logits_tiny",
            &[&params, &lora],
            "tiny_p50",
            &[&dparams, &dlora],
        )
        .unwrap()
    };
    let gen = mk();
    let mut rng = Rng::new(1);
    let _first = gen
        .generate_batch(&["AAAAAAAA BBBB CCCC DDDD".to_string()], greedy, &mut rng)
        .unwrap();
    let reused = gen
        .generate_batch(&["Q: 2+3=".to_string()], greedy, &mut rng)
        .unwrap();
    let fresh = mk()
        .generate_batch(&["Q: 2+3=".to_string()], greedy, &mut rng)
        .unwrap();
    assert_eq!(reused, fresh, "stale speculative cache leaked into the recycled row");
}

/// The scheduler over the real speculative engine: mixed greedy/sampled
/// configs share the batch, stats surface acceptance, nothing leaks.
#[test]
fn speculative_serves_mixed_configs_through_scheduler() {
    let Some(rt) = try_runtime(SPEC_ARTS) else { return };
    let cfg = rt.load("logits_tiny").unwrap().meta.config.clone();
    let params = init_params(&cfg, 42);
    let lora = init_lora(&cfg, 43);
    let (dparams, dlora) = sliced_drafter(&rt, &cfg, &params);
    let gen = Generator::with_speculative(
        &rt,
        "logits_tiny",
        &[&params, &lora],
        "tiny_p50",
        &[&dparams, &dlora],
    )
    .unwrap();
    let b = gen.batch_size();
    let mut srv = Server::new(gen, 3);
    for i in 0..b + 2 {
        // alternate greedy and sampled rows: sampled rows must degrade to
        // per-token decode inside the same batched verify call
        srv.enqueue(
            format!("Q: {i}+{i}="),
            SampleCfg {
                temperature: if i % 2 == 0 { 0.0 } else { 0.7 },
                top_p: 0.9,
                max_new: 2 + i % 3,
            },
        );
    }
    let rs = srv.drain().unwrap();
    assert_eq!(rs.len(), b + 2);
    assert_eq!(srv.stats.served, b + 2);
    let spec = srv.stats.spec.expect("speculative engine reports counters");
    assert!(spec.verify_steps > 0);
    // the server's event-level accepted count can only trail the
    // engine's (an EOS inside a verified window truncates the events)
    assert!(srv.stats.accepted_tokens <= spec.accepted_tokens);
    assert!(srv.stats.accepted_tokens <= srv.stats.total_tokens);
    assert_eq!(srv.in_flight(), 0);
}

const ADAPTER_ARTS: &[&str] = &[
    "logits_tiny",
    "logits_tiny_a3",
    "decode_prefill_tiny_a3",
    "decode_step_tiny_a3",
];

/// `n` distinct adapters with non-trivial `b` factors (zero-b adapters
/// would all collapse onto the base model and prove nothing).
fn distinct_adapters(cfg: &loram::runtime::ModelCfg, n: usize) -> Vec<TensorStore> {
    (0..n)
        .map(|i| {
            let mut l = init_lora(cfg, 50 + i as u64);
            let mut rng = Rng::new(70 + i as u64);
            for (k, t) in l.map.iter_mut() {
                if k.ends_with("lora_b") {
                    *t = Tensor::from_f32(&t.shape, rng.normal_vec(t.len(), 0.05));
                }
            }
            l
        })
        .collect()
}

/// Offline merge W' = W + s·a@b — the per-adapter deployment reference.
fn merge_adapter(
    cfg: &loram::runtime::ModelCfg,
    params: &TensorStore,
    lora: &TensorStore,
) -> TensorStore {
    let scale = (cfg.lora_alpha / cfg.lora_rank as f64) as f32;
    let mut merged = params.clone();
    let mut names: Vec<String> = (0..cfg.n_layers)
        .flat_map(|i| {
            cfg.layer_proj_shapes(i)
                .into_iter()
                .map(move |(p, _)| format!("l{i}.{p}"))
        })
        .collect();
    names.push("lm_head".to_string());
    for nm in names {
        let a = lora.get(&format!("{nm}.lora_a")).unwrap();
        let b = lora.get(&format!("{nm}.lora_b")).unwrap();
        let delta = loram::coordinator::analysis::lora_delta(a, b);
        let w = merged.map.get_mut(&nm).unwrap();
        for (x, d) in w.f32s_mut().iter_mut().zip(delta.f32s()) {
            *x += scale * d;
        }
    }
    merged
}

/// The tentpole acceptance: a mixed batch with 3 distinct adapters serves
/// through ONE compiled artifact on BOTH decode paths, and each request's
/// greedy stream equals the offline per-adapter merge of its adapter.
#[test]
fn stacked_adapter_mixed_batch_matches_offline_merge_on_both_paths() {
    let Some(rt) = try_runtime(ADAPTER_ARTS) else { return };
    let cfg = rt.load("logits_tiny_a3").unwrap().meta.config.clone();
    let params = init_params(&cfg, 40);
    let adapters = distinct_adapters(&cfg, 3);
    let greedy = SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 5 };
    let prompts = ["Q: 2+3=", "The quick brown fox", "Once upon a time"];
    // per-adapter reference: merge adapter i into the base, decode prompt
    // i through the plain (single-LoRA) artifact with zero LoRA
    let zero = init_lora(&cfg, 0);
    let refs: Vec<Vec<i32>> = prompts
        .iter()
        .zip(&adapters)
        .map(|(p, ad)| {
            let merged = merge_adapter(&cfg, &params, ad);
            let gen = Generator::with_path(
                &rt,
                "logits_tiny",
                &[&merged, &zero],
                Some(DecodePath::Reforward),
            )
            .unwrap();
            let mut rng = Rng::new(0);
            gen.generate_batch(&[p.to_string()], greedy, &mut rng)
                .unwrap()
                .remove(0)
        })
        .collect();
    assert!(
        refs.iter().collect::<std::collections::HashSet<_>>().len() > 1,
        "adapters too weak to steer the streams apart — the test is vacuous"
    );
    for path in [DecodePath::Reforward, DecodePath::KvCache] {
        let gen =
            Generator::with_adapters(&rt, "logits_tiny_a3", &[&params], Some(path), None)
                .unwrap();
        assert_eq!(gen.decode_path(), path);
        assert_eq!(gen.adapter_capacity(), Some(3));
        let ids: Vec<AdapterId> = adapters
            .iter()
            .enumerate()
            .map(|(i, w)| gen.register_adapter(&format!("task{i}"), w.clone()).unwrap())
            .collect();
        let reqs: Vec<(String, AdapterId)> = prompts
            .iter()
            .zip(&ids)
            .map(|(p, id)| (p.to_string(), *id))
            .collect();
        let mut rng = Rng::new(0);
        let outs = gen.generate_adapter_batch(&reqs, greedy, &mut rng).unwrap();
        assert_eq!(
            outs, refs,
            "{path:?}: stacked-adapter streams diverged from offline merges"
        );
    }
}

/// Adapter lifecycle through the scheduler: per-request routing, lanes in
/// the stats, and ref-counted eviction (never under an in-flight row).
#[test]
fn adapter_server_routes_refcounts_and_evicts() {
    let Some(rt) = try_runtime(ADAPTER_ARTS) else { return };
    let cfg = rt.load("logits_tiny_a3").unwrap().meta.config.clone();
    let params = init_params(&cfg, 44);
    let adapters = distinct_adapters(&cfg, 3);
    let gen =
        Generator::with_adapters(&rt, "logits_tiny_a3", &[&params], None, None).unwrap();
    let ids: Vec<AdapterId> = adapters
        .iter()
        .enumerate()
        .map(|(i, w)| gen.register_adapter(&format!("task{i}"), w.clone()).unwrap())
        .collect();
    // a registered name resolves; a fourth registration exceeds capacity
    assert_eq!(gen.adapter_id("task1"), Some(ids[1]));
    assert!(gen
        .register_adapter("overflow", adapters[0].clone())
        .is_err());
    // rows in flight pin their adapter: evict must fail mid-decode
    let row = gen
        .prefill_adapter("Q: 1+1=", SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 3 }, Some(ids[0]))
        .unwrap();
    assert!(gen.evict_adapter(ids[0]).is_err(), "evicted a pinned adapter");
    let mut rng = Rng::new(0);
    while !gen.decode_step(&mut rng).unwrap().is_empty() {}
    gen.take(row).unwrap();
    gen.evict_adapter(ids[0]).unwrap();
    // the freed slot admits a replacement, servable immediately — under a
    // fresh handle, so the evicted id cannot route to the newcomer
    let repl = gen.register_adapter("task0b", adapters[0].clone()).unwrap();
    assert_eq!(repl.ix(), ids[0].ix());
    assert_ne!(repl, ids[0]);
    // mixed-adapter traffic through the continuous-batching scheduler
    let mut srv = Server::new(gen, 5);
    let route = [repl, ids[1], ids[2]];
    for i in 0..6 {
        srv.enqueue_adapter(
            format!("Q: {i}+{i}="),
            SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 2 + i % 2 },
            Some(route[i % 3]),
        );
    }
    let rs = srv.drain().unwrap();
    assert_eq!(rs.len(), 6);
    assert_eq!(srv.stats.per_adapter.len(), 3);
    for id in route {
        let lane = &srv.stats.per_adapter[&Some(id)];
        assert_eq!(lane.requests, 2);
        assert_eq!(lane.served, 2);
        assert!(lane.tokens >= 2);
    }
    let lane_tokens: usize = srv.stats.per_adapter.values().map(|l| l.tokens).sum();
    assert_eq!(lane_tokens, srv.stats.total_tokens);
}

/// The training→serving handoff: a pipeline-exported adapter loads from
/// its `.lmck` through the AdapterStore and serves through the stacked
/// artifact exactly like its in-memory twin.
#[test]
fn adapter_export_roundtrips_through_disk_store() {
    let Some(rt) = try_runtime(ADAPTER_ARTS) else { return };
    let cfg = rt.load("logits_tiny_a3").unwrap().meta.config.clone();
    let params = init_params(&cfg, 46);
    let adapters = distinct_adapters(&cfg, 1);
    let dir = tmp_runs().join("adapters");
    std::fs::create_dir_all(&dir).unwrap();
    AdapterStore::save(&dir, "exported", &adapters[0]).unwrap();
    assert_eq!(AdapterStore::list(&dir).unwrap(), vec!["exported"]);
    let greedy = SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 4 };
    let mut outs = vec![];
    for from_disk in [false, true] {
        let gen = Generator::with_adapters(
            &rt,
            "logits_tiny_a3",
            &[&params],
            Some(DecodePath::Reforward),
            Some(dir.clone()),
        )
        .unwrap();
        let id = if from_disk {
            gen.register_adapter_from_disk("exported").unwrap()
        } else {
            gen.register_adapter("exported", adapters[0].clone()).unwrap()
        };
        let mut rng = Rng::new(0);
        outs.push(
            gen.generate_adapter_batch(&[("Q: 2+3=".to_string(), id)], greedy, &mut rng)
                .unwrap(),
        );
    }
    assert_eq!(outs[0], outs[1], "disk-loaded adapter diverged from in-memory");
}

#[test]
fn merge_equivalence_recovered_lora_on_full_model() {
    // Eq. 6/7: evaluating the full model with recovered LoRA must equal
    // evaluating with factors manually merged into W0 (within f32 noise).
    let rt = runtime();
    let cfg = rt.load("eval_tiny").unwrap().meta.config.clone();
    let params = init_params(&cfg, 13);
    let mut lora = init_lora(&cfg, 14);
    let mut rng = Rng::new(15);
    for (k, t) in lora.map.iter_mut() {
        if k.ends_with("lora_b") {
            *t = Tensor::from_f32(&t.shape, rng.normal_vec(t.len(), 0.02));
        }
    }
    // manual merge: W' = W + scale * a@b
    let scale = (cfg.lora_alpha / cfg.lora_rank as f64) as f32;
    let mut merged = params.clone();
    for i in 0..cfg.n_layers {
        for (proj, _) in cfg.layer_proj_shapes(i) {
            let nm = format!("l{i}.{proj}");
            let a = lora.get(&format!("{nm}.lora_a")).unwrap();
            let b = lora.get(&format!("{nm}.lora_b")).unwrap();
            let delta = loram::coordinator::analysis::lora_delta(a, b);
            let w = merged.map.get_mut(&nm).unwrap();
            for (x, d) in w.f32s_mut().iter_mut().zip(delta.f32s()) {
                *x += scale * d;
            }
        }
    }
    let a = lora.get("lm_head.lora_a").unwrap();
    let b = lora.get("lm_head.lora_b").unwrap();
    let delta = loram::coordinator::analysis::lora_delta(a, b);
    {
        let w = merged.map.get_mut("lm_head").unwrap();
        for (x, d) in w.f32s_mut().iter_mut().zip(delta.f32s()) {
            *x += scale * d;
        }
    }
    let zero = init_lora(&cfg, 0);
    let seqs = test_sequences(Dataset::Alpaca, 1, 4);
    let p_fused = Evaluator::new(&rt, "eval_tiny", &[&params, &lora])
        .unwrap()
        .perplexity(&seqs, true)
        .unwrap();
    let p_merged = Evaluator::new(&rt, "eval_tiny", &[&merged, &zero])
        .unwrap()
        .perplexity(&seqs, true)
        .unwrap();
    assert!(
        (p_fused - p_merged).abs() / p_merged < 1e-3,
        "fused {p_fused} merged {p_merged}"
    );
}

#[test]
fn slo_preemption_on_kv_path_streams_byte_identical() {
    // ISSUE 9 acceptance on the real kv-cache engine: a Low-priority row
    // preempted for a High arrival (evict -> requeue -> re-prefill) must
    // stream byte-identically to the same request in an unpreempted run.
    let Some(rt) = try_runtime(DECODE_ARTS) else { return };
    let cfg = rt.load("logits_tiny").unwrap().meta.config.clone();
    let params = init_params(&cfg, 36);
    let lora = init_lora(&cfg, 37);
    let kv = Some(DecodePath::KvCache);
    let greedy = |max_new| SampleCfg { temperature: 0.0, top_p: 1.0, max_new };
    let run = |with_vip: bool| -> (Vec<(u64, String)>, usize, usize) {
        let gen =
            Generator::with_path(&rt, "logits_tiny", &[&params, &lora], kv).unwrap();
        let b = gen.batch_size();
        let mut srv = Server::new(gen, 5);
        srv.set_slo(true);
        for i in 0..b {
            srv.enqueue_slo(format!("Q: {i}+{i}="), greedy(6), None, Priority::Low, None);
        }
        srv.step().unwrap(); // grid full, every Low holds a row
        srv.step().unwrap();
        if with_vip {
            srv.enqueue_slo("Q: 9+9=", greedy(2), None, Priority::High, None);
        }
        let rs = srv.drain().unwrap();
        let mut texts: Vec<(u64, String)> =
            rs.into_iter().map(|r| (r.id, r.text)).collect();
        texts.sort();
        (texts, srv.stats.preempted, b)
    };
    let (reference, p0, b) = run(false);
    let (preempted, p1, _) = run(true);
    assert_eq!(p0, 0, "the reference run must not preempt");
    assert_eq!(p1, 1, "full grid + High arrival must preempt one row");
    assert_eq!(preempted.len(), b + 1);
    // every Low stream — including the evicted-and-rerun victim — is
    // byte-identical to the unpreempted run
    let lows: Vec<(u64, String)> =
        preempted.into_iter().filter(|(id, _)| *id < b as u64).collect();
    assert_eq!(lows, reference, "preempted stream diverged after re-prefill");
}

#[test]
fn slo_deadline_cancellation_with_real_engine() {
    // A queued request whose deadline expires behind a full grid is
    // cancelled — never admitted, never decoded — while everything
    // in flight finishes untouched.
    let Some(rt) = try_runtime(&["logits_tiny"]) else { return };
    let cfg = rt.load("logits_tiny").unwrap().meta.config.clone();
    let params = init_params(&cfg, 38);
    let lora = init_lora(&cfg, 39);
    let gen = Generator::new(&rt, "logits_tiny", &[&params, &lora]).unwrap();
    let b = gen.batch_size();
    let mut srv = Server::new(gen, 3);
    srv.set_slo(true);
    let greedy = SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 6 };
    for i in 0..b {
        srv.enqueue_slo(format!("Q: {i}+1="), greedy, None, Priority::Normal, None);
    }
    let doomed = srv.enqueue_slo("Q: late=", greedy, None, Priority::Normal, Some(1));
    let responses = srv.drain().unwrap();
    assert_eq!(srv.stats.cancelled, 1, "the expired request must cancel");
    assert!(responses.iter().all(|r| r.id != doomed));
    assert_eq!(responses.len(), b);
    assert_eq!(srv.stats.served, b);
    assert_eq!(srv.stats.rejected, 0);
    assert_eq!(srv.stats.deadline_misses, 0, "in-flight rows had no deadlines");
}

#[test]
fn chaos_fault_storm_on_real_engine_resolves_every_request() {
    // §2j end-to-end on the PJRT decode path: the deterministic fault
    // storm through the real engine under bounded retry +
    // failure-domain isolation. Every enqueue must resolve as exactly
    // one response (or a pre-admission reject) — nothing lost silently
    // — and the survivors' streams are real decoded text.
    let Some(rt) = try_runtime(&["logits_tiny"]) else { return };
    let cfg = rt.load("logits_tiny").unwrap().meta.config.clone();
    let params = init_params(&cfg, 40);
    let lora = init_lora(&cfg, 41);
    let gen = Generator::new(&rt, "logits_tiny", &[&params, &lora]).unwrap();
    let chaos = ChaosEngine::new(gen, "fault-storm", 64, 9).unwrap();
    let mut srv = Server::new(chaos, 5);
    srv.set_retry_policy(Some(2), 1);
    let n = 12;
    let reqs = loram::workload::generate("faults", n, 9).unwrap();
    let rs = loram::workload::run(&mut srv, &reqs).unwrap();
    assert_eq!(
        rs.len() + srv.stats.rejected,
        n,
        "every enqueue must resolve: {} responses + {} rejects",
        rs.len(),
        srv.stats.rejected
    );
    assert!(srv.engine.injected > 0, "the storm must actually storm");
    let served = rs.iter().filter(|r| r.outcome == Outcome::Ok).count();
    let failed = rs.iter().filter(|r| r.outcome == Outcome::Failed).count();
    assert_eq!(served, srv.stats.served);
    assert_eq!(failed, srv.stats.failed);
    assert!(served > 0, "the storm must be survivable on the real engine");
    assert!(
        rs.iter().filter(|r| r.outcome == Outcome::Ok).all(|r| !r.text.is_empty()),
        "served responses carry real decoded text"
    );
}

#[test]
fn chaos_off_real_engine_is_byte_identical_to_plain_serving() {
    // §2j acceptance on the real engine: an armed-but-empty chaos plan
    // plus a retry policy that never fires must leave every decoded
    // stream byte-identical to the plain server — the failure-domain
    // machinery is pure overheadless opt-in until a fault actually fires.
    let Some(rt) = try_runtime(&["logits_tiny"]) else { return };
    let cfg = rt.load("logits_tiny").unwrap().meta.config.clone();
    let params = init_params(&cfg, 42);
    let lora = init_lora(&cfg, 43);
    let greedy = |i: usize| SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 4 + i % 3 };
    let drive = |wrap: bool| -> Vec<(u64, String, Outcome)> {
        let gen = Generator::new(&rt, "logits_tiny", &[&params, &lora]).unwrap();
        let mut collect = |rs: Vec<loram::serve::Response>| {
            let mut v: Vec<_> =
                rs.into_iter().map(|r| (r.id, r.text, r.outcome)).collect();
            v.sort_by(|a, b| a.0.cmp(&b.0));
            v
        };
        if wrap {
            let mut srv = Server::new(ChaosEngine::from_plan(gen, vec![]), 5);
            srv.set_retry_policy(Some(3), 2);
            for i in 0..6 {
                srv.enqueue(format!("Q: {i}+2="), greedy(i));
            }
            let rs = srv.drain().unwrap();
            assert_eq!(srv.engine.injected, 0);
            assert_eq!(srv.stats.retries, 0);
            assert_eq!(srv.stats.failed, 0);
            collect(rs)
        } else {
            let mut srv = Server::new(gen, 5);
            for i in 0..6 {
                srv.enqueue(format!("Q: {i}+2="), greedy(i));
            }
            collect(srv.drain().unwrap())
        }
    };
    assert_eq!(drive(true), drive(false), "chaos-off streams diverged");
}

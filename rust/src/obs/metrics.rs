//! Unified metrics registry: counters / gauges / histograms.
//!
//! The serving stack's stats structs (`ServerStats`, `PrefillStats`,
//! `PagedStats`, `SpecStats`) each export into one `Metrics` registry
//! (`ServerStats::to_metrics` fans out to the others), and every external
//! surface — `BENCH_serve.json`, `tab8_serving.csv`, the `serve` summary —
//! reads named registry entries instead of reaching into struct fields.
//! Adding a stat means adding one `set_counter`/`observe` call; the
//! exporters pick it up by name.
//!
//! Names are dot-scoped (`serve.total_tokens`, `paged.prefix_hits`,
//! `adapter.<label>.tokens`) and iterate in sorted order (BTreeMap), so
//! serialized registries are deterministic.

use crate::util::json::Json;
use crate::util::stats;
use std::collections::BTreeMap;

#[derive(Debug, Clone, Default, PartialEq)]
pub struct Metrics {
    counters: BTreeMap<String, f64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, Vec<f64>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    // -- counters (monotonic totals) --------------------------------------
    pub fn inc(&mut self, name: &str, by: f64) {
        *self.counters.entry(name.to_string()).or_insert(0.0) += by;
    }
    /// Set a counter to an absolute total (used when exporting an already
    /// accumulated stats struct).
    pub fn set_counter(&mut self, name: &str, v: f64) {
        self.counters.insert(name.to_string(), v);
    }
    pub fn counter(&self, name: &str) -> f64 {
        self.counters.get(name).copied().unwrap_or(0.0)
    }
    pub fn has_counter(&self, name: &str) -> bool {
        self.counters.contains_key(name)
    }

    // -- gauges (last-value samples) --------------------------------------
    pub fn set_gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }
    pub fn gauge(&self, name: &str) -> f64 {
        self.gauges.get(name).copied().unwrap_or(0.0)
    }
    pub fn has_gauge(&self, name: &str) -> bool {
        self.gauges.contains_key(name)
    }

    // -- histograms (raw observation vectors) -----------------------------
    pub fn observe(&mut self, name: &str, v: f64) {
        self.hists.entry(name.to_string()).or_default().push(v);
    }
    pub fn observe_all(&mut self, name: &str, vs: &[f64]) {
        self.hists.entry(name.to_string()).or_default().extend_from_slice(vs);
    }
    pub fn hist(&self, name: &str) -> &[f64] {
        self.hists.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }
    /// Batch percentiles of one histogram (single sort via
    /// `stats::percentiles_of`).
    pub fn hist_pcts(&self, name: &str, ps: &[f64]) -> Vec<f64> {
        stats::percentiles_of(self.hist(name), ps)
    }

    /// Merge another registry into this one: counters add, gauges take the
    /// other's value, histograms concatenate.
    pub fn merge(&mut self, other: &Metrics) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0.0) += v;
        }
        for (k, v) in &other.gauges {
            self.gauges.insert(k.clone(), *v);
        }
        for (k, v) in &other.hists {
            self.hists.entry(k.clone()).or_default().extend_from_slice(v);
        }
    }

    /// Deterministic JSON snapshot: histograms are summarized (count, mean,
    /// p50/p95), raw vectors stay in-process.
    pub fn to_json(&self) -> Json {
        let counters: Vec<(&str, Json)> =
            self.counters.iter().map(|(k, v)| (k.as_str(), Json::num(*v))).collect();
        let gauges: Vec<(&str, Json)> =
            self.gauges.iter().map(|(k, v)| (k.as_str(), Json::num(*v))).collect();
        let hists: Vec<(&str, Json)> = self
            .hists
            .iter()
            .map(|(k, v)| {
                let ps = stats::percentiles_of(v, &[50.0, 95.0]);
                (
                    k.as_str(),
                    Json::obj(vec![
                        ("count", Json::num(v.len() as f64)),
                        ("mean", Json::num(stats::mean(v))),
                        ("p50", Json::num(ps[0])),
                        ("p95", Json::num(ps[1])),
                    ]),
                )
            })
            .collect();
        Json::obj(vec![
            ("counters", Json::obj(counters)),
            ("gauges", Json::obj(gauges)),
            ("hists", Json::obj(hists)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_hists_roundtrip() {
        let mut m = Metrics::new();
        m.inc("serve.total_tokens", 10.0);
        m.inc("serve.total_tokens", 5.0);
        m.set_counter("serve.served", 3.0);
        m.set_gauge("queue_depth", 7.0);
        m.set_gauge("queue_depth", 2.0);
        m.observe_all("serve.ttft_ticks", &[1.0, 3.0, 2.0]);
        assert_eq!(m.counter("serve.total_tokens"), 15.0);
        assert_eq!(m.counter("serve.served"), 3.0);
        assert_eq!(m.counter("missing"), 0.0);
        assert_eq!(m.gauge("queue_depth"), 2.0);
        assert_eq!(m.hist("serve.ttft_ticks"), &[1.0, 3.0, 2.0]);
        assert_eq!(m.hist_pcts("serve.ttft_ticks", &[0.0, 50.0, 100.0]), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn merge_adds_counters_concats_hists() {
        let mut a = Metrics::new();
        a.inc("c", 1.0);
        a.observe("h", 1.0);
        a.set_gauge("g", 1.0);
        let mut b = Metrics::new();
        b.inc("c", 2.0);
        b.observe("h", 2.0);
        b.set_gauge("g", 9.0);
        a.merge(&b);
        assert_eq!(a.counter("c"), 3.0);
        assert_eq!(a.hist("h"), &[1.0, 2.0]);
        assert_eq!(a.gauge("g"), 9.0);
    }

    #[test]
    fn json_snapshot_is_sorted_and_summarized() {
        let mut m = Metrics::new();
        m.set_counter("b", 2.0);
        m.set_counter("a", 1.0);
        m.observe_all("h", &[1.0, 2.0, 3.0]);
        let s = m.to_json().to_string();
        // BTreeMap ordering: "a" before "b"; hist summarized, not raw
        assert!(s.find("\"a\"").unwrap() < s.find("\"b\"").unwrap());
        assert!(s.contains("\"count\":3"));
        assert!(!s.contains("[1,2,3]"));
    }
}

//! Request-lifecycle trace sink.
//!
//! A bounded ring buffer of typed scheduler events, **off by default** and
//! zero-cost when disabled: [`emit`] takes a closure, so the event (and any
//! `String` inside it) is never constructed unless a sink is installed. The
//! per-thread [`recorded`] counter counts constructed events, which is what
//! the "no allocation on the disabled hot path" test asserts on.
//!
//! Two clock domains stamp every event:
//! * `tick` — the scheduler tick ([`set_tick`] is called by `serve::Server`
//!   at enqueue time and around each `step`). Deterministic under `SimEngine`.
//! * `wall_ms` — milliseconds since [`install`]. Sim traces install with
//!   `wall_clock = false` so `wall_ms` stays `0.0` and two identical sim
//!   runs serialize to identical bytes.
//!
//! The sink is thread-local: the serving stack is single-threaded by design
//! (see DESIGN.md §2g), and `cargo test` runs tests on parallel threads —
//! a process-global sink would interleave events across unrelated tests.

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::time::Instant;

/// Typed scheduler event. Variants are row- or block-keyed where the
/// emitting layer does not know the request id; `tools/trace_report.py`
/// reconstructs the row → request mapping from `Admit`/`Finish` lifetimes.
///
/// NOTE: `tools/event_sync_check.py` parses this enum's variant names out
/// of the source text and diffs them against the Python parser's kind
/// table — keep one variant per line.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// Request entered the server queue.
    Enqueue { req: u64 },
    /// Request left the queue and reserved engine row `row`.
    Admit { req: u64, row: usize },
    /// Request dropped: admission prefill error or mid-chunk failure.
    Reject { req: u64 },
    /// Admission gate (`can_admit`) bounced the queue head back.
    Requeue { req: u64 },
    /// One chunked-prefill window ran: `bucket` padded tokens at `start`.
    PrefillWindow { row: usize, start: usize, bucket: usize },
    /// One sampled token on `row` (emitted per token, not per batch step).
    DecodeStep { row: usize },
    /// One speculative verify round: `k` drafted, `accepted` kept.
    VerifyRound { row: usize, k: usize, accepted: usize },
    /// KV cache rewound `n` positions on `row` (speculation rollback).
    Rewind { row: usize, n: usize },
    /// Engine released `row` (cache slot freed / paged tables dropped).
    Evict { row: usize },
    /// Request completed with `tokens` sampled tokens.
    Finish { req: u64, row: usize, tokens: usize },
    /// SLO scheduler evicted `row` mid-decode; `tokens` sampled so far are
    /// discarded and the request is requeued for re-prefill from the prompt.
    Preempt { req: u64, row: usize, tokens: usize },
    /// Queued request dropped before admission: its deadline expired.
    Cancel { req: u64 },
    /// Request finished after its deadline (served, but outside the SLO).
    DeadlineMiss { req: u64 },
    /// Paged pool handed out physical block `block`.
    BlockAlloc { block: usize },
    /// Physical block refcount hit zero (or was reclaimed/evicted).
    BlockFree { block: usize },
    /// Prefix-index hit mapped `blocks` shared blocks (`tokens` tokens).
    PrefixHit { blocks: usize, tokens: usize },
    /// Copy-on-write fork into fresh block `block` (must not fire in serve).
    CowCopy { block: usize },
    /// Sampled per-tick gauge (queue depth, in-flight rows, blocks in use).
    Gauge { name: &'static str, value: f64 },
    /// One `runtime::Session::run` with its h2d / execute / d2h split.
    SessionRun { artifact: String, h2d_ms: f64, exec_ms: f64, d2h_ms: f64 },
    /// A fault (`fault` names a `chaos::FAULT_KINDS` entry) hit `req` on `row`.
    Fault { req: u64, row: usize, fault: &'static str },
    /// Faulted request requeued for retry `attempt` (1-based) with backoff.
    Retry { req: u64, attempt: usize },
    /// Terminal failure: retry budget exhausted (or the engine was lost);
    /// `tokens` sampled so far are discarded, `attempts` faults were taken.
    Failed { req: u64, tokens: usize, attempts: usize },
    /// Health state left `Healthy`: `level` is "degraded" or "failing".
    Degrade { level: &'static str },
    /// Health state returned to `Healthy` (closes the `Degrade` bracket).
    Recover {},
}

/// Event-kind names, in enum order. Mirrored by `KINDS` in
/// `tools/trace_report.py`; `tools/event_sync_check.py` fails CI on drift.
pub const KINDS: &[&str] = &[
    "Enqueue",
    "Admit",
    "Reject",
    "Requeue",
    "PrefillWindow",
    "DecodeStep",
    "VerifyRound",
    "Rewind",
    "Evict",
    "Finish",
    "Preempt",
    "Cancel",
    "DeadlineMiss",
    "BlockAlloc",
    "BlockFree",
    "PrefixHit",
    "CowCopy",
    "Gauge",
    "SessionRun",
    "Fault",
    "Retry",
    "Failed",
    "Degrade",
    "Recover",
];

impl Event {
    pub fn kind(&self) -> &'static str {
        match self {
            Event::Enqueue { .. } => "Enqueue",
            Event::Admit { .. } => "Admit",
            Event::Reject { .. } => "Reject",
            Event::Requeue { .. } => "Requeue",
            Event::PrefillWindow { .. } => "PrefillWindow",
            Event::DecodeStep { .. } => "DecodeStep",
            Event::VerifyRound { .. } => "VerifyRound",
            Event::Rewind { .. } => "Rewind",
            Event::Evict { .. } => "Evict",
            Event::Finish { .. } => "Finish",
            Event::Preempt { .. } => "Preempt",
            Event::Cancel { .. } => "Cancel",
            Event::DeadlineMiss { .. } => "DeadlineMiss",
            Event::BlockAlloc { .. } => "BlockAlloc",
            Event::BlockFree { .. } => "BlockFree",
            Event::PrefixHit { .. } => "PrefixHit",
            Event::CowCopy { .. } => "CowCopy",
            Event::Gauge { .. } => "Gauge",
            Event::SessionRun { .. } => "SessionRun",
            Event::Fault { .. } => "Fault",
            Event::Retry { .. } => "Retry",
            Event::Failed { .. } => "Failed",
            Event::Degrade { .. } => "Degrade",
            Event::Recover { .. } => "Recover",
        }
    }
}

/// An [`Event`] stamped with both clock domains.
#[derive(Debug, Clone, PartialEq)]
pub struct Stamped {
    pub tick: u64,
    pub wall_ms: f64,
    pub ev: Event,
}

/// Bounded ring of stamped events. When full, the oldest event is dropped
/// and `dropped` counts it — a trace is a window, never an OOM.
#[derive(Debug)]
pub struct TraceSink {
    cap: usize,
    wall: bool,
    t0: Instant,
    events: VecDeque<Stamped>,
    dropped: u64,
}

/// Default ring capacity: enough for every event of a bench-sized sim run
/// (hundreds of requests × tens of tokens × a handful of events each).
pub const DEFAULT_CAP: usize = 1 << 18;

impl TraceSink {
    fn new(cap: usize, wall: bool) -> TraceSink {
        TraceSink {
            cap: cap.max(1),
            wall,
            t0: Instant::now(),
            events: VecDeque::new(),
            dropped: 0,
        }
    }

    fn push(&mut self, tick: u64, ev: Event) {
        let wall_ms = if self.wall { self.t0.elapsed().as_secs_f64() * 1e3 } else { 0.0 };
        if self.events.len() == self.cap {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(Stamped { tick, wall_ms, ev });
    }

    pub fn events(&self) -> &VecDeque<Stamped> {
        &self.events
    }
    pub fn into_events(self) -> Vec<Stamped> {
        self.events.into()
    }
    pub fn dropped(&self) -> u64 {
        self.dropped
    }
    pub fn len(&self) -> usize {
        self.events.len()
    }
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
    /// `true` when this sink stamps wall-clock ms (pjrt serve); `false`
    /// for tick-only sim traces.
    pub fn wall_clock(&self) -> bool {
        self.wall
    }
}

thread_local! {
    static SINK: RefCell<Option<TraceSink>> = const { RefCell::new(None) };
    static TICK: Cell<u64> = const { Cell::new(0) };
    static RECORDED: Cell<u64> = const { Cell::new(0) };
}

/// Install a sink on this thread (replacing any previous one).
/// `wall_clock = false` pins `wall_ms` to 0.0 for byte-deterministic traces.
pub fn install(cap: usize, wall_clock: bool) {
    SINK.with(|s| *s.borrow_mut() = Some(TraceSink::new(cap, wall_clock)));
}

/// Remove and return this thread's sink (tracing becomes disabled again).
pub fn take() -> Option<TraceSink> {
    SINK.with(|s| s.borrow_mut().take())
}

/// Is a sink installed on this thread?
pub fn active() -> bool {
    SINK.with(|s| s.borrow().is_some())
}

/// Set the scheduler tick that stamps subsequent events (and, while a sink
/// is active, `util::log` lines).
pub fn set_tick(t: u64) {
    TICK.with(|c| c.set(t));
}

/// Current scheduler tick on this thread.
pub fn tick() -> u64 {
    TICK.with(|c| c.get())
}

/// Record an event. The closure runs — and the event is constructed — only
/// when a sink is active; the disabled path is one thread-local branch.
#[inline]
pub fn emit(f: impl FnOnce() -> Event) {
    SINK.with(|s| {
        if let Some(sink) = s.borrow_mut().as_mut() {
            let ev = f();
            RECORDED.with(|c| c.set(c.get() + 1));
            sink.push(TICK.with(|c| c.get()), ev);
        }
    });
}

/// Monotonic count of events *constructed* on this thread. With tracing
/// disabled this never moves — the acceptance test for the zero-cost claim.
pub fn recorded() -> u64 {
    RECORDED.with(|c| c.get())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_sink_constructs_nothing() {
        let _ = take();
        let before = recorded();
        for _ in 0..64 {
            emit(|| Event::DecodeStep { row: 0 });
        }
        assert_eq!(recorded(), before, "disabled trace must not build events");
    }

    #[test]
    fn ring_is_bounded_and_counts_drops() {
        install(4, false);
        for i in 0..10 {
            set_tick(i);
            emit(|| Event::DecodeStep { row: i as usize });
        }
        let sink = take().unwrap();
        assert_eq!(sink.len(), 4);
        assert_eq!(sink.dropped(), 6);
        // the ring keeps the newest events
        assert_eq!(sink.events()[0].tick, 6);
        assert_eq!(sink.events()[3].tick, 9);
    }

    #[test]
    fn sim_clock_pins_wall_ms_to_zero() {
        install(16, false);
        set_tick(3);
        emit(|| Event::Enqueue { req: 7 });
        let sink = take().unwrap();
        let s = &sink.events()[0];
        assert_eq!(s.tick, 3);
        assert_eq!(s.wall_ms, 0.0);
        assert_eq!(s.ev, Event::Enqueue { req: 7 });
    }

    #[test]
    fn kind_table_matches_enum_order() {
        let sample: Vec<Event> = vec![
            Event::Enqueue { req: 0 },
            Event::Admit { req: 0, row: 0 },
            Event::Reject { req: 0 },
            Event::Requeue { req: 0 },
            Event::PrefillWindow { row: 0, start: 0, bucket: 16 },
            Event::DecodeStep { row: 0 },
            Event::VerifyRound { row: 0, k: 4, accepted: 2 },
            Event::Rewind { row: 0, n: 2 },
            Event::Evict { row: 0 },
            Event::Finish { req: 0, row: 0, tokens: 1 },
            Event::Preempt { req: 0, row: 0, tokens: 1 },
            Event::Cancel { req: 0 },
            Event::DeadlineMiss { req: 0 },
            Event::BlockAlloc { block: 0 },
            Event::BlockFree { block: 0 },
            Event::PrefixHit { blocks: 1, tokens: 8 },
            Event::CowCopy { block: 0 },
            Event::Gauge { name: "queue_depth", value: 0.0 },
            Event::SessionRun {
                artifact: "decode_step".into(),
                h2d_ms: 0.0,
                exec_ms: 0.0,
                d2h_ms: 0.0,
            },
            Event::Fault { req: 0, row: 0, fault: "decode-transient" },
            Event::Retry { req: 0, attempt: 1 },
            Event::Failed { req: 0, tokens: 1, attempts: 2 },
            Event::Degrade { level: "degraded" },
            Event::Recover {},
        ];
        let kinds: Vec<&str> = sample.iter().map(|e| e.kind()).collect();
        assert_eq!(kinds, KINDS, "Event::kind()/KINDS drifted from the enum");
    }
}

//! In-process trace auditor — the Rust mirror of `tools/trace_report.py`.
//!
//! Replays a raw event stream and checks the scheduler's conservation
//! laws, reconstructing the TTFT/ITL tick distributions exactly as
//! `serve::Server::step` accumulates them (same per-row first-token /
//! last-token-tick state machine, same event order), so tests can assert
//! `audit.ttft_ticks == stats.ttft_ticks` element-for-element. The Python
//! tool applies the identical rules to exported traces; this module is
//! what lets `cargo test` enforce them without Python.
//!
//! Laws checked (violations are human-readable strings):
//! 1. per request: enqueue ≤ admit ≤ first-token ≤ finish (tick order)
//! 2. token conservation: DecodeStep count per request == `Finish.tokens`
//! 3. lifecycle: every admitted request finishes or is rejected; no
//!    decode on an unoccupied row; no double-admit of a live row
//! 4. block discipline: no alloc of a live block, no free of a dead one
//!    (end-of-run residency is reported, not judged — the prefix index
//!    legitimately holds blocks across requests)
//! 5. `cow_copies` is reported for the caller to judge (0 under serve —
//!    the §2f share-only-full-blocks invariant)
//! 6. preemption conservation (§2i): `Preempt.tokens` equals the
//!    DecodeStep count of the life it ends; the preempted row is freed;
//!    the request may be re-admitted and its eventual `Finish.tokens`
//!    counts only the final life (the discarded stream is accounted in
//!    `preempted_tokens`, so total DecodeSteps == finish + preempted)
//! 7. cancel is terminal and pre-admission: a `Cancel` of an in-flight
//!    or finished request, or any `Admit` after `Cancel`, is a violation
//! 8. admission ledger: admits == finishes + preempts + mid-flight
//!    rejects, and `DeadlineMiss` only fires for requests that finish

use super::trace::{Event, Stamped};
use std::collections::BTreeMap;

#[derive(Debug, Default)]
struct Life {
    enq: Option<u64>,
    /// First admission tick — tick-order law anchor (TTFT may precede a
    /// later re-admission after preemption).
    first_admit: Option<u64>,
    /// Current-life admission tick; cleared by `Preempt` so a re-admit is
    /// legal while a genuine double-admit still trips the law.
    admit: Option<u64>,
    first_tok: Option<u64>,
    last_tok: Option<u64>,
    finish: Option<u64>,
    /// DecodeStep count of the *current* life (reset by `Preempt`).
    tokens: usize,
    finish_tokens: Option<usize>,
    rejected: bool,
    cancelled: bool,
    deadline_miss: bool,
}

/// Replay result: violations plus the reconstructed distributions.
#[derive(Debug, Default)]
pub struct AuditReport {
    pub violations: Vec<String>,
    /// enqueue → first-token tick counts, in `Server::step` push order
    pub ttft_ticks: Vec<usize>,
    /// inter-token tick gaps, in `Server::step` push order
    pub itl_ticks: Vec<usize>,
    pub enqueued: usize,
    pub admitted: usize,
    pub finished: usize,
    pub rejected: usize,
    pub requeues: usize,
    pub tokens: usize,
    /// SLO-scheduler lifecycle counts (§2i)
    pub preempted: usize,
    /// DecodeSteps discarded across all preemptions (global conservation:
    /// `tokens == Σ Finish.tokens + preempted_tokens`)
    pub preempted_tokens: usize,
    pub cancelled: usize,
    pub deadline_misses: usize,
    /// blocks still allocated when the trace ends
    pub live_blocks: usize,
    pub cow_copies: usize,
    pub prefix_hits: usize,
    pub verify_rounds: usize,
}

impl AuditReport {
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Replay `events` (chronological emission order, as a `TraceSink` stores
/// them) and check every conservation law.
pub fn audit(events: &[Stamped]) -> AuditReport {
    let mut r = AuditReport::default();
    let mut lives: BTreeMap<u64, Life> = BTreeMap::new();
    // engine row -> occupant request
    let mut rows: BTreeMap<usize, u64> = BTreeMap::new();
    let mut live_blocks: BTreeMap<usize, u64> = BTreeMap::new();
    // admissions that ended in a mid-flight Reject (for the admission ledger)
    let mut rejected_inflight: usize = 0;

    for s in events {
        let t = s.tick;
        match &s.ev {
            Event::Enqueue { req } => {
                r.enqueued += 1;
                let l = lives.entry(*req).or_default();
                if l.enq.is_some() {
                    r.violations.push(format!("req {req}: enqueued twice"));
                }
                l.enq = Some(t);
            }
            Event::Requeue { .. } => r.requeues += 1,
            Event::Admit { req, row } => {
                r.admitted += 1;
                if let Some(prev) = rows.get(row) {
                    r.violations
                        .push(format!("row {row}: admit req {req} over live req {prev}"));
                }
                rows.insert(*row, *req);
                let l = lives.entry(*req).or_default();
                if l.admit.is_some() {
                    r.violations.push(format!("req {req}: admitted twice"));
                }
                if l.cancelled {
                    r.violations.push(format!("req {req}: admitted after cancel"));
                }
                match l.enq {
                    None => r.violations.push(format!("req {req}: admitted, never enqueued")),
                    Some(e) if t < e => {
                        r.violations.push(format!("req {req}: admit tick {t} < enqueue {e}"))
                    }
                    _ => {}
                }
                if l.first_admit.is_none() {
                    l.first_admit = Some(t);
                }
                l.admit = Some(t);
            }
            Event::Reject { req } => {
                r.rejected += 1;
                let l = lives.entry(*req).or_default();
                l.rejected = true;
                if l.admit.is_some() {
                    rejected_inflight += 1;
                }
                // mid-flight rejection frees the row
                if let Some(&row) =
                    rows.iter().find_map(|(row, occ)| (occ == req).then_some(row))
                {
                    rows.remove(&row);
                }
            }
            Event::DecodeStep { row } => {
                r.tokens += 1;
                let Some(req) = rows.get(row).copied() else {
                    r.violations.push(format!("tick {t}: token on unoccupied row {row}"));
                    continue;
                };
                let l = lives.entry(req).or_default();
                l.tokens += 1;
                // exact Server::step replication: TTFT on the first token,
                // an ITL gap for every token with a predecessor
                if l.first_tok.is_none() {
                    l.first_tok = Some(t);
                    let enq = l.enq.unwrap_or(t);
                    r.ttft_ticks.push((t - enq.min(t)) as usize);
                }
                if let Some(last) = l.last_tok {
                    r.itl_ticks.push((t - last.min(t)) as usize);
                }
                l.last_tok = Some(t);
            }
            Event::Finish { req, row, tokens } => {
                r.finished += 1;
                match rows.remove(row) {
                    None => {
                        r.violations.push(format!("req {req}: finish on unoccupied row {row}"))
                    }
                    Some(occ) if occ != *req => r.violations.push(format!(
                        "row {row}: finish req {req} but occupant is req {occ}"
                    )),
                    _ => {}
                }
                let l = lives.entry(*req).or_default();
                l.finish = Some(t);
                l.finish_tokens = Some(*tokens);
            }
            Event::Preempt { req, row, tokens } => {
                r.preempted += 1;
                match rows.remove(row) {
                    None => r
                        .violations
                        .push(format!("req {req}: preempt on unoccupied row {row}")),
                    Some(occ) if occ != *req => r.violations.push(format!(
                        "row {row}: preempt req {req} but occupant is req {occ}"
                    )),
                    _ => {}
                }
                let l = lives.entry(*req).or_default();
                if l.admit.is_none() {
                    r.violations.push(format!("req {req}: preempted while not admitted"));
                }
                if *tokens != l.tokens {
                    r.violations.push(format!(
                        "req {req}: Preempt says {tokens} tokens but life sampled {}",
                        l.tokens
                    ));
                }
                // the discarded stream is conserved into preempted_tokens;
                // the re-run life starts with a clean token/ITL slate (TTFT
                // was recorded once, on the first-ever token)
                r.preempted_tokens += l.tokens;
                l.tokens = 0;
                l.last_tok = None;
                l.admit = None;
            }
            Event::Cancel { req } => {
                r.cancelled += 1;
                let l = lives.entry(*req).or_default();
                if l.enq.is_none() {
                    r.violations.push(format!("req {req}: cancelled, never enqueued"));
                }
                if l.cancelled {
                    r.violations.push(format!("req {req}: cancelled twice"));
                }
                if l.admit.is_some() {
                    r.violations.push(format!("req {req}: cancelled while in flight"));
                }
                if l.finish.is_some() {
                    r.violations.push(format!("req {req}: cancelled after finish"));
                }
                l.cancelled = true;
            }
            Event::DeadlineMiss { req } => {
                r.deadline_misses += 1;
                let l = lives.entry(*req).or_default();
                if l.deadline_miss {
                    r.violations.push(format!("req {req}: deadline missed twice"));
                }
                l.deadline_miss = true;
            }
            Event::BlockAlloc { block } => {
                if live_blocks.insert(*block, t).is_some() {
                    r.violations.push(format!("block {block}: allocated while live"));
                }
            }
            Event::BlockFree { block } => {
                if live_blocks.remove(block).is_none() {
                    r.violations.push(format!("block {block}: freed while free"));
                }
            }
            Event::CowCopy { .. } => r.cow_copies += 1,
            Event::PrefixHit { .. } => r.prefix_hits += 1,
            Event::VerifyRound { k, accepted, .. } => {
                r.verify_rounds += 1;
                if accepted > k {
                    r.violations
                        .push(format!("tick {t}: verify accepted {accepted} > drafted {k}"));
                }
            }
            // informational: no conservation law attaches
            Event::PrefillWindow { .. }
            | Event::Rewind { .. }
            | Event::Evict { .. }
            | Event::Gauge { .. }
            | Event::SessionRun { .. } => {}
        }
    }

    for (req, l) in &lives {
        if l.deadline_miss && l.finish.is_none() {
            r.violations.push(format!("req {req}: deadline miss without a finish"));
        }
        let (Some(enq), Some(admit)) = (l.enq, l.admit) else {
            if l.admit.is_some() {
                // already flagged above
            } else if !l.rejected && !l.cancelled && l.enq.is_some() {
                r.violations.push(format!("req {req}: enqueued but never admitted or rejected"));
            }
            continue;
        };
        if l.rejected {
            continue;
        }
        let Some(finish) = l.finish else {
            r.violations.push(format!("req {req}: admitted but never finished"));
            continue;
        };
        let Some(first) = l.first_tok else {
            r.violations.push(format!("req {req}: finished without a first token"));
            continue;
        };
        // tick order anchors on the *first* admission: TTFT is recorded
        // once per request, and a preempted request's final admit tick may
        // legitimately postdate its first-ever token
        let admit0 = l.first_admit.unwrap_or(admit);
        if !(enq <= admit0 && admit0 <= first && first <= finish) {
            r.violations.push(format!(
                "req {req}: tick order broken (enq {enq} ≤ admit {admit0} ≤ first {first} ≤ finish {finish})"
            ));
        }
        if let Some(ft) = l.finish_tokens {
            if ft != l.tokens {
                r.violations.push(format!(
                    "req {req}: {} DecodeStep tokens but Finish says {ft}",
                    l.tokens
                ));
            }
        }
    }
    // admission ledger: every admission ends in exactly one of finish /
    // preempt / mid-flight reject
    if r.admitted != r.finished + r.preempted + rejected_inflight {
        r.violations.push(format!(
            "admission ledger broken: {} admits != {} finishes + {} preempts + {} mid-flight rejects",
            r.admitted, r.finished, r.preempted, rejected_inflight
        ));
    }
    if !rows.is_empty() {
        let stuck: Vec<String> = rows.iter().map(|(row, req)| format!("{row}:req {req}")).collect();
        r.violations.push(format!("rows still occupied at end of trace: {}", stuck.join(", ")));
    }
    r.live_blocks = live_blocks.len();
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    fn st(tick: u64, ev: Event) -> Stamped {
        Stamped { tick, wall_ms: 0.0, ev }
    }

    #[test]
    fn clean_lifecycle_passes_and_reconstructs_latencies() {
        let evs = vec![
            st(0, Event::Enqueue { req: 0 }),
            st(0, Event::Admit { req: 0, row: 0 }),
            st(2, Event::DecodeStep { row: 0 }), // ttft = 2
            st(3, Event::DecodeStep { row: 0 }), // itl = 1
            st(5, Event::DecodeStep { row: 0 }), // itl = 2
            st(5, Event::Finish { req: 0, row: 0, tokens: 3 }),
        ];
        let a = audit(&evs);
        assert!(a.ok(), "unexpected violations: {:?}", a.violations);
        assert_eq!(a.ttft_ticks, vec![2]);
        assert_eq!(a.itl_ticks, vec![1, 2]);
        assert_eq!(a.tokens, 3);
        assert_eq!(a.finished, 1);
    }

    #[test]
    fn token_mismatch_and_orphan_rows_are_violations() {
        let evs = vec![
            st(0, Event::Enqueue { req: 0 }),
            st(0, Event::Admit { req: 0, row: 0 }),
            st(1, Event::DecodeStep { row: 0 }),
            st(1, Event::DecodeStep { row: 3 }), // unoccupied row
            st(2, Event::Finish { req: 0, row: 0, tokens: 9 }), // wrong total
            st(2, Event::Enqueue { req: 1 }),
            st(2, Event::Admit { req: 1, row: 1 }), // never finishes
        ];
        let a = audit(&evs);
        assert!(!a.ok());
        let text = a.violations.join("\n");
        assert!(text.contains("unoccupied row 3"), "{text}");
        assert!(text.contains("Finish says 9"), "{text}");
        assert!(text.contains("req 1: admitted but never finished"), "{text}");
        assert!(text.contains("rows still occupied"), "{text}");
    }

    #[test]
    fn block_discipline_is_enforced() {
        let evs = vec![
            st(0, Event::BlockAlloc { block: 4 }),
            st(0, Event::BlockAlloc { block: 4 }), // double alloc
            st(1, Event::BlockFree { block: 4 }),
            st(1, Event::BlockFree { block: 7 }), // free of a dead block
            st(2, Event::BlockAlloc { block: 5 }), // stays live at end
        ];
        let a = audit(&evs);
        let text = a.violations.join("\n");
        assert!(text.contains("block 4: allocated while live"), "{text}");
        assert!(text.contains("block 7: freed while free"), "{text}");
        assert_eq!(a.live_blocks, 1);
    }

    #[test]
    fn preempt_conserves_tokens_and_frees_row_for_reuse() {
        let evs = vec![
            st(0, Event::Enqueue { req: 0 }),
            st(0, Event::Admit { req: 0, row: 0 }),
            st(1, Event::DecodeStep { row: 0 }), // ttft = 1 (first-ever token)
            st(2, Event::DecodeStep { row: 0 }), // itl = 1
            st(3, Event::Preempt { req: 0, row: 0, tokens: 2 }),
            st(3, Event::Evict { row: 0 }),
            st(3, Event::Enqueue { req: 1 }),
            st(3, Event::Admit { req: 1, row: 0 }), // freed row is reusable
            st(4, Event::DecodeStep { row: 0 }),
            st(4, Event::Finish { req: 1, row: 0, tokens: 1 }),
            st(5, Event::Admit { req: 0, row: 1 }), // re-admit after preempt
            st(6, Event::DecodeStep { row: 1 }),    // no TTFT (already recorded)
            st(7, Event::DecodeStep { row: 1 }),    // itl = 1, no cross-life gap
            st(8, Event::DecodeStep { row: 1 }),
            st(8, Event::Finish { req: 0, row: 1, tokens: 3 }),
        ];
        let a = audit(&evs);
        assert!(a.ok(), "unexpected violations: {:?}", a.violations);
        assert_eq!(a.preempted, 1);
        assert_eq!(a.preempted_tokens, 2);
        // global conservation: DecodeSteps == finish tokens + discarded
        assert_eq!(a.tokens, 3 + 1 + 2);
        assert_eq!(a.ttft_ticks, vec![1, 1]);
        // req 0's ITL gaps never span the preemption boundary
        assert_eq!(a.itl_ticks, vec![1, 1, 1]);
    }

    #[test]
    fn preempt_token_lie_and_unadmitted_preempt_are_violations() {
        let evs = vec![
            st(0, Event::Enqueue { req: 0 }),
            st(0, Event::Admit { req: 0, row: 0 }),
            st(1, Event::DecodeStep { row: 0 }),
            st(2, Event::Preempt { req: 0, row: 0, tokens: 5 }), // lies: sampled 1
            st(3, Event::Preempt { req: 0, row: 2, tokens: 0 }), // not admitted
        ];
        let a = audit(&evs);
        let text = a.violations.join("\n");
        assert!(text.contains("Preempt says 5 tokens but life sampled 1"), "{text}");
        assert!(text.contains("preempt on unoccupied row 2"), "{text}");
        assert!(text.contains("preempted while not admitted"), "{text}");
    }

    #[test]
    fn cancel_is_terminal_and_pre_admission() {
        let clean = vec![
            st(0, Event::Enqueue { req: 0 }),
            st(4, Event::Cancel { req: 0 }),
        ];
        let a = audit(&clean);
        assert!(a.ok(), "unexpected violations: {:?}", a.violations);
        assert_eq!(a.cancelled, 1);

        let bad = vec![
            st(0, Event::Enqueue { req: 0 }),
            st(0, Event::Admit { req: 0, row: 0 }),
            st(1, Event::Cancel { req: 0 }), // in flight: not cancellable
            st(2, Event::Admit { req: 0, row: 1 }), // nothing after cancel
        ];
        let text = audit(&bad).violations.join("\n");
        assert!(text.contains("cancelled while in flight"), "{text}");
        assert!(text.contains("admitted after cancel"), "{text}");
    }

    #[test]
    fn deadline_miss_requires_a_finish_and_admission_ledger_balances() {
        let evs = vec![
            st(0, Event::Enqueue { req: 0 }),
            st(0, Event::Admit { req: 0, row: 0 }),
            st(9, Event::DecodeStep { row: 0 }),
            st(9, Event::DeadlineMiss { req: 0 }),
            st(9, Event::Finish { req: 0, row: 0, tokens: 1 }),
        ];
        let a = audit(&evs);
        assert!(a.ok(), "unexpected violations: {:?}", a.violations);
        assert_eq!(a.deadline_misses, 1);

        let orphan = audit(&[st(0, Event::DeadlineMiss { req: 3 })]);
        assert!(orphan
            .violations
            .iter()
            .any(|v| v.contains("deadline miss without a finish")));

        // an admission with no terminal event breaks the ledger
        let open = audit(&[
            st(0, Event::Enqueue { req: 0 }),
            st(0, Event::Admit { req: 0, row: 0 }),
        ]);
        assert!(open.violations.iter().any(|v| v.contains("admission ledger broken")));
    }

    #[test]
    fn mid_flight_reject_frees_the_row() {
        let evs = vec![
            st(0, Event::Enqueue { req: 0 }),
            st(0, Event::Admit { req: 0, row: 0 }),
            st(1, Event::Reject { req: 0 }),
            st(1, Event::Enqueue { req: 1 }),
            st(1, Event::Admit { req: 1, row: 0 }),
            st(2, Event::DecodeStep { row: 0 }),
            st(2, Event::Finish { req: 1, row: 0, tokens: 1 }),
        ];
        let a = audit(&evs);
        assert!(a.ok(), "unexpected violations: {:?}", a.violations);
        assert_eq!(a.rejected, 1);
    }
}

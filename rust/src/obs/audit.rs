//! In-process trace auditor — the Rust mirror of `tools/trace_report.py`.
//!
//! Replays a raw event stream and checks the scheduler's conservation
//! laws, reconstructing the TTFT/ITL tick distributions exactly as
//! `serve::Server::step` accumulates them (same per-row first-token /
//! last-token-tick state machine, same event order), so tests can assert
//! `audit.ttft_ticks == stats.ttft_ticks` element-for-element. The Python
//! tool applies the identical rules to exported traces; this module is
//! what lets `cargo test` enforce them without Python.
//!
//! Laws checked (violations are human-readable strings):
//! 1. per request: enqueue ≤ admit ≤ first-token ≤ finish (tick order)
//! 2. token conservation: DecodeStep count per request == `Finish.tokens`
//! 3. lifecycle: every admitted request finishes or is rejected; no
//!    decode on an unoccupied row; no double-admit of a live row
//! 4. block discipline: no alloc of a live block, no free of a dead one
//!    (end-of-run residency is reported, not judged — the prefix index
//!    legitimately holds blocks across requests)
//! 5. `cow_copies` is reported for the caller to judge (0 under serve —
//!    the §2f share-only-full-blocks invariant)
//! 6. preemption conservation (§2i): `Preempt.tokens` equals the
//!    DecodeStep count of the life it ends; the preempted row is freed;
//!    the request may be re-admitted and its eventual `Finish.tokens`
//!    counts only the final life (the discarded stream is accounted in
//!    `preempted_tokens`, so total DecodeSteps == finish + preempted)
//! 7. cancel is terminal and pre-admission: a `Cancel` of an in-flight
//!    or finished request, or any `Admit` after `Cancel`, is a violation
//! 8. admission ledger: admits == finishes + preempts + mid-flight
//!    rejects + fails, and `DeadlineMiss` only fires for requests that
//!    finish
//! 9. retry ledger (§2j): every `Fault` is answered by exactly one
//!    `Retry` or terminal `Failed` — per request, faults == retries
//!    while live, and faults == retries + 1 at an in-flight `Failed`;
//!    `Retry` attempts count 1, 2, … in order
//! 10. failure terminality: `Failed` is a terminal outcome — no event
//!     may name the request afterwards; `Failed.tokens` conserves the
//!     discarded life (like `Preempt`) into `failed_tokens`
//! 11. degradation bracketing: every `Degrade("degraded")` is closed by
//!     a `Recover` or escalates to `Degrade("failing")`; a trace may
//!     only end degraded if it ends in the failing state

use super::trace::{Event, Stamped};
use std::collections::BTreeMap;

#[derive(Debug, Default)]
struct Life {
    enq: Option<u64>,
    /// First admission tick — tick-order law anchor (TTFT may precede a
    /// later re-admission after preemption).
    first_admit: Option<u64>,
    /// Current-life admission tick; cleared by `Preempt` so a re-admit is
    /// legal while a genuine double-admit still trips the law.
    admit: Option<u64>,
    first_tok: Option<u64>,
    last_tok: Option<u64>,
    finish: Option<u64>,
    /// DecodeStep count of the *current* life (reset by `Preempt`).
    tokens: usize,
    finish_tokens: Option<usize>,
    rejected: bool,
    cancelled: bool,
    deadline_miss: bool,
    faults: usize,
    retries: usize,
    failed: bool,
}

/// Replay result: violations plus the reconstructed distributions.
#[derive(Debug, Default)]
pub struct AuditReport {
    pub violations: Vec<String>,
    /// enqueue → first-token tick counts, in `Server::step` push order
    pub ttft_ticks: Vec<usize>,
    /// inter-token tick gaps, in `Server::step` push order
    pub itl_ticks: Vec<usize>,
    pub enqueued: usize,
    pub admitted: usize,
    pub finished: usize,
    pub rejected: usize,
    pub requeues: usize,
    pub tokens: usize,
    /// SLO-scheduler lifecycle counts (§2i)
    pub preempted: usize,
    /// DecodeSteps discarded across all preemptions (global conservation:
    /// `tokens == Σ Finish.tokens + preempted_tokens`)
    pub preempted_tokens: usize,
    pub cancelled: usize,
    pub deadline_misses: usize,
    /// chaos lifecycle counts (§2j)
    pub faults: usize,
    pub retries: usize,
    pub failed: usize,
    /// DecodeSteps discarded across all terminal failures (global
    /// conservation: `tokens == Σ Finish.tokens + preempted_tokens +
    /// failed_tokens`)
    pub failed_tokens: usize,
    pub degrades: usize,
    /// blocks still allocated when the trace ends
    pub live_blocks: usize,
    pub cow_copies: usize,
    pub prefix_hits: usize,
    pub verify_rounds: usize,
}

impl AuditReport {
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Replay `events` (chronological emission order, as a `TraceSink` stores
/// them) and check every conservation law.
pub fn audit(events: &[Stamped]) -> AuditReport {
    let mut r = AuditReport::default();
    let mut lives: BTreeMap<u64, Life> = BTreeMap::new();
    // engine row -> occupant request
    let mut rows: BTreeMap<usize, u64> = BTreeMap::new();
    let mut live_blocks: BTreeMap<usize, u64> = BTreeMap::new();
    // admissions that ended in a mid-flight Reject (for the admission ledger)
    let mut rejected_inflight: usize = 0;
    // admissions that ended in a terminal Failed (for the admission ledger)
    let mut failed_inflight: usize = 0;
    // degradation bracket state (law 11)
    let mut health = "healthy";

    for s in events {
        let t = s.tick;
        // law 10: Failed is terminal — nothing may name the request after
        let named = match &s.ev {
            Event::Enqueue { req }
            | Event::Requeue { req }
            | Event::Reject { req }
            | Event::Cancel { req }
            | Event::DeadlineMiss { req }
            | Event::Admit { req, .. }
            | Event::Finish { req, .. }
            | Event::Preempt { req, .. }
            | Event::Fault { req, .. }
            | Event::Retry { req, .. } => Some(*req),
            _ => None,
        };
        if let Some(req) = named {
            if lives.get(&req).map_or(false, |l| l.failed) {
                r.violations.push(format!(
                    "req {req}: {} after Failed (failure is terminal)",
                    s.ev.kind()
                ));
            }
        }
        match &s.ev {
            Event::Enqueue { req } => {
                r.enqueued += 1;
                let l = lives.entry(*req).or_default();
                if l.enq.is_some() {
                    r.violations.push(format!("req {req}: enqueued twice"));
                }
                l.enq = Some(t);
            }
            Event::Requeue { .. } => r.requeues += 1,
            Event::Admit { req, row } => {
                r.admitted += 1;
                if let Some(prev) = rows.get(row) {
                    r.violations
                        .push(format!("row {row}: admit req {req} over live req {prev}"));
                }
                rows.insert(*row, *req);
                let l = lives.entry(*req).or_default();
                if l.admit.is_some() {
                    r.violations.push(format!("req {req}: admitted twice"));
                }
                if l.cancelled {
                    r.violations.push(format!("req {req}: admitted after cancel"));
                }
                match l.enq {
                    None => r.violations.push(format!("req {req}: admitted, never enqueued")),
                    Some(e) if t < e => {
                        r.violations.push(format!("req {req}: admit tick {t} < enqueue {e}"))
                    }
                    _ => {}
                }
                if l.first_admit.is_none() {
                    l.first_admit = Some(t);
                }
                l.admit = Some(t);
            }
            Event::Reject { req } => {
                r.rejected += 1;
                let l = lives.entry(*req).or_default();
                l.rejected = true;
                if l.admit.is_some() {
                    rejected_inflight += 1;
                }
                // mid-flight rejection frees the row
                if let Some(&row) =
                    rows.iter().find_map(|(row, occ)| (occ == req).then_some(row))
                {
                    rows.remove(&row);
                }
            }
            Event::DecodeStep { row } => {
                r.tokens += 1;
                let Some(req) = rows.get(row).copied() else {
                    r.violations.push(format!("tick {t}: token on unoccupied row {row}"));
                    continue;
                };
                let l = lives.entry(req).or_default();
                l.tokens += 1;
                // exact Server::step replication: TTFT on the first token,
                // an ITL gap for every token with a predecessor
                if l.first_tok.is_none() {
                    l.first_tok = Some(t);
                    let enq = l.enq.unwrap_or(t);
                    r.ttft_ticks.push((t - enq.min(t)) as usize);
                }
                if let Some(last) = l.last_tok {
                    r.itl_ticks.push((t - last.min(t)) as usize);
                }
                l.last_tok = Some(t);
            }
            Event::Finish { req, row, tokens } => {
                r.finished += 1;
                match rows.remove(row) {
                    None => {
                        r.violations.push(format!("req {req}: finish on unoccupied row {row}"))
                    }
                    Some(occ) if occ != *req => r.violations.push(format!(
                        "row {row}: finish req {req} but occupant is req {occ}"
                    )),
                    _ => {}
                }
                let l = lives.entry(*req).or_default();
                l.finish = Some(t);
                l.finish_tokens = Some(*tokens);
            }
            Event::Preempt { req, row, tokens } => {
                r.preempted += 1;
                match rows.remove(row) {
                    None => r
                        .violations
                        .push(format!("req {req}: preempt on unoccupied row {row}")),
                    Some(occ) if occ != *req => r.violations.push(format!(
                        "row {row}: preempt req {req} but occupant is req {occ}"
                    )),
                    _ => {}
                }
                let l = lives.entry(*req).or_default();
                if l.admit.is_none() {
                    r.violations.push(format!("req {req}: preempted while not admitted"));
                }
                if *tokens != l.tokens {
                    r.violations.push(format!(
                        "req {req}: Preempt says {tokens} tokens but life sampled {}",
                        l.tokens
                    ));
                }
                // the discarded stream is conserved into preempted_tokens;
                // the re-run life starts with a clean token/ITL slate (TTFT
                // was recorded once, on the first-ever token)
                r.preempted_tokens += l.tokens;
                l.tokens = 0;
                l.last_tok = None;
                l.admit = None;
            }
            Event::Cancel { req } => {
                r.cancelled += 1;
                let l = lives.entry(*req).or_default();
                if l.enq.is_none() {
                    r.violations.push(format!("req {req}: cancelled, never enqueued"));
                }
                if l.cancelled {
                    r.violations.push(format!("req {req}: cancelled twice"));
                }
                if l.admit.is_some() {
                    r.violations.push(format!("req {req}: cancelled while in flight"));
                }
                if l.finish.is_some() {
                    r.violations.push(format!("req {req}: cancelled after finish"));
                }
                l.cancelled = true;
            }
            Event::DeadlineMiss { req } => {
                r.deadline_misses += 1;
                let l = lives.entry(*req).or_default();
                if l.deadline_miss {
                    r.violations.push(format!("req {req}: deadline missed twice"));
                }
                l.deadline_miss = true;
            }
            Event::Fault { req, row, .. } => {
                r.faults += 1;
                let occupied = rows.get(row) == Some(req);
                let l = lives.entry(*req).or_default();
                if l.admit.is_none() {
                    r.violations.push(format!("req {req}: fault while not admitted"));
                } else if !occupied {
                    r.violations
                        .push(format!("req {req}: fault on row {row} it does not occupy"));
                }
                l.faults += 1;
            }
            Event::Retry { req, attempt } => {
                r.retries += 1;
                let l = lives.entry(*req).or_default();
                if l.faults != l.retries + 1 {
                    r.violations.push(format!(
                        "req {req}: retry without a pending fault ({} faults, {} retries)",
                        l.faults, l.retries
                    ));
                } else if *attempt != l.retries + 1 {
                    r.violations.push(format!(
                        "req {req}: Retry says attempt {attempt} but this is retry {}",
                        l.retries + 1
                    ));
                }
                l.retries += 1;
            }
            Event::Failed { req, tokens, attempts } => {
                r.failed += 1;
                let freed_row =
                    rows.iter().find_map(|(row, occ)| (occ == req).then_some(*row));
                let l = lives.entry(*req).or_default();
                if l.enq.is_none() {
                    r.violations.push(format!("req {req}: failed, never enqueued"));
                }
                if l.cancelled {
                    r.violations.push(format!("req {req}: failed after cancel"));
                }
                if l.finish.is_some() {
                    r.violations.push(format!("req {req}: failed after finish"));
                }
                if *tokens != l.tokens {
                    r.violations.push(format!(
                        "req {req}: Failed says {tokens} tokens but life sampled {}",
                        l.tokens
                    ));
                }
                if *attempts != l.faults {
                    r.violations.push(format!(
                        "req {req}: Failed says {attempts} attempts but life took {} faults",
                        l.faults
                    ));
                }
                if l.admit.is_some() {
                    // in-flight failure: closes the admission (ledger), frees
                    // the row, conserves the discarded stream (like Preempt)
                    if l.faults != l.retries + 1 {
                        r.violations.push(format!(
                            "req {req}: retry ledger broken at Failed ({} faults != {} retries + 1)",
                            l.faults, l.retries
                        ));
                    }
                    failed_inflight += 1;
                    if let Some(row) = freed_row {
                        rows.remove(&row);
                    }
                } else if l.faults != l.retries {
                    r.violations.push(format!(
                        "req {req}: retry ledger broken at queue Failed ({} faults != {} retries)",
                        l.faults, l.retries
                    ));
                }
                r.failed_tokens += l.tokens;
                l.tokens = 0;
                l.last_tok = None;
                l.admit = None;
                l.failed = true;
            }
            Event::Degrade { level } => {
                r.degrades += 1;
                if !matches!(*level, "degraded" | "failing") {
                    r.violations.push(format!("tick {t}: unknown degrade level {level:?}"));
                } else if *level == "degraded" && health != "healthy" {
                    r.violations.push(format!("tick {t}: degrade to degraded while {health}"));
                } else if *level == "failing" && health == "failing" {
                    r.violations
                        .push(format!("tick {t}: degrade to failing while already failing"));
                } else {
                    health = *level;
                }
            }
            Event::Recover {} => {
                if health == "healthy" {
                    r.violations.push(format!("tick {t}: recover while healthy"));
                } else if health == "failing" {
                    r.violations
                        .push(format!("tick {t}: recover from failing (failing is terminal)"));
                } else {
                    health = "healthy";
                }
            }
            Event::BlockAlloc { block } => {
                if live_blocks.insert(*block, t).is_some() {
                    r.violations.push(format!("block {block}: allocated while live"));
                }
            }
            Event::BlockFree { block } => {
                if live_blocks.remove(block).is_none() {
                    r.violations.push(format!("block {block}: freed while free"));
                }
            }
            Event::CowCopy { .. } => r.cow_copies += 1,
            Event::PrefixHit { .. } => r.prefix_hits += 1,
            Event::VerifyRound { k, accepted, .. } => {
                r.verify_rounds += 1;
                if accepted > k {
                    r.violations
                        .push(format!("tick {t}: verify accepted {accepted} > drafted {k}"));
                }
            }
            // informational: no conservation law attaches
            Event::PrefillWindow { .. }
            | Event::Rewind { .. }
            | Event::Evict { .. }
            | Event::Gauge { .. }
            | Event::SessionRun { .. } => {}
        }
    }

    for (req, l) in &lives {
        if l.deadline_miss && l.finish.is_none() {
            r.violations.push(format!("req {req}: deadline miss without a finish"));
        }
        if !l.failed && l.faults != l.retries {
            r.violations.push(format!(
                "req {req}: retry ledger broken at end of trace ({} faults, {} retries, no terminal Failed)",
                l.faults, l.retries
            ));
        }
        let (Some(enq), Some(admit)) = (l.enq, l.admit) else {
            if l.admit.is_some() {
                // already flagged above
            } else if !l.rejected && !l.cancelled && !l.failed && l.enq.is_some() {
                r.violations.push(format!("req {req}: enqueued but never admitted or rejected"));
            }
            continue;
        };
        if l.rejected {
            continue;
        }
        let Some(finish) = l.finish else {
            r.violations.push(format!("req {req}: admitted but never finished"));
            continue;
        };
        let Some(first) = l.first_tok else {
            r.violations.push(format!("req {req}: finished without a first token"));
            continue;
        };
        // tick order anchors on the *first* admission: TTFT is recorded
        // once per request, and a preempted request's final admit tick may
        // legitimately postdate its first-ever token
        let admit0 = l.first_admit.unwrap_or(admit);
        if !(enq <= admit0 && admit0 <= first && first <= finish) {
            r.violations.push(format!(
                "req {req}: tick order broken (enq {enq} ≤ admit {admit0} ≤ first {first} ≤ finish {finish})"
            ));
        }
        if let Some(ft) = l.finish_tokens {
            if ft != l.tokens {
                r.violations.push(format!(
                    "req {req}: {} DecodeStep tokens but Finish says {ft}",
                    l.tokens
                ));
            }
        }
    }
    // admission ledger: every admission ends in exactly one of finish /
    // preempt / mid-flight reject / terminal failure
    if r.admitted != r.finished + r.preempted + rejected_inflight + failed_inflight {
        r.violations.push(format!(
            "admission ledger broken: {} admits != {} finishes + {} preempts + {} mid-flight rejects + {} fails",
            r.admitted, r.finished, r.preempted, rejected_inflight, failed_inflight
        ));
    }
    if health == "degraded" {
        r.violations
            .push("degradation never closed: trace ends degraded, not failing".to_string());
    }
    if !rows.is_empty() {
        let stuck: Vec<String> = rows.iter().map(|(row, req)| format!("{row}:req {req}")).collect();
        r.violations.push(format!("rows still occupied at end of trace: {}", stuck.join(", ")));
    }
    r.live_blocks = live_blocks.len();
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    fn st(tick: u64, ev: Event) -> Stamped {
        Stamped { tick, wall_ms: 0.0, ev }
    }

    #[test]
    fn clean_lifecycle_passes_and_reconstructs_latencies() {
        let evs = vec![
            st(0, Event::Enqueue { req: 0 }),
            st(0, Event::Admit { req: 0, row: 0 }),
            st(2, Event::DecodeStep { row: 0 }), // ttft = 2
            st(3, Event::DecodeStep { row: 0 }), // itl = 1
            st(5, Event::DecodeStep { row: 0 }), // itl = 2
            st(5, Event::Finish { req: 0, row: 0, tokens: 3 }),
        ];
        let a = audit(&evs);
        assert!(a.ok(), "unexpected violations: {:?}", a.violations);
        assert_eq!(a.ttft_ticks, vec![2]);
        assert_eq!(a.itl_ticks, vec![1, 2]);
        assert_eq!(a.tokens, 3);
        assert_eq!(a.finished, 1);
    }

    #[test]
    fn token_mismatch_and_orphan_rows_are_violations() {
        let evs = vec![
            st(0, Event::Enqueue { req: 0 }),
            st(0, Event::Admit { req: 0, row: 0 }),
            st(1, Event::DecodeStep { row: 0 }),
            st(1, Event::DecodeStep { row: 3 }), // unoccupied row
            st(2, Event::Finish { req: 0, row: 0, tokens: 9 }), // wrong total
            st(2, Event::Enqueue { req: 1 }),
            st(2, Event::Admit { req: 1, row: 1 }), // never finishes
        ];
        let a = audit(&evs);
        assert!(!a.ok());
        let text = a.violations.join("\n");
        assert!(text.contains("unoccupied row 3"), "{text}");
        assert!(text.contains("Finish says 9"), "{text}");
        assert!(text.contains("req 1: admitted but never finished"), "{text}");
        assert!(text.contains("rows still occupied"), "{text}");
    }

    #[test]
    fn block_discipline_is_enforced() {
        let evs = vec![
            st(0, Event::BlockAlloc { block: 4 }),
            st(0, Event::BlockAlloc { block: 4 }), // double alloc
            st(1, Event::BlockFree { block: 4 }),
            st(1, Event::BlockFree { block: 7 }), // free of a dead block
            st(2, Event::BlockAlloc { block: 5 }), // stays live at end
        ];
        let a = audit(&evs);
        let text = a.violations.join("\n");
        assert!(text.contains("block 4: allocated while live"), "{text}");
        assert!(text.contains("block 7: freed while free"), "{text}");
        assert_eq!(a.live_blocks, 1);
    }

    #[test]
    fn preempt_conserves_tokens_and_frees_row_for_reuse() {
        let evs = vec![
            st(0, Event::Enqueue { req: 0 }),
            st(0, Event::Admit { req: 0, row: 0 }),
            st(1, Event::DecodeStep { row: 0 }), // ttft = 1 (first-ever token)
            st(2, Event::DecodeStep { row: 0 }), // itl = 1
            st(3, Event::Preempt { req: 0, row: 0, tokens: 2 }),
            st(3, Event::Evict { row: 0 }),
            st(3, Event::Enqueue { req: 1 }),
            st(3, Event::Admit { req: 1, row: 0 }), // freed row is reusable
            st(4, Event::DecodeStep { row: 0 }),
            st(4, Event::Finish { req: 1, row: 0, tokens: 1 }),
            st(5, Event::Admit { req: 0, row: 1 }), // re-admit after preempt
            st(6, Event::DecodeStep { row: 1 }),    // no TTFT (already recorded)
            st(7, Event::DecodeStep { row: 1 }),    // itl = 1, no cross-life gap
            st(8, Event::DecodeStep { row: 1 }),
            st(8, Event::Finish { req: 0, row: 1, tokens: 3 }),
        ];
        let a = audit(&evs);
        assert!(a.ok(), "unexpected violations: {:?}", a.violations);
        assert_eq!(a.preempted, 1);
        assert_eq!(a.preempted_tokens, 2);
        // global conservation: DecodeSteps == finish tokens + discarded
        assert_eq!(a.tokens, 3 + 1 + 2);
        assert_eq!(a.ttft_ticks, vec![1, 1]);
        // req 0's ITL gaps never span the preemption boundary
        assert_eq!(a.itl_ticks, vec![1, 1, 1]);
    }

    #[test]
    fn preempt_token_lie_and_unadmitted_preempt_are_violations() {
        let evs = vec![
            st(0, Event::Enqueue { req: 0 }),
            st(0, Event::Admit { req: 0, row: 0 }),
            st(1, Event::DecodeStep { row: 0 }),
            st(2, Event::Preempt { req: 0, row: 0, tokens: 5 }), // lies: sampled 1
            st(3, Event::Preempt { req: 0, row: 2, tokens: 0 }), // not admitted
        ];
        let a = audit(&evs);
        let text = a.violations.join("\n");
        assert!(text.contains("Preempt says 5 tokens but life sampled 1"), "{text}");
        assert!(text.contains("preempt on unoccupied row 2"), "{text}");
        assert!(text.contains("preempted while not admitted"), "{text}");
    }

    #[test]
    fn cancel_is_terminal_and_pre_admission() {
        let clean = vec![
            st(0, Event::Enqueue { req: 0 }),
            st(4, Event::Cancel { req: 0 }),
        ];
        let a = audit(&clean);
        assert!(a.ok(), "unexpected violations: {:?}", a.violations);
        assert_eq!(a.cancelled, 1);

        let bad = vec![
            st(0, Event::Enqueue { req: 0 }),
            st(0, Event::Admit { req: 0, row: 0 }),
            st(1, Event::Cancel { req: 0 }), // in flight: not cancellable
            st(2, Event::Admit { req: 0, row: 1 }), // nothing after cancel
        ];
        let text = audit(&bad).violations.join("\n");
        assert!(text.contains("cancelled while in flight"), "{text}");
        assert!(text.contains("admitted after cancel"), "{text}");
    }

    #[test]
    fn deadline_miss_requires_a_finish_and_admission_ledger_balances() {
        let evs = vec![
            st(0, Event::Enqueue { req: 0 }),
            st(0, Event::Admit { req: 0, row: 0 }),
            st(9, Event::DecodeStep { row: 0 }),
            st(9, Event::DeadlineMiss { req: 0 }),
            st(9, Event::Finish { req: 0, row: 0, tokens: 1 }),
        ];
        let a = audit(&evs);
        assert!(a.ok(), "unexpected violations: {:?}", a.violations);
        assert_eq!(a.deadline_misses, 1);

        let orphan = audit(&[st(0, Event::DeadlineMiss { req: 3 })]);
        assert!(orphan
            .violations
            .iter()
            .any(|v| v.contains("deadline miss without a finish")));

        // an admission with no terminal event breaks the ledger
        let open = audit(&[
            st(0, Event::Enqueue { req: 0 }),
            st(0, Event::Admit { req: 0, row: 0 }),
        ]);
        assert!(open.violations.iter().any(|v| v.contains("admission ledger broken")));
    }

    #[test]
    fn mid_flight_reject_frees_the_row() {
        let evs = vec![
            st(0, Event::Enqueue { req: 0 }),
            st(0, Event::Admit { req: 0, row: 0 }),
            st(1, Event::Reject { req: 0 }),
            st(1, Event::Enqueue { req: 1 }),
            st(1, Event::Admit { req: 1, row: 0 }),
            st(2, Event::DecodeStep { row: 0 }),
            st(2, Event::Finish { req: 1, row: 0, tokens: 1 }),
        ];
        let a = audit(&evs);
        assert!(a.ok(), "unexpected violations: {:?}", a.violations);
        assert_eq!(a.rejected, 1);
    }

    #[test]
    fn retry_ledger_clean_fault_retry_finish() {
        // retry-as-preempt: Fault → Preempt (conserve the life) → Retry,
        // then a fresh admission that finishes normally
        let evs = vec![
            st(0, Event::Enqueue { req: 0 }),
            st(0, Event::Admit { req: 0, row: 0 }),
            st(1, Event::DecodeStep { row: 0 }),
            st(2, Event::Fault { req: 0, row: 0, fault: "decode-transient" }),
            st(2, Event::Preempt { req: 0, row: 0, tokens: 1 }),
            st(2, Event::Retry { req: 0, attempt: 1 }),
            st(4, Event::Admit { req: 0, row: 0 }),
            st(5, Event::DecodeStep { row: 0 }),
            st(5, Event::Finish { req: 0, row: 0, tokens: 1 }),
        ];
        let a = audit(&evs);
        assert!(a.ok(), "unexpected violations: {:?}", a.violations);
        assert_eq!((a.faults, a.retries, a.failed), (1, 1, 0));
        assert_eq!(a.preempted_tokens, 1);
    }

    #[test]
    fn terminal_failed_conserves_tokens_and_balances_ledger() {
        let evs = vec![
            st(0, Event::Enqueue { req: 0 }),
            st(0, Event::Admit { req: 0, row: 0 }),
            st(1, Event::DecodeStep { row: 0 }),
            st(2, Event::Fault { req: 0, row: 0, fault: "decode-transient" }),
            st(2, Event::Failed { req: 0, tokens: 1, attempts: 1 }),
        ];
        let a = audit(&evs);
        assert!(a.ok(), "unexpected violations: {:?}", a.violations);
        assert_eq!(a.failed, 1);
        assert_eq!(a.failed_tokens, 1);
        // the in-flight Failed closed the admission and freed the row, so
        // the extended ledger balances and no "rows still occupied" fires
    }

    #[test]
    fn queue_failed_needs_no_admission() {
        // Failing-mode drain: queued requests fail with zero tokens and
        // zero attempts, and a trace may legally end in the failing state
        let evs = vec![
            st(0, Event::Enqueue { req: 0 }),
            st(1, Event::Degrade { level: "failing" }),
            st(1, Event::Failed { req: 0, tokens: 0, attempts: 0 }),
        ];
        let a = audit(&evs);
        assert!(a.ok(), "unexpected violations: {:?}", a.violations);
        assert_eq!(a.failed, 1);
    }

    #[test]
    fn retry_ledger_violations_fire() {
        // Retry with no pending fault
        let t1 = audit(&[
            st(0, Event::Enqueue { req: 0 }),
            st(0, Event::Admit { req: 0, row: 0 }),
            st(1, Event::Retry { req: 0, attempt: 1 }),
        ])
        .violations
        .join("\n");
        assert!(t1.contains("retry without a pending fault"), "{t1}");

        // Failed lies about both conserved quantities
        let t2 = audit(&[
            st(0, Event::Enqueue { req: 0 }),
            st(0, Event::Admit { req: 0, row: 0 }),
            st(1, Event::DecodeStep { row: 0 }),
            st(2, Event::Fault { req: 0, row: 0, fault: "decode-transient" }),
            st(2, Event::Failed { req: 0, tokens: 7, attempts: 3 }),
        ])
        .violations
        .join("\n");
        assert!(t2.contains("Failed says 7 tokens but life sampled 1"), "{t2}");
        assert!(t2.contains("Failed says 3 attempts but life took 1 faults"), "{t2}");

        // a fault with no answering Retry or Failed dangles at EOF
        let t3 = audit(&[
            st(0, Event::Enqueue { req: 0 }),
            st(0, Event::Admit { req: 0, row: 0 }),
            st(1, Event::Fault { req: 0, row: 0, fault: "stuck-tick" }),
            st(2, Event::DecodeStep { row: 0 }),
            st(2, Event::Finish { req: 0, row: 0, tokens: 1 }),
        ])
        .violations
        .join("\n");
        assert!(t3.contains("retry ledger broken at end of trace"), "{t3}");
    }

    #[test]
    fn failure_is_terminal() {
        let evs = vec![
            st(0, Event::Enqueue { req: 0 }),
            st(0, Event::Admit { req: 0, row: 0 }),
            st(1, Event::Fault { req: 0, row: 0, fault: "decode-transient" }),
            st(1, Event::Failed { req: 0, tokens: 0, attempts: 1 }),
            st(2, Event::Enqueue { req: 0 }), // anything naming the req trips law 10
        ];
        let text = audit(&evs).violations.join("\n");
        assert!(text.contains("Enqueue after Failed (failure is terminal)"), "{text}");
    }

    #[test]
    fn degradation_brackets_are_enforced() {
        let clean = audit(&[
            st(0, Event::Degrade { level: "degraded" }),
            st(2, Event::Recover {}),
            st(3, Event::Degrade { level: "degraded" }),
            st(4, Event::Degrade { level: "failing" }), // ending failing is legal
        ]);
        assert!(clean.ok(), "unexpected violations: {:?}", clean.violations);
        assert_eq!(clean.degrades, 3);

        let text = audit(&[st(0, Event::Recover {})]).violations.join("\n");
        assert!(text.contains("recover while healthy"), "{text}");

        let text =
            audit(&[st(0, Event::Degrade { level: "degraded" })]).violations.join("\n");
        assert!(text.contains("degradation never closed"), "{text}");

        let text = audit(&[
            st(0, Event::Degrade { level: "failing" }),
            st(1, Event::Recover {}),
        ])
        .violations
        .join("\n");
        assert!(text.contains("recover from failing (failing is terminal)"), "{text}");
    }
}

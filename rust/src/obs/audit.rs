//! In-process trace auditor — the Rust mirror of `tools/trace_report.py`.
//!
//! Replays a raw event stream and checks the scheduler's conservation
//! laws, reconstructing the TTFT/ITL tick distributions exactly as
//! `serve::Server::step` accumulates them (same per-row first-token /
//! last-token-tick state machine, same event order), so tests can assert
//! `audit.ttft_ticks == stats.ttft_ticks` element-for-element. The Python
//! tool applies the identical rules to exported traces; this module is
//! what lets `cargo test` enforce them without Python.
//!
//! Laws checked (violations are human-readable strings):
//! 1. per request: enqueue ≤ admit ≤ first-token ≤ finish (tick order)
//! 2. token conservation: DecodeStep count per request == `Finish.tokens`
//! 3. lifecycle: every admitted request finishes or is rejected; no
//!    decode on an unoccupied row; no double-admit of a live row
//! 4. block discipline: no alloc of a live block, no free of a dead one
//!    (end-of-run residency is reported, not judged — the prefix index
//!    legitimately holds blocks across requests)
//! 5. `cow_copies` is reported for the caller to judge (0 under serve —
//!    the §2f share-only-full-blocks invariant)

use super::trace::{Event, Stamped};
use std::collections::BTreeMap;

#[derive(Debug, Default)]
struct Life {
    enq: Option<u64>,
    admit: Option<u64>,
    first_tok: Option<u64>,
    last_tok: Option<u64>,
    finish: Option<u64>,
    tokens: usize,
    finish_tokens: Option<usize>,
    rejected: bool,
}

/// Replay result: violations plus the reconstructed distributions.
#[derive(Debug, Default)]
pub struct AuditReport {
    pub violations: Vec<String>,
    /// enqueue → first-token tick counts, in `Server::step` push order
    pub ttft_ticks: Vec<usize>,
    /// inter-token tick gaps, in `Server::step` push order
    pub itl_ticks: Vec<usize>,
    pub enqueued: usize,
    pub admitted: usize,
    pub finished: usize,
    pub rejected: usize,
    pub requeues: usize,
    pub tokens: usize,
    /// blocks still allocated when the trace ends
    pub live_blocks: usize,
    pub cow_copies: usize,
    pub prefix_hits: usize,
    pub verify_rounds: usize,
}

impl AuditReport {
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Replay `events` (chronological emission order, as a `TraceSink` stores
/// them) and check every conservation law.
pub fn audit(events: &[Stamped]) -> AuditReport {
    let mut r = AuditReport::default();
    let mut lives: BTreeMap<u64, Life> = BTreeMap::new();
    // engine row -> occupant request
    let mut rows: BTreeMap<usize, u64> = BTreeMap::new();
    let mut live_blocks: BTreeMap<usize, u64> = BTreeMap::new();

    for s in events {
        let t = s.tick;
        match &s.ev {
            Event::Enqueue { req } => {
                r.enqueued += 1;
                let l = lives.entry(*req).or_default();
                if l.enq.is_some() {
                    r.violations.push(format!("req {req}: enqueued twice"));
                }
                l.enq = Some(t);
            }
            Event::Requeue { .. } => r.requeues += 1,
            Event::Admit { req, row } => {
                r.admitted += 1;
                if let Some(prev) = rows.get(row) {
                    r.violations
                        .push(format!("row {row}: admit req {req} over live req {prev}"));
                }
                rows.insert(*row, *req);
                let l = lives.entry(*req).or_default();
                if l.admit.is_some() {
                    r.violations.push(format!("req {req}: admitted twice"));
                }
                match l.enq {
                    None => r.violations.push(format!("req {req}: admitted, never enqueued")),
                    Some(e) if t < e => {
                        r.violations.push(format!("req {req}: admit tick {t} < enqueue {e}"))
                    }
                    _ => {}
                }
                l.admit = Some(t);
            }
            Event::Reject { req } => {
                r.rejected += 1;
                let l = lives.entry(*req).or_default();
                l.rejected = true;
                // mid-flight rejection frees the row
                if let Some(&row) =
                    rows.iter().find_map(|(row, occ)| (occ == req).then_some(row))
                {
                    rows.remove(&row);
                }
            }
            Event::DecodeStep { row } => {
                r.tokens += 1;
                let Some(req) = rows.get(row).copied() else {
                    r.violations.push(format!("tick {t}: token on unoccupied row {row}"));
                    continue;
                };
                let l = lives.entry(req).or_default();
                l.tokens += 1;
                // exact Server::step replication: TTFT on the first token,
                // an ITL gap for every token with a predecessor
                if l.first_tok.is_none() {
                    l.first_tok = Some(t);
                    let enq = l.enq.unwrap_or(t);
                    r.ttft_ticks.push((t - enq.min(t)) as usize);
                }
                if let Some(last) = l.last_tok {
                    r.itl_ticks.push((t - last.min(t)) as usize);
                }
                l.last_tok = Some(t);
            }
            Event::Finish { req, row, tokens } => {
                r.finished += 1;
                match rows.remove(row) {
                    None => {
                        r.violations.push(format!("req {req}: finish on unoccupied row {row}"))
                    }
                    Some(occ) if occ != *req => r.violations.push(format!(
                        "row {row}: finish req {req} but occupant is req {occ}"
                    )),
                    _ => {}
                }
                let l = lives.entry(*req).or_default();
                l.finish = Some(t);
                l.finish_tokens = Some(*tokens);
            }
            Event::BlockAlloc { block } => {
                if live_blocks.insert(*block, t).is_some() {
                    r.violations.push(format!("block {block}: allocated while live"));
                }
            }
            Event::BlockFree { block } => {
                if live_blocks.remove(block).is_none() {
                    r.violations.push(format!("block {block}: freed while free"));
                }
            }
            Event::CowCopy { .. } => r.cow_copies += 1,
            Event::PrefixHit { .. } => r.prefix_hits += 1,
            Event::VerifyRound { k, accepted, .. } => {
                r.verify_rounds += 1;
                if accepted > k {
                    r.violations
                        .push(format!("tick {t}: verify accepted {accepted} > drafted {k}"));
                }
            }
            // informational: no conservation law attaches
            Event::PrefillWindow { .. }
            | Event::Rewind { .. }
            | Event::Evict { .. }
            | Event::Gauge { .. }
            | Event::SessionRun { .. } => {}
        }
    }

    for (req, l) in &lives {
        let (Some(enq), Some(admit)) = (l.enq, l.admit) else {
            if l.admit.is_some() {
                // already flagged above
            } else if !l.rejected && l.enq.is_some() {
                r.violations.push(format!("req {req}: enqueued but never admitted or rejected"));
            }
            continue;
        };
        if l.rejected {
            continue;
        }
        let Some(finish) = l.finish else {
            r.violations.push(format!("req {req}: admitted but never finished"));
            continue;
        };
        let Some(first) = l.first_tok else {
            r.violations.push(format!("req {req}: finished without a first token"));
            continue;
        };
        if !(enq <= admit && admit <= first && first <= finish) {
            r.violations.push(format!(
                "req {req}: tick order broken (enq {enq} ≤ admit {admit} ≤ first {first} ≤ finish {finish})"
            ));
        }
        if let Some(ft) = l.finish_tokens {
            if ft != l.tokens {
                r.violations.push(format!(
                    "req {req}: {} DecodeStep tokens but Finish says {ft}",
                    l.tokens
                ));
            }
        }
    }
    if !rows.is_empty() {
        let stuck: Vec<String> = rows.iter().map(|(row, req)| format!("{row}:req {req}")).collect();
        r.violations.push(format!("rows still occupied at end of trace: {}", stuck.join(", ")));
    }
    r.live_blocks = live_blocks.len();
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    fn st(tick: u64, ev: Event) -> Stamped {
        Stamped { tick, wall_ms: 0.0, ev }
    }

    #[test]
    fn clean_lifecycle_passes_and_reconstructs_latencies() {
        let evs = vec![
            st(0, Event::Enqueue { req: 0 }),
            st(0, Event::Admit { req: 0, row: 0 }),
            st(2, Event::DecodeStep { row: 0 }), // ttft = 2
            st(3, Event::DecodeStep { row: 0 }), // itl = 1
            st(5, Event::DecodeStep { row: 0 }), // itl = 2
            st(5, Event::Finish { req: 0, row: 0, tokens: 3 }),
        ];
        let a = audit(&evs);
        assert!(a.ok(), "unexpected violations: {:?}", a.violations);
        assert_eq!(a.ttft_ticks, vec![2]);
        assert_eq!(a.itl_ticks, vec![1, 2]);
        assert_eq!(a.tokens, 3);
        assert_eq!(a.finished, 1);
    }

    #[test]
    fn token_mismatch_and_orphan_rows_are_violations() {
        let evs = vec![
            st(0, Event::Enqueue { req: 0 }),
            st(0, Event::Admit { req: 0, row: 0 }),
            st(1, Event::DecodeStep { row: 0 }),
            st(1, Event::DecodeStep { row: 3 }), // unoccupied row
            st(2, Event::Finish { req: 0, row: 0, tokens: 9 }), // wrong total
            st(2, Event::Enqueue { req: 1 }),
            st(2, Event::Admit { req: 1, row: 1 }), // never finishes
        ];
        let a = audit(&evs);
        assert!(!a.ok());
        let text = a.violations.join("\n");
        assert!(text.contains("unoccupied row 3"), "{text}");
        assert!(text.contains("Finish says 9"), "{text}");
        assert!(text.contains("req 1: admitted but never finished"), "{text}");
        assert!(text.contains("rows still occupied"), "{text}");
    }

    #[test]
    fn block_discipline_is_enforced() {
        let evs = vec![
            st(0, Event::BlockAlloc { block: 4 }),
            st(0, Event::BlockAlloc { block: 4 }), // double alloc
            st(1, Event::BlockFree { block: 4 }),
            st(1, Event::BlockFree { block: 7 }), // free of a dead block
            st(2, Event::BlockAlloc { block: 5 }), // stays live at end
        ];
        let a = audit(&evs);
        let text = a.violations.join("\n");
        assert!(text.contains("block 4: allocated while live"), "{text}");
        assert!(text.contains("block 7: freed while free"), "{text}");
        assert_eq!(a.live_blocks, 1);
    }

    #[test]
    fn mid_flight_reject_frees_the_row() {
        let evs = vec![
            st(0, Event::Enqueue { req: 0 }),
            st(0, Event::Admit { req: 0, row: 0 }),
            st(1, Event::Reject { req: 0 }),
            st(1, Event::Enqueue { req: 1 }),
            st(1, Event::Admit { req: 1, row: 0 }),
            st(2, Event::DecodeStep { row: 0 }),
            st(2, Event::Finish { req: 1, row: 0, tokens: 1 }),
        ];
        let a = audit(&evs);
        assert!(a.ok(), "unexpected violations: {:?}", a.violations);
        assert_eq!(a.rejected, 1);
    }
}

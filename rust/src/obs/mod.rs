//! Observability: request-lifecycle tracing + the unified metrics registry
//! (DESIGN.md §2g).
//!
//! * [`trace`] — typed scheduler events into a bounded thread-local ring,
//!   off by default and zero-cost when disabled (dual tick/wall clocks;
//!   sim traces are byte-deterministic)
//! * [`metrics`] — counters/gauges/histograms registry; the single export
//!   path behind `BENCH_serve.json`, `tab8_serving.csv` and the serve
//!   summary
//! * [`export`] — Chrome trace-event JSON (Perfetto) + JSONL writers
//! * [`audit`] — in-process conservation-law checker, the Rust mirror of
//!   `tools/trace_report.py`

pub mod audit;
pub mod export;
pub mod metrics;
pub mod trace;

pub use metrics::Metrics;
pub use trace::{Event, Stamped, TraceSink};

//! Trace exporters: Chrome trace-event JSON (Perfetto-loadable) + JSONL.
//!
//! `serve --trace out.json` writes one Chrome trace-format object with
//! three extra top-level keys Perfetto ignores but `tools/trace_report.py`
//! reads: `loramEvents` (the raw typed events), `serverStats` (the
//! scheduler's own percentiles, for the bit-for-bit cross-check) and
//! `otherData` (clock domain, drop count, schema version). A compact
//! `out.jsonl` sibling carries the same raw events one-per-line.
//!
//! Chrome-trace mapping (all `ts` in the tick domain, 1 tick = 1000 µs so
//! Perfetto renders one tick per millisecond):
//! * request lifecycle → `B`/`E` span "req N" on the row's thread track
//! * `PrefillWindow`   → `X` slice on the row track (`args.start/bucket`)
//! * `DecodeStep` / `VerifyRound` / `Rewind` / `Evict` → thread instants
//! * queue events (`Enqueue`/`Reject`/`Requeue`/`Cancel`/`DeadlineMiss`)
//!   → instants on tid 0; `Preempt` closes the row span like a mid-flight
//!   reject and drops a scheduler instant
//! * block events → instants on the `kv-pool` track (tid 900)
//! * `SessionRun` → `X` on the `session` track (tid 901), dur = measured ms
//! * `Gauge` → `C` counter tracks (queue depth, in-flight, blocks in use)

use super::trace::{Event, Stamped, TraceSink};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// Trace file schema version (bump on breaking event/field changes).
pub const TRACE_SCHEMA_VERSION: u64 = 1;

pub const TID_SCHED: usize = 0;
pub const TID_KV: usize = 900;
pub const TID_SESSION: usize = 901;

fn row_tid(row: usize) -> usize {
    row + 1
}

/// One raw event as a flat JSON object: `tick`, `wall_ms`, `kind`, fields.
pub fn event_json(s: &Stamped) -> Json {
    let mut f: Vec<(&str, Json)> = vec![
        ("tick", Json::num(s.tick as f64)),
        ("wall_ms", Json::num(s.wall_ms)),
        ("kind", Json::str(s.ev.kind())),
    ];
    match &s.ev {
        Event::Enqueue { req }
        | Event::Reject { req }
        | Event::Requeue { req }
        | Event::Cancel { req }
        | Event::DeadlineMiss { req } => {
            f.push(("req", Json::num(*req as f64)));
        }
        Event::Admit { req, row } => {
            f.push(("req", Json::num(*req as f64)));
            f.push(("row", Json::num(*row as f64)));
        }
        Event::PrefillWindow { row, start, bucket } => {
            f.push(("row", Json::num(*row as f64)));
            f.push(("start", Json::num(*start as f64)));
            f.push(("bucket", Json::num(*bucket as f64)));
        }
        Event::DecodeStep { row } | Event::Evict { row } => {
            f.push(("row", Json::num(*row as f64)));
        }
        Event::VerifyRound { row, k, accepted } => {
            f.push(("row", Json::num(*row as f64)));
            f.push(("k", Json::num(*k as f64)));
            f.push(("accepted", Json::num(*accepted as f64)));
        }
        Event::Rewind { row, n } => {
            f.push(("row", Json::num(*row as f64)));
            f.push(("n", Json::num(*n as f64)));
        }
        Event::Finish { req, row, tokens } | Event::Preempt { req, row, tokens } => {
            f.push(("req", Json::num(*req as f64)));
            f.push(("row", Json::num(*row as f64)));
            f.push(("tokens", Json::num(*tokens as f64)));
        }
        Event::BlockAlloc { block } | Event::BlockFree { block } | Event::CowCopy { block } => {
            f.push(("block", Json::num(*block as f64)));
        }
        Event::PrefixHit { blocks, tokens } => {
            f.push(("blocks", Json::num(*blocks as f64)));
            f.push(("tokens", Json::num(*tokens as f64)));
        }
        Event::Gauge { name, value } => {
            f.push(("name", Json::str(*name)));
            f.push(("value", Json::num(*value)));
        }
        Event::SessionRun { artifact, h2d_ms, exec_ms, d2h_ms } => {
            f.push(("artifact", Json::str(artifact.clone())));
            f.push(("h2d_ms", Json::num(*h2d_ms)));
            f.push(("exec_ms", Json::num(*exec_ms)));
            f.push(("d2h_ms", Json::num(*d2h_ms)));
        }
        Event::Fault { req, row, fault } => {
            f.push(("req", Json::num(*req as f64)));
            f.push(("row", Json::num(*row as f64)));
            f.push(("fault", Json::str(*fault)));
        }
        Event::Retry { req, attempt } => {
            f.push(("req", Json::num(*req as f64)));
            f.push(("attempt", Json::num(*attempt as f64)));
        }
        Event::Failed { req, tokens, attempts } => {
            f.push(("req", Json::num(*req as f64)));
            f.push(("tokens", Json::num(*tokens as f64)));
            f.push(("attempts", Json::num(*attempts as f64)));
        }
        Event::Degrade { level } => {
            f.push(("level", Json::str(*level)));
        }
        Event::Recover {} => {}
    }
    Json::obj(f)
}

/// Compact event log: one `event_json` object per line.
pub fn jsonl(events: &[Stamped]) -> String {
    let mut out = String::new();
    for s in events {
        out.push_str(&event_json(s).to_string());
        out.push('\n');
    }
    out
}

fn ts(tick: u64) -> f64 {
    (tick * 1000) as f64
}

fn te(name: &str, ph: &str, tick: u64, tid: usize, args: Vec<(&str, Json)>) -> Json {
    let mut f = vec![
        ("name", Json::str(name)),
        ("ph", Json::str(ph)),
        ("ts", Json::num(ts(tick))),
        ("pid", Json::num(0.0)),
        ("tid", Json::num(tid as f64)),
    ];
    if ph == "i" {
        f.push(("s", Json::str("t"))); // thread-scoped instant
    }
    if !args.is_empty() {
        f.push(("args", Json::obj(args)));
    }
    Json::obj(f)
}

fn meta_thread(tid: usize, name: &str) -> Json {
    Json::obj(vec![
        ("name", Json::str("thread_name")),
        ("ph", Json::str("M")),
        ("pid", Json::num(0.0)),
        ("tid", Json::num(tid as f64)),
        ("args", Json::obj(vec![("name", Json::str(name))])),
    ])
}

/// Build the Chrome trace-event array from raw events.
pub fn chrome_events(events: &[Stamped]) -> Vec<Json> {
    let mut out: Vec<Json> = Vec::new();
    // open request spans: row -> (req, admit tick)
    let mut open: BTreeMap<usize, (u64, u64)> = BTreeMap::new();
    // admitted request -> row, for closing the span on a mid-flight Reject
    let mut req_row: BTreeMap<u64, usize> = BTreeMap::new();
    let mut used_rows: Vec<usize> = Vec::new();
    let mut saw_kv = false;
    let mut saw_session = false;
    let mut last_tick: u64 = 0;

    for s in events {
        last_tick = last_tick.max(s.tick);
        match &s.ev {
            Event::Enqueue { req } => {
                out.push(te(&format!("enqueue req {req}"), "i", s.tick, TID_SCHED, vec![]));
            }
            Event::Requeue { req } => {
                out.push(te(&format!("requeue req {req}"), "i", s.tick, TID_SCHED, vec![]));
            }
            Event::Reject { req } => {
                out.push(te(&format!("reject req {req}"), "i", s.tick, TID_SCHED, vec![]));
                if let Some(row) = req_row.remove(req) {
                    // mid-flight failure: close the open span
                    if open.remove(&row).is_some() {
                        out.push(te(&format!("req {req}"), "E", s.tick, row_tid(row), vec![]));
                    }
                }
            }
            Event::Admit { req, row } => {
                if !used_rows.contains(row) {
                    used_rows.push(*row);
                }
                open.insert(*row, (*req, s.tick));
                req_row.insert(*req, *row);
                out.push(te(
                    &format!("req {req}"),
                    "B",
                    s.tick,
                    row_tid(*row),
                    vec![("req", Json::num(*req as f64))],
                ));
            }
            Event::Finish { req, row, tokens } => {
                open.remove(row);
                req_row.remove(req);
                out.push(te(
                    &format!("req {req}"),
                    "E",
                    s.tick,
                    row_tid(*row),
                    vec![("tokens", Json::num(*tokens as f64))],
                ));
            }
            Event::Preempt { req, row, tokens } => {
                // preemption closes the span; a later re-admit opens a new one
                open.remove(row);
                req_row.remove(req);
                out.push(te(
                    &format!("req {req}"),
                    "E",
                    s.tick,
                    row_tid(*row),
                    vec![("preempted_tokens", Json::num(*tokens as f64))],
                ));
                out.push(te(&format!("preempt req {req}"), "i", s.tick, TID_SCHED, vec![]));
            }
            Event::Cancel { req } => {
                out.push(te(&format!("cancel req {req}"), "i", s.tick, TID_SCHED, vec![]));
            }
            Event::DeadlineMiss { req } => {
                out.push(te(&format!("deadline miss req {req}"), "i", s.tick, TID_SCHED, vec![]));
            }
            Event::PrefillWindow { row, start, bucket } => {
                let mut e = te(
                    &format!("prefill[{bucket}]"),
                    "X",
                    s.tick,
                    row_tid(*row),
                    vec![
                        ("start", Json::num(*start as f64)),
                        ("bucket", Json::num(*bucket as f64)),
                    ],
                );
                if let Json::Obj(m) = &mut e {
                    m.insert("dur".to_string(), Json::num(1000.0));
                }
                out.push(e);
            }
            Event::DecodeStep { row } => {
                out.push(te("tok", "i", s.tick, row_tid(*row), vec![]));
            }
            Event::VerifyRound { row, k, accepted } => {
                out.push(te(
                    "verify",
                    "i",
                    s.tick,
                    row_tid(*row),
                    vec![
                        ("k", Json::num(*k as f64)),
                        ("accepted", Json::num(*accepted as f64)),
                    ],
                ));
            }
            Event::Rewind { row, n } => {
                out.push(te("rewind", "i", s.tick, row_tid(*row), vec![(
                    "n",
                    Json::num(*n as f64),
                )]));
            }
            Event::Evict { row } => {
                out.push(te("evict", "i", s.tick, row_tid(*row), vec![]));
            }
            Event::BlockAlloc { block } => {
                saw_kv = true;
                out.push(te("alloc", "i", s.tick, TID_KV, vec![(
                    "block",
                    Json::num(*block as f64),
                )]));
            }
            Event::BlockFree { block } => {
                saw_kv = true;
                out.push(te("free", "i", s.tick, TID_KV, vec![(
                    "block",
                    Json::num(*block as f64),
                )]));
            }
            Event::PrefixHit { blocks, tokens } => {
                saw_kv = true;
                out.push(te("prefix_hit", "i", s.tick, TID_KV, vec![
                    ("blocks", Json::num(*blocks as f64)),
                    ("tokens", Json::num(*tokens as f64)),
                ]));
            }
            Event::CowCopy { block } => {
                saw_kv = true;
                out.push(te("cow_copy", "i", s.tick, TID_KV, vec![(
                    "block",
                    Json::num(*block as f64),
                )]));
            }
            Event::Gauge { name, value } => {
                out.push(Json::obj(vec![
                    ("name", Json::str(*name)),
                    ("ph", Json::str("C")),
                    ("ts", Json::num(ts(s.tick))),
                    ("pid", Json::num(0.0)),
                    ("args", Json::obj(vec![(*name, Json::num(*value))])),
                ]));
            }
            Event::SessionRun { artifact, h2d_ms, exec_ms, d2h_ms } => {
                saw_session = true;
                let mut e = te(
                    artifact,
                    "X",
                    s.tick,
                    TID_SESSION,
                    vec![
                        ("h2d_ms", Json::num(*h2d_ms)),
                        ("exec_ms", Json::num(*exec_ms)),
                        ("d2h_ms", Json::num(*d2h_ms)),
                    ],
                );
                if let Json::Obj(m) = &mut e {
                    // ms rendered in the tick µs domain (1 ms = 1000 µs)
                    let dur = ((h2d_ms + exec_ms + d2h_ms) * 1000.0).max(1.0);
                    m.insert("dur".to_string(), Json::num(dur));
                }
                out.push(e);
            }
            Event::Fault { req, row, fault } => {
                out.push(te(&format!("fault[{fault}] req {req}"), "i", s.tick, row_tid(*row), vec![]));
            }
            Event::Retry { req, attempt } => {
                out.push(te(&format!("retry req {req} #{attempt}"), "i", s.tick, TID_SCHED, vec![]));
            }
            Event::Failed { req, tokens, attempts } => {
                // terminal failure closes the open span like a mid-flight reject
                if let Some(row) = req_row.remove(req) {
                    if open.remove(&row).is_some() {
                        out.push(te(&format!("req {req}"), "E", s.tick, row_tid(row), vec![]));
                    }
                }
                out.push(te(&format!("failed req {req}"), "i", s.tick, TID_SCHED, vec![
                    ("tokens", Json::num(*tokens as f64)),
                    ("attempts", Json::num(*attempts as f64)),
                ]));
            }
            Event::Degrade { level } => {
                out.push(te(&format!("degrade[{level}]"), "i", s.tick, TID_SCHED, vec![]));
            }
            Event::Recover {} => {
                out.push(te("recover", "i", s.tick, TID_SCHED, vec![]));
            }
        }
    }
    // close spans still open at end-of-trace so Perfetto renders them
    for (row, (req, _)) in &open {
        out.push(te(&format!("req {req}"), "E", last_tick + 1, row_tid(*row), vec![]));
    }
    // thread-name metadata
    let mut meta = vec![Json::obj(vec![
        ("name", Json::str("process_name")),
        ("ph", Json::str("M")),
        ("pid", Json::num(0.0)),
        ("args", Json::obj(vec![("name", Json::str("loram-serve"))])),
    ])];
    meta.push(meta_thread(TID_SCHED, "scheduler"));
    used_rows.sort_unstable();
    for row in used_rows {
        meta.push(meta_thread(row_tid(row), &format!("row {row}")));
    }
    if saw_kv {
        meta.push(meta_thread(TID_KV, "kv-pool"));
    }
    if saw_session {
        meta.push(meta_thread(TID_SESSION, "session"));
    }
    meta.extend(out);
    meta
}

/// Full trace-file JSON: Chrome `traceEvents` plus the raw-event /
/// stats side-channels read by `tools/trace_report.py`. `extra` carries
/// caller context, e.g. `("serverStats", ...)`.
pub fn trace_json(sink: &TraceSink, extra: Vec<(&str, Json)>) -> Json {
    let events: Vec<Stamped> = sink.events().iter().cloned().collect();
    let mut top = vec![
        ("displayTimeUnit", Json::str("ms")),
        ("traceEvents", Json::Arr(chrome_events(&events))),
        ("loramEvents", Json::Arr(events.iter().map(event_json).collect())),
        (
            "otherData",
            Json::obj(vec![
                ("schema_version", Json::num(TRACE_SCHEMA_VERSION as f64)),
                ("clock", Json::str(if sink.wall_clock() { "wall" } else { "tick" })),
                ("dropped", Json::num(sink.dropped() as f64)),
            ]),
        ),
    ];
    top.extend(extra);
    Json::obj(top)
}

/// Write `path` (Chrome trace) and a `.jsonl` sibling (compact event log).
/// Returns the jsonl path.
pub fn write_trace_files(
    path: &Path,
    sink: &TraceSink,
    extra: Vec<(&str, Json)>,
) -> std::io::Result<PathBuf> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let json = trace_json(sink, extra);
    let mut f = std::fs::File::create(path)?;
    f.write_all(json.to_string().as_bytes())?;
    f.write_all(b"\n")?;
    let jsonl_path = path.with_extension("jsonl");
    let events: Vec<Stamped> = sink.events().iter().cloned().collect();
    std::fs::write(&jsonl_path, jsonl(&events))?;
    Ok(jsonl_path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace;

    fn sample_sink() -> TraceSink {
        trace::install(1024, false);
        trace::set_tick(0);
        trace::emit(|| Event::Enqueue { req: 1 });
        trace::emit(|| Event::Admit { req: 1, row: 0 });
        trace::set_tick(1);
        trace::emit(|| Event::PrefillWindow { row: 0, start: 0, bucket: 16 });
        trace::set_tick(2);
        trace::emit(|| Event::DecodeStep { row: 0 });
        trace::emit(|| Event::Gauge { name: "queue_depth", value: 0.0 });
        trace::set_tick(3);
        trace::emit(|| Event::Finish { req: 1, row: 0, tokens: 1 });
        trace::take().unwrap()
    }

    #[test]
    fn chrome_trace_has_spans_counters_and_metadata() {
        let sink = sample_sink();
        let j = trace_json(&sink, vec![("serverStats", Json::obj(vec![]))]);
        let s = j.to_string();
        // parses back as valid JSON
        let parsed = Json::parse(&s).unwrap();
        let evs = parsed.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        let phs: Vec<&str> =
            evs.iter().filter_map(|e| e.get("ph").and_then(|p| p.as_str())).collect();
        assert!(phs.contains(&"B") && phs.contains(&"E"), "request span missing");
        assert!(phs.contains(&"X"), "prefill slice missing");
        assert!(phs.contains(&"C"), "counter track missing");
        assert!(phs.contains(&"M"), "thread metadata missing");
        // side-channels present
        assert!(parsed.get("loramEvents").and_then(|e| e.as_arr()).unwrap().len() == sink.len());
        assert!(parsed.get("serverStats").is_some());
        assert_eq!(
            parsed.get("otherData").and_then(|o| o.get("clock")).and_then(|c| c.as_str()),
            Some("tick")
        );
    }

    #[test]
    fn jsonl_is_one_event_per_line() {
        let sink = sample_sink();
        let events: Vec<Stamped> = sink.events().iter().cloned().collect();
        let text = jsonl(&events);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), sink.len());
        let first = Json::parse(lines[0]).unwrap();
        assert_eq!(first.get("kind").and_then(|k| k.as_str()), Some("Enqueue"));
        assert_eq!(first.get("tick").and_then(|t| t.as_f64()), Some(0.0));
    }

    #[test]
    fn export_is_deterministic_for_tick_clock_traces() {
        let a = {
            let sink = sample_sink();
            trace_json(&sink, vec![]).to_string()
        };
        let b = {
            let sink = sample_sink();
            trace_json(&sink, vec![]).to_string()
        };
        assert_eq!(a, b, "tick-clock trace export must be byte-deterministic");
    }
}

//! Host tensors, named tensor stores, and the `.lmck` checkpoint format.
//!
//! Artifacts speak f32/i32 only (see aot.py), so the host `Tensor` carries
//! those two dtypes in row-major layout. `TensorStore` is an *ordered* map
//! (BTreeMap on names) — but artifact packing order always comes from the
//! meta JSON, never from map order.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

pub mod checkpoint;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn from_str(s: &str) -> Result<Dtype> {
        match s {
            "float32" | "f32" => Ok(Dtype::F32),
            "int32" | "i32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype {other}"),
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: Data::F32(vec![0.0; shape.iter().product()]),
        }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor {
            shape: shape.to_vec(),
            data: Data::F32(data),
        }
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor {
            shape: shape.to_vec(),
            data: Data::I32(data),
        }
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::from_f32(&[], vec![v])
    }

    pub fn dtype(&self) -> Dtype {
        match self.data {
            Data::F32(_) => Dtype::F32,
            Data::I32(_) => Dtype::I32,
        }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn f32s(&self) -> &[f32] {
        match &self.data {
            Data::F32(v) => v,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn f32s_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            Data::F32(v) => v,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn i32s(&self) -> &[i32] {
        match &self.data {
            Data::I32(v) => v,
            _ => panic!("tensor is not i32"),
        }
    }

    /// (rows, cols) of a rank-2 tensor.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.shape.len(), 2, "expected rank-2, got {:?}", self.shape);
        (self.shape[0], self.shape[1])
    }

    /// Keep the given rows (axis 0) in order.
    pub fn select_rows(&self, rows: &[usize]) -> Tensor {
        let (r, c) = self.dims2();
        let src = self.f32s();
        let mut out = Vec::with_capacity(rows.len() * c);
        for &i in rows {
            assert!(i < r);
            out.extend_from_slice(&src[i * c..(i + 1) * c]);
        }
        Tensor::from_f32(&[rows.len(), c], out)
    }

    /// Keep the given columns (axis 1) in order.
    pub fn select_cols(&self, cols: &[usize]) -> Tensor {
        let (r, c) = self.dims2();
        let src = self.f32s();
        let mut out = Vec::with_capacity(r * cols.len());
        for i in 0..r {
            for &j in cols {
                assert!(j < c);
                out.push(src[i * c + j]);
            }
        }
        Tensor::from_f32(&[r, cols.len()], out)
    }

    /// Scatter this (pruned) matrix into a zero matrix of `full` shape,
    /// placing row i at full row `rows[i]` (identity on cols). The recovery
    /// primitive R(·) of Eq. 5 for the row-sliced case.
    pub fn scatter_rows(&self, rows: &[usize], full_rows: usize) -> Tensor {
        let (r, c) = self.dims2();
        assert_eq!(r, rows.len());
        let src = self.f32s();
        let mut out = vec![0.0f32; full_rows * c];
        for (i, &fi) in rows.iter().enumerate() {
            out[fi * c..(fi + 1) * c].copy_from_slice(&src[i * c..(i + 1) * c]);
        }
        Tensor::from_f32(&[full_rows, c], out)
    }

    /// Column-scatter analogue of `scatter_rows`.
    pub fn scatter_cols(&self, cols: &[usize], full_cols: usize) -> Tensor {
        let (r, c) = self.dims2();
        assert_eq!(c, cols.len());
        let src = self.f32s();
        let mut out = vec![0.0f32; r * full_cols];
        for i in 0..r {
            for (j, &fj) in cols.iter().enumerate() {
                out[i * full_cols + fj] = src[i * c + j];
            }
        }
        Tensor::from_f32(&[r, full_cols], out)
    }

    pub fn l2_norm(&self) -> f64 {
        self.f32s().iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.f32s()
            .iter()
            .zip(other.f32s())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Named, ordered collection of tensors — model params, LoRA state,
/// optimiser moments, masks, quantised blobs.
#[derive(Debug, Clone, Default)]
pub struct TensorStore {
    pub map: BTreeMap<String, Tensor>,
}

impl TensorStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: impl Into<String>, t: Tensor) {
        self.map.insert(name.into(), t);
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map.get(name).with_context(|| format!("missing tensor '{name}'"))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn total_params(&self) -> usize {
        self.map.values().map(|t| t.len()).sum()
    }

    /// Merge another store under a name prefix (e.g. "adam_m.").
    pub fn extend_prefixed(&mut self, prefix: &str, other: &TensorStore) {
        for (k, v) in &other.map {
            self.insert(format!("{prefix}{k}"), v.clone());
        }
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        checkpoint::save(self, path)
    }

    pub fn load(path: &Path) -> Result<TensorStore> {
        checkpoint::load(path)
    }
}

// re-export for callers
pub use checkpoint::{load as load_checkpoint, save as save_checkpoint};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn select_and_scatter_rows_roundtrip() {
        let t = Tensor::from_f32(&[4, 3], (0..12).map(|x| x as f32).collect());
        let rows = [0, 2];
        let sel = t.select_rows(&rows);
        assert_eq!(sel.shape, vec![2, 3]);
        assert_eq!(sel.f32s(), &[0., 1., 2., 6., 7., 8.]);
        let back = sel.scatter_rows(&rows, 4);
        assert_eq!(back.f32s()[0..3], [0., 1., 2.]);
        assert_eq!(back.f32s()[3..6], [0., 0., 0.]); // pruned row zeroed
        assert_eq!(back.f32s()[6..9], [6., 7., 8.]);
    }

    #[test]
    fn select_and_scatter_cols_roundtrip() {
        let t = Tensor::from_f32(&[2, 4], (0..8).map(|x| x as f32).collect());
        let cols = [1, 3];
        let sel = t.select_cols(&cols);
        assert_eq!(sel.f32s(), &[1., 3., 5., 7.]);
        let back = sel.scatter_cols(&cols, 4);
        assert_eq!(back.f32s(), &[0., 1., 0., 3., 0., 5., 0., 7.]);
    }

    #[test]
    fn store_ordering_is_deterministic() {
        let mut s = TensorStore::new();
        s.insert("b", Tensor::zeros(&[1]));
        s.insert("a", Tensor::zeros(&[2]));
        let names: Vec<_> = s.names().cloned().collect();
        assert_eq!(names, vec!["a", "b"]);
        assert_eq!(s.total_params(), 3);
    }
}

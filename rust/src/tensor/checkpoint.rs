//! `.lmck` — the LoRAM binary checkpoint format.
//!
//! Layout (little-endian):
//!   magic  b"LMCK"            4 bytes
//!   version u32               currently 1
//!   count   u32               number of tensors
//!   per tensor:
//!     name_len u32, name bytes (utf-8)
//!     dtype    u8   (0 = f32, 1 = i32)
//!     ndim     u8
//!     dims     u64 × ndim
//!     data     raw little-endian values
//!
//! Used for base model weights, LoRA state (pruned and recovered),
//! optimiser moments and pruning metadata side-files.

use super::{Data, Tensor, TensorStore};
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"LMCK";
const VERSION: u32 = 1;

pub fn save(store: &TensorStore, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(store.map.len() as u32).to_le_bytes())?;
    for (name, t) in &store.map {
        w.write_all(&(name.len() as u32).to_le_bytes())?;
        w.write_all(name.as_bytes())?;
        let (code, bytes): (u8, Vec<u8>) = match &t.data {
            Data::F32(v) => (0, v.iter().flat_map(|x| x.to_le_bytes()).collect()),
            Data::I32(v) => (1, v.iter().flat_map(|x| x.to_le_bytes()).collect()),
        };
        w.write_all(&[code, t.shape.len() as u8])?;
        for &d in &t.shape {
            w.write_all(&(d as u64).to_le_bytes())?;
        }
        w.write_all(&bytes)?;
    }
    w.flush()?;
    Ok(())
}

pub fn load(path: &Path) -> Result<TensorStore> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: not an LMCK checkpoint", path.display());
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    let count = read_u32(&mut r)? as usize;
    let mut store = TensorStore::new();
    for _ in 0..count {
        let name_len = read_u32(&mut r)? as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("bad tensor name")?;
        let mut hdr = [0u8; 2];
        r.read_exact(&mut hdr)?;
        let (code, ndim) = (hdr[0], hdr[1] as usize);
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            let mut b = [0u8; 8];
            r.read_exact(&mut b)?;
            shape.push(u64::from_le_bytes(b) as usize);
        }
        let n: usize = shape.iter().product();
        let mut raw = vec![0u8; n * 4];
        r.read_exact(&mut raw)?;
        let data = match code {
            0 => Data::F32(
                raw.chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            1 => Data::I32(
                raw.chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            other => bail!("unknown dtype code {other}"),
        };
        store.insert(name, Tensor { shape, data });
    }
    Ok(store)
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("loram_ck_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.lmck");
        let mut s = TensorStore::new();
        s.insert("w", Tensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]));
        s.insert("ids", Tensor::from_i32(&[4], vec![-1, 0, 7, 42]));
        s.insert("scalar", Tensor::scalar_f32(3.5));
        save(&s, &path).unwrap();
        let l = load(&path).unwrap();
        assert_eq!(l.map, s.map);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir();
        let path = dir.join("loram_bad.lmck");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }
}

//! LoRAM — "Train Small, Infer Large": memory-efficient LoRA training
//! (ICLR 2025) as a three-layer Rust + JAX + Pallas system.
//!
//! Layer 1 (Pallas kernels) and Layer 2 (JAX model) live in `python/compile`
//! and are AOT-lowered to HLO-text artifacts at build time. This crate is
//! Layer 3: the coordinator that owns pruning, alignment, LoRA training,
//! recovery, inference and every experiment in the paper — executing the
//! artifacts through PJRT with no Python on the request path.
//!
//! Module map (see DESIGN.md §1 for the full inventory):
//! * [`runtime`] — PJRT client, artifact registry, the unified `Session`
//!   execution layer (host/device backends, meta-declared state threading)
//! * [`tensor`] — host tensors, checkpoints
//! * [`params`] — parameter / LoRA / optimiser-state initialisation
//! * [`util`] — hand-rolled JSON / CLI / RNG / stats substrates
//! * [`tokenizer`] — byte-level tokenizer
//! * [`data`] — synthetic corpora + downstream task generators
//! * [`pruning`] — structured/semi/unstructured pruning + recovery R(·)
//! * [`quant`] — blockwise NF4 quantisation (QLoRAM)
//! * [`memory`] — analytic parameter/HBM accounting (paper Tables 4–6)
//! * [`coordinator`] — pipeline, training loops, evaluators, experiments,
//!   and the decode state machine behind generation
//! * [`serve`] — continuous-batching generation scheduler
//! * [`chaos`] — deterministic fault injection for the serving stack
//! * [`obs`] — request-lifecycle tracing + unified metrics registry
//! * [`bench`] — bench harness (no criterion in the vendor set)

pub mod bench;
pub mod chaos;
pub mod coordinator;
pub mod data;
pub mod memory;
pub mod obs;
pub mod params;
pub mod pruning;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod tokenizer;
pub mod util;
pub mod workload;

/// Default artifact directory: `$LORAM_ARTIFACTS` or `artifacts/`.
pub fn default_artifact_dir() -> std::path::PathBuf {
    std::env::var("LORAM_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

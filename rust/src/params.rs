//! Parameter, LoRA and optimiser-state initialisation.
//!
//! Mirrors model.py's init *semantics* (scaled normal, zero `lora_b`, ones
//! for norms). Bit-identity with jax.random is not required: the base model
//! is genuinely pre-trained by the Rust pipeline before any LoRAM stage
//! (DESIGN.md §2, substitution table).

use crate::runtime::ModelCfg;
use crate::tensor::{Tensor, TensorStore};
use crate::util::rng::Rng;

/// Base parameters: scaled-normal projections (GPT-2-style residual scaling
/// on wo / w_down), ones for RMSNorm scales.
pub fn init_params(cfg: &ModelCfg, seed: u64) -> TensorStore {
    let mut rng = Rng::new(seed);
    let resid = 1.0 / (2.0 * cfg.n_layers as f32).sqrt();
    let mut store = TensorStore::new();
    for (name, shape) in cfg.param_shapes() {
        let n: usize = shape.iter().product();
        let t = if name.ends_with("norm") {
            Tensor::from_f32(&shape, vec![1.0; n])
        } else {
            let std = if name.ends_with(".wo") || name.ends_with(".w_down") {
                0.02 * resid
            } else {
                0.02
            };
            Tensor::from_f32(&shape, rng.normal_vec(n, std))
        };
        store.insert(name, t);
    }
    store
}

/// LoRA factors: `a` ~ N(0, 1/in_features), `b` = 0 — so fresh LoRA is an
/// exact identity on the forward pass (tested in python/tests/test_model.py
/// and rust integration tests).
pub fn init_lora(cfg: &ModelCfg, seed: u64) -> TensorStore {
    let mut rng = Rng::new(seed ^ LORA_SEED_SALT);
    let mut store = TensorStore::new();
    for (name, shape) in cfg.lora_shapes() {
        let n: usize = shape.iter().product();
        let t = if name.ends_with("lora_a") {
            let std = 1.0 / (shape[0] as f32).sqrt();
            Tensor::from_f32(&shape, rng.normal_vec(n, std))
        } else {
            Tensor::from_f32(&shape, vec![0.0; n])
        };
        store.insert(name, t);
    }
    store
}

/// Salt separating the LoRA init stream from the base-param stream.
const LORA_SEED_SALT: u64 = 0x1042_5043_10aa_77f3;

/// Zeroed Adam moments matching an arbitrary tensor store.
pub fn zeros_like(store: &TensorStore) -> TensorStore {
    let mut out = TensorStore::new();
    for (k, t) in &store.map {
        out.insert(k.clone(), Tensor::zeros(&t.shape));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ModelCfg;

    fn cfg() -> ModelCfg {
        ModelCfg {
            name: "t".into(),
            vocab_size: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 2,
            d_ff: 48,
            max_seq: 32,
            lora_rank: 4,
            lora_alpha: 8.0,
            lora_lm_head: true,
            layer_plan: None,
        }
    }

    #[test]
    fn init_covers_all_params() {
        let c = cfg();
        let p = init_params(&c, 0);
        assert_eq!(p.len(), c.param_shapes().len());
        assert_eq!(p.total_params(), c.param_count());
        // norms are ones
        assert!(p.get("l0.attn_norm").unwrap().f32s().iter().all(|&x| x == 1.0));
        // projections are non-trivial
        assert!(p.get("l0.wq").unwrap().l2_norm() > 0.0);
    }

    #[test]
    fn lora_b_zero_a_nonzero() {
        let c = cfg();
        let l = init_lora(&c, 0);
        assert!(l.get("l0.wq.lora_b").unwrap().f32s().iter().all(|&x| x == 0.0));
        assert!(l.get("l0.wq.lora_a").unwrap().l2_norm() > 0.0);
    }

    #[test]
    fn deterministic_across_calls() {
        let c = cfg();
        let a = init_params(&c, 42);
        let b = init_params(&c, 42);
        assert_eq!(a.get("l1.wv").unwrap(), b.get("l1.wv").unwrap());
        let d = init_params(&c, 43);
        assert_ne!(a.get("l1.wv").unwrap(), d.get("l1.wv").unwrap());
    }

    #[test]
    fn zeros_like_shapes() {
        let c = cfg();
        let p = init_params(&c, 0);
        let z = zeros_like(&p);
        assert_eq!(z.total_params(), p.total_params());
        assert!(z.get("embed").unwrap().f32s().iter().all(|&x| x == 0.0));
    }
}

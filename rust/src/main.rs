//! `loram` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   info                          list artifacts + runtime info
//!   pretrain   --cfg l13b         pre-train (and cache) a proxy base model
//!   pipeline   --base l13b --variant stru [...]   run the LoRAM pipeline
//!   eval       --base l13b [--lora f.lmck]        perplexity of a model
//!   generate   --base l13b --prompt "Q: 2+3="     sample completions
//!   serve      --base l13b --requests 16          batched generation demo
//!   downstream --base l13b [--lora f.lmck]        math/CSR/code battery
//!   memory                         print paper Tables 4–6 (exact)
//!   repro      --exp fig7 [--scale smoke|paper]   regenerate a table/figure
//!
//! Python never runs here: every computation executes AOT artifacts through
//! the PJRT runtime (see DESIGN.md).

use anyhow::{bail, Context, Result};
use loram::chaos::ChaosEngine;
use loram::coordinator::downstream::{eval_all, ModelUnderTest};
use loram::coordinator::experiments::{self, Scale};
use loram::coordinator::generate::{Generator, SampleCfg};
use loram::coordinator::kvcache::{paged_pool_blocks, PAGED_BLOCK};
use loram::coordinator::pipeline::{ensure_base, Pipeline, PipelineConfig, Variant};
use loram::data::instruct::Dataset;
use loram::memory;
use loram::params::init_lora;
use loram::runtime::Runtime;
use loram::serve::{Server, SimEngine};
use loram::tensor::TensorStore;
use loram::util::cli::Args;
use loram::util::json::Json;
use loram::util::log;
use loram::util::rng::Rng;
use std::path::{Path, PathBuf};

fn main() {
    let args = Args::from_env();
    if args.has_flag("quiet") {
        log::set_verbose(false);
    }
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_str() {
        "" | "help" => {
            print!("{}", HELP);
            Ok(())
        }
        "memory" => cmd_memory(),
        // artifact-free serving: the scheduler over a SimEngine, no PJRT
        // runtime or artifact dir needed (CI exercises `--trace` this way)
        "serve" if args.get("engine") == Some("sim") => cmd_serve_sim(args),
        sub => {
            let dir = args
                .get("artifacts")
                .map(PathBuf::from)
                .unwrap_or_else(loram::default_artifact_dir);
            let rt = Runtime::new(&dir)
                .with_context(|| format!("artifacts dir {}", dir.display()))?;
            match sub {
                "info" => cmd_info(&rt),
                "pretrain" => cmd_pretrain(&rt, args),
                "pipeline" => cmd_pipeline(&rt, args),
                "eval" => cmd_eval(&rt, args),
                "generate" => cmd_generate(&rt, args),
                "serve" => cmd_serve(&rt, args),
                "downstream" => cmd_downstream(&rt, args),
                "repro" => cmd_repro(&rt, args),
                other => bail!("unknown subcommand '{other}' (try `loram help`)"),
            }
        }
    }
}

const HELP: &str = "\
loram — Train Small, Infer Large (LoRAM, ICLR 2025) coordinator

usage: loram <subcommand> [--key value] [--flag]

  info                              artifacts + runtime summary
  pretrain   --cfg tiny --steps 50  pre-train + cache a proxy base model
  pipeline   --base tiny --pruned tiny_p50 --variant stru|rand|semi|unst|lora
             [--quantized] [--no-align] [--dataset hermes|orca]
             [--pretrain-steps N --align-steps N --sft-steps N] [--save out.lmck]
             [--adapter-dir adapters/ [--adapter-name math]]  export after R(·)
             [--drafter-dir drafter/]  export the pruned base + pre-R(·)
                                       factors for speculative serving
  eval       --base tiny [--lora f.lmck] [--dataset alpaca] [--n 32]
  generate   --base tiny --prompt 'Q: 2+3=' [--temperature 0.4] [--max-new 16]
  serve      --base tiny --requests 16      batched generation service demo
             [--adapters dir/]  multi-adapter serving: route each request
                                through one of the dir's .lmck adapters
             [--decode-path auto|reforward|kvcache|speculative]
             [--drafter tiny_p50]      drafter model for the speculative
                                       path (default <base>_p50)
             [--drafter-dir drafter/]  pipeline-exported drafter weights
                                       (else: sliced base + zero factors)
             [--prefill-chunk on|off]  chunked admission through the bucket
                                       ladder (default: on when the chunk
                                       artifacts are registered)
             [--prefill-budget N]      prefill window tokens per scheduler
                                       tick (Sarathi-style pacing; default
                                       unbounded — admissions finish the
                                       tick they begin)
             [--paged on|off]          block-pooled KV cache with shared-
                                       prefix reuse (needs the decode_*_paged
                                       artifact family; default off)
             [--block-size N]          assert the paged family's KV block
                                       size is N (sanity check only; the
                                       size is baked into the artifacts)
             [--engine pjrt|sim]       sim: artifact-free scheduler run on
                                       the deterministic tick clock
                                       ([--sim-mode chunked|spec|paged]
                                       [--batch N])
             [--slo]                   SLO-aware scheduling (DESIGN.md
                                       §2i): priority classes, deadline
                                       cancellation, preemptive admission
             [--workload SCENARIO]     sim only: adversarial generated
                                       stream — steady|bursty-heavytail|
                                       adapter-skew|deadline-storm|
                                       rejection-storm|faults  [--seed N]
             [--fair-rows N]           cap the engine rows one adapter
                                       lane may hold concurrently
             [--chaos SCENARIO]        sim only: deterministic fault
                                       injection (DESIGN.md §2j) —
                                       fault-storm|decode-flaky|
                                       admit-flaky|pool-squeeze|
                                       stuck-stall|device-loss
                                       [--chaos-ticks T] plan horizon
             [--retry-budget N]        bounded retries per faulted request
                                       (§2j; without it faults are fatal)
             [--backoff-base T]        exponential retry backoff base in
                                       ticks (default 1)
             [--trace out.json]        write a Perfetto-loadable Chrome
                                       trace (+ .jsonl event log); audit
                                       it with tools/trace_report.py
  downstream --base tiny [--lora f.lmck]    math / CSR / code battery
  memory                                    paper Tables 4-6 (exact, analytic)
  repro      --exp fig3|fig4|tab1|fig5|fig6|fig7|fig8|tab456|tab7|tab8|fig16|appD|all
             [--scale smoke|paper] [--seed N]

common: --artifacts DIR (default artifacts/), --quiet,
        LORAM_LOG=error|warn|info|debug (log threshold; tick-stamped under --trace)
";

fn cmd_info(rt: &Runtime) -> Result<()> {
    let names = rt.manifest().unwrap_or_default();
    println!("artifact dir: {}", rt.artifact_dir().display());
    println!("artifacts ({}):", names.len());
    for n in &names {
        println!("  {n}");
    }
    Ok(())
}

fn cmd_memory() -> Result<()> {
    println!("Table 4 (LLaMA-2-13B), Table 5 (70B sweep), Table 6 (QLoRAM):");
    println!(
        "{:<16} {:<18} {:>6} {:>16} {:>10} {:>8}",
        "model", "method", "ratio", "pruned_params", "reduction", "HBM_GB"
    );
    let rows = vec![
        (&memory::LLAMA2_13B, memory::loram_row(&memory::LLAMA2_13B, "LoRAM-Semi", 0.50)),
        (&memory::LLAMA2_13B, memory::loram_row(&memory::LLAMA2_13B, "LoRAM-Unst", 0.55)),
        (&memory::LLAMA2_13B, memory::loram_row(&memory::LLAMA2_13B, "LoRAM-Rand&Stru", 0.65)),
        (&memory::LLAMA2_70B, memory::loram_row(&memory::LLAMA2_70B, "LoRAM-Rand&Stru", 0.65)),
        (&memory::LLAMA2_70B, memory::loram_row(&memory::LLAMA2_70B, "LoRAM-Rand&Stru", 0.75)),
        (&memory::LLAMA2_70B, memory::loram_row(&memory::LLAMA2_70B, "LoRAM-Rand&Stru", 0.85)),
        (&memory::LLAMA2_70B, memory::loram_row(&memory::LLAMA2_70B, "LoRAM-Rand&Stru", 0.95)),
        (&memory::LLAMA31_70B, memory::loram_row(&memory::LLAMA31_70B, "LoRAM-Rand&Stru", 0.85)),
        (&memory::LLAMA2_70B, memory::qloram_row(&memory::LLAMA2_70B, "QLoRAM-Rand&Stru", 0.65)),
        (&memory::LLAMA2_70B, memory::qloram_row(&memory::LLAMA2_70B, "QLoRAM-Rand&Stru", 0.75)),
        (&memory::LLAMA2_70B, memory::qloram_row(&memory::LLAMA2_70B, "QLoRAM-Rand&Stru", 0.85)),
        (&memory::LLAMA2_70B, memory::qloram_row(&memory::LLAMA2_70B, "QLoRAM-Rand&Stru", 0.95)),
        (&memory::LLAMA31_70B, memory::qloram_row(&memory::LLAMA31_70B, "QLoRAM-Rand&Stru", 0.85)),
    ];
    for (spec, r) in rows {
        println!(
            "{:<16} {:<18} {:>6.2} {:>16} {:>9.2}x {:>8.2}",
            spec.name, r.method, r.prune_ratio, r.pruned_params, r.reduction, r.hbm_gb
        );
    }
    Ok(())
}

fn cmd_pretrain(rt: &Runtime, args: &Args) -> Result<()> {
    let cfg = args.get_or("cfg", "tiny");
    let steps = args.get_usize("steps", 50);
    let lr = args.get_f64("lr", 1e-3);
    let seed = args.get_usize("seed", 0) as u64;
    let run_dir = PathBuf::from(args.get_or("run-dir", "runs"));
    std::fs::create_dir_all(&run_dir)?;
    let params = ensure_base(rt, cfg, steps, lr, seed, &run_dir)?;
    println!(
        "base[{cfg}]: {} tensors, {} params",
        params.len(),
        params.total_params()
    );
    Ok(())
}

fn parse_pipeline_cfg(args: &Args) -> Result<PipelineConfig> {
    let variant = Variant::from_str(args.get_or("variant", "stru"))
        .context("bad --variant (lora|rand|stru|semi|unst)")?;
    let base = args.get_or("base", "tiny").to_string();
    let pruned = args.get("pruned").map(String::from).or_else(|| {
        if variant.structured() {
            Some(format!("{base}_p50"))
        } else {
            None
        }
    });
    Ok(PipelineConfig {
        base,
        pruned,
        variant,
        quantized: args.has_flag("quantized"),
        unst_ratio: args.get_f64("unst-ratio", 0.55),
        pretrain_steps: args.get_usize("pretrain-steps", 50),
        align_steps: args.get_usize("align-steps", 10),
        sft_steps: args.get_usize("sft-steps", 20),
        lr_pretrain: args.get_f64("lr-pretrain", 1e-3),
        lr_align: args.get_f64("lr-align", 5e-4),
        lr_sft: args.get_f64("lr", 1e-3),
        dataset: Dataset::from_str(args.get_or("dataset", "hermes")).context("bad --dataset")?,
        seed: args.get_usize("seed", 0) as u64,
        eval_every: args.get_usize("eval-every", 10),
        eval_seqs: args.get_usize("eval-seqs", 16),
        align: !args.has_flag("no-align"),
        run_dir: PathBuf::from(args.get_or("run-dir", "runs")),
        adapter_dir: args.get("adapter-dir").map(PathBuf::from),
        adapter_name: args.get("adapter-name").map(String::from),
        drafter_dir: args.get("drafter-dir").map(PathBuf::from),
    })
}

fn cmd_pipeline(rt: &Runtime, args: &Args) -> Result<()> {
    let cfg = parse_pipeline_cfg(args)?;
    std::fs::create_dir_all(&cfg.run_dir)?;
    let base = cfg.base.clone();
    let res = Pipeline::new(rt, cfg).run()?;
    println!(
        "sft losses: first {:.4} last {:.4}",
        res.sft_losses[0],
        res.sft_losses.last().unwrap()
    );
    for p in &res.eval_points {
        println!(
            "step {:>5}  ood_ppl {:>8.3}  id_ppl {:>8.3}{}",
            p.step,
            p.ood_ppl,
            p.id_ppl,
            p.ood_ppl_pruned
                .map(|x| format!("  (w/o recovery {x:.3})"))
                .unwrap_or_default()
        );
    }
    println!(
        "mean sft step: {:.1} ms, peak rss {:.0} MiB",
        res.sft_step_ms, res.peak_rss_mib
    );
    if let Some(path) = args.get("save") {
        res.lora_recovered.save(std::path::Path::new(path))?;
        println!("recovered LoRA ({base}) saved to {path}");
    }
    Ok(())
}

fn load_weights(rt: &Runtime, args: &Args, base: &str) -> Result<(TensorStore, TensorStore)> {
    let run_dir = PathBuf::from(args.get_or("run-dir", "runs"));
    let steps = args.get_usize("pretrain-steps", 50);
    let seed = args.get_usize("seed", 0) as u64;
    let params = ensure_base(rt, base, steps, 1e-3, seed, &run_dir)?;
    let cfg = rt.load(&format!("eval_{base}"))?.meta.config.clone();
    let lora = match args.get("lora") {
        Some(p) => TensorStore::load(std::path::Path::new(p))?,
        None => init_lora(&cfg, 0),
    };
    Ok((params, lora))
}

fn cmd_eval(rt: &Runtime, args: &Args) -> Result<()> {
    let base = args.get_or("base", "tiny");
    let (params, lora) = load_weights(rt, args, base)?;
    let ev = loram::coordinator::evaluate::Evaluator::new(
        rt,
        &format!("eval_{base}"),
        &[&params, &lora],
    )?;
    let ds = Dataset::from_str(args.get_or("dataset", "alpaca")).context("bad --dataset")?;
    let n = args.get_usize("n", 32);
    let seqs = loram::coordinator::evaluate::test_sequences(ds, 0, n);
    let ppl = ev.perplexity(&seqs, true)?;
    println!("{base} on {ds:?} ({n} seqs): ppl {ppl:.4}");
    Ok(())
}

fn cmd_generate(rt: &Runtime, args: &Args) -> Result<()> {
    let base = args.get_or("base", "tiny");
    let (params, lora) = load_weights(rt, args, base)?;
    let gen = Generator::new(rt, &format!("logits_{base}"), &[&params, &lora])?;
    let prompt = args.get_or("prompt", "Q: 2+3=").to_string();
    let cfg = SampleCfg {
        temperature: args.get_f64("temperature", 0.0),
        top_p: args.get_f64("top-p", 0.95),
        max_new: args.get_usize("max-new", 16),
    };
    let mut rng = Rng::new(args.get_usize("seed", 0) as u64);
    let outs = gen.complete(&[prompt.clone()], cfg, &mut rng)?;
    println!("prompt: {prompt}");
    println!("completion: {}", outs[0]);
    Ok(())
}

/// Drafter weights for `--decode-path speculative`: pipeline-exported
/// checkpoints when `--drafter-dir` points at them, else a stand-in built
/// by slicing the base params under a random structured plan (drafter
/// fidelity only moves the acceptance rate, never correctness).
fn drafter_weights(
    rt: &Runtime,
    args: &Args,
    base: &str,
    drafter: &str,
    params: &TensorStore,
    lora: &TensorStore,
) -> Result<(TensorStore, TensorStore)> {
    if let Some(dir) = args.get("drafter-dir") {
        let (ppath, lpath) =
            loram::coordinator::speculative::drafter_paths(Path::new(dir));
        anyhow::ensure!(
            ppath.exists() && lpath.exists(),
            "--drafter-dir {dir} holds no drafter checkpoints (run \
             `loram pipeline --drafter-dir {dir}` first)"
        );
        return Ok((TensorStore::load(&ppath)?, TensorStore::load(&lpath)?));
    }
    if drafter == base {
        // self-speculative: the model drafts for itself
        return Ok((params.clone(), lora.clone()));
    }
    let full_cfg = rt.load(&format!("eval_{base}"))?.meta.config.clone();
    let seed = args.get_usize("seed", 0) as u64;
    loram::coordinator::speculative::sliced_drafter_standin(
        rt, &full_cfg, params, drafter, seed,
    )
}

/// `--trace out.json`: install the bounded ring sink before any request
/// is enqueued. Wall clocks run only on the PJRT engine — sim traces stay
/// on the tick clock alone, so identical runs export identical bytes.
fn trace_begin(args: &Args, wall: bool) {
    if args.get("trace").is_some() {
        loram::obs::trace::install(loram::obs::trace::DEFAULT_CAP, wall);
    }
}

/// Drain the sink into the Chrome-trace file (+ `.jsonl` sibling), with
/// the scheduler's own percentiles embedded for `tools/trace_report.py
/// --check` to cross-check against its replay of the raw events.
fn trace_finish(args: &Args, st: &loram::serve::ServerStats) -> Result<()> {
    let Some(path) = args.get("trace") else { return Ok(()) };
    let sink = loram::obs::trace::take()
        .context("--trace set but the sink is gone (double finish?)")?;
    let ps = [50.0, 95.0];
    let ttft = st.ttft_tick_pcts(&ps);
    let itl = st.itl_tick_pcts(&ps);
    let mut stats = vec![
        ("served", Json::num(st.served as f64)),
        ("admitted", Json::num(st.admitted as f64)),
        ("rejected", Json::num(st.rejected as f64)),
        ("preempted", Json::num(st.preempted as f64)),
        ("cancelled", Json::num(st.cancelled as f64)),
        ("deadline_misses", Json::num(st.deadline_misses as f64)),
        ("failed", Json::num(st.failed as f64)),
        ("retries", Json::num(st.retries as f64)),
        ("degraded_ticks", Json::num(st.degraded_ticks as f64)),
        ("goodput", Json::num(st.goodput())),
        ("total_tokens", Json::num(st.total_tokens as f64)),
        ("ticks", Json::num(st.ticks as f64)),
        ("ttft_tick_p50", Json::num(ttft[0])),
        ("ttft_tick_p95", Json::num(ttft[1])),
        ("itl_tick_p50", Json::num(itl[0])),
        ("itl_tick_p95", Json::num(itl[1])),
    ];
    if let Some(pg) = &st.paged {
        stats.push(("cow_copies", Json::num(pg.cow_copies as f64)));
        stats.push(("blocks_in_use", Json::num(pg.blocks_in_use as f64)));
    }
    let jsonl = loram::obs::export::write_trace_files(
        Path::new(path),
        &sink,
        vec![("serverStats", Json::obj(stats))],
    )?;
    println!(
        "trace: {} events ({} dropped) -> {path} (+ {})",
        sink.len(),
        sink.dropped(),
        jsonl.display()
    );
    Ok(())
}

/// `serve --engine sim`: the scheduler over a [`SimEngine`] — no
/// artifacts, no PJRT, deterministic on the tick clock. The cheapest way
/// to produce a complete `--trace` file (the ci.sh trace lane), and a
/// scheduler demo that runs anywhere.
fn cmd_serve_sim(args: &Args) -> Result<()> {
    let n = args.get_usize("requests", 24);
    let batch = args.get_usize("batch", 4);
    let mode = args.get_or("sim-mode", "chunked").to_string();
    trace_begin(args, false);
    let engine = match mode.as_str() {
        "chunked" => SimEngine::with_prefill(batch, vec![16, 64], false),
        "spec" => SimEngine::with_spec(
            batch,
            args.get_usize("spec-k", 4),
            args.get_f64("accept", 0.7),
            args.get_usize("seed", 0) as u64,
        ),
        // same-bytes sizing as the §2f tests: the pool byte-matches a
        // dense `batch x 64` grid, rows decoupled from the grid
        "paged" => SimEngine::with_paged(
            paged_pool_blocks(batch, 64, PAGED_BLOCK),
            PAGED_BLOCK,
            8 * batch,
            vec![16, 64],
        )?,
        other => bail!("bad --sim-mode '{other}' (chunked|spec|paged)"),
    };
    // §2j: --chaos wraps the engine in deterministic fault injection;
    // the scheduler and workload code below is shared byte-for-byte
    if let Some(scenario) = args.get("chaos") {
        let chaotic = ChaosEngine::new(
            engine,
            scenario,
            args.get_usize("chaos-ticks", 64),
            args.get_usize("seed", 0) as u64,
        )?;
        let server = drive_sim(args, Server::new(chaotic, 0), &mode, n)?;
        println!(
            "chaos[{scenario}]: {} faults injected ({} unfired), health {:?}",
            server.engine.injected,
            server.engine.remaining(),
            server.health()
        );
        trace_finish(args, &server.stats)
    } else {
        let server = drive_sim(args, Server::new(engine, 0), &mode, n)?;
        trace_finish(args, &server.stats)
    }
}

/// The sim demo body, generic over the engine so the chaos-wrapped and
/// plain paths share one driver. Returns the drained server for
/// engine-specific reporting.
fn drive_sim<E: loram::serve::DecodeEngine>(
    args: &Args,
    mut server: Server<E>,
    mode: &str,
    n: usize,
) -> Result<Server<E>> {
    if mode != "spec" {
        server.set_prefill_budget(Some(args.get_usize("prefill-budget", 16)));
    }
    if args.has_flag("slo") {
        server.set_slo(true);
    }
    if args.get("fair-rows").is_some() {
        server.set_adapter_fair_cap(Some(args.get_usize("fair-rows", 2)));
    }
    // §2j: bounded retry/backoff is opt-in — without it any injected
    // fault stays fatal, which is exactly the abort-on-error baseline
    if args.get("retry-budget").is_some() {
        server.set_retry_policy(
            Some(args.get_usize("retry-budget", 2) as u32),
            args.get_usize("backoff-base", 1) as u64,
        );
    }
    let responses = if let Some(scenario) = args.get("workload") {
        // adversarial generated stream (DESIGN.md §2i scenario catalog):
        // arrivals paced on the tick clock instead of all-upfront
        let reqs =
            loram::workload::generate(scenario, n, args.get_usize("seed", 0) as u64)?;
        loram::workload::run(&mut server, &reqs)?
    } else {
        let sys = "system: you are a terse helpful assistant. ";
        for i in 0..n {
            let prompt = match mode {
                // shared system prompt: exercises prefix reuse + block ledger
                "paged" => format!("{sys}user {i}"),
                _ if i % 3 == 0 => "L".repeat(60), // near-grid-long
                _ => format!("req {i}"),
            };
            server.enqueue(prompt, serve_cfg(i));
        }
        server.drain()?
    };
    // every enqueue resolves as exactly one of response (served or
    // failed), cancellation, or admission rejection — nothing vanishes
    anyhow::ensure!(
        responses.len() + server.stats.cancelled + server.stats.rejected == n,
        "sim resolved {} + cancelled {} + rejected {} of {n}",
        responses.len(),
        server.stats.cancelled,
        server.stats.rejected
    );
    let st = &server.stats;
    println!(
        "sim[{mode}] served {} requests over {} ticks — {} tokens, \
         ttft p50/p95 {:.0}/{:.0} ticks, itl p95 {:.0} ticks, peak {} rows",
        st.served,
        st.ticks,
        st.total_tokens,
        st.ttft_tick_p(50.0),
        st.ttft_tick_p(95.0),
        st.itl_tick_p(95.0),
        st.peak_in_flight
    );
    if args.has_flag("slo") || args.get("workload").is_some() {
        println!(
            "slo: {} preempted, {} cancelled, {} deadline misses, goodput {:.3}",
            st.preempted,
            st.cancelled,
            st.deadline_misses,
            st.goodput()
        );
    }
    if let Some(pg) = &st.paged {
        println!(
            "paged kv: {} prefix hits ({} tokens reused), {}/{} blocks in \
             use, {} cow copies",
            pg.prefix_hits,
            pg.prefix_hit_tokens,
            pg.blocks_in_use,
            pg.pool_blocks,
            pg.cow_copies
        );
    }
    if st.failed > 0 || st.retries > 0 || st.degraded_ticks > 0 {
        println!(
            "faults: {} failed, {} retries, {} rejected, {} degraded ticks",
            st.failed, st.retries, st.rejected, st.degraded_ticks
        );
    }
    Ok(server)
}

fn cmd_serve(rt: &Runtime, args: &Args) -> Result<()> {
    if let Some(e) = args.get("engine") {
        if e != "pjrt" {
            bail!("bad --engine '{e}' (pjrt|sim)");
        }
    }
    trace_begin(args, true);
    let base = args.get_or("base", "tiny");
    let (params, lora) = load_weights(rt, args, base)?;
    let path = match args.get_or("decode-path", "auto") {
        "reforward" => Some(loram::coordinator::generate::DecodePath::Reforward),
        "kvcache" => Some(loram::coordinator::generate::DecodePath::KvCache),
        "speculative" => Some(loram::coordinator::generate::DecodePath::Speculative),
        _ => None,
    };
    let speculative = path == Some(loram::coordinator::generate::DecodePath::Speculative);
    // §2f: block-pooled KV cache behind per-row block tables, with
    // shared-prefix reuse. The block size is baked into the emitted
    // decode_*_paged artifacts; --block-size only asserts it.
    let paged = match args.get("paged") {
        Some("on") => true,
        Some("off") | None => false,
        Some(other) => bail!("bad --paged '{other}' (on|off)"),
    };
    if paged && path == Some(loram::coordinator::generate::DecodePath::Reforward) {
        bail!("--paged on needs a cached decode path (reforward keeps no KV)");
    }
    if let Some(bs) = args.get("block-size") {
        if !paged {
            bail!("--block-size only applies with --paged on");
        }
        let want: usize = bs.parse().with_context(|| format!("bad --block-size '{bs}'"))?;
        let art = rt.load(&format!("decode_step_paged_{base}")).with_context(|| {
            format!("--paged on needs the decode_*_paged family for '{base}'")
        })?;
        let spec = art.meta.paged().with_context(|| {
            format!("'decode_step_paged_{base}' carries no extra.paged declaration")
        })?;
        if spec.block_size != want {
            bail!(
                "--block-size {want} but 'decode_step_paged_{base}' was emitted \
                 with block_size {} ({} pool blocks); re-emit the paged family \
                 to change it",
                spec.block_size,
                spec.n_blocks
            );
        }
    }
    let n = args.get_usize("requests", 8);
    let mut ig = loram::data::instruct::InstructGen::new(Dataset::Hermes, 1, 1);

    // --adapters dir/: serve the stacked-adapter artifact, one frozen base
    // + every .lmck adapter in the directory, routed per request
    let mut server = if let Some(dir) = args.get("adapters") {
        if paged {
            bail!(
                "--paged on under --adapters is not wired up yet: the stacked \
                 logits_*_a<N> artifacts have no paged decode family; drop one \
                 of the two flags"
            );
        }
        if speculative {
            bail!(
                "--decode-path speculative under --adapters is not wired up \
                 yet: drop one of the two flags"
            );
        }
        if args.get("lora").is_some() {
            loram::util::log::warn(
                "--lora is ignored under --adapters: the stacked artifact \
                 serves the base model plus the directory's adapters only",
            );
        }
        let art_name = stacked_artifact_name(rt, base)?
            .with_context(|| format!("no stacked logits_{base}_a<N> artifact registered"))?;
        let gen = Generator::with_adapters(
            rt,
            &art_name,
            &[&params],
            path,
            Some(PathBuf::from(dir)),
        )?;
        let cap = gen.adapter_capacity().unwrap_or(0);
        let names = loram::coordinator::adapters::AdapterStore::list(Path::new(dir))?;
        anyhow::ensure!(!names.is_empty(), "no .lmck adapters in {dir}");
        if names.len() > cap {
            loram::util::log::warn(format!(
                "{} adapters in {dir} but '{art_name}' stacks only {cap} \
                 slots; serving the first {cap}",
                names.len()
            ));
        }
        let mut ids = vec![];
        for name in names.iter().take(cap) {
            let id = gen.register_adapter_from_disk(name)?;
            println!("adapter {id}: {name}");
            ids.push(id);
        }
        println!("decode path: {} ({art_name}, {} adapters)", gen.decode_path().name(), ids.len());
        let mut server = Server::new(gen, 0);
        for i in 0..n {
            let (ex, _) = ig.next();
            server.enqueue_adapter(
                ex.instruction,
                serve_cfg(i),
                Some(ids[i % ids.len()]),
            );
        }
        server
    } else {
        let gen = if speculative {
            let drafter_default = format!("{base}_p50");
            let drafter = args.get_or("drafter", &drafter_default);
            let (dparams, dlora) =
                drafter_weights(rt, args, base, drafter, &params, &lora)?;
            let gen = Generator::with_speculative_paged(
                rt,
                &format!("logits_{base}"),
                &[&params, &lora],
                drafter,
                &[&dparams, &dlora],
                paged,
            )?;
            println!(
                "decode path: speculative (drafter {drafter}{})",
                if gen.paged() { ", paged" } else { "" }
            );
            gen
        } else {
            let gen = Generator::with_path_paged(
                rt,
                &format!("logits_{base}"),
                &[&params, &lora],
                path,
                paged,
            )?;
            println!(
                "decode path: {}{}",
                gen.decode_path().name(),
                if gen.paged() { " (paged)" } else { "" }
            );
            gen
        };
        let mut server = Server::new(gen, 0);
        for i in 0..n {
            let (ex, _) = ig.next();
            // mixed per-request sampling configs: the continuous-batching
            // scheduler decodes them in one batch anyway
            server.enqueue(ex.instruction, serve_cfg(i));
        }
        server
    };

    // §2e knobs: chunked admission + the scheduler's prefill token budget
    match args.get("prefill-chunk") {
        Some("on") => server.engine.set_chunked_prefill(true)?,
        Some("off") => server.engine.set_chunked_prefill(false)?,
        Some(other) => bail!("bad --prefill-chunk '{other}' (on|off)"),
        None => {}
    }
    if args.get("prefill-budget").is_some() {
        server.set_prefill_budget(Some(args.get_usize("prefill-budget", 64)));
    }
    if args.has_flag("slo") {
        // the demo queue is all Normal/no-deadline, so this admits FIFO —
        // but the preemptive machinery runs, matching the sim path
        server.set_slo(true);
    }
    println!(
        "prefill: {}",
        if server.engine.chunked_prefill() { "chunked" } else { "monolithic" }
    );

    let t0 = std::time::Instant::now();
    let responses = server.drain()?;
    let dt = t0.elapsed().as_secs_f64();
    for r in responses.iter().take(4) {
        println!(
            "#{:<3} [{} ttft {:>6.1} ms, total {:>6.1} ms, rows={}] {}",
            r.id,
            loram::serve::adapter_label(r.adapter),
            r.ttft_ms,
            r.latency_ms,
            r.batch_rows,
            r.text
        );
    }
    let st = &server.stats;
    println!(
        "served {} requests in {dt:.2}s — {:.1} tok/s decode, mean ttft {:.1} ms, \
         {} decode steps (occupancy {:.2}, queue wait {:.1} ms, peak depth {})",
        st.served,
        st.tokens_per_sec(),
        st.mean_ttft_ms(),
        st.decode_steps,
        st.mean_occupancy(),
        st.mean_queue_wait_ms(),
        st.peak_queue_depth
    );
    if st.prefill.prefill_tokens > 0 {
        println!(
            "prefill: {} window tokens over {} chunks ({} padded); \
             ttft p95 {:.0} ticks, itl p95 {:.0} ticks",
            st.prefill.prefill_tokens,
            st.prefill.chunks,
            st.prefill.padded_prefill_tokens,
            st.ttft_tick_p(95.0),
            st.itl_tick_p(95.0)
        );
    }
    if let Some(pg) = &st.paged {
        println!(
            "paged kv: prefix hit rate {:.2} ({} hits / {} lookups, {} tokens \
             reused), {}/{} pool blocks in use, {} cow copies, peak {} rows",
            pg.prefix_hit_rate(),
            pg.prefix_hits,
            pg.lookups,
            pg.prefix_hit_tokens,
            pg.blocks_in_use,
            pg.pool_blocks,
            pg.cow_copies,
            st.peak_in_flight
        );
    }
    if let Some(spec) = &st.spec {
        println!(
            "speculative: acceptance {:.2} ({}/{} drafts), {:.2} tokens/verify \
             ({} draft steps, {} verify steps)",
            spec.acceptance_rate(),
            spec.accepted_tokens,
            spec.drafted_tokens,
            spec.tokens_per_verify(),
            spec.draft_steps,
            spec.verify_steps
        );
    }
    for (adapter, lane) in &st.per_adapter {
        let name = adapter
            .and_then(|id| server.engine.adapter_name(id))
            .unwrap_or_default();
        println!(
            "  [{}] {name}: {} req, {:.1} tok/s, mean ttft {:.1} ms",
            loram::serve::adapter_label(*adapter),
            lane.requests,
            lane.tokens_per_sec(st.decode_ms),
            lane.mean_ttft_ms()
        );
    }
    trace_finish(args, st)
}

/// Mixed per-request sampling configs for the serve demo workload.
fn serve_cfg(i: usize) -> SampleCfg {
    SampleCfg {
        temperature: if i % 2 == 0 { 0.0 } else { 0.4 },
        top_p: if i % 3 == 0 { 0.95 } else { 0.8 },
        max_new: 8 + 4 * (i % 2),
    }
}

/// First `logits_<base>_a<N>` artifact in the manifest (the stacked
/// multi-adapter serving artifact for this base model). A manifest read
/// failure propagates — it must not masquerade as "no such artifact".
fn stacked_artifact_name(rt: &Runtime, base: &str) -> Result<Option<String>> {
    let manifest = rt.manifest().context("read artifact manifest")?;
    Ok(loram::coordinator::adapters::stacked_logits_artifact(&manifest, base))
}

fn cmd_downstream(rt: &Runtime, args: &Args) -> Result<()> {
    let base = args.get_or("base", "tiny");
    let (params, lora) = load_weights(rt, args, base)?;
    let m = ModelUnderTest::new(rt, base, &[&params, &lora])?;
    let s = eval_all(&m, 0, 12, 8, 4, 4, &[0.0, 0.4])?;
    println!("mathqa {:.3}  gsm {:.3}", s.mathqa, s.gsm);
    println!("csr mean {:.3} ± {:.3}", s.csr_mean, s.csr_se);
    for (name, acc) in &s.csr {
        println!("  {name:<10} {acc:.3}");
    }
    println!("pass@1 {:.3}  pass@10 {:.3}", s.pass1, s.pass10);
    Ok(())
}

fn cmd_repro(rt: &Runtime, args: &Args) -> Result<()> {
    let scale = Scale::from_str(args.get_or("scale", "smoke")).context("bad --scale")?;
    let seed = args.get_usize("seed", 0) as u64;
    let exp = args.get_or("exp", "all");
    if exp == "all" {
        for e in experiments::ALL_EXPERIMENTS {
            log::info(format!("=== repro {e} ({scale:?}) ==="));
            experiments::run(rt, e, scale, seed)?;
        }
        Ok(())
    } else {
        experiments::run(rt, exp, scale, seed)
    }
}

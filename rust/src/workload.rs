//! Trace-driven adversarial workload generator for the SLO serving bench
//! (DESIGN.md §2i). Each scenario is a pure function of
//! `(scenario, n, seed)` built from the repo PCG64-DXSM [`Rng`] using
//! *integer draws only* — no float math touches the request stream — so
//! `tools/workload_gen.py` reproduces every stream bit-for-bit and the
//! Python tick model in `tools/slo_sim.py` replays identical arrivals.
//! The loramlint contract-mirror pins [`SCENARIOS`] against the Python
//! side; renaming a scenario on one side fails the lint.
//!
//! Draw order per request is part of the contract (the mirror consumes
//! the same Rng stream): each arm documents its exact sequence of
//! `below()` calls.

use crate::coordinator::adapters::AdapterId;
use crate::coordinator::generate::SampleCfg;
use crate::serve::{DecodeEngine, Priority, Response, Server};
use crate::util::rng::Rng;
use anyhow::{bail, Result};

/// Scenario catalog — mirrored verbatim by `tools/workload_gen.py`.
pub const SCENARIOS: &[&str] = &[
    "steady",
    "bursty-heavytail",
    "adapter-skew",
    "deadline-storm",
    "rejection-storm",
    "faults",
];

/// One synthetic request: when it arrives (scheduler ticks), how big it
/// is, and the SLO contract it carries. `prompt_len` is a *character*
/// count (the sim tokenizer is byte-oriented); `deadline_ticks` is
/// relative to arrival, exactly what [`Server::enqueue_slo`] takes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkloadReq {
    pub arrival_tick: usize,
    pub prompt_len: usize,
    pub max_new: usize,
    pub priority: Priority,
    pub deadline_ticks: Option<usize>,
    pub adapter_ix: Option<usize>,
}

/// Heavy-tailed length via integer doubling: uniform in `[base, 2·base)`
/// then doubled with probability 1/4 per round until `cap` — a discrete
/// Pareto-ish tail with no `powf`, so the mirror stays exact. Draws:
/// one `below(base)`, then one `below(4)` per doubling round (the round
/// that leaves the loop included; none once `cap` is hit).
fn heavy_tail(rng: &mut Rng, base: usize, cap: usize) -> usize {
    let mut len = base + rng.below(base);
    while len < cap && rng.below(4) == 0 {
        len *= 2;
    }
    len.min(cap)
}

/// Generate `n` requests of the named scenario. Arrival ticks are
/// non-decreasing; every request has `prompt_len >= 1` and
/// `max_new >= 1`. Unknown names are an error listing the catalog.
pub fn generate(scenario: &str, n: usize, seed: u64) -> Result<Vec<WorkloadReq>> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(n);
    let mut tick = 0usize;
    for i in 0..n {
        let req = match scenario {
            // one arrival per tick, uniform small sizes, no SLO terms —
            // the control arm. Draws: below(8), below(4).
            "steady" => WorkloadReq {
                arrival_tick: i,
                prompt_len: 8 + rng.below(8),
                max_new: 4 + rng.below(4),
                priority: Priority::Normal,
                deadline_ticks: None,
                adapter_ix: None,
            },
            // diurnal bursts of heavy-tail lengths with a high-priority
            // deadline-carrying slice — the A/B headline scenario.
            // Draws: below(4) gap coin [+ below(6) gap], heavy_tail(8),
            // heavy_tail(4), below(10) class [+ below(8) deadline].
            "bursty-heavytail" => {
                if rng.below(4) == 0 {
                    tick += 1 + rng.below(6);
                }
                let prompt_len = heavy_tail(&mut rng, 8, 512);
                let max_new = heavy_tail(&mut rng, 4, 64);
                let priority = match rng.below(10) {
                    0 | 1 => Priority::High,
                    2..=7 => Priority::Normal,
                    _ => Priority::Low,
                };
                let deadline_ticks =
                    (priority == Priority::High).then(|| 8 + rng.below(8));
                WorkloadReq {
                    arrival_tick: tick,
                    prompt_len,
                    max_new,
                    priority,
                    deadline_ticks,
                    adapter_ix: None,
                }
            }
            // 10:1 lane skew: ~10 of 11 requests hit the hot adapter —
            // the fairness-cap stressor. Draws: below(2) gap coin,
            // below(11) lane, below(8), below(6).
            "adapter-skew" => {
                tick += usize::from(rng.below(2) == 0);
                let hot = rng.below(11) < 10;
                WorkloadReq {
                    arrival_tick: tick,
                    prompt_len: 8 + rng.below(8),
                    max_new: 2 + rng.below(6),
                    priority: Priority::Normal,
                    deadline_ticks: None,
                    adapter_ix: Some(usize::from(!hot)),
                }
            }
            // waves of 8 simultaneous arrivals, every request armed with
            // a tight deadline — most of a wave expires in the queue.
            // Draws: below(8), below(4), below(6).
            "deadline-storm" => {
                if i > 0 && i % 8 == 0 {
                    tick += 4;
                }
                WorkloadReq {
                    arrival_tick: tick,
                    prompt_len: 8 + rng.below(8),
                    max_new: 2 + rng.below(4),
                    priority: Priority::Normal,
                    deadline_ticks: Some(1 + rng.below(6)),
                    adapter_ix: None,
                }
            }
            // everything lands at tick 0 with heavy-tail prompts — the
            // admission-pressure / rejection stressor. Draws:
            // heavy_tail(64), below(4).
            "rejection-storm" => WorkloadReq {
                arrival_tick: 0,
                prompt_len: heavy_tail(&mut rng, 64, 2048),
                max_new: 1 + rng.below(4),
                priority: Priority::Normal,
                deadline_ticks: None,
                adapter_ix: None,
            },
            // chaos-bench arrivals (§2j): a steady trickle with
            // occasional gaps and a small deadline-armed slice, sized so
            // the fault-storm A/B measures retry/backoff overhead rather
            // than admission pressure. Draws: below(3) gap coin
            // [+ below(4) gap], below(12), below(6), below(8) class
            // [+ below(10) deadline].
            "faults" => {
                if rng.below(3) == 0 {
                    tick += 1 + rng.below(4);
                }
                let prompt_len = 6 + rng.below(12);
                let max_new = 3 + rng.below(6);
                let priority =
                    if rng.below(8) == 0 { Priority::High } else { Priority::Normal };
                let deadline_ticks =
                    (priority == Priority::High).then(|| 12 + rng.below(10));
                WorkloadReq {
                    arrival_tick: tick,
                    prompt_len,
                    max_new,
                    priority,
                    deadline_ticks,
                    adapter_ix: None,
                }
            }
            other => bail!(
                "unknown workload scenario {other:?} (expected one of {SCENARIOS:?})"
            ),
        };
        out.push(req);
    }
    Ok(out)
}

/// Drive a server through a workload: enqueue each request at its
/// arrival tick, stepping the scheduler between arrivals, then drain.
/// The sim clock only advances while work exists, so idle gaps collapse
/// — arrivals into an empty server enqueue immediately.
pub fn run<E: DecodeEngine>(
    srv: &mut Server<E>,
    reqs: &[WorkloadReq],
) -> Result<Vec<Response>> {
    let mut out = vec![];
    for r in reqs {
        while srv.stats.ticks < r.arrival_tick && (srv.pending() > 0 || srv.in_flight() > 0)
        {
            out.extend(srv.step()?);
        }
        srv.enqueue_slo(
            "x".repeat(r.prompt_len),
            SampleCfg { max_new: r.max_new, ..SampleCfg::default() },
            r.adapter_ix.map(AdapterId::for_slot),
            r.priority,
            r.deadline_ticks,
        );
    }
    out.extend(srv.drain()?);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::audit::audit;
    use crate::obs::trace;
    use crate::serve::SimEngine;

    #[test]
    fn scenarios_are_deterministic_and_well_formed() {
        for &s in SCENARIOS {
            let a = generate(s, 64, 9).unwrap();
            let b = generate(s, 64, 9).unwrap();
            assert_eq!(a, b, "{s} must be a pure function of (n, seed)");
            assert_eq!(a.len(), 64);
            let mut last = 0;
            for r in &a {
                assert!(r.arrival_tick >= last, "{s} arrivals must be monotonic");
                last = r.arrival_tick;
                assert!(r.prompt_len >= 1 && r.max_new >= 1);
            }
            assert_ne!(
                generate(s, 64, 10).unwrap(),
                a,
                "{s} must actually consume the seed"
            );
        }
    }

    #[test]
    fn bursty_heavytail_has_a_tail_bursts_and_a_deadline_class() {
        let reqs = generate("bursty-heavytail", 256, 7).unwrap();
        assert!(reqs.iter().all(|r| r.prompt_len <= 512 && r.max_new <= 64));
        assert!(
            reqs.iter().any(|r| r.prompt_len > 64),
            "no heavy tail in 256 draws"
        );
        assert!(
            reqs.iter()
                .any(|r| r.priority == Priority::High && r.deadline_ticks.is_some()),
            "the high-priority deadline slice is missing"
        );
        assert!(
            reqs.iter().any(|r| r.priority == Priority::Low),
            "no low class"
        );
        // bursts: some consecutive pair shares an arrival tick
        assert!(reqs.windows(2).any(|w| w[0].arrival_tick == w[1].arrival_tick));
    }

    #[test]
    fn adapter_skew_is_roughly_ten_to_one() {
        let reqs = generate("adapter-skew", 512, 11).unwrap();
        let hot = reqs.iter().filter(|r| r.adapter_ix == Some(0)).count();
        let cold = reqs.iter().filter(|r| r.adapter_ix == Some(1)).count();
        assert_eq!(hot + cold, 512);
        assert!(cold > 0, "cold lane never drawn");
        assert!(hot > 6 * cold, "skew collapsed: {hot}:{cold}");
    }

    #[test]
    fn deadline_storm_arms_every_request_in_waves() {
        let reqs = generate("deadline-storm", 32, 5).unwrap();
        assert!(reqs.iter().all(|r| r.deadline_ticks.is_some()));
        let waves: std::collections::BTreeSet<usize> =
            reqs.iter().map(|r| r.arrival_tick).collect();
        assert_eq!(waves.len(), 4, "32 requests must arrive in 4 waves of 8");
    }

    /// Cross-language contract: the first four requests of every
    /// scenario at seed 9, exactly as `tools/workload_gen.py` produces
    /// them (python/tests/test_slo_sched.py pins the same tuples).
    #[test]
    fn generated_streams_match_the_python_mirror_goldens() {
        use Priority::{High, Low, Normal};
        #[allow(clippy::type_complexity)]
        let tup = |r: &WorkloadReq| -> (usize, usize, usize, Priority, Option<usize>, Option<usize>) {
            (r.arrival_tick, r.prompt_len, r.max_new, r.priority, r.deadline_ticks, r.adapter_ix)
        };
        let gold = |s: &str| {
            generate(s, 4, 9).unwrap().iter().map(tup).collect::<Vec<_>>()
        };
        assert_eq!(
            gold("steady"),
            vec![
                (0, 9, 4, Normal, None, None),
                (1, 14, 7, Normal, None, None),
                (2, 9, 4, Normal, None, None),
                (3, 10, 4, Normal, None, None),
            ]
        );
        assert_eq!(
            gold("bursty-heavytail"),
            vec![
                (1, 14, 8, High, Some(12), None),
                (1, 20, 6, Normal, None, None),
                (1, 8, 14, Low, None, None),
                (6, 11, 4, Normal, None, None),
            ]
        );
        assert_eq!(
            gold("adapter-skew"),
            vec![
                (1, 14, 7, Normal, None, Some(0)),
                (2, 10, 2, Normal, None, Some(0)),
                (2, 10, 3, Normal, None, Some(0)),
                (2, 14, 6, Normal, None, Some(0)),
            ]
        );
        assert_eq!(
            gold("deadline-storm"),
            vec![
                (0, 9, 2, Normal, Some(5), None),
                (0, 15, 2, Normal, Some(2), None),
                (0, 10, 2, Normal, Some(4), None),
                (0, 13, 3, Normal, Some(2), None),
            ]
        );
        assert_eq!(
            gold("rejection-storm"),
            vec![
                (0, 150, 4, Normal, None, None),
                (0, 158, 1, Normal, None, None),
                (0, 103, 2, Normal, None, None),
                (0, 76, 3, Normal, None, None),
            ]
        );
        assert_eq!(
            gold("faults"),
            vec![
                (1, 15, 8, Normal, None, None),
                (3, 6, 6, Normal, None, None),
                (4, 14, 6, Normal, None, None),
                (4, 14, 3, Normal, None, None),
            ]
        );
    }

    /// §2j chaos-bench arrivals: a deadline-armed High slice exists (so
    /// goodput under the fault storm is meaningful) and the stream paces
    /// out instead of dog-piling tick 0.
    #[test]
    fn faults_scenario_has_a_deadline_slice_and_paced_arrivals() {
        let reqs = generate("faults", 64, 9).unwrap();
        assert!(reqs
            .iter()
            .any(|r| r.priority == Priority::High && r.deadline_ticks.is_some()));
        assert!(reqs.iter().all(|r| r.priority != Priority::Low));
        assert!(reqs.last().unwrap().arrival_tick > 32, "arrivals must spread");
    }

    #[test]
    fn unknown_scenario_errors_with_the_catalog() {
        let err = generate("nope", 1, 0).unwrap_err().to_string();
        assert!(err.contains("steady"), "error must list the catalog: {err}");
    }

    /// End-to-end: a bursty workload through the SLO scheduler passes
    /// the full conservation audit — nothing leaks, every arrival is
    /// served, cancelled, or (transiently) preempted and re-served.
    #[test]
    fn workload_through_slo_server_passes_conservation_audit() {
        trace::install(trace::DEFAULT_CAP, false);
        let mut srv = Server::new(SimEngine::new(4), 0);
        srv.set_slo(true);
        let reqs = generate("bursty-heavytail", 24, 3).unwrap();
        let rs = run(&mut srv, &reqs).unwrap();
        let a = audit(&trace::take().expect("sink installed").into_events());
        assert!(a.ok(), "conservation violations: {:#?}", a.violations);
        assert_eq!(a.enqueued, 24);
        assert_eq!(a.finished, srv.stats.served);
        assert_eq!(a.tokens, srv.stats.total_tokens);
        assert_eq!(rs.len() + srv.stats.cancelled, 24, "every arrival accounted");
    }
}

//! Tables 1–3 (and App. E Figs. 14–15): downstream performance of LoRAM
//! variants vs the core competition (untrained big model, LoRA-trained
//! small sibling) on math / CSR / code, for both instruction datasets.
//!
//! All three tables come from the same trained models, so one runner emits
//! tab1_math.csv, tab2_csr.csv (+ per-subtask appE rows) and tab3_code.csv.

use super::{ExpCtx, Scale};
use crate::coordinator::downstream::{eval_all, ModelUnderTest};
use crate::coordinator::pipeline::{ensure_base, Pipeline, PipelineConfig, Variant};
use crate::data::instruct::Dataset;
use crate::params::init_lora;
use crate::util::log::{self, Csv};
use anyhow::Result;

pub fn run(ctx: &ExpCtx) -> Result<()> {
    let (pre, align, sft) = ctx.scale.steps();
    let (n_math, n_csr, n_code, code_samples) = ctx.scale.downstream_sizes();
    let temps = ctx.scale.temps();
    let mut math_csv = Csv::create(
        ctx.out_dir.join("tab1_math.csv"),
        &["family", "method", "dataset", "mathqa", "gsm", "param_reduction"],
    )?;
    let mut csr_csv = Csv::create(
        ctx.out_dir.join("tab2_csr.csv"),
        &["family", "method", "dataset", "csr_mean", "csr_se", "param_reduction"],
    )?;
    let mut csr_sub_csv = Csv::create(
        ctx.out_dir.join("appE_csr_subtasks.csv"),
        &["family", "method", "dataset", "subtask", "acc"],
    )?;
    let mut code_csv = Csv::create(
        ctx.out_dir.join("tab3_code.csv"),
        &["family", "method", "dataset", "pass1", "pass10", "param_reduction"],
    )?;

    let mut families = vec![("13b", ctx.scale.family2())];
    if ctx.scale == Scale::Paper {
        families.push(("70b", ctx.scale.family70()));
    }

    for dataset in [Dataset::Hermes, Dataset::Orca] {
        for &(family, (small, big, big_pruned, quantized)) in &families {
            let big_params = ensure_base(ctx.rt, big, pre, 1e-3, ctx.seed, &ctx.run_dir)?;
            let big_cfg = ctx.rt.load(&format!("eval_{big}"))?.meta.config.clone();
            let reduction = |pruned_name: Option<&str>, q: bool| -> Result<f64> {
                let count = match pruned_name {
                    Some(p) => {
                        let c = ctx.rt.load(&format!("eval_{p}"))?.meta.config.clone();
                        let n = c.param_count();
                        if q { n / 4 } else { n }
                    }
                    None => big_cfg.param_count(),
                };
                Ok(big_cfg.param_count() as f64 / count as f64)
            };

            // -- core competition: big w/o FT -------------------------------
            let zero_lora = init_lora(&big_cfg, 0);
            let mut rows: Vec<(String, ModelUnderTest, f64)> = vec![(
                format!("{big} w/o FT"),
                ModelUnderTest::new(ctx.rt, big, &[&big_params, &zero_lora])?,
                1.0,
            )];

            // -- core competition: small LoRA -------------------------------
            if small != big {
                let small_params =
                    ensure_base(ctx.rt, small, pre, 1e-3, ctx.seed, &ctx.run_dir)?;
                let small_cfg = ctx.rt.load(&format!("eval_{small}"))?.meta.config.clone();
                let plc = PipelineConfig {
                    base: small.to_string(),
                    pruned: None,
                    variant: Variant::Lora,
                    pretrain_steps: pre,
                    align_steps: 0,
                    sft_steps: sft,
                    dataset,
                    seed: ctx.seed,
                    eval_every: 0,
                    eval_seqs: 8,
                    run_dir: ctx.run_dir.clone(),
                    ..Default::default()
                };
                let res = Pipeline::new(ctx.rt, plc).run()?;
                let red = big_cfg.param_count() as f64 / small_cfg.param_count() as f64;
                rows.push((
                    format!("{small} LoRA"),
                    ModelUnderTest::new(ctx.rt, small, &[&small_params, &res.lora_recovered])?,
                    red,
                ));
            }

            // -- LoRAM variants ---------------------------------------------
            let variants: Vec<(&str, Variant)> = if family == "70b" {
                vec![("QLoRAM-Rand", Variant::Rand), ("QLoRAM-Stru", Variant::Stru)]
            } else {
                vec![
                    ("LoRAM-Rand", Variant::Rand),
                    ("LoRAM-Stru", Variant::Stru),
                    ("LoRAM-Semi", Variant::Semi),
                    ("LoRAM-Unst", Variant::Unst),
                ]
            };
            for (name, v) in variants {
                let pruned = if v.structured() { Some(big_pruned) } else { None };
                let plc = PipelineConfig {
                    base: big.to_string(),
                    pruned: pruned.map(String::from),
                    variant: v,
                    quantized: quantized && v.structured(),
                    pretrain_steps: pre,
                    align_steps: align,
                    sft_steps: sft,
                    dataset,
                    seed: ctx.seed,
                    eval_every: 0,
                    eval_seqs: 8,
                    run_dir: ctx.run_dir.clone(),
                    ..Default::default()
                };
                let res = Pipeline::new(ctx.rt, plc).run()?;
                let red = reduction(pruned, quantized && v.structured())?;
                rows.push((
                    format!("{big} {name}"),
                    ModelUnderTest::new(ctx.rt, big, &[&res.base_params, &res.lora_recovered])?,
                    red,
                ));
            }

            for (method, m, red) in &rows {
                log::info(format!("tab1-3[{dataset:?}] evaluating {method}"));
                let s = eval_all(m, ctx.seed, n_math, n_csr, n_code, code_samples, &temps)?;
                let ds = format!("{dataset:?}");
                math_csv.row(&crate::csv_row![
                    family, method, ds, s.mathqa, s.gsm, red
                ])?;
                csr_csv.row(&crate::csv_row![
                    family, method, ds, s.csr_mean, s.csr_se, red
                ])?;
                for (sub, acc) in &s.csr {
                    csr_sub_csv.row(&crate::csv_row![family, method, ds, sub, acc])?;
                }
                code_csv.row(&crate::csv_row![
                    family, method, ds, s.pass1, s.pass10, red
                ])?;
            }
        }
    }
    log::info(format!("tab1-3 -> {}", ctx.out_dir.display()));
    Ok(())
}

//! Tables 4–6: parameter reduction ratios + HBM footprints for the *real*
//! LLaMA-2-13B / LLaMA-2-70B / LLaMA-3.1-70B — analytic, exact (the unit
//! tests in `memory.rs` pin every published integer).

use super::ExpCtx;
use crate::memory::{self, LlamaSpec};
use crate::util::log::{self, Csv};
use anyhow::Result;

pub fn run(ctx: &ExpCtx) -> Result<()> {
    let mut csv = Csv::create(
        ctx.out_dir.join("tab456_memory.csv"),
        &["table", "model", "method", "prune_ratio", "orig_params",
          "pruned_params", "reduction", "hbm_gb"],
    )?;
    let emit = |csv: &mut Csv, table: &str, spec: &LlamaSpec, row: memory::ReductionRow| {
        csv.row(&crate::csv_row![
            table,
            spec.name,
            row.method,
            row.prune_ratio,
            row.orig_params,
            row.pruned_params,
            format!("{:.2}", row.reduction),
            format!("{:.2}", row.hbm_gb)
        ])
        .unwrap();
    };

    // Table 4: LLaMA-2-13B
    let s13 = memory::LLAMA2_13B;
    emit(&mut csv, "tab4", &s13, memory::loram_row(&s13, "LoRAM-Semi", 0.50));
    emit(&mut csv, "tab4", &s13, memory::loram_row(&s13, "LoRAM-Unst", 0.55));
    emit(&mut csv, "tab4", &s13, memory::loram_row(&s13, "LoRAM-Rand&Stru", 0.65));

    // Table 5: LLaMA-2-70B sweep + LLaMA-3.1-70B
    let s70 = memory::LLAMA2_70B;
    for ratio in [0.65, 0.75, 0.85, 0.95] {
        emit(&mut csv, "tab5", &s70, memory::loram_row(&s70, "LoRAM-Rand&Stru", ratio));
    }
    let s703 = memory::LLAMA31_70B;
    emit(&mut csv, "tab5", &s703, memory::loram_row(&s703, "LoRAM-Rand&Stru", 0.85));

    // Table 6: QLoRAM (NF4) rows
    for ratio in [0.65, 0.75, 0.85, 0.95] {
        emit(&mut csv, "tab6", &s70, memory::qloram_row(&s70, "QLoRAM-Rand&Stru", ratio));
    }
    emit(&mut csv, "tab6", &s703, memory::qloram_row(&s703, "QLoRAM-Rand&Stru", 0.85));

    log::info(format!("tab456 -> {} (exactness pinned by memory.rs unit tests)",
        ctx.out_dir.display()));
    Ok(())
}

//! Fig. 6: necessity of Recovery & Alignment — ablation over all four
//! pruning strategies × {±alignment}, tracking both the recovered (full
//! model) and non-recovered (pruned model) OOD perplexity per eval point.
//!
//! The pipeline already computes both series (EvalPoint.ood_ppl vs
//! .ood_ppl_pruned), so this runner is a 4×2 sweep.

use super::ExpCtx;
use crate::coordinator::pipeline::{Pipeline, PipelineConfig, Variant};
use crate::data::instruct::Dataset;
use crate::util::log::{self, Csv};
use anyhow::Result;

pub fn run(ctx: &ExpCtx) -> Result<()> {
    let (pre, align, sft) = ctx.scale.steps();
    let (_small, big, big_pruned, _) = ctx.scale.family2();
    let mut csv = Csv::create(
        ctx.out_dir.join("fig6_ablation.csv"),
        &["variant", "aligned", "step", "ppl_w_recovery", "ppl_wo_recovery"],
    )?;

    for (name, v) in [
        ("rand", Variant::Rand),
        ("stru", Variant::Stru),
        ("semi", Variant::Semi),
        ("unst", Variant::Unst),
    ] {
        for aligned in [true, false] {
            let plc = PipelineConfig {
                base: big.to_string(),
                pruned: if v.structured() {
                    Some(big_pruned.to_string())
                } else {
                    None
                },
                variant: v,
                pretrain_steps: pre,
                align_steps: align,
                align: aligned,
                sft_steps: sft,
                dataset: Dataset::Hermes,
                seed: ctx.seed,
                eval_every: ctx.scale.eval_every(),
                eval_seqs: ctx.scale.eval_seqs(),
                run_dir: ctx.run_dir.clone(),
                ..Default::default()
            };
            log::info(format!("fig6 running {name} aligned={aligned}"));
            let res = Pipeline::new(ctx.rt, plc).run()?;
            for p in &res.eval_points {
                csv.row(&crate::csv_row![
                    name,
                    aligned,
                    p.step,
                    p.ood_ppl,
                    p.ood_ppl_pruned.map(|x| x.to_string()).unwrap_or_default()
                ])?;
            }
        }
    }
    log::info(format!("fig6 -> {}", ctx.out_dir.display()));
    Ok(())
}

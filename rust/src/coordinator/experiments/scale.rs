//! Experiment scale presets.
//!
//! `smoke` exercises every code path in minutes on the tiny artifacts;
//! `paper` runs the proxy-family reproduction (hours on this single-core
//! box — step counts noted per experiment in DESIGN.md §3).

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Smoke,
    Paper,
}

impl Scale {
    pub fn from_str(s: &str) -> Option<Scale> {
        match s {
            "smoke" => Some(Scale::Smoke),
            "paper" => Some(Scale::Paper),
            _ => None,
        }
    }

    /// (pretrain, align, sft) step counts.
    pub fn steps(&self) -> (usize, usize, usize) {
        match self {
            Scale::Smoke => (30, 8, 16),
            Scale::Paper => (600, 120, 200),
        }
    }

    pub fn eval_every(&self) -> usize {
        match self {
            Scale::Smoke => 8,
            Scale::Paper => 40,
        }
    }

    pub fn eval_seqs(&self) -> usize {
        match self {
            Scale::Smoke => 16,
            Scale::Paper => 64,
        }
    }

    /// (n_math, n_csr_per_task, n_code, code_samples)
    pub fn downstream_sizes(&self) -> (usize, usize, usize, usize) {
        match self {
            Scale::Smoke => (12, 8, 4, 4),
            Scale::Paper => (60, 40, 16, 10),
        }
    }

    pub fn temps(&self) -> Vec<f64> {
        match self {
            Scale::Smoke => vec![0.0, 0.4],
            Scale::Paper => vec![0.0, 0.2, 0.4, 0.6, 0.8],
        }
    }

    /// Model configs for the LLaMA-2 experiment family:
    /// (small_lora_baseline, big_base, big_pruned, quantized)
    pub fn family2(&self) -> (&'static str, &'static str, &'static str, bool) {
        match self {
            Scale::Smoke => ("tiny", "tiny", "tiny_p50", false),
            Scale::Paper => ("l7b", "l13b", "l13b_p65", false),
        }
    }

    /// The 70B-analogue family: (lora_baseline, base, pruned, quantized).
    pub fn family70(&self) -> (&'static str, &'static str, &'static str, bool) {
        match self {
            Scale::Smoke => ("tiny", "tiny", "tiny_p50", false),
            Scale::Paper => ("l13b", "l70b", "l70b_p75", true),
        }
    }

    /// LLaMA-3.1 family (fig5/tab7).
    pub fn family31(&self) -> (&'static str, &'static str, &'static str, bool) {
        match self {
            Scale::Smoke => ("tiny", "tiny", "tiny_p50", false),
            Scale::Paper => ("l8b", "l70b3", "l70b3_p85", true),
        }
    }
}

//! Fig. 16 (App. G): learning-rate tuning for the LoRA baselines — final
//! in-domain and out-of-domain perplexity across an LR grid.

use super::ExpCtx;
use crate::coordinator::pipeline::{Pipeline, PipelineConfig, Variant};
use crate::data::instruct::Dataset;
use crate::util::log::{self, Csv};
use anyhow::Result;

pub fn run(ctx: &ExpCtx) -> Result<()> {
    let (pre, _align, sft) = ctx.scale.steps();
    let (small, big, _p, _) = ctx.scale.family2();
    let lrs = match ctx.scale {
        super::Scale::Smoke => vec![1e-3, 1e-4],
        super::Scale::Paper => vec![1e-2, 1e-3, 1e-4, 1e-5],
    };
    let mut csv = Csv::create(
        ctx.out_dir.join("fig16_lr_sweep.csv"),
        &["model", "lr", "final_ood_ppl", "final_id_ppl"],
    )?;
    let models: Vec<&str> = if small == big { vec![big] } else { vec![small, big] };
    for model in models {
        for &lr in &lrs {
            let plc = PipelineConfig {
                base: model.to_string(),
                pruned: None,
                variant: Variant::Lora,
                pretrain_steps: pre,
                align_steps: 0,
                sft_steps: sft,
                lr_sft: lr,
                dataset: Dataset::Hermes,
                seed: ctx.seed,
                eval_every: 0, // final point only
                eval_seqs: ctx.scale.eval_seqs(),
                run_dir: ctx.run_dir.clone(),
                ..Default::default()
            };
            log::info(format!("fig16 {model} lr={lr}"));
            let res = Pipeline::new(ctx.rt, plc).run()?;
            let last = res.eval_points.last().expect("final eval point");
            csv.row(&crate::csv_row![model, lr, last.ood_ppl, last.id_ppl])?;
        }
    }
    log::info(format!("fig16 -> {}", ctx.out_dir.display()));
    Ok(())
}

//! Figs. 3 & 4: test perplexity over SFT iterations — out-of-domain
//! (Alpaca stand-in) and in-domain test sets, LoRA baselines vs the four
//! LoRAM variants, for the 13B-proxy family and (paper scale) the
//! 70B-proxy QLoRAM family.

use super::{ExpCtx, Scale};
use crate::coordinator::pipeline::{Pipeline, PipelineConfig, Variant};
use crate::data::instruct::Dataset;
use crate::util::log::{self, Csv};
use anyhow::Result;

pub fn run(ctx: &ExpCtx, dataset: Dataset) -> Result<()> {
    let (pre, align, sft) = ctx.scale.steps();
    let mut csv = Csv::create(
        ctx.out_dir.join("ppl_curves.csv"),
        &["family", "method", "step", "ood_ppl", "id_ppl", "ood_ppl_wo_recovery"],
    )?;

    let (small, big, big_pruned, _) = ctx.scale.family2();
    let mut jobs: Vec<(&str, String, PipelineConfig)> = vec![];
    let base_cfg = |base: &str, pruned: Option<&str>, variant, quantized| PipelineConfig {
        base: base.to_string(),
        pruned: pruned.map(String::from),
        variant,
        quantized,
        pretrain_steps: pre,
        align_steps: align,
        sft_steps: sft,
        dataset,
        seed: ctx.seed,
        eval_every: ctx.scale.eval_every(),
        eval_seqs: ctx.scale.eval_seqs(),
        run_dir: ctx.run_dir.clone(),
        ..Default::default()
    };

    jobs.push(("13b", format!("{small} LoRA"), base_cfg(small, None, Variant::Lora, false)));
    jobs.push(("13b", format!("{big} LoRA"), base_cfg(big, None, Variant::Lora, false)));
    for (name, v) in [
        ("LoRAM-Rand", Variant::Rand),
        ("LoRAM-Stru", Variant::Stru),
        ("LoRAM-Semi", Variant::Semi),
        ("LoRAM-Unst", Variant::Unst),
    ] {
        let pruned = if v.structured() { Some(big_pruned) } else { None };
        jobs.push(("13b", format!("{big} {name}"), base_cfg(big, pruned, v, false)));
    }
    if ctx.scale == Scale::Paper {
        let (small70, big70, big70_pruned, q) = ctx.scale.family70();
        jobs.push((
            "70b",
            format!("{small70} LoRA"),
            base_cfg(small70, None, Variant::Lora, false),
        ));
        for (name, v) in [("QLoRAM-Rand", Variant::Rand), ("QLoRAM-Stru", Variant::Stru)] {
            jobs.push((
                "70b",
                format!("{big70} {name}"),
                base_cfg(big70, Some(big70_pruned), v, q),
            ));
        }
    }

    for (family, method, cfg) in jobs {
        log::info(format!("fig3/4[{dataset:?}] running {method}"));
        let res = Pipeline::new(ctx.rt, cfg).run()?;
        for p in &res.eval_points {
            csv.row(&crate::csv_row![
                family,
                method,
                p.step,
                p.ood_ppl,
                p.id_ppl,
                p.ood_ppl_pruned.map(|x| x.to_string()).unwrap_or_default()
            ])?;
        }
    }
    log::info(format!("fig3/4 -> {}", ctx.out_dir.display()));
    Ok(())
}

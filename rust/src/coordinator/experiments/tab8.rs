//! Table 8: online-phase peak memory / latency / throughput — small-LoRA
//! vs big-LoRA vs big-LoRAM-Stru, measured on this testbed (the paper's
//! 1024-sample workload scaled by the artifact batch size).

use super::ExpCtx;
use crate::coordinator::adapters::AdapterId;
use crate::coordinator::generate::{DecodePath, Generator, SampleCfg};
use crate::coordinator::pipeline::ensure_base;
use crate::coordinator::train::TrainSession;
use crate::data::instruct::{Dataset, InstructGen};
use crate::data::make_batch;
use crate::params::init_lora;
use crate::pruning;
use crate::chaos::ChaosEngine;
use crate::serve::{Server, ServerStats};
use crate::tokenizer::Tokenizer;
use crate::util::log::{self, Csv};
use anyhow::Result;
use std::time::Instant;

/// The shared serving workload: one seed, one config mix, so the baseline
/// rows and the mixed-adapter row of `tab8_serving.csv` stay comparable.
/// `ids` routes request i through `ids[i % len]` (empty = adapter-less).
fn enqueue_serve_workload(
    srv: &mut Server<Generator<'_>>,
    n: usize,
    seed: u64,
    ids: &[AdapterId],
    temperature: f64,
) {
    let mut ig = InstructGen::new(Dataset::Hermes, seed, 2);
    for i in 0..n {
        let (ex, _) = ig.next();
        srv.enqueue_adapter(
            ex.instruction,
            SampleCfg {
                temperature,
                top_p: if i % 2 == 0 { 0.95 } else { 0.8 },
                max_new: 8,
            },
            if ids.is_empty() { None } else { Some(ids[i % ids.len()]) },
        );
    }
}

pub fn run(ctx: &ExpCtx) -> Result<()> {
    let (pre, _align, _sft) = ctx.scale.steps();
    let (small, big, big_pruned, _) = ctx.scale.family2();
    let workload_steps = match ctx.scale {
        super::Scale::Smoke => 6,
        super::Scale::Paper => 32,
    };
    let mut csv = Csv::create(
        ctx.out_dir.join("tab8_training_cost.csv"),
        &["method", "model_params", "reduction", "peak_rss_mib",
          "latency_s", "throughput_samples_s"],
    )?;

    let big_cfg = ctx.rt.load(&format!("eval_{big}"))?.meta.config.clone();
    let jobs: Vec<(String, String)> = vec![
        (format!("{small} LoRA"), format!("sft_{small}")),
        (format!("{big} LoRA"), format!("sft_{big}")),
        (format!("{big} LoRAM-Stru"), format!("sft_{big_pruned}")),
    ];

    for (method, artifact) in jobs {
        let art = ctx.rt.load(&artifact)?;
        let cfg = art.meta.config.clone();
        // weights for the model the artifact trains
        let params = if artifact.contains(&format!("sft_{big_pruned}")) {
            let base = ensure_base(ctx.rt, big, pre, 1e-3, ctx.seed, &ctx.run_dir)?;
            let full_cfg = ctx.rt.load(&format!("eval_{big}"))?.meta.config.clone();
            let plan = pruning::StructuredPlan::random(&full_cfg, &cfg, ctx.seed)?;
            pruning::slice_params(&base, &full_cfg, &plan)?
        } else {
            let name = artifact.trim_start_matches("sft_");
            ensure_base(ctx.rt, name, pre, 1e-3, ctx.seed, &ctx.run_dir)?
        };
        let lora = init_lora(&cfg, ctx.seed);
        let mut sess = TrainSession::new(ctx.rt, &artifact, &[&params, &lora])?;
        let b = sess.batch_size();
        let s = sess.seq_len();
        let tk = Tokenizer::new();
        let mut gen = InstructGen::new(Dataset::Hermes, ctx.seed, 0);
        // one warmup step (compile+cache effects), then timed workload
        let seqs: Vec<Vec<i32>> = gen.batch_examples(b).iter().map(|e| e.tokens(&tk)).collect();
        sess.train_step(&make_batch(&seqs, b, s, true), 1e-3)?;
        let t0 = Instant::now();
        for _ in 0..workload_steps {
            let seqs: Vec<Vec<i32>> =
                gen.batch_examples(b).iter().map(|e| e.tokens(&tk)).collect();
            sess.train_step(&make_batch(&seqs, b, s, true), 1e-3)?;
        }
        let latency = t0.elapsed().as_secs_f64();
        let samples = (workload_steps * b) as f64;
        let reduction = big_cfg.param_count() as f64 / cfg.param_count() as f64;
        log::info(format!(
            "tab8 {method}: {latency:.2}s for {samples} samples ({:.2} samples/s)",
            samples / latency
        ));
        csv.row(&crate::csv_row![
            method,
            cfg.param_count(),
            format!("{reduction:.2}"),
            format!("{:.0}", crate::bench::peak_rss_mib()),
            format!("{latency:.2}"),
            format!("{:.3}", samples / latency)
        ])?;
    }

    // serving-side counterpart (the "infer large" hot path): decode
    // throughput and TTFT through the continuous-batching scheduler, small
    // LoRA target vs the big recovered-inference target; the `adapter`
    // column breaks every method down per adapter lane ("all" = aggregate)
    // acceptance_rate: engine-level drafts-accepted/drafts-proposed on
    // aggregate rows; per-lane rows report that lane's accepted-token
    // share instead (per-lane proposals are not separable — lanes share
    // every draft round). Blank off the speculative path.
    // prefill column: monolithic pad-to-S vs the §2e chunked bucket
    // ladder; padded_prefill_tokens is the admission waste counter and
    // the tick percentiles are the sim-time TTFT/ITL distributions
    // prefix_hit_rate/blocks_in_use/cow_copies: the §2f block-pool
    // counters, blank off the paged path (cow_copies must read 0 — the
    // serving flow shares only full immutable prefix blocks)
    // goodput/preempted/cancelled/deadline_misses: the §2i SLO columns —
    // goodput is in-deadline finishes over offered load, and all four
    // read 0/1.000 under the plain FIFO scheduler used here (aggregate
    // rows only; the lane rows leave them blank)
    // failed/retries/degraded_ticks: the §2j fault columns — zero on
    // every row but the fault-storm A/B pair at the bottom, where the
    // retry+isolation arm must out-goodput the abort-on-error arm
    let mut scsv = Csv::create(
        ctx.out_dir.join("tab8_serving.csv"),
        &["method", "decode_path", "prefill", "adapter", "requests",
          "tokens_per_sec", "mean_ttft_ms", "mean_latency_ms",
          "mean_occupancy", "mean_queue_wait_ms", "peak_queue_depth",
          "padded_prefill_tokens", "ttft_p95_ticks", "itl_p95_ticks",
          "acceptance_rate", "draft_steps", "verify_steps",
          "prefix_hit_rate", "blocks_in_use", "cow_copies",
          "goodput", "preempted", "cancelled", "deadline_misses",
          "failed", "retries", "degraded_ticks"],
    )?;
    let serve_requests = workload_steps * 2;
    let mut serve_rows = |method: &str,
                          decode_path: &str,
                          prefill: &str,
                          stats: &ServerStats|
     -> Result<()> {
        // every cell reads back out of the unified metrics registry
        // (DESIGN.md §2g) — the CSV cannot drift from BENCH_serve.json or
        // the serve summary, because all three read the same names
        let m = stats.to_metrics();
        log::info(format!(
            "tab8 {method} [{decode_path}/{prefill}]: {:.1} tok/s, ttft {:.1} ms, \
             occupancy {:.2}, queue wait {:.2} ms (peak depth {}, {} padded \
             prefill tokens)",
            m.gauge("serve.tokens_per_sec"),
            m.gauge("serve.mean_ttft_ms"),
            m.gauge("serve.mean_occupancy"),
            m.gauge("serve.mean_queue_wait_ms"),
            m.gauge("serve.peak_queue_depth") as usize,
            m.counter("prefill.padded_tokens") as usize
        ));
        let spec = m.has_counter("spec.rounds");
        let (rate, dsteps, vsteps) = if spec {
            (
                format!("{:.3}", m.gauge("spec.acceptance_rate")),
                format!("{}", m.counter("spec.draft_steps") as usize),
                format!("{}", m.counter("spec.verify_steps") as usize),
            )
        } else {
            (String::new(), String::new(), String::new())
        };
        let (hit_rate, blocks, cow) = if m.has_gauge("paged.prefix_hit_rate") {
            (
                format!("{:.3}", m.gauge("paged.prefix_hit_rate")),
                format!("{}", m.gauge("paged.blocks_in_use") as usize),
                format!("{}", m.counter("paged.cow_copies") as usize),
            )
        } else {
            (String::new(), String::new(), String::new())
        };
        scsv.row(&crate::csv_row![
            method,
            decode_path,
            prefill,
            "all",
            m.counter("serve.admitted") as usize,
            format!("{:.2}", m.gauge("serve.tokens_per_sec")),
            format!("{:.2}", m.gauge("serve.mean_ttft_ms")),
            format!("{:.2}", m.gauge("serve.mean_latency_ms")),
            format!("{:.3}", m.gauge("serve.mean_occupancy")),
            format!("{:.2}", m.gauge("serve.mean_queue_wait_ms")),
            m.gauge("serve.peak_queue_depth") as usize,
            m.counter("prefill.padded_tokens") as usize,
            format!("{:.0}", m.gauge("serve.ttft_tick_p95")),
            format!("{:.0}", m.gauge("serve.itl_tick_p95")),
            rate,
            dsteps,
            vsteps,
            hit_rate,
            blocks,
            cow,
            format!("{:.3}", m.gauge("serve.goodput")),
            m.counter("serve.preempted") as usize,
            m.counter("serve.cancelled") as usize,
            m.counter("serve.deadline_misses") as usize,
            m.counter("serve.failed") as usize,
            m.counter("serve.retries") as usize,
            m.counter("serve.degraded_ticks") as usize
        ])?;
        for adapter in stats.per_adapter.keys() {
            let label = crate::serve::adapter_label(*adapter);
            let k = |field: &str| format!("adapter.{label}.{field}");
            let lane_rate = if spec {
                format!("{:.3}", m.gauge(&k("draft_accept_share")))
            } else {
                String::new()
            };
            scsv.row(&crate::csv_row![
                method,
                decode_path,
                prefill,
                label,
                m.counter(&k("requests")) as usize,
                format!("{:.2}", m.gauge(&k("tokens_per_sec"))),
                format!("{:.2}", m.gauge(&k("mean_ttft_ms"))),
                format!("{:.2}", m.gauge(&k("mean_latency_ms"))),
                "",
                "",
                "",
                "",
                "",
                "",
                lane_rate,
                "",
                "",
                "",
                "",
                "",
                "",
                "",
                "",
                "",
                "",
                "",
                ""
            ])?;
        }
        Ok(())
    };
    for (method, base) in [(format!("{small} serve"), small), (format!("{big} serve"), big)] {
        let params = ensure_base(ctx.rt, base, pre, 1e-3, ctx.seed, &ctx.run_dir)?;
        let mcfg = ctx.rt.load(&format!("eval_{base}"))?.meta.config.clone();
        let lora = init_lora(&mcfg, ctx.seed);
        let gen = Generator::new(ctx.rt, &format!("logits_{base}"), &[&params, &lora])?;
        let decode_path = gen.decode_path().name().to_string();
        let chunked = gen.chunked_prefill();
        let prefill = if chunked { "chunked" } else { "monolithic" };
        let mut srv = Server::new(gen, ctx.seed);
        enqueue_serve_workload(&mut srv, serve_requests, ctx.seed, &[], 0.4);
        srv.drain()?;
        serve_rows(&method, &decode_path, prefill, &srv.stats)?;
        if chunked {
            // the §2e A/B: the same workload through the monolithic
            // pad-to-S admission, so the padded-token and latency deltas
            // are read off adjacent rows
            let gen =
                Generator::new(ctx.rt, &format!("logits_{base}"), &[&params, &lora])?;
            gen.set_chunked_prefill(false)?;
            let decode_path = gen.decode_path().name().to_string();
            let mut srv = Server::new(gen, ctx.seed);
            enqueue_serve_workload(&mut srv, serve_requests, ctx.seed, &[], 0.4);
            srv.drain()?;
            serve_rows(&format!("{method} (pad-to-S)"), &decode_path, "monolithic", &srv.stats)?;
        }
        // the §2f A/B: the same workload through the paged decode family
        // (pooled block caches + shared-prefix reuse) when it is in the
        // suite, adjacent to the dense rows so the pool counters and
        // latency deltas read off directly
        let paged_ready = ctx.rt.load(&format!("decode_prefill_paged_{base}")).is_ok()
            && ctx.rt.load(&format!("decode_step_paged_{base}")).is_ok();
        if paged_ready {
            let gen = Generator::with_path_paged(
                ctx.rt,
                &format!("logits_{base}"),
                &[&params, &lora],
                Some(DecodePath::KvCache),
                true,
            )?;
            let prefill = if gen.chunked_prefill() { "chunked" } else { "monolithic" };
            let mut srv = Server::new(gen, ctx.seed);
            enqueue_serve_workload(&mut srv, serve_requests, ctx.seed, &[], 0.4);
            srv.drain()?;
            serve_rows(&format!("{method} (paged)"), "kvcache-paged", prefill, &srv.stats)?;
        } else {
            log::info(format!(
                "tab8: no decode_*_paged_{base} family registered; skipping \
                 the paged serving row"
            ));
        }
    }

    // mixed-adapter serving: one frozen base, every request routed through
    // its own adapter slot of the stacked artifact (DESIGN.md §2c)
    // a dir without manifest.json is legitimate here (artifacts loaded by
    // name), but the skip must name the real cause, not claim absence
    let manifest = match ctx.rt.manifest() {
        Ok(m) => m,
        Err(e) => {
            log::info(format!("tab8: artifact manifest unavailable ({e:#})"));
            vec![]
        }
    };
    let stacked = crate::coordinator::adapters::stacked_logits_artifact(&manifest, big);
    match stacked {
        Some(art_name) => {
            let params = ensure_base(ctx.rt, big, pre, 1e-3, ctx.seed, &ctx.run_dir)?;
            let gen = Generator::with_adapters(ctx.rt, &art_name, &[&params], None, None)?;
            let cap = gen.adapter_capacity().unwrap_or(1);
            let mcfg = ctx.rt.load(&art_name)?.meta.config.clone();
            let ids: Vec<_> = (0..cap)
                .map(|i| {
                    gen.register_adapter(
                        &format!("task{i}"),
                        init_lora(&mcfg, ctx.seed ^ (i as u64 + 1)),
                    )
                })
                .collect::<Result<_>>()?;
            let method = format!("{big} serve x{cap} adapters");
            let decode_path = gen.decode_path().name().to_string();
            let prefill = if gen.chunked_prefill() { "chunked" } else { "monolithic" };
            let mut srv = Server::new(gen, ctx.seed);
            enqueue_serve_workload(&mut srv, serve_requests, ctx.seed, &ids, 0.4);
            srv.drain()?;
            serve_rows(&method, &decode_path, prefill, &srv.stats)?;
        }
        None => log::info(format!(
            "tab8: no stacked logits_{big}_a<N> artifact; skipping the \
             mixed-adapter serving row"
        )),
    }

    // draft small, verify large: the pruned proxy drafts, the big model
    // verifies (DESIGN.md §2d) — skipped with a log line when the verify
    // or drafter artifacts are not in the suite
    let spec_ready = ctx.rt.load(&format!("decode_verify_{big}")).is_ok()
        && ctx.rt.load(&format!("decode_prefill_{big_pruned}")).is_ok()
        && ctx.rt.load(&format!("decode_step_{big_pruned}")).is_ok();
    if spec_ready {
        let params = ensure_base(ctx.rt, big, pre, 1e-3, ctx.seed, &ctx.run_dir)?;
        let full_cfg = ctx.rt.load(&format!("eval_{big}"))?.meta.config.clone();
        let lora = init_lora(&full_cfg, ctx.seed);
        let (dparams, dlora) = crate::coordinator::speculative::sliced_drafter_standin(
            ctx.rt, &full_cfg, &params, big_pruned, ctx.seed,
        )?;
        let gen = Generator::with_speculative(
            ctx.rt,
            &format!("logits_{big}"),
            &[&params, &lora],
            big_pruned,
            &[&dparams, &dlora],
        )?;
        let prefill = if gen.chunked_prefill() { "chunked" } else { "monolithic" };
        let mut srv = Server::new(gen, ctx.seed);
        // greedy workload: speculative acceptance is a greedy-path
        // concept (sampled rows degrade to 1-token verify windows)
        enqueue_serve_workload(&mut srv, serve_requests, ctx.seed, &[], 0.0);
        srv.drain()?;
        serve_rows(
            &format!("{big} serve (drafter {big_pruned})"),
            "speculative",
            prefill,
            &srv.stats,
        )?;
    } else {
        log::info(format!(
            "tab8: decode_verify_{big} or the {big_pruned} drafter pair \
             missing; skipping the speculative serving row"
        ));
    }

    // the §2j fault-storm A/B: the same deterministic storm
    // (`ChaosEngine`, scenario "fault-storm", pinned seed) through the
    // real small-target engine, abort-on-error vs bounded retry +
    // failure-domain isolation. The abort arm's drain dies at the first
    // unabsorbed fault — its partial stats with zero graceful failures
    // ARE the measurement; the retry arm must resolve every request and
    // read higher goodput off the adjacent row.
    {
        let params = ensure_base(ctx.rt, small, pre, 1e-3, ctx.seed, &ctx.run_dir)?;
        let mcfg = ctx.rt.load(&format!("eval_{small}"))?.meta.config.clone();
        for (label, retry) in [("abort-on-error", false), ("retry+isolation", true)] {
            let lora = init_lora(&mcfg, ctx.seed);
            let gen = Generator::new(ctx.rt, &format!("logits_{small}"), &[&params, &lora])?;
            let decode_path = gen.decode_path().name().to_string();
            let prefill = if gen.chunked_prefill() { "chunked" } else { "monolithic" };
            let chaos = ChaosEngine::new(gen, "fault-storm", 64, 9)?;
            let mut srv = Server::new(chaos, ctx.seed);
            if retry {
                srv.set_retry_policy(Some(2), 1);
            }
            let reqs = crate::workload::generate("faults", serve_requests, 9)?;
            if let Err(e) = crate::workload::run(&mut srv, &reqs) {
                anyhow::ensure!(
                    !retry,
                    "tab8 chaos: the retry+isolation arm must survive the storm: {e}"
                );
                log::info(format!("tab8 chaos abort arm died as designed: {e:#}"));
            }
            serve_rows(
                &format!("{small} serve fault-storm ({label})"),
                &decode_path,
                prefill,
                &srv.stats,
            )?;
        }
    }
    log::info(format!("tab8 -> {}", ctx.out_dir.display()));
    Ok(())
}

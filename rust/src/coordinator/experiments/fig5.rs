//! Fig. 5: LLaMA-3.1 adaptation — perplexity + downstream for the 3.1
//! proxy family, and the effect of alignment step count (0 / 200-analogue /
//! full) on QLoRAM-Stru performance.

use super::ExpCtx;
use crate::coordinator::downstream::{eval_all, ModelUnderTest};
use crate::coordinator::pipeline::{Pipeline, PipelineConfig, Variant};
use crate::data::instruct::Dataset;
use crate::util::log::{self, Csv};
use anyhow::Result;

pub fn run(ctx: &ExpCtx) -> Result<()> {
    let (pre, align, sft) = ctx.scale.steps();
    let (small, big, big_pruned, quantized) = ctx.scale.family31();
    let (n_math, n_csr, n_code, code_samples) = ctx.scale.downstream_sizes();
    let mut ppl_csv = Csv::create(
        ctx.out_dir.join("fig5_ppl.csv"),
        &["method", "align_steps", "step", "ood_ppl", "id_ppl"],
    )?;
    let mut ds_csv = Csv::create(
        ctx.out_dir.join("fig5_downstream.csv"),
        &["method", "align_steps", "mathqa", "gsm", "csr_mean", "pass10"],
    )?;

    // alignment-steps sweep: 0 (w/o alignment), 1/8, full — mirroring the
    // paper's QLoRAM-Stru 0/200/400/1600 sweep
    let sweeps = [0usize, (align / 8).max(1), align];
    let mut jobs: Vec<(String, usize, PipelineConfig)> = vec![];
    let mk = |base: &str, pruned: Option<&str>, v, q, align_steps: usize| PipelineConfig {
        base: base.to_string(),
        pruned: pruned.map(String::from),
        variant: v,
        quantized: q,
        pretrain_steps: pre,
        align_steps,
        align: align_steps > 0,
        sft_steps: sft,
        dataset: Dataset::Hermes,
        seed: ctx.seed,
        eval_every: ctx.scale.eval_every(),
        eval_seqs: ctx.scale.eval_seqs(),
        run_dir: ctx.run_dir.clone(),
        ..Default::default()
    };
    jobs.push((format!("{small} LoRA"), 0, mk(small, None, Variant::Lora, false, 0)));
    jobs.push((format!("{big} LoRA"), 0, mk(big, None, Variant::Lora, false, 0)));
    for &a in &sweeps {
        jobs.push((
            format!("{big} QLoRAM-Stru"),
            a,
            mk(big, Some(big_pruned), Variant::Stru, quantized, a),
        ));
    }

    for (method, align_steps, plc) in jobs {
        log::info(format!("fig5 running {method} (align={align_steps})"));
        let base = plc.base.clone();
        let res = Pipeline::new(ctx.rt, plc).run()?;
        for p in &res.eval_points {
            ppl_csv.row(&crate::csv_row![method, align_steps, p.step, p.ood_ppl, p.id_ppl])?;
        }
        let m = ModelUnderTest::new(ctx.rt, &base, &[&res.base_params, &res.lora_recovered])?;
        let s = eval_all(&m, ctx.seed, n_math, n_csr, n_code, code_samples, &ctx.scale.temps())?;
        ds_csv.row(&crate::csv_row![
            method, align_steps, s.mathqa, s.gsm, s.csr_mean, s.pass10
        ])?;
    }
    log::info(format!("fig5 -> {}", ctx.out_dir.display()));
    Ok(())
}

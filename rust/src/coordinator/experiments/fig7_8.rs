//! Fig. 7: perplexity vs parameter-reduction ratio — QLoRAM-Stru against
//! naive pruning (the pruned+aligned model evaluated directly, no LoRA, no
//! recovery) across the 70B-proxy pruning sweep.
//!
//! Fig. 8: downstream task scores across the same reduction sweep.

use super::{ExpCtx, Scale};
use crate::coordinator::downstream::{eval_all, ModelUnderTest};
use crate::coordinator::evaluate::{test_sequences, Evaluator};
use crate::coordinator::pipeline::{Pipeline, PipelineConfig, Variant};
use crate::data::instruct::Dataset;
use crate::params::init_lora;
use crate::util::log::{self, Csv};
use anyhow::Result;

fn sweep(ctx: &ExpCtx) -> Vec<(&'static str, &'static str)> {
    match ctx.scale {
        Scale::Smoke => vec![("tiny", "tiny_p50")],
        Scale::Paper => vec![
            ("l70b", "l70b_p65"),
            ("l70b", "l70b_p75"),
            ("l70b", "l70b_p85"),
            ("l70b", "l70b_p95"),
        ],
    }
}

fn pipeline_cfg(ctx: &ExpCtx, base: &str, pruned: &str, steps: (usize, usize, usize)) -> PipelineConfig {
    PipelineConfig {
        base: base.to_string(),
        pruned: Some(pruned.to_string()),
        variant: Variant::Stru,
        quantized: ctx.scale == Scale::Paper,
        pretrain_steps: steps.0,
        align_steps: steps.1,
        sft_steps: steps.2,
        dataset: Dataset::Hermes,
        seed: ctx.seed,
        eval_every: 0,
        eval_seqs: ctx.scale.eval_seqs(),
        run_dir: ctx.run_dir.clone(),
        ..Default::default()
    }
}

pub fn run_fig7(ctx: &ExpCtx) -> Result<()> {
    let steps = ctx.scale.steps();
    let mut csv = Csv::create(
        ctx.out_dir.join("fig7_scaling.csv"),
        &["pruned_cfg", "reduction", "qloram_ppl", "naive_ppl", "lora_big_ppl"],
    )?;
    let ood = test_sequences(Dataset::Alpaca, ctx.seed, ctx.scale.eval_seqs());

    for (base, pruned) in sweep(ctx) {
        log::info(format!("fig7 running {pruned}"));
        let plc = pipeline_cfg(ctx, base, pruned, steps);
        let quantized = plc.quantized;
        let res = Pipeline::new(ctx.rt, plc).run()?;
        let big_cfg = ctx.rt.load(&format!("eval_{base}"))?.meta.config.clone();
        let pruned_cfg = ctx.rt.load(&format!("eval_{pruned}"))?.meta.config.clone();
        let reduction = big_cfg.param_count() as f64
            / (pruned_cfg.param_count() / if quantized { 4 } else { 1 }) as f64;
        // QLoRAM: recovered lora on the full model
        let ev = Evaluator::new(
            ctx.rt,
            &format!("eval_{base}"),
            &[&res.base_params, &res.lora_recovered],
        )?;
        let qloram_ppl = ev.perplexity(&ood, true)?;
        // naive pruning: aligned pruned model, fresh (identity) lora
        let zero = init_lora(&pruned_cfg, 0);
        let evn = Evaluator::new(
            ctx.rt,
            &format!("eval_{pruned}"),
            &[&res.pruned_params, &zero],
        )?;
        let naive_ppl = evn.perplexity(&ood, true)?;
        // reference: untouched big base
        let zero_big = init_lora(&big_cfg, 0);
        let evb = Evaluator::new(ctx.rt, &format!("eval_{base}"), &[&res.base_params, &zero_big])?;
        let big_ppl = evb.perplexity(&ood, true)?;
        csv.row(&crate::csv_row![pruned, reduction, qloram_ppl, naive_ppl, big_ppl])?;
    }
    log::info(format!("fig7 -> {}", ctx.out_dir.display()));
    Ok(())
}

pub fn run_fig8(ctx: &ExpCtx) -> Result<()> {
    let steps = ctx.scale.steps();
    let (n_math, n_csr, n_code, code_samples) = ctx.scale.downstream_sizes();
    let mut csv = Csv::create(
        ctx.out_dir.join("fig8_downstream_vs_reduction.csv"),
        &["pruned_cfg", "reduction", "mathqa", "gsm", "csr_mean", "pass1", "pass10"],
    )?;
    for (base, pruned) in sweep(ctx) {
        log::info(format!("fig8 running {pruned}"));
        let plc = pipeline_cfg(ctx, base, pruned, steps);
        let quantized = plc.quantized;
        let res = Pipeline::new(ctx.rt, plc).run()?;
        let big_cfg = ctx.rt.load(&format!("eval_{base}"))?.meta.config.clone();
        let pruned_cfg = ctx.rt.load(&format!("eval_{pruned}"))?.meta.config.clone();
        let reduction = big_cfg.param_count() as f64
            / (pruned_cfg.param_count() / if quantized { 4 } else { 1 }) as f64;
        let m = ModelUnderTest::new(ctx.rt, base, &[&res.base_params, &res.lora_recovered])?;
        let s = eval_all(&m, ctx.seed, n_math, n_csr, n_code, code_samples, &ctx.scale.temps())?;
        csv.row(&crate::csv_row![
            pruned, reduction, s.mathqa, s.gsm, s.csr_mean, s.pass1, s.pass10
        ])?;
    }
    log::info(format!("fig8 -> {}", ctx.out_dir.display()));
    Ok(())
}

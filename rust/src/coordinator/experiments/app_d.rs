//! Appendix D: visualisation data for the trained low-rank matrices —
//! head-wise attention norms + layer-wise MLP norms, for LoRA vs
//! LoRAM-Stru (recovered), as CSV heatmap inputs.

use super::ExpCtx;
use crate::coordinator::analysis::dump_lora_norms;
use crate::coordinator::pipeline::{Pipeline, PipelineConfig, Variant};
use crate::data::instruct::Dataset;
use crate::util::log;
use anyhow::Result;

pub fn run(ctx: &ExpCtx) -> Result<()> {
    let (pre, align, sft) = ctx.scale.steps();
    let (_small, big, big_pruned, _) = ctx.scale.family2();
    let big_cfg = ctx.rt.load(&format!("eval_{big}"))?.meta.config.clone();

    for (tag, variant, pruned) in [
        ("lora", Variant::Lora, None),
        ("loram_stru", Variant::Stru, Some(big_pruned)),
    ] {
        let plc = PipelineConfig {
            base: big.to_string(),
            pruned: pruned.map(String::from),
            variant,
            pretrain_steps: pre,
            align_steps: align,
            sft_steps: sft,
            dataset: Dataset::Hermes,
            seed: ctx.seed,
            eval_every: 0,
            eval_seqs: 8,
            run_dir: ctx.run_dir.clone(),
            ..Default::default()
        };
        log::info(format!("appD running {tag}"));
        let res = Pipeline::new(ctx.rt, plc).run()?;
        // recovered factors live in full-model shapes for both variants
        dump_lora_norms(&big_cfg, &res.lora_recovered, &ctx.out_dir, tag)?;
    }
    log::info(format!("appD -> {}", ctx.out_dir.display()));
    Ok(())
}

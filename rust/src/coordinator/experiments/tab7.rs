//! Table 7: domain-specific fine-tuning (GSM8K stand-in) — QLoRAM-Stru on
//! the 3.1-70B proxy, SFT'd on the math-heavy chain task directly, vs the
//! general-instruction variant and the LoRA/base references.

use super::ExpCtx;
use crate::coordinator::downstream::{eval_gsm, ModelUnderTest};
use crate::coordinator::pipeline::{ensure_base, Pipeline, PipelineConfig, Variant};
use crate::data::downstream::gsm_set;
use crate::data::instruct::Dataset;
use crate::params::init_lora;
use crate::util::log::{self, Csv};
use anyhow::Result;

pub fn run(ctx: &ExpCtx) -> Result<()> {
    let (pre, align, sft) = ctx.scale.steps();
    let (small, big, big_pruned, quantized) = ctx.scale.family31();
    let (n_math, _, _, _) = ctx.scale.downstream_sizes();
    let items = gsm_set(ctx.seed ^ 7, n_math);
    let mut csv = Csv::create(
        ctx.out_dir.join("tab7_domain.csv"),
        &["method", "train_data", "gsm_acc", "param_reduction"],
    )?;

    let big_cfg = ctx.rt.load(&format!("eval_{big}"))?.meta.config.clone();
    let small_cfg = ctx.rt.load(&format!("eval_{small}"))?.meta.config.clone();
    let pruned_cfg = ctx.rt.load(&format!("eval_{big_pruned}"))?.meta.config.clone();
    let red_small = big_cfg.param_count() as f64 / small_cfg.param_count() as f64;
    let red_q = big_cfg.param_count() as f64
        / (pruned_cfg.param_count() / if quantized { 4 } else { 1 }) as f64;

    // references without fine-tuning
    let big_params = ensure_base(ctx.rt, big, pre, 1e-3, ctx.seed, &ctx.run_dir)?;
    let small_params = ensure_base(ctx.rt, small, pre, 1e-3, ctx.seed, &ctx.run_dir)?;
    let m_big = ModelUnderTest::new(ctx.rt, big, &[&big_params, &init_lora(&big_cfg, 0)])?;
    let m_small = ModelUnderTest::new(ctx.rt, small, &[&small_params, &init_lora(&small_cfg, 0)])?;
    csv.row(&crate::csv_row![format!("{small} w/o FT"), "-", eval_gsm(&m_small, &items)?, red_small])?;
    csv.row(&crate::csv_row![format!("{big} w/o FT"), "-", eval_gsm(&m_big, &items)?, 1.0])?;

    // QLoRAM-Stru: general SFT (hermes) vs domain SFT (orca's chain-heavy mix)
    for (train, dataset) in [("general", Dataset::Hermes), ("domain", Dataset::Orca)] {
        let plc = PipelineConfig {
            base: big.to_string(),
            pruned: Some(big_pruned.to_string()),
            variant: Variant::Stru,
            quantized,
            pretrain_steps: pre,
            align_steps: align,
            sft_steps: sft,
            dataset,
            seed: ctx.seed,
            eval_every: 0,
            eval_seqs: 8,
            run_dir: ctx.run_dir.clone(),
            ..Default::default()
        };
        log::info(format!("tab7 running QLoRAM-Stru ({train})"));
        let res = Pipeline::new(ctx.rt, plc).run()?;
        let m = ModelUnderTest::new(ctx.rt, big, &[&res.base_params, &res.lora_recovered])?;
        csv.row(&crate::csv_row![
            format!("{big} QLoRAM-Stru"),
            train,
            eval_gsm(&m, &items)?,
            red_q
        ])?;
    }

    // 70B LoRA upper reference
    let plc = PipelineConfig {
        base: big.to_string(),
        pruned: None,
        variant: Variant::Lora,
        pretrain_steps: pre,
        align_steps: 0,
        sft_steps: sft,
        dataset: Dataset::Hermes,
        seed: ctx.seed,
        eval_every: 0,
        eval_seqs: 8,
        run_dir: ctx.run_dir.clone(),
        ..Default::default()
    };
    let res = Pipeline::new(ctx.rt, plc).run()?;
    let m = ModelUnderTest::new(ctx.rt, big, &[&res.base_params, &res.lora_recovered])?;
    csv.row(&crate::csv_row![format!("{big} LoRA"), "general", eval_gsm(&m, &items)?, 1.0])?;

    log::info(format!("tab7 -> {}", ctx.out_dir.display()));
    Ok(())
}

//! Generic training session over a `pretrain_*` or `sft_*` artifact.
//!
//! A thin loop on top of [`Session`]: the artifact's meta declares the
//! input order and the output→input state bindings, the session keeps all
//! trainable + frozen state in named slots and donates each step's state
//! outputs (`new.<p>` / `new_m.<p>` / `new_v.<p>`) back onto their input
//! slots. The same mechanics drive full-parameter pre-training, alignment
//! (Eq. 8) and LoRA SFT (dense, masked, quantised).
//!
//! Backend selection is the session's (`LORAM_HOST_PATH=1` forces the
//! host literal-roundtrip baseline; device-resident PJRT buffers are the
//! default hot path — DESIGN.md §Perf). Both produce identical losses;
//! the integration tests assert it.

use crate::data::Batch;
use crate::runtime::{Artifact, Runtime, Session};
use crate::tensor::{Tensor, TensorStore};
use anyhow::Result;
use std::rc::Rc;
use std::time::Instant;

pub use crate::runtime::host_path_forced;

pub struct TrainSession<'r> {
    pub rt: &'r Runtime,
    pub art: Rc<Artifact>,
    sess: Session,
    pub step: usize,
    pub losses: Vec<f32>,
    pub step_ms: Vec<f64>,
}

impl<'r> TrainSession<'r> {
    /// `stores`: the frozen + trainable tensors (params, quant, masks,
    /// lora). Adam moments for the trainable set are created zeroed from
    /// the artifact meta's zero-init declaration if absent.
    pub fn new(rt: &'r Runtime, artifact: &str, stores: &[&TensorStore]) -> Result<TrainSession<'r>> {
        let art = rt.load(artifact)?;
        let sess = Session::new(rt, art.clone(), stores)?;
        Ok(TrainSession {
            rt,
            art,
            sess,
            step: 0,
            losses: vec![],
            step_ms: vec![],
        })
    }

    /// One optimiser step; returns the batch loss.
    pub fn train_step(&mut self, batch: &Batch, lr: f64) -> Result<f32> {
        self.step += 1;
        let t0 = Instant::now();
        self.sess.set(self.rt, "step", &Tensor::scalar_f32(self.step as f32))?;
        self.sess.set(self.rt, "lr", &Tensor::scalar_f32(lr as f32))?;
        self.sess.set(self.rt, "tokens", &batch.tokens)?;
        self.sess.set(self.rt, "loss_mask", &batch.loss_mask)?;
        let out = self.sess.run(self.rt)?;
        let loss = out.get("loss")?.f32s()[0];
        self.losses.push(loss);
        self.step_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        Ok(loss)
    }

    /// Extract the tensors whose names appear in `names` (e.g. the updated
    /// LoRA factors after SFT, or the full params after alignment) — the
    /// stepped state, fetched from the session's slots.
    pub fn extract(&self, names: &[String]) -> Result<TensorStore> {
        self.sess.fetch_all(self.rt, names)
    }

    pub fn backend(&self) -> crate::runtime::BackendKind {
        self.sess.backend()
    }

    pub fn batch_size(&self) -> usize {
        self.art.meta.batch()
    }

    pub fn seq_len(&self) -> usize {
        self.art.meta.seq()
    }

    pub fn mean_step_ms(&self) -> f64 {
        crate::util::stats::mean(&self.step_ms)
    }
}

//! Generic training session over a `pretrain_*` or `sft_*` artifact.
//!
//! The artifact's meta defines the input order; the session keeps *all*
//! trainable and frozen state keyed by input names (`adam_m.<p>` /
//! `adam_v.<p>` prefixes for moments) and routes outputs (`new.<p>` /
//! `new_m.<p>` / `new_v.<p>`) back after each step. The same mechanics
//! drive full-parameter pre-training, alignment (Eq. 8) and LoRA SFT
//! (dense, masked, quantised).
//!
//! Two backends (EXPERIMENTS.md §Perf):
//! * device (default): state lives in PJRT buffers, only (step, lr,
//!   tokens, loss_mask) upload per step, outputs re-bind on device —
//!   requires the vendored `untuple_result` patch.
//! * host (`LORAM_HOST_PATH=1`): v1 literal-roundtrip path, kept as the
//!   §Perf baseline and as a fallback.

use crate::data::Batch;
use crate::runtime::{Artifact, DeviceSession, Runtime};
use crate::tensor::{Tensor, TensorStore};
use anyhow::{Context, Result};
use std::rc::Rc;
use std::time::Instant;

enum Backend {
    Host { state: TensorStore },
    Device(DeviceSession),
}

pub struct TrainSession<'r> {
    pub rt: &'r Runtime,
    pub art: Rc<Artifact>,
    backend: Backend,
    pub step: usize,
    pub losses: Vec<f32>,
    pub step_ms: Vec<f64>,
}

pub fn host_path_forced() -> bool {
    std::env::var("LORAM_HOST_PATH").map(|v| v == "1").unwrap_or(false)
}

impl<'r> TrainSession<'r> {
    /// `stores`: the frozen + trainable tensors (params, quant, masks,
    /// lora). Adam moments for the trainable set are created zeroed from
    /// the artifact meta if absent.
    pub fn new(rt: &'r Runtime, artifact: &str, stores: &[&TensorStore]) -> Result<TrainSession<'r>> {
        let art = rt.load(artifact)?;
        let backend = if host_path_forced() {
            let mut state = TensorStore::new();
            for s in stores {
                for (k, v) in &s.map {
                    state.insert(k.clone(), v.clone());
                }
            }
            for spec in &art.meta.inputs {
                if (spec.name.starts_with("adam_m.") || spec.name.starts_with("adam_v."))
                    && !state.contains(&spec.name)
                {
                    state.insert(spec.name.clone(), Tensor::zeros(&spec.shape));
                }
            }
            Backend::Host { state }
        } else {
            Backend::Device(DeviceSession::new(rt, art.clone(), stores)?)
        };
        Ok(TrainSession {
            rt,
            art,
            backend,
            step: 0,
            losses: vec![],
            step_ms: vec![],
        })
    }

    /// One optimiser step; returns the batch loss.
    pub fn train_step(&mut self, batch: &Batch, lr: f64) -> Result<f32> {
        self.step += 1;
        let t0 = Instant::now();
        let loss = match &mut self.backend {
            Backend::Host { state } => {
                state.insert("step", Tensor::scalar_f32(self.step as f32));
                state.insert("lr", Tensor::scalar_f32(lr as f32));
                state.insert("tokens", batch.tokens.clone());
                state.insert("loss_mask", batch.loss_mask.clone());
                let out = self.rt.run(&self.art, state)?;
                let loss = out.get("loss")?.f32s()[0];
                for (name, t) in out.map {
                    if let Some(p) = name.strip_prefix("new_m.") {
                        state.insert(format!("adam_m.{p}"), t);
                    } else if let Some(p) = name.strip_prefix("new_v.") {
                        state.insert(format!("adam_v.{p}"), t);
                    } else if let Some(p) = name.strip_prefix("new.") {
                        state.insert(p.to_string(), t);
                    }
                }
                loss
            }
            Backend::Device(sess) => {
                sess.set(self.rt, "step", &Tensor::scalar_f32(self.step as f32))?;
                sess.set(self.rt, "lr", &Tensor::scalar_f32(lr as f32))?;
                sess.set(self.rt, "tokens", &batch.tokens)?;
                sess.set(self.rt, "loss_mask", &batch.loss_mask)?;
                let out = sess.run(self.rt)?;
                out.get("loss")?.f32s()[0]
            }
        };
        self.losses.push(loss);
        self.step_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        Ok(loss)
    }

    /// Extract the tensors whose names appear in `names` (e.g. the updated
    /// LoRA factors after SFT, or the full params after alignment).
    pub fn extract(&self, names: &[String]) -> Result<TensorStore> {
        match &self.backend {
            Backend::Host { state } => {
                let mut out = TensorStore::new();
                for n in names {
                    out.insert(n.clone(), state.get(n).context("extract")?.clone());
                }
                Ok(out)
            }
            Backend::Device(sess) => sess.fetch_all(self.rt, names),
        }
    }

    pub fn batch_size(&self) -> usize {
        self.art.meta.batch()
    }

    pub fn seq_len(&self) -> usize {
        self.art.meta.seq()
    }

    pub fn mean_step_ms(&self) -> f64 {
        crate::util::stats::mean(&self.step_ms)
    }
}

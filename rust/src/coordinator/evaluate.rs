//! Perplexity / NLL evaluation over `eval_*` artifacts, plus option
//! log-likelihood scoring (the lm-eval-harness mechanism behind MathQA and
//! the CSR subtasks).

use crate::data::{make_batch, Batch};
use crate::runtime::{Artifact, Runtime};
use crate::tensor::TensorStore;
use crate::tokenizer::{Tokenizer, SEP};
use anyhow::Result;
use std::rc::Rc;

pub struct Evaluator<'r> {
    pub rt: &'r Runtime,
    pub art: Rc<Artifact>,
    /// weights live in session slots: uploaded once at construction, only
    /// (tokens, loss_mask) move per batch (DESIGN.md §Perf)
    sess: std::cell::RefCell<crate::runtime::Session>,
}

impl<'r> Evaluator<'r> {
    pub fn new(rt: &'r Runtime, artifact: &str, stores: &[&TensorStore]) -> Result<Evaluator<'r>> {
        let art = rt.load(artifact)?;
        let sess = crate::runtime::Session::new(rt, art.clone(), stores)?;
        Ok(Evaluator {
            rt,
            art,
            sess: std::cell::RefCell::new(sess),
        })
    }

    pub fn batch_size(&self) -> usize {
        self.art.meta.batch()
    }

    pub fn seq_len(&self) -> usize {
        self.art.meta.seq()
    }

    /// Per-sequence (nll_sum, token_count) for one batch.
    pub fn eval_batch(&self, batch: &Batch) -> Result<(Vec<f32>, Vec<f32>)> {
        let mut sess = self.sess.borrow_mut();
        sess.set(self.rt, "tokens", &batch.tokens)?;
        sess.set(self.rt, "loss_mask", &batch.loss_mask)?;
        let out = sess.run(self.rt)?;
        Ok((
            out.get("nll_sum")?.f32s().to_vec(),
            out.get("tok_count")?.f32s().to_vec(),
        ))
    }

    /// Corpus perplexity over token sequences (padding the tail batch).
    pub fn perplexity(&self, seqs: &[Vec<i32>], answer_only: bool) -> Result<f64> {
        let b = self.batch_size();
        let s = self.seq_len();
        let (mut nll, mut count) = (0f64, 0f64);
        for chunk in seqs.chunks(b) {
            let mut padded: Vec<Vec<i32>> = chunk.to_vec();
            while padded.len() < b {
                padded.push(vec![crate::tokenizer::PAD; 2]);
            }
            let batch = make_batch(&padded, b, s, answer_only);
            let (ns, cs) = self.eval_batch(&batch)?;
            for i in 0..chunk.len() {
                nll += ns[i] as f64;
                count += cs[i] as f64;
            }
        }
        Ok((nll / count.max(1.0)).exp())
    }

    /// Score `prompt + option` continuations; returns the index of the
    /// lowest per-token NLL option (lm-eval style length-normalised).
    pub fn score_options(&self, prompt: &str, options: &[String]) -> Result<usize> {
        let tk = Tokenizer::new();
        let b = self.batch_size();
        let s = self.seq_len();
        let mut scores = vec![f64::INFINITY; options.len()];
        for (chunk_start, chunk) in options.chunks(b).enumerate().map(|(i, c)| (i * b, c)) {
            let mut seqs: Vec<Vec<i32>> = chunk
                .iter()
                .map(|o| tk.encode_pair(prompt, o))
                .collect();
            while seqs.len() < b {
                seqs.push(vec![crate::tokenizer::PAD; 2]);
            }
            // answer_only mask: loss over the option tokens only
            let batch = make_batch(&seqs, b, s, true);
            let (ns, cs) = self.eval_batch(&batch)?;
            for i in 0..chunk.len() {
                scores[chunk_start + i] = ns[i] as f64 / (cs[i] as f64).max(1.0);
            }
        }
        Ok(scores
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0))
    }
}

/// Utility shared by evaluate/generate: encode a prompt for scoring with no
/// response yet (BOS + prompt + SEP).
pub fn encode_prompt(prompt: &str) -> Vec<i32> {
    let tk = Tokenizer::new();
    let mut ids = vec![crate::tokenizer::BOS];
    ids.extend(tk.encode(prompt));
    ids.push(SEP);
    ids
}

/// Build held-out perplexity sequences for a dataset split.
pub fn test_sequences(
    dataset: crate::data::instruct::Dataset,
    seed: u64,
    n: usize,
) -> Vec<Vec<i32>> {
    let tk = Tokenizer::new();
    let mut g = crate::data::instruct::InstructGen::new(dataset, seed, 1);
    (0..n).map(|_| g.next().0.tokens(&tk)).collect()
}


//! Downstream task evaluation (paper Tables 1–3, 7, Figs. 8, 14–15).
//!
//! * math: MathQA stand-in via option log-likelihood (1-shot) + GSM8K
//!   stand-in via greedy decode and strict match (paper: 8-shot CoT strict)
//! * CSR: six option-scored subtasks, mean ± standard error (Table 2)
//! * code: program synthesis; temperature sweep, unbiased pass@k (Table 3)

use crate::coordinator::evaluate::Evaluator;
use crate::coordinator::generate::{Generator, SampleCfg};
use crate::data::downstream::{self, EvalItem, CSR_SUBTASKS};
use crate::data::tasks;
use crate::runtime::Runtime;
use crate::tensor::TensorStore;
use crate::util::rng::Rng;
use crate::util::stats;
use anyhow::Result;

/// Weight bundle for downstream evaluation: base params + (possibly zero /
/// recovered) LoRA factors, evaluated with the *full* model artifacts.
pub struct ModelUnderTest<'r> {
    pub evaluator: Evaluator<'r>,
    pub generator: Generator<'r>,
}

impl<'r> ModelUnderTest<'r> {
    pub fn new(
        rt: &'r Runtime,
        base_cfg: &str,
        stores: &[&TensorStore],
    ) -> Result<ModelUnderTest<'r>> {
        Ok(ModelUnderTest {
            evaluator: Evaluator::new(rt, &format!("eval_{base_cfg}"), stores)?,
            generator: Generator::new(rt, &format!("logits_{base_cfg}"), stores)?,
        })
    }
}

#[derive(Debug, Clone, Default)]
pub struct DownstreamScores {
    pub mathqa: f64,
    pub gsm: f64,
    pub csr: Vec<(String, f64)>,
    pub csr_mean: f64,
    pub csr_se: f64,
    pub pass1: f64,
    pub pass10: f64,
}

/// MathQA stand-in accuracy: option scoring with gold shuffled into place.
pub fn eval_mathqa(m: &ModelUnderTest, items: &[EvalItem], seed: u64) -> Result<f64> {
    let mut rng = Rng::new(seed);
    let mut correct = 0usize;
    for it in items {
        let mut opts = it.options.clone();
        // shuffle so the gold isn't always option 0
        let mut order: Vec<usize> = (0..opts.len()).collect();
        rng.shuffle(&mut order);
        let shuffled: Vec<String> = order.iter().map(|&i| opts[i].clone()).collect();
        let gold_pos = order.iter().position(|&i| i == 0).unwrap();
        opts = shuffled;
        let pick = m.evaluator.score_options(&it.prompt, &opts)?;
        if pick == gold_pos {
            correct += 1;
        }
    }
    Ok(correct as f64 / items.len().max(1) as f64)
}

/// GSM8K stand-in: greedy decode, strict string match on the answer.
pub fn eval_gsm(m: &ModelUnderTest, items: &[EvalItem]) -> Result<f64> {
    let mut rng = Rng::new(0);
    let prompts: Vec<String> = items.iter().map(|i| i.prompt.clone()).collect();
    let cfg = SampleCfg {
        temperature: 0.0,
        top_p: 1.0,
        max_new: 8,
    };
    let outs = m.generator.complete(&prompts, cfg, &mut rng)?;
    let correct = outs
        .iter()
        .zip(items)
        .filter(|(o, it)| o.trim() == it.gold)
        .count();
    Ok(correct as f64 / items.len().max(1) as f64)
}

/// All six CSR subtasks; returns per-task accuracy and the mean ± se row.
pub fn eval_csr(
    m: &ModelUnderTest,
    seed: u64,
    n_per_task: usize,
) -> Result<(Vec<(String, f64)>, f64, f64)> {
    let mut per = vec![];
    let mut rng = Rng::new(seed ^ 0xc5);
    for (name, _) in CSR_SUBTASKS {
        let items = downstream::csr_set(name, seed, n_per_task);
        let mut correct = 0usize;
        for it in &items {
            let mut order: Vec<usize> = (0..it.options.len()).collect();
            rng.shuffle(&mut order);
            let opts: Vec<String> = order.iter().map(|&i| it.options[i].clone()).collect();
            let gold_pos = order.iter().position(|&i| i == 0).unwrap();
            if m.evaluator.score_options(&it.prompt, &opts)? == gold_pos {
                correct += 1;
            }
        }
        per.push((name.to_string(), correct as f64 / items.len() as f64));
    }
    let accs: Vec<f64> = per.iter().map(|(_, a)| *a).collect();
    let mean = stats::mean(&accs);
    let se = stats::proportion_se(mean, n_per_task * CSR_SUBTASKS.len());
    Ok((per, mean, se))
}

/// Code generation pass@1 / pass@10: n samples per item across the paper's
/// temperature sweep, checked by the stack-machine VM, best-over-temps.
pub fn eval_code(
    m: &ModelUnderTest,
    items: &[EvalItem],
    n_samples: usize,
    temps: &[f64],
    seed: u64,
) -> Result<(f64, f64)> {
    let mut best = (0.0f64, 0.0f64);
    for &t in temps {
        let mut rng = Rng::new(seed ^ (t * 1000.0) as u64);
        let (mut p1_sum, mut p10_sum) = (0.0, 0.0);
        for it in items {
            let gold = tasks::Program::parse(&it.gold).expect("gold parses");
            let mut correct = 0usize;
            let n = if t == 0.0 { 1 } else { n_samples };
            for chunk in (0..n).collect::<Vec<_>>().chunks(m.generator.batch_size()) {
                let prompts: Vec<String> =
                    chunk.iter().map(|_| it.prompt.clone()).collect();
                let cfg = SampleCfg {
                    temperature: t,
                    top_p: 0.95,
                    max_new: 12,
                };
                let outs = m.generator.complete(&prompts, cfg, &mut rng)?;
                correct += outs
                    .iter()
                    .filter(|o| tasks::check_program(&gold, o.trim()))
                    .count();
            }
            p1_sum += stats::pass_at_k(n, correct, 1);
            p10_sum += stats::pass_at_k(n, correct, 10.min(n));
        }
        let p1 = p1_sum / items.len().max(1) as f64;
        let p10 = p10_sum / items.len().max(1) as f64;
        if p1 > best.0 {
            best.0 = p1;
        }
        if p10 > best.1 {
            best.1 = p10;
        }
    }
    Ok(best)
}

/// The full downstream battery (one row of Tables 1+2+3).
pub fn eval_all(
    m: &ModelUnderTest,
    seed: u64,
    n_math: usize,
    n_csr: usize,
    n_code: usize,
    code_samples: usize,
    temps: &[f64],
) -> Result<DownstreamScores> {
    let mathqa = eval_mathqa(m, &downstream::mathqa_set(seed, n_math), seed)?;
    let gsm = eval_gsm(m, &downstream::gsm_set(seed, n_math))?;
    let (csr, csr_mean, csr_se) = eval_csr(m, seed, n_csr)?;
    let (pass1, pass10) = eval_code(
        m,
        &downstream::code_set(seed, n_code),
        code_samples,
        temps,
        seed,
    )?;
    Ok(DownstreamScores {
        mathqa,
        gsm,
        csr,
        csr_mean,
        csr_se,
        pass1,
        pass10,
    })
}

//! KV-cache decode: per-row cache slot lifecycle over the decode artifact
//! trio (`decode_prefill_*` / `decode_step_*` / `decode_verify_*`), riding
//! the Session state-donation layer.
//!
//! The caches are artifact state: aot.py declares every `new.cache_*`
//! output bound onto its `cache_*` input (`extra.state_bindings`), so
//! between decode steps they never leave the step session's slots — PJRT
//! buffers on the device backend, exactly like optimiser moments in
//! training artifacts. Admission routes the caches through the prefill
//! session and back via [`Session::donate_slots`], which moves buffer
//! handles, not bytes; the only per-token traffic is the (B, 1) frontier
//! tokens up and the (B, V) logits down.
//!
//! Row lifecycle is tracked by [`CacheSlots`] (pure bookkeeping, unit
//! tested): `admit` installs a row's prompt cache, `advance` records each
//! decode-step write at the row frontier, `rewind` rolls the frontier back
//! past rejected speculative drafts (never below the admission prefill),
//! `evict` frees the slot after `take`. A recycled row is safe by
//! construction — its next admission rewrites the whole cache row under
//! the prefill's `row_onehot` mask.
//!
//! The optional verify session (DESIGN.md §2d) is the third artifact of
//! the trio: a (B, K+1) window that scores a whole draft run in one
//! batched forward, sharing the pair's donated cache tensors bitwise.
//!
//! The optional chunked-prefill ladder (DESIGN.md §2e) generalizes
//! admission the same way: `decode_prefill_chunk_<model>_c<C>` artifacts
//! forward one (1, C) prompt *window* at `start_pos` instead of a
//! monolithic pad-to-S grid, so a short prompt costs its covering bucket
//! and a long one can be paced across scheduler ticks
//! (`Generator::prefill_tick`) without ever freezing the decoding batch.

use crate::runtime::{Runtime, Session};
use crate::tensor::{Dtype, Tensor, TensorStore};
use crate::tokenizer::{pad_to, PAD};
use crate::util::log;
use anyhow::{bail, ensure, Context, Result};

/// Chunked-prefill bucket ladder for an S-long decode grid — the Rust
/// mirror of aot.py's `chunk_ladder`. The shared formula IS the discovery
/// contract: [`KvDecoder::try_new`] probes exactly the bucket names
/// `decode_prefill_chunk_<model>_c<C>` for C in this ladder, so no
/// manifest is needed to find the chunk artifacts.
pub fn chunk_ladder(seq: usize) -> Vec<usize> {
    let mut v = vec![16.min(seq), 64.min(seq), seq];
    v.sort_unstable();
    v.dedup();
    v
}

/// Pick the bucket for the next prefill window of a prompt with
/// `remaining` unfed tokens, under the tick's unspent token `budget`.
/// A covering window (smallest bucket >= remaining) finishes the prompt
/// in one call, but is only taken when its padding beats the worst-case
/// tail pad of splitting (< ladder[0]) — a 17-token remainder under a
/// [16, 64] ladder takes a 16 + 16 split (<= 15 padded), never a
/// 64-window (47 padded). Otherwise the largest budget-funded bucket
/// that fits *inside* the remainder runs as a zero-padding full window.
/// `None` when the budget funds nothing — unless `force` (nothing spent
/// yet this tick) demands progress, so a budget below the smallest
/// bucket still converges.
pub(crate) fn next_bucket(
    ladder: &[usize],
    remaining: usize,
    budget: usize,
    force: bool,
) -> Option<usize> {
    debug_assert!(remaining > 0 && !ladder.is_empty());
    let fit = ladder
        .iter()
        .copied()
        .find(|&c| c >= remaining)
        .filter(|&c| c - remaining < ladder[0]);
    if let Some(c) = fit {
        if c <= budget || force {
            return Some(c);
        }
    }
    // full mid-prompt window (zero padding); when even the smallest
    // bucket is unfunded, `force` takes it anyway — it always fits the
    // remainder here, since a rejected/absent `fit` implies
    // remaining > ladder[0]
    match ladder
        .iter()
        .copied()
        .filter(|&c| c <= remaining && c <= budget)
        .last()
    {
        Some(c) => Some(c),
        None if force => Some(ladder[0]),
        None => None,
    }
}

/// The window plan for admitting a whole `len`-token prompt with an
/// unbounded budget: `(start, take, bucket)` per chunk. With a ladder
/// containing the full grid this is a single right-sized window; the
/// budget-paced multi-tick variant lives in `Generator::prefill_tick`.
pub(crate) fn chunk_plan(ladder: &[usize], len: usize) -> Vec<(usize, usize, usize)> {
    let mut out = vec![];
    let mut start = 0;
    while start < len {
        let bucket = next_bucket(ladder, len - start, usize::MAX, true)
            .expect("unbounded budget always funds a bucket");
        let take = bucket.min(len - start);
        out.push((start, take, bucket));
        start += take;
    }
    out
}

/// Cumulative prefill accounting (surfaced through
/// [`crate::serve::ServerStats`] and the serving benches): how many
/// window tokens admissions processed and how many of those were padding
/// — the wasted FLOPs the bucket ladder exists to shrink (monolithic
/// admission pays S - len per prompt).
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct PrefillStats {
    /// prefill window tokens processed (bucket sizes, padding included)
    pub prefill_tokens: usize,
    /// of those, padding beyond the prompt tokens
    pub padded_prefill_tokens: usize,
    /// admission windows run (a monolithic admission counts as one)
    pub chunks: usize,
}

impl PrefillStats {
    pub fn merge(self, other: PrefillStats) -> PrefillStats {
        PrefillStats {
            prefill_tokens: self.prefill_tokens + other.prefill_tokens,
            padded_prefill_tokens: self.padded_prefill_tokens
                + other.padded_prefill_tokens,
            chunks: self.chunks + other.chunks,
        }
    }
}

/// One occupied row's cache extent: `len` valid positions, of which the
/// first `admit` came from the admission prefill (the prompt — never
/// rewindable, a draft can only reject *generated* positions).
#[derive(Debug, Clone, Copy)]
struct RowSlot {
    len: usize,
    admit: usize,
}

/// Pure per-row cache bookkeeping: which rows hold a cache, and how many
/// positions of each row are valid. Kept separate from the sessions so the
/// lifecycle invariants are unit-testable without artifacts.
#[derive(Debug, Clone)]
pub struct CacheSlots {
    /// cached-position extent per row (None = free slot)
    rows: Vec<Option<RowSlot>>,
    seq: usize,
}

impl CacheSlots {
    pub fn new(batch: usize, seq: usize) -> CacheSlots {
        CacheSlots { rows: vec![None; batch], seq }
    }

    pub fn batch(&self) -> usize {
        self.rows.len()
    }

    /// Cached positions of an occupied row.
    pub fn len(&self, row: usize) -> Option<usize> {
        self.rows.get(row).copied().flatten().map(|r| r.len)
    }

    pub fn occupied(&self) -> usize {
        self.rows.iter().flatten().count()
    }

    /// Claim a free row for a prompt of `len` cached positions.
    pub fn admit(&mut self, row: usize, len: usize) -> Result<()> {
        let slot = self
            .rows
            .get_mut(row)
            .with_context(|| format!("kvcache: row {row} out of range"))?;
        ensure!(slot.is_none(), "kvcache: admit into occupied row {row}");
        ensure!(len >= 1, "kvcache: admit of empty prompt into row {row}");
        ensure!(
            len <= self.seq,
            "kvcache: prompt of {len} exceeds cache capacity {}",
            self.seq
        );
        *slot = Some(RowSlot { len, admit: len });
        Ok(())
    }

    /// Record a decode-step write at `pos`. Writes must land at the row
    /// frontier (`pos == len`, growing the cache) or rewrite the last
    /// cached position (`pos == len - 1`, the first step after admission);
    /// anything else would leave garbage gaps.
    pub fn advance(&mut self, row: usize, pos: usize) -> Result<()> {
        let slot = self
            .rows
            .get_mut(row)
            .with_context(|| format!("kvcache: row {row} out of range"))?
            .as_mut()
            .with_context(|| format!("kvcache: advance on free row {row}"))?;
        ensure!(
            pos + 1 == slot.len || pos == slot.len,
            "kvcache: write at {pos} away from row {row} frontier {}",
            slot.len
        );
        ensure!(pos < self.seq, "kvcache: write at {pos} beyond capacity {}", self.seq);
        slot.len = slot.len.max(pos + 1);
        Ok(())
    }

    /// Roll the row frontier back `n` positions — the rejected-draft path
    /// of speculative decoding. Purely logical, like `evict`: the K/V
    /// beyond the new frontier stay in the tensors as garbage, protected
    /// by the step/verify position masks (writes land at the frontier,
    /// attention never looks past the query position). Rewinding past the
    /// admission prefill is refused: prompt positions are never drafts.
    pub fn rewind(&mut self, row: usize, n: usize) -> Result<()> {
        let slot = self
            .rows
            .get_mut(row)
            .with_context(|| format!("kvcache: row {row} out of range"))?
            .as_mut()
            .with_context(|| format!("kvcache: rewind on free row {row}"))?;
        ensure!(
            slot.len - slot.admit >= n,
            "kvcache: rewind of {n} from row {row} frontier {} crosses its \
             admit length {}",
            slot.len,
            slot.admit
        );
        slot.len -= n;
        Ok(())
    }

    /// Free a row after `take`; the cache contents become garbage and are
    /// fully rewritten by the next admission.
    pub fn evict(&mut self, row: usize) -> Result<()> {
        let slot = self
            .rows
            .get_mut(row)
            .with_context(|| format!("kvcache: row {row} out of range"))?;
        ensure!(slot.is_some(), "kvcache: evict of free row {row}");
        *slot = None;
        Ok(())
    }
}

/// One row's feed into a [`KvDecoder::verify`] call: the frontier token
/// followed by the draft candidates (padded to the artifact's K+1 window),
/// the grid position of the frontier, and how many window tokens are
/// `live` — actually written and tracked (frontier + drafts that fit).
#[derive(Debug, Clone)]
pub struct VerifyFeed {
    pub tokens: Vec<i32>,
    pub pos: usize,
    pub live: usize,
}

/// The executable decode subsystem: the prefill and step sessions plus the
/// cache lifecycle. Constructed by [`crate::coordinator::generate::Generator`]
/// when the decode artifact pair is registered for its model.
pub struct KvDecoder {
    prefill: Session,
    step: Session,
    /// the speculative verification window (`decode_verify_*`), when that
    /// third artifact of the decode trio is registered
    verify: Option<Session>,
    /// chunked-prefill bucket sessions, ascending window length C, when
    /// the `decode_prefill_chunk_<model>_c<C>` ladder is registered
    chunks: Vec<(usize, Session)>,
    /// admissions route through the bucket ladder instead of the
    /// monolithic (1, S) prefill (on by default when a ladder loaded)
    chunked: bool,
    /// draft window size K of the verify artifact (tokens are (B, K+1))
    draft_k: Option<usize>,
    cache_names: Vec<String>,
    pub slots: CacheSlots,
    /// cumulative admission accounting (window tokens, padding waste)
    pub pstats: PrefillStats,
    batch: usize,
    seq: usize,
    vocab: usize,
    /// gather input name when the pair serves a stacked adapter group
    adapter_in: Option<String>,
}

impl KvDecoder {
    /// Load the decode artifact pair for `model`; `Ok(None)` when the pair
    /// is absent (the caller falls back to full reforward). A *half*
    /// -registered pair is almost certainly an emission mistake — it also
    /// falls back, but loudly, naming the missing artifact.
    pub fn try_new(
        rt: &Runtime,
        model: &str,
        stores: &[&TensorStore],
    ) -> Result<Option<KvDecoder>> {
        let pname = format!("decode_prefill_{model}");
        let sname = format!("decode_step_{model}");
        let (pa, sa) = match (rt.load(&pname), rt.load(&sname)) {
            (Ok(pa), Ok(sa)) => (pa, sa),
            (Ok(_), Err(_)) => {
                log::warn(format!(
                    "decode pair for '{model}' incomplete: '{pname}' is \
                     registered but '{sname}' is missing — falling back to \
                     full reforward"
                ));
                return Ok(None);
            }
            (Err(_), Ok(_)) => {
                log::warn(format!(
                    "decode pair for '{model}' incomplete: '{sname}' is \
                     registered but '{pname}' is missing — falling back to \
                     full reforward"
                ));
                return Ok(None);
            }
            (Err(_), Err(_)) => return Ok(None),
        };
        let (b, s) = (sa.meta.batch(), sa.meta.seq());
        ensure!(
            pa.meta.batch() == b && pa.meta.seq() == s,
            "decode pair grid mismatch: {pname} ({}, {}) vs {sname} ({b}, {s})",
            pa.meta.batch(),
            pa.meta.seq()
        );
        let cache_names = sa.meta.name_list("cache_names");
        ensure!(!cache_names.is_empty(), "{sname}: meta declares no cache_names");
        // slot donation moves raw buffers between the sessions, so the two
        // artifacts must declare bitwise-identical cache tensors
        for n in &cache_names {
            let ps = pa.meta.input_spec(n)?;
            let ss = sa.meta.input_spec(n)?;
            ensure!(
                ps.shape == ss.shape && ps.dtype == ss.dtype,
                "cache '{n}' differs between {pname} and {sname}"
            );
        }
        let vocab = sa.meta.config.vocab_size;
        // an adapter group must be declared by both halves identically:
        // the same registered slot serves admission and every step
        let pg = pa.meta.adapter_group()?;
        let sg = sa.meta.adapter_group()?;
        let adapter_in = match (&pg, &sg) {
            (Some(p), Some(s)) => {
                ensure!(
                    p.size == s.size && p.members == s.members && p.input == s.input,
                    "adapter group differs between {pname} and {sname}"
                );
                Some(s.input.clone())
            }
            (None, None) => None,
            _ => bail!("adapter group declared by only one of {pname}/{sname}"),
        };
        // the optional third artifact of the trio: the speculative verify
        // window. Its absence is fine (no spec path); a *defective* one —
        // wrong grid, caches or adapter group — falls back loudly, like
        // every other pair defect.
        let vname = format!("decode_verify_{model}");
        let (verify_art, draft_k) = match rt.load(&vname) {
            Err(_) => (None, None),
            Ok(va) => {
                let check = || -> Result<usize> {
                    ensure!(
                        va.meta.batch() == b && va.meta.seq() == s,
                        "verify grid ({}, {}) != decode grid ({b}, {s})",
                        va.meta.batch(),
                        va.meta.seq()
                    );
                    for n in &cache_names {
                        let vs = va.meta.input_spec(n)?;
                        let ss = sa.meta.input_spec(n)?;
                        ensure!(
                            vs.shape == ss.shape && vs.dtype == ss.dtype,
                            "cache '{n}' differs between {vname} and {sname}"
                        );
                    }
                    let vg = va.meta.adapter_group()?;
                    ensure!(
                        vg.as_ref().map(|g| (&g.input, g.size))
                            == sg.as_ref().map(|g| (&g.input, g.size)),
                        "adapter group differs between {vname} and {sname}"
                    );
                    let k = va
                        .meta
                        .draft_k()
                        .context("verify meta declares no draft_k")?;
                    ensure!(k >= 1, "draft_k must be >= 1");
                    let ts = va.meta.input_spec("tokens")?;
                    ensure!(
                        ts.shape == [b, k + 1],
                        "verify tokens shape {:?} is not (B, draft_k+1) = \
                         ({b}, {})",
                        ts.shape,
                        k + 1
                    );
                    Ok(k)
                };
                match check() {
                    Ok(k) => (Some(va), Some(k)),
                    Err(e) => {
                        log::warn(format!(
                            "decode trio for '{model}': '{vname}' is \
                             registered but defective ({e:#}) — serving \
                             without the speculative verify window"
                        ));
                        (None, None)
                    }
                }
            }
        };
        // the chunked-prefill ladder (DESIGN.md §2e): one (1, C) window
        // artifact per `chunk_ladder(s)` bucket, probed by the shared
        // formula. A missing bucket is fine (that size just isn't
        // served); a *defective* one is skipped loudly, like every other
        // family defect.
        let mut chunk_arts = vec![];
        for c in chunk_ladder(s) {
            let cname = format!("decode_prefill_chunk_{model}_c{c}");
            let Ok(ca) = rt.load(&cname) else { continue };
            let check = || -> Result<()> {
                ensure!(
                    ca.meta.batch() == b && ca.meta.seq() == s,
                    "chunk grid ({}, {}) != decode grid ({b}, {s})",
                    ca.meta.batch(),
                    ca.meta.seq()
                );
                let declared = ca
                    .meta
                    .chunk()
                    .context("chunk meta declares no extra.chunk")?;
                ensure!(
                    declared == c,
                    "extra.chunk {declared} != bucket {c} in the artifact name"
                );
                let ts = ca.meta.input_spec("tokens")?;
                ensure!(
                    ts.shape == [1, c],
                    "chunk tokens shape {:?} is not (1, {c})",
                    ts.shape
                );
                // the window-addressing inputs, mirroring the
                // compile.meta_check chunk rule — a bucket that would
                // only fail later at Session::set must be skipped now
                for scalar in ["start_pos", "last_pos"] {
                    let sp = ca.meta.input_spec(scalar)?;
                    ensure!(
                        sp.shape.is_empty() && sp.dtype == Dtype::I32,
                        "{scalar} is not a scalar int32 input"
                    );
                }
                let oh = ca.meta.input_spec("row_onehot")?;
                ensure!(
                    oh.shape == [b] && oh.dtype == Dtype::F32,
                    "row_onehot shape {:?} is not ({b},)",
                    oh.shape
                );
                for n in &cache_names {
                    let cs = ca.meta.input_spec(n)?;
                    let ss = sa.meta.input_spec(n)?;
                    ensure!(
                        cs.shape == ss.shape && cs.dtype == ss.dtype,
                        "cache '{n}' differs between {cname} and {sname}"
                    );
                }
                let cg = ca.meta.adapter_group()?;
                ensure!(
                    cg.as_ref().map(|g| (&g.input, g.size))
                        == sg.as_ref().map(|g| (&g.input, g.size)),
                    "adapter group differs between {cname} and {sname}"
                );
                Ok(())
            };
            match check() {
                Ok(()) => chunk_arts.push((c, ca)),
                Err(e) => log::warn(format!(
                    "decode ladder for '{model}': '{cname}' is registered \
                     but defective ({e:#}) — skipping that bucket"
                )),
            }
        }
        let prefill = Session::new(rt, pa, stores)?;
        let step = Session::new(rt, sa, stores)?;
        let verify = verify_art
            .map(|va| Session::new(rt, va, stores))
            .transpose()?;
        let mut chunks = vec![];
        for (c, ca) in chunk_arts {
            // a bucket that probes clean but fails session construction
            // (e.g. misdeclared bindings) is skipped like any other
            // ladder defect — it must never take the healthy pair down
            match Session::new(rt, ca, stores) {
                Ok(sess) => chunks.push((c, sess)),
                Err(e) => log::warn(format!(
                    "decode ladder for '{model}': \
                     'decode_prefill_chunk_{model}_c{c}' failed to load \
                     ({e:#}) — skipping that bucket"
                )),
            }
        }
        let chunked = !chunks.is_empty();
        Ok(Some(KvDecoder {
            prefill,
            step,
            verify,
            chunks,
            chunked,
            draft_k,
            cache_names,
            slots: CacheSlots::new(b, s),
            pstats: PrefillStats::default(),
            batch: b,
            seq: s,
            vocab,
            adapter_in,
        }))
    }

    /// Adapter slots the pair's artifacts stack (group size), if any.
    pub fn adapter_capacity(&self) -> Option<usize> {
        self.step.group_size("adapter")
    }

    /// Stage one adapter slot's factors into every session of the family
    /// (uploaded at each session's next run; see `Session::put_group`).
    pub fn put_adapter(&mut self, ix: usize, weights: &TensorStore) -> Result<()> {
        self.prefill.put_group("adapter", ix, weights)?;
        if let Some(v) = self.verify.as_mut() {
            v.put_group("adapter", ix, weights)?;
        }
        for (_, sess) in self.chunks.iter_mut() {
            sess.put_group("adapter", ix, weights)?;
        }
        self.step.put_group("adapter", ix, weights)
    }

    /// Bucket lengths of the registered chunked-prefill ladder, ascending
    /// (empty = no chunk artifacts, monolithic admission only).
    pub fn ladder(&self) -> Vec<usize> {
        self.chunks.iter().map(|(c, _)| *c).collect()
    }

    /// Whether admissions route through the bucket ladder.
    pub fn chunked(&self) -> bool {
        self.chunked
    }

    /// Force admissions onto/off the bucket ladder (the §Perf A/B knob);
    /// turning it on without a registered ladder is refused.
    pub fn set_chunked(&mut self, on: bool) -> Result<()> {
        ensure!(
            !on || !self.chunks.is_empty(),
            "kvcache: no chunked-prefill ladder registered for this pair"
        );
        self.chunked = on;
        Ok(())
    }

    /// Draft window size of the registered verify artifact, if the decode
    /// trio is complete (`None` = prefill/step pair only, no spec path).
    pub fn verify_k(&self) -> Option<usize> {
        self.draft_k
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    pub fn seq_len(&self) -> usize {
        self.seq
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab
    }

    /// Admit a row: run the prefill artifact over its sequence, writing
    /// this row's cache while every other row's passes through untouched
    /// (mid-decode admission never perturbs in-flight rows), then donate
    /// the caches back into the step session. On a stacked-adapter pair,
    /// `adapter_ix` names the slot the row decodes under for its lifetime.
    pub fn admit(
        &mut self,
        rt: &Runtime,
        row: usize,
        seq: &[i32],
        adapter_ix: Option<i32>,
    ) -> Result<()> {
        ensure!(row < self.batch, "kvcache: admit into out-of-range row {row}");
        ensure!(
            !seq.is_empty() && seq.len() <= self.seq,
            "kvcache: prompt of {} tokens does not fit the (·, {}) cache",
            seq.len(),
            self.seq
        );
        let (b, s) = (self.batch, self.seq);
        let mut onehot = vec![0.0f32; b];
        onehot[row] = 1.0;
        let Self { prefill, step, cache_names, adapter_in, .. } = self;
        // stage the row inputs before touching the caches, so an invalid
        // input cannot strand them mid-handoff
        prefill.set(rt, "tokens", &Tensor::from_i32(&[1, s], pad_to(seq, s)))?;
        prefill.set(rt, "last_pos", &Tensor::from_i32(&[], vec![(seq.len() - 1) as i32]))?;
        prefill.set(rt, "row_onehot", &Tensor::from_f32(&[b], onehot))?;
        match (adapter_in.as_deref(), adapter_ix) {
            (Some(name), ix) => {
                // an adapter-less admission on a stacked pair decodes
                // under slot 0's zero-init identity only if the caller
                // routes every row that way; the Generator enforces the
                // policy — here slot 0 is simply the default gather
                prefill.set(rt, name, &Tensor::from_i32(&[], vec![ix.unwrap_or(0)]))?;
            }
            (None, Some(_)) => {
                bail!("kvcache: adapter admission on a pair with no adapter group")
            }
            (None, None) => {}
        }
        // between calls the caches live in the step session; route them
        // through the prefill session for this admission
        step.donate_slots(prefill, cache_names)?;
        // on success the cache outputs rebind onto the prefill session's
        // own input slots; on failure the slots still hold the pre-run
        // caches — donate back either way so a failed admission leaves
        // every in-flight row's cache intact and the decoder usable
        let run = prefill.run(rt);
        prefill.donate_slots(step, cache_names)?;
        run?;
        self.pstats.prefill_tokens += s;
        self.pstats.padded_prefill_tokens += s - seq.len();
        self.pstats.chunks += 1;
        self.slots.admit(row, seq.len())
    }

    /// Run one prompt window through the `bucket` chunk session: `window`
    /// tokens land at grid positions start..start+window.len(), scattered
    /// into `row`'s cache while every other row — and every untouched
    /// slot of the row itself — passes through. Pure cache filling: the
    /// slots ledger only records the admission once the final window has
    /// been fed (see [`KvDecoder::admit_chunked`] and the budget-paced
    /// `Generator::prefill_tick`).
    pub fn prefill_chunk(
        &mut self,
        rt: &Runtime,
        row: usize,
        window: &[i32],
        start: usize,
        bucket: usize,
        adapter_ix: Option<i32>,
    ) -> Result<()> {
        ensure!(row < self.batch, "kvcache: chunk into out-of-range row {row}");
        ensure!(
            self.slots.len(row).is_none(),
            "kvcache: chunk into already-admitted row {row}"
        );
        ensure!(
            !window.is_empty() && window.len() <= bucket,
            "kvcache: window of {} tokens does not fit the {bucket}-token bucket",
            window.len()
        );
        ensure!(
            start + window.len() <= self.seq,
            "kvcache: window at {start}..{} overruns the (·, {}) cache",
            start + window.len(),
            self.seq
        );
        let b = self.batch;
        let mut onehot = vec![0.0f32; b];
        onehot[row] = 1.0;
        let Self { step, chunks, cache_names, adapter_in, pstats, .. } = self;
        let sess = chunks
            .iter_mut()
            .find(|(c, _)| *c == bucket)
            .map(|(_, s)| s)
            .with_context(|| {
                format!("kvcache: no {bucket}-token chunk bucket registered")
            })?;
        // stage the window inputs before touching the caches, so an
        // invalid input cannot strand them mid-handoff
        sess.set(rt, "tokens", &Tensor::from_i32(&[1, bucket], pad_to(window, bucket)))?;
        sess.set(rt, "start_pos", &Tensor::from_i32(&[], vec![start as i32]))?;
        sess.set(rt, "last_pos", &Tensor::from_i32(&[], vec![(window.len() - 1) as i32]))?;
        sess.set(rt, "row_onehot", &Tensor::from_f32(&[b], onehot))?;
        match (adapter_in.as_deref(), adapter_ix) {
            (Some(name), ix) => {
                sess.set(rt, name, &Tensor::from_i32(&[], vec![ix.unwrap_or(0)]))?;
            }
            (None, Some(_)) => {
                bail!("kvcache: adapter admission on a pair with no adapter group")
            }
            (None, None) => {}
        }
        // caches hop step session -> chunk session -> back, exactly like
        // the monolithic admission routes them through prefill
        step.donate_slots(sess, cache_names)?;
        let run = sess.run(rt);
        sess.donate_slots(step, cache_names)?;
        run?;
        pstats.prefill_tokens += bucket;
        pstats.padded_prefill_tokens += bucket - window.len();
        pstats.chunks += 1;
        Ok(())
    }

    /// Admit a row through the bucket ladder in one call: the prompt is
    /// fed as `chunk_plan` windows (see [`next_bucket`] — no more
    /// pad-to-S, per-prompt padding < the smallest bucket), then the
    /// slots ledger records the admission. The tick-paced variant that
    /// spreads the windows across scheduler ticks lives in
    /// `Generator::prefill_tick`.
    pub fn admit_chunked(
        &mut self,
        rt: &Runtime,
        row: usize,
        seq: &[i32],
        adapter_ix: Option<i32>,
    ) -> Result<()> {
        ensure!(
            !seq.is_empty() && seq.len() <= self.seq,
            "kvcache: prompt of {} tokens does not fit the (·, {}) cache",
            seq.len(),
            self.seq
        );
        let ladder = self.ladder();
        ensure!(!ladder.is_empty(), "kvcache: no chunked-prefill ladder registered");
        for (start, take, bucket) in chunk_plan(&ladder, seq.len()) {
            self.prefill_chunk(rt, row, &seq[start..start + take], start, bucket, adapter_ix)?;
        }
        self.slots.admit(row, seq.len())
    }

    /// Admission through the bucket ladder when enabled, the monolithic
    /// (1, S) prefill otherwise.
    pub fn admit_auto(
        &mut self,
        rt: &Runtime,
        row: usize,
        seq: &[i32],
        adapter_ix: Option<i32>,
    ) -> Result<()> {
        if self.chunked {
            self.admit_chunked(rt, row, seq, adapter_ix)
        } else {
            self.admit(rt, row, seq, adapter_ix)
        }
    }

    /// One incremental step over the whole grid: feeds each occupied row's
    /// frontier `(token, pos)` (free — or mid-chunked-admission — rows
    /// ride along as off-grid dummies that write nothing) and returns
    /// next-token logits (B, V) on the host. On a stacked-adapter pair
    /// `adapter_ix` carries each row's slot (free rows gather slot 0,
    /// harmlessly).
    pub fn step(
        &mut self,
        rt: &Runtime,
        feeds: &[Option<(i32, usize)>],
        adapter_ix: Option<&[i32]>,
    ) -> Result<Tensor> {
        ensure!(
            feeds.len() == self.batch,
            "kvcache: {} feeds for batch {}",
            feeds.len(),
            self.batch
        );
        let mut toks = Vec::with_capacity(self.batch);
        let mut pos = Vec::with_capacity(self.batch);
        for (row, feed) in feeds.iter().enumerate() {
            match feed {
                Some((t, p)) => {
                    self.slots.advance(row, *p)?;
                    toks.push(*t);
                    pos.push(*p as i32);
                }
                None => {
                    ensure!(
                        self.slots.len(row).is_none(),
                        "kvcache: occupied row {row} fed no frontier token"
                    );
                    toks.push(PAD);
                    // off-grid: the (grid == pos) scatter is empty at
                    // pos == S, so a dummy row writes nothing. (The old
                    // pos-0 dummy relied on monolithic prefill rewriting
                    // the whole row at the next admission; a chunked
                    // admission only rewrites prompt positions, and a
                    // row mid-chunked-admission rides decode steps as a
                    // dummy — a pos-0 write would corrupt it.)
                    pos.push(self.seq as i32);
                }
            }
        }
        let batch = self.batch;
        // split-borrow so the gather-input name needn't be cloned on the
        // per-token hot path
        let Self { step, adapter_in, .. } = self;
        step.set(rt, "tokens", &Tensor::from_i32(&[batch, 1], toks))?;
        step.set(rt, "pos", &Tensor::from_i32(&[batch], pos))?;
        match (adapter_in.as_deref(), adapter_ix) {
            (Some(name), ix) => {
                let ix = match ix {
                    Some(v) => {
                        ensure!(
                            v.len() == batch,
                            "kvcache: {} adapter feeds for batch {batch}",
                            v.len()
                        );
                        v.to_vec()
                    }
                    None => vec![0; batch],
                };
                step.set(rt, name, &Tensor::from_i32(&[batch], ix))?;
            }
            (None, Some(_)) => {
                bail!("kvcache: adapter feeds on a pair with no adapter group")
            }
            (None, None) => {}
        }
        let out = step.run(rt)?;
        let logits = out.get("logits")?;
        if logits.shape != [self.batch, self.vocab] {
            bail!(
                "kvcache: step logits shape {:?}, want {:?}",
                logits.shape,
                [self.batch, self.vocab]
            );
        }
        Ok(logits.clone())
    }

    /// One speculative verification pass over the whole grid: each `Some`
    /// row feeds its frontier token + drafts (a (K+1)-token window starting
    /// at `pos`, of which `live` are real) and gets logits at *every*
    /// window position back, (B, K+1, V) on the host. `None` rows ride
    /// along as off-grid dummies (`pos = S`): the artifact writes nothing
    /// for them, so even an occupied-but-idle row's cache stays intact.
    ///
    /// The caches hop step session → verify session → back, exactly like
    /// admission routes them through prefill; only `live` positions are
    /// recorded in the slots, so the caller rewinds rejected drafts with
    /// [`KvDecoder::rewind`] afterwards.
    pub fn verify(
        &mut self,
        rt: &Runtime,
        feeds: &[Option<VerifyFeed>],
        adapter_ix: Option<&[i32]>,
    ) -> Result<Tensor> {
        let k = self
            .draft_k
            .context("kvcache: verify on a decoder without the verify artifact")?;
        ensure!(
            feeds.len() == self.batch,
            "kvcache: {} verify feeds for batch {}",
            feeds.len(),
            self.batch
        );
        let mut toks = Vec::with_capacity(self.batch * (k + 1));
        let mut pos = Vec::with_capacity(self.batch);
        for (row, feed) in feeds.iter().enumerate() {
            match feed {
                Some(f) => {
                    ensure!(
                        f.tokens.len() == k + 1,
                        "kvcache: verify window of {} tokens, want {}",
                        f.tokens.len(),
                        k + 1
                    );
                    ensure!(
                        1 <= f.live && f.live <= k + 1,
                        "kvcache: verify live count {} outside 1..={}",
                        f.live,
                        k + 1
                    );
                    for t in 0..f.live {
                        self.slots.advance(row, f.pos + t)?;
                    }
                    toks.extend_from_slice(&f.tokens);
                    pos.push(f.pos as i32);
                }
                None => {
                    toks.extend(std::iter::repeat(PAD).take(k + 1));
                    pos.push(self.seq as i32); // off-grid: writes nothing
                }
            }
        }
        let batch = self.batch;
        let Self { step, verify, cache_names, adapter_in, .. } = self;
        let sess = verify.as_mut().expect("draft_k implies a verify session");
        sess.set(rt, "tokens", &Tensor::from_i32(&[batch, k + 1], toks))?;
        sess.set(rt, "pos", &Tensor::from_i32(&[batch], pos))?;
        match (adapter_in.as_deref(), adapter_ix) {
            (Some(name), ix) => {
                let ix = match ix {
                    Some(v) => {
                        ensure!(
                            v.len() == batch,
                            "kvcache: {} adapter feeds for batch {batch}",
                            v.len()
                        );
                        v.to_vec()
                    }
                    None => vec![0; batch],
                };
                sess.set(rt, name, &Tensor::from_i32(&[batch], ix))?;
            }
            (None, Some(_)) => {
                bail!("kvcache: adapter feeds on a trio with no adapter group")
            }
            (None, None) => {}
        }
        // between calls the caches live in the step session; route them
        // through the verify session for this pass — donate back whether
        // the run succeeded or not, so a failed verify leaves the decoder
        // usable (the slots above may have advanced; callers treat a
        // verify error as fatal for the affected generator anyway)
        step.donate_slots(sess, cache_names)?;
        let run = sess.run(rt);
        sess.donate_slots(step, cache_names)?;
        let out = run?;
        let logits = out.get("logits")?;
        if logits.shape != [batch, k + 1, self.vocab] {
            bail!(
                "kvcache: verify logits shape {:?}, want {:?}",
                logits.shape,
                [batch, k + 1, self.vocab]
            );
        }
        Ok(logits.clone())
    }

    /// Roll a row's frontier back `n` positions (rejected drafts). Logical
    /// only — see [`CacheSlots::rewind`] for the safety rules.
    pub fn rewind(&mut self, row: usize, n: usize) -> Result<()> {
        self.slots.rewind(row, n)
    }

    /// Free a row's cache slot after `take`.
    pub fn evict(&mut self, row: usize) -> Result<()> {
        self.slots.evict(row)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_admit_advance_evict_tracks_positions() {
        let mut cs = CacheSlots::new(2, 8);
        assert_eq!(cs.occupied(), 0);
        cs.admit(0, 3).unwrap();
        assert_eq!(cs.len(0), Some(3));
        // first step rewrites the frontier token's position (pos = len-1)
        cs.advance(0, 2).unwrap();
        assert_eq!(cs.len(0), Some(3));
        // subsequent steps grow the cache (pos = len)
        cs.advance(0, 3).unwrap();
        cs.advance(0, 4).unwrap();
        assert_eq!(cs.len(0), Some(5));
        cs.evict(0).unwrap();
        assert_eq!(cs.len(0), None);
        assert_eq!(cs.occupied(), 0);
    }

    #[test]
    fn admit_rejects_occupied_row_and_oversized_prompt() {
        let mut cs = CacheSlots::new(2, 8);
        cs.admit(1, 4).unwrap();
        assert!(cs.admit(1, 2).is_err(), "double admit");
        assert!(cs.admit(0, 9).is_err(), "prompt longer than capacity");
        assert!(cs.admit(0, 0).is_err(), "empty prompt");
        assert!(cs.admit(2, 1).is_err(), "row out of range");
    }

    #[test]
    fn advance_rejects_gaps_free_rows_and_overflow() {
        let mut cs = CacheSlots::new(1, 6);
        assert!(cs.advance(0, 0).is_err(), "free row");
        cs.admit(0, 2).unwrap();
        assert!(cs.advance(0, 0).is_err(), "behind the frontier");
        assert!(cs.advance(0, 3).is_err(), "gap past the frontier");
        cs.advance(0, 2).unwrap();
        cs.advance(0, 3).unwrap();
        cs.advance(0, 4).unwrap();
        cs.advance(0, 5).unwrap();
        assert_eq!(cs.len(0), Some(6));
        assert!(cs.advance(0, 6).is_err(), "write beyond capacity");
    }

    #[test]
    fn rewind_boundaries() {
        let mut cs = CacheSlots::new(2, 16);
        cs.admit(0, 4).unwrap();
        // grow the frontier by 3 generated positions: 4 -> 7
        cs.advance(0, 3).unwrap();
        for p in 4..7 {
            cs.advance(0, p).unwrap();
        }
        assert_eq!(cs.len(0), Some(7));
        // rewind 0 is a no-op
        cs.rewind(0, 0).unwrap();
        assert_eq!(cs.len(0), Some(7));
        // rewind within the generated tail
        cs.rewind(0, 2).unwrap();
        assert_eq!(cs.len(0), Some(5));
        // rewind exactly to the admit length is allowed
        cs.rewind(0, 1).unwrap();
        assert_eq!(cs.len(0), Some(4));
        // rewind past the admit length (into the prompt) is refused
        assert!(cs.rewind(0, 1).is_err(), "crossed the admit length");
        assert_eq!(cs.len(0), Some(4), "failed rewind must not move the frontier");
        // rewind on a free row / out-of-range row is refused
        assert!(cs.rewind(1, 0).is_err(), "free row");
        assert!(cs.rewind(2, 0).is_err(), "row out of range");
        // rewind on an evicted row is refused
        cs.evict(0).unwrap();
        assert!(cs.rewind(0, 0).is_err(), "evicted row");
    }

    #[test]
    fn rewind_then_advance_rewrites_the_new_frontier() {
        // after a rejection the next write lands at the rolled-back
        // frontier (pos == len), exactly like a normal growth step
        let mut cs = CacheSlots::new(1, 16);
        cs.admit(0, 3).unwrap();
        for p in 3..8 {
            cs.advance(0, p).unwrap();
        }
        cs.rewind(0, 4).unwrap();
        assert_eq!(cs.len(0), Some(4));
        assert!(cs.advance(0, 6).is_err(), "gap past the rolled-back frontier");
        cs.advance(0, 4).unwrap();
        cs.advance(0, 5).unwrap();
        assert_eq!(cs.len(0), Some(6));
    }

    #[test]
    fn recycling_after_mid_stream_rejection_starts_from_the_new_prompt() {
        // a row evicted right after a rewind (mid-stream rejection, then
        // the request finished) re-admits cleanly: the new occupant's
        // admit length, not the old frontier, bounds future rewinds
        let mut cs = CacheSlots::new(1, 16);
        cs.admit(0, 6).unwrap();
        for p in 6..10 {
            cs.advance(0, p).unwrap();
        }
        cs.rewind(0, 3).unwrap();
        cs.evict(0).unwrap();
        cs.admit(0, 2).unwrap();
        assert_eq!(cs.len(0), Some(2));
        cs.advance(0, 2).unwrap();
        cs.rewind(0, 1).unwrap();
        assert_eq!(cs.len(0), Some(2));
        assert!(cs.rewind(0, 1).is_err(), "old admit length leaked into the row");
    }

    #[test]
    fn chunk_ladder_mirrors_the_aot_formula() {
        // keep in lockstep with aot.chunk_ladder (test_aot.py asserts the
        // same table on the python side)
        assert_eq!(chunk_ladder(8), vec![8]);
        assert_eq!(chunk_ladder(16), vec![16]);
        assert_eq!(chunk_ladder(32), vec![16, 32]);
        assert_eq!(chunk_ladder(64), vec![16, 64]);
        assert_eq!(chunk_ladder(128), vec![16, 64, 128]);
    }

    #[test]
    fn next_bucket_prefers_low_padding_then_funded_then_forced() {
        let ladder = [16, 64, 128];
        // the covering bucket when its padding beats the smallest bucket
        assert_eq!(next_bucket(&ladder, 10, 1000, false), Some(16));
        assert_eq!(next_bucket(&ladder, 16, 1000, false), Some(16));
        assert_eq!(next_bucket(&ladder, 60, 1000, false), Some(64));
        assert_eq!(next_bucket(&ladder, 128, 1000, false), Some(128));
        // a covering bucket that would pad >= ladder[0] loses to a full
        // window split (17 -> 16 + 16, padded 15, not a 64/47-pad window)
        assert_eq!(next_bucket(&ladder, 17, 1000, false), Some(16));
        assert_eq!(next_bucket(&ladder, 70, 1000, false), Some(64));
        // covering bucket over budget: the largest funded full window
        assert_eq!(next_bucket(&ladder, 100, 64, false), Some(64));
        assert_eq!(next_bucket(&ladder, 100, 63, false), Some(16));
        assert_eq!(next_bucket(&ladder, 20, 16, false), Some(16));
        // nothing funded: None, unless forced (the per-tick progress
        // guarantee), which takes the covering (or smallest) bucket
        assert_eq!(next_bucket(&ladder, 100, 8, false), None);
        assert_eq!(next_bucket(&ladder, 100, 8, true), Some(16));
        assert_eq!(next_bucket(&ladder, 10, 0, true), Some(16));
    }

    #[test]
    fn chunk_plan_covers_the_prompt_without_pad_to_grid() {
        // short prompt: one right-sized window
        assert_eq!(chunk_plan(&[16, 64], 5), vec![(0, 5, 16)]);
        // exact bucket fit
        assert_eq!(chunk_plan(&[16, 64], 16), vec![(0, 16, 16)]);
        // between buckets: full windows + a small tail, never a
        // pad-heavy covering window
        assert_eq!(chunk_plan(&[16, 64], 20), vec![(0, 16, 16), (16, 4, 16)]);
        assert_eq!(chunk_plan(&[16, 64], 60), vec![(0, 60, 64)]);
        assert_eq!(chunk_plan(&[16, 64], 64), vec![(0, 64, 64)]);
        // a ladder without a covering bucket splits into windows
        assert_eq!(chunk_plan(&[8], 20), vec![(0, 8, 8), (8, 8, 8), (16, 4, 8)]);
        // plans tile the prompt exactly, padding < the smallest bucket
        for len in 1..40 {
            let plan = chunk_plan(&[8, 32], len);
            let mut at = 0;
            let mut windows = 0;
            for &(start, take, bucket) in &plan {
                assert_eq!(start, at);
                assert!(take <= bucket);
                at += take;
                windows += bucket;
            }
            assert_eq!(at, len);
            assert!(windows - len < 8, "len {len} padded {}", windows - len);
        }
    }

    #[test]
    fn prefill_stats_merge_sums_counters() {
        let a = PrefillStats { prefill_tokens: 64, padded_prefill_tokens: 10, chunks: 2 };
        let b = PrefillStats { prefill_tokens: 16, padded_prefill_tokens: 3, chunks: 1 };
        assert_eq!(
            a.merge(b),
            PrefillStats { prefill_tokens: 80, padded_prefill_tokens: 13, chunks: 3 }
        );
    }

    #[test]
    fn recycling_a_row_requires_evict_then_admit() {
        let mut cs = CacheSlots::new(1, 8);
        cs.admit(0, 5).unwrap();
        assert!(cs.evict(0).is_ok());
        assert!(cs.evict(0).is_err(), "double evict");
        // the recycled row starts from the new prompt's length, not the
        // old frontier
        cs.admit(0, 2).unwrap();
        assert_eq!(cs.len(0), Some(2));
    }
}

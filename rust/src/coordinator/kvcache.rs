//! KV-cache decode: per-row cache slot lifecycle over the decode artifact
//! trio (`decode_prefill_*` / `decode_step_*` / `decode_verify_*`), riding
//! the Session state-donation layer.
//!
//! The caches are artifact state: aot.py declares every `new.cache_*`
//! output bound onto its `cache_*` input (`extra.state_bindings`), so
//! between decode steps they never leave the step session's slots — PJRT
//! buffers on the device backend, exactly like optimiser moments in
//! training artifacts. Admission routes the caches through the prefill
//! session and back via [`Session::donate_slots`], which moves buffer
//! handles, not bytes; the only per-token traffic is the (B, 1) frontier
//! tokens up and the (B, V) logits down.
//!
//! Row lifecycle is tracked by [`CacheSlots`] (pure bookkeeping, unit
//! tested): `admit` installs a row's prompt cache, `advance` records each
//! decode-step write at the row frontier, `rewind` rolls the frontier back
//! past rejected speculative drafts (never below the admission prefill),
//! `evict` frees the slot after `take`. A recycled row is safe by
//! construction — its next admission rewrites the whole cache row under
//! the prefill's `row_onehot` mask.
//!
//! The optional verify session (DESIGN.md §2d) is the third artifact of
//! the trio: a (B, K+1) window that scores a whole draft run in one
//! batched forward, sharing the pair's donated cache tensors bitwise.
//!
//! The optional chunked-prefill ladder (DESIGN.md §2e) generalizes
//! admission the same way: `decode_prefill_chunk_<model>_c<C>` artifacts
//! forward one (1, C) prompt *window* at `start_pos` instead of a
//! monolithic pad-to-S grid, so a short prompt costs its covering bucket
//! and a long one can be paced across scheduler ticks
//! (`Generator::prefill_tick`) without ever freezing the decoding batch.
//!
//! The paged family (DESIGN.md §2f) replaces the dense `(B, S, ...)` cache
//! rows with a fixed pool of `(n_blocks, block, ...)` blocks behind
//! per-row block tables: [`BlockPool`] refcounts the physical blocks,
//! [`PrefixIndex`] maps chain-hashed full-block prompt prefixes to
//! resident blocks so `admit_chunked` skips windows whose prefix another
//! row already computed, and [`PagedKv`] carries each row's table. Same
//! `KvDecoder` surface, probing `decode_*_paged_<model>` artifact names.


// The static mirror of this policy is `tools/loramlint` (panic-surface
// pass); both gate the same hot path. Test code is exempt on both sides.
#![cfg_attr(
    not(test),
    deny(clippy::unwrap_used, clippy::expect_used, clippy::panic, clippy::unreachable)
)]
#![cfg_attr(not(test), warn(clippy::indexing_slicing))]

use crate::obs::trace::{self, Event};
use crate::obs::Metrics;
use crate::runtime::{Runtime, Session};
use crate::tensor::{Dtype, Tensor, TensorStore};
use crate::tokenizer::{pad_to, PAD};
use crate::util::log;
use anyhow::{bail, ensure, Context, Result};
use std::collections::HashMap;

/// Chunked-prefill bucket ladder for an S-long decode grid — the Rust
/// mirror of aot.py's `chunk_ladder`. The shared formula IS the discovery
/// contract: [`KvDecoder::try_new`] probes exactly the bucket names
/// `decode_prefill_chunk_<model>_c<C>` for C in this ladder, so no
/// manifest is needed to find the chunk artifacts.
pub fn chunk_ladder(seq: usize) -> Vec<usize> {
    let mut v = vec![16.min(seq), 64.min(seq), seq];
    v.sort_unstable();
    v.dedup();
    v
}

/// Paged-KV block size in token slots — the Rust mirror of aot.py's
/// `PAGED_BLOCK`. Both sides size the same compiled artifacts, so the
/// pair is a `contract-mirror` lint contract (`paged-geometry`).
pub const PAGED_BLOCK: usize = 8;

/// Pool size (in blocks) that byte-matches a dense `b x s` KV grid — the
/// Rust mirror of aot.py's `paged_pool_blocks`. The parameter names and
/// the expression mirror the Python source token-for-token: the lint
/// compares the two formulas textually, not numerically.
pub fn paged_pool_blocks(b: usize, s: usize, block: usize) -> usize {
    b * (s / block)
}

/// Pick the bucket for the next prefill window of a prompt with
/// `remaining` unfed tokens, under the tick's unspent token `budget`.
/// A covering window (smallest bucket >= remaining) finishes the prompt
/// in one call, but is only taken when its padding beats the worst-case
/// tail pad of splitting (< ladder[0]) — a 17-token remainder under a
/// [16, 64] ladder takes a 16 + 16 split (<= 15 padded), never a
/// 64-window (47 padded). Otherwise the largest budget-funded bucket
/// that fits *inside* the remainder runs as a zero-padding full window.
/// `None` when the budget funds nothing — unless `force` (nothing spent
/// yet this tick) demands progress, so a budget below the smallest
/// bucket still converges.
pub(crate) fn next_bucket(
    ladder: &[usize],
    remaining: usize,
    budget: usize,
    force: bool,
) -> Option<usize> {
    debug_assert!(remaining > 0 && !ladder.is_empty());
    let fit = ladder
        .iter()
        .copied()
        .find(|&c| c >= remaining)
        .filter(|&c| c - remaining < ladder[0]);
    if let Some(c) = fit {
        if c <= budget || force {
            return Some(c);
        }
    }
    // full mid-prompt window (zero padding); when even the smallest
    // bucket is unfunded, `force` takes it anyway — it always fits the
    // remainder here, since a rejected/absent `fit` implies
    // remaining > ladder[0]
    match ladder
        .iter()
        .copied()
        .filter(|&c| c <= remaining && c <= budget)
        .last()
    {
        Some(c) => Some(c),
        None if force => Some(ladder[0]),
        None => None,
    }
}

/// The window plan for admitting a whole `len`-token prompt with an
/// unbounded budget: `(start, take, bucket)` per chunk. With a ladder
/// containing the full grid this is a single right-sized window; the
/// budget-paced multi-tick variant lives in `Generator::prefill_tick`.
pub(crate) fn chunk_plan(ladder: &[usize], len: usize) -> Vec<(usize, usize, usize)> {
    let mut out = vec![];
    let mut start = 0;
    while start < len {
        let Some(bucket) = next_bucket(ladder, len - start, usize::MAX, true) else {
            break; // empty ladder: no window can be planned
        };
        let take = bucket.min(len - start);
        out.push((start, take, bucket));
        start += take;
    }
    out
}

/// Cumulative prefill accounting (surfaced through
/// [`crate::serve::ServerStats`] and the serving benches): how many
/// window tokens admissions processed and how many of those were padding
/// — the wasted FLOPs the bucket ladder exists to shrink (monolithic
/// admission pays S - len per prompt).
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct PrefillStats {
    /// prefill window tokens processed (bucket sizes, padding included)
    pub prefill_tokens: usize,
    /// of those, padding beyond the prompt tokens
    pub padded_prefill_tokens: usize,
    /// admission windows run (a monolithic admission counts as one)
    pub chunks: usize,
}

impl PrefillStats {
    pub fn merge(self, other: PrefillStats) -> PrefillStats {
        PrefillStats {
            prefill_tokens: self.prefill_tokens + other.prefill_tokens,
            padded_prefill_tokens: self.padded_prefill_tokens
                + other.padded_prefill_tokens,
            chunks: self.chunks + other.chunks,
        }
    }

    /// Export into the unified registry (DESIGN.md §2g) under `prefill.*`.
    pub fn export_into(&self, m: &mut Metrics) {
        m.set_counter("prefill.tokens", self.prefill_tokens as f64);
        m.set_counter("prefill.padded_tokens", self.padded_prefill_tokens as f64);
        m.set_counter("prefill.chunks", self.chunks as f64);
        let share = self.padded_prefill_tokens as f64 / self.prefill_tokens.max(1) as f64;
        m.set_gauge("prefill.padded_share", share);
    }
}

// ---------------------------------------------------------------------------
// Paged KV cache (DESIGN.md §2f): a fixed pool of `block`-slot cache blocks
// behind a per-row block table, with shared-prefix reuse keyed by
// prompt-chunk hash. Pure host bookkeeping — the device side is the
// `decode_*_paged` artifact family, whose pooled `(n_blocks, block, ...)`
// caches are addressed through the int32 block-table input these
// structures maintain.
// ---------------------------------------------------------------------------

/// Chained FNV-1a 64 over a token run: `prev == 0` starts a fresh hash,
/// otherwise the digest continues from the preceding prefix's hash, so
/// each full block's key commits to the *entire* token prefix ending at
/// it. Shared with the prefix index and its tests; collisions are real
/// (64-bit) but harmless — [`PrefixIndex::lookup`] compares the stored
/// tokens and falls back to a full prefill on mismatch.
pub fn prefix_chunk_hash(prev: u64, tokens: &[i32]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = if prev == 0 { OFFSET } else { prev };
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
    }
    h
}

/// The physical block allocator: refcounted fixed-size cache blocks. A
/// block is *in use* while any row or the prefix index holds a reference;
/// at refcount zero it returns to the free list. `pinned` blocks survive
/// cache-pressure eviction ([`BlockPool::evict`] refuses them) — the
/// operator knob for hot shared prefixes. Copy-on-write ([`BlockPool::cow`])
/// forks a shared block into a fresh private one; in the serving flow
/// writes never target shared blocks (see [`PagedKv`]), so `cow_copies`
/// staying at zero is itself a checked invariant.
#[derive(Debug, Clone)]
pub struct BlockPool {
    block: usize,
    refcnt: Vec<u32>,
    pinned: Vec<bool>,
    free: Vec<usize>,
    cow_copies: usize,
}

impl BlockPool {
    pub fn new(n_blocks: usize, block: usize) -> Result<BlockPool> {
        ensure!(n_blocks >= 1 && block >= 1, "kvcache: degenerate block pool");
        Ok(BlockPool {
            block,
            refcnt: vec![0; n_blocks],
            pinned: vec![false; n_blocks],
            // pop from the back: low ids first, deterministic for tests
            free: (0..n_blocks).rev().collect(),
            cow_copies: 0,
        })
    }

    pub fn n_blocks(&self) -> usize {
        self.refcnt.len()
    }

    pub fn block_size(&self) -> usize {
        self.block
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn blocks_in_use(&self) -> usize {
        self.n_blocks() - self.free.len()
    }

    pub fn cow_copies(&self) -> usize {
        self.cow_copies
    }

    pub fn refcount(&self, id: usize) -> u32 {
        self.refcnt.get(id).copied().unwrap_or(0)
    }

    pub fn is_pinned(&self, id: usize) -> bool {
        self.pinned.get(id).copied().unwrap_or(false)
    }

    /// Claim a free block (refcount 1), `None` when the pool is exhausted
    /// — the caller decides between reclaiming index-only blocks and
    /// failing the admission.
    pub fn alloc(&mut self) -> Option<usize> {
        let id = self.free.pop()?;
        debug_assert_eq!(self.refcnt[id], 0);
        self.refcnt[id] = 1;
        self.pinned[id] = false;
        trace::emit(|| Event::BlockAlloc { block: id });
        Some(id)
    }

    /// Take an additional reference on an allocated block (a row reusing
    /// a resident prefix block, or the index retaining a registered one).
    pub fn retain(&mut self, id: usize) -> Result<()> {
        ensure!(self.refcount(id) > 0, "kvcache: retain of free block {id}");
        self.refcnt[id] += 1;
        Ok(())
    }

    /// Drop one reference; at zero the block returns to the free list
    /// (and loses its pin — an unreferenced block is nobody's to pin).
    pub fn release(&mut self, id: usize) -> Result<()> {
        ensure!(self.refcount(id) > 0, "kvcache: release of free block {id}");
        self.refcnt[id] -= 1;
        if self.refcnt[id] == 0 {
            self.pinned[id] = false;
            self.free.push(id);
            trace::emit(|| Event::BlockFree { block: id });
        }
        Ok(())
    }

    /// Shield an allocated block from cache-pressure [`BlockPool::evict`].
    pub fn pin(&mut self, id: usize) -> Result<()> {
        ensure!(self.refcount(id) > 0, "kvcache: pin of free block {id}");
        self.pinned[id] = true;
        Ok(())
    }

    pub fn unpin(&mut self, id: usize) -> Result<()> {
        ensure!(self.refcount(id) > 0, "kvcache: unpin of free block {id}");
        self.pinned[id] = false;
        Ok(())
    }

    /// Cache-pressure reclaim: force an allocated block back to the free
    /// list regardless of its refcount. Refuses pinned blocks — eviction
    /// policy must never take a prefix the operator marked hot. Callers
    /// ([`PrefixIndex::reclaim`]) only evict blocks whose sole reference
    /// is their own, so no row ever loses a live block underneath it.
    pub fn evict(&mut self, id: usize) -> Result<()> {
        ensure!(self.refcount(id) > 0, "kvcache: evict of free block {id}");
        ensure!(!self.pinned[id], "kvcache: refusing to evict pinned block {id}");
        self.refcnt[id] = 0;
        self.free.push(id);
        trace::emit(|| Event::BlockFree { block: id });
        Ok(())
    }

    /// Copy-on-write: make the caller's reference to `id` exclusively
    /// writable. An already-exclusive block is returned as-is; a shared
    /// one loses this caller's reference and a fresh block is allocated
    /// in its place (`cow_copies` counts the forks). Errors when the fork
    /// needs a block the pool cannot supply.
    pub fn cow(&mut self, id: usize) -> Result<usize> {
        ensure!(self.refcount(id) > 0, "kvcache: cow of free block {id}");
        if self.refcnt[id] == 1 {
            return Ok(id);
        }
        let fresh = self
            .alloc()
            .with_context(|| format!("kvcache: pool exhausted forking shared block {id}"))?;
        self.refcnt[id] -= 1;
        self.cow_copies += 1;
        trace::emit(|| Event::CowCopy { block: fresh });
        Ok(fresh)
    }
}

/// One registered full-block prefix: the chain hash of `tokens` maps to
/// the physical `block` holding its last `block_size` positions. Tokens
/// are stored so a hash collision is detected by comparison, never
/// trusted.
#[derive(Debug, Clone)]
struct PrefixEntry {
    tokens: Vec<i32>,
    block: usize,
    stamp: u64,
}

/// The shared-prefix index: chain-hash of every registered full-block
/// prompt prefix → the resident physical block, so admission can map the
/// longest already-computed prefix of a new prompt onto existing blocks
/// instead of re-prefilling it. The index holds its own reference on
/// every registered block, keeping prefixes resident across row eviction;
/// [`PrefixIndex::reclaim`] releases cold index-only blocks under pool
/// pressure (LRU by lookup stamp).
#[derive(Debug, Default)]
pub struct PrefixIndex {
    map: HashMap<u64, PrefixEntry>,
    clock: u64,
}

impl PrefixIndex {
    pub fn new() -> PrefixIndex {
        PrefixIndex::default()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Register the full blocks of `tokens` as resident in `blocks` (the
    /// owning row's leading table entries). Each *newly* inserted entry
    /// retains its block; a hash already present keeps its existing entry
    /// — when the stored tokens match, the content is identical by
    /// construction, and when they differ it is a collision the lookup
    /// side detects.
    pub fn insert(
        &mut self,
        pool: &mut BlockPool,
        tokens: &[i32],
        blocks: &[usize],
    ) -> Result<()> {
        let bs = pool.block_size();
        let full = (tokens.len() / bs).min(blocks.len());
        let mut h = 0u64;
        for j in 0..full {
            h = prefix_chunk_hash(h, &tokens[j * bs..(j + 1) * bs]);
            if self.map.contains_key(&h) {
                continue;
            }
            pool.retain(blocks[j])?;
            self.clock += 1;
            self.map.insert(
                h,
                PrefixEntry {
                    tokens: tokens[..(j + 1) * bs].to_vec(),
                    block: blocks[j],
                    stamp: self.clock,
                },
            );
        }
        Ok(())
    }

    /// The longest resident full-block prefix of `tokens`: the physical
    /// block run, longest-first-match walking one block at a time. A hash
    /// hit whose stored tokens differ — a collision — stops the walk, so
    /// the caller prefills from there (never trusting the hash alone).
    /// Bumps the LRU stamp of every entry on the run.
    pub fn lookup(&mut self, block_size: usize, tokens: &[i32]) -> Vec<usize> {
        let mut run = vec![];
        let mut h = 0u64;
        for j in 0..tokens.len() / block_size {
            h = prefix_chunk_hash(h, &tokens[j * block_size..(j + 1) * block_size]);
            match self.map.get_mut(&h) {
                Some(e) if e.tokens == tokens[..(j + 1) * block_size] => {
                    self.clock += 1;
                    e.stamp = self.clock;
                    run.push(e.block);
                }
                _ => break,
            }
        }
        run
    }

    /// Release cold index-only entries (their block's sole reference is
    /// the index's own, and the block is not pinned) until `need` blocks
    /// have been freed; returns how many were. Dropping a mid-chain entry
    /// can orphan its suffix entries — they become unreachable, never get
    /// their stamps bumped, and age into the next reclaim's coldest
    /// candidates, so the index is self-cleaning under sustained pressure.
    pub fn reclaim(&mut self, pool: &mut BlockPool, need: usize) -> usize {
        let mut cold: Vec<(u64, u64, usize)> = self
            .map
            .iter()
            .filter(|(_, e)| pool.refcount(e.block) == 1 && !pool.is_pinned(e.block))
            .map(|(h, e)| (e.stamp, *h, e.block))
            .collect();
        cold.sort_unstable();
        let mut freed = 0;
        for (_, h, block) in cold {
            if freed >= need {
                break;
            }
            self.map.remove(&h);
            if pool.release(block).is_ok() {
                freed += 1;
            }
        }
        freed
    }

    /// Drop every entry, releasing the index's references.
    pub fn clear(&mut self, pool: &mut BlockPool) {
        for (_, e) in self.map.drain() {
            // lint: allow(result, "best-effort drain: one bad refcount must not abort the clear")
            let _ = pool.release(e.block);
        }
    }

    #[cfg(test)]
    /// Test hook: plant an entry whose stored tokens need not hash to
    /// `hash` — the only way to exercise the collision path without
    /// forging a real 64-bit FNV collision.
    fn inject(&mut self, hash: u64, tokens: Vec<i32>, block: usize) {
        self.clock += 1;
        self.map.insert(hash, PrefixEntry { tokens, block, stamp: self.clock });
    }
}

/// Paged-decode counters, surfaced through `ServerStats` / the serving
/// benches / `tab8_serving.csv`.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct PagedStats {
    /// prefix-index lookups (one per prefix-eligible admission)
    pub lookups: usize,
    /// of those, lookups that mapped >= 1 resident block
    pub prefix_hits: usize,
    /// prompt tokens admitted from resident blocks instead of prefill
    pub prefix_hit_tokens: usize,
    /// copy-on-write forks (zero in the serving flow — writes never
    /// target shared blocks; see [`PagedKv`])
    pub cow_copies: usize,
    /// pool blocks currently referenced by rows or the prefix index
    pub blocks_in_use: usize,
    pub pool_blocks: usize,
}

impl PagedStats {
    /// Fraction of prefix-eligible admissions that reused resident blocks.
    pub fn prefix_hit_rate(&self) -> f64 {
        self.prefix_hits as f64 / self.lookups.max(1) as f64
    }

    /// Fraction of the pool currently in use.
    pub fn utilization(&self) -> f64 {
        self.blocks_in_use as f64 / self.pool_blocks.max(1) as f64
    }

    /// Export into the unified registry (DESIGN.md §2g) under `paged.*`.
    pub fn export_into(&self, m: &mut Metrics) {
        m.set_counter("paged.lookups", self.lookups as f64);
        m.set_counter("paged.prefix_hits", self.prefix_hits as f64);
        m.set_counter("paged.prefix_hit_tokens", self.prefix_hit_tokens as f64);
        m.set_counter("paged.cow_copies", self.cow_copies as f64);
        m.set_gauge("paged.blocks_in_use", self.blocks_in_use as f64);
        m.set_gauge("paged.pool_blocks", self.pool_blocks as f64);
        m.set_gauge("paged.prefix_hit_rate", self.prefix_hit_rate());
        m.set_gauge("paged.utilization", self.utilization());
    }
}

/// One admitted row's view of the pool: its physical block run, of which
/// the first `shared` were taken resident from the prefix index at
/// admission (the row holds its own reference on those too).
#[derive(Debug, Clone)]
struct PagedRow {
    blocks: Vec<usize>,
    shared: usize,
}

/// Per-row block tables over a [`BlockPool`] + [`PrefixIndex`]: the host
/// side of the paged decode contract. Key invariant (why `cow_copies`
/// stays zero in the serving flow): a block is shared only while it is
/// *full* and covers positions `< len - 1` of every row referencing it —
/// [`PagedKv::plan_admit`] caps the resident run at `(len-1)/block`
/// blocks so the final prefill window (which produces the frontier
/// logits) always runs privately, and [`PagedKv::register`] only indexes
/// blocks fully below the frontier. Every subsequent write (chunk windows
/// from the resident boundary, decode/verify steps at `pos >= len - 1`)
/// therefore lands in privately-allocated blocks. [`PagedKv::ensure_writable`]
/// enforces the invariant anyway — a write aimed at a shared block forks
/// it copy-on-write and counts it, so a violation is visible, not silent.
#[derive(Debug)]
pub struct PagedKv {
    pool: BlockPool,
    index: PrefixIndex,
    rows: Vec<Option<PagedRow>>,
    blocks_per_row: usize,
    seq: usize,
    lookups: usize,
    prefix_hits: usize,
    prefix_hit_tokens: usize,
}

impl PagedKv {
    pub fn new(n_blocks: usize, block: usize, batch: usize, seq: usize) -> Result<PagedKv> {
        ensure!(
            block >= 1 && seq >= block && seq % block == 0,
            "kvcache: seq {seq} is not a whole number of {block}-slot blocks"
        );
        Ok(PagedKv {
            pool: BlockPool::new(n_blocks, block)?,
            index: PrefixIndex::new(),
            rows: vec![None; batch],
            blocks_per_row: seq / block,
            seq,
            lookups: 0,
            prefix_hits: 0,
            prefix_hit_tokens: 0,
        })
    }

    pub fn block_size(&self) -> usize {
        self.pool.block_size()
    }

    pub fn blocks_per_row(&self) -> usize {
        self.blocks_per_row
    }

    pub fn pool(&self) -> &BlockPool {
        &self.pool
    }

    pub fn seq_len(&self) -> usize {
        self.seq
    }

    pub fn batch_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn free_blocks(&self) -> usize {
        self.pool.free_blocks()
    }

    /// Non-binding admission probe: how many *private* blocks a prompt of
    /// `tokens` growing to `need_len` positions would still need after
    /// shared-prefix credit — the scheduler's keep-queued-vs-admit signal
    /// (conservative: reclaimable index-only blocks are not counted as
    /// free). Bumps the prefix index's LRU stamps; allocates and retains
    /// nothing.
    pub fn probe(&mut self, tokens: &[i32], need_len: usize) -> usize {
        if tokens.is_empty() {
            return 0;
        }
        let bs = self.pool.block_size();
        let len = tokens.len().min(self.seq);
        let need = need_len.clamp(len, self.seq);
        let want = (need + bs - 1) / bs;
        let resident = self
            .index
            .lookup(bs, &tokens[..len])
            .len()
            .min((len - 1) / bs);
        want - resident
    }

    /// Plan a row's block table for a `tokens`-long prompt that will grow
    /// to at most `need_len` positions (clamped to the grid; the real
    /// decoder passes the full grid, the serving simulator passes
    /// prompt + max_new to model capacity). With `use_prefix`, the
    /// longest resident full-block prefix — capped at `(len-1)/block`
    /// blocks so the final window always runs — is mapped in by
    /// reference; the remainder is privately allocated, reclaiming cold
    /// index-only blocks under pool pressure. Returns the resident token
    /// count (0 = full prefill needed); on exhaustion the row is left
    /// unplanned and every taken reference released.
    pub fn plan_admit(
        &mut self,
        row: usize,
        tokens: &[i32],
        need_len: usize,
        use_prefix: bool,
    ) -> Result<usize> {
        let slot = self
            .rows
            .get(row)
            .with_context(|| format!("kvcache: paged row {row} out of range"))?;
        ensure!(slot.is_none(), "kvcache: paged plan for occupied row {row}");
        ensure!(
            !tokens.is_empty() && tokens.len() <= self.seq,
            "kvcache: prompt of {} tokens does not fit the {}-slot paged grid",
            tokens.len(),
            self.seq
        );
        let bs = self.pool.block_size();
        let need = need_len.clamp(tokens.len(), self.seq);
        let want = (need + bs - 1) / bs;
        let mut blocks = vec![];
        let mut shared = 0;
        if use_prefix {
            self.lookups += 1;
            blocks = self.index.lookup(bs, tokens);
            // the final prefill window must always run — it carries the
            // frontier logits and the first decode step rewrites pos
            // len-1 — so the frontier block is never taken resident
            blocks.truncate((tokens.len() - 1) / bs);
            shared = blocks.len();
            if shared > 0 {
                self.prefix_hits += 1;
                self.prefix_hit_tokens += shared * bs;
                trace::emit(|| Event::PrefixHit { blocks: shared, tokens: shared * bs });
            }
            for &id in &blocks {
                self.pool.retain(id)?;
            }
        }
        while blocks.len() < want {
            match self.pool.alloc() {
                Some(id) => blocks.push(id),
                None => {
                    if self.index.reclaim(&mut self.pool, want - blocks.len()) == 0 {
                        for &id in &blocks {
                            // lint: allow(result, "rollback of just-alloc'd blocks; the bail! below carries the error")
                            let _ = self.pool.release(id);
                        }
                        bail!(
                            "kvcache: block pool exhausted (row {row} needs {want} \
                             blocks, 0 free, nothing reclaimable)"
                        );
                    }
                }
            }
        }
        self.rows[row] = Some(PagedRow { blocks, shared });
        Ok(shared * bs)
    }

    /// Register a freshly-prefilled row's prompt in the prefix index:
    /// every full block strictly below the frontier (`(len-1)/block` of
    /// them) becomes resident for future admissions. The frontier block
    /// is deliberately excluded — the first decode step rewrites position
    /// len-1, and shared blocks must never be written.
    pub fn register(&mut self, row: usize, tokens: &[i32]) -> Result<()> {
        let r = self
            .rows
            .get(row)
            .and_then(|r| r.as_ref())
            .with_context(|| format!("kvcache: register of unplanned paged row {row}"))?;
        let bs = self.pool.block_size();
        let full = tokens.len().saturating_sub(1) / bs;
        let blocks = r.blocks[..full.min(r.blocks.len())].to_vec();
        self.index.insert(&mut self.pool, &tokens[..full * bs], &blocks)
    }

    /// The row's block table padded to the full table width with block 0
    /// (positions beyond the planned extent are never written, and reads
    /// are clamped + masked device-side).
    pub fn table_i32(&self, row: usize) -> Option<Vec<i32>> {
        self.rows.get(row)?.as_ref().map(|r| {
            let mut t: Vec<i32> = r.blocks.iter().map(|&b| b as i32).collect();
            t.resize(self.blocks_per_row, 0);
            t
        })
    }

    /// The whole-grid `(B, S/block)` table for step/verify calls; rows
    /// without a planned table feed zeros (off-grid dummies write nothing).
    pub fn grid_table_i32(&self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.rows.len() * self.blocks_per_row);
        for row in 0..self.rows.len() {
            match self.table_i32(row) {
                Some(t) => out.extend(t),
                None => out.extend(std::iter::repeat(0).take(self.blocks_per_row)),
            }
        }
        out
    }

    /// Make the block holding `pos` exclusively writable for `row`,
    /// forking it copy-on-write if shared. In the serving flow this never
    /// forks (the invariant above); it exists so a violation surfaces as
    /// a counted fork — and as the rewind-safety mechanism for callers
    /// (the serving simulator, tests) that share blocks more aggressively.
    pub fn ensure_writable(&mut self, row: usize, pos: usize) -> Result<bool> {
        let bs = self.pool.block_size();
        let r = self
            .rows
            .get_mut(row)
            .and_then(|r| r.as_mut())
            .with_context(|| format!("kvcache: paged row {row} has no block table"))?;
        let j = pos / bs;
        ensure!(
            j < r.blocks.len(),
            "kvcache: position {pos} beyond row {row}'s {}-block table",
            r.blocks.len()
        );
        let id = r.blocks[j];
        if self.pool.refcount(id) <= 1 {
            return Ok(false);
        }
        r.blocks[j] = self.pool.cow(id)?;
        Ok(true)
    }

    /// Pin the resident full-block prefix of `tokens` (a hot system
    /// prompt) against cache-pressure reclaim; returns how many blocks.
    pub fn pin_prefix(&mut self, tokens: &[i32]) -> usize {
        let bs = self.pool.block_size();
        let run = self.index.lookup(bs, tokens);
        for &id in &run {
            // lint: allow(result, "pin of a block the index just returned cannot fail")
            let _ = self.pool.pin(id);
        }
        run.len()
    }

    /// Release every block reference the row holds (shared prefix blocks
    /// stay resident through the index's own reference). A row with no
    /// planned table is a no-op — abort paths call this unconditionally.
    pub fn evict_row(&mut self, row: usize) -> Result<()> {
        let Some(slot) = self.rows.get_mut(row) else {
            bail!("kvcache: paged row {row} out of range");
        };
        if let Some(r) = slot.take() {
            for id in r.blocks {
                self.pool.release(id)?;
            }
        }
        Ok(())
    }

    pub fn stats(&self) -> PagedStats {
        PagedStats {
            lookups: self.lookups,
            prefix_hits: self.prefix_hits,
            prefix_hit_tokens: self.prefix_hit_tokens,
            cow_copies: self.pool.cow_copies(),
            blocks_in_use: self.pool.blocks_in_use(),
            pool_blocks: self.pool.n_blocks(),
        }
    }

    #[cfg(test)]
    fn row_blocks(&self, row: usize) -> Option<Vec<usize>> {
        self.rows.get(row)?.as_ref().map(|r| r.blocks.clone())
    }

    #[cfg(test)]
    fn row_shared(&self, row: usize) -> Option<usize> {
        self.rows.get(row)?.as_ref().map(|r| r.shared)
    }
}

/// One occupied row's cache extent: `len` valid positions, of which the
/// first `admit` came from the admission prefill (the prompt — never
/// rewindable, a draft can only reject *generated* positions).
#[derive(Debug, Clone, Copy)]
struct RowSlot {
    len: usize,
    admit: usize,
}

/// Pure per-row cache bookkeeping: which rows hold a cache, and how many
/// positions of each row are valid. Kept separate from the sessions so the
/// lifecycle invariants are unit-testable without artifacts.
#[derive(Debug, Clone)]
pub struct CacheSlots {
    /// cached-position extent per row (None = free slot)
    rows: Vec<Option<RowSlot>>,
    seq: usize,
}

impl CacheSlots {
    pub fn new(batch: usize, seq: usize) -> CacheSlots {
        CacheSlots { rows: vec![None; batch], seq }
    }

    pub fn batch(&self) -> usize {
        self.rows.len()
    }

    /// Cached positions of an occupied row.
    pub fn len(&self, row: usize) -> Option<usize> {
        self.rows.get(row).copied().flatten().map(|r| r.len)
    }

    pub fn occupied(&self) -> usize {
        self.rows.iter().flatten().count()
    }

    /// Claim a free row for a prompt of `len` cached positions.
    pub fn admit(&mut self, row: usize, len: usize) -> Result<()> {
        let slot = self
            .rows
            .get_mut(row)
            .with_context(|| format!("kvcache: row {row} out of range"))?;
        ensure!(slot.is_none(), "kvcache: admit into occupied row {row}");
        ensure!(len >= 1, "kvcache: admit of empty prompt into row {row}");
        ensure!(
            len <= self.seq,
            "kvcache: prompt of {len} exceeds cache capacity {}",
            self.seq
        );
        *slot = Some(RowSlot { len, admit: len });
        Ok(())
    }

    /// Record a decode-step write at `pos`. Writes must land at the row
    /// frontier (`pos == len`, growing the cache) or rewrite the last
    /// cached position (`pos == len - 1`, the first step after admission);
    /// anything else would leave garbage gaps.
    pub fn advance(&mut self, row: usize, pos: usize) -> Result<()> {
        let slot = self
            .rows
            .get_mut(row)
            .with_context(|| format!("kvcache: row {row} out of range"))?
            .as_mut()
            .with_context(|| format!("kvcache: advance on free row {row}"))?;
        ensure!(
            pos + 1 == slot.len || pos == slot.len,
            "kvcache: write at {pos} away from row {row} frontier {}",
            slot.len
        );
        ensure!(pos < self.seq, "kvcache: write at {pos} beyond capacity {}", self.seq);
        slot.len = slot.len.max(pos + 1);
        Ok(())
    }

    /// Roll the row frontier back `n` positions — the rejected-draft path
    /// of speculative decoding. Purely logical, like `evict`: the K/V
    /// beyond the new frontier stay in the tensors as garbage, protected
    /// by the step/verify position masks (writes land at the frontier,
    /// attention never looks past the query position). Rewinding past the
    /// admission prefill is refused: prompt positions are never drafts.
    pub fn rewind(&mut self, row: usize, n: usize) -> Result<()> {
        let slot = self
            .rows
            .get_mut(row)
            .with_context(|| format!("kvcache: row {row} out of range"))?
            .as_mut()
            .with_context(|| format!("kvcache: rewind on free row {row}"))?;
        ensure!(
            slot.len - slot.admit >= n,
            "kvcache: rewind of {n} from row {row} frontier {} crosses its \
             admit length {}",
            slot.len,
            slot.admit
        );
        slot.len -= n;
        Ok(())
    }

    /// Free a row after `take`; the cache contents become garbage and are
    /// fully rewritten by the next admission.
    pub fn evict(&mut self, row: usize) -> Result<()> {
        let slot = self
            .rows
            .get_mut(row)
            .with_context(|| format!("kvcache: row {row} out of range"))?;
        ensure!(slot.is_some(), "kvcache: evict of free row {row}");
        *slot = None;
        Ok(())
    }
}

/// One row's feed into a [`KvDecoder::verify`] call: the frontier token
/// followed by the draft candidates (padded to the artifact's K+1 window),
/// the grid position of the frontier, and how many window tokens are
/// `live` — actually written and tracked (frontier + drafts that fit).
#[derive(Debug, Clone)]
pub struct VerifyFeed {
    pub tokens: Vec<i32>,
    pub pos: usize,
    pub live: usize,
}

/// The executable decode subsystem: the prefill and step sessions plus the
/// cache lifecycle. Constructed by [`crate::coordinator::generate::Generator`]
/// when the decode artifact pair is registered for its model.
pub struct KvDecoder {
    prefill: Session,
    step: Session,
    /// the speculative verification window (`decode_verify_*`), when that
    /// third artifact of the decode trio is registered
    verify: Option<Session>,
    /// chunked-prefill bucket sessions, ascending window length C, when
    /// the `decode_prefill_chunk_<model>_c<C>` ladder is registered
    chunks: Vec<(usize, Session)>,
    /// admissions route through the bucket ladder instead of the
    /// monolithic (1, S) prefill (on by default when a ladder loaded)
    chunked: bool,
    /// draft window size K of the verify artifact (tokens are (B, K+1))
    draft_k: Option<usize>,
    cache_names: Vec<String>,
    pub slots: CacheSlots,
    /// cumulative admission accounting (window tokens, padding waste)
    pub pstats: PrefillStats,
    /// block-pool + prefix-index bookkeeping when the decoder serves the
    /// paged artifact family (`decode_*_paged_*`, DESIGN.md §2f)
    paged: Option<PagedKv>,
    batch: usize,
    seq: usize,
    vocab: usize,
    /// gather input name when the pair serves a stacked adapter group
    adapter_in: Option<String>,
}

impl KvDecoder {
    /// Load the decode artifact pair for `model`; `Ok(None)` when the pair
    /// is absent (the caller falls back to full reforward). A *half*
    /// -registered pair is almost certainly an emission mistake — it also
    /// falls back, but loudly, naming the missing artifact.
    pub fn try_new(
        rt: &Runtime,
        model: &str,
        stores: &[&TensorStore],
    ) -> Result<Option<KvDecoder>> {
        Self::try_new_inner(rt, model, stores, false)
    }

    /// Load the *paged* decode family (`decode_prefill_paged_*` /
    /// `decode_step_paged_*` + optional verify/chunk siblings): pooled
    /// `(n_blocks, block, ...)` caches behind per-row block tables, with
    /// shared-prefix reuse across admissions. Same fallback contract as
    /// [`KvDecoder::try_new`].
    pub fn try_new_paged(
        rt: &Runtime,
        model: &str,
        stores: &[&TensorStore],
    ) -> Result<Option<KvDecoder>> {
        Self::try_new_inner(rt, model, stores, true)
    }

    fn try_new_inner(
        rt: &Runtime,
        model: &str,
        stores: &[&TensorStore],
        paged: bool,
    ) -> Result<Option<KvDecoder>> {
        let infix = if paged { "_paged" } else { "" };
        let pname = format!("decode_prefill{infix}_{model}");
        let sname = format!("decode_step{infix}_{model}");
        let (pa, sa) = match (rt.load(&pname), rt.load(&sname)) {
            (Ok(pa), Ok(sa)) => (pa, sa),
            (Ok(_), Err(_)) => {
                log::warn(format!(
                    "decode pair for '{model}' incomplete: '{pname}' is \
                     registered but '{sname}' is missing — falling back to \
                     full reforward"
                ));
                return Ok(None);
            }
            (Err(_), Ok(_)) => {
                log::warn(format!(
                    "decode pair for '{model}' incomplete: '{sname}' is \
                     registered but '{pname}' is missing — falling back to \
                     full reforward"
                ));
                return Ok(None);
            }
            (Err(_), Err(_)) => return Ok(None),
        };
        let (b, s) = (sa.meta.batch(), sa.meta.seq());
        ensure!(
            pa.meta.batch() == b && pa.meta.seq() == s,
            "decode pair grid mismatch: {pname} ({}, {}) vs {sname} ({b}, {s})",
            pa.meta.batch(),
            pa.meta.seq()
        );
        let cache_names = sa.meta.name_list("cache_names");
        ensure!(!cache_names.is_empty(), "{sname}: meta declares no cache_names");
        // slot donation moves raw buffers between the sessions, so the two
        // artifacts must declare bitwise-identical cache tensors
        for n in &cache_names {
            let ps = pa.meta.input_spec(n)?;
            let ss = sa.meta.input_spec(n)?;
            ensure!(
                ps.shape == ss.shape && ps.dtype == ss.dtype,
                "cache '{n}' differs between {pname} and {sname}"
            );
        }
        // the paged family declares its pool geometry in extra.paged and a
        // block_table input per artifact (the §2f contract, mirrored by
        // compile.meta_check); a family that fails the contract is an
        // emission bug — error out, never half-load
        let geom = if paged {
            let g = sa
                .meta
                .paged()
                .with_context(|| format!("{sname}: paged family declares no extra.paged"))?;
            ensure!(
                pa.meta.paged() == Some(g),
                "extra.paged differs between {pname} and {sname}"
            );
            ensure!(
                g.block_size >= 1 && s % g.block_size == 0,
                "{sname}: seq {s} is not a whole number of {}-slot blocks",
                g.block_size
            );
            let bpr = s / g.block_size;
            let st = sa.meta.input_spec("block_table")?;
            ensure!(
                st.shape == [b, bpr] && st.dtype == Dtype::I32,
                "{sname}: block_table {:?} is not int32 ({b}, {bpr})",
                st.shape
            );
            let pt = pa.meta.input_spec("block_table")?;
            ensure!(
                pt.shape == [bpr] && pt.dtype == Dtype::I32,
                "{pname}: block_table {:?} is not int32 ({bpr},)",
                pt.shape
            );
            for n in &cache_names {
                let ss = sa.meta.input_spec(n)?;
                ensure!(
                    ss.shape.len() >= 2
                        && ss.shape[0] == g.n_blocks
                        && ss.shape[1] == g.block_size,
                    "{sname}: cache '{n}' shape {:?} is not pooled ({}, {}, ...)",
                    ss.shape,
                    g.n_blocks,
                    g.block_size
                );
            }
            Some(g)
        } else {
            None
        };
        let vocab = sa.meta.config.vocab_size;
        // an adapter group must be declared by both halves identically:
        // the same registered slot serves admission and every step
        let pg = pa.meta.adapter_group()?;
        let sg = sa.meta.adapter_group()?;
        let adapter_in = match (&pg, &sg) {
            (Some(p), Some(s)) => {
                ensure!(
                    p.size == s.size && p.members == s.members && p.input == s.input,
                    "adapter group differs between {pname} and {sname}"
                );
                Some(s.input.clone())
            }
            (None, None) => None,
            _ => bail!("adapter group declared by only one of {pname}/{sname}"),
        };
        // the optional third artifact of the trio: the speculative verify
        // window. Its absence is fine (no spec path); a *defective* one —
        // wrong grid, caches or adapter group — falls back loudly, like
        // every other pair defect.
        let vname = format!("decode_verify{infix}_{model}");
        let (verify_art, draft_k) = match rt.load(&vname) {
            Err(_) => (None, None),
            Ok(va) => {
                let check = || -> Result<usize> {
                    ensure!(
                        va.meta.batch() == b && va.meta.seq() == s,
                        "verify grid ({}, {}) != decode grid ({b}, {s})",
                        va.meta.batch(),
                        va.meta.seq()
                    );
                    if let Some(g) = geom {
                        ensure!(
                            va.meta.paged() == Some(g),
                            "extra.paged differs between {vname} and {sname}"
                        );
                        let bt = va.meta.input_spec("block_table")?;
                        ensure!(
                            bt.shape == [b, s / g.block_size] && bt.dtype == Dtype::I32,
                            "{vname}: block_table {:?} is not int32 ({b}, {})",
                            bt.shape,
                            s / g.block_size
                        );
                    }
                    for n in &cache_names {
                        let vs = va.meta.input_spec(n)?;
                        let ss = sa.meta.input_spec(n)?;
                        ensure!(
                            vs.shape == ss.shape && vs.dtype == ss.dtype,
                            "cache '{n}' differs between {vname} and {sname}"
                        );
                    }
                    let vg = va.meta.adapter_group()?;
                    ensure!(
                        vg.as_ref().map(|g| (&g.input, g.size))
                            == sg.as_ref().map(|g| (&g.input, g.size)),
                        "adapter group differs between {vname} and {sname}"
                    );
                    let k = va
                        .meta
                        .draft_k()
                        .context("verify meta declares no draft_k")?;
                    ensure!(k >= 1, "draft_k must be >= 1");
                    let ts = va.meta.input_spec("tokens")?;
                    ensure!(
                        ts.shape == [b, k + 1],
                        "verify tokens shape {:?} is not (B, draft_k+1) = \
                         ({b}, {})",
                        ts.shape,
                        k + 1
                    );
                    Ok(k)
                };
                match check() {
                    Ok(k) => (Some(va), Some(k)),
                    Err(e) => {
                        log::warn(format!(
                            "decode trio for '{model}': '{vname}' is \
                             registered but defective ({e:#}) — serving \
                             without the speculative verify window"
                        ));
                        (None, None)
                    }
                }
            }
        };
        // the chunked-prefill ladder (DESIGN.md §2e): one (1, C) window
        // artifact per `chunk_ladder(s)` bucket, probed by the shared
        // formula. A missing bucket is fine (that size just isn't
        // served); a *defective* one is skipped loudly, like every other
        // family defect.
        let mut chunk_arts = vec![];
        for c in chunk_ladder(s) {
            let cname = format!("decode_prefill_chunk{infix}_{model}_c{c}");
            let Ok(ca) = rt.load(&cname) else { continue };
            let check = || -> Result<()> {
                ensure!(
                    ca.meta.batch() == b && ca.meta.seq() == s,
                    "chunk grid ({}, {}) != decode grid ({b}, {s})",
                    ca.meta.batch(),
                    ca.meta.seq()
                );
                let declared = ca
                    .meta
                    .chunk()
                    .context("chunk meta declares no extra.chunk")?;
                ensure!(
                    declared == c,
                    "extra.chunk {declared} != bucket {c} in the artifact name"
                );
                let ts = ca.meta.input_spec("tokens")?;
                ensure!(
                    ts.shape == [1, c],
                    "chunk tokens shape {:?} is not (1, {c})",
                    ts.shape
                );
                // the window-addressing inputs, mirroring the
                // compile.meta_check chunk rule — a bucket that would
                // only fail later at Session::set must be skipped now
                for scalar in ["start_pos", "last_pos"] {
                    let sp = ca.meta.input_spec(scalar)?;
                    ensure!(
                        sp.shape.is_empty() && sp.dtype == Dtype::I32,
                        "{scalar} is not a scalar int32 input"
                    );
                }
                // the row selection: dense windows scatter under a
                // row_onehot mask; paged windows address the row's own
                // blocks through a (S/block,) table instead
                match geom {
                    Some(g) => {
                        ensure!(
                            ca.meta.paged() == Some(g),
                            "extra.paged differs between {cname} and {sname}"
                        );
                        let bt = ca.meta.input_spec("block_table")?;
                        ensure!(
                            bt.shape == [s / g.block_size] && bt.dtype == Dtype::I32,
                            "block_table shape {:?} is not ({},)",
                            bt.shape,
                            s / g.block_size
                        );
                    }
                    None => {
                        let oh = ca.meta.input_spec("row_onehot")?;
                        ensure!(
                            oh.shape == [b] && oh.dtype == Dtype::F32,
                            "row_onehot shape {:?} is not ({b},)",
                            oh.shape
                        );
                    }
                }
                for n in &cache_names {
                    let cs = ca.meta.input_spec(n)?;
                    let ss = sa.meta.input_spec(n)?;
                    ensure!(
                        cs.shape == ss.shape && cs.dtype == ss.dtype,
                        "cache '{n}' differs between {cname} and {sname}"
                    );
                }
                let cg = ca.meta.adapter_group()?;
                ensure!(
                    cg.as_ref().map(|g| (&g.input, g.size))
                        == sg.as_ref().map(|g| (&g.input, g.size)),
                    "adapter group differs between {cname} and {sname}"
                );
                Ok(())
            };
            match check() {
                Ok(()) => chunk_arts.push((c, ca)),
                Err(e) => log::warn(format!(
                    "decode ladder for '{model}': '{cname}' is registered \
                     but defective ({e:#}) — skipping that bucket"
                )),
            }
        }
        let prefill = Session::new(rt, pa, stores)?;
        let step = Session::new(rt, sa, stores)?;
        let verify = verify_art
            .map(|va| Session::new(rt, va, stores))
            .transpose()?;
        let mut chunks = vec![];
        for (c, ca) in chunk_arts {
            // a bucket that probes clean but fails session construction
            // (e.g. misdeclared bindings) is skipped like any other
            // ladder defect — it must never take the healthy pair down
            match Session::new(rt, ca, stores) {
                Ok(sess) => chunks.push((c, sess)),
                Err(e) => log::warn(format!(
                    "decode ladder for '{model}': \
                     'decode_prefill_chunk{infix}_{model}_c{c}' failed to \
                     load ({e:#}) — skipping that bucket"
                )),
            }
        }
        let chunked = !chunks.is_empty();
        let paged_kv = geom
            .map(|g| PagedKv::new(g.n_blocks, g.block_size, b, s))
            .transpose()?;
        Ok(Some(KvDecoder {
            prefill,
            step,
            verify,
            chunks,
            chunked,
            draft_k,
            cache_names,
            slots: CacheSlots::new(b, s),
            pstats: PrefillStats::default(),
            paged: paged_kv,
            batch: b,
            seq: s,
            vocab,
            adapter_in,
        }))
    }

    /// Whether this decoder serves the paged artifact family.
    pub fn is_paged(&self) -> bool {
        self.paged.is_some()
    }

    /// Paged-decode counters (prefix hits, block utilization, CoW forks);
    /// `None` on a dense decoder.
    pub fn paged_stats(&self) -> Option<PagedStats> {
        self.paged.as_ref().map(|p| p.stats())
    }

    /// Adapter slots the pair's artifacts stack (group size), if any.
    pub fn adapter_capacity(&self) -> Option<usize> {
        self.step.group_size("adapter")
    }

    /// Stage one adapter slot's factors into every session of the family
    /// (uploaded at each session's next run; see `Session::put_group`).
    pub fn put_adapter(&mut self, ix: usize, weights: &TensorStore) -> Result<()> {
        self.prefill.put_group("adapter", ix, weights)?;
        if let Some(v) = self.verify.as_mut() {
            v.put_group("adapter", ix, weights)?;
        }
        for (_, sess) in self.chunks.iter_mut() {
            sess.put_group("adapter", ix, weights)?;
        }
        self.step.put_group("adapter", ix, weights)
    }

    /// Bucket lengths of the registered chunked-prefill ladder, ascending
    /// (empty = no chunk artifacts, monolithic admission only).
    pub fn ladder(&self) -> Vec<usize> {
        self.chunks.iter().map(|(c, _)| *c).collect()
    }

    /// Whether admissions route through the bucket ladder.
    pub fn chunked(&self) -> bool {
        self.chunked
    }

    /// Force admissions onto/off the bucket ladder (the §Perf A/B knob);
    /// turning it on without a registered ladder is refused.
    pub fn set_chunked(&mut self, on: bool) -> Result<()> {
        ensure!(
            !on || !self.chunks.is_empty(),
            "kvcache: no chunked-prefill ladder registered for this pair"
        );
        self.chunked = on;
        Ok(())
    }

    /// Draft window size of the registered verify artifact, if the decode
    /// trio is complete (`None` = prefill/step pair only, no spec path).
    pub fn verify_k(&self) -> Option<usize> {
        self.draft_k
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    pub fn seq_len(&self) -> usize {
        self.seq
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab
    }

    /// Admit a row: run the prefill artifact over its sequence, writing
    /// this row's cache while every other row's passes through untouched
    /// (mid-decode admission never perturbs in-flight rows), then donate
    /// the caches back into the step session. On a stacked-adapter pair,
    /// `adapter_ix` names the slot the row decodes under for its lifetime.
    pub fn admit(
        &mut self,
        rt: &Runtime,
        row: usize,
        seq: &[i32],
        adapter_ix: Option<i32>,
    ) -> Result<()> {
        ensure!(row < self.batch, "kvcache: admit into out-of-range row {row}");
        ensure!(
            !seq.is_empty() && seq.len() <= self.seq,
            "kvcache: prompt of {} tokens does not fit the (·, {}) cache",
            seq.len(),
            self.seq
        );
        let (b, s) = (self.batch, self.seq);
        // paged: plan a fully *private* table before staging — the
        // monolithic window rewrites every grid position of the row, so
        // resident prefix blocks must never be aliased into it
        if let Some(pk) = self.paged.as_mut() {
            pk.plan_admit(row, seq, s, false)?;
        }
        let Self { prefill, step, cache_names, adapter_in, paged, .. } = self;
        // stage the row inputs before touching the caches, so an invalid
        // input cannot strand them mid-handoff
        prefill.set(rt, "tokens", &Tensor::from_i32(&[1, s], pad_to(seq, s)))?;
        prefill.set(rt, "last_pos", &Tensor::from_i32(&[], vec![(seq.len() - 1) as i32]))?;
        match paged.as_ref() {
            Some(pk) => {
                let table = match pk.table_i32(row) {
                    Some(t) => t,
                    None => bail!("row {row} has no paged block table (plan_admit missing)"),
                };
                prefill.set(rt, "block_table", &Tensor::from_i32(&[table.len()], table))?;
            }
            None => {
                let mut onehot = vec![0.0f32; b];
                onehot[row] = 1.0;
                prefill.set(rt, "row_onehot", &Tensor::from_f32(&[b], onehot))?;
            }
        }
        match (adapter_in.as_deref(), adapter_ix) {
            (Some(name), ix) => {
                // an adapter-less admission on a stacked pair decodes
                // under slot 0's zero-init identity only if the caller
                // routes every row that way; the Generator enforces the
                // policy — here slot 0 is simply the default gather
                prefill.set(rt, name, &Tensor::from_i32(&[], vec![ix.unwrap_or(0)]))?;
            }
            (None, Some(_)) => {
                bail!("kvcache: adapter admission on a pair with no adapter group")
            }
            (None, None) => {}
        }
        // between calls the caches live in the step session; route them
        // through the prefill session for this admission
        step.donate_slots(prefill, cache_names)?;
        // on success the cache outputs rebind onto the prefill session's
        // own input slots; on failure the slots still hold the pre-run
        // caches — donate back either way so a failed admission leaves
        // every in-flight row's cache intact and the decoder usable
        let run = prefill.run(rt);
        prefill.donate_slots(step, cache_names)?;
        if let Err(e) = run {
            // a failed paged admission must not leak the planned blocks
            if let Some(pk) = self.paged.as_mut() {
                // lint: allow(result, "cleanup on the error path; `e` below is the root cause")
                let _ = pk.evict_row(row);
            }
            return Err(e);
        }
        self.pstats.prefill_tokens += s;
        self.pstats.padded_prefill_tokens += s - seq.len();
        self.pstats.chunks += 1;
        self.slots.admit(row, seq.len())?;
        if let Some(pk) = self.paged.as_mut() {
            pk.register(row, seq)?;
        }
        Ok(())
    }

    /// Run one prompt window through the `bucket` chunk session: `window`
    /// tokens land at grid positions start..start+window.len(), scattered
    /// into `row`'s cache while every other row — and every untouched
    /// slot of the row itself — passes through. Pure cache filling: the
    /// slots ledger only records the admission once the final window has
    /// been fed (see [`KvDecoder::admit_chunked`] and the budget-paced
    /// `Generator::prefill_tick`).
    pub fn prefill_chunk(
        &mut self,
        rt: &Runtime,
        row: usize,
        window: &[i32],
        start: usize,
        bucket: usize,
        adapter_ix: Option<i32>,
    ) -> Result<()> {
        ensure!(row < self.batch, "kvcache: chunk into out-of-range row {row}");
        ensure!(
            self.slots.len(row).is_none(),
            "kvcache: chunk into already-admitted row {row}"
        );
        ensure!(
            !window.is_empty() && window.len() <= bucket,
            "kvcache: window of {} tokens does not fit the {bucket}-token bucket",
            window.len()
        );
        ensure!(
            start + window.len() <= self.seq,
            "kvcache: window at {start}..{} overruns the (·, {}) cache",
            start + window.len(),
            self.seq
        );
        let b = self.batch;
        let Self { step, chunks, cache_names, adapter_in, pstats, paged, .. } = self;
        let sess = chunks
            .iter_mut()
            .find(|(c, _)| *c == bucket)
            .map(|(_, s)| s)
            .with_context(|| {
                format!("kvcache: no {bucket}-token chunk bucket registered")
            })?;
        // stage the window inputs before touching the caches, so an
        // invalid input cannot strand them mid-handoff
        sess.set(rt, "tokens", &Tensor::from_i32(&[1, bucket], pad_to(window, bucket)))?;
        sess.set(rt, "start_pos", &Tensor::from_i32(&[], vec![start as i32]))?;
        sess.set(rt, "last_pos", &Tensor::from_i32(&[], vec![(window.len() - 1) as i32]))?;
        match paged.as_ref() {
            Some(pk) => {
                let table = pk.table_i32(row).with_context(|| {
                    format!(
                        "kvcache: chunk into paged row {row} with no planned \
                         block table — admission_start must run first"
                    )
                })?;
                sess.set(rt, "block_table", &Tensor::from_i32(&[table.len()], table))?;
            }
            None => {
                let mut onehot = vec![0.0f32; b];
                onehot[row] = 1.0;
                sess.set(rt, "row_onehot", &Tensor::from_f32(&[b], onehot))?;
            }
        }
        match (adapter_in.as_deref(), adapter_ix) {
            (Some(name), ix) => {
                sess.set(rt, name, &Tensor::from_i32(&[], vec![ix.unwrap_or(0)]))?;
            }
            (None, Some(_)) => {
                bail!("kvcache: adapter admission on a pair with no adapter group")
            }
            (None, None) => {}
        }
        // caches hop step session -> chunk session -> back, exactly like
        // the monolithic admission routes them through prefill
        step.donate_slots(sess, cache_names)?;
        let run = sess.run(rt);
        sess.donate_slots(step, cache_names)?;
        run?;
        pstats.prefill_tokens += bucket;
        pstats.padded_prefill_tokens += bucket - window.len();
        pstats.chunks += 1;
        trace::emit(|| Event::PrefillWindow { row, start, bucket });
        Ok(())
    }

    /// Admit a row through the bucket ladder in one call: the prompt is
    /// fed as `chunk_plan` windows (see [`next_bucket`] — no more
    /// pad-to-S, per-prompt padding < the smallest bucket), then the
    /// slots ledger records the admission. The tick-paced variant that
    /// spreads the windows across scheduler ticks lives in
    /// `Generator::prefill_tick`.
    pub fn admit_chunked(
        &mut self,
        rt: &Runtime,
        row: usize,
        seq: &[i32],
        adapter_ix: Option<i32>,
    ) -> Result<()> {
        ensure!(
            !seq.is_empty() && seq.len() <= self.seq,
            "kvcache: prompt of {} tokens does not fit the (·, {}) cache",
            seq.len(),
            self.seq
        );
        let ladder = self.ladder();
        ensure!(!ladder.is_empty(), "kvcache: no chunked-prefill ladder registered");
        // paged: map the longest resident full-block prefix in by
        // reference and only window the remainder — the prefix-reuse win
        let resident = self.admission_start(row, seq)?;
        let mut failed = None;
        for (start, take, bucket) in chunk_plan(&ladder, seq.len() - resident) {
            let at = resident + start;
            if let Err(e) =
                self.prefill_chunk(rt, row, &seq[at..at + take], at, bucket, adapter_ix)
            {
                failed = Some(e);
                break;
            }
        }
        if let Some(e) = failed {
            self.abort_admission(row);
            return Err(e);
        }
        self.admission_finish(row, seq)
    }

    /// Begin an admission: on a paged decoder, plan the row's block table
    /// — reusing the longest resident shared prefix — and return how many
    /// prompt tokens are already cached (prefill windows start there). On
    /// a dense decoder this is a no-op returning 0. The tick-paced
    /// `Generator::prefill_tick` calls this before a row's first window;
    /// [`KvDecoder::admit_chunked`] wraps the whole lifecycle in one call.
    pub fn admission_start(&mut self, row: usize, seq: &[i32]) -> Result<usize> {
        ensure!(row < self.batch, "kvcache: admit into out-of-range row {row}");
        ensure!(
            !seq.is_empty() && seq.len() <= self.seq,
            "kvcache: prompt of {} tokens does not fit the (·, {}) cache",
            seq.len(),
            self.seq
        );
        match self.paged.as_mut() {
            // always-resident prefix capped below the final window, so
            // every admission runs at least one chunk (frontier logits)
            Some(pk) => pk.plan_admit(row, seq, self.seq, true),
            None => Ok(0),
        }
    }

    /// Complete an admission after its final window: record the row in
    /// the slots ledger and (paged) register its prompt's full blocks in
    /// the prefix index for future admissions to reuse.
    pub fn admission_finish(&mut self, row: usize, seq: &[i32]) -> Result<()> {
        self.slots.admit(row, seq.len())?;
        if let Some(pk) = self.paged.as_mut() {
            pk.register(row, seq)?;
        }
        Ok(())
    }

    /// Abandon a part-fed admission (a failed window): release the paged
    /// row's planned blocks so nothing leaks. A no-op for dense decoders,
    /// unplanned rows, and rows already recorded in the slots ledger
    /// (those are released through [`KvDecoder::evict`]).
    pub fn abort_admission(&mut self, row: usize) {
        if self.slots.len(row).is_some() {
            return;
        }
        if let Some(pk) = self.paged.as_mut() {
            // lint: allow(result, "abort of an unplanned row is a no-op Err by design")
            let _ = pk.evict_row(row);
        }
    }

    /// Admission through the bucket ladder when enabled, the monolithic
    /// (1, S) prefill otherwise.
    pub fn admit_auto(
        &mut self,
        rt: &Runtime,
        row: usize,
        seq: &[i32],
        adapter_ix: Option<i32>,
    ) -> Result<()> {
        if self.chunked {
            self.admit_chunked(rt, row, seq, adapter_ix)
        } else {
            self.admit(rt, row, seq, adapter_ix)
        }
    }

    /// One incremental step over the whole grid: feeds each occupied row's
    /// frontier `(token, pos)` (free — or mid-chunked-admission — rows
    /// ride along as off-grid dummies that write nothing) and returns
    /// next-token logits (B, V) on the host. On a stacked-adapter pair
    /// `adapter_ix` carries each row's slot (free rows gather slot 0,
    /// harmlessly).
    pub fn step(
        &mut self,
        rt: &Runtime,
        feeds: &[Option<(i32, usize)>],
        adapter_ix: Option<&[i32]>,
    ) -> Result<Tensor> {
        ensure!(
            feeds.len() == self.batch,
            "kvcache: {} feeds for batch {}",
            feeds.len(),
            self.batch
        );
        let mut toks = Vec::with_capacity(self.batch);
        let mut pos = Vec::with_capacity(self.batch);
        for (row, feed) in feeds.iter().enumerate() {
            match feed {
                Some((t, p)) => {
                    self.slots.advance(row, *p)?;
                    toks.push(*t);
                    pos.push(*p as i32);
                }
                None => {
                    ensure!(
                        self.slots.len(row).is_none(),
                        "kvcache: occupied row {row} fed no frontier token"
                    );
                    toks.push(PAD);
                    // off-grid: the (grid == pos) scatter is empty at
                    // pos == S, so a dummy row writes nothing. (The old
                    // pos-0 dummy relied on monolithic prefill rewriting
                    // the whole row at the next admission; a chunked
                    // admission only rewrites prompt positions, and a
                    // row mid-chunked-admission rides decode steps as a
                    // dummy — a pos-0 write would corrupt it.)
                    pos.push(self.seq as i32);
                }
            }
        }
        let batch = self.batch;
        // split-borrow so the gather-input name needn't be cloned on the
        // per-token hot path
        let Self { step, adapter_in, paged, .. } = self;
        step.set(rt, "tokens", &Tensor::from_i32(&[batch, 1], toks))?;
        step.set(rt, "pos", &Tensor::from_i32(&[batch], pos))?;
        if let Some(pk) = paged.as_ref() {
            let table = pk.grid_table_i32();
            step.set(
                rt,
                "block_table",
                &Tensor::from_i32(&[batch, pk.blocks_per_row()], table),
            )?;
        }
        match (adapter_in.as_deref(), adapter_ix) {
            (Some(name), ix) => {
                let ix = match ix {
                    Some(v) => {
                        ensure!(
                            v.len() == batch,
                            "kvcache: {} adapter feeds for batch {batch}",
                            v.len()
                        );
                        v.to_vec()
                    }
                    None => vec![0; batch],
                };
                step.set(rt, name, &Tensor::from_i32(&[batch], ix))?;
            }
            (None, Some(_)) => {
                bail!("kvcache: adapter feeds on a pair with no adapter group")
            }
            (None, None) => {}
        }
        let out = step.run(rt)?;
        let logits = out.get("logits")?;
        if logits.shape != [self.batch, self.vocab] {
            bail!(
                "kvcache: step logits shape {:?}, want {:?}",
                logits.shape,
                [self.batch, self.vocab]
            );
        }
        Ok(logits.clone())
    }

    /// One speculative verification pass over the whole grid: each `Some`
    /// row feeds its frontier token + drafts (a (K+1)-token window starting
    /// at `pos`, of which `live` are real) and gets logits at *every*
    /// window position back, (B, K+1, V) on the host. `None` rows ride
    /// along as off-grid dummies (`pos = S`): the artifact writes nothing
    /// for them, so even an occupied-but-idle row's cache stays intact.
    ///
    /// The caches hop step session → verify session → back, exactly like
    /// admission routes them through prefill; only `live` positions are
    /// recorded in the slots, so the caller rewinds rejected drafts with
    /// [`KvDecoder::rewind`] afterwards.
    pub fn verify(
        &mut self,
        rt: &Runtime,
        feeds: &[Option<VerifyFeed>],
        adapter_ix: Option<&[i32]>,
    ) -> Result<Tensor> {
        let k = self
            .draft_k
            .context("kvcache: verify on a decoder without the verify artifact")?;
        ensure!(
            feeds.len() == self.batch,
            "kvcache: {} verify feeds for batch {}",
            feeds.len(),
            self.batch
        );
        let mut toks = Vec::with_capacity(self.batch * (k + 1));
        let mut pos = Vec::with_capacity(self.batch);
        for (row, feed) in feeds.iter().enumerate() {
            match feed {
                Some(f) => {
                    ensure!(
                        f.tokens.len() == k + 1,
                        "kvcache: verify window of {} tokens, want {}",
                        f.tokens.len(),
                        k + 1
                    );
                    ensure!(
                        1 <= f.live && f.live <= k + 1,
                        "kvcache: verify live count {} outside 1..={}",
                        f.live,
                        k + 1
                    );
                    for t in 0..f.live {
                        self.slots.advance(row, f.pos + t)?;
                    }
                    toks.extend_from_slice(&f.tokens);
                    pos.push(f.pos as i32);
                }
                None => {
                    toks.extend(std::iter::repeat(PAD).take(k + 1));
                    pos.push(self.seq as i32); // off-grid: writes nothing
                }
            }
        }
        let batch = self.batch;
        let Self { step, verify, cache_names, adapter_in, paged, .. } = self;
        let Some(sess) = verify.as_mut() else {
            bail!("verify round without a verify session (draft_k = 0?)")
        };
        sess.set(rt, "tokens", &Tensor::from_i32(&[batch, k + 1], toks))?;
        sess.set(rt, "pos", &Tensor::from_i32(&[batch], pos))?;
        if let Some(pk) = paged.as_ref() {
            let table = pk.grid_table_i32();
            sess.set(
                rt,
                "block_table",
                &Tensor::from_i32(&[batch, pk.blocks_per_row()], table),
            )?;
        }
        match (adapter_in.as_deref(), adapter_ix) {
            (Some(name), ix) => {
                let ix = match ix {
                    Some(v) => {
                        ensure!(
                            v.len() == batch,
                            "kvcache: {} adapter feeds for batch {batch}",
                            v.len()
                        );
                        v.to_vec()
                    }
                    None => vec![0; batch],
                };
                sess.set(rt, name, &Tensor::from_i32(&[batch], ix))?;
            }
            (None, Some(_)) => {
                bail!("kvcache: adapter feeds on a trio with no adapter group")
            }
            (None, None) => {}
        }
        // between calls the caches live in the step session; route them
        // through the verify session for this pass — donate back whether
        // the run succeeded or not, so a failed verify leaves the decoder
        // usable (the slots above may have advanced; callers treat a
        // verify error as fatal for the affected generator anyway)
        step.donate_slots(sess, cache_names)?;
        let run = sess.run(rt);
        sess.donate_slots(step, cache_names)?;
        let out = run?;
        let logits = out.get("logits")?;
        if logits.shape != [batch, k + 1, self.vocab] {
            bail!(
                "kvcache: verify logits shape {:?}, want {:?}",
                logits.shape,
                [batch, k + 1, self.vocab]
            );
        }
        Ok(logits.clone())
    }

    /// Roll a row's frontier back `n` positions (rejected drafts). Logical
    /// only — see [`CacheSlots::rewind`] for the safety rules. On a paged
    /// decoder the row's blocks stay allocated (rewinds never cross the
    /// admission prefill, so the shared prefix is untouched, and the
    /// rolled-back positions live in the row's own private blocks — the
    /// re-decode overwrites them there, never needing a fork).
    pub fn rewind(&mut self, row: usize, n: usize) -> Result<()> {
        self.slots.rewind(row, n)?;
        if n > 0 {
            trace::emit(|| Event::Rewind { row, n });
        }
        Ok(())
    }

    /// Free a row's cache slot after `take`; a paged decoder also releases
    /// the row's block references (shared prefix blocks stay resident
    /// through the prefix index for future admissions to reuse).
    pub fn evict(&mut self, row: usize) -> Result<()> {
        self.slots.evict(row)?;
        if let Some(pk) = self.paged.as_mut() {
            pk.evict_row(row)?;
        }
        trace::emit(|| Event::Evict { row });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_admit_advance_evict_tracks_positions() {
        let mut cs = CacheSlots::new(2, 8);
        assert_eq!(cs.occupied(), 0);
        cs.admit(0, 3).unwrap();
        assert_eq!(cs.len(0), Some(3));
        // first step rewrites the frontier token's position (pos = len-1)
        cs.advance(0, 2).unwrap();
        assert_eq!(cs.len(0), Some(3));
        // subsequent steps grow the cache (pos = len)
        cs.advance(0, 3).unwrap();
        cs.advance(0, 4).unwrap();
        assert_eq!(cs.len(0), Some(5));
        cs.evict(0).unwrap();
        assert_eq!(cs.len(0), None);
        assert_eq!(cs.occupied(), 0);
    }

    #[test]
    fn admit_rejects_occupied_row_and_oversized_prompt() {
        let mut cs = CacheSlots::new(2, 8);
        cs.admit(1, 4).unwrap();
        assert!(cs.admit(1, 2).is_err(), "double admit");
        assert!(cs.admit(0, 9).is_err(), "prompt longer than capacity");
        assert!(cs.admit(0, 0).is_err(), "empty prompt");
        assert!(cs.admit(2, 1).is_err(), "row out of range");
    }

    #[test]
    fn advance_rejects_gaps_free_rows_and_overflow() {
        let mut cs = CacheSlots::new(1, 6);
        assert!(cs.advance(0, 0).is_err(), "free row");
        cs.admit(0, 2).unwrap();
        assert!(cs.advance(0, 0).is_err(), "behind the frontier");
        assert!(cs.advance(0, 3).is_err(), "gap past the frontier");
        cs.advance(0, 2).unwrap();
        cs.advance(0, 3).unwrap();
        cs.advance(0, 4).unwrap();
        cs.advance(0, 5).unwrap();
        assert_eq!(cs.len(0), Some(6));
        assert!(cs.advance(0, 6).is_err(), "write beyond capacity");
    }

    #[test]
    fn rewind_boundaries() {
        let mut cs = CacheSlots::new(2, 16);
        cs.admit(0, 4).unwrap();
        // grow the frontier by 3 generated positions: 4 -> 7
        cs.advance(0, 3).unwrap();
        for p in 4..7 {
            cs.advance(0, p).unwrap();
        }
        assert_eq!(cs.len(0), Some(7));
        // rewind 0 is a no-op
        cs.rewind(0, 0).unwrap();
        assert_eq!(cs.len(0), Some(7));
        // rewind within the generated tail
        cs.rewind(0, 2).unwrap();
        assert_eq!(cs.len(0), Some(5));
        // rewind exactly to the admit length is allowed
        cs.rewind(0, 1).unwrap();
        assert_eq!(cs.len(0), Some(4));
        // rewind past the admit length (into the prompt) is refused
        assert!(cs.rewind(0, 1).is_err(), "crossed the admit length");
        assert_eq!(cs.len(0), Some(4), "failed rewind must not move the frontier");
        // rewind on a free row / out-of-range row is refused
        assert!(cs.rewind(1, 0).is_err(), "free row");
        assert!(cs.rewind(2, 0).is_err(), "row out of range");
        // rewind on an evicted row is refused
        cs.evict(0).unwrap();
        assert!(cs.rewind(0, 0).is_err(), "evicted row");
    }

    #[test]
    fn rewind_then_advance_rewrites_the_new_frontier() {
        // after a rejection the next write lands at the rolled-back
        // frontier (pos == len), exactly like a normal growth step
        let mut cs = CacheSlots::new(1, 16);
        cs.admit(0, 3).unwrap();
        for p in 3..8 {
            cs.advance(0, p).unwrap();
        }
        cs.rewind(0, 4).unwrap();
        assert_eq!(cs.len(0), Some(4));
        assert!(cs.advance(0, 6).is_err(), "gap past the rolled-back frontier");
        cs.advance(0, 4).unwrap();
        cs.advance(0, 5).unwrap();
        assert_eq!(cs.len(0), Some(6));
    }

    #[test]
    fn recycling_after_mid_stream_rejection_starts_from_the_new_prompt() {
        // a row evicted right after a rewind (mid-stream rejection, then
        // the request finished) re-admits cleanly: the new occupant's
        // admit length, not the old frontier, bounds future rewinds
        let mut cs = CacheSlots::new(1, 16);
        cs.admit(0, 6).unwrap();
        for p in 6..10 {
            cs.advance(0, p).unwrap();
        }
        cs.rewind(0, 3).unwrap();
        cs.evict(0).unwrap();
        cs.admit(0, 2).unwrap();
        assert_eq!(cs.len(0), Some(2));
        cs.advance(0, 2).unwrap();
        cs.rewind(0, 1).unwrap();
        assert_eq!(cs.len(0), Some(2));
        assert!(cs.rewind(0, 1).is_err(), "old admit length leaked into the row");
    }

    #[test]
    fn chunk_ladder_mirrors_the_aot_formula() {
        // keep in lockstep with aot.chunk_ladder (test_aot.py asserts the
        // same table on the python side)
        assert_eq!(chunk_ladder(8), vec![8]);
        assert_eq!(chunk_ladder(16), vec![16]);
        assert_eq!(chunk_ladder(32), vec![16, 32]);
        assert_eq!(chunk_ladder(64), vec![16, 64]);
        assert_eq!(chunk_ladder(128), vec![16, 64, 128]);
    }

    #[test]
    fn next_bucket_prefers_low_padding_then_funded_then_forced() {
        let ladder = [16, 64, 128];
        // the covering bucket when its padding beats the smallest bucket
        assert_eq!(next_bucket(&ladder, 10, 1000, false), Some(16));
        assert_eq!(next_bucket(&ladder, 16, 1000, false), Some(16));
        assert_eq!(next_bucket(&ladder, 60, 1000, false), Some(64));
        assert_eq!(next_bucket(&ladder, 128, 1000, false), Some(128));
        // a covering bucket that would pad >= ladder[0] loses to a full
        // window split (17 -> 16 + 16, padded 15, not a 64/47-pad window)
        assert_eq!(next_bucket(&ladder, 17, 1000, false), Some(16));
        assert_eq!(next_bucket(&ladder, 70, 1000, false), Some(64));
        // covering bucket over budget: the largest funded full window
        assert_eq!(next_bucket(&ladder, 100, 64, false), Some(64));
        assert_eq!(next_bucket(&ladder, 100, 63, false), Some(16));
        assert_eq!(next_bucket(&ladder, 20, 16, false), Some(16));
        // nothing funded: None, unless forced (the per-tick progress
        // guarantee), which takes the covering (or smallest) bucket
        assert_eq!(next_bucket(&ladder, 100, 8, false), None);
        assert_eq!(next_bucket(&ladder, 100, 8, true), Some(16));
        assert_eq!(next_bucket(&ladder, 10, 0, true), Some(16));
    }

    #[test]
    fn chunk_plan_covers_the_prompt_without_pad_to_grid() {
        // short prompt: one right-sized window
        assert_eq!(chunk_plan(&[16, 64], 5), vec![(0, 5, 16)]);
        // exact bucket fit
        assert_eq!(chunk_plan(&[16, 64], 16), vec![(0, 16, 16)]);
        // between buckets: full windows + a small tail, never a
        // pad-heavy covering window
        assert_eq!(chunk_plan(&[16, 64], 20), vec![(0, 16, 16), (16, 4, 16)]);
        assert_eq!(chunk_plan(&[16, 64], 60), vec![(0, 60, 64)]);
        assert_eq!(chunk_plan(&[16, 64], 64), vec![(0, 64, 64)]);
        // a ladder without a covering bucket splits into windows
        assert_eq!(chunk_plan(&[8], 20), vec![(0, 8, 8), (8, 8, 8), (16, 4, 8)]);
        // plans tile the prompt exactly, padding < the smallest bucket
        for len in 1..40 {
            let plan = chunk_plan(&[8, 32], len);
            let mut at = 0;
            let mut windows = 0;
            for &(start, take, bucket) in &plan {
                assert_eq!(start, at);
                assert!(take <= bucket);
                at += take;
                windows += bucket;
            }
            assert_eq!(at, len);
            assert!(windows - len < 8, "len {len} padded {}", windows - len);
        }
    }

    #[test]
    fn prefill_stats_merge_sums_counters() {
        let a = PrefillStats { prefill_tokens: 64, padded_prefill_tokens: 10, chunks: 2 };
        let b = PrefillStats { prefill_tokens: 16, padded_prefill_tokens: 3, chunks: 1 };
        assert_eq!(
            a.merge(b),
            PrefillStats { prefill_tokens: 80, padded_prefill_tokens: 13, chunks: 3 }
        );
    }

    #[test]
    fn recycling_a_row_requires_evict_then_admit() {
        let mut cs = CacheSlots::new(1, 8);
        cs.admit(0, 5).unwrap();
        assert!(cs.evict(0).is_ok());
        assert!(cs.evict(0).is_err(), "double evict");
        // the recycled row starts from the new prompt's length, not the
        // old frontier
        cs.admit(0, 2).unwrap();
        assert_eq!(cs.len(0), Some(2));
    }

    // ---- paged KV: block pool / prefix index / per-row tables (§2f) ----

    #[test]
    fn block_pool_refcounted_alloc_release() {
        let mut p = BlockPool::new(3, 8).unwrap();
        assert_eq!((p.n_blocks(), p.block_size(), p.free_blocks()), (3, 8, 3));
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(p.blocks_in_use(), 2);
        assert_eq!(p.refcount(a), 1);
        // a second reference keeps the block allocated across one release
        p.retain(a).unwrap();
        assert_eq!(p.refcount(a), 2);
        p.release(a).unwrap();
        assert_eq!((p.refcount(a), p.blocks_in_use()), (1, 2));
        // the final release returns it to the free list
        p.release(a).unwrap();
        assert_eq!((p.refcount(a), p.blocks_in_use()), (0, 1));
        assert!(p.release(a).is_err(), "release of a free block");
        assert!(p.retain(a).is_err(), "retain of a free block");
        // exhaustion: 2 remaining (one freed + one never taken), then None
        assert!(p.alloc().is_some() && p.alloc().is_some());
        assert_eq!(p.alloc(), None);
    }

    #[test]
    fn block_pool_eviction_refuses_pinned_blocks() {
        let mut p = BlockPool::new(2, 4).unwrap();
        let a = p.alloc().unwrap();
        p.pin(a).unwrap();
        assert!(p.is_pinned(a));
        assert!(p.evict(a).is_err(), "pinned block must survive eviction");
        assert_eq!(p.refcount(a), 1, "failed eviction must not drop the block");
        p.unpin(a).unwrap();
        p.evict(a).unwrap();
        assert_eq!(p.refcount(a), 0);
        // an evicted block is reallocatable, and the pin never leaks into
        // the next owner
        let b = p.alloc().unwrap();
        let c = p.alloc().unwrap();
        assert!(!p.is_pinned(b) && !p.is_pinned(c));
        assert!(p.evict(2).is_err(), "out-of-range block");
    }

    #[test]
    fn block_pool_cow_forks_only_shared_blocks() {
        let mut p = BlockPool::new(2, 4).unwrap();
        let a = p.alloc().unwrap();
        // exclusive: writable in place, no fork
        assert_eq!(p.cow(a).unwrap(), a);
        assert_eq!(p.cow_copies(), 0);
        // shared: the caller's reference moves to a fresh block
        p.retain(a).unwrap();
        let forked = p.cow(a).unwrap();
        assert_ne!(forked, a);
        assert_eq!(p.refcount(a), 1, "the other holder keeps the original");
        assert_eq!(p.refcount(forked), 1);
        assert_eq!(p.cow_copies(), 1);
        // a fork that needs a block the pool cannot supply errors and
        // leaves the share intact
        p.retain(a).unwrap();
        assert!(p.cow(a).is_err(), "pool exhausted");
        assert_eq!(p.refcount(a), 2);
    }

    #[test]
    fn prefix_index_maps_longest_resident_run() {
        let mut pool = BlockPool::new(8, 4).unwrap();
        let mut ix = PrefixIndex::new();
        let toks: Vec<i32> = (0..12).collect();
        let blocks = vec![
            pool.alloc().unwrap(),
            pool.alloc().unwrap(),
            pool.alloc().unwrap(),
        ];
        ix.insert(&mut pool, &toks, &blocks).unwrap();
        assert_eq!(ix.len(), 3);
        // the index holds its own reference on every registered block
        assert!(blocks.iter().all(|&b| pool.refcount(b) == 2));
        assert_eq!(ix.lookup(4, &toks), blocks);
        // a shorter prompt maps its own full blocks only
        assert_eq!(ix.lookup(4, &toks[..8]), blocks[..2].to_vec());
        // a partial tail never matches (full blocks only)
        assert_eq!(ix.lookup(4, &toks[..11]), blocks[..2].to_vec());
        // divergence after the first block maps just that block
        let mut fork = toks.clone();
        fork[5] = 99;
        assert_eq!(ix.lookup(4, &fork), blocks[..1].to_vec());
        // totally different prompt: no resident prefix
        assert!(ix.lookup(4, &[7, 7, 7, 7]).is_empty());
    }

    #[test]
    fn prefix_hash_collision_falls_back_to_full_prefill() {
        // same hash, different tokens: the stored-token comparison stops
        // the walk, so admission prefills from position 0 instead of
        // trusting an aliased block
        let mut pool = BlockPool::new(4, 4).unwrap();
        let mut ix = PrefixIndex::new();
        let toks: Vec<i32> = vec![1, 2, 3, 4];
        let planted = pool.alloc().unwrap();
        let h = prefix_chunk_hash(0, &toks);
        ix.inject(h, vec![9, 9, 9, 9], planted);
        assert!(
            ix.lookup(4, &toks).is_empty(),
            "colliding entry must never be taken as resident"
        );
        // the planted tokens hash differently, so they miss too — the
        // aliased block is unreachable rather than mis-served
        assert!(ix.lookup(4, &[9, 9, 9, 9]).is_empty());
    }

    #[test]
    fn prefix_index_reclaims_cold_index_only_blocks() {
        let mut pool = BlockPool::new(2, 4).unwrap();
        let mut ix = PrefixIndex::new();
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        ix.insert(&mut pool, &[1, 2, 3, 4], &[a]).unwrap();
        ix.insert(&mut pool, &[5, 6, 7, 8], &[b]).unwrap();
        // both blocks still row-held: nothing is index-only, nothing frees
        assert_eq!(ix.reclaim(&mut pool, 1), 0);
        // drop the row references; `a` is older (colder) than `b`
        pool.release(a).unwrap();
        pool.release(b).unwrap();
        // a lookup bumps `a`, making `b` the LRU victim
        assert_eq!(ix.lookup(4, &[1, 2, 3, 4]), vec![a]);
        assert_eq!(ix.reclaim(&mut pool, 1), 1);
        assert_eq!(ix.len(), 1);
        assert_eq!(pool.refcount(b), 0, "cold entry released its block");
        assert_eq!(pool.refcount(a), 1, "hot entry survived");
        // pinned index-only blocks are not reclaim candidates
        pool.pin(a).unwrap();
        assert_eq!(ix.reclaim(&mut pool, 1), 0);
    }

    #[test]
    fn paged_admission_shares_resident_prefix_blocks() {
        // pool of 16 × 4-slot blocks over a 32-slot grid, 2 rows; the sim
        // capacity model passes need-based lengths, exercised here
        let mut pk = PagedKv::new(16, 4, 2, 32).unwrap();
        let toks: Vec<i32> = (100..112).collect(); // 12 tokens = 3 blocks
        assert_eq!(pk.plan_admit(0, &toks, 12, true).unwrap(), 0, "cold start");
        pk.register(0, &toks).unwrap();
        // only blocks strictly below the frontier are indexed: 12 tokens
        // → (12-1)/4 = 2 full blocks, never the frontier block
        assert_eq!(pk.index.len(), 2);
        // an identical prompt maps both resident blocks (8 tokens skipped)
        assert_eq!(pk.plan_admit(1, &toks, 12, true).unwrap(), 8);
        assert_eq!(pk.row_shared(1), Some(2));
        let (r0, r1) = (pk.row_blocks(0).unwrap(), pk.row_blocks(1).unwrap());
        assert_eq!(r0[..2], r1[..2], "shared physical prefix");
        assert_ne!(r0[2], r1[2], "private frontier block");
        // shared blocks: row0 + row1 + index = 3 references
        assert_eq!(pk.pool().refcount(r0[0]), 3);
        let st = pk.stats();
        assert_eq!((st.lookups, st.prefix_hits, st.prefix_hit_tokens), (2, 1, 8));
        assert_eq!(st.blocks_in_use, 4); // 3 (row0) + 1 private (row1)
        assert_eq!(st.cow_copies, 0);
        // eviction keeps the prefix resident through the index reference
        pk.evict_row(0).unwrap();
        assert_eq!(pk.pool().refcount(r0[0]), 2);
        pk.evict_row(1).unwrap();
        assert_eq!(pk.pool().refcount(r0[0]), 1, "index keeps the prefix warm");
        assert_eq!(pk.stats().blocks_in_use, 2);
    }

    #[test]
    fn paged_tables_pad_to_grid_and_feed_zero_for_free_rows() {
        let mut pk = PagedKv::new(8, 4, 2, 16).unwrap(); // 4 blocks/row
        let toks: Vec<i32> = (0..6).collect();
        pk.plan_admit(0, &toks, 6, true).unwrap(); // 2 blocks planned
        let t = pk.table_i32(0).unwrap();
        assert_eq!(t.len(), 4, "padded to S/block");
        let grid = pk.grid_table_i32();
        assert_eq!(grid.len(), 8);
        assert_eq!(&grid[..4], &t[..]);
        assert_eq!(&grid[4..], &[0, 0, 0, 0], "free row feeds zeros");
    }

    #[test]
    fn paged_cow_under_speculative_rewind_forks_shared_block() {
        // Two rows share prefix blocks; one rewinds past rejected drafts
        // and a (hypothetical) write lands inside the shared run. The
        // serving flow never does this — ensure_writable is the enforced
        // escape hatch: the block forks, the write stays private, and the
        // fork is counted instead of silently corrupting the other row.
        let mut pk = PagedKv::new(16, 4, 2, 32).unwrap();
        let toks: Vec<i32> = (0..9).collect(); // 2 full blocks + frontier
        pk.plan_admit(0, &toks, 32, true).unwrap();
        pk.register(0, &toks).unwrap();
        pk.plan_admit(1, &toks, 32, true).unwrap(); // shares blocks 0..2
        let before = pk.row_blocks(1).unwrap();
        assert_eq!(pk.row_shared(1), Some(2));
        // a write into the private tail never forks
        assert!(!pk.ensure_writable(1, 8).unwrap());
        // a write into the shared prefix forks exactly that block
        assert!(pk.ensure_writable(1, 2).unwrap());
        let after = pk.row_blocks(1).unwrap();
        assert_ne!(after[0], before[0], "row 1 moved onto a private fork");
        assert_eq!(
            pk.row_blocks(0).unwrap()[0],
            before[0],
            "row 0 keeps the original block"
        );
        assert_eq!(pk.stats().cow_copies, 1);
        // now exclusive: a second write is in place
        assert!(!pk.ensure_writable(1, 2).unwrap());
        assert_eq!(pk.stats().cow_copies, 1);
    }

    #[test]
    fn paged_pool_pressure_reclaims_then_errors_clean() {
        // 4-block pool, 4-slot blocks, 16-slot grid: one full-grid row
        // uses the whole pool
        let mut pk = PagedKv::new(4, 4, 2, 16).unwrap();
        let t0: Vec<i32> = (0..16).collect();
        pk.plan_admit(0, &t0, 16, true).unwrap();
        pk.register(0, &t0).unwrap();
        pk.evict_row(0).unwrap();
        // 3 blocks are index-held, 1 free; a cold-prompt admission must
        // reclaim the index blocks to fit
        let t1: Vec<i32> = (100..116).collect();
        assert_eq!(pk.plan_admit(1, &t1, 16, true).unwrap(), 0);
        assert_eq!(pk.stats().blocks_in_use, 4);
        // and with the pool fully row-held, a further admission fails
        // without leaking its partial allocation
        let used = pk.stats().blocks_in_use;
        assert!(pk.plan_admit(0, &t0, 16, true).is_err());
        assert_eq!(pk.stats().blocks_in_use, used, "failed plan released refs");
        assert!(pk.table_i32(0).is_none(), "failed plan leaves the row free");
    }

    #[test]
    fn paged_pin_prefix_shields_hot_blocks_from_reclaim() {
        let mut pk = PagedKv::new(4, 4, 2, 16).unwrap();
        let sys: Vec<i32> = (0..12).collect();
        pk.plan_admit(0, &sys, 12, true).unwrap();
        pk.register(0, &sys).unwrap();
        pk.evict_row(0).unwrap();
        assert_eq!(pk.pin_prefix(&sys), 2, "both indexed blocks pinned");
        // a full-grid admission cannot reclaim the pinned prefix: only
        // 2 free blocks remain for a 4-block need
        let cold: Vec<i32> = (50..66).collect();
        assert!(pk.plan_admit(1, &cold, 16, true).is_err());
        // the pinned prefix is still resident and mappable
        assert_eq!(pk.plan_admit(1, &sys, 12, true).unwrap(), 8);
    }
}

//! First-class adapter lifecycle: the `AdapterStore`.
//!
//! LoRAM's product is a *recovered* low-rank adapter applied to the frozen
//! large model at inference (paper §3, R(·)). In the canonical deployment
//! one frozen base serves many cheap task adapters — each produced by a
//! LoRAM run over a different pruning strategy or task — selectable per
//! request. The store owns that lifecycle end to end:
//!
//! * **disk**: recovered adapters persist as `.lmck` checkpoints in an
//!   adapter directory (`pipeline` exports into it right after recovery);
//! * **slots**: the compiled stacked artifact has a fixed adapter
//!   capacity (its meta's adapter slot group, DESIGN.md §2c); `register`
//!   claims a slot and yields the [`AdapterId`] requests route by;
//! * **ref-counting**: every in-flight row holds a reference
//!   (`acquire`/`release`), and `evict` refuses to free a pinned slot —
//!   swapping an adapter out never yanks it from under a decoding row;
//! * **dirty tracking**: freshly registered slots queue for upload;
//!   `drain_dirty` hands them to the engine, which stages them into its
//!   sessions via `Session::put_group` (re-uploading only what changed).
//!
//! Pure bookkeeping + file I/O: no sessions, no PJRT — fully unit-tested
//! without artifacts.


// The static mirror of this policy is `tools/loramlint` (panic-surface
// pass, ratcheted in baseline.json); `warn` until the remaining sites
// burn down, then promote to `deny` as serve.rs/kvcache.rs already did.
#![cfg_attr(
    not(test),
    warn(clippy::unwrap_used, clippy::expect_used, clippy::panic, clippy::unreachable)
)]
#![cfg_attr(not(test), warn(clippy::indexing_slicing))]

use crate::tensor::TensorStore;
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeSet;
use std::fmt;
use std::path::{Path, PathBuf};

/// Handle to a registered adapter: its slot index in the stacked
/// artifact's adapter group plus a per-slot generation. Requests carry
/// this; the engine feeds the slot index as the artifact's `adapter_ix`
/// gather input. The generation defeats ABA reuse: a handle issued before
/// a slot was evicted and re-registered no longer resolves, so a stale id
/// (e.g. in a queued request) errors instead of silently decoding under
/// the replacement adapter.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct AdapterId {
    slot: usize,
    gen: u32,
}

impl AdapterId {
    /// Slot index in the stacked artifact (the `adapter_ix` gather value).
    pub fn ix(self) -> usize {
        self.slot
    }

    /// First-generation handle for a slot — for simulators and scheduler
    /// tests that route without a store. Store-issued handles come from
    /// [`AdapterStore::register`] and match this only for a slot's first
    /// occupant.
    pub fn for_slot(slot: usize) -> AdapterId {
        AdapterId { slot, gen: 0 }
    }
}

impl fmt::Display for AdapterId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "a{}", self.slot)
    }
}

/// The `logits_<base>_a<N>` entry with the *largest* capacity N in an
/// artifact name list — the stacked multi-adapter serving artifact for a
/// base model (the one naming rule, shared by the CLI and the experiment
/// runners; largest-N keeps the choice deterministic when several
/// capacities are registered, instead of depending on manifest order).
pub fn stacked_logits_artifact(names: &[String], base: &str) -> Option<String> {
    let prefix = format!("logits_{base}_a");
    names
        .iter()
        .filter_map(|n| {
            let cap: usize = n.strip_prefix(&prefix)?.parse().ok()?;
            Some((cap, n))
        })
        .max_by_key(|(cap, _)| *cap)
        .map(|(_, n)| n.clone())
}

struct Entry {
    name: String,
    weights: TensorStore,
    refs: usize,
}

/// Registry of live adapters for one serving deployment (see module docs).
pub struct AdapterStore {
    dir: Option<PathBuf>,
    slots: Vec<Option<Entry>>,
    /// per-slot generation, bumped on evict so recycled slots issue fresh
    /// handles and stale ones stop resolving
    gens: Vec<u32>,
    dirty: BTreeSet<usize>,
}

impl AdapterStore {
    /// In-memory store with `capacity` slots (the stacked artifact's
    /// adapter-group size).
    pub fn new(capacity: usize) -> AdapterStore {
        AdapterStore {
            dir: None,
            slots: (0..capacity).map(|_| None).collect(),
            gens: vec![0; capacity],
            dirty: BTreeSet::new(),
        }
    }

    /// Store backed by an adapter directory of `.lmck` checkpoints.
    pub fn with_dir(dir: impl Into<PathBuf>, capacity: usize) -> AdapterStore {
        AdapterStore { dir: Some(dir.into()), ..AdapterStore::new(capacity) }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Occupied slots.
    pub fn registered(&self) -> usize {
        self.slots.iter().flatten().count()
    }

    /// Checkpoint path of adapter `name` under `dir`.
    pub fn path(dir: &Path, name: &str) -> PathBuf {
        dir.join(format!("{name}.lmck"))
    }

    /// Persist a recovered adapter — the export the pipeline runs right
    /// after R(·). Returns the written path.
    pub fn save(dir: &Path, name: &str, weights: &TensorStore) -> Result<PathBuf> {
        ensure!(!name.is_empty(), "adapter name must not be empty");
        let p = Self::path(dir, name);
        weights.save(&p).with_context(|| format!("save adapter '{name}'"))?;
        Ok(p)
    }

    /// Adapter names available in a directory, sorted.
    pub fn list(dir: &Path) -> Result<Vec<String>> {
        let mut names = vec![];
        for e in std::fs::read_dir(dir).with_context(|| format!("read {}", dir.display()))? {
            let p = e?.path();
            if p.extension().and_then(|x| x.to_str()) == Some("lmck") {
                if let Some(stem) = p.file_stem().and_then(|x| x.to_str()) {
                    names.push(stem.to_string());
                }
            }
        }
        names.sort();
        Ok(names)
    }

    /// Claim a free slot for `weights`. Errors when the name is already
    /// registered or every slot is occupied (evict one first — occupied
    /// slots are never silently recycled, a pinned adapter must keep
    /// serving its in-flight rows).
    pub fn register(&mut self, name: &str, weights: TensorStore) -> Result<AdapterId> {
        ensure!(!name.is_empty(), "adapter name must not be empty");
        if let Some(id) = self.lookup(name) {
            bail!("adapter '{name}' already registered as {id}");
        }
        let Some(ix) = self.slots.iter().position(|s| s.is_none()) else {
            bail!(
                "no free adapter slot ({} of {} in use); evict one first",
                self.registered(),
                self.capacity()
            );
        };
        self.slots[ix] = Some(Entry { name: name.to_string(), weights, refs: 0 });
        self.dirty.insert(ix);
        Ok(self.id_at(ix))
    }

    /// Register an adapter from this store's directory.
    pub fn register_from_disk(&mut self, name: &str) -> Result<AdapterId> {
        let dir = self.dir.clone().context("adapter store has no directory")?;
        let weights = TensorStore::load(&Self::path(&dir, name))
            .with_context(|| format!("load adapter '{name}'"))?;
        self.register(name, weights)
    }

    /// Free a slot. Refuses while any in-flight row still references it.
    /// The slot's generation bumps, so every outstanding handle to the
    /// evicted adapter — including ones sitting in a request queue — goes
    /// stale instead of resolving to the slot's next occupant.
    pub fn evict(&mut self, id: AdapterId) -> Result<()> {
        let slot = self.entry_mut(id)?;
        ensure!(
            slot.refs == 0,
            "adapter {id} ('{}') has {} in-flight rows",
            slot.name,
            slot.refs
        );
        self.slots[id.slot] = None;
        self.gens[id.slot] += 1;
        // the stale stack row needs no re-upload: nothing routes to it
        // until the next register, which re-marks the slot dirty
        self.dirty.remove(&id.slot);
        Ok(())
    }

    pub fn lookup(&self, name: &str) -> Option<AdapterId> {
        self.slots
            .iter()
            .position(|s| s.as_ref().map_or(false, |e| e.name == name))
            .map(|ix| self.id_at(ix))
    }

    /// Registered ids, in slot order.
    pub fn ids(&self) -> Vec<AdapterId> {
        (0..self.slots.len())
            .filter(|&i| self.slots[i].is_some())
            .map(|ix| self.id_at(ix))
            .collect()
    }

    pub fn name(&self, id: AdapterId) -> Option<&str> {
        self.entry(id).map(|e| e.name.as_str())
    }

    pub fn weights(&self, id: AdapterId) -> Result<&TensorStore> {
        self.entry(id)
            .map(|e| &e.weights)
            .with_context(|| format!("adapter {id} is not registered (stale or evicted handle)"))
    }

    pub fn refs(&self, id: AdapterId) -> usize {
        self.entry(id).map_or(0, |e| e.refs)
    }

    /// Pin an adapter for one in-flight row (admission).
    pub fn acquire(&mut self, id: AdapterId) -> Result<()> {
        self.entry_mut(id)?.refs += 1;
        Ok(())
    }

    /// Drop one row's pin (row taken/evicted).
    pub fn release(&mut self, id: AdapterId) -> Result<()> {
        let e = self.entry_mut(id)?;
        ensure!(e.refs > 0, "adapter {id} released more times than acquired");
        e.refs -= 1;
        Ok(())
    }

    /// Slots registered since the last drain, i.e. whose stacked rows the
    /// engine must re-upload (`Session::put_group`).
    pub fn drain_dirty(&mut self) -> Vec<AdapterId> {
        let dirty = std::mem::take(&mut self.dirty);
        dirty.into_iter().map(|ix| self.id_at(ix)).collect()
    }

    /// Current-generation handle for an occupied-or-free slot index.
    fn id_at(&self, ix: usize) -> AdapterId {
        AdapterId { slot: ix, gen: self.gens[ix] }
    }

    /// Gen-checked entry lookup: `None` for free slots AND stale handles.
    fn entry(&self, id: AdapterId) -> Option<&Entry> {
        if self.gens.get(id.slot) != Some(&id.gen) {
            return None;
        }
        self.slots.get(id.slot)?.as_ref()
    }

    fn entry_mut(&mut self, id: AdapterId) -> Result<&mut Entry> {
        if self.gens.get(id.slot) != Some(&id.gen) {
            bail!("adapter {id} is not registered (stale or evicted handle)");
        }
        self.slots
            .get_mut(id.slot)
            .and_then(|s| s.as_mut())
            .with_context(|| format!("adapter {id} is not registered"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn weights(v: f32) -> TensorStore {
        let mut s = TensorStore::new();
        s.insert("l0.wq.lora_a", Tensor::from_f32(&[2, 2], vec![v; 4]));
        s
    }

    #[test]
    fn register_evict_lifecycle_with_refcounts() {
        let mut st = AdapterStore::new(2);
        let a = st.register("math", weights(1.0)).unwrap();
        let b = st.register("code", weights(2.0)).unwrap();
        assert_eq!((a.ix(), b.ix()), (0, 1));
        assert_eq!((a, b), (AdapterId::for_slot(0), AdapterId::for_slot(1)));
        assert_eq!(st.lookup("code"), Some(b));
        assert_eq!(st.registered(), 2);
        // full store refuses a third registration
        assert!(st.register("chat", weights(3.0)).is_err());
        // pinned slots survive eviction attempts
        st.acquire(a).unwrap();
        st.acquire(a).unwrap();
        assert_eq!(st.refs(a), 2);
        assert!(st.evict(a).is_err(), "evict of pinned adapter");
        st.release(a).unwrap();
        st.release(a).unwrap();
        assert!(st.release(a).is_err(), "release below zero");
        st.evict(a).unwrap();
        assert_eq!(st.registered(), 1);
        // the freed slot is reused under a fresh generation
        let c = st.register("math2", weights(4.0)).unwrap();
        assert_eq!(c.ix(), 0);
        assert_ne!(c, a, "recycled slot must issue a new handle");
    }

    #[test]
    fn stale_handle_after_recycle_is_rejected() {
        let mut st = AdapterStore::new(1);
        let a = st.register("x", weights(1.0)).unwrap();
        st.evict(a).unwrap();
        let b = st.register("y", weights(2.0)).unwrap();
        assert_eq!(a.ix(), b.ix());
        // the pre-eviction handle must not resolve to the new occupant
        assert!(st.acquire(a).is_err(), "stale handle pinned the replacement");
        assert!(st.weights(a).is_err());
        assert!(st.evict(a).is_err());
        assert_eq!(st.name(a), None);
        assert_eq!(st.refs(a), 0);
        st.acquire(b).unwrap();
        assert_eq!(st.lookup("y"), Some(b));
    }

    #[test]
    fn duplicate_names_are_rejected() {
        let mut st = AdapterStore::new(2);
        st.register("math", weights(1.0)).unwrap();
        assert!(st.register("math", weights(2.0)).is_err());
    }

    #[test]
    fn dirty_tracks_fresh_registrations_only() {
        let mut st = AdapterStore::new(3);
        let a = st.register("x", weights(1.0)).unwrap();
        let b = st.register("y", weights(2.0)).unwrap();
        assert_eq!(st.drain_dirty(), vec![a, b]);
        assert!(st.drain_dirty().is_empty(), "drain clears the set");
        st.evict(b).unwrap();
        assert!(st.drain_dirty().is_empty(), "eviction alone needs no upload");
        let c = st.register("z", weights(3.0)).unwrap();
        assert_eq!(c.ix(), b.ix(), "slot recycled");
        assert_eq!(st.drain_dirty(), vec![c]);
    }

    #[test]
    fn save_list_and_register_from_disk_roundtrip() {
        let dir = std::env::temp_dir().join(format!("loram_ad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        AdapterStore::save(&dir, "math", &weights(1.5)).unwrap();
        AdapterStore::save(&dir, "code", &weights(2.5)).unwrap();
        assert_eq!(AdapterStore::list(&dir).unwrap(), vec!["code", "math"]);
        let mut st = AdapterStore::with_dir(&dir, 2);
        let id = st.register_from_disk("math").unwrap();
        let w = st.weights(id).unwrap();
        assert_eq!(w.get("l0.wq.lora_a").unwrap().f32s(), &[1.5; 4]);
        assert!(st.register_from_disk("missing").is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn stacked_artifact_discovery_matches_naming_rule() {
        let names: Vec<String> = ["logits_tiny", "logits_tiny_abc", "logits_tiny_a3",
                                  "decode_step_tiny_a3"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert_eq!(
            stacked_logits_artifact(&names, "tiny").as_deref(),
            Some("logits_tiny_a3")
        );
        assert_eq!(stacked_logits_artifact(&names, "l13b"), None);
        // several capacities: the largest wins, regardless of list order
        let multi: Vec<String> = ["logits_tiny_a3", "logits_tiny_a8"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert_eq!(
            stacked_logits_artifact(&multi, "tiny").as_deref(),
            Some("logits_tiny_a8")
        );
    }

    #[test]
    fn acquire_unregistered_adapter_errors() {
        let mut st = AdapterStore::new(1);
        assert!(st.acquire(AdapterId::for_slot(0)).is_err());
        assert!(st.acquire(AdapterId::for_slot(5)).is_err());
        assert!(st.weights(AdapterId::for_slot(0)).is_err());
    }
}

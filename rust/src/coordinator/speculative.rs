//! Speculative decoding: draft small, verify large (DESIGN.md §2d).
//!
//! LoRAM's training trick — the pruned model is a faithful cheap proxy of
//! the large one — is exactly the drafter/target pairing speculative
//! decoding needs at serving time. [`SpecDecoder`] runs two
//! [`KvDecoder`]s in lockstep over the same batch grid:
//!
//! * the **drafter**: the pruned proxy's decode pair
//!   (`decode_{prefill,step}_<pruned>`) with the *pruned-side* LoRA
//!   factors (pre-R(·), straight out of the pipeline's SFT stage);
//! * the **target**: the full model's decode trio, whose third artifact
//!   (`decode_verify_*`, a (B, K+1) window) scores a whole draft run in
//!   one batched forward.
//!
//! Each round drafts up to K tokens greedily on the drafter, verifies
//! them in ONE target call, accepts the longest matching prefix plus the
//! target's own correction token, and rewinds both caches past the first
//! mismatch ([`CacheSlots::rewind`] — logical only; rejected K/V stay in
//! the tensors beyond the frontier, masked out by construction). Greedy
//! acceptance is *provably lossless*: every emitted token is the argmax
//! of target logits, so the stream is byte-identical to the kv-cache (and
//! reforward) paths — asserted at the JAX level in `test_model.py` and
//! end-to-end in `tests/integration.rs`.
//!
//! Rows sampling at temperature > 0 ride the same batched verify call as
//! a 1-token window (no drafts): lossless sampling would need rejection
//! resampling, so they simply degrade to per-token decode while greedy
//! rows around them speculate freely.


// The static mirror of this policy is `tools/loramlint` (panic-surface
// pass, ratcheted in baseline.json); `warn` until the remaining sites
// burn down, then promote to `deny` as serve.rs/kvcache.rs already did.
#![cfg_attr(
    not(test),
    warn(clippy::unwrap_used, clippy::expect_used, clippy::panic, clippy::unreachable)
)]
#![cfg_attr(not(test), warn(clippy::indexing_slicing))]

use super::generate::argmax;
use super::kvcache::{KvDecoder, VerifyFeed};
use crate::obs::trace::{self, Event};
use crate::obs::Metrics;
use crate::runtime::Runtime;
use crate::tensor::TensorStore;
use crate::tokenizer::PAD;
use anyhow::{ensure, Context, Result};

/// Cumulative speculative-decoding counters (surfaced per server in
/// [`crate::serve::ServerStats`] and per bench entry in BENCH_serve.json).
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct SpecStats {
    /// draft/verify rounds run
    pub rounds: usize,
    /// (B, 1) drafter forwards (incl. the write-only sync step per round)
    pub draft_steps: usize,
    /// (B, K+1) target verify forwards
    pub verify_steps: usize,
    /// draft tokens proposed across all rows
    pub drafted_tokens: usize,
    /// draft tokens accepted (emitted from an accepted draft position)
    pub accepted_tokens: usize,
    /// tokens emitted in total (accepted drafts + correction tokens)
    pub emitted_tokens: usize,
}

impl SpecStats {
    /// Fraction of proposed drafts the target accepted.
    pub fn acceptance_rate(&self) -> f64 {
        self.accepted_tokens as f64 / self.drafted_tokens.max(1) as f64
    }

    /// Mean tokens emitted per verify call (the speed-up lever: one
    /// target forward amortises over this many tokens).
    pub fn tokens_per_verify(&self) -> f64 {
        self.emitted_tokens as f64 / self.verify_steps.max(1) as f64
    }

    /// Export into the unified registry (DESIGN.md §2g) under `spec.*`.
    pub fn export_into(&self, m: &mut Metrics) {
        m.set_counter("spec.rounds", self.rounds as f64);
        m.set_counter("spec.draft_steps", self.draft_steps as f64);
        m.set_counter("spec.verify_steps", self.verify_steps as f64);
        m.set_counter("spec.drafted_tokens", self.drafted_tokens as f64);
        m.set_counter("spec.accepted_tokens", self.accepted_tokens as f64);
        m.set_counter("spec.emitted_tokens", self.emitted_tokens as f64);
        m.set_gauge("spec.acceptance_rate", self.acceptance_rate());
        m.set_gauge("spec.tokens_per_verify", self.tokens_per_verify());
    }
}

/// Expected tokens emitted per round at per-draft acceptance probability
/// `alpha` and draft length `k`: `(1 - alpha^(k+1)) / (1 - alpha)` — the
/// §Perf speed-up model (Leviathan et al. 2023, greedy case).
pub fn expected_emitted(alpha: f64, k: usize) -> f64 {
    if (alpha - 1.0).abs() < 1e-12 {
        return (k + 1) as f64;
    }
    (1.0 - alpha.powi(k as i32 + 1)) / (1.0 - alpha)
}

/// The drafter checkpoint convention shared by the pipeline's
/// `--drafter-dir` export and `loram serve --decode-path speculative`:
/// one drafter per directory, as (pruned base params, pruned pre-R(·)
/// LoRA factors).
pub fn drafter_paths(dir: &std::path::Path) -> (std::path::PathBuf, std::path::PathBuf) {
    (dir.join("drafter_params.lmck"), dir.join("drafter_lora.lmck"))
}

/// Stand-in drafter weights when no pipeline-trained checkpoint exists:
/// the target's own params sliced under a random structured plan for
/// `drafter_model`'s config, plus fresh (zero-`b`, identity) factors —
/// close enough to the target for drafts to land, different enough for
/// rejections. Drafter fidelity only moves the acceptance rate, never
/// correctness. The single definition behind the serve CLI, `repro tab8`,
/// `cargo bench serve` and the integration tests.
pub fn sliced_drafter_standin(
    rt: &Runtime,
    full_cfg: &crate::runtime::ModelCfg,
    params: &TensorStore,
    drafter_model: &str,
    seed: u64,
) -> Result<(TensorStore, TensorStore)> {
    let pruned_cfg = rt
        .load(&format!("eval_{drafter_model}"))?
        .meta
        .config
        .clone();
    let plan = crate::pruning::StructuredPlan::random(full_cfg, &pruned_cfg, seed)?;
    let dparams = crate::pruning::slice_params(params, full_cfg, &plan)?;
    let dlora = crate::params::init_lora(&pruned_cfg, seed);
    Ok((dparams, dlora))
}

/// One active row's input to a [`SpecDecoder::round`].
#[derive(Debug, Clone, Copy)]
pub struct SpecFeed {
    /// the row's frontier token (last of its sequence)
    pub token: i32,
    /// grid position of the frontier (sequence length - 1)
    pub pos: usize,
    /// greedy rows draft + verify; sampled rows take a 1-token window
    pub greedy: bool,
    /// most tokens the row may emit this round (budget and grid room);
    /// must be >= 1 for an active row
    pub max_emit: usize,
}

/// One row's outcome from a [`SpecDecoder::round`].
#[derive(Debug, Clone)]
pub enum SpecRowOut {
    /// Greedy row: the verified tokens to append, in stream order. The
    /// first `accepted` of them came from accepted drafts; the rest (at
    /// most one) is the target's correction token.
    Greedy { tokens: Vec<i32>, accepted: usize },
    /// Sampled row: the target's next-token logits — the caller samples
    /// host-side under the row's own config, as on every other path.
    Logits(Vec<f32>),
}

/// Longest accepted prefix: how many leading drafts the target agreed
/// with. Pure, unit-tested — the whole lossless-ness argument sits here.
pub(crate) fn accept_prefix(drafts: &[i32], target: &[i32]) -> usize {
    drafts
        .iter()
        .zip(target)
        .take_while(|(d, t)| d == t)
        .count()
}

/// Draft budget for one row this round: never past the verify window K,
/// never more than `max_emit - 1` (the +1 correction token must fit), and
/// never past the cache grid (`seq - 1 - pos` slots remain after `pos`).
pub(crate) fn draft_budget(k: usize, max_emit: usize, seq: usize, pos: usize) -> usize {
    k.min(max_emit.saturating_sub(1)).min(seq - 1 - pos)
}

/// The speculative decode subsystem: drafter and target decoders in
/// lockstep over one shared batch grid.
pub struct SpecDecoder {
    target: KvDecoder,
    drafter: KvDecoder,
    k: usize,
    pub stats: SpecStats,
}

impl SpecDecoder {
    /// Load the target's decode trio and the drafter's decode pair. The
    /// target *must* have the `decode_verify_*` artifact registered; the
    /// two grids must match exactly (rows are shared 1:1).
    pub fn try_new(
        rt: &Runtime,
        target_model: &str,
        target_stores: &[&TensorStore],
        drafter_model: &str,
        drafter_stores: &[&TensorStore],
    ) -> Result<SpecDecoder> {
        SpecDecoder::try_new_inner(rt, target_model, target_stores, drafter_model, drafter_stores, false)
    }

    /// [`SpecDecoder::try_new`] over pooled block caches (DESIGN.md §2f):
    /// the target loads its `decode_*_paged_*` trio; the drafter pages
    /// too when its own paged family is registered and falls back to its
    /// dense pair otherwise — paging changes cache layout, not the
    /// draft/verify token contract, so mixed pairings stay byte-exact.
    /// Rewinds after rejected drafts stay logical on both sides: block
    /// tables are untouched and re-decode overwrites the row's private
    /// frontier blocks (shared prefix blocks sit strictly below the
    /// rewind floor).
    pub fn try_new_paged(
        rt: &Runtime,
        target_model: &str,
        target_stores: &[&TensorStore],
        drafter_model: &str,
        drafter_stores: &[&TensorStore],
    ) -> Result<SpecDecoder> {
        SpecDecoder::try_new_inner(rt, target_model, target_stores, drafter_model, drafter_stores, true)
    }

    fn try_new_inner(
        rt: &Runtime,
        target_model: &str,
        target_stores: &[&TensorStore],
        drafter_model: &str,
        drafter_stores: &[&TensorStore],
        paged: bool,
    ) -> Result<SpecDecoder> {
        let target = if paged {
            KvDecoder::try_new_paged(rt, target_model, target_stores)?
        } else {
            KvDecoder::try_new(rt, target_model, target_stores)?
        }
        .with_context(|| {
            let family = if paged { "paged decode family" } else { "decode artifact pair" };
            format!("{family} for '{target_model}' not registered")
        })?;
        let k = target.verify_k().with_context(|| {
            let infix = if paged { "_paged" } else { "" };
            format!(
                "speculative decoding needs 'decode_verify{infix}_{target_model}' \
                 registered alongside the decode pair"
            )
        })?;
        let drafter = match if paged {
            KvDecoder::try_new_paged(rt, drafter_model, drafter_stores)?
        } else {
            None
        } {
            Some(d) => d,
            None => KvDecoder::try_new(rt, drafter_model, drafter_stores)?
                .with_context(|| {
                    format!("drafter decode pair for '{drafter_model}' not registered")
                })?,
        };
        ensure!(
            drafter.batch_size() == target.batch_size()
                && drafter.seq_len() == target.seq_len(),
            "drafter grid ({}, {}) != target grid ({}, {})",
            drafter.batch_size(),
            drafter.seq_len(),
            target.batch_size(),
            target.seq_len()
        );
        ensure!(
            drafter.vocab_size() == target.vocab_size(),
            "drafter vocab {} != target vocab {} — drafts would not be \
             token-compatible",
            drafter.vocab_size(),
            target.vocab_size()
        );
        Ok(SpecDecoder { target, drafter, k, stats: SpecStats::default() })
    }

    pub fn batch_size(&self) -> usize {
        self.target.batch_size()
    }

    pub fn seq_len(&self) -> usize {
        self.target.seq_len()
    }

    /// Verify-window draft length K.
    pub fn draft_k(&self) -> usize {
        self.k
    }

    /// Adapter slots the *target* trio stacks, if any (the drafter always
    /// decodes its single baked-in pruned factors).
    pub fn adapter_capacity(&self) -> Option<usize> {
        self.target.adapter_capacity()
    }

    /// Stage one adapter slot into the target trio's sessions.
    pub fn put_adapter(&mut self, ix: usize, weights: &TensorStore) -> Result<()> {
        self.target.put_adapter(ix, weights)
    }

    /// Whether admissions run through the chunked-prefill ladder
    /// (DESIGN.md §2e; the target's setting is authoritative).
    pub fn chunked(&self) -> bool {
        self.target.chunked()
    }

    /// Force chunked admission on/off for the pairing. The target must
    /// have a registered ladder; the drafter follows when it has one of
    /// its own and stays monolithic otherwise (correctness is untouched
    /// either way — only the admission FLOPs differ).
    pub fn set_chunked(&mut self, on: bool) -> Result<()> {
        self.target.set_chunked(on)?;
        self.drafter
            .set_chunked(on && !self.drafter.ladder().is_empty())
            .expect("guarded by the ladder check");
        Ok(())
    }

    /// Combined admission accounting of both decoders (greedy rows admit
    /// into target *and* drafter, so both sides' window tokens count).
    pub fn prefill_stats(&self) -> crate::coordinator::kvcache::PrefillStats {
        self.target.pstats.merge(self.drafter.pstats)
    }

    /// Block-pool counters from the *target* trio (the capacity-bearing
    /// side; the drafter's pool, when paged, is its own private economy).
    /// `None` when the target decodes dense.
    pub fn paged_stats(&self) -> Option<crate::coordinator::kvcache::PagedStats> {
        self.target.paged_stats()
    }

    /// Admit a row into the target cache — and, for greedy rows, into the
    /// drafter too (sampled rows never draft, so their drafter slot stays
    /// free). On drafter failure the target admission is rolled back.
    pub fn admit(
        &mut self,
        rt: &Runtime,
        row: usize,
        seq: &[i32],
        adapter_ix: Option<i32>,
        greedy: bool,
    ) -> Result<()> {
        self.target.admit_auto(rt, row, seq, adapter_ix)?;
        if greedy {
            if let Err(e) = self.drafter.admit_auto(rt, row, seq, None) {
                self.target.evict(row).expect("target row admitted above");
                return Err(e);
            }
        }
        Ok(())
    }

    /// Free a row in both decoders. A preemption can land while the
    /// drafter's frontier still sits past the target's committed position
    /// (a verify round that drafted but never rewound — e.g. an error out
    /// of `round` between the draft steps and the rewind). Those pending
    /// draft positions are rewound first, so the trace shows the same
    /// rewind-then-evict sequence as any rejected draft and the audit's
    /// row lifecycle never sees an evict with unverified cache state.
    pub fn evict(&mut self, row: usize) -> Result<()> {
        if let (Some(t), Some(d)) = (self.target.slots.len(row), self.drafter.slots.len(row)) {
            if d > t {
                self.drafter.rewind(row, d - t)?;
            }
        }
        self.target.evict(row)?;
        if self.drafter.slots.len(row).is_some() {
            self.drafter.evict(row)?;
        }
        Ok(())
    }

    /// One draft → verify → accept → rewind round over the whole grid.
    ///
    /// Greedy rows draft up to K tokens on the drafter (one extra
    /// write-only step syncs the last draft's K/V so the drafter cache
    /// always covers the accepted prefix), verify them in one target
    /// call, and emit the longest matching prefix + 1 correction token.
    /// Sampled rows ride the same verify call as a 1-token window and get
    /// their logits back. `adapter_ix` routes target rows through their
    /// adapter slots, as on the plain kv path.
    pub fn round(
        &mut self,
        rt: &Runtime,
        feeds: &[Option<SpecFeed>],
        adapter_ix: Option<&[i32]>,
    ) -> Result<Vec<Option<SpecRowOut>>> {
        let b = self.batch_size();
        let s = self.seq_len();
        let k = self.k;
        ensure!(feeds.len() == b, "spec: {} feeds for batch {b}", feeds.len());
        // per-row draft budget: 0 for sampled rows and rows whose drafter
        // slot is free (admitted sampled, or budget already exhausted)
        let k_eff: Vec<usize> = feeds
            .iter()
            .enumerate()
            .map(|(row, f)| match f {
                Some(f) if f.greedy && self.drafter.slots.len(row).is_some() => {
                    ensure!(f.max_emit >= 1, "spec: row {row} with max_emit 0");
                    ensure!(f.pos < s, "spec: row {row} frontier {} off-grid", f.pos);
                    Ok(draft_budget(k, f.max_emit, s, f.pos))
                }
                _ => Ok(0),
            })
            .collect::<Result<_>>()?;
        let max_k = k_eff.iter().copied().max().unwrap_or(0);

        // ---- draft max_k tokens greedily (+ the write-only sync step) ----
        let mut drafts: Vec<Vec<i32>> = vec![vec![]; b];
        if max_k > 0 {
            for t in 0..=max_k {
                let dfeeds: Vec<Option<(i32, usize)>> = (0..b)
                    .map(|row| {
                        let ke = k_eff[row];
                        if ke > 0 && t <= ke {
                            let f = feeds[row].as_ref().expect("ke > 0 implies a feed");
                            let tok = if t == 0 { f.token } else { drafts[row][t - 1] };
                            Some((tok, f.pos + t))
                        } else if ke > 0 {
                            // done drafting this round: re-write the sync
                            // position with the same token (idempotent)
                            let f = feeds[row].as_ref().expect("ke > 0 implies a feed");
                            Some((drafts[row][ke - 1], f.pos + ke))
                        } else if let Some(f) =
                            feeds[row].as_ref().filter(|_| self.drafter.slots.len(row).is_some())
                        {
                            // active row not drafting this round (budget or
                            // grid leaves no draft room): feed its *real*
                            // frontier, which both writes correct K/V and
                            // keeps the drafter frontier in lockstep with
                            // the one token the row emits per such round —
                            // the drafter cache stays valid without any
                            // assumption about future rounds
                            Some((f.token, f.pos))
                        } else {
                            // done/free occupied drafter row (feed is
                            // None): harmless PAD rewrite — a done row
                            // never decodes again before take + re-admit,
                            // which rewrites the whole cache row
                            self.drafter.slots.len(row).map(|len| (PAD, len - 1))
                        }
                    })
                    .collect();
                let logits = self.drafter.step(rt, &dfeeds, None)?;
                self.stats.draft_steps += 1;
                let lf = logits.f32s();
                let v = lf.len() / b;
                for row in 0..b {
                    if t < k_eff[row] {
                        drafts[row].push(argmax(&lf[row * v..(row + 1) * v]) as i32);
                        self.stats.drafted_tokens += 1;
                    }
                }
            }
        }

        // ---- one batched verification of every row's window --------------
        let vfeeds: Vec<Option<VerifyFeed>> = feeds
            .iter()
            .enumerate()
            .map(|(row, f)| {
                f.as_ref().map(|f| {
                    let mut tokens = Vec::with_capacity(k + 1);
                    tokens.push(f.token);
                    tokens.extend_from_slice(&drafts[row]);
                    tokens.resize(k + 1, PAD);
                    VerifyFeed { tokens, pos: f.pos, live: k_eff[row] + 1 }
                })
            })
            .collect();
        let logits = self.target.verify(rt, &vfeeds, adapter_ix)?;
        self.stats.verify_steps += 1;
        self.stats.rounds += 1;
        let lf = logits.f32s();
        let v = lf.len() / (b * (k + 1));

        // ---- accept the longest matching prefix + 1 correction token -----
        let mut out: Vec<Option<SpecRowOut>> = Vec::with_capacity(b);
        for (row, f) in feeds.iter().enumerate() {
            let Some(f) = f else {
                out.push(None);
                continue;
            };
            let ke = k_eff[row];
            let window = |j: usize| {
                let at = (row * (k + 1) + j) * v;
                &lf[at..at + v]
            };
            if !f.greedy {
                out.push(Some(SpecRowOut::Logits(window(0).to_vec())));
                continue;
            }
            let target_tok: Vec<i32> =
                (0..=ke).map(|j| argmax(window(j)) as i32).collect();
            let a = accept_prefix(&drafts[row], &target_tok);
            let p = (a + 1).min(f.max_emit);
            // the caches advanced to pos + ke + 1 during draft/verify;
            // the new frontier (the last emitted token) must stay
            // *uncached*, so both roll back to pos + p
            let n = ke + 1 - p;
            self.target.rewind(row, n)?;
            if ke > 0 {
                self.drafter.rewind(row, n)?;
            }
            self.stats.accepted_tokens += a.min(p);
            self.stats.emitted_tokens += p;
            trace::emit(|| Event::VerifyRound { row, k: ke, accepted: a.min(p) });
            out.push(Some(SpecRowOut::Greedy {
                tokens: target_tok[..p].to_vec(),
                accepted: a.min(p),
            }));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accept_prefix_stops_at_first_mismatch() {
        assert_eq!(accept_prefix(&[], &[7]), 0);
        assert_eq!(accept_prefix(&[1, 2, 3], &[1, 2, 3, 9]), 3);
        assert_eq!(accept_prefix(&[1, 2, 3], &[1, 5, 3, 9]), 1);
        assert_eq!(accept_prefix(&[4, 2], &[1, 2, 3]), 0);
        // a later re-match after a mismatch must NOT count: positions
        // after the first divergence condition on a different prefix
        assert_eq!(accept_prefix(&[1, 9, 3], &[1, 2, 3, 0]), 1);
    }

    #[test]
    fn draft_budget_respects_window_budget_and_grid() {
        // plain: the verify window K bounds the drafts
        assert_eq!(draft_budget(4, 100, 32, 5), 4);
        // the +1 correction token must fit max_emit
        assert_eq!(draft_budget(4, 3, 32, 5), 2);
        assert_eq!(draft_budget(4, 1, 32, 5), 0);
        // the window must fit the cache grid after pos
        assert_eq!(draft_budget(4, 100, 8, 5), 2);
        assert_eq!(draft_budget(4, 100, 8, 7), 0);
    }

    #[test]
    fn expected_emitted_matches_closed_form_extremes() {
        // alpha = 0: every round emits exactly the 1 correction token
        assert!((expected_emitted(0.0, 4) - 1.0).abs() < 1e-12);
        // alpha = 1: every round emits the full window
        assert!((expected_emitted(1.0, 4) - 5.0).abs() < 1e-12);
        // monotone in alpha and bounded by K+1
        let mut last = 0.0;
        for i in 0..=10 {
            let e = expected_emitted(i as f64 / 10.0, 4);
            assert!(e >= last && e <= 5.0 + 1e-12);
            last = e;
        }
    }

    #[test]
    fn spec_stats_rates() {
        let st = SpecStats {
            rounds: 4,
            draft_steps: 10,
            verify_steps: 4,
            drafted_tokens: 12,
            accepted_tokens: 9,
            emitted_tokens: 13,
        };
        assert!((st.acceptance_rate() - 0.75).abs() < 1e-12);
        assert!((st.tokens_per_verify() - 3.25).abs() < 1e-12);
        // empty stats divide by nothing
        let z = SpecStats::default();
        assert_eq!(z.acceptance_rate(), 0.0);
        assert_eq!(z.tokens_per_verify(), 0.0);
    }
}

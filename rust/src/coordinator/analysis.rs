//! Appendix D analysis: L2 norms of the trained low-rank matrices.
//!
//! Head-wise norms for attention adapters (Eq. 10) and masked layer-wise
//! mean norms for MLP adapters (Eq. 11), emitted as CSV heatmap data.

use crate::runtime::ModelCfg;
use crate::tensor::{Tensor, TensorStore};
use crate::util::log::Csv;
use anyhow::Result;
use std::path::Path;

/// Materialise W_Δ = a @ b for one projection (small at proxy scale).
pub fn lora_delta(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, r) = a.dims2();
    let (r2, n) = b.dims2();
    assert_eq!(r, r2);
    let av = a.f32s();
    let bv = b.f32s();
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        for k in 0..r {
            let aik = av[i * r + k];
            if aik == 0.0 {
                continue;
            }
            let brow = &bv[k * n..(k + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += aik * brow[j];
            }
        }
    }
    Tensor::from_f32(&[m, n], out)
}

/// Eq. 10: per-head L2 norm of W_Δ for q/k/v (column blocks) or o (row
/// blocks).
pub fn head_norms(delta: &Tensor, n_heads: usize, head_dim: usize, is_output: bool) -> Vec<f64> {
    let (m, n) = delta.dims2();
    let v = delta.f32s();
    (0..n_heads)
        .map(|h| {
            let mut s = 0f64;
            if is_output {
                for i in h * head_dim..(h + 1) * head_dim {
                    for j in 0..n {
                        s += (v[i * n + j] as f64).powi(2);
                    }
                }
            } else {
                for i in 0..m {
                    for j in h * head_dim..(h + 1) * head_dim {
                        s += (v[i * n + j] as f64).powi(2);
                    }
                }
            }
            s.sqrt()
        })
        .collect()
}

/// Eq. 11: masked mean row/col L2 norm of an MLP adapter delta.
pub fn mlp_mean_norm(delta: &Tensor, rows: bool) -> f64 {
    let (m, n) = delta.dims2();
    let v = delta.f32s();
    let outer = if rows { m } else { n };
    let mut total = 0f64;
    let mut active = 0usize;
    for i in 0..outer {
        let mut s = 0f64;
        for j in 0..(if rows { n } else { m }) {
            let x = if rows { v[i * n + j] } else { v[j * n + i] };
            s += (x as f64).powi(2);
        }
        if s > 0.0 {
            total += s.sqrt();
            active += 1;
        }
    }
    if active == 0 {
        0.0
    } else {
        total / active as f64
    }
}

/// Emit Appendix-D CSVs: attention head norms + MLP layer norms.
pub fn dump_lora_norms(
    cfg: &ModelCfg,
    lora: &TensorStore,
    out_dir: &Path,
    tag: &str,
) -> Result<()> {
    let hd = cfg.head_dim();
    let mut att = Csv::create(
        out_dir.join(format!("appD_attn_norms_{tag}.csv")),
        &["layer", "proj", "head", "l2"],
    )?;
    let mut mlp = Csv::create(
        out_dir.join(format!("appD_mlp_norms_{tag}.csv")),
        &["layer", "proj", "mean_l2"],
    )?;
    for i in 0..cfg.n_layers {
        let (h, kv, _ff) = cfg.layer_shapes(i);
        for (proj, heads, is_out) in [
            ("wq", h, false),
            ("wk", kv, false),
            ("wv", kv, false),
            ("wo", h, true),
        ] {
            let a = lora.get(&format!("l{i}.{proj}.lora_a"))?;
            let b = lora.get(&format!("l{i}.{proj}.lora_b"))?;
            let delta = lora_delta(a, b);
            for (hh, norm) in head_norms(&delta, heads, hd, is_out).iter().enumerate() {
                att.row(&crate::csv_row![i, proj, hh, norm])?;
            }
        }
        for (proj, rows) in [("w_up", false), ("w_gate", false), ("w_down", true)] {
            let a = lora.get(&format!("l{i}.{proj}.lora_a"))?;
            let b = lora.get(&format!("l{i}.{proj}.lora_b"))?;
            let delta = lora_delta(a, b);
            mlp.row(&crate::csv_row![i, proj, mlp_mean_norm(&delta, rows)])?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lora_delta_matches_manual_matmul() {
        let a = Tensor::from_f32(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::from_f32(&[2, 3], vec![1., 0., 1., 0., 1., 1.]);
        let d = lora_delta(&a, &b);
        assert_eq!(d.f32s(), &[1., 2., 3., 3., 4., 7.]);
    }

    #[test]
    fn head_norms_partition_total() {
        let d = Tensor::from_f32(&[2, 4], vec![3., 0., 0., 4., 0., 0., 0., 0.]);
        let hn = head_norms(&d, 2, 2, false);
        assert!((hn[0] - 3.0).abs() < 1e-9);
        assert!((hn[1] - 4.0).abs() < 1e-9);
    }

    #[test]
    fn mlp_mean_norm_ignores_zero_rows() {
        let d = Tensor::from_f32(&[2, 2], vec![3., 4., 0., 0.]);
        assert!((mlp_mean_norm(&d, true) - 5.0).abs() < 1e-9);
    }
}

//! The LoRAM coordinator: training sessions, the prune→align→SFT→recover
//! pipeline, evaluators, generation, analysis, and the per-table/figure
//! experiment runners.

pub mod adapters;
pub mod analysis;
pub mod downstream;
pub mod evaluate;
pub mod experiments;
pub mod generate;
pub mod kvcache;
pub mod pipeline;
pub mod speculative;
pub mod train;

pub use adapters::{AdapterId, AdapterStore};
pub use pipeline::{Pipeline, PipelineConfig, Variant};
pub use train::TrainSession;

//! The LoRAM pipeline (paper Fig. 2 / Algorithm 1):
//!
//!   W0  --P(·)-->  W0^P  --L_A-->  W0^{P,A}  --Q(·)-->  W0^{P,A,Q}   (offline)
//!   W_Δ --P(·)-->  W_Δ^P --L_SFT--> W_Δ^{P*} --R(·)-->  W_Δ^{R*}     (online)
//!   inference: h = x (W0 + W_Δ^{R*})
//!
//! Stages map 1:1 onto methods here: `ensure_base` (the stand-in for the
//! published pre-trained checkpoint), `prune`, `align`, `sft`, `recover`.
//! Plain-LoRA baselines run the same machinery with no pruning stage.

use crate::coordinator::evaluate::{test_sequences, Evaluator};
use crate::coordinator::train::TrainSession;
use crate::data::instruct::{Dataset, InstructGen};
use crate::data::{corpus::Corpus, make_batch};
use crate::params::{init_lora, init_params};
use crate::pruning::{self, StructuredPlan};
use crate::quant;
use crate::runtime::Runtime;
use crate::tensor::TensorStore;
use crate::tokenizer::Tokenizer;
use crate::util::log;
use anyhow::{bail, Context, Result};
use std::path::PathBuf;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// plain LoRA on the (unpruned) base — the paper's baselines
    Lora,
    /// LoRAM-Rand: randomly structured
    Rand,
    /// LoRAM-Stru: gradient-importance structured (LLM-Pruner-style)
    Stru,
    /// LoRAM-Semi: 4:8 semi-structured masks
    Semi,
    /// LoRAM-Unst: unstructured magnitude masks
    Unst,
}

impl Variant {
    pub fn from_str(s: &str) -> Option<Variant> {
        match s {
            "lora" => Some(Variant::Lora),
            "rand" => Some(Variant::Rand),
            "stru" => Some(Variant::Stru),
            "semi" => Some(Variant::Semi),
            "unst" => Some(Variant::Unst),
            _ => None,
        }
    }

    pub fn structured(&self) -> bool {
        matches!(self, Variant::Rand | Variant::Stru)
    }

    pub fn masked(&self) -> bool {
        matches!(self, Variant::Semi | Variant::Unst)
    }
}

#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub base: String,           // e.g. "l13b"
    pub pruned: Option<String>, // e.g. "l13b_p65" for structured variants
    pub variant: Variant,
    pub quantized: bool, // QLoRAM: NF4 base during SFT
    pub unst_ratio: f64, // pruning ratio for Unst masks (Semi is fixed 4:8)
    pub pretrain_steps: usize,
    pub align_steps: usize,
    pub sft_steps: usize,
    pub lr_pretrain: f64,
    pub lr_align: f64,
    pub lr_sft: f64,
    pub dataset: Dataset,
    pub seed: u64,
    pub eval_every: usize, // 0 = only final
    pub eval_seqs: usize,  // held-out sequences per ppl point
    pub align: bool,       // false = "w/o Alignment" ablation
    pub run_dir: PathBuf,  // cache directory for base checkpoints
    /// export the recovered adapter into this `AdapterStore` directory
    /// right after R(·) — the training→serving handoff (DESIGN.md §2c)
    pub adapter_dir: Option<PathBuf>,
    /// adapter name for the export (default: `<base>_<variant>`)
    pub adapter_name: Option<String>,
    /// export the drafter half of "draft small, verify large" into this
    /// directory: the (aligned) pruned base params and the *pre-R(·)*
    /// pruned LoRA factors, the exact weights the speculative drafter
    /// decodes with (DESIGN.md §2d)
    pub drafter_dir: Option<PathBuf>,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            base: "l13b".into(),
            pruned: Some("l13b_p65".into()),
            variant: Variant::Stru,
            quantized: false,
            unst_ratio: 0.55,
            pretrain_steps: 300,
            align_steps: 60,
            sft_steps: 120,
            lr_pretrain: 1e-3,
            lr_align: 5e-4,
            lr_sft: 1e-3,
            dataset: Dataset::Hermes,
            seed: 0,
            eval_every: 30,
            eval_seqs: 32,
            align: true,
            run_dir: PathBuf::from("runs"),
            adapter_dir: None,
            adapter_name: None,
            drafter_dir: None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct EvalPoint {
    pub step: usize,
    pub ood_ppl: f64,     // Alpaca stand-in, recovered/full model
    pub id_ppl: f64,      // in-domain test split, recovered/full model
    pub ood_ppl_pruned: Option<f64>, // "w/o Recovery" ablation
}

pub struct PipelineResult {
    pub base_params: TensorStore,
    pub pruned_params: TensorStore, // == base for masked variants (masked weights)
    pub masks: Option<TensorStore>,
    pub plan: Option<StructuredPlan>,
    pub lora_pruned: TensorStore,
    pub lora_recovered: TensorStore,
    pub sft_losses: Vec<f32>,
    pub align_losses: Vec<f32>,
    pub eval_points: Vec<EvalPoint>,
    pub sft_step_ms: f64,
    pub peak_rss_mib: f64,
}

pub struct Pipeline<'r> {
    pub rt: &'r Runtime,
    pub cfg: PipelineConfig,
}

impl<'r> Pipeline<'r> {
    pub fn new(rt: &'r Runtime, cfg: PipelineConfig) -> Pipeline<'r> {
        Pipeline { rt, cfg }
    }

    /// The "published checkpoint" stand-in: pre-train the base config on the
    /// general corpus once and cache it under run_dir.
    pub fn ensure_base(&self) -> Result<TensorStore> {
        ensure_base(
            self.rt,
            &self.cfg.base,
            self.cfg.pretrain_steps,
            self.cfg.lr_pretrain,
            self.cfg.seed,
            &self.cfg.run_dir,
        )
    }

    /// Full LoRAM pipeline. Returns weights + curves for the experiments.
    pub fn run(&self) -> Result<PipelineResult> {
        let cfg = &self.cfg;
        let base_params = self.ensure_base()?;
        let base_art = self.rt.load(&format!("eval_{}", cfg.base))?;
        let full_cfg = base_art.meta.config.clone();

        // ---- P(·): prune -------------------------------------------------
        let (mut pruned_params, plan, masks) = match cfg.variant {
            Variant::Lora => (base_params.clone(), None, None),
            Variant::Rand | Variant::Stru => {
                let pruned_name = cfg
                    .pruned
                    .as_ref()
                    .context("structured variant needs a pruned config name")?;
                let pruned_cfg = self
                    .rt
                    .load(&format!("eval_{pruned_name}"))?
                    .meta
                    .config
                    .clone();
                let plan = if cfg.variant == Variant::Rand {
                    StructuredPlan::random(&full_cfg, &pruned_cfg, cfg.seed ^ 0xa11)?
                } else {
                    let (head_imp, ff_imp) = self.grad_importance(&base_params)?;
                    StructuredPlan::from_importance(&full_cfg, &pruned_cfg, &head_imp, &ff_imp)?
                };
                let sliced = pruning::slice_params(&base_params, &full_cfg, &plan)?;
                (sliced, Some(plan), None)
            }
            Variant::Semi | Variant::Unst => {
                let strategy = if cfg.variant == Variant::Semi { "semi" } else { "unst" };
                let (masks, masked) =
                    pruning::build_masks(&base_params, &full_cfg, strategy, cfg.unst_ratio)?;
                (masked, None, Some(masks))
            }
        };

        // ---- L_A: alignment (continual pre-training of the pruned model) -
        let mut align_losses = vec![];
        if cfg.align && cfg.align_steps > 0 && cfg.variant != Variant::Lora {
            let align_art = match cfg.variant {
                Variant::Rand | Variant::Stru => {
                    format!("pretrain_{}", cfg.pruned.as_ref().unwrap())
                }
                _ => format!("pretrain_{}_m", cfg.base),
            };
            let mut stores: Vec<&TensorStore> = vec![&pruned_params];
            if let Some(m) = &masks {
                stores.push(m);
            }
            let mut sess = TrainSession::new(self.rt, &align_art, &stores)?;
            let b = sess.batch_size();
            let s = sess.seq_len();
            // alignment corpus: same generator family as pre-training,
            // disjoint stream (paper §B: ~105M-token general corpus)
            let mut corpus = Corpus::new(cfg.seed ^ 0xa119, 0.5);
            for step in 0..cfg.align_steps {
                let seqs = corpus.next_seqs(b, s);
                let batch = make_batch(&seqs, b, s, false);
                let loss = sess.train_step(&batch, cfg.lr_align)?;
                align_losses.push(loss);
                if step % 20 == 0 {
                    log::info(format!("align[{}] step {step} loss {loss:.4}", cfg.base));
                }
            }
            let pnames: Vec<String> = sess
                .art
                .meta
                .name_list("param_names");
            pruned_params = sess.extract(&pnames)?;
        }

        // ---- Q(·): NF4 quantisation of the (aligned) pruned base ---------
        let quant_store = if cfg.quantized {
            let sft_art_name = self.sft_artifact_name()?;
            let sft_art = self.rt.load(&sft_art_name)?;
            let qnames = sft_art.meta.name_list("quant_names");
            Some(quant::quantize_projections(
                &pruned_params,
                &qnames,
                quant::NF4_BLOCK,
            )?)
        } else {
            None
        };

        // ---- L_SFT: pruned low-rank matrix training ----------------------
        let sft_art_name = self.sft_artifact_name()?;
        let sft_art = self.rt.load(&sft_art_name)?;
        let train_cfg = sft_art.meta.config.clone();
        let lora_init = init_lora(&train_cfg, cfg.seed ^ 0x5f7);
        let mut stores: Vec<&TensorStore> = vec![&pruned_params, &lora_init];
        if let Some(q) = &quant_store {
            stores.push(q);
        }
        if let Some(m) = &masks {
            stores.push(m);
        }
        let mut sess = TrainSession::new(self.rt, &sft_art_name, &stores)?;
        let b = sess.batch_size();
        let s = sess.seq_len();
        let lnames = sess.art.meta.name_list("lora_names");
        let tk = Tokenizer::new();
        let mut gen = InstructGen::new(cfg.dataset, cfg.seed, 0);
        let ood_seqs = test_sequences(Dataset::Alpaca, cfg.seed, cfg.eval_seqs);
        let id_seqs = test_sequences(cfg.dataset, cfg.seed, cfg.eval_seqs);
        let mut eval_points = vec![];

        for step in 0..cfg.sft_steps {
            let seqs: Vec<Vec<i32>> = gen
                .batch_examples(b)
                .iter()
                .map(|e| e.tokens(&tk))
                .collect();
            let batch = make_batch(&seqs, b, s, true);
            let loss = sess.train_step(&batch, cfg.lr_sft)?;
            if step % 20 == 0 {
                log::info(format!(
                    "sft[{}:{:?}] step {step} loss {loss:.4}",
                    cfg.base, cfg.variant
                ));
            }
            let at_eval = cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0;
            if at_eval || step + 1 == cfg.sft_steps {
                let lora_now = sess.extract(&lnames)?;
                let recovered = self.recover(&lora_now, &full_cfg, plan.as_ref())?;
                let ev = Evaluator::new(
                    self.rt,
                    &format!("eval_{}", cfg.base),
                    &[&base_params, &recovered],
                )?;
                let ood = ev.perplexity(&ood_seqs, true)?;
                let id = ev.perplexity(&id_seqs, true)?;
                // "w/o Recovery": evaluate on the pruned/masked base
                let ood_pruned = match cfg.variant {
                    Variant::Rand | Variant::Stru => {
                        let evp = Evaluator::new(
                            self.rt,
                            &format!("eval_{}", cfg.pruned.as_ref().unwrap()),
                            &[&pruned_params, &lora_now],
                        )?;
                        Some(evp.perplexity(&ood_seqs, true)?)
                    }
                    Variant::Semi | Variant::Unst => {
                        let evp = Evaluator::new(
                            self.rt,
                            &format!("eval_{}", cfg.base),
                            &[&pruned_params, &lora_now],
                        )?;
                        Some(evp.perplexity(&ood_seqs, true)?)
                    }
                    Variant::Lora => None,
                };
                eval_points.push(EvalPoint {
                    step: step + 1,
                    ood_ppl: ood,
                    id_ppl: id,
                    ood_ppl_pruned: ood_pruned,
                });
                log::info(format!(
                    "  eval step {} ood_ppl {ood:.3} id_ppl {id:.3}",
                    step + 1
                ));
            }
        }

        let lora_pruned = sess.extract(&lnames)?;
        let lora_recovered = self.recover(&lora_pruned, &full_cfg, plan.as_ref())?;
        // the training→serving handoff: recovered factors land in the
        // adapter store as a first-class, servable adapter
        if let Some(dir) = &cfg.adapter_dir {
            let name = cfg.adapter_name.clone().unwrap_or_else(|| {
                format!("{}_{}", cfg.base, format!("{:?}", cfg.variant).to_lowercase())
            });
            let path = crate::coordinator::adapters::AdapterStore::save(
                dir,
                &name,
                &lora_recovered,
            )?;
            log::info(format!("adapter '{name}' exported to {}", path.display()));
        }
        // the drafter handoff: the pruned model + its pre-recovery factors
        // are exactly what the speculative drafter decodes with
        if let Some(dir) = &cfg.drafter_dir {
            std::fs::create_dir_all(dir)?;
            let (ppath, lpath) = crate::coordinator::speculative::drafter_paths(dir);
            pruned_params.save(&ppath)?;
            lora_pruned.save(&lpath)?;
            log::info(format!(
                "drafter (pruned base + pre-R(·) factors) exported to {}",
                dir.display()
            ));
        }
        Ok(PipelineResult {
            base_params,
            pruned_params,
            masks,
            plan,
            lora_pruned,
            lora_recovered,
            sft_losses: sess.losses.clone(),
            align_losses,
            eval_points,
            sft_step_ms: sess.mean_step_ms(),
            peak_rss_mib: crate::bench::peak_rss_mib(),
        })
    }

    /// R(·): recovery — scatter for structured variants, identity for
    /// non-structured (deployment note C3) and plain LoRA.
    pub fn recover(
        &self,
        lora: &TensorStore,
        full_cfg: &crate::runtime::ModelCfg,
        plan: Option<&StructuredPlan>,
    ) -> Result<TensorStore> {
        match plan {
            Some(p) => pruning::recover_lora(lora, full_cfg, p),
            None => Ok(lora.clone()),
        }
    }

    fn sft_artifact_name(&self) -> Result<String> {
        let cfg = &self.cfg;
        Ok(match cfg.variant {
            Variant::Lora => format!("sft_{}", cfg.base),
            Variant::Rand | Variant::Stru => {
                let p = cfg.pruned.as_ref().context("pruned cfg required")?;
                if cfg.quantized {
                    format!("sft_{p}_q")
                } else {
                    format!("sft_{p}")
                }
            }
            Variant::Semi | Variant::Unst => {
                if cfg.quantized {
                    bail!("masked + quantized SFT artifact not in the suite");
                }
                format!("sft_{}_m", cfg.base)
            }
        })
    }

    /// Run the gradimp artifact on a calibration batch -> (head_imp, ff_imp).
    pub fn grad_importance(
        &self,
        base_params: &TensorStore,
    ) -> Result<(crate::tensor::Tensor, crate::tensor::Tensor)> {
        let art = self.rt.load(&format!("gradimp_{}", self.cfg.base))?;
        let b = art.meta.batch();
        let s = art.meta.seq();
        let mut corpus = Corpus::new(self.cfg.seed ^ 0xca11b, 0.5);
        let seqs = corpus.next_seqs(b, s);
        let batch = make_batch(&seqs, b, s, false);
        let mut store = base_params.clone();
        store.insert("tokens", batch.tokens);
        store.insert("loss_mask", batch.loss_mask);
        let out = self.rt.run(&art, &store)?;
        Ok((out.get("head_imp")?.clone(), out.get("ff_imp")?.clone()))
    }
}

/// Pre-train (or load the cached) base model for `cfg_name`.
pub fn ensure_base(
    rt: &Runtime,
    cfg_name: &str,
    steps: usize,
    lr: f64,
    seed: u64,
    run_dir: &std::path::Path,
) -> Result<TensorStore> {
    let path = run_dir.join(format!("base_{cfg_name}_s{seed}_t{steps}.lmck"));
    if path.exists() {
        log::info(format!("base[{cfg_name}]: loading cached {}", path.display()));
        return TensorStore::load(&path);
    }
    let art_name = format!("pretrain_{cfg_name}");
    let art = rt.load(&art_name)?;
    let cfg = art.meta.config.clone();
    let params = init_params(&cfg, seed);
    let mut sess = TrainSession::new(rt, &art_name, &[&params])?;
    let b = sess.batch_size();
    let s = sess.seq_len();
    let mut corpus = Corpus::new(seed ^ 0x9e37, 0.5);
    for step in 0..steps {
        let seqs = corpus.next_seqs(b, s);
        let batch = make_batch(&seqs, b, s, false);
        let loss = sess.train_step(&batch, lr)?;
        if step % 50 == 0 {
            log::info(format!("pretrain[{cfg_name}] step {step} loss {loss:.4}"));
        }
    }
    let pnames = sess.art.meta.name_list("param_names");
    let out = sess.extract(&pnames)?;
    out.save(&path)?;
    log::info(format!(
        "base[{cfg_name}]: trained {steps} steps, saved {}",
        path.display()
    ));
    Ok(out)
}

//! Experiment runners: one entry per paper table/figure (DESIGN.md §3).
//!
//! `loram repro --exp <id> [--scale smoke|paper]` dispatches here; every
//! runner writes CSV/JSON under `results/<id>/` with the same rows/series
//! the paper reports.

use crate::runtime::Runtime;
use anyhow::{bail, Result};
use std::path::PathBuf;

pub mod scale;
mod fig3_4;
mod fig5;
mod fig6;
mod fig7_8;
mod fig16;
mod tab1_3;
mod tab456;
mod tab7;
mod tab8;
mod app_d;

pub use scale::Scale;

pub struct ExpCtx<'r> {
    pub rt: &'r Runtime,
    pub scale: Scale,
    pub out_dir: PathBuf,
    pub run_dir: PathBuf,
    pub seed: u64,
}

impl<'r> ExpCtx<'r> {
    pub fn new(rt: &'r Runtime, scale: Scale, exp: &str, seed: u64) -> Result<ExpCtx<'r>> {
        let out_dir = PathBuf::from("results").join(exp);
        std::fs::create_dir_all(&out_dir)?;
        let run_dir = PathBuf::from("runs");
        std::fs::create_dir_all(&run_dir)?;
        Ok(ExpCtx {
            rt,
            scale,
            out_dir,
            run_dir,
            seed,
        })
    }
}

/// Dispatch by experiment id.
pub fn run(rt: &Runtime, exp: &str, scale: Scale, seed: u64) -> Result<()> {
    let ctx = ExpCtx::new(rt, scale, exp, seed)?;
    match exp {
        "fig3" => fig3_4::run(&ctx, crate::data::instruct::Dataset::Hermes),
        "fig4" => fig3_4::run(&ctx, crate::data::instruct::Dataset::Orca),
        "tab1" | "tab2" | "tab3" => tab1_3::run(&ctx),
        "fig5" => fig5::run(&ctx),
        "fig6" => fig6::run(&ctx),
        "fig7" => fig7_8::run_fig7(&ctx),
        "fig8" => fig7_8::run_fig8(&ctx),
        "tab456" => tab456::run(&ctx),
        "tab7" => tab7::run(&ctx),
        "tab8" => tab8::run(&ctx),
        "fig16" => fig16::run(&ctx),
        "appD" => app_d::run(&ctx),
        other => bail!("unknown experiment '{other}' (see DESIGN.md §3)"),
    }
}

pub const ALL_EXPERIMENTS: &[&str] = &[
    "fig3", "fig4", "tab1", "fig5", "fig6", "fig7", "fig8", "tab456", "tab7", "tab8", "fig16",
    "appD",
];

//! Autoregressive generation over a `logits_*` artifact.
//!
//! The artifact computes full-sequence logits for a fixed (B, S); the
//! generator packs up to B prompts per call, reads the logits at each
//! prompt's frontier position, samples (greedy or temperature/top-p), and
//! repeats until EOS or budget. This full-reforward decode is the v1 hot
//! path measured in EXPERIMENTS.md §Perf.

use crate::runtime::{Artifact, Runtime};
use crate::tensor::{Tensor, TensorStore};
use crate::tokenizer::{Tokenizer, EOS, PAD, SEP};
use crate::util::rng::Rng;
use anyhow::Result;
use std::rc::Rc;

#[derive(Debug, Clone, Copy)]
pub struct SampleCfg {
    /// 0.0 = greedy
    pub temperature: f64,
    pub top_p: f64,
    pub max_new: usize,
}

impl Default for SampleCfg {
    fn default() -> Self {
        SampleCfg {
            temperature: 0.0,
            top_p: 0.95,
            max_new: 16,
        }
    }
}

pub struct Generator<'r> {
    pub rt: &'r Runtime,
    pub art: Rc<Artifact>,
    /// weights device-resident; only the token grid re-uploads per step
    sess: std::cell::RefCell<crate::runtime::DeviceSession>,
    pub vocab: usize,
}

impl<'r> Generator<'r> {
    pub fn new(rt: &'r Runtime, artifact: &str, stores: &[&TensorStore]) -> Result<Generator<'r>> {
        let art = rt.load(artifact)?;
        let sess = crate::runtime::DeviceSession::new(rt, art.clone(), stores)?;
        let vocab = art.meta.config.vocab_size;
        Ok(Generator {
            rt,
            art,
            sess: std::cell::RefCell::new(sess),
            vocab,
        })
    }

    pub fn batch_size(&self) -> usize {
        self.art.meta.batch()
    }

    pub fn seq_len(&self) -> usize {
        self.art.meta.seq()
    }

    /// Generate completions for up to `batch_size` prompts at once.
    /// Returns the generated token ids (response segment only).
    pub fn generate_batch(
        &self,
        prompts: &[String],
        cfg: SampleCfg,
        rng: &mut Rng,
    ) -> Result<Vec<Vec<i32>>> {
        let b = self.batch_size();
        let s = self.seq_len();
        assert!(prompts.len() <= b);
        let tk = Tokenizer::new();
        // BOS + prompt + SEP, truncated from the left to leave room
        let mut seqs: Vec<Vec<i32>> = prompts
            .iter()
            .map(|p| {
                let mut ids = vec![crate::tokenizer::BOS];
                ids.extend(tk.encode(p));
                ids.push(SEP);
                if ids.len() > s - cfg.max_new.min(s / 2) {
                    let keep = s - cfg.max_new.min(s / 2);
                    ids = ids[ids.len() - keep..].to_vec();
                }
                ids
            })
            .collect();
        let starts: Vec<usize> = seqs.iter().map(|x| x.len()).collect();
        let mut done = vec![false; prompts.len()];
        for _ in 0..cfg.max_new {
            if done.iter().all(|&d| d) || seqs.iter().any(|x| x.len() >= s) {
                break;
            }
            let mut toks = Vec::with_capacity(b * s);
            for i in 0..b {
                if i < seqs.len() {
                    toks.extend(crate::tokenizer::pad_to(&seqs[i], s));
                } else {
                    toks.extend(std::iter::repeat(PAD).take(s));
                }
            }
            let mut sess = self.sess.borrow_mut();
            sess.set(self.rt, "tokens", &Tensor::from_i32(&[b, s], toks))?;
            let out = sess.run(self.rt)?;
            let logits = out.get("logits")?;
            for (i, seq) in seqs.iter_mut().enumerate() {
                if done[i] {
                    continue;
                }
                let pos = seq.len() - 1;
                let row = &logits.f32s()[(i * s + pos) * self.vocab..(i * s + pos + 1) * self.vocab];
                let next = sample_token(row, cfg, rng);
                seq.push(next);
                if next == EOS || next == PAD {
                    done[i] = true;
                }
            }
        }
        Ok(seqs
            .iter()
            .zip(&starts)
            .map(|(seq, &st)| {
                let tail = &seq[st..];
                let end = tail
                    .iter()
                    .position(|&t| t == EOS || t == PAD)
                    .unwrap_or(tail.len());
                tail[..end].to_vec()
            })
            .collect())
    }

    /// Convenience: generate text responses for prompts (chunked to fit B).
    pub fn complete(&self, prompts: &[String], cfg: SampleCfg, rng: &mut Rng) -> Result<Vec<String>> {
        let tk = Tokenizer::new();
        let mut out = vec![];
        for chunk in prompts.chunks(self.batch_size()) {
            for ids in self.generate_batch(chunk, cfg, rng)? {
                out.push(tk.decode(&ids));
            }
        }
        Ok(out)
    }
}

/// Greedy / temperature+top-p sampling from a logits row.
pub fn sample_token(logits: &[f32], cfg: SampleCfg, rng: &mut Rng) -> i32 {
    if cfg.temperature <= 0.0 {
        return argmax(logits) as i32;
    }
    // softmax with temperature
    let t = cfg.temperature as f32;
    let mx = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut probs: Vec<(usize, f32)> = logits
        .iter()
        .enumerate()
        .map(|(i, &l)| (i, ((l - mx) / t).exp()))
        .collect();
    let z: f32 = probs.iter().map(|(_, p)| p).sum();
    for p in probs.iter_mut() {
        p.1 /= z;
    }
    // top-p nucleus
    probs.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let mut cum = 0.0;
    let mut cut = probs.len();
    for (i, (_, p)) in probs.iter().enumerate() {
        cum += p;
        if cum >= cfg.top_p as f32 {
            cut = i + 1;
            break;
        }
    }
    probs.truncate(cut);
    let ws: Vec<f32> = probs.iter().map(|(_, p)| *p).collect();
    probs[rng.weighted(&ws)].0 as i32
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax() {
        let mut rng = Rng::new(0);
        let logits = [0.1, 2.0, -1.0, 1.9];
        let t = sample_token(
            &logits,
            SampleCfg {
                temperature: 0.0,
                ..Default::default()
            },
            &mut rng,
        );
        assert_eq!(t, 1);
    }

    #[test]
    fn low_temperature_concentrates() {
        let mut rng = Rng::new(1);
        let logits = [0.0, 5.0, 0.0, 0.0];
        let cfg = SampleCfg {
            temperature: 0.2,
            top_p: 1.0,
            max_new: 1,
        };
        let hits = (0..100)
            .filter(|_| sample_token(&logits, cfg, &mut rng) == 1)
            .count();
        assert!(hits > 95);
    }

    #[test]
    fn top_p_restricts_support() {
        let mut rng = Rng::new(2);
        // one dominant token, tiny tail; top_p=0.5 keeps only the head
        let logits = [10.0, 0.0, 0.0, 0.0];
        let cfg = SampleCfg {
            temperature: 1.0,
            top_p: 0.5,
            max_new: 1,
        };
        for _ in 0..50 {
            assert_eq!(sample_token(&logits, cfg, &mut rng), 0);
        }
    }
}

//! Autoregressive generation over a `logits_*` artifact, structured as an
//! explicit decode state machine.
//!
//! The artifact computes full-sequence logits for a fixed (B, S); the
//! generator owns one *row* of per-request decode state per batch slot:
//! the token sequence, its frontier position, and that request's own
//! [`SampleCfg`]. `prefill` admits a prompt into a free row; `decode_step`
//! runs one forward over the whole grid and samples exactly one token per
//! active row — each under its row's config, since sampling is host-side
//! and per-row; `take` removes a finished row and frees its slot. Rows are
//! independent, so the serving scheduler can admit new requests mid-decode
//! (continuous batching, see `serve`). `generate_batch` / `complete` are
//! thin all-rows-at-once wrappers over the same machine.
//!
//! Three decode paths share the row state machine (DESIGN.md §2a/§2d):
//! *reforward* runs the full-sequence `logits_*` artifact every step (the
//! v1 baseline); *kv-cache* — selected automatically when the
//! `decode_prefill_*`/`decode_step_*` artifact pair is registered — runs a
//! (B, 1) incremental forward over device-resident K/V caches owned by
//! [`super::kvcache::KvDecoder`]; *speculative*
//! ([`Generator::with_speculative`]) drafts K tokens on the pruned proxy
//! and verifies them in one (B, K+1) target window
//! ([`super::speculative::SpecDecoder`]), emitting several tokens per
//! step with byte-identical greedy streams. Row state, the scheduler, and
//! every caller are identical across all of them.


// The static mirror of this policy is `tools/loramlint` (panic-surface
// pass, ratcheted in baseline.json); `warn` until the remaining sites
// burn down, then promote to `deny` as serve.rs/kvcache.rs already did.
#![cfg_attr(
    not(test),
    warn(clippy::unwrap_used, clippy::expect_used, clippy::panic, clippy::unreachable)
)]
#![cfg_attr(not(test), warn(clippy::indexing_slicing))]

use super::adapters::{AdapterId, AdapterStore};
use super::kvcache::{next_bucket, KvDecoder, PagedStats, PrefillStats};
use super::speculative::{SpecDecoder, SpecFeed, SpecRowOut, SpecStats};
use crate::runtime::{Artifact, Runtime, Session, SlotGroup};
use crate::tensor::{Tensor, TensorStore};
use crate::tokenizer::{Tokenizer, BOS, EOS, PAD, SEP};
use crate::util::rng::Rng;
use anyhow::{bail, ensure, Context, Result};
use std::cell::RefCell;
use std::path::PathBuf;
use std::rc::Rc;

/// Which decode implementation a [`Generator`] runs each step on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodePath {
    /// Full (B, S) reforward through the `logits_*` artifact per token.
    Reforward,
    /// (B, 1) incremental forward over donated K/V caches.
    KvCache,
    /// Draft small, verify large: the pruned proxy drafts K tokens, the
    /// target verifies them in one (B, K+1) window (DESIGN.md §2d).
    /// Greedy streams are byte-identical to the other two paths.
    Speculative,
}

impl DecodePath {
    pub fn name(self) -> &'static str {
        match self {
            DecodePath::Reforward => "reforward",
            DecodePath::KvCache => "kvcache",
            DecodePath::Speculative => "speculative",
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SampleCfg {
    /// 0.0 = greedy
    pub temperature: f64,
    pub top_p: f64,
    pub max_new: usize,
}

impl Default for SampleCfg {
    fn default() -> Self {
        SampleCfg {
            temperature: 0.0,
            top_p: 0.95,
            max_new: 16,
        }
    }
}

/// Per-row decode state: one in-flight request.
#[derive(Debug, Clone)]
struct RowState {
    seq: Vec<i32>,
    /// frontier: index where generation begins (prompt length after
    /// truncation); `seq[start..]` is the generated tail
    start: usize,
    cfg: SampleCfg,
    generated: usize,
    done: bool,
    /// adapter slot this row decodes under (stacked-adapter artifacts);
    /// holds one `AdapterStore` reference until `take`
    adapter: Option<AdapterId>,
    /// admission complete — the row decodes. False only while a chunked
    /// prefill is being paced across scheduler ticks (`prefill_begin`
    /// with `defer` + `prefill_tick`); un-admitted rows are skipped by
    /// decode steps and hold no cache-slot ledger entry yet.
    admitted: bool,
    /// prompt tokens already fed through the chunk ladder (== start once
    /// admitted)
    fed: usize,
}

/// Outcome of one [`Generator::prefill_tick`]: prefill window tokens
/// spent (padding included), rows whose deferred admission completed
/// this tick, and rows whose admission failed mid-chunk — those are
/// already released (slot freed, adapter pin dropped), the caller only
/// accounts the rejection.
#[derive(Debug, Default, Clone)]
pub struct PrefillTickOut {
    pub spent: usize,
    pub completed: Vec<usize>,
    pub failed: Vec<usize>,
}

/// One sampled token, as reported by [`Generator::decode_step`]. On the
/// speculative path one step may report *several* tokens per row.
#[derive(Debug, Clone, Copy)]
pub struct StepOut {
    pub row: usize,
    pub token: i32,
    /// the row reached EOS/PAD, its `max_new` budget, or the grid edge;
    /// it stays occupied until [`Generator::take`]
    pub finished: bool,
    /// the token came from an accepted speculative draft (always false on
    /// the reforward/kvcache paths and for verify-correction tokens)
    pub accepted: bool,
}

struct DecodeState {
    sess: Session,
    /// present iff the decode artifact pair is registered (the kv path)
    kv: Option<KvDecoder>,
    /// present iff constructed via `with_speculative` (the spec path;
    /// `kv` is then None — the target caches live inside the SpecDecoder)
    spec: Option<SpecDecoder>,
    rows: Vec<Option<RowState>>,
    /// adapter registry when serving a stacked-adapter artifact through
    /// `with_adapters`; rows then route by their `AdapterId`
    adapters: Option<AdapterStore>,
}

pub struct Generator<'r> {
    pub rt: &'r Runtime,
    pub art: Rc<Artifact>,
    /// session + row state behind a RefCell so scoring/eval callers can
    /// share an immutable generator (batch-internal mutation only)
    state: RefCell<DecodeState>,
    /// the artifact's adapter slot group, when it serves stacked adapters
    adapter_group: Option<SlotGroup>,
    /// constructed once per generator lifetime
    tk: Tokenizer,
    pub vocab: usize,
}

impl<'r> Generator<'r> {
    /// Auto path selection: kv-cache when the decode artifact pair for
    /// this model is registered (and grid-compatible), reforward otherwise.
    pub fn new(rt: &'r Runtime, artifact: &str, stores: &[&TensorStore]) -> Result<Generator<'r>> {
        Generator::with_path(rt, artifact, stores, None)
    }

    /// `path`: `None` = auto; `Some(DecodePath::KvCache)` errors when the
    /// decode artifacts are missing; `Some(DecodePath::Reforward)` forces
    /// the full-reforward baseline (the §Perf comparison knob).
    pub fn with_path(
        rt: &'r Runtime,
        artifact: &str,
        stores: &[&TensorStore],
        path: Option<DecodePath>,
    ) -> Result<Generator<'r>> {
        Generator::with_path_paged(rt, artifact, stores, path, false)
    }

    /// Like [`Generator::with_path`] with the paged-cache toggle
    /// (DESIGN.md §2f): `paged` loads the `decode_*_paged_<model>`
    /// family — pooled block caches behind a per-row block table, with
    /// shared-prefix reuse on chunked admission. On auto path selection
    /// a missing paged family falls back to reforward, exactly like a
    /// missing dense pair; `Some(DecodePath::KvCache)` hard-fails.
    pub fn with_path_paged(
        rt: &'r Runtime,
        artifact: &str,
        stores: &[&TensorStore],
        path: Option<DecodePath>,
        paged: bool,
    ) -> Result<Generator<'r>> {
        let art = rt.load(artifact)?;
        let sess = Session::new(rt, art.clone(), stores)?;
        let vocab = art.meta.config.vocab_size;
        let (b, s) = (art.meta.batch(), art.meta.seq());
        // the decode pair shares the logits artifact's name suffix, so an
        // adapter-stacked `logits_tiny_a3` pairs with
        // `decode_{prefill,step}_tiny_a3`, never the plain pair
        let model = artifact
            .strip_prefix("logits_")
            .map(String::from)
            .unwrap_or_else(|| art.meta.config.name.clone());
        let load = |rt, model: &str, stores| {
            if paged {
                KvDecoder::try_new_paged(rt, model, stores)
            } else {
                KvDecoder::try_new(rt, model, stores)
            }
        };
        let kv = match path {
            Some(DecodePath::Reforward) => None,
            Some(DecodePath::Speculative) => bail!(
                "the speculative path needs the drafter's weights — \
                 construct via Generator::with_speculative"
            ),
            Some(DecodePath::KvCache) => Some(
                load(rt, &model, stores)?.with_context(|| {
                    let family = if paged { "paged decode family" } else { "decode artifact pair" };
                    format!("{family} for '{model}' not registered")
                })?,
            ),
            None => load(rt, &model, stores)?,
        };
        let kv = match kv {
            // the decode grid must match the logits artifact the Generator
            // sizes its rows by; on auto, a mismatched pair is ignored
            Some(kv) if kv.batch_size() != b || kv.seq_len() != s => {
                ensure!(
                    path != Some(DecodePath::KvCache),
                    "decode pair grid ({}, {}) != logits grid ({b}, {s})",
                    kv.batch_size(),
                    kv.seq_len()
                );
                None
            }
            other => other,
        };
        let adapter_group = art.meta.adapter_group()?;
        let kv = match (&adapter_group, kv) {
            // a pair whose adapter capacity disagrees with the logits
            // artifact (stale mixed-version dir) is defective: on auto it
            // falls back to reforward — loudly — like every other pair
            // defect; only an explicit kv request hard-fails
            (Some(g), Some(kv)) if kv.adapter_capacity() != Some(g.size) => {
                ensure!(
                    path != Some(DecodePath::KvCache),
                    "decode pair adapter capacity {:?} != logits capacity {}",
                    kv.adapter_capacity(),
                    g.size
                );
                crate::util::log::warn(format!(
                    "decode pair for '{model}' stacks {:?} adapter slots but \
                     '{artifact}' stacks {} — falling back to full reforward",
                    kv.adapter_capacity(),
                    g.size
                ));
                None
            }
            (_, kv) => kv,
        };
        let rows = (0..b).map(|_| None).collect();
        Ok(Generator {
            rt,
            art,
            state: RefCell::new(DecodeState { sess, kv, spec: None, rows, adapters: None }),
            adapter_group,
            tk: Tokenizer::new(),
            vocab,
        })
    }

    /// A generator on the speculative path: the pruned proxy named by
    /// `drafter_model` (its `decode_{prefill,step}_*` pair, running
    /// `drafter_stores` — pruned base + pruned-side pre-R(·) LoRA factors)
    /// drafts; this artifact's model (its decode *trio*, running `stores`)
    /// verifies. Greedy rows emit streams byte-identical to the other
    /// decode paths; rows sampling at temperature > 0 degrade to
    /// per-token decode through the same batched verify call.
    pub fn with_speculative(
        rt: &'r Runtime,
        artifact: &str,
        stores: &[&TensorStore],
        drafter_model: &str,
        drafter_stores: &[&TensorStore],
    ) -> Result<Generator<'r>> {
        Generator::with_speculative_paged(rt, artifact, stores, drafter_model, drafter_stores, false)
    }

    /// [`Generator::with_speculative`] with the paged-cache toggle: the
    /// target trio loads its `decode_*_paged_*` family; the drafter pages
    /// too when its own family is registered and stays dense otherwise
    /// (the grids match either way — paging changes cache layout, not
    /// the decode contract).
    pub fn with_speculative_paged(
        rt: &'r Runtime,
        artifact: &str,
        stores: &[&TensorStore],
        drafter_model: &str,
        drafter_stores: &[&TensorStore],
        paged: bool,
    ) -> Result<Generator<'r>> {
        let gen = Generator::with_path(rt, artifact, stores, Some(DecodePath::Reforward))?;
        let model = artifact
            .strip_prefix("logits_")
            .map(String::from)
            .unwrap_or_else(|| gen.art.meta.config.name.clone());
        let spec = if paged {
            SpecDecoder::try_new_paged(rt, &model, stores, drafter_model, drafter_stores)?
        } else {
            SpecDecoder::try_new(rt, &model, stores, drafter_model, drafter_stores)?
        };
        ensure!(
            spec.batch_size() == gen.batch_size() && spec.seq_len() == gen.seq_len(),
            "speculative grid ({}, {}) != logits grid ({}, {})",
            spec.batch_size(),
            spec.seq_len(),
            gen.batch_size(),
            gen.seq_len()
        );
        if let Some(g) = &gen.adapter_group {
            ensure!(
                spec.adapter_capacity() == Some(g.size),
                "target trio adapter capacity {:?} != logits capacity {}",
                spec.adapter_capacity(),
                g.size
            );
        }
        gen.state.borrow_mut().spec = Some(spec);
        Ok(gen)
    }

    /// Speculative-decoding counters (None off the speculative path).
    pub fn spec_stats(&self) -> Option<SpecStats> {
        self.state.borrow().spec.as_ref().map(|s| s.stats)
    }

    /// Verify-window draft length K (None off the speculative path).
    pub fn draft_k(&self) -> Option<usize> {
        self.state.borrow().spec.as_ref().map(|s| s.draft_k())
    }

    /// A generator over a stacked-adapter artifact with a live
    /// [`AdapterStore`] sized by the artifact's adapter group. Registered
    /// adapters become routable per request (`prefill_adapter`); `dir`
    /// backs the store with an `.lmck` adapter directory.
    pub fn with_adapters(
        rt: &'r Runtime,
        artifact: &str,
        stores: &[&TensorStore],
        path: Option<DecodePath>,
        dir: Option<PathBuf>,
    ) -> Result<Generator<'r>> {
        let gen = Generator::with_path(rt, artifact, stores, path)?;
        let group = gen.adapter_group.as_ref().with_context(|| {
            format!("artifact '{artifact}' declares no adapter slot group")
        })?;
        let store = match dir {
            Some(d) => AdapterStore::with_dir(d, group.size),
            None => AdapterStore::new(group.size),
        };
        gen.state.borrow_mut().adapters = Some(store);
        Ok(gen)
    }

    /// Adapter slots the artifact stacks (adapter-group size), if any.
    pub fn adapter_capacity(&self) -> Option<usize> {
        self.adapter_group.as_ref().map(|g| g.size)
    }

    /// Register an adapter's recovered factors into a free slot and stage
    /// them into every session (uploaded lazily at each session's next
    /// run — only the changed stacked tensors move).
    pub fn register_adapter(&self, name: &str, weights: TensorStore) -> Result<AdapterId> {
        let mut st = self.state.borrow_mut();
        let st = &mut *st;
        let ad = st
            .adapters
            .as_mut()
            .context("generator has no adapter store (use with_adapters)")?;
        let id = ad.register(name, weights)?;
        finish_registration(ad, id, &mut st.sess, st.kv.as_mut(), st.spec.as_mut())
    }

    /// Register an adapter from the store's backing directory.
    pub fn register_adapter_from_disk(&self, name: &str) -> Result<AdapterId> {
        let mut st = self.state.borrow_mut();
        let st = &mut *st;
        let ad = st
            .adapters
            .as_mut()
            .context("generator has no adapter store (use with_adapters)")?;
        let id = ad.register_from_disk(name)?;
        finish_registration(ad, id, &mut st.sess, st.kv.as_mut(), st.spec.as_mut())
    }

    /// Evict a registered adapter (fails while rows still decode it).
    pub fn evict_adapter(&self, id: AdapterId) -> Result<()> {
        let mut st = self.state.borrow_mut();
        st.adapters
            .as_mut()
            .context("generator has no adapter store")?
            .evict(id)
    }

    /// Id of a registered adapter by name.
    pub fn adapter_id(&self, name: &str) -> Option<AdapterId> {
        self.state.borrow().adapters.as_ref()?.lookup(name)
    }

    /// Name of a registered adapter.
    pub fn adapter_name(&self, id: AdapterId) -> Option<String> {
        self.state
            .borrow()
            .adapters
            .as_ref()?
            .name(id)
            .map(String::from)
    }

    /// Which decode implementation `decode_step` runs.
    pub fn decode_path(&self) -> DecodePath {
        let st = self.state.borrow();
        if st.spec.is_some() {
            DecodePath::Speculative
        } else if st.kv.is_some() {
            DecodePath::KvCache
        } else {
            DecodePath::Reforward
        }
    }

    /// Whether admissions run through the chunked-prefill bucket ladder
    /// (DESIGN.md §2e). Always false on the reforward path.
    pub fn chunked_prefill(&self) -> bool {
        let st = self.state.borrow();
        if let Some(kv) = st.kv.as_ref() {
            kv.chunked()
        } else if let Some(spec) = st.spec.as_ref() {
            spec.chunked()
        } else {
            false
        }
    }

    /// Force chunked admission on/off — the §Perf A/B knob and
    /// `serve --prefill-chunk`. Turning it on needs the kv (or
    /// speculative) path with a registered bucket ladder.
    pub fn set_chunked_prefill(&self, on: bool) -> Result<()> {
        let mut st = self.state.borrow_mut();
        match (st.kv.as_mut(), st.spec.as_mut()) {
            (Some(kv), _) => kv.set_chunked(on),
            (None, Some(spec)) => spec.set_chunked(on),
            (None, None) => {
                ensure!(!on, "chunked prefill needs the kv or speculative decode path");
                Ok(())
            }
        }
    }

    /// Cumulative admission accounting from the cache subsystem (window
    /// tokens processed, padding waste). Zero on the reforward path,
    /// whose admission runs no prefill at all.
    pub fn prefill_stats(&self) -> PrefillStats {
        let st = self.state.borrow();
        if let Some(kv) = st.kv.as_ref() {
            kv.pstats
        } else if let Some(spec) = st.spec.as_ref() {
            spec.prefill_stats()
        } else {
            PrefillStats::default()
        }
    }

    /// Whether this generator decodes through pooled block caches
    /// (DESIGN.md §2f). False on the dense kv and reforward paths.
    pub fn paged(&self) -> bool {
        self.paged_stats().is_some()
    }

    /// Block-pool counters (prefix hits, copy-on-write forks, pool
    /// utilisation) — `None` off the paged path.
    pub fn paged_stats(&self) -> Option<PagedStats> {
        let st = self.state.borrow();
        if let Some(kv) = st.kv.as_ref() {
            kv.paged_stats()
        } else if let Some(spec) = st.spec.as_ref() {
            spec.paged_stats()
        } else {
            None
        }
    }

    pub fn batch_size(&self) -> usize {
        self.art.meta.batch()
    }

    pub fn seq_len(&self) -> usize {
        self.art.meta.seq()
    }

    pub fn tokenizer(&self) -> &Tokenizer {
        &self.tk
    }

    /// Batch rows with no request in them.
    pub fn free_rows(&self) -> usize {
        self.state.borrow().rows.iter().filter(|r| r.is_none()).count()
    }

    /// Occupied rows still decoding (not yet finished).
    pub fn active_rows(&self) -> usize {
        self.state
            .borrow()
            .rows
            .iter()
            .flatten()
            .filter(|r| !r.done)
            .count()
    }

    /// Admit a prompt into a free row: tokenize (BOS + prompt + SEP),
    /// left-truncate to leave generation room, and install the row state.
    /// On the kv path this also runs the prefill artifact, filling the
    /// row's cache (admission cost is the one full forward; every
    /// subsequent step is (B, 1)). Returns the row index; errors when
    /// every row is occupied. Every row emits at least one token
    /// (`max_new` is clamped to ≥ 1) so a finished `StepOut` always
    /// reports it and the slot is reclaimable.
    pub fn prefill(&self, prompt: &str, cfg: SampleCfg) -> Result<usize> {
        self.prefill_adapter(prompt, cfg, None)
    }

    /// Like [`Generator::prefill`], routed through a registered adapter:
    /// the row decodes under that adapter's slot for its whole lifetime
    /// and pins it (ref-count) until `take`. With an adapter store
    /// attached, every request must name an adapter — slot 0 is a real
    /// adapter, not a base-model default; without one, `adapter` must be
    /// `None` (plain single-LoRA artifacts).
    pub fn prefill_adapter(
        &self,
        prompt: &str,
        cfg: SampleCfg,
        adapter: Option<AdapterId>,
    ) -> Result<usize> {
        self.prefill_begin(prompt, cfg, adapter, false).map(|(row, _)| row)
    }

    /// Begin admitting a prompt. With `defer` and a chunked kv ladder the
    /// row is only *reserved* — its prompt is fed later, window by
    /// window, by [`Generator::prefill_tick`] (the scheduler's
    /// token-budget pacing) — and this returns `(row, false)`. In every
    /// other configuration (reforward, monolithic kv, the speculative
    /// path, or `defer = false`) admission completes here and this
    /// returns `(row, true)`.
    pub fn prefill_begin(
        &self,
        prompt: &str,
        cfg: SampleCfg,
        adapter: Option<AdapterId>,
        defer: bool,
    ) -> Result<(usize, bool)> {
        let cfg = SampleCfg { max_new: cfg.max_new.max(1), ..cfg };
        let mut st = self.state.borrow_mut();
        let st = &mut *st;
        let row = st
            .rows
            .iter()
            .position(|r| r.is_none())
            .context("prefill: no free batch row")?;
        match (st.adapters.as_mut(), adapter) {
            (Some(ad), Some(id)) => {
                // pin before the admission forward; released on failure
                ad.acquire(id)
                    .with_context(|| format!("prefill: adapter {id} not registered"))?;
            }
            (Some(_), None) => {
                bail!("prefill: this generator serves per-request adapters; \
                       the request names none")
            }
            (None, Some(id)) => {
                bail!("prefill: adapter {id} requested but the generator has \
                       no adapter store")
            }
            (None, None) => {}
        }
        let mut ids = vec![BOS];
        ids.extend(self.tk.encode(prompt));
        ids.push(SEP);
        let (ids, start) = truncate_prompt(ids, self.seq_len(), cfg.max_new);
        // deferred chunked admission: only the plain kv path paces its
        // prefill across ticks; reforward admission is free and the spec
        // path admits both decoders at once
        let deferred = defer
            && st.spec.is_none()
            && st.kv.as_ref().map_or(false, |kv| kv.chunked());
        let mut resident = 0;
        if deferred {
            // reserve the row's cache geometry up front: on the paged path
            // this plans the block table (consulting the prefix index, so
            // resident shared-prefix tokens are never re-fed) and holds the
            // blocks until admission_finish/abort; dense planning is free
            let kv = st.kv.as_mut().expect("deferred implies a kv decoder");
            match kv.admission_start(row, &ids) {
                Ok(r) => resident = r,
                Err(e) => {
                    if let (Some(ad), Some(id)) = (st.adapters.as_mut(), adapter) {
                        ad.release(id).expect("acquired above");
                    }
                    return Err(e);
                }
            }
        } else {
            // fill the caches first: on failure the row stays free
            let kv_adapter = adapter.map(|id| id.ix() as i32);
            let admitted = if let Some(spec) = st.spec.as_mut() {
                // greedy rows also admit into the drafter; sampled rows
                // only ever ride the 1-token verify window
                spec.admit(self.rt, row, &ids, kv_adapter, cfg.temperature <= 0.0)
            } else if let Some(kv) = st.kv.as_mut() {
                kv.admit_auto(self.rt, row, &ids, kv_adapter)
            } else {
                Ok(())
            };
            if let Err(e) = admitted {
                if let (Some(ad), Some(id)) = (st.adapters.as_mut(), adapter) {
                    ad.release(id).expect("acquired above");
                }
                return Err(e);
            }
        }
        let fed = if deferred { resident } else { start };
        st.rows[row] = Some(RowState {
            seq: ids,
            start,
            cfg,
            generated: 0,
            done: false,
            adapter,
            admitted: !deferred,
            fed,
        });
        Ok((row, !deferred))
    }

    /// Spend up to `budget` prefill window tokens on rows reserved by a
    /// deferred [`Generator::prefill_begin`], in row order. While any row
    /// is pending at least one window is always fed (progress guarantee),
    /// so a budget below the smallest bucket still converges. A window
    /// failure releases that row (and its adapter pin) and reports it in
    /// `failed` instead of aborting the rows behind it.
    pub fn prefill_tick(&self, budget: usize) -> Result<PrefillTickOut> {
        let mut st = self.state.borrow_mut();
        let st = &mut *st;
        let mut out = PrefillTickOut::default();
        let Some(kv) = st.kv.as_mut() else { return Ok(out) };
        let ladder = kv.ladder();
        if ladder.is_empty() {
            return Ok(out);
        }
        let mut budget_left = budget;
        for row in 0..st.rows.len() {
            if !matches!(&st.rows[row], Some(r) if !r.admitted) {
                continue;
            }
            loop {
                let r = st.rows[row].as_mut().expect("pending row checked above");
                let len = r.seq.len();
                if r.fed == len {
                    break;
                }
                let Some(bucket) =
                    next_bucket(&ladder, len - r.fed, budget_left, out.spent == 0)
                else {
                    return Ok(out); // tick budget exhausted
                };
                let take = bucket.min(len - r.fed);
                let window: Vec<i32> = r.seq[r.fed..r.fed + take].to_vec();
                let (fed, adapter) = (r.fed, r.adapter);
                match kv.prefill_chunk(
                    self.rt,
                    row,
                    &window,
                    fed,
                    bucket,
                    adapter.map(|id| id.ix() as i32),
                ) {
                    Ok(()) => {
                        out.spent += bucket;
                        budget_left = budget_left.saturating_sub(bucket);
                        st.rows[row].as_mut().expect("pending row").fed += take;
                    }
                    Err(e) => {
                        // mid-chunk rejection (e.g. a defective window):
                        // release the row — garbage K/V from the fed
                        // windows is masked by position, like any
                        // recycled row's — and the adapter pin with it
                        crate::util::log::warn(format!(
                            "chunked admission of row {row} failed mid-window: {e:#}"
                        ));
                        st.rows[row] = None;
                        kv.abort_admission(row);
                        if let (Some(ad), Some(id)) = (st.adapters.as_mut(), adapter) {
                            ad.release(id).expect("pending row held a pin");
                        }
                        out.failed.push(row);
                        break;
                    }
                }
            }
            if let Some(r) = st.rows[row].as_mut() {
                if !r.admitted && r.fed == r.seq.len() {
                    kv.admission_finish(row, &r.seq)?;
                    r.admitted = true;
                    out.completed.push(row);
                }
            }
        }
        Ok(out)
    }

    /// One decode step for the whole grid, then one sampled token per
    /// active row *under that row's own config*. Work per token is (B, S)
    /// on the reforward path, (B, 1) on the kv path — the sampling,
    /// bookkeeping and events are identical. Returns one event per
    /// sampled token; empty when no row is actively decoding.
    pub fn decode_step(&self, rng: &mut Rng) -> Result<Vec<StepOut>> {
        let mut st = self.state.borrow_mut();
        let st = &mut *st;
        if !st.rows.iter().flatten().any(|r| r.admitted && !r.done) {
            return Ok(vec![]);
        }
        let (b, s) = (self.batch_size(), self.seq_len());
        // the kv path yields (B, V) rows, the reforward path (B, S, V)
        // grids sliced at each row's frontier (borrowed, not copied —
        // this is the per-token hot path)
        // per-row adapter routing: each row gathers its own adapter slot;
        // free / adapter-less rows gather slot 0 (harmless: their samples
        // are discarded or, with no store attached, slot 0 is zero-init)
        let adapter_ix: Option<Vec<i32>> = self.adapter_group.as_ref().map(|_| {
            st.rows
                .iter()
                .map(|slot| {
                    slot.as_ref()
                        .and_then(|r| r.adapter)
                        .map_or(0, |id| id.ix() as i32)
                })
                .collect()
        });
        if st.spec.is_some() {
            return self.spec_decode_step(st, adapter_ix, rng);
        }
        let kv_logits;
        let re_out;
        let (lf, full_grid): (&[f32], bool) = match st.kv.as_mut() {
            Some(kv) => {
                let feeds: Vec<Option<(i32, usize)>> = st
                    .rows
                    .iter()
                    .map(|slot| {
                        // rows mid-chunked-admission ride as off-grid
                        // dummies: no slots entry, no cache write
                        slot.as_ref()
                            .filter(|r| r.admitted)
                            .map(|r| (*r.seq.last().expect("row has a frontier"), r.seq.len() - 1))
                    })
                    .collect();
                kv_logits = kv.step(self.rt, &feeds, adapter_ix.as_deref())?;
                (kv_logits.f32s(), false)
            }
            None => {
                let mut toks = Vec::with_capacity(b * s);
                for slot in &st.rows {
                    match slot {
                        Some(r) => toks.extend(crate::tokenizer::pad_to(&r.seq, s)),
                        None => toks.extend(std::iter::repeat(PAD).take(s)),
                    }
                }
                st.sess.set(self.rt, "tokens", &Tensor::from_i32(&[b, s], toks))?;
                if let (Some(g), Some(ix)) = (self.adapter_group.as_ref(), &adapter_ix) {
                    st.sess
                        .set(self.rt, &g.input, &Tensor::from_i32(&[b], ix.clone()))?;
                }
                re_out = st.sess.run(self.rt)?;
                (re_out.get("logits")?.f32s(), true)
            }
        };
        let mut events = vec![];
        for (i, slot) in st.rows.iter_mut().enumerate() {
            let Some(r) = slot.as_mut() else { continue };
            if r.done || !r.admitted {
                continue;
            }
            let at = if full_grid { i * s + (r.seq.len() - 1) } else { i };
            let row_logits = &lf[at * self.vocab..(at + 1) * self.vocab];
            let next = sample_token(row_logits, r.cfg, rng);
            r.seq.push(next);
            r.generated += 1;
            let finished = next == EOS
                || next == PAD
                || r.generated >= r.cfg.max_new
                || r.seq.len() >= s;
            r.done = finished;
            events.push(StepOut { row: i, token: next, finished, accepted: false });
        }
        Ok(events)
    }

    /// The speculative decode step: one [`SpecDecoder::round`] over the
    /// grid, then per-row bookkeeping. Greedy rows may emit several
    /// tokens per call (accepted drafts + the correction token); sampled
    /// rows emit exactly one, host-sampled from their verify logits.
    fn spec_decode_step(
        &self,
        st: &mut DecodeState,
        adapter_ix: Option<Vec<i32>>,
        rng: &mut Rng,
    ) -> Result<Vec<StepOut>> {
        let s = self.seq_len();
        let feeds: Vec<Option<SpecFeed>> = st
            .rows
            .iter()
            .map(|slot| {
                slot.as_ref().filter(|r| r.admitted && !r.done).map(|r| SpecFeed {
                    token: *r.seq.last().expect("row has a frontier"),
                    pos: r.seq.len() - 1,
                    greedy: r.cfg.temperature <= 0.0,
                    max_emit: (r.cfg.max_new - r.generated)
                        .min(s - r.seq.len())
                        .max(1),
                })
            })
            .collect();
        let spec = st.spec.as_mut().expect("spec_decode_step needs a SpecDecoder");
        let outs = spec.round(self.rt, &feeds, adapter_ix.as_deref())?;
        let mut events = vec![];
        for (i, (slot, out)) in st.rows.iter_mut().zip(outs).enumerate() {
            let Some(r) = slot.as_mut() else { continue };
            let Some(out) = out else { continue };
            let mut push = |r: &mut RowState, next: i32, accepted: bool| {
                r.seq.push(next);
                r.generated += 1;
                let finished = next == EOS
                    || next == PAD
                    || r.generated >= r.cfg.max_new
                    || r.seq.len() >= s;
                r.done = finished;
                events.push(StepOut { row: i, token: next, finished, accepted });
            };
            match out {
                SpecRowOut::Greedy { tokens, accepted } => {
                    for (j, next) in tokens.into_iter().enumerate() {
                        push(r, next, j < accepted);
                        if r.done {
                            // EOS/PAD inside the window: the rest of the
                            // verified run does not exist on the other
                            // paths either — drop it
                            break;
                        }
                    }
                }
                SpecRowOut::Logits(lg) => {
                    let next = sample_token(&lg, r.cfg, rng);
                    push(r, next, false);
                }
            }
        }
        Ok(events)
    }

    /// Remove a row and return its generated token ids (response segment
    /// only, trimmed at the first EOS/PAD). Frees the slot — and its cache
    /// slot on the kv path — for admission.
    pub fn take(&self, row: usize) -> Option<Vec<i32>> {
        let mut st = self.state.borrow_mut();
        let st = &mut *st;
        let r = st.rows.get_mut(row)?.take()?;
        // a row taken mid-chunked-admission has no slots ledger entry yet;
        // its partially filled cache is garbage masked by position, like
        // any recycled row's
        if r.admitted {
            if let Some(kv) = st.kv.as_mut() {
                kv.evict(row).expect("occupied row has a cache slot");
            }
            if let Some(spec) = st.spec.as_mut() {
                spec.evict(row).expect("occupied row has cache slots");
            }
        } else if let Some(kv) = st.kv.as_mut() {
            // taken mid-chunked-admission: no slots ledger entry, but a
            // paged row already holds planned blocks — release them
            kv.abort_admission(row);
        }
        if let (Some(ad), Some(id)) = (st.adapters.as_mut(), r.adapter) {
            ad.release(id).expect("row held an adapter reference");
        }
        let tail = &r.seq[r.start..];
        let end = tail
            .iter()
            .position(|&t| t == EOS || t == PAD)
            .unwrap_or(tail.len());
        Some(tail[..end].to_vec())
    }

    /// Generate completions for up to `batch_size` prompts at once (all
    /// rows must be free). Returns the generated token ids per prompt.
    pub fn generate_batch(
        &self,
        prompts: &[String],
        cfg: SampleCfg,
        rng: &mut Rng,
    ) -> Result<Vec<Vec<i32>>> {
        let b = self.batch_size();
        assert!(prompts.len() <= b);
        anyhow::ensure!(
            self.free_rows() == b,
            "generate_batch needs an idle generator ({} rows in flight)",
            b - self.free_rows()
        );
        let rows = self.admit_all(prompts.iter().map(|p| (p.as_str(), None)), cfg)?;
        loop {
            if self.decode_step(rng)?.is_empty() {
                break;
            }
        }
        rows.into_iter()
            .map(|r| self.take(r).context("decode row vanished"))
            .collect()
    }

    /// Admit a sequence of (prompt, adapter) requests; on any failure the
    /// already-admitted rows are taken back (freeing their slots, cache
    /// rows and adapter pins) before the error propagates, so a partial
    /// batch never strands the generator non-idle.
    fn admit_all<'a>(
        &self,
        reqs: impl Iterator<Item = (&'a str, Option<AdapterId>)>,
        cfg: SampleCfg,
    ) -> Result<Vec<usize>> {
        let mut rows = vec![];
        for (prompt, adapter) in reqs {
            match self.prefill_adapter(prompt, cfg, adapter) {
                Ok(row) => rows.push(row),
                Err(e) => {
                    for row in rows {
                        // lint: allow(result, "rollback of already-admitted rows; `e` is propagated")
                        let _ = self.take(row);
                    }
                    return Err(e);
                }
            }
        }
        Ok(rows)
    }

    /// Like [`Generator::generate_batch`] but each prompt routes through
    /// its own registered adapter — a heterogeneous-adapter batch through
    /// one compiled artifact.
    pub fn generate_adapter_batch(
        &self,
        reqs: &[(String, AdapterId)],
        cfg: SampleCfg,
        rng: &mut Rng,
    ) -> Result<Vec<Vec<i32>>> {
        let b = self.batch_size();
        assert!(reqs.len() <= b);
        ensure!(
            self.free_rows() == b,
            "generate_adapter_batch needs an idle generator ({} rows in flight)",
            b - self.free_rows()
        );
        let rows =
            self.admit_all(reqs.iter().map(|(p, id)| (p.as_str(), Some(*id))), cfg)?;
        loop {
            if self.decode_step(rng)?.is_empty() {
                break;
            }
        }
        rows.into_iter()
            .map(|r| self.take(r).context("decode row vanished"))
            .collect()
    }

    /// Convenience: generate text responses for prompts (chunked to fit B).
    pub fn complete(&self, prompts: &[String], cfg: SampleCfg, rng: &mut Rng) -> Result<Vec<String>> {
        let mut out = vec![];
        for chunk in prompts.chunks(self.batch_size()) {
            for ids in self.generate_batch(chunk, cfg, rng)? {
                out.push(self.tk.decode(&ids));
            }
        }
        Ok(out)
    }
}

/// Stage every freshly registered adapter slot into the given sessions
/// (the plain session, the kv pair's, and/or the speculative target
/// trio's); the device upload happens at each session's next run
/// (Session-level dirty tracking), so back-to-back registrations upload
/// once.
fn stage_dirty_adapters(
    ad: &mut AdapterStore,
    sess: &mut Session,
    mut kv: Option<&mut KvDecoder>,
    mut spec: Option<&mut SpecDecoder>,
) -> Result<()> {
    for id in ad.drain_dirty() {
        let w = ad.weights(id)?;
        sess.put_group("adapter", id.ix(), w)?;
        if let Some(kv) = kv.as_deref_mut() {
            kv.put_adapter(id.ix(), w)?;
        }
        if let Some(spec) = spec.as_deref_mut() {
            spec.put_adapter(id.ix(), w)?;
        }
    }
    Ok(())
}

/// Stage a just-registered adapter; on failure (e.g. an `.lmck` trained
/// for a different config whose factor shapes don't fit the stack), the
/// registration is rolled back so the store never resolves a name to a
/// half-staged slot — the slot stays free for a corrected retry.
fn finish_registration(
    ad: &mut AdapterStore,
    id: AdapterId,
    sess: &mut Session,
    kv: Option<&mut KvDecoder>,
    spec: Option<&mut SpecDecoder>,
) -> Result<AdapterId> {
    match stage_dirty_adapters(ad, sess, kv, spec) {
        Ok(()) => Ok(id),
        Err(e) => {
            ad.evict(id).expect("just-registered adapter has no refs");
            Err(e)
        }
    }
}

/// Left-truncate an encoded prompt to fit the (S-long) decode grid while
/// always leaving generation room: at least one slot, at most
/// `min(max_new, S/2)`. Returns `(ids, start)` where `start` is the
/// frontier (generation begins at `seq[start]`); the kept ids are the
/// prompt's *suffix* (recency matters more than the head) and are never
/// empty, so every admitted row has a frontier token to decode from.
pub fn truncate_prompt(ids: Vec<i32>, s: usize, max_new: usize) -> (Vec<i32>, usize) {
    let room = max_new.min(s / 2).max(1);
    let keep = s.saturating_sub(room).max(1);
    let ids = if ids.len() > keep {
        ids[ids.len() - keep..].to_vec()
    } else {
        ids
    };
    let start = ids.len();
    (ids, start)
}

/// Greedy / temperature+top-p sampling from a logits row.
pub fn sample_token(logits: &[f32], cfg: SampleCfg, rng: &mut Rng) -> i32 {
    if cfg.temperature <= 0.0 {
        return argmax(logits) as i32;
    }
    // softmax with temperature
    let t = cfg.temperature as f32;
    let mx = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut probs: Vec<(usize, f32)> = logits
        .iter()
        .enumerate()
        .map(|(i, &l)| (i, ((l - mx) / t).exp()))
        .collect();
    let z: f32 = probs.iter().map(|(_, p)| p).sum();
    for p in probs.iter_mut() {
        p.1 /= z;
    }
    // top-p nucleus
    probs.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let mut cum = 0.0;
    let mut cut = probs.len();
    for (i, (_, p)) in probs.iter().enumerate() {
        cum += p;
        if cum >= cfg.top_p as f32 {
            cut = i + 1;
            break;
        }
    }
    probs.truncate(cut);
    let ws: Vec<f32> = probs.iter().map(|(_, p)| *p).collect();
    probs[rng.weighted(&ws)].0 as i32
}

/// Greedy argmax (`max_by`'s last-wins tie-break); shared with the
/// speculative verifier so accepted drafts and sampled tokens agree
/// bit-for-bit on ties.
pub(crate) fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax() {
        let mut rng = Rng::new(0);
        let logits = [0.1, 2.0, -1.0, 1.9];
        let t = sample_token(
            &logits,
            SampleCfg {
                temperature: 0.0,
                ..Default::default()
            },
            &mut rng,
        );
        assert_eq!(t, 1);
    }

    #[test]
    fn low_temperature_concentrates() {
        let mut rng = Rng::new(1);
        let logits = [0.0, 5.0, 0.0, 0.0];
        let cfg = SampleCfg {
            temperature: 0.2,
            top_p: 1.0,
            max_new: 1,
        };
        let hits = (0..100)
            .filter(|_| sample_token(&logits, cfg, &mut rng) == 1)
            .count();
        assert!(hits > 95);
    }

    #[test]
    fn top_p_restricts_support() {
        let mut rng = Rng::new(2);
        // one dominant token, tiny tail; top_p=0.5 keeps only the head
        let logits = [10.0, 0.0, 0.0, 0.0];
        let cfg = SampleCfg {
            temperature: 1.0,
            top_p: 0.5,
            max_new: 1,
        };
        for _ in 0..50 {
            assert_eq!(sample_token(&logits, cfg, &mut rng), 0);
        }
    }

    #[test]
    fn truncate_prompt_exactly_filling_grid_leaves_generation_room() {
        let s = 32;
        let ids: Vec<i32> = (0..s as i32).collect();
        let (kept, start) = truncate_prompt(ids.clone(), s, 8);
        assert_eq!(start, kept.len());
        assert_eq!(kept.len(), s - 8, "reserves the full max_new");
        assert_eq!(kept, ids[8..].to_vec(), "keeps the prompt suffix");
        assert!(start <= s - 1, "at least one generation slot remains");
    }

    #[test]
    fn truncate_prompt_longer_than_grid_keeps_suffix() {
        let s = 16;
        let ids: Vec<i32> = (0..100).collect();
        let (kept, start) = truncate_prompt(ids, s, 4);
        assert_eq!(kept.len(), s - 4);
        assert_eq!(kept, (88..100).collect::<Vec<i32>>());
        assert!(start + 4 <= s, "full budget fits the grid");
    }

    #[test]
    fn truncate_prompt_empty_prompt_passes_through() {
        // an "empty" prompt still carries BOS + SEP from tokenization
        let (kept, start) = truncate_prompt(vec![BOS, SEP], 32, 8);
        assert_eq!(kept, vec![BOS, SEP]);
        assert_eq!(start, 2);
    }

    #[test]
    fn truncate_prompt_huge_budget_caps_at_half_grid() {
        let s = 32;
        let ids: Vec<i32> = (0..s as i32).collect();
        let (kept, start) = truncate_prompt(ids, s, 1000);
        assert_eq!(kept.len(), s / 2, "budget reservation caps at S/2");
        assert_eq!(start, s / 2);
    }

    #[test]
    fn truncate_prompt_degenerate_grids_always_keep_a_frontier_token() {
        // the old inline logic computed keep = s - min(max_new, s/2),
        // which for s <= 1 left keep == s (no generation slot) — the
        // frontier invariant must survive every degenerate combination
        for s in 1..=4 {
            for max_new in 0..=4 {
                let ids: Vec<i32> = (0..10).collect();
                let (kept, start) = truncate_prompt(ids, s, max_new);
                assert!(!kept.is_empty(), "s={s} max_new={max_new}");
                assert_eq!(start, kept.len());
                assert!(start <= s.saturating_sub(1).max(1),
                        "s={s} max_new={max_new}: start {start} leaves no room");
            }
        }
    }

    #[test]
    fn per_row_cfg_changes_sampling_support() {
        // the same logits row sampled under two different per-row configs:
        // tight nucleus pins the head token, wide nucleus reaches the tail
        let logits = [2.0, 1.9, 1.8, 1.7];
        let tight = SampleCfg { temperature: 1.0, top_p: 0.25, max_new: 1 };
        let wide = SampleCfg { temperature: 1.0, top_p: 1.0, max_new: 1 };
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            assert_eq!(sample_token(&logits, tight, &mut rng), 0);
        }
        let mut seen = std::collections::HashSet::new();
        for _ in 0..200 {
            seen.insert(sample_token(&logits, wide, &mut rng));
        }
        assert!(seen.len() > 1, "wide nucleus never left the head token");
    }
}

//! Synthetic data substrate.
//!
//! The paper fine-tunes on OpenHermes / OpenOrca, aligns on
//! FineWeb + OpenWebMath, and evaluates on Alpaca + MathQA/GSM8K/CSR/
//! HumanEval. None of those are available offline, so this module builds a
//! *synthetic micro-world* with the same structure (DESIGN.md §2):
//!
//! * [`tasks`] — atomic skills (arithmetic, comparison, string ops,
//!   sequences, analogies, categories, tiny programs) with checkable answers
//! * [`corpus`] — the general pre-train/alignment corpus: declarative
//!   statements of those skills + Zipf filler text (FineWeb+OpenWebMath
//!   stand-in)
//! * [`instruct`] — three instruction distributions: `hermes` and `orca`
//!   (different template + task mixes; the two SFT datasets) and `alpaca`
//!   (held-out template mix; the out-of-domain test set)
//! * [`downstream`] — evaluation sets: math (choice + strict match), six
//!   CSR option-scoring subtasks, and program-synthesis tasks with a
//!   stack-machine checker (HumanEval stand-in)
//!
//! All generators are deterministic in the seed.

pub mod corpus;
pub mod downstream;
pub mod instruct;
pub mod tasks;

use crate::tensor::Tensor;
use crate::tokenizer::{loss_mask, pad_to, Tokenizer};

/// A (tokens, loss_mask) batch matching a train/eval artifact's (B, S+1) /
/// (B, S) shapes.
#[derive(Debug, Clone)]
pub struct Batch {
    pub tokens: Tensor,    // (B, S+1) i32
    pub loss_mask: Tensor, // (B, S) f32
}

/// Pack token sequences into a batch for an artifact with batch `b` and
/// sequence length `s` (tokens get s+1 slots: inputs + shifted targets).
pub fn make_batch(seqs: &[Vec<i32>], b: usize, s: usize, answer_only: bool) -> Batch {
    assert_eq!(seqs.len(), b, "batch size mismatch");
    let mut toks = Vec::with_capacity(b * (s + 1));
    let mut mask = Vec::with_capacity(b * s);
    for seq in seqs {
        let padded = pad_to(seq, s + 1);
        mask.extend(loss_mask(&padded, answer_only));
        toks.extend(padded);
    }
    Batch {
        tokens: Tensor::from_i32(&[b, s + 1], toks),
        loss_mask: Tensor::from_f32(&[b, s], mask),
    }
}

/// An instruction/response example plus its provenance.
#[derive(Debug, Clone)]
pub struct Example {
    pub instruction: String,
    pub response: String,
}

impl Example {
    pub fn tokens(&self, tk: &Tokenizer) -> Vec<i32> {
        tk.encode_pair(&self.instruction, &self.response)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokenizer::{PAD, SEP};

    #[test]
    fn make_batch_shapes() {
        let tk = Tokenizer::new();
        let e = Example {
            instruction: "2+2=".into(),
            response: "4".into(),
        };
        let seqs = vec![e.tokens(&tk), e.tokens(&tk)];
        let b = make_batch(&seqs, 2, 16, true);
        assert_eq!(b.tokens.shape, vec![2, 17]);
        assert_eq!(b.loss_mask.shape, vec![2, 16]);
        // SEP present, padding after EOS
        assert!(b.tokens.i32s().contains(&SEP));
        assert!(b.tokens.i32s().contains(&PAD));
        // answer-only mask is sparse but nonzero
        let ones: f32 = b.loss_mask.f32s().iter().sum();
        assert!(ones >= 2.0 && ones < 16.0);
    }
}

//! Atomic skills of the synthetic micro-world.
//!
//! Each generator returns (instruction, answer) strings with a checkable
//! ground truth. The same skills appear (a) declaratively in the pre-train
//! corpus, (b) as instruction data in `instruct`, and (c) as evaluation
//! items in `downstream` — mirroring how real LLM skills flow from
//! pre-training into SFT and benchmarks.

use crate::util::rng::Rng;

/// A categorical world for analogy / membership / odd-one-out tasks.
pub const CATEGORIES: &[(&str, &[&str])] = &[
    ("animal", &["cat", "dog", "fox", "owl", "bee", "ant"]),
    ("plant", &["oak", "fern", "rose", "ivy", "moss", "palm"]),
    ("metal", &["iron", "gold", "zinc", "lead", "tin"]),
    ("color", &["red", "blue", "green", "pink", "gray"]),
    ("tool", &["saw", "axe", "drill", "file", "clamp"]),
    ("fruit", &["apple", "pear", "plum", "fig", "melon"]),
];

pub fn category_of(word: &str) -> Option<&'static str> {
    CATEGORIES
        .iter()
        .find(|(_, ws)| ws.contains(&word))
        .map(|(c, _)| *c)
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Skill {
    Add,
    Sub,
    Mul,
    Chain,    // two-step arithmetic (GSM8K-style)
    Max,
    Reverse,
    Succ,     // next number in arithmetic sequence
    Analogy,  // a:cat_a :: b:?
    Member,   // "x is a <cat>" true/false
    OddOne,   // odd-one-out
    Program,  // tiny stack-machine synthesis (HumanEval-style)
}

pub const ALL_SKILLS: &[Skill] = &[
    Skill::Add,
    Skill::Sub,
    Skill::Mul,
    Skill::Chain,
    Skill::Max,
    Skill::Reverse,
    Skill::Succ,
    Skill::Analogy,
    Skill::Member,
    Skill::OddOne,
    Skill::Program,
];

/// A generated item: question text, gold answer text, and (for choice
/// tasks) distractor answers.
#[derive(Debug, Clone)]
pub struct Item {
    pub skill: Skill,
    pub question: String,
    pub answer: String,
    pub distractors: Vec<String>,
}

pub fn gen(skill: Skill, rng: &mut Rng) -> Item {
    match skill {
        Skill::Add => {
            let a = rng.range(0, 20);
            let b = rng.range(0, 20);
            num_item(skill, format!("{a}+{b}="), a + b, rng)
        }
        Skill::Sub => {
            let a = rng.range(5, 25);
            let b = rng.range(0, a);
            num_item(skill, format!("{a}-{b}="), a - b, rng)
        }
        Skill::Mul => {
            let a = rng.range(2, 10);
            let b = rng.range(2, 10);
            num_item(skill, format!("{a}*{b}="), a * b, rng)
        }
        Skill::Chain => {
            // "a=3. b=a+4. b*2=?" — two dependent steps
            let a = rng.range(1, 8);
            let c = rng.range(1, 8);
            let d = rng.range(2, 4);
            let b = a + c;
            num_item(
                skill,
                format!("a={a}. b=a+{c}. b*{d}=?"),
                b * d,
                rng,
            )
        }
        Skill::Max => {
            let a = rng.range(0, 50);
            let mut b = rng.range(0, 50);
            if b == a {
                b += 1;
            }
            num_item(skill, format!("max({a},{b})="), a.max(b), rng)
        }
        Skill::Reverse => {
            let n = rng.range(3, 6) as usize;
            let s: String = (0..n)
                .map(|_| (b'a' + rng.below(6) as u8) as char)
                .collect();
            let rev: String = s.chars().rev().collect();
            let mut distractors = vec![s.clone()];
            let mut shuf: Vec<char> = s.chars().collect();
            rng.shuffle(&mut shuf);
            let shuf: String = shuf.into_iter().collect();
            if shuf != rev {
                distractors.push(shuf);
            }
            Item {
                skill,
                question: format!("rev({s})="),
                answer: rev,
                distractors,
            }
        }
        Skill::Succ => {
            let start = rng.range(0, 10);
            let step = rng.range(1, 5);
            let q = format!(
                "{} {} {} ?",
                start,
                start + step,
                start + 2 * step
            );
            num_item(skill, q, start + 3 * step, rng)
        }
        Skill::Analogy => {
            let ci = rng.below(CATEGORIES.len());
            let mut cj = rng.below(CATEGORIES.len());
            if cj == ci {
                cj = (cj + 1) % CATEGORIES.len();
            }
            let (ca, wa) = CATEGORIES[ci];
            let (cb, wb) = CATEGORIES[cj];
            let a = *rng.choice(wa);
            let b = *rng.choice(wb);
            let mut distractors = vec![ca.to_string()];
            let ck = (cj + 1 + rng.below(CATEGORIES.len() - 1)) % CATEGORIES.len();
            if CATEGORIES[ck].0 != cb {
                distractors.push(CATEGORIES[ck].0.to_string());
            }
            Item {
                skill,
                question: format!("{a}:{ca}::{b}:"),
                answer: cb.to_string(),
                distractors,
            }
        }
        Skill::Member => {
            let ci = rng.below(CATEGORIES.len());
            let (cat, ws) = CATEGORIES[ci];
            let w = *rng.choice(ws);
            let truth = rng.below(2) == 0;
            let asked_cat = if truth {
                cat.to_string()
            } else {
                let mut cj = rng.below(CATEGORIES.len());
                if cj == ci {
                    cj = (cj + 1) % CATEGORIES.len();
                }
                CATEGORIES[cj].0.to_string()
            };
            Item {
                skill,
                question: format!("{w} is a {asked_cat}. "),
                answer: if truth { "yes".into() } else { "no".into() },
                distractors: vec![if truth { "no".into() } else { "yes".into() }],
            }
        }
        Skill::OddOne => {
            let ci = rng.below(CATEGORIES.len());
            let mut cj = rng.below(CATEGORIES.len());
            if cj == ci {
                cj = (cj + 1) % CATEGORIES.len();
            }
            let (_, ws) = CATEGORIES[ci];
            let idx = rng.sample_indices(ws.len(), 2);
            let a = ws[idx[0]];
            let b = ws[idx[1]];
            let odd = *rng.choice(CATEGORIES[cj].1);
            // random position for the odd word
            let mut words = [a, b, odd];
            let pos = rng.below(3);
            words.swap(2, pos);
            Item {
                skill,
                question: format!("odd({},{},{})=", words[0], words[1], words[2]),
                answer: odd.to_string(),
                distractors: vec![a.to_string(), b.to_string()],
            }
        }
        Skill::Program => {
            let (prog, spec) = gen_program(rng);
            Item {
                skill,
                question: spec,
                answer: prog.render(),
                distractors: vec![],
            }
        }
    }
}

fn num_item(skill: Skill, question: String, answer: i64, rng: &mut Rng) -> Item {
    let mut ds = vec![];
    while ds.len() < 3 {
        let delta = rng.range(-4, 5);
        let cand = answer + if delta == 0 { 5 } else { delta };
        let cand_s = cand.to_string();
        if cand != answer && !ds.contains(&cand_s) {
            ds.push(cand_s);
        }
    }
    Item {
        skill,
        question,
        answer: answer.to_string(),
        distractors: ds,
    }
}

// ---------------------------------------------------------------------------
// Tiny stack-machine programs (HumanEval stand-in)
// ---------------------------------------------------------------------------

/// Ops of the one-register machine programs the model must synthesise.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Op {
    Add(i64),
    Mul(i64),
    Sub(i64),
    Neg,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Program(pub Vec<Op>);

impl Program {
    pub fn eval(&self, x: i64) -> i64 {
        let mut v = x;
        for op in &self.0 {
            v = match op {
                Op::Add(k) => v + k,
                Op::Mul(k) => v * k,
                Op::Sub(k) => v - k,
                Op::Neg => -v,
            };
        }
        v
    }

    pub fn render(&self) -> String {
        self.0
            .iter()
            .map(|op| match op {
                Op::Add(k) => format!("add {k}"),
                Op::Mul(k) => format!("mul {k}"),
                Op::Sub(k) => format!("sub {k}"),
                Op::Neg => "neg".to_string(),
            })
            .collect::<Vec<_>>()
            .join(";")
    }

    /// Parse the textual form emitted by the model; returns None on any
    /// syntax error (counts as an incorrect sample for pass@k).
    pub fn parse(s: &str) -> Option<Program> {
        let mut ops = vec![];
        for part in s.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let mut it = part.split_whitespace();
            let op = it.next()?;
            match op {
                "neg" => ops.push(Op::Neg),
                "add" | "mul" | "sub" => {
                    let k: i64 = it.next()?.parse().ok()?;
                    match op {
                        "add" => ops.push(Op::Add(k)),
                        "mul" => ops.push(Op::Mul(k)),
                        _ => ops.push(Op::Sub(k)),
                    }
                }
                _ => return None,
            }
            if it.next().is_some() {
                return None;
            }
        }
        if ops.is_empty() {
            None
        } else {
            Some(Program(ops))
        }
    }
}

/// Generate a random 1-2 op program plus its I/O-example spec string.
pub fn gen_program(rng: &mut Rng) -> (Program, String) {
    let n_ops = 1 + rng.below(2);
    let mut ops = vec![];
    for _ in 0..n_ops {
        ops.push(match rng.below(4) {
            0 => Op::Add(rng.range(1, 6)),
            1 => Op::Mul(rng.range(2, 4)),
            2 => Op::Sub(rng.range(1, 6)),
            _ => Op::Neg,
        });
    }
    let prog = Program(ops);
    let x1 = rng.range(0, 6);
    let x2 = x1 + rng.range(1, 5);
    let spec = format!(
        "f({x1})={} f({x2})={} f=",
        prog.eval(x1),
        prog.eval(x2)
    );
    (prog, spec)
}

/// Check a candidate program text against the spec's hidden tests: the two
/// shown examples plus three held-out inputs derived from the gold program.
pub fn check_program(gold: &Program, candidate: &str) -> bool {
    match Program::parse(candidate) {
        None => false,
        Some(p) => (-2..3).all(|x| p.eval(x) == gold.eval(x)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic_answers_correct() {
        let mut rng = Rng::new(0);
        for _ in 0..50 {
            let it = gen(Skill::Add, &mut rng);
            let q = it.question.trim_end_matches('=');
            let parts: Vec<i64> = q.split('+').map(|x| x.parse().unwrap()).collect();
            assert_eq!((parts[0] + parts[1]).to_string(), it.answer);
            assert!(!it.distractors.contains(&it.answer));
        }
    }

    #[test]
    fn chain_is_two_step() {
        let mut rng = Rng::new(1);
        let it = gen(Skill::Chain, &mut rng);
        assert!(it.question.contains("a=") && it.question.contains("b=a+"));
    }

    #[test]
    fn reverse_answer_is_reversed_question() {
        let mut rng = Rng::new(2);
        for _ in 0..20 {
            let it = gen(Skill::Reverse, &mut rng);
            let inner = it
                .question
                .trim_start_matches("rev(")
                .trim_end_matches(")=");
            let rev: String = inner.chars().rev().collect();
            assert_eq!(rev, it.answer);
        }
    }

    #[test]
    fn analogy_answer_is_true_category() {
        let mut rng = Rng::new(3);
        for _ in 0..30 {
            let it = gen(Skill::Analogy, &mut rng);
            // question "a:ca::b:" — answer must be b's category
            let b = it
                .question
                .split("::")
                .nth(1)
                .unwrap()
                .trim_end_matches(':');
            assert_eq!(category_of(b), Some(it.answer.as_str()), "{}", it.question);
        }
    }

    #[test]
    fn member_truthfulness() {
        let mut rng = Rng::new(4);
        for _ in 0..30 {
            let it = gen(Skill::Member, &mut rng);
            let mut parts = it.question.trim().splitn(4, ' ');
            let w = parts.next().unwrap();
            let _is = parts.next();
            let _a = parts.next();
            let cat = parts.next().unwrap().trim_end_matches('.');
            let truth = category_of(w) == Some(cat);
            assert_eq!(it.answer == "yes", truth, "{}", it.question);
        }
    }

    #[test]
    fn program_roundtrip_and_check() {
        let mut rng = Rng::new(5);
        for _ in 0..50 {
            let (p, _spec) = gen_program(&mut rng);
            let text = p.render();
            let parsed = Program::parse(&text).unwrap();
            assert_eq!(parsed, p);
            assert!(check_program(&p, &text));
        }
        let (p, _) = gen_program(&mut rng);
        assert!(!check_program(&p, "frobnicate 3"));
        assert!(!check_program(&p, ""));
    }

    #[test]
    fn program_semantically_equivalent_counts() {
        // "add 2;add 3" must pass against gold "add 5"
        let gold = Program(vec![Op::Add(5)]);
        assert!(check_program(&gold, "add 2;add 3"));
        assert!(!check_program(&gold, "add 4"));
    }

    #[test]
    fn all_skills_generate() {
        let mut rng = Rng::new(6);
        for &s in ALL_SKILLS {
            let it = gen(s, &mut rng);
            assert!(!it.question.is_empty());
            assert!(!it.answer.is_empty());
        }
    }
}

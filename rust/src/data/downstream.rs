//! Downstream evaluation sets (MathQA / GSM8K / CSR / HumanEval stand-ins).
//!
//! Generators here produce the items + prompts; the actual scoring (option
//! log-likelihood, greedy decode, temperature sampling + pass@k) lives in
//! `coordinator::downstream`, which drives the eval/logits artifacts.

use super::tasks::{self, Item, Skill};
use crate::util::rng::Rng;

/// A multiple-choice item: prompt, options (gold first — shuffled by the
/// evaluator when rendering letters), or a strict-match generation target.
#[derive(Debug, Clone)]
pub struct EvalItem {
    pub prompt: String,
    pub gold: String,
    /// gold + distractors for option-scored tasks; empty for generative
    pub options: Vec<String>,
    pub item: Item,
}

/// The six CSR subtasks (stand-ins for Arc-C/Arc-E/HellaSwag/OBQA/PIQA/
/// WinoGrande): all option-scored with 1-shot prompts.
pub const CSR_SUBTASKS: &[(&str, Skill)] = &[
    ("member", Skill::Member),
    ("analogy", Skill::Analogy),
    ("oddone", Skill::OddOne),
    ("compare", Skill::Max),
    ("sequence", Skill::Succ),
    ("reverse", Skill::Reverse),
];

/// One solved example of the same skill, prepended for n-shot prompting.
fn shot_prefix(skill: Skill, rng: &mut Rng, shots: usize) -> String {
    let mut out = String::new();
    for _ in 0..shots {
        let it = tasks::gen(skill, rng);
        if it.question.ends_with('=') || it.question.ends_with(':') {
            out.push_str(&format!("{}{} ", it.question, it.answer));
        } else {
            out.push_str(&format!("{} {} ", it.question, it.answer));
        }
    }
    out
}

fn eval_item(skill: Skill, rng: &mut Rng, shots: usize) -> EvalItem {
    let prefix = shot_prefix(skill, rng, shots);
    let it = tasks::gen(skill, rng);
    let mut options = vec![it.answer.clone()];
    options.extend(it.distractors.iter().cloned());
    EvalItem {
        prompt: format!("{prefix}{}", it.question),
        gold: it.answer.clone(),
        options,
        item: it,
    }
}

/// MathQA stand-in: single-step arithmetic, option-scored, 1-shot.
pub fn mathqa_set(seed: u64, n: usize) -> Vec<EvalItem> {
    let mut rng = Rng::new(seed ^ 0x6d617468);
    (0..n)
        .map(|i| {
            let skill = [Skill::Add, Skill::Sub, Skill::Mul][i % 3];
            eval_item(skill, &mut rng, 1)
        })
        .collect()
}

/// GSM8K stand-in: multi-step chains, strict-match generation. The paper
/// uses 8-shot CoT; our 64-token context supports 2 shots of the short
/// chain format (noted in DESIGN.md §3).
pub fn gsm_set(seed: u64, n: usize) -> Vec<EvalItem> {
    let mut rng = Rng::new(seed ^ 0x67736d38);
    (0..n)
        .map(|_| {
            let mut it = eval_item(Skill::Chain, &mut rng, 2);
            it.options.clear(); // generative
            it
        })
        .collect()
}

/// One CSR subtask set (1-shot, option-scored).
pub fn csr_set(subtask: &str, seed: u64, n: usize) -> Vec<EvalItem> {
    let skill = CSR_SUBTASKS
        .iter()
        .find(|(name, _)| *name == subtask)
        .map(|&(_, s)| s)
        .unwrap_or(Skill::Member);
    let mut rng = Rng::new(seed ^ 0x637372 ^ hash_name(subtask));
    (0..n).map(|_| eval_item(skill, &mut rng, 1)).collect()
}

/// HumanEval stand-in: program-synthesis specs, checked by the stack VM.
pub fn code_set(seed: u64, n: usize) -> Vec<EvalItem> {
    let mut rng = Rng::new(seed ^ 0x636f6465);
    (0..n)
        .map(|_| {
            let prefix = shot_prefix(Skill::Program, &mut rng, 1);
            let (prog, spec) = tasks::gen_program(&mut rng);
            EvalItem {
                prompt: format!("{prefix}{spec}"),
                gold: prog.render(),
                options: vec![],
                item: Item {
                    skill: Skill::Program,
                    question: spec,
                    answer: prog.render(),
                    distractors: vec![],
                },
            }
        })
        .collect()
}

fn hash_name(s: &str) -> u64 {
    s.bytes().fold(1469598103934665603u64, |h, b| {
        (h ^ b as u64).wrapping_mul(1099511628211)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mathqa_has_options_gold_first() {
        let set = mathqa_set(0, 12);
        assert_eq!(set.len(), 12);
        for it in &set {
            assert!(it.options.len() >= 3);
            assert_eq!(it.options[0], it.gold);
            assert!(it.prompt.contains('='));
        }
    }

    #[test]
    fn gsm_is_generative() {
        let set = gsm_set(0, 4);
        for it in &set {
            assert!(it.options.is_empty());
            // 2-shot prefix: the prompt contains two solved chains + query
            assert!(it.prompt.matches("a=").count() >= 3, "{}", it.prompt);
        }
    }

    #[test]
    fn csr_subtasks_all_generate() {
        for (name, _) in CSR_SUBTASKS {
            let set = csr_set(name, 1, 8);
            assert_eq!(set.len(), 8);
            assert!(set.iter().all(|it| it.options.len() >= 2));
        }
    }

    #[test]
    fn csr_subtasks_differ() {
        let a = csr_set("member", 1, 4);
        let b = csr_set("analogy", 1, 4);
        assert_ne!(a[0].prompt, b[0].prompt);
    }

    #[test]
    fn code_items_check_against_gold() {
        let set = code_set(0, 10);
        for it in &set {
            let gold_prog = tasks::Program::parse(&it.gold).unwrap();
            assert!(tasks::check_program(&gold_prog, &it.gold));
        }
    }

    #[test]
    fn sets_are_deterministic() {
        assert_eq!(mathqa_set(5, 3)[0].prompt, mathqa_set(5, 3)[0].prompt);
    }
}

//! General pre-train / alignment corpus (FineWeb + OpenWebMath stand-in).
//!
//! Two mixed streams, mirroring the paper's §B alignment mix:
//! * "web" text — templated sentences over a Zipf-weighted vocabulary
//!   (declarative facts about the category world, connective filler)
//! * "math" text — declarative arithmetic/sequence statements
//!
//! Pre-training on this corpus is what gives the proxy base models the
//! knowledge that pruning disturbs and alignment (Eq. 8, same generator,
//! different seed) restores.

use super::tasks::{self, Skill};
use crate::util::rng::Rng;

const CONNECTIVES: &[&str] = &["and", "but", "so", "then", "also", "thus"];
const VERBS: &[&str] = &["sees", "likes", "finds", "has", "meets", "helps"];

/// One declarative "web" sentence.
fn web_sentence(rng: &mut Rng) -> String {
    match rng.below(3) {
        0 => {
            // category fact: "a fox is an animal."
            let (cat, ws) = *rng.choice(tasks::CATEGORIES);
            let w = *rng.choice(ws);
            format!("{w} is a {cat}.")
        }
        1 => {
            // relational filler with Zipf-ish word choice
            let (_, ws1) = *rng.choice(tasks::CATEGORIES);
            let (_, ws2) = *rng.choice(tasks::CATEGORIES);
            let a = ws1[zipf(rng, ws1.len())];
            let b = ws2[zipf(rng, ws2.len())];
            let v = *rng.choice(VERBS);
            let c = *rng.choice(CONNECTIVES);
            format!("the {a} {v} the {b} {c} waits.")
        }
        _ => {
            // odd-one-out / comparison facts
            let it = tasks::gen(Skill::OddOne, rng);
            format!("{}{}.", it.question, it.answer)
        }
    }
}

/// One declarative "math" sentence.
fn math_sentence(rng: &mut Rng) -> String {
    let skill = match rng.below(6) {
        0 => Skill::Add,
        1 => Skill::Sub,
        2 => Skill::Mul,
        3 => Skill::Max,
        4 => Skill::Succ,
        _ => Skill::Chain,
    };
    let it = tasks::gen(skill, rng);
    if it.question.ends_with('=') {
        format!("{}{}.", it.question, it.answer)
    } else {
        format!("{} {}.", it.question, it.answer)
    }
}

/// Streaming corpus generator: emits token sequences of exactly `seq_len+1`
/// tokens (packed sentences, no padding — pre-training uses every slot).
pub struct Corpus {
    rng: Rng,
    /// fraction of math sentences in the mix (paper mixes FineWeb with
    /// OpenWebMath; we default to an even blend)
    pub math_frac: f64,
    buf: Vec<i32>,
}

impl Corpus {
    pub fn new(seed: u64, math_frac: f64) -> Corpus {
        Corpus {
            rng: Rng::new(seed),
            math_frac,
            buf: vec![],
        }
    }

    /// Next packed sequence of len+1 tokens.
    pub fn next_seq(&mut self, len: usize) -> Vec<i32> {
        let tk = crate::tokenizer::Tokenizer::new();
        while self.buf.len() < len + 1 {
            let s = if self.rng.f64() < self.math_frac {
                math_sentence(&mut self.rng)
            } else {
                web_sentence(&mut self.rng)
            };
            self.buf.extend(tk.encode(&s));
            self.buf.push(b' ' as i32);
        }
        let out: Vec<i32> = self.buf.drain(..len + 1).collect();
        out
    }

    pub fn next_seqs(&mut self, n: usize, len: usize) -> Vec<Vec<i32>> {
        (0..n).map(|_| self.next_seq(len)).collect()
    }
}

/// Zipf-ish index sampler: P(i) ∝ 1/(i+1).
fn zipf(rng: &mut Rng, n: usize) -> usize {
    let ws: Vec<f32> = (0..n).map(|i| 1.0 / (i + 1) as f32).collect();
    rng.weighted(&ws)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequences_have_exact_length_and_no_pad() {
        let mut c = Corpus::new(0, 0.5);
        for _ in 0..5 {
            let s = c.next_seq(64);
            assert_eq!(s.len(), 65);
            assert!(s.iter().all(|&t| (0..256).contains(&t)));
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let mut a = Corpus::new(7, 0.5);
        let mut b = Corpus::new(7, 0.5);
        assert_eq!(a.next_seq(32), b.next_seq(32));
        let mut c = Corpus::new(8, 0.5);
        assert_ne!(a.next_seq(32), c.next_seq(32));
    }

    #[test]
    fn math_frac_controls_mix() {
        let mut all_math = Corpus::new(1, 1.0);
        let s = all_math.next_seq(128);
        let text = crate::tokenizer::Tokenizer::new().decode(&s);
        // math sentences contain digits
        assert!(text.chars().any(|c| c.is_ascii_digit()), "{text}");
    }
}

//! Instruction-tuning distributions (OpenHermes / OpenOrca / Alpaca
//! stand-ins).
//!
//! The three datasets share the same underlying skills but differ in
//! template style and task mixture — exactly the structure the paper's
//! experiments need: two SFT sets with distinct distributions (Figs. 3 vs
//! 4) and a third held-out distribution for out-of-domain perplexity.

use super::tasks::{self, Skill};
use super::Example;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dataset {
    Hermes,
    Orca,
    Alpaca,
}

impl Dataset {
    pub fn from_str(s: &str) -> Option<Dataset> {
        match s {
            "hermes" => Some(Dataset::Hermes),
            "orca" => Some(Dataset::Orca),
            "alpaca" => Some(Dataset::Alpaca),
            _ => None,
        }
    }

    /// Task mixture (skill, weight): Hermes skews to arithmetic/string/code,
    /// Orca to reasoning-flavoured tasks, Alpaca is a uniform blend.
    fn mixture(&self) -> Vec<(Skill, f32)> {
        match self {
            Dataset::Hermes => vec![
                (Skill::Add, 3.0),
                (Skill::Sub, 2.0),
                (Skill::Mul, 2.0),
                (Skill::Chain, 2.0),
                (Skill::Reverse, 2.0),
                (Skill::Program, 2.0),
                (Skill::Max, 1.0),
                (Skill::Member, 1.0),
            ],
            Dataset::Orca => vec![
                (Skill::Chain, 3.0),
                (Skill::Analogy, 2.0),
                (Skill::OddOne, 2.0),
                (Skill::Member, 2.0),
                (Skill::Succ, 2.0),
                (Skill::Max, 2.0),
                (Skill::Add, 1.0),
                (Skill::Program, 1.0),
            ],
            Dataset::Alpaca => tasks::ALL_SKILLS.iter().map(|&s| (s, 1.0)).collect(),
        }
    }

    /// Render an item in the dataset's template style.
    fn render(&self, q: &str, a: &str) -> Example {
        match self {
            Dataset::Hermes => Example {
                instruction: format!("Q: {q}"),
                response: format!("A: {a}"),
            },
            Dataset::Orca => Example {
                instruction: format!("solve: {q}"),
                response: a.to_string(),
            },
            Dataset::Alpaca => Example {
                instruction: format!("### {q} ->"),
                response: a.to_string(),
            },
        }
    }

    pub fn seed_salt(&self) -> u64 {
        match self {
            Dataset::Hermes => 0x4865726d,
            Dataset::Orca => 0x4f726361,
            Dataset::Alpaca => 0x416c7061,
        }
    }
}

/// Deterministic instruction-data stream.
pub struct InstructGen {
    pub dataset: Dataset,
    rng: Rng,
    mixture: Vec<(Skill, f32)>,
    weights: Vec<f32>,
}

impl InstructGen {
    /// `split`: 0 = train, 1 = test (disjoint streams).
    pub fn new(dataset: Dataset, seed: u64, split: u64) -> InstructGen {
        let mixture = dataset.mixture();
        let weights = mixture.iter().map(|&(_, w)| w).collect();
        InstructGen {
            dataset,
            rng: Rng::new(seed ^ dataset.seed_salt() ^ (split << 32)),
            mixture,
            weights,
        }
    }

    pub fn next(&mut self) -> (Example, tasks::Item) {
        let k = self.rng.weighted(&self.weights);
        let skill = self.mixture[k].0;
        let item = tasks::gen(skill, &mut self.rng);
        let ex = self.dataset.render(&item.question, &item.answer);
        (ex, item)
    }

    pub fn batch_examples(&mut self, n: usize) -> Vec<Example> {
        (0..n).map(|_| self.next().0).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn datasets_render_differently() {
        let mut h = InstructGen::new(Dataset::Hermes, 0, 0);
        let mut o = InstructGen::new(Dataset::Orca, 0, 0);
        let (eh, _) = h.next();
        let (eo, _) = o.next();
        assert!(eh.instruction.starts_with("Q: "));
        assert!(eo.instruction.starts_with("solve: "));
    }

    #[test]
    fn splits_are_disjoint_streams() {
        let mut train = InstructGen::new(Dataset::Hermes, 1, 0);
        let mut test = InstructGen::new(Dataset::Hermes, 1, 1);
        let a: Vec<String> = (0..5).map(|_| train.next().0.instruction).collect();
        let b: Vec<String> = (0..5).map(|_| test.next().0.instruction).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn deterministic() {
        let mut a = InstructGen::new(Dataset::Orca, 2, 0);
        let mut b = InstructGen::new(Dataset::Orca, 2, 0);
        for _ in 0..10 {
            assert_eq!(a.next().0.instruction, b.next().0.instruction);
        }
    }

    #[test]
    fn mixtures_have_distinct_skill_profiles() {
        let count = |ds: Dataset| {
            let mut g = InstructGen::new(ds, 3, 0);
            let mut programs = 0;
            for _ in 0..300 {
                if g.next().1.skill == Skill::Program {
                    programs += 1;
                }
            }
            programs
        };
        // Hermes is code-heavier than Orca (2/15 vs 1/15 weight)
        assert!(count(Dataset::Hermes) > count(Dataset::Orca));
    }
}

//! Batched generation service (Table 8's serving-side counterpart and the
//! `serve_generate` example).
//!
//! A deliberately small vLLM-style loop: callers enqueue requests, the
//! worker drains the queue into dynamic batches of up to the artifact's
//! batch size, runs the generator, and delivers completions. Single-threaded
//! by design (the PJRT CPU client is not Sync, and the box has one core);
//! the queue/batcher structure is what Table 8 measures.

use crate::coordinator::generate::{Generator, SampleCfg};
use crate::util::rng::Rng;
use anyhow::Result;
use std::collections::VecDeque;
use std::time::Instant;

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub cfg: SampleCfg,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub text: String,
    pub latency_ms: f64,
    pub batch_size: usize,
}

pub struct Server<'r> {
    gen: Generator<'r>,
    queue: VecDeque<(Request, Instant)>,
    next_id: u64,
    rng: Rng,
    pub stats: ServerStats,
}

#[derive(Debug, Default, Clone)]
pub struct ServerStats {
    pub served: usize,
    pub batches: usize,
    pub total_latency_ms: f64,
    pub total_batch_occupancy: f64,
}

impl<'r> Server<'r> {
    pub fn new(gen: Generator<'r>, seed: u64) -> Server<'r> {
        Server {
            gen,
            queue: VecDeque::new(),
            next_id: 0,
            rng: Rng::new(seed),
            stats: ServerStats::default(),
        }
    }

    pub fn enqueue(&mut self, prompt: impl Into<String>, cfg: SampleCfg) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back((
            Request {
                id,
                prompt: prompt.into(),
                cfg,
            },
            Instant::now(),
        ));
        id
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Drain one dynamic batch (grouped by sampling config) and serve it.
    pub fn step(&mut self) -> Result<Vec<Response>> {
        if self.queue.is_empty() {
            return Ok(vec![]);
        }
        let b = self.gen.batch_size();
        // group the head-of-queue requests sharing the head's SampleCfg
        let head_cfg = self.queue[0].0.cfg;
        let mut batch = vec![];
        let mut rest = VecDeque::new();
        while let Some((req, t0)) = self.queue.pop_front() {
            if batch.len() < b
                && req.cfg.temperature == head_cfg.temperature
                && req.cfg.max_new == head_cfg.max_new
            {
                batch.push((req, t0));
            } else {
                rest.push_back((req, t0));
            }
        }
        self.queue = rest;
        let prompts: Vec<String> = batch.iter().map(|(r, _)| r.prompt.clone()).collect();
        let ids = self.gen.generate_batch(&prompts, head_cfg, &mut self.rng)?;
        let tk = crate::tokenizer::Tokenizer::new();
        let out: Vec<Response> = batch
            .iter()
            .zip(ids)
            .map(|((req, t0), toks)| Response {
                id: req.id,
                text: tk.decode(&toks),
                latency_ms: t0.elapsed().as_secs_f64() * 1e3,
                batch_size: batch.len(),
            })
            .collect();
        self.stats.served += out.len();
        self.stats.batches += 1;
        self.stats.total_batch_occupancy += batch.len() as f64 / b as f64;
        self.stats.total_latency_ms += out.iter().map(|r| r.latency_ms).sum::<f64>();
        Ok(out)
    }

    /// Serve until the queue is empty; returns all responses.
    pub fn drain(&mut self) -> Result<Vec<Response>> {
        let mut all = vec![];
        while self.pending() > 0 {
            all.extend(self.step()?);
        }
        Ok(all)
    }
}

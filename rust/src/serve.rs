//! Continuous-batching generation service (Table 8's serving-side
//! counterpart and the `serve_generate` example).
//!
//! A deliberately small vLLM-style scheduler: callers enqueue requests,
//! `step` admits queued requests into *free batch rows* — mid-decode, so a
//! new request never waits for the current batch to finish — then runs one
//! decode step across all in-flight rows. Sampling is host-side and
//! per-row, so a batch freely mixes [`SampleCfg`]s (temperature, top-p,
//! budget) and the FIFO queue has no head-of-line blocking: any request
//! fits any free row. Single-threaded by design (the PJRT CPU client is
//! not Sync, and the box has one core); the scheduler structure is what
//! the serving benches measure.
//!
//! The decode backend is abstracted as [`DecodeEngine`] — the real
//! [`Generator`] in production, the deterministic [`SimEngine`] for
//! scheduler tests and benches that must run without artifacts.
//!
//! Requests may name an [`AdapterId`] (DESIGN.md §2c): the scheduler is
//! adapter-oblivious by construction — any adapter fits any free row
//! because the stacked artifact gathers per row — so a mixed-adapter
//! queue has no head-of-line blocking either. [`ServerStats`] keeps a
//! per-adapter lane breakdown on top of the aggregate counters.

use crate::coordinator::adapters::AdapterId;
use crate::coordinator::generate::{Generator, SampleCfg, StepOut};
use crate::coordinator::speculative::SpecStats;
use crate::tokenizer::Tokenizer;
use crate::util::log;
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, VecDeque};
use std::time::Instant;

/// Row-oriented decode backend the scheduler drives.
pub trait DecodeEngine {
    fn batch_size(&self) -> usize;
    fn free_rows(&self) -> usize;
    /// Admit a prompt into a free row (routed through `adapter` when the
    /// request names one); returns the row index.
    fn prefill(&mut self, prompt: &str, cfg: SampleCfg, adapter: Option<AdapterId>)
        -> Result<usize>;
    /// Sample one token for every active row (each under its own config).
    fn decode_step(&mut self, rng: &mut Rng) -> Result<Vec<StepOut>>;
    /// Remove a row, returning its generated ids and freeing the slot.
    fn take(&mut self, row: usize) -> Option<Vec<i32>>;
    fn decode_text(&self, ids: &[i32]) -> String;
    /// Cumulative speculative-decoding counters, when the engine decodes
    /// on the speculative path (None everywhere else).
    fn spec_stats(&self) -> Option<SpecStats> {
        None
    }
}

impl DecodeEngine for Generator<'_> {
    fn batch_size(&self) -> usize {
        Generator::batch_size(self)
    }

    fn free_rows(&self) -> usize {
        Generator::free_rows(self)
    }

    fn prefill(
        &mut self,
        prompt: &str,
        cfg: SampleCfg,
        adapter: Option<AdapterId>,
    ) -> Result<usize> {
        Generator::prefill_adapter(self, prompt, cfg, adapter)
    }

    fn decode_step(&mut self, rng: &mut Rng) -> Result<Vec<StepOut>> {
        Generator::decode_step(self, rng)
    }

    fn take(&mut self, row: usize) -> Option<Vec<i32>> {
        Generator::take(self, row)
    }

    fn decode_text(&self, ids: &[i32]) -> String {
        self.tokenizer().decode(ids)
    }

    fn spec_stats(&self) -> Option<SpecStats> {
        Generator::spec_stats(self)
    }
}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub cfg: SampleCfg,
    /// adapter the request decodes under (None = the engine's single
    /// baked-in weights; required by adapter-store engines)
    pub adapter: Option<AdapterId>,
}

/// Stats label for an adapter lane ("base" for adapter-less requests).
pub fn adapter_label(adapter: Option<AdapterId>) -> String {
    adapter.map_or_else(|| "base".to_string(), |id| id.to_string())
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub text: String,
    /// generated tokens after EOS/PAD trimming
    pub tokens: usize,
    /// enqueue → first sampled token
    pub ttft_ms: f64,
    /// enqueue → completion
    pub latency_ms: f64,
    /// in-flight rows during this request's final decode step
    pub batch_rows: usize,
    /// adapter the request decoded under
    pub adapter: Option<AdapterId>,
}

/// Per-request bookkeeping while its row decodes.
struct InFlight {
    id: u64,
    enqueued: Instant,
    ttft_ms: Option<f64>,
    adapter: Option<AdapterId>,
}

pub struct Server<E> {
    pub engine: E,
    queue: VecDeque<(Request, Instant)>,
    /// in-flight request per engine row
    inflight: Vec<Option<InFlight>>,
    next_id: u64,
    rng: Rng,
    pub stats: ServerStats,
}

/// Per-adapter slice of the serving stats (keyed by [`AdapterId`]; the
/// `None` lane holds adapter-less requests).
#[derive(Debug, Default, Clone)]
pub struct AdapterLane {
    /// requests admitted into a row
    pub requests: usize,
    /// requests completed
    pub served: usize,
    /// tokens sampled for this adapter's rows
    pub tokens: usize,
    /// of those, tokens that came from an accepted speculative draft
    /// (0 off the speculative path)
    pub accepted_tokens: usize,
    pub total_ttft_ms: f64,
    pub total_latency_ms: f64,
}

impl AdapterLane {
    /// Fraction of this lane's served tokens that came from accepted
    /// drafts (the per-lane acceptance signal; the engine-wide rate over
    /// *proposed* drafts lives in [`ServerStats::spec`]).
    pub fn draft_accept_share(&self) -> f64 {
        self.accepted_tokens as f64 / self.tokens.max(1) as f64
    }

    pub fn mean_ttft_ms(&self) -> f64 {
        self.total_ttft_ms / self.served.max(1) as f64
    }

    pub fn mean_latency_ms(&self) -> f64 {
        self.total_latency_ms / self.served.max(1) as f64
    }

    /// This adapter's share of decode throughput: its sampled tokens over
    /// the server's total decode wall time (lanes share every batch, so
    /// per-lane wall time is not separable — shares sum to the aggregate).
    pub fn tokens_per_sec(&self, decode_ms: f64) -> f64 {
        self.tokens as f64 / (decode_ms / 1e3).max(1e-9)
    }
}

#[derive(Debug, Default, Clone)]
pub struct ServerStats {
    pub served: usize,
    pub admitted: usize,
    pub decode_steps: usize,
    /// wall time spent inside decode steps
    pub decode_ms: f64,
    /// tokens sampled across all decode steps
    pub total_tokens: usize,
    pub total_ttft_ms: f64,
    pub total_latency_ms: f64,
    /// summed per-step (in-flight rows / batch size)
    pub total_batch_occupancy: f64,
    /// summed enqueue → admission wait over admitted requests
    pub total_queue_wait_ms: f64,
    /// most requests ever waiting in the queue at once
    pub peak_queue_depth: usize,
    /// requests dropped at admission (e.g. naming an unregistered
    /// adapter) — a bad request never takes the server down
    pub rejected: usize,
    /// tokens that came from accepted speculative drafts (0 off the
    /// speculative path)
    pub accepted_tokens: usize,
    /// the engine's speculative counters (draft/verify step counts,
    /// acceptance rate over proposed drafts), snapshotted each step;
    /// None when the engine does not decode speculatively
    pub spec: Option<SpecStats>,
    /// per-adapter breakdown, keyed by the request's adapter
    pub per_adapter: BTreeMap<Option<AdapterId>, AdapterLane>,
}

impl ServerStats {
    fn lane(&mut self, adapter: Option<AdapterId>) -> &mut AdapterLane {
        self.per_adapter.entry(adapter).or_default()
    }

    /// Mean time-to-first-token over completed requests.
    pub fn mean_ttft_ms(&self) -> f64 {
        self.total_ttft_ms / self.served.max(1) as f64
    }

    pub fn mean_latency_ms(&self) -> f64 {
        self.total_latency_ms / self.served.max(1) as f64
    }

    /// Steady-state decode throughput: sampled tokens per second of decode
    /// wall time.
    pub fn tokens_per_sec(&self) -> f64 {
        self.total_tokens as f64 / (self.decode_ms / 1e3).max(1e-9)
    }

    pub fn mean_occupancy(&self) -> f64 {
        self.total_batch_occupancy / self.decode_steps.max(1) as f64
    }

    /// Mean enqueue → admission wait (queue pressure; 0 when every request
    /// found a free row immediately).
    pub fn mean_queue_wait_ms(&self) -> f64 {
        self.total_queue_wait_ms / self.admitted.max(1) as f64
    }

    /// Fraction of served tokens that came from accepted drafts.
    pub fn draft_accept_share(&self) -> f64 {
        self.accepted_tokens as f64 / self.total_tokens.max(1) as f64
    }

    /// Acceptance rate over *proposed* drafts, when the engine reported
    /// speculative counters.
    pub fn acceptance_rate(&self) -> Option<f64> {
        self.spec.map(|s| s.acceptance_rate())
    }
}

impl<E: DecodeEngine> Server<E> {
    pub fn new(engine: E, seed: u64) -> Server<E> {
        let b = engine.batch_size();
        Server {
            engine,
            queue: VecDeque::new(),
            inflight: (0..b).map(|_| None).collect(),
            next_id: 0,
            rng: Rng::new(seed),
            stats: ServerStats::default(),
        }
    }

    pub fn enqueue(&mut self, prompt: impl Into<String>, cfg: SampleCfg) -> u64 {
        self.enqueue_adapter(prompt, cfg, None)
    }

    /// Enqueue a request decoding under a registered adapter. FIFO with
    /// free-row admission as ever: adapters never partition the batch, so
    /// a mixed-adapter queue keeps zero head-of-line blocking.
    pub fn enqueue_adapter(
        &mut self,
        prompt: impl Into<String>,
        cfg: SampleCfg,
        adapter: Option<AdapterId>,
    ) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back((
            Request { id, prompt: prompt.into(), cfg, adapter },
            Instant::now(),
        ));
        self.stats.peak_queue_depth = self.stats.peak_queue_depth.max(self.queue.len());
        id
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn in_flight(&self) -> usize {
        self.inflight.iter().flatten().count()
    }

    /// Admit queued requests into free rows (FIFO; any config fits any
    /// row, so nothing blocks behind a mismatched head request). A
    /// request whose admission fails — an unregistered adapter, a prefill
    /// error — is rejected and dropped rather than aborting the batch the
    /// other requests are decoding in; but when *every* admission failed
    /// and nothing is in flight, the server cannot make progress and the
    /// last error propagates (a broken engine must not silently drain the
    /// queue into `rejected`).
    fn admit(&mut self) -> Result<()> {
        let mut admitted_now = 0usize;
        let mut last_err = None;
        while self.engine.free_rows() > 0 {
            let Some((req, t0)) = self.queue.pop_front() else { break };
            let row = match self.engine.prefill(&req.prompt, req.cfg, req.adapter) {
                Ok(row) => row,
                Err(e) => {
                    log::warn(format!("request {} rejected at admission: {e:#}", req.id));
                    self.stats.rejected += 1;
                    last_err = Some(e);
                    continue;
                }
            };
            admitted_now += 1;
            let slot = self
                .inflight
                .get_mut(row)
                .with_context(|| format!("engine admitted into out-of-range row {row}"))?;
            if slot.is_some() {
                bail!("engine admitted into occupied row {row}");
            }
            *slot = Some(InFlight {
                id: req.id,
                enqueued: t0,
                ttft_ms: None,
                adapter: req.adapter,
            });
            self.stats.admitted += 1;
            self.stats.lane(req.adapter).requests += 1;
            self.stats.total_queue_wait_ms += t0.elapsed().as_secs_f64() * 1e3;
        }
        if let Some(e) = last_err {
            if admitted_now == 0 && self.in_flight() == 0 {
                return Err(e.context("every admission failed with no requests in flight"));
            }
        }
        Ok(())
    }

    /// One scheduler tick: admit into free rows, run one decode step,
    /// return the requests that completed this step.
    pub fn step(&mut self) -> Result<Vec<Response>> {
        self.admit()?;
        let active = self.in_flight();
        if active == 0 {
            return Ok(vec![]);
        }
        let t0 = Instant::now();
        let events = self.engine.decode_step(&mut self.rng)?;
        self.stats.decode_ms += t0.elapsed().as_secs_f64() * 1e3;
        self.stats.decode_steps += 1;
        self.stats.total_batch_occupancy += active as f64 / self.engine.batch_size() as f64;
        if events.is_empty() {
            bail!("decode engine made no progress with {active} requests in flight");
        }
        let mut done_rows = vec![];
        for ev in &events {
            let f = self
                .inflight
                .get_mut(ev.row)
                .and_then(|s| s.as_mut())
                .with_context(|| format!("decode event for idle row {}", ev.row))?;
            self.stats.total_tokens += 1;
            let adapter = f.adapter;
            if f.ttft_ms.is_none() {
                f.ttft_ms = Some(f.enqueued.elapsed().as_secs_f64() * 1e3);
            }
            if ev.accepted {
                self.stats.accepted_tokens += 1;
            }
            let lane = self.stats.lane(adapter);
            lane.tokens += 1;
            if ev.accepted {
                lane.accepted_tokens += 1;
            }
            if ev.finished {
                done_rows.push(ev.row);
            }
        }
        self.stats.spec = self.engine.spec_stats();
        let mut out = vec![];
        for row in done_rows {
            let f = self.inflight[row].take().expect("finished row tracked");
            let ids = self.engine.take(row).unwrap_or_default();
            let ttft_ms = f.ttft_ms.unwrap_or_default();
            let latency_ms = f.enqueued.elapsed().as_secs_f64() * 1e3;
            self.stats.served += 1;
            self.stats.total_ttft_ms += ttft_ms;
            self.stats.total_latency_ms += latency_ms;
            let lane = self.stats.lane(f.adapter);
            lane.served += 1;
            lane.total_ttft_ms += ttft_ms;
            lane.total_latency_ms += latency_ms;
            out.push(Response {
                id: f.id,
                text: self.engine.decode_text(&ids),
                tokens: ids.len(),
                ttft_ms,
                latency_ms,
                batch_rows: active,
                adapter: f.adapter,
            });
        }
        Ok(out)
    }

    /// Serve until queue and batch are empty; returns all responses in
    /// completion order.
    pub fn drain(&mut self) -> Result<Vec<Response>> {
        let mut all = vec![];
        while self.pending() > 0 || self.in_flight() > 0 {
            all.extend(self.step()?);
        }
        Ok(all)
    }
}

/// Deterministic in-process decode engine for scheduler tests and benches.
///
/// Each admitted request emits `max_new` copies of a marker token derived
/// from *its own* [`SampleCfg`] ([`SimEngine::marker`]) — or, when the
/// request routes an adapter, from that [`AdapterId`]
/// ([`SimEngine::adapter_marker`]: adapter slot i emits `'A' + i`). A test
/// can therefore assert both that a request was sampled under the config
/// it asked for *and* that the scheduler routed it through the adapter it
/// named, without artifacts or the PJRT runtime.
///
/// [`SimEngine::with_spec`] turns on *drafter mode*: each decode step
/// runs one simulated draft/verify round per row (draft length K,
/// configurable per-draft acceptance probability), emitting multi-token
/// bursts — so scheduler behaviour under speculative decoding, including
/// a 0%-acceptance rejection storm, is testable artifact-free too.
pub struct SimEngine {
    batch: usize,
    rows: Vec<Option<SimRow>>,
    tk: Tokenizer,
    /// drafter simulation: each decode step runs one draft/verify round
    /// per active row instead of emitting a single token
    spec: Option<SimSpec>,
    /// (prompt, cfg, adapter) in admission order, for test assertions
    pub admissions: Vec<(String, SampleCfg, Option<AdapterId>)>,
}

/// Simulated drafter: every draft is accepted independently with
/// probability `accept_prob`, so a round emits `accepted-prefix + 1`
/// tokens — the scheduler sees exactly the multi-token event bursts (and,
/// at 0%, the rejection storm) a real [`SpecDecoder`] produces, without
/// artifacts.
struct SimSpec {
    k: usize,
    accept_prob: f64,
    rng: Rng,
    stats: SpecStats,
}

struct SimRow {
    cfg: SampleCfg,
    adapter: Option<AdapterId>,
    emitted: Vec<i32>,
    budget: usize,
}

impl SimEngine {
    pub fn new(batch: usize) -> SimEngine {
        SimEngine {
            batch,
            rows: (0..batch).map(|_| None).collect(),
            tk: Tokenizer::new(),
            spec: None,
            admissions: vec![],
        }
    }

    /// A [`SimEngine`] in drafter mode: draft length `k`, per-draft
    /// acceptance probability `accept_prob` in [0, 1].
    pub fn with_spec(batch: usize, k: usize, accept_prob: f64, seed: u64) -> SimEngine {
        let mut e = SimEngine::new(batch);
        e.spec = Some(SimSpec {
            k: k.max(1),
            accept_prob: accept_prob.clamp(0.0, 1.0),
            rng: Rng::new(seed),
            stats: SpecStats::default(),
        });
        e
    }

    /// The token every step of an adapter-less request emits: its top-p as
    /// a printable byte (e.g. `top_p = 0.9` → 90 → `'Z'`).
    pub fn marker(cfg: &SampleCfg) -> i32 {
        (cfg.top_p * 100.0).round() as i32 % 256
    }

    /// The token an adapter-routed request emits: the adapter id as a
    /// capital letter (`a0` → `'A'`, `a1` → `'B'`, ...), so the emitted
    /// text *is* the routing record.
    pub fn adapter_marker(adapter: Option<AdapterId>, cfg: &SampleCfg) -> i32 {
        match adapter {
            Some(id) => b'A' as i32 + (id.ix() as i32 % 26),
            None => Self::marker(cfg),
        }
    }
}

impl DecodeEngine for SimEngine {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn free_rows(&self) -> usize {
        self.rows.iter().filter(|r| r.is_none()).count()
    }

    fn prefill(
        &mut self,
        prompt: &str,
        cfg: SampleCfg,
        adapter: Option<AdapterId>,
    ) -> Result<usize> {
        let row = self
            .rows
            .iter()
            .position(|r| r.is_none())
            .context("sim prefill: no free row")?;
        self.admissions.push((prompt.to_string(), cfg, adapter));
        self.rows[row] = Some(SimRow {
            cfg,
            adapter,
            emitted: vec![],
            budget: cfg.max_new.max(1),
        });
        Ok(row)
    }

    fn decode_step(&mut self, _rng: &mut Rng) -> Result<Vec<StepOut>> {
        let mut events = vec![];
        for (i, slot) in self.rows.iter_mut().enumerate() {
            let Some(r) = slot.as_mut() else { continue };
            if r.emitted.len() >= r.budget {
                continue; // finished, awaiting take
            }
            let token = Self::adapter_marker(r.adapter, &r.cfg);
            match self.spec.as_mut() {
                None => {
                    r.emitted.push(token);
                    events.push(StepOut {
                        row: i,
                        token,
                        finished: r.emitted.len() >= r.budget,
                        accepted: false,
                    });
                }
                Some(sp) => {
                    // one draft/verify round: k_eff drafts, accept the
                    // prefix that survives the coin flips, +1 correction
                    // the +1 correction token must fit the row's budget
                    let k_eff = sp.k.min(r.budget - r.emitted.len() - 1);
                    let mut accepted = 0;
                    while accepted < k_eff && sp.rng.f64() < sp.accept_prob {
                        accepted += 1;
                    }
                    sp.stats.rounds += 1;
                    sp.stats.draft_steps += if k_eff > 0 { k_eff + 1 } else { 0 };
                    sp.stats.verify_steps += 1;
                    sp.stats.drafted_tokens += k_eff;
                    sp.stats.accepted_tokens += accepted;
                    sp.stats.emitted_tokens += accepted + 1;
                    for j in 0..accepted + 1 {
                        r.emitted.push(token);
                        events.push(StepOut {
                            row: i,
                            token,
                            finished: r.emitted.len() >= r.budget,
                            accepted: j < accepted,
                        });
                    }
                }
            }
        }
        Ok(events)
    }

    fn take(&mut self, row: usize) -> Option<Vec<i32>> {
        self.rows.get_mut(row)?.take().map(|r| r.emitted)
    }

    fn decode_text(&self, ids: &[i32]) -> String {
        self.tk.decode(ids)
    }

    fn spec_stats(&self) -> Option<SpecStats> {
        self.spec.as_ref().map(|s| s.stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(top_p: f64, max_new: usize) -> SampleCfg {
        SampleCfg { temperature: 1.0, top_p, max_new }
    }

    /// Regression for the old `Server::step` grouping bug: requests were
    /// batched by (temperature, max_new) only, so a request with a
    /// different top_p was silently served under the head request's
    /// config. With per-row SampleCfg both decode together, each under its
    /// own config.
    #[test]
    fn two_requests_with_different_top_p_sample_under_their_own_cfg() {
        let mut srv = Server::new(SimEngine::new(2), 0);
        let a = srv.enqueue("alpha", cfg(0.90, 3)); // marker 90 = 'Z'
        let b = srv.enqueue("beta", cfg(0.50, 3)); // marker 50 = '2'
        let rs = srv.drain().unwrap();
        assert_eq!(rs.len(), 2);
        let ra = rs.iter().find(|r| r.id == a).unwrap();
        let rb = rs.iter().find(|r| r.id == b).unwrap();
        assert_eq!(ra.text, "ZZZ", "request a must sample under top_p=0.90");
        assert_eq!(rb.text, "222", "request b must sample under top_p=0.50");
        // and they shared the batch: 3 decode steps total, not 3 + 3
        assert_eq!(srv.stats.decode_steps, 3);
        assert_eq!(srv.engine.admissions.len(), 2);
        assert_eq!(srv.engine.admissions[0].1.top_p, 0.90);
        assert_eq!(srv.engine.admissions[1].1.top_p, 0.50);
    }

    /// A newly enqueued request is admitted into a freed row while an
    /// earlier request is still mid-decode (continuous batching), and a
    /// short request behind a long one is never head-of-line blocked.
    #[test]
    fn admits_mid_decode_and_short_requests_overtake_long_ones() {
        let mut srv = Server::new(SimEngine::new(2), 0);
        let r1 = srv.enqueue("one", cfg(0.9, 1));
        let r2 = srv.enqueue("two", cfg(0.9, 5));
        let r3 = srv.enqueue("three", cfg(0.9, 1));
        // tick 1: rows full with r1+r2, r3 queued; r1 completes
        let done1 = srv.step().unwrap();
        assert_eq!(done1.iter().map(|r| r.id).collect::<Vec<_>>(), vec![r1]);
        assert_eq!(srv.in_flight(), 1, "r2 still decoding");
        assert_eq!(srv.pending(), 1, "r3 still queued");
        // tick 2: r3 admitted into r1's freed row *while r2 decodes*
        let done2 = srv.step().unwrap();
        assert_eq!(srv.engine.admissions.len(), 3, "r3 admitted mid-decode");
        assert_eq!(done2.iter().map(|r| r.id).collect::<Vec<_>>(), vec![r3]);
        assert_eq!(srv.in_flight(), 1, "r2 still in flight after r3 finished");
        // r2 finishes last: completion order r1, r3, r2
        let rest = srv.drain().unwrap();
        assert_eq!(rest.iter().map(|r| r.id).collect::<Vec<_>>(), vec![r2]);
        assert_eq!(srv.stats.served, 3);
    }

    #[test]
    fn stats_track_ttft_throughput_and_occupancy() {
        let mut srv = Server::new(SimEngine::new(2), 0);
        for i in 0..4 {
            srv.enqueue(format!("req{i}"), cfg(0.95, 2 + i));
        }
        let rs = srv.drain().unwrap();
        assert_eq!(rs.len(), 4);
        let st = &srv.stats;
        assert_eq!(st.served, 4);
        assert_eq!(st.total_tokens, 2 + 3 + 4 + 5);
        assert!(st.tokens_per_sec() > 0.0 && st.tokens_per_sec().is_finite());
        assert!(st.mean_ttft_ms() >= 0.0 && st.mean_ttft_ms() <= st.mean_latency_ms());
        assert!(st.mean_occupancy() > 0.0 && st.mean_occupancy() <= 1.0);
        for r in &rs {
            assert!(r.ttft_ms <= r.latency_ms);
            assert!(r.tokens > 0);
        }
    }

    #[test]
    fn queue_pressure_stats_track_wait_and_peak_depth() {
        let mut srv = Server::new(SimEngine::new(2), 0);
        for i in 0..5 {
            srv.enqueue(format!("req{i}"), cfg(0.9, 2));
        }
        // nothing admitted yet: all five are waiting at once
        assert_eq!(srv.stats.peak_queue_depth, 5);
        assert_eq!(srv.stats.total_queue_wait_ms, 0.0);
        let rs = srv.drain().unwrap();
        assert_eq!(rs.len(), 5);
        assert_eq!(srv.stats.admitted, 5);
        // every admission recorded a (non-negative) wait; the peak is a
        // high-water mark, not reset by the drain
        assert!(srv.stats.mean_queue_wait_ms() >= 0.0);
        assert!(srv.stats.total_queue_wait_ms >= 0.0);
        assert_eq!(srv.stats.peak_queue_depth, 5);
        // an unloaded server records no queue pressure
        let mut idle = Server::new(SimEngine::new(2), 0);
        idle.enqueue("solo", cfg(0.9, 1));
        assert_eq!(idle.stats.peak_queue_depth, 1);
        idle.drain().unwrap();
        assert_eq!(idle.stats.admitted, 1);
    }

    /// The tentpole's scheduler contract: a mixed batch with >= 3 distinct
    /// adapters decodes *simultaneously* (no adapter partitions the batch)
    /// and every request's emitted stream proves it was routed through the
    /// adapter it named.
    #[test]
    fn mixed_adapter_batch_routes_each_request_through_its_own_adapter() {
        let mut srv = Server::new(SimEngine::new(4), 0);
        let a = srv.enqueue_adapter("alpha", cfg(0.9, 3), Some(AdapterId::for_slot(0)));
        let b = srv.enqueue_adapter("beta", cfg(0.9, 3), Some(AdapterId::for_slot(1)));
        let c = srv.enqueue_adapter("gamma", cfg(0.9, 3), Some(AdapterId::for_slot(2)));
        let d = srv.enqueue("delta", cfg(0.5, 3)); // adapter-less, marker '2'
        // all four decode in one batch: 3 steps total, not 4 x 3
        let rs = srv.drain().unwrap();
        assert_eq!(rs.len(), 4);
        assert_eq!(srv.stats.decode_steps, 3);
        let text = |id| rs.iter().find(|r| r.id == id).unwrap().text.clone();
        assert_eq!(text(a), "AAA", "request a must decode under adapter a0");
        assert_eq!(text(b), "BBB", "request b must decode under adapter a1");
        assert_eq!(text(c), "CCC", "request c must decode under adapter a2");
        assert_eq!(text(d), "222", "adapter-less request keeps its cfg marker");
        // the engine saw the adapters the requests named, in order
        let routed: Vec<_> = srv.engine.admissions.iter().map(|(_, _, ad)| *ad).collect();
        assert_eq!(
            routed,
            vec![Some(AdapterId::for_slot(0)), Some(AdapterId::for_slot(1)), Some(AdapterId::for_slot(2)), None]
        );
        // responses carry their adapter
        assert_eq!(rs.iter().find(|r| r.id == a).unwrap().adapter, Some(AdapterId::for_slot(0)));
    }

    /// Mixed-adapter queues keep free-row admission: an adapter never
    /// waits for same-adapter rows to free up.
    #[test]
    fn adapters_do_not_head_of_line_block_each_other() {
        let mut srv = Server::new(SimEngine::new(2), 0);
        let long = srv.enqueue_adapter("long", cfg(0.9, 5), Some(AdapterId::for_slot(0)));
        let _long2 = srv.enqueue_adapter("long2", cfg(0.9, 1), Some(AdapterId::for_slot(0)));
        let late = srv.enqueue_adapter("late", cfg(0.9, 1), Some(AdapterId::for_slot(1)));
        // tick 1: rows hold long+long2; late (different adapter) queued
        let done1 = srv.step().unwrap();
        assert_eq!(done1.len(), 1);
        // tick 2: late admitted into the freed row while long decodes
        let done2 = srv.step().unwrap();
        assert_eq!(done2.iter().map(|r| r.id).collect::<Vec<_>>(), vec![late]);
        assert!(srv.stats.served >= 2);
        let rest = srv.drain().unwrap();
        assert_eq!(rest.iter().map(|r| r.id).collect::<Vec<_>>(), vec![long]);
    }

    #[test]
    fn per_adapter_stats_break_down_requests_tokens_and_ttft() {
        let mut srv = Server::new(SimEngine::new(4), 0);
        for _ in 0..2 {
            srv.enqueue_adapter("x", cfg(0.9, 4), Some(AdapterId::for_slot(0)));
        }
        srv.enqueue_adapter("y", cfg(0.9, 2), Some(AdapterId::for_slot(1)));
        srv.enqueue("z", cfg(0.9, 3));
        srv.drain().unwrap();
        let st = &srv.stats;
        assert_eq!(st.per_adapter.len(), 3);
        let a0 = &st.per_adapter[&Some(AdapterId::for_slot(0))];
        let a1 = &st.per_adapter[&Some(AdapterId::for_slot(1))];
        let base = &st.per_adapter[&None];
        assert_eq!((a0.requests, a0.served, a0.tokens), (2, 2, 8));
        assert_eq!((a1.requests, a1.served, a1.tokens), (1, 1, 2));
        assert_eq!((base.requests, base.served, base.tokens), (1, 1, 3));
        // lanes partition the aggregate token count and throughput
        let lane_tokens: usize = st.per_adapter.values().map(|l| l.tokens).sum();
        assert_eq!(lane_tokens, st.total_tokens);
        let lane_tps: f64 = st
            .per_adapter
            .values()
            .map(|l| l.tokens_per_sec(st.decode_ms))
            .sum();
        assert!((lane_tps - st.tokens_per_sec()).abs() / st.tokens_per_sec() < 1e-6);
        for lane in st.per_adapter.values() {
            assert!(lane.mean_ttft_ms() >= 0.0);
            assert!(lane.mean_ttft_ms() <= lane.mean_latency_ms());
        }
        assert_eq!(adapter_label(Some(AdapterId::for_slot(2))), "a2");
        assert_eq!(adapter_label(None), "base");
    }

    /// An engine that refuses admission for a marker prompt — stands in
    /// for "request names an unregistered adapter".
    struct PickyEngine(SimEngine);

    impl DecodeEngine for PickyEngine {
        fn batch_size(&self) -> usize {
            self.0.batch_size()
        }
        fn free_rows(&self) -> usize {
            self.0.free_rows()
        }
        fn prefill(
            &mut self,
            prompt: &str,
            cfg: SampleCfg,
            adapter: Option<AdapterId>,
        ) -> Result<usize> {
            anyhow::ensure!(prompt != "bad", "adapter not registered");
            self.0.prefill(prompt, cfg, adapter)
        }
        fn decode_step(&mut self, rng: &mut Rng) -> Result<Vec<StepOut>> {
            self.0.decode_step(rng)
        }
        fn take(&mut self, row: usize) -> Option<Vec<i32>> {
            self.0.take(row)
        }
        fn decode_text(&self, ids: &[i32]) -> String {
            self.0.decode_text(ids)
        }
    }

    #[test]
    fn bad_request_is_rejected_without_taking_the_server_down() {
        let mut srv = Server::new(PickyEngine(SimEngine::new(2)), 0);
        let ok1 = srv.enqueue_adapter("fine", cfg(0.9, 2), Some(AdapterId::for_slot(0)));
        srv.enqueue("bad", cfg(0.9, 2));
        let ok2 = srv.enqueue_adapter("also fine", cfg(0.9, 2), Some(AdapterId::for_slot(1)));
        let rs = srv.drain().unwrap();
        let mut served: Vec<u64> = rs.iter().map(|r| r.id).collect();
        served.sort_unstable();
        assert_eq!(served, vec![ok1, ok2], "good requests survive the bad one");
        assert_eq!(srv.stats.rejected, 1);
        assert_eq!(srv.stats.served, 2);
        assert_eq!(srv.stats.admitted, 2);
    }

    #[test]
    fn engine_fault_with_no_progress_propagates() {
        // nothing in flight and every admission failing = the server
        // cannot make progress; that must surface, not drain into stats
        let mut srv = Server::new(PickyEngine(SimEngine::new(2)), 0);
        srv.enqueue("bad", cfg(0.9, 2));
        let err = srv.drain().unwrap_err().to_string();
        assert!(err.contains("no requests in flight"), "{err}");
        assert_eq!(srv.stats.rejected, 1);
        assert_eq!(srv.stats.served, 0);
    }

    /// The rejection-storm acceptance scenario: a drafter whose every
    /// draft is rejected degenerates to per-token decode. The scheduler
    /// must survive it — every request served, every row reclaimed, no
    /// token double-counted — with an acceptance rate of exactly 0.
    #[test]
    fn zero_acceptance_storm_leaks_no_rows() {
        let mut srv = Server::new(SimEngine::with_spec(2, 4, 0.0, 7), 0);
        for i in 0..6 {
            srv.enqueue(format!("req{i}"), cfg(0.9, 3 + i % 3));
        }
        let rs = srv.drain().unwrap();
        assert_eq!(rs.len(), 6);
        assert_eq!(srv.stats.served, 6);
        assert_eq!(srv.engine.free_rows(), 2, "rows leaked after the storm");
        assert_eq!(srv.in_flight(), 0);
        // 0% acceptance: every round emitted exactly the correction token
        let spec = srv.stats.spec.expect("spec engine reports counters");
        assert_eq!(spec.accepted_tokens, 0);
        assert_eq!(spec.emitted_tokens, srv.stats.total_tokens);
        assert_eq!(spec.verify_steps, srv.stats.total_tokens);
        assert_eq!(srv.stats.acceptance_rate(), Some(0.0));
        assert_eq!(srv.stats.accepted_tokens, 0);
        assert_eq!(srv.stats.draft_accept_share(), 0.0);
        // drafts were genuinely proposed (and all rejected)
        assert!(spec.drafted_tokens > 0);
    }

    /// Full acceptance: whole windows land per step; the scheduler must
    /// credit multiple tokens per row per tick and finish requests early.
    #[test]
    fn full_acceptance_emits_whole_windows_per_step() {
        let k = 3;
        let mut srv = Server::new(SimEngine::with_spec(2, k, 1.0, 7), 0);
        let a = srv.enqueue("a", cfg(0.9, 8)); // 8 tokens = 2 rounds of k+1
        let b = srv.enqueue("b", cfg(0.5, 8));
        let rs = srv.drain().unwrap();
        assert_eq!(rs.len(), 2);
        let text = |id| rs.iter().find(|r| r.id == id).unwrap().text.clone();
        assert_eq!(text(a), "Z".repeat(8), "burst tokens kept their row cfg");
        assert_eq!(text(b), "2".repeat(8));
        assert_eq!(srv.stats.decode_steps, 2, "k+1 tokens per row per step");
        assert_eq!(srv.stats.total_tokens, 16);
        let spec = srv.stats.spec.unwrap();
        assert_eq!(spec.accepted_tokens, spec.drafted_tokens);
        assert!((srv.stats.acceptance_rate().unwrap() - 1.0).abs() < 1e-12);
        // per-lane accepted tokens: k of every k+1 emitted
        let lane = &srv.stats.per_adapter[&None];
        assert_eq!(lane.tokens, 16);
        assert_eq!(lane.accepted_tokens, 12);
        assert!((lane.draft_accept_share() - 0.75).abs() < 1e-12);
    }

    /// Mid-acceptance drafter mixed with continuous batching: stats stay
    /// consistent (accepted <= drafted, emitted == served tokens) and
    /// rows keep recycling mid-decode.
    #[test]
    fn partial_acceptance_keeps_stats_consistent_under_churn() {
        let mut srv = Server::new(SimEngine::with_spec(2, 4, 0.6, 11), 3);
        for i in 0..8 {
            srv.enqueue(format!("req{i}"), cfg(0.9, 2 + i % 5));
        }
        let rs = srv.drain().unwrap();
        assert_eq!(rs.len(), 8);
        let spec = srv.stats.spec.unwrap();
        assert!(spec.accepted_tokens <= spec.drafted_tokens);
        assert_eq!(spec.emitted_tokens, srv.stats.total_tokens);
        assert_eq!(srv.stats.accepted_tokens, spec.accepted_tokens);
        let rate = srv.stats.acceptance_rate().unwrap();
        assert!((0.0..=1.0).contains(&rate));
        assert!(spec.tokens_per_verify() >= 1.0);
        // lanes still partition the totals under multi-token events
        let lane_tokens: usize =
            srv.stats.per_adapter.values().map(|l| l.tokens).sum();
        assert_eq!(lane_tokens, srv.stats.total_tokens);
        let lane_accepted: usize =
            srv.stats.per_adapter.values().map(|l| l.accepted_tokens).sum();
        assert_eq!(lane_accepted, srv.stats.accepted_tokens);
        assert_eq!(srv.engine.free_rows(), 2);
    }

    #[test]
    fn step_with_nothing_to_do_is_a_noop() {
        let mut srv = Server::new(SimEngine::new(2), 0);
        assert!(srv.step().unwrap().is_empty());
        assert_eq!(srv.stats.decode_steps, 0);
        assert!(srv.drain().unwrap().is_empty());
    }

    #[test]
    fn zero_token_budget_is_clamped_so_requests_complete() {
        let mut srv = Server::new(SimEngine::new(1), 0);
        srv.enqueue("empty", cfg(0.9, 0));
        let rs = srv.drain().unwrap();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].tokens, 1);
    }
}

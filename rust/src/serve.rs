//! Continuous-batching generation service (Table 8's serving-side
//! counterpart and the `serve_generate` example).
//!
//! A deliberately small vLLM-style scheduler: callers enqueue requests,
//! `step` admits queued requests into *free batch rows* — mid-decode, so a
//! new request never waits for the current batch to finish — then runs one
//! decode step across all in-flight rows. Sampling is host-side and
//! per-row, so a batch freely mixes [`SampleCfg`]s (temperature, top-p,
//! budget) and the FIFO queue has no head-of-line blocking: any request
//! fits any free row. Single-threaded by design (the PJRT CPU client is
//! not Sync, and the box has one core); the scheduler structure is what
//! the serving benches measure.
//!
//! The decode backend is abstracted as [`DecodeEngine`] — the real
//! [`Generator`] in production, the deterministic [`SimEngine`] for
//! scheduler tests and benches that must run without artifacts.
//!
//! Requests may name an [`AdapterId`] (DESIGN.md §2c): the scheduler is
//! adapter-oblivious by construction — any adapter fits any free row
//! because the stacked artifact gathers per row — so a mixed-adapter
//! queue has no head-of-line blocking either. [`ServerStats`] keeps a
//! per-adapter lane breakdown on top of the aggregate counters.
//!
//! [`Server::set_slo`] turns on the SLO-aware scheduler (DESIGN.md §2i):
//! requests carry a [`Priority`] class and an optional absolute deadline
//! tick ([`Server::enqueue_slo`]); admission picks the highest waiting
//! class (FIFO within a class), queued requests whose deadline already
//! passed are cancelled, and a full grid preempts one strictly-lower
//! priority in-flight row per tick for a waiting higher one — evict →
//! requeue → re-prefill from the prompt, so the re-run stream is
//! byte-identical to an unpreempted run. [`Server::set_adapter_fair_cap`]
//! bounds the rows any one adapter lane holds concurrently (a row emits
//! one token per tick, so a row cap *is* a tokens-per-tick cap), keeping
//! a hot adapter from starving the rest. Every transition is traced
//! (`Preempt`/`Cancel`/`DeadlineMiss`) and held to conservation laws by
//! `obs::audit` / `tools/trace_report.py`.


// The static mirror of this policy is `tools/loramlint` (panic-surface
// pass); both gate the same hot path. Test code is exempt on both sides.
#![cfg_attr(
    not(test),
    deny(clippy::unwrap_used, clippy::expect_used, clippy::panic, clippy::unreachable)
)]
#![cfg_attr(not(test), warn(clippy::indexing_slicing))]

use crate::coordinator::adapters::AdapterId;
use crate::coordinator::generate::{Generator, PrefillTickOut, SampleCfg, StepOut};
use crate::coordinator::kvcache::{chunk_plan, PagedKv, PagedStats, PrefillStats};
use crate::coordinator::speculative::SpecStats;
use crate::obs::trace::{self, Event};
use crate::obs::Metrics;
use crate::tokenizer::Tokenizer;
use crate::util::log;
use crate::util::rng::Rng;
use anyhow::{bail, ensure, Context, Result};
use std::cmp::Reverse;
use std::collections::{BTreeMap, VecDeque};
use std::time::Instant;

/// Failure domain of an engine fault (DESIGN.md §2j): how much blast
/// radius the scheduler must assume when an engine call errors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultDomain {
    /// one row's request is afflicted; every other row is healthy —
    /// the scheduler retries or fails just that request
    Row(usize),
    /// the whole engine misbehaved this tick (stuck tick, watchdog
    /// timeout); transient — a later tick may succeed
    Engine,
    /// the device is gone; no future tick can succeed
    Lost,
}

/// Classification an engine attaches to its most recent error, read via
/// [`DecodeEngine::last_fault`]. `kind` names a `chaos::FAULT_KINDS`
/// entry and is carried verbatim into the `Fault` trace event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultInfo {
    pub domain: FaultDomain,
    pub kind: &'static str,
}

/// Row-oriented decode backend the scheduler drives.
pub trait DecodeEngine {
    fn batch_size(&self) -> usize;
    fn free_rows(&self) -> usize;
    /// Admit a prompt into a free row (routed through `adapter` when the
    /// request names one); returns the row index.
    fn prefill(&mut self, prompt: &str, cfg: SampleCfg, adapter: Option<AdapterId>)
        -> Result<usize>;
    /// Begin admission; `defer` asks the engine to only *reserve* the row
    /// and let [`DecodeEngine::prefill_tick`] pace the prompt across
    /// scheduler ticks (token-budget scheduling, DESIGN.md §2e). Engines
    /// without paced admission complete here. Returns
    /// (row, admission_complete).
    fn prefill_begin(
        &mut self,
        prompt: &str,
        cfg: SampleCfg,
        adapter: Option<AdapterId>,
        defer: bool,
    ) -> Result<(usize, bool)> {
        let _ = defer;
        self.prefill(prompt, cfg, adapter).map(|row| (row, true))
    }
    /// Spend up to `budget` prefill window tokens on deferred admissions
    /// (at least one window while any is pending, so ticks always make
    /// progress). The default engine has nothing pending.
    fn prefill_tick(&mut self, budget: usize) -> Result<PrefillTickOut> {
        let _ = budget;
        Ok(PrefillTickOut::default())
    }
    /// Cumulative admission accounting (window tokens, padding waste).
    fn prefill_stats(&self) -> PrefillStats {
        PrefillStats::default()
    }
    /// Called once at the top of every scheduler tick with the
    /// *pre-increment* tick counter — fault-injecting engines key their
    /// schedules on it (DESIGN.md §2j); real engines ignore it.
    fn begin_tick(&mut self, tick: u64) {
        let _ = tick;
    }
    /// Sample one token for every active row (each under its own config).
    fn decode_step(&mut self, rng: &mut Rng) -> Result<Vec<StepOut>>;
    /// Classification of the engine's most recent error, when the engine
    /// distinguishes failure domains (chaos / fault-injecting engines).
    /// `None` means any error is engine-wide and fatal — the pre-§2j
    /// contract every real engine keeps by default.
    fn last_fault(&self) -> Option<FaultInfo> {
        None
    }
    /// Enable/disable speculative decoding when the engine has a drafter
    /// (Degraded health turns the drafter off, §2j); engines without one
    /// ignore it.
    fn set_spec_enabled(&mut self, on: bool) {
        let _ = on;
    }
    /// Remove a row, returning its generated ids and freeing the slot.
    fn take(&mut self, row: usize) -> Option<Vec<i32>>;
    fn decode_text(&self, ids: &[i32]) -> String;
    /// Cumulative speculative-decoding counters, when the engine decodes
    /// on the speculative path (None everywhere else).
    fn spec_stats(&self) -> Option<SpecStats> {
        None
    }
    /// Whether the engine has cache capacity for this request *right
    /// now* — block-pool headroom on the paged path (DESIGN.md §2f),
    /// where free rows alone no longer imply a free cache. `false` keeps
    /// the request queued instead of rejecting it; engines whose rows
    /// are the only capacity always say yes.
    fn can_admit(&mut self, prompt: &str, cfg: &SampleCfg) -> bool {
        let _ = (prompt, cfg);
        true
    }
    /// Block-pool counters (prefix hits, copy-on-write forks, pool
    /// utilisation) when the engine decodes through pooled paged caches;
    /// None everywhere else.
    fn paged_stats(&self) -> Option<PagedStats> {
        None
    }
}

impl DecodeEngine for Generator<'_> {
    fn batch_size(&self) -> usize {
        Generator::batch_size(self)
    }

    fn free_rows(&self) -> usize {
        Generator::free_rows(self)
    }

    fn prefill(
        &mut self,
        prompt: &str,
        cfg: SampleCfg,
        adapter: Option<AdapterId>,
    ) -> Result<usize> {
        Generator::prefill_adapter(self, prompt, cfg, adapter)
    }

    fn prefill_begin(
        &mut self,
        prompt: &str,
        cfg: SampleCfg,
        adapter: Option<AdapterId>,
        defer: bool,
    ) -> Result<(usize, bool)> {
        Generator::prefill_begin(self, prompt, cfg, adapter, defer)
    }

    fn prefill_tick(&mut self, budget: usize) -> Result<PrefillTickOut> {
        Generator::prefill_tick(self, budget)
    }

    fn prefill_stats(&self) -> PrefillStats {
        Generator::prefill_stats(self)
    }

    fn decode_step(&mut self, rng: &mut Rng) -> Result<Vec<StepOut>> {
        Generator::decode_step(self, rng)
    }

    fn take(&mut self, row: usize) -> Option<Vec<i32>> {
        Generator::take(self, row)
    }

    fn decode_text(&self, ids: &[i32]) -> String {
        self.tokenizer().decode(ids)
    }

    fn spec_stats(&self) -> Option<SpecStats> {
        Generator::spec_stats(self)
    }

    fn paged_stats(&self) -> Option<PagedStats> {
        Generator::paged_stats(self)
    }
}

/// Scheduling class for the SLO-aware scheduler (DESIGN.md §2i).
/// Derived `Ord` follows declaration order: `Low < Normal < High`.
/// FIFO within a class; across classes the scheduler admits the highest
/// waiting class first and may preempt a strictly lower-priority
/// in-flight row for a waiting higher one. Plain FIFO mode ignores it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum Priority {
    Low,
    #[default]
    Normal,
    High,
}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub cfg: SampleCfg,
    /// adapter the request decodes under (None = the engine's single
    /// baked-in weights; required by adapter-store engines)
    pub adapter: Option<AdapterId>,
    /// scheduling class; [`Priority::Normal`] unless enqueued via
    /// [`Server::enqueue_slo`]
    pub priority: Priority,
    /// absolute tick the request must *finish* by to count toward
    /// goodput. A queued request whose deadline already passed is
    /// cancelled; one that finishes late records a `DeadlineMiss`.
    /// `None` = no deadline: never cancelled, always good once served.
    pub deadline_tick: Option<usize>,
}

/// Stats label for an adapter lane ("base" for adapter-less requests).
pub fn adapter_label(adapter: Option<AdapterId>) -> String {
    adapter.map_or_else(|| "base".to_string(), |id| id.to_string())
}

/// How a request resolved (DESIGN.md §2j). Every enqueue that is not
/// cancelled or rejected at admission ends in exactly one [`Response`],
/// and this field says which kind — a failure is a first-class response,
/// never a silent drop.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Outcome {
    /// completed normally; `text`/`tokens` hold the generation
    #[default]
    Ok,
    /// terminal failure: the retry budget was exhausted or the engine
    /// was lost; `text` is empty and `tokens` is 0
    Failed,
}

/// Scheduler health (DESIGN.md §2j). Engine-level faults degrade it;
/// clean decode ticks recover it; `Failing` is terminal.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Health {
    #[default]
    Healthy,
    /// an engine-level transient fault was seen recently: speculative
    /// decoding is disabled and admission is capped at one per tick
    Degraded,
    /// device lost or repeated engine faults: survivors and queue are
    /// failed loudly; the server never serves again
    Failing,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub text: String,
    /// generated tokens after EOS/PAD trimming
    pub tokens: usize,
    /// enqueue → first sampled token
    pub ttft_ms: f64,
    /// enqueue → completion
    pub latency_ms: f64,
    /// in-flight rows during this request's final decode step
    pub batch_rows: usize,
    /// adapter the request decoded under
    pub adapter: Option<AdapterId>,
    /// how the request resolved (§2j); [`Outcome::Ok`] everywhere chaos
    /// is off
    pub outcome: Outcome,
}

/// A queued request with its wait-accounting clocks. `ttft_ms` is only
/// ever `Some` for a *preempted* request back in the queue: TTFT is
/// recorded once per request, on its first-ever token, and must survive
/// the evict → requeue → re-prefill cycle (the audit's law 6 mirrors
/// this — no second TTFT sample, no ITL gap across the boundary).
struct Queued {
    req: Request,
    t0: Instant,
    enq_tick: usize,
    ttft_ms: Option<f64>,
    /// engine faults this request has survived (retry count, §2j)
    attempts: u32,
    /// earliest tick the entry may be admitted — the retry backoff
    /// (0 = immediately; only ever nonzero under a retry policy)
    not_before: usize,
}

/// Per-request bookkeeping while its row decodes.
struct InFlight {
    req: Request,
    enqueued: Instant,
    /// tick count at enqueue (sim-time TTFT baseline)
    enq_tick: usize,
    ttft_ms: Option<f64>,
    /// tick of the row's most recent sampled token (ITL tracking)
    last_token_tick: Option<usize>,
    /// enqueue → leaving-the-queue wait, measured when the row was
    /// reserved — so paced multi-tick prefill never inflates the queue
    /// metric (that time belongs to TTFT, not queueing)
    queue_wait_ms: f64,
    /// admission still being paced by `prefill_tick` (row reserved, not
    /// yet decoding); queue-wait/admitted accounting lands on completion
    /// so a mid-chunk rejection never leaks into either
    pending: bool,
    /// admission forced past a `can_admit` refusal because nothing was
    /// in flight; if it then fails mid-chunk *with* concurrent occupants
    /// the failure is pool pressure, not an oversized request — requeue
    /// it (as a zero-token preempt) instead of rejecting
    forced: bool,
    /// tokens sampled for this request so far (the trace `Finish` total —
    /// `Response.tokens` differs after EOS/PAD trimming)
    tokens: usize,
    /// engine faults this request has survived (retry count, §2j)
    attempts: u32,
}

pub struct Server<E> {
    pub engine: E,
    queue: VecDeque<Queued>,
    /// in-flight request per engine row
    inflight: Vec<Option<InFlight>>,
    next_id: u64,
    rng: Rng,
    pub stats: ServerStats,
    /// prefill window tokens each tick may spend on paced admissions
    /// (None = every admission completes the tick it begins — the
    /// monolithic stall the §2e budget loop removes)
    prefill_budget: Option<usize>,
    /// SLO-aware scheduling on: priority-ordered admission, deadline
    /// cancellation, preemption (DESIGN.md §2i). Off = plain FIFO.
    slo: bool,
    /// max engine rows one adapter lane may hold concurrently (None =
    /// uncapped); queue entries whose lane is at the cap are skipped
    fair_rows: Option<usize>,
    /// per-tick gauge samples (queue depth, in-flight rows, blocks in
    /// use) — merged into the registry snapshot by [`Server::metrics`]
    tick_metrics: Metrics,
    /// per-request retry budget (§2j). None = retries off: any engine
    /// error propagates and aborts the tick, the pre-§2j contract
    retry_budget: Option<u32>,
    /// backoff base B: retry k waits B·2^(k-1) ticks before re-admission
    backoff_base: u64,
    /// health state machine (§2j); [`Health::Healthy`] forever when no
    /// engine-level fault ever fires
    health: Health,
    /// consecutive clean decode ticks while Degraded (3 → Recover)
    clean_ticks: u32,
    /// consecutive engine-level faulted decode ticks (3 → Failing)
    engine_fault_streak: u32,
}

/// Per-adapter slice of the serving stats (keyed by [`AdapterId`]; the
/// `None` lane holds adapter-less requests).
#[derive(Debug, Default, Clone)]
pub struct AdapterLane {
    /// requests admitted into a row
    pub requests: usize,
    /// requests completed
    pub served: usize,
    /// tokens sampled for this adapter's rows
    pub tokens: usize,
    /// of those, tokens that came from an accepted speculative draft
    /// (0 off the speculative path)
    pub accepted_tokens: usize,
    pub total_ttft_ms: f64,
    pub total_latency_ms: f64,
}

impl AdapterLane {
    /// Fraction of this lane's served tokens that came from accepted
    /// drafts (the per-lane acceptance signal; the engine-wide rate over
    /// *proposed* drafts lives in [`ServerStats::spec`]).
    pub fn draft_accept_share(&self) -> f64 {
        self.accepted_tokens as f64 / self.tokens.max(1) as f64
    }

    pub fn mean_ttft_ms(&self) -> f64 {
        self.total_ttft_ms / self.served.max(1) as f64
    }

    pub fn mean_latency_ms(&self) -> f64 {
        self.total_latency_ms / self.served.max(1) as f64
    }

    /// This adapter's share of decode throughput: its sampled tokens over
    /// the server's total decode wall time (lanes share every batch, so
    /// per-lane wall time is not separable — shares sum to the aggregate).
    pub fn tokens_per_sec(&self, decode_ms: f64) -> f64 {
        self.tokens as f64 / (decode_ms / 1e3).max(1e-9)
    }
}

#[derive(Debug, Default, Clone)]
pub struct ServerStats {
    pub served: usize,
    pub admitted: usize,
    pub decode_steps: usize,
    /// wall time spent inside decode steps
    pub decode_ms: f64,
    /// tokens sampled across all decode steps
    pub total_tokens: usize,
    pub total_ttft_ms: f64,
    pub total_latency_ms: f64,
    /// summed per-step (in-flight rows / batch size)
    pub total_batch_occupancy: f64,
    /// summed enqueue → admission wait over admitted requests
    pub total_queue_wait_ms: f64,
    /// most requests ever waiting in the queue at once
    pub peak_queue_depth: usize,
    /// requests dropped at admission (e.g. naming an unregistered
    /// adapter) — a bad request never takes the server down
    pub rejected: usize,
    /// in-flight rows evicted for a higher class (SLO scheduler); each
    /// preemption discards the row's partial stream and requeues the
    /// request, whose re-admission counts into `admitted` again
    pub preempted: usize,
    /// queued requests dropped because their deadline expired before
    /// admission (terminal: a cancelled request never decodes)
    pub cancelled: usize,
    /// requests that finished after their deadline — served, but outside
    /// the SLO (subtracted from goodput, never from `served`)
    pub deadline_misses: usize,
    /// requests terminally failed: retry budget exhausted or the engine
    /// was lost (§2j) — resolved as first-class [`Outcome::Failed`]
    /// responses, counted against goodput like cancellations
    pub failed: usize,
    /// fault → preempt → requeue cycles taken (each re-admission counts
    /// into `admitted` again, like preemptions)
    pub retries: usize,
    /// decode ticks run while health was not [`Health::Healthy`]
    pub degraded_ticks: usize,
    /// tokens that came from accepted speculative drafts (0 off the
    /// speculative path)
    pub accepted_tokens: usize,
    /// the engine's speculative counters (draft/verify step counts,
    /// acceptance rate over proposed drafts), snapshotted each step;
    /// None when the engine does not decode speculatively
    pub spec: Option<SpecStats>,
    /// the engine's block-pool counters (prefix hits, copy-on-write
    /// forks, pool utilisation), snapshotted each step; None off the
    /// paged path (DESIGN.md §2f)
    pub paged: Option<PagedStats>,
    /// most requests ever holding rows at once (decoding or pending
    /// admission) — on the paged path this exceeds a dense grid's batch
    /// at equal cache bytes, the §2f capacity decoupling
    pub peak_in_flight: usize,
    /// per-adapter breakdown, keyed by the request's adapter
    pub per_adapter: BTreeMap<Option<AdapterId>, AdapterLane>,
    /// scheduler ticks run (every `step` that found work — decode,
    /// paced prefill, or a stall — counts one; the sim-time clock)
    pub ticks: usize,
    /// per-request enqueue → first-token tick counts (the sim-time TTFT
    /// distribution; wall-clock ms live in `total_ttft_ms`). NOTE: grows
    /// one entry per served request for the server's lifetime — sized for
    /// bench/test workloads; a long-lived deployment would swap in a
    /// bounded reservoir before these matter (one usize per request)
    pub ttft_ticks: Vec<usize>,
    /// per-token tick gaps between consecutive tokens of a row (the
    /// sim-time inter-token-latency distribution; a monolithic admission
    /// stall shows up here as a spike). Same lifetime-growth caveat as
    /// `ttft_ticks`, one usize per token
    pub itl_ticks: Vec<usize>,
    /// engine admission accounting snapshot: window tokens processed and
    /// the padded share (the §2e waste counter)
    pub prefill: PrefillStats,
}

impl ServerStats {
    fn lane(&mut self, adapter: Option<AdapterId>) -> &mut AdapterLane {
        self.per_adapter.entry(adapter).or_default()
    }

    /// Mean time-to-first-token over completed requests.
    pub fn mean_ttft_ms(&self) -> f64 {
        self.total_ttft_ms / self.served.max(1) as f64
    }

    pub fn mean_latency_ms(&self) -> f64 {
        self.total_latency_ms / self.served.max(1) as f64
    }

    /// Steady-state decode throughput: sampled tokens per second of decode
    /// wall time.
    pub fn tokens_per_sec(&self) -> f64 {
        self.total_tokens as f64 / (self.decode_ms / 1e3).max(1e-9)
    }

    pub fn mean_occupancy(&self) -> f64 {
        self.total_batch_occupancy / self.decode_steps.max(1) as f64
    }

    /// Mean enqueue → admission wait (queue pressure; 0 when every request
    /// found a free row immediately).
    pub fn mean_queue_wait_ms(&self) -> f64 {
        self.total_queue_wait_ms / self.admitted.max(1) as f64
    }

    /// Goodput under SLO: the fraction of *resolved* requests (served,
    /// cancelled, or failed) that finished within their deadline.
    /// Requests without a deadline count as good once served; cancelled
    /// and terminally-failed requests are resolved non-good outcomes, so
    /// deadline storms and fault storms both drag this down even when
    /// every surviving request finishes in time. Identical to the PR 9
    /// formula whenever `failed == 0`.
    pub fn goodput(&self) -> f64 {
        self.served.saturating_sub(self.deadline_misses) as f64
            / (self.served + self.cancelled + self.failed).max(1) as f64
    }

    /// Fraction of served tokens that came from accepted drafts.
    pub fn draft_accept_share(&self) -> f64 {
        self.accepted_tokens as f64 / self.total_tokens.max(1) as f64
    }

    /// Acceptance rate over *proposed* drafts, when the engine reported
    /// speculative counters.
    pub fn acceptance_rate(&self) -> Option<f64> {
        self.spec.map(|s| s.acceptance_rate())
    }

    /// Percentile of the enqueue → first-token tick distribution
    /// (`p` in 0..=100; 0.0 when nothing finished a first token yet).
    pub fn ttft_tick_p(&self, p: f64) -> f64 {
        tick_percentile(&self.ttft_ticks, p)
    }

    /// Percentile of the inter-token tick-gap distribution.
    pub fn itl_tick_p(&self, p: f64) -> f64 {
        tick_percentile(&self.itl_ticks, p)
    }

    /// Batch percentiles of the TTFT tick distribution — one sort via
    /// `stats::percentiles_of` (exporters all want p50+p95 of the same
    /// vector; `ttft_tick_p` re-sorts per call).
    pub fn ttft_tick_pcts(&self, ps: &[f64]) -> Vec<f64> {
        crate::util::stats::tick_percentiles(&self.ttft_ticks, ps)
    }

    /// Batch percentiles of the ITL tick-gap distribution.
    pub fn itl_tick_pcts(&self, ps: &[f64]) -> Vec<f64> {
        crate::util::stats::tick_percentiles(&self.itl_ticks, ps)
    }

    /// Export every counter this struct accumulates into the unified
    /// registry (DESIGN.md §2g) — the single path `BENCH_serve.json`,
    /// `tab8_serving.csv` and the serve summary read. Derived rates are
    /// exported as gauges so no exporter re-implements a formula.
    pub fn to_metrics(&self) -> Metrics {
        let mut m = Metrics::new();
        m.set_counter("serve.served", self.served as f64);
        m.set_counter("serve.admitted", self.admitted as f64);
        m.set_counter("serve.rejected", self.rejected as f64);
        m.set_counter("serve.preempted", self.preempted as f64);
        m.set_counter("serve.cancelled", self.cancelled as f64);
        m.set_counter("serve.deadline_misses", self.deadline_misses as f64);
        m.set_counter("serve.failed", self.failed as f64);
        m.set_counter("serve.retries", self.retries as f64);
        m.set_counter("serve.degraded_ticks", self.degraded_ticks as f64);
        m.set_counter("serve.decode_steps", self.decode_steps as f64);
        m.set_counter("serve.decode_ms", self.decode_ms);
        m.set_counter("serve.total_tokens", self.total_tokens as f64);
        m.set_counter("serve.accepted_tokens", self.accepted_tokens as f64);
        m.set_counter("serve.ticks", self.ticks as f64);
        m.set_counter("serve.total_ttft_ms", self.total_ttft_ms);
        m.set_counter("serve.total_latency_ms", self.total_latency_ms);
        m.set_counter("serve.total_queue_wait_ms", self.total_queue_wait_ms);
        m.set_gauge("serve.peak_queue_depth", self.peak_queue_depth as f64);
        m.set_gauge("serve.peak_in_flight", self.peak_in_flight as f64);
        m.set_gauge("serve.tokens_per_sec", self.tokens_per_sec());
        m.set_gauge("serve.mean_ttft_ms", self.mean_ttft_ms());
        m.set_gauge("serve.mean_latency_ms", self.mean_latency_ms());
        m.set_gauge("serve.mean_queue_wait_ms", self.mean_queue_wait_ms());
        m.set_gauge("serve.mean_occupancy", self.mean_occupancy());
        m.set_gauge("serve.draft_accept_share", self.draft_accept_share());
        m.set_gauge("serve.goodput", self.goodput());
        let ttft = self.ttft_tick_pcts(&[50.0, 95.0]);
        m.set_gauge("serve.ttft_tick_p50", ttft[0]);
        m.set_gauge("serve.ttft_tick_p95", ttft[1]);
        let itl = self.itl_tick_pcts(&[50.0, 95.0]);
        m.set_gauge("serve.itl_tick_p50", itl[0]);
        m.set_gauge("serve.itl_tick_p95", itl[1]);
        m.observe_all(
            "serve.ttft_ticks",
            &self.ttft_ticks.iter().map(|&t| t as f64).collect::<Vec<_>>(),
        );
        m.observe_all(
            "serve.itl_ticks",
            &self.itl_ticks.iter().map(|&t| t as f64).collect::<Vec<_>>(),
        );
        self.prefill.export_into(&mut m);
        if let Some(s) = &self.spec {
            s.export_into(&mut m);
        }
        if let Some(p) = &self.paged {
            p.export_into(&mut m);
        }
        for (adapter, lane) in &self.per_adapter {
            let label = adapter_label(*adapter);
            let k = |field: &str| format!("adapter.{label}.{field}");
            m.set_counter(&k("requests"), lane.requests as f64);
            m.set_counter(&k("served"), lane.served as f64);
            m.set_counter(&k("tokens"), lane.tokens as f64);
            m.set_counter(&k("accepted_tokens"), lane.accepted_tokens as f64);
            m.set_gauge(&k("mean_ttft_ms"), lane.mean_ttft_ms());
            m.set_gauge(&k("mean_latency_ms"), lane.mean_latency_ms());
            m.set_gauge(&k("tokens_per_sec"), lane.tokens_per_sec(self.decode_ms));
            m.set_gauge(&k("draft_accept_share"), lane.draft_accept_share());
        }
        m
    }
}

/// One-value wrapper over [`crate::util::stats::tick_percentiles`] — the
/// single percentile implementation every exporter and `trace_report.py`
/// agree on (ISSUE 9 satellite: no private lerp in serve).
fn tick_percentile(xs: &[usize], p: f64) -> f64 {
    crate::util::stats::tick_percentiles(xs, &[p]).first().copied().unwrap_or(0.0)
}

impl<E: DecodeEngine> Server<E> {
    pub fn new(engine: E, seed: u64) -> Server<E> {
        let b = engine.batch_size();
        Server {
            engine,
            queue: VecDeque::new(),
            inflight: (0..b).map(|_| None).collect(),
            next_id: 0,
            rng: Rng::new(seed),
            stats: ServerStats::default(),
            prefill_budget: None,
            slo: false,
            fair_rows: None,
            tick_metrics: Metrics::new(),
            retry_budget: None,
            backoff_base: 1,
            health: Health::Healthy,
            clean_ticks: 0,
            engine_fault_streak: 0,
        }
    }

    /// Sample the per-tick gauges into the registry and (when tracing)
    /// the trace's counter tracks. Runs once per counted scheduler tick.
    fn sample_gauges(&mut self, active: usize, pending: usize) {
        let qd = self.queue.len() as f64;
        let inflight = (active + pending) as f64;
        self.tick_metrics.set_gauge("serve.queue_depth", qd);
        self.tick_metrics.observe("serve.queue_depth", qd);
        self.tick_metrics.set_gauge("serve.in_flight", inflight);
        self.tick_metrics.observe("serve.in_flight", inflight);
        trace::emit(|| Event::Gauge { name: "queue_depth", value: qd });
        trace::emit(|| Event::Gauge { name: "in_flight", value: inflight });
        if let Some(p) = &self.stats.paged {
            let blocks = p.blocks_in_use as f64;
            self.tick_metrics.set_gauge("paged.blocks_in_use", blocks);
            self.tick_metrics.observe("paged.blocks_in_use", blocks);
            trace::emit(|| Event::Gauge { name: "blocks_in_use", value: blocks });
        }
    }

    /// Registry snapshot: the cumulative [`ServerStats`] export plus the
    /// per-tick gauge samples. This is the single surface the exporters
    /// (`BENCH_serve.json`, `tab8_serving.csv`, the serve summary) read.
    pub fn metrics(&self) -> Metrics {
        let mut m = self.stats.to_metrics();
        m.merge(&self.tick_metrics);
        m
    }

    /// Cap the prefill window tokens each tick spends on admissions
    /// (Sarathi-style token-budget scheduling, DESIGN.md §2e): chunked
    /// engines then pace long prompts across ticks *interleaved* with the
    /// decode step instead of stalling the batch. `None` restores
    /// complete-on-admission behaviour.
    pub fn set_prefill_budget(&mut self, budget: Option<usize>) {
        self.prefill_budget = budget;
    }

    /// Turn the SLO-aware scheduler on (DESIGN.md §2i): priority-ordered
    /// admission, deadline cancellation of expired queued requests, and
    /// preemption of strictly-lower-priority in-flight rows for waiting
    /// higher ones. Off (the default) is plain FIFO — priorities and
    /// deadlines on enqueued requests are then carried but ignored,
    /// which is what the FIFO arm of an A/B bench wants.
    pub fn set_slo(&mut self, on: bool) {
        self.slo = on;
    }

    /// Cap the engine rows any one adapter lane may hold concurrently.
    /// Each row samples one token per tick, so a row cap *is* a max
    /// tokens-per-tick cap per lane: admission skips queue entries whose
    /// lane is at the cap (it looks past them, so a 10:1-skewed queue
    /// cannot starve the cold lanes). `None` = uncapped; a cap of 0 is
    /// clamped to 1 (a lane that may never hold a row would wedge).
    pub fn set_adapter_fair_cap(&mut self, cap: Option<usize>) {
        self.fair_rows = cap.map(|c| c.max(1));
    }

    /// Turn on bounded retries with exponential backoff (DESIGN.md §2j):
    /// a row-scoped engine fault preempts the afflicted request — the
    /// partial stream is discarded and conserved, exactly like an SLO
    /// preemption — and requeues it at the queue front, waiting
    /// `backoff_base · 2^(k-1)` ticks before retry `k`, up to `budget`
    /// retries; the next fault past the budget fails it terminally as a
    /// first-class [`Outcome::Failed`] response. Engine-scoped faults
    /// drive the [`Health`] machine instead. `None` restores the
    /// abort-on-error contract (any engine error propagates, every
    /// in-flight request dies with the tick) — and with no fault ever
    /// firing, a server with a retry policy behaves byte-identically to
    /// one without.
    pub fn set_retry_policy(&mut self, budget: Option<u32>, backoff_base: u64) {
        self.retry_budget = budget;
        self.backoff_base = backoff_base.max(1);
    }

    /// Current health state (§2j); [`Health::Healthy`] forever when no
    /// engine-level fault ever fires.
    pub fn health(&self) -> Health {
        self.health
    }

    pub fn enqueue(&mut self, prompt: impl Into<String>, cfg: SampleCfg) -> u64 {
        self.enqueue_adapter(prompt, cfg, None)
    }

    /// Enqueue a request decoding under a registered adapter. FIFO with
    /// free-row admission as ever: adapters never partition the batch, so
    /// a mixed-adapter queue keeps zero head-of-line blocking.
    pub fn enqueue_adapter(
        &mut self,
        prompt: impl Into<String>,
        cfg: SampleCfg,
        adapter: Option<AdapterId>,
    ) -> u64 {
        self.enqueue_slo(prompt, cfg, adapter, Priority::default(), None)
    }

    /// Enqueue with an SLO contract: a [`Priority`] class and an optional
    /// deadline `deadline_ticks` ticks from now (the absolute deadline is
    /// `current tick + deadline_ticks`; the request must *finish* by it
    /// to count toward goodput). Under plain FIFO both are ignored.
    pub fn enqueue_slo(
        &mut self,
        prompt: impl Into<String>,
        cfg: SampleCfg,
        adapter: Option<AdapterId>,
        priority: Priority,
        deadline_ticks: Option<usize>,
    ) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        let deadline_tick = deadline_ticks.map(|d| self.stats.ticks + d);
        self.queue.push_back(Queued {
            req: Request {
                id,
                prompt: prompt.into(),
                cfg,
                adapter,
                priority,
                deadline_tick,
            },
            t0: Instant::now(),
            enq_tick: self.stats.ticks,
            ttft_ms: None,
            attempts: 0,
            not_before: 0,
        });
        trace::set_tick(self.stats.ticks as u64);
        trace::emit(|| Event::Enqueue { req: id });
        self.stats.peak_queue_depth = self.stats.peak_queue_depth.max(self.queue.len());
        id
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn in_flight(&self) -> usize {
        self.inflight.iter().flatten().count()
    }

    /// Index of the next queue entry to admit. Plain FIFO picks the head
    /// (any config fits any row, so nothing blocks behind a mismatched
    /// head request); the SLO scheduler picks the highest waiting
    /// [`Priority`] class, FIFO within it. Either way, an entry whose
    /// adapter lane is at the fairness row cap is skipped — admission
    /// looks past it, so a skewed queue cannot starve the other lanes.
    /// `None` = nothing admissible right now.
    fn pick_ix(&self) -> Option<usize> {
        if !self.slo && self.fair_rows.is_none() && self.retry_budget.is_none() {
            return (!self.queue.is_empty()).then_some(0);
        }
        let now = self.stats.ticks;
        let mut best: Option<(Priority, usize)> = None;
        for (ix, q) in self.queue.iter().enumerate() {
            if q.not_before > now {
                continue; // §2j retry backoff: not admissible yet
            }
            if let Some(cap) = self.fair_rows {
                let lane_rows = self
                    .inflight
                    .iter()
                    .flatten()
                    .filter(|f| f.req.adapter == q.req.adapter)
                    .count();
                if lane_rows >= cap {
                    continue;
                }
            }
            let better = match best {
                None => true,
                Some((bp, _)) => self.slo && q.req.priority > bp,
            };
            if better {
                best = Some((q.req.priority, ix));
            }
        }
        best.map(|(_, ix)| ix)
    }

    /// Drop queued requests whose deadline already passed. Decode lands
    /// on post-increment ticks, so a request still queued at
    /// `tick >= deadline` cannot finish in time — serving it would only
    /// burn rows that deadline-feasible work could use. `Cancel` is
    /// terminal and strictly pre-admission (the audit's law 7): in-flight
    /// requests are never cancelled, they finish and at worst record a
    /// `DeadlineMiss`.
    fn cancel_expired(&mut self) {
        let now = self.stats.ticks;
        let mut cancelled = 0usize;
        self.queue.retain(|q| match q.req.deadline_tick {
            Some(d) if d <= now => {
                trace::emit(|| Event::Cancel { req: q.req.id });
                cancelled += 1;
                false
            }
            _ => true,
        });
        self.stats.cancelled += cancelled;
    }

    /// Evict `row` mid-decode for a higher class. The partial stream is
    /// discarded — the trace's `Preempt` carries its token count, which
    /// the audit conserves into `preempted_tokens` — `engine.take` frees
    /// the cache slot / paged blocks and releases the adapter pin, and
    /// the request returns to the queue front with its *original* clocks:
    /// TTFT was recorded once, on its first-ever token, and the re-run
    /// life must not re-record it (nor bridge an ITL gap across the
    /// boundary). Re-prefill from the prompt then re-derives the exact
    /// same stream, so preemption never changes what a request says.
    fn preempt(&mut self, row: usize) -> Result<()> {
        let f = self
            .inflight
            .get_mut(row)
            .and_then(Option::take)
            .with_context(|| format!("preempt of untracked row {row}"))?;
        let (id, tokens) = (f.req.id, f.tokens);
        trace::emit(|| Event::Preempt { req: id, row, tokens });
        let _ = self.engine.take(row);
        self.stats.preempted += 1;
        self.queue.push_front(Queued {
            req: f.req,
            t0: f.enqueued,
            enq_tick: f.enq_tick,
            ttft_ms: f.ttft_ms,
            attempts: f.attempts,
            not_before: 0,
        });
        Ok(())
    }

    /// Admit queued requests into free rows — FIFO by default, priority
    /// ordered with deadline cancellation and preemption under
    /// [`Server::set_slo`] (see [`Server::pick_ix`] for the pick rule).
    /// When the rows are full and a strictly higher class is waiting, at
    /// most one lower-priority in-flight row is preempted per tick (the
    /// lowest class; the youngest enqueue among ties; never a row still
    /// mid-prefill) and the admission loop retries into the freed row. A
    /// request whose admission fails — an unregistered adapter, a prefill
    /// error — is rejected and dropped rather than aborting the batch the
    /// other requests are decoding in; but when *every* admission failed
    /// and nothing is in flight, the server cannot make progress and the
    /// last error propagates (a broken engine must not silently drain the
    /// queue into `rejected`).
    fn admit(&mut self) -> Result<()> {
        if self.slo {
            self.cancel_expired();
        }
        // with a prefill budget set, admissions are *deferred*: the row
        // is reserved now and prefill_tick paces the prompt into it
        let defer = self.prefill_budget.is_some();
        let mut admitted_now = 0usize;
        let mut last_err = None;
        let mut preempted_now = false;
        loop {
            while self.engine.free_rows() > 0 {
                // Degraded health shrinks admission to one request per
                // tick (§2j): keep serving, stop piling load on an
                // engine that just faulted
                if self.health == Health::Degraded && admitted_now >= 1 {
                    break;
                }
                let Some(ix) = self.pick_ix() else { break };
                let Some(q) = self.queue.remove(ix) else { break };
                // a paged engine may have free rows but no block-pool
                // headroom: keep the request queued while anything else
                // makes progress; with nothing in flight, attempt the
                // admission anyway so a genuinely oversized request
                // surfaces as a rejection instead of a wedged queue
                let can = self.engine.can_admit(&q.req.prompt, &q.req.cfg);
                if !can && (admitted_now > 0 || self.in_flight() > 0) {
                    trace::emit(|| Event::Requeue { req: q.req.id });
                    self.queue.insert(ix, q);
                    break;
                }
                let (row, done) = match self.engine.prefill_begin(
                    &q.req.prompt,
                    q.req.cfg,
                    q.req.adapter,
                    defer,
                ) {
                    Ok(x) => x,
                    Err(e) => {
                        log::warn(format!(
                            "request {} rejected at admission: {e:#}",
                            q.req.id
                        ));
                        trace::emit(|| Event::Reject { req: q.req.id });
                        self.stats.rejected += 1;
                        last_err = Some(e);
                        continue;
                    }
                };
                admitted_now += 1;
                let slot = self
                    .inflight
                    .get_mut(row)
                    .with_context(|| format!("engine admitted into out-of-range row {row}"))?;
                if slot.is_some() {
                    bail!("engine admitted into occupied row {row}");
                }
                let queue_wait_ms = q.t0.elapsed().as_secs_f64() * 1e3;
                let (id, adapter) = (q.req.id, q.req.adapter);
                trace::emit(|| Event::Admit { req: id, row });
                *slot = Some(InFlight {
                    req: q.req,
                    enqueued: q.t0,
                    enq_tick: q.enq_tick,
                    ttft_ms: q.ttft_ms,
                    last_token_tick: None,
                    queue_wait_ms,
                    pending: !done,
                    forced: !can,
                    tokens: 0,
                    attempts: q.attempts,
                });
                if done {
                    self.stats.admitted += 1;
                    self.stats.lane(adapter).requests += 1;
                    self.stats.total_queue_wait_ms += queue_wait_ms;
                }
            }
            // preemption: rows full and a strictly higher class waiting —
            // evict one victim, retry the admission loop into its row
            if !self.slo || preempted_now || self.engine.free_rows() > 0 {
                break;
            }
            let Some(want) = self.queue.iter().map(|q| q.req.priority).max() else {
                break;
            };
            let victim = self
                .inflight
                .iter()
                .enumerate()
                .filter_map(|(row, s)| s.as_ref().map(|f| (row, f)))
                .filter(|(_, f)| !f.pending && f.req.priority < want)
                .min_by_key(|&(_, f)| (f.req.priority, Reverse(f.enq_tick)))
                .map(|(row, _)| row);
            let Some(row) = victim else { break };
            self.preempt(row)?;
            preempted_now = true;
        }
        if let Some(e) = last_err {
            // under a retry policy transient admission faults are
            // expected — rejection isolation plus the fault-storm A/B
            // account for them, so a no-progress tick is not fatal (§2j)
            if admitted_now == 0 && self.in_flight() == 0 && self.retry_budget.is_none() {
                return Err(e.context("every admission failed with no requests in flight"));
            }
        }
        Ok(())
    }

    /// One scheduler tick: admit into free rows, spend the tick's prefill
    /// token budget on paced admissions, run one decode step for the live
    /// rows, and return the requests that completed this step. With a
    /// budget set the prefill windows *interleave* with decoding — a long
    /// prompt amortizes across ticks instead of freezing the batch (the
    /// §Perf stall-amortization model: tick time max(decode, budget·c_tok)
    /// instead of decode + S·c_tok).
    pub fn step(&mut self) -> Result<Vec<Response>> {
        // admission (and any engine-side prefill/block events it triggers)
        // happens on the pre-increment tick; decode events land on the
        // post-increment tick below — matching `enq_tick`/`ttft_ticks`
        trace::set_tick(self.stats.ticks as u64);
        self.engine.begin_tick(self.stats.ticks as u64);
        if self.health == Health::Failing {
            // terminal: nothing decodes again — fail any late arrivals
            // loudly instead of wedging them in the queue (§2j)
            return Ok(self.fail_queue());
        }
        self.admit()?;
        self.stats.peak_in_flight = self.stats.peak_in_flight.max(self.in_flight());
        let tick = self
            .engine
            .prefill_tick(self.prefill_budget.unwrap_or(usize::MAX))?;
        for row in tick.completed {
            let f = self
                .inflight
                .get_mut(row)
                .and_then(|s| s.as_mut())
                .with_context(|| format!("prefill completed for untracked row {row}"))?;
            f.pending = false;
            self.stats.admitted += 1;
            self.stats.lane(f.req.adapter).requests += 1;
            self.stats.total_queue_wait_ms += f.queue_wait_ms;
        }
        for row in tick.failed {
            // a mid-chunk rejection (e.g. a defective window): the engine
            // already released the row; drop the request without letting
            // it leak into the admitted/queue-wait/peak-depth accounting
            let f = self
                .inflight
                .get_mut(row)
                .and_then(|s| s.take())
                .with_context(|| format!("prefill failed for untracked row {row}"))?;
            if f.forced && self.in_flight() > 0 {
                // the admission was forced past a `can_admit` refusal
                // because nothing was in flight — but other rows admitted
                // since are holding cache now, so this failure is
                // concurrent pool pressure, not an oversized request:
                // requeue with the original clocks (a zero-token preempt
                // keeps the audit's admission ledger balanced) instead of
                // rejecting. With nothing else in flight the request is
                // genuinely oversized and falls through to the rejection
                // below, so the retry loop terminates.
                let id = f.req.id;
                let tokens = f.tokens;
                trace::emit(|| Event::Preempt { req: id, row, tokens });
                self.stats.preempted += 1;
                self.queue.push_front(Queued {
                    req: f.req,
                    t0: f.enqueued,
                    enq_tick: f.enq_tick,
                    ttft_ms: f.ttft_ms,
                    attempts: f.attempts,
                    not_before: 0,
                });
                continue;
            }
            log::warn(format!("request {} rejected mid-admission", f.req.id));
            trace::emit(|| Event::Reject { req: f.req.id });
            self.stats.rejected += 1;
        }
        self.stats.prefill = self.engine.prefill_stats();
        self.stats.paged = self.engine.paged_stats();
        let active = self.inflight.iter().flatten().filter(|f| !f.pending).count();
        let pending = self.in_flight() - active;
        // termination backstop: both real engines force at least one
        // window per tick while anything is pending, so a zero-spend
        // tick with admissions still pending is a stuck engine — bail
        // rather than letting drain() spin forever
        ensure!(
            pending == 0 || tick.spent > 0,
            "{pending} admissions pending but the engine fed no prefill \
             window this tick"
        );
        if active == 0 && pending == 0 {
            // §2j: when every queued entry is backing off, the only way
            // forward is to let sim time pass — count an idle tick so
            // `not_before` eventually unblocks instead of wedging drain
            if self.retry_budget.is_some()
                && !self.queue.is_empty()
                && self.queue.iter().all(|q| q.not_before > self.stats.ticks)
            {
                self.stats.ticks += 1;
            }
            return Ok(vec![]);
        }
        self.stats.ticks += 1;
        if self.health != Health::Healthy {
            self.stats.degraded_ticks += 1;
        }
        trace::set_tick(self.stats.ticks as u64);
        self.sample_gauges(active, pending);
        if active == 0 {
            // the tick only fed prefill windows; decoding starts once an
            // admission completes
            return Ok(vec![]);
        }
        let t0 = Instant::now();
        let step_out = self.engine.decode_step(&mut self.rng);
        self.stats.decode_ms += t0.elapsed().as_secs_f64() * 1e3;
        let events = match step_out {
            Ok(ev) => {
                // a clean decode tick heals: the engine-fault streak
                // resets, and three in a row while Degraded recover
                self.engine_fault_streak = 0;
                if self.health == Health::Degraded {
                    self.clean_ticks += 1;
                    if self.clean_ticks >= 3 {
                        self.set_health(Health::Healthy);
                    }
                }
                ev
            }
            Err(e) => return self.on_decode_fault(e, active),
        };
        if events.is_empty() {
            // legitimate only while admissions are in flight: a stalled
            // tick (the monolithic sim cost model) or a prefill-only tick
            ensure!(
                pending > 0 || tick.spent > 0,
                "decode engine made no progress with {active} requests in flight"
            );
            return Ok(vec![]);
        }
        self.stats.decode_steps += 1;
        self.stats.total_batch_occupancy += active as f64 / self.engine.batch_size() as f64;
        let now_tick = self.stats.ticks;
        let mut done_rows = vec![];
        for ev in &events {
            let f = self
                .inflight
                .get_mut(ev.row)
                .and_then(|s| s.as_mut())
                .with_context(|| format!("decode event for idle row {}", ev.row))?;
            trace::emit(|| Event::DecodeStep { row: ev.row });
            self.stats.total_tokens += 1;
            f.tokens += 1;
            let adapter = f.req.adapter;
            if f.ttft_ms.is_none() {
                f.ttft_ms = Some(f.enqueued.elapsed().as_secs_f64() * 1e3);
                self.stats.ttft_ticks.push(now_tick - f.enq_tick);
            }
            if let Some(last) = f.last_token_tick {
                self.stats.itl_ticks.push(now_tick - last);
            }
            f.last_token_tick = Some(now_tick);
            if ev.accepted {
                self.stats.accepted_tokens += 1;
            }
            let lane = self.stats.lane(adapter);
            lane.tokens += 1;
            if ev.accepted {
                lane.accepted_tokens += 1;
            }
            if ev.finished {
                done_rows.push(ev.row);
            }
        }
        self.stats.spec = self.engine.spec_stats();
        let mut out = vec![];
        for row in done_rows {
            let Some(f) = self.inflight.get_mut(row).and_then(Option::take) else {
                continue; // engine finished a row the server no longer tracks
            };
            trace::emit(|| Event::Finish { req: f.req.id, row, tokens: f.tokens });
            // deadline check against the finish tick: served late is
            // still served, but it is not goodput
            if let Some(d) = f.req.deadline_tick {
                if now_tick > d {
                    trace::emit(|| Event::DeadlineMiss { req: f.req.id });
                    self.stats.deadline_misses += 1;
                }
            }
            let ids = self.engine.take(row).unwrap_or_default();
            let ttft_ms = f.ttft_ms.unwrap_or_default();
            let latency_ms = f.enqueued.elapsed().as_secs_f64() * 1e3;
            self.stats.served += 1;
            self.stats.total_ttft_ms += ttft_ms;
            self.stats.total_latency_ms += latency_ms;
            let lane = self.stats.lane(f.req.adapter);
            lane.served += 1;
            lane.total_ttft_ms += ttft_ms;
            lane.total_latency_ms += latency_ms;
            out.push(Response {
                id: f.req.id,
                text: self.engine.decode_text(&ids),
                tokens: ids.len(),
                ttft_ms,
                latency_ms,
                batch_rows: active,
                adapter: f.req.adapter,
                outcome: Outcome::Ok,
            });
        }
        Ok(out)
    }

    /// Health transition (§2j): emits the `Degrade`/`Recover` trace
    /// bracket and toggles the degradation levers — Degraded disables
    /// speculative decoding (re-enabled on recovery); the admission cap
    /// lives in [`Server::admit`]. No-op when already in the state.
    fn set_health(&mut self, h: Health) {
        if self.health == h {
            return;
        }
        match h {
            Health::Healthy => {
                trace::emit(|| Event::Recover {});
                self.engine.set_spec_enabled(true);
            }
            Health::Degraded => {
                trace::emit(|| Event::Degrade { level: "degraded" });
                self.engine.set_spec_enabled(false);
            }
            Health::Failing => trace::emit(|| Event::Degrade { level: "failing" }),
        }
        self.health = h;
        self.clean_ticks = 0;
    }

    /// Route a `decode_step` error through the failure-domain machinery
    /// (§2j). Without a retry policy, or when the engine does not
    /// classify its faults, the error propagates — the pre-§2j
    /// abort-on-error contract.
    fn on_decode_fault(&mut self, err: anyhow::Error, active: usize) -> Result<Vec<Response>> {
        if self.retry_budget.is_none() {
            return Err(err);
        }
        let Some(info) = self.engine.last_fault() else {
            return Err(err);
        };
        match info.domain {
            FaultDomain::Row(row) => {
                // blast radius one request: everything else keeps its
                // row and decodes again next tick (a lost tick, not a
                // lost batch)
                if self.inflight.get(row).map_or(false, Option::is_some) {
                    return Ok(self.fault_row(row, info.kind, active)?.into_iter().collect());
                }
                // aimed at an empty row: a harmless lost tick
                Ok(vec![])
            }
            FaultDomain::Engine => {
                self.clean_ticks = 0;
                self.engine_fault_streak += 1;
                if self.engine_fault_streak >= 3 {
                    log::warn(format!(
                        "engine fault streak hit {} ({}): failing",
                        self.engine_fault_streak, info.kind
                    ));
                    return Ok(self.fail_everything(info.kind));
                }
                self.set_health(Health::Degraded);
                Ok(vec![])
            }
            FaultDomain::Lost => Ok(self.fail_everything(info.kind)),
        }
    }

    /// Resolve a row-scoped fault (§2j): within the retry budget the
    /// request is preempted (partial stream discarded and conserved,
    /// like an SLO preemption) and requeued at the front with
    /// exponential backoff; past it, the request terminates as a
    /// first-class [`Outcome::Failed`] response — never a silent drop,
    /// never a wedged row.
    fn fault_row(
        &mut self,
        row: usize,
        kind: &'static str,
        active: usize,
    ) -> Result<Option<Response>> {
        let f = self
            .inflight
            .get_mut(row)
            .and_then(Option::take)
            .with_context(|| format!("fault on untracked row {row}"))?;
        let id = f.req.id;
        trace::emit(|| Event::Fault { req: id, row, fault: kind });
        let attempts = f.attempts + 1;
        if attempts <= self.retry_budget.unwrap_or(0) {
            let tokens = f.tokens;
            trace::emit(|| Event::Preempt { req: id, row, tokens });
            let _ = self.engine.take(row);
            self.stats.preempted += 1;
            trace::emit(|| Event::Retry { req: id, attempt: attempts as usize });
            self.stats.retries += 1;
            // exponential tick backoff: retry k waits B·2^(k-1) ticks
            // (shift capped — a budget anywhere near 64 would overflow)
            let backoff = (self.backoff_base << (attempts - 1).min(32)) as usize;
            self.queue.push_front(Queued {
                req: f.req,
                t0: f.enqueued,
                enq_tick: f.enq_tick,
                ttft_ms: f.ttft_ms,
                attempts,
                not_before: self.stats.ticks + backoff,
            });
            self.stats.peak_queue_depth =
                self.stats.peak_queue_depth.max(self.queue.len());
            return Ok(None);
        }
        log::warn(format!("request {id} failed terminally after fault {attempts} ({kind})"));
        let (tokens, n) = (f.tokens, attempts as usize);
        trace::emit(|| Event::Failed { req: id, tokens, attempts: n });
        let _ = self.engine.take(row);
        self.stats.failed += 1;
        Ok(Some(Self::failed_response(f.req, f.enqueued, f.ttft_ms, active)))
    }

    /// Enter [`Health::Failing`] (§2j): fail every survivor — in-flight
    /// rows as terminal faults, queued requests as zero-token failures —
    /// loudly, as [`Outcome::Failed`] responses. The server never
    /// decodes again; later `step`s only flush late arrivals the same
    /// way.
    fn fail_everything(&mut self, kind: &'static str) -> Vec<Response> {
        self.set_health(Health::Failing);
        log::warn(format!("engine failing ({kind}): draining all requests as failed"));
        let mut out = vec![];
        for row in 0..self.inflight.len() {
            let Some(f) = self.inflight.get_mut(row).and_then(Option::take) else {
                continue;
            };
            let id = f.req.id;
            trace::emit(|| Event::Fault { req: id, row, fault: kind });
            let (tokens, attempts) = (f.tokens, (f.attempts + 1) as usize);
            trace::emit(|| Event::Failed { req: id, tokens, attempts });
            let _ = self.engine.take(row);
            self.stats.failed += 1;
            out.push(Self::failed_response(f.req, f.enqueued, f.ttft_ms, 0));
        }
        out.extend(self.fail_queue());
        out
    }

    /// Fail every queued request (Failing-mode drain): zero tokens were
    /// sampled and `attempts` faults were taken in earlier lives.
    fn fail_queue(&mut self) -> Vec<Response> {
        let mut out = vec![];
        while let Some(q) = self.queue.pop_front() {
            let id = q.req.id;
            let attempts = q.attempts as usize;
            trace::emit(|| Event::Failed { req: id, tokens: 0, attempts });
            self.stats.failed += 1;
            out.push(Self::failed_response(q.req, q.t0, q.ttft_ms, 0));
        }
        out
    }

    fn failed_response(
        req: Request,
        enqueued: Instant,
        ttft_ms: Option<f64>,
        batch_rows: usize,
    ) -> Response {
        Response {
            id: req.id,
            text: String::new(),
            tokens: 0,
            ttft_ms: ttft_ms.unwrap_or_default(),
            latency_ms: enqueued.elapsed().as_secs_f64() * 1e3,
            batch_rows,
            adapter: req.adapter,
            outcome: Outcome::Failed,
        }
    }

    /// Serve until queue and batch are empty; returns all responses in
    /// completion order. Bounded: a wedged row (an engine that never
    /// finishes it) surfaces as a contextful error naming the stuck
    /// rows after [`DRAIN_MAX_TICKS`] iterations instead of looping
    /// forever.
    pub fn drain(&mut self) -> Result<Vec<Response>> {
        let mut all = vec![];
        let mut spins = 0usize;
        while self.pending() > 0 || self.in_flight() > 0 {
            spins += 1;
            if spins > DRAIN_MAX_TICKS {
                let stuck: Vec<String> = self
                    .inflight
                    .iter()
                    .enumerate()
                    .filter_map(|(row, s)| {
                        s.as_ref().map(|f| format!("{row}:req {}", f.req.id))
                    })
                    .collect();
                bail!(
                    "drain stuck after {DRAIN_MAX_TICKS} ticks: rows [{}] never \
                     finish, {} requests still queued",
                    stuck.join(", "),
                    self.pending()
                );
            }
            all.extend(self.step()?);
        }
        Ok(all)
    }
}

/// Iteration bound for [`Server::drain`] — far above any legitimate
/// drain (the worst sim workloads run ~16k ticks) yet instant to hit in
/// a test with a never-finishing engine.
pub const DRAIN_MAX_TICKS: usize = 100_000;

/// Deterministic in-process decode engine for scheduler tests and benches.
///
/// Each admitted request emits `max_new` copies of a marker token derived
/// from *its own* [`SampleCfg`] ([`SimEngine::marker`]) — or, when the
/// request routes an adapter, from that [`AdapterId`]
/// ([`SimEngine::adapter_marker`]: adapter slot i emits `'A' + i`). A test
/// can therefore assert both that a request was sampled under the config
/// it asked for *and* that the scheduler routed it through the adapter it
/// named, without artifacts or the PJRT runtime.
///
/// [`SimEngine::with_spec`] turns on *drafter mode*: each decode step
/// runs one simulated draft/verify round per row (draft length K,
/// configurable per-draft acceptance probability), emitting multi-token
/// bursts — so scheduler behaviour under speculative decoding, including
/// a 0%-acceptance rejection storm, is testable artifact-free too.
///
/// [`SimEngine::with_prefill`] turns on the *admission cost model*
/// ([`SimPrefill`]): prompts charge planned window tokens drained at the
/// scheduler's prefill budget, so the §2e stall — and the token-budget
/// loop's removal of it — is measurable in sim ticks without artifacts.
pub struct SimEngine {
    batch: usize,
    rows: Vec<Option<SimRow>>,
    tk: Tokenizer,
    /// drafter simulation: each decode step runs one draft/verify round
    /// per active row instead of emitting a single token
    spec: Option<SimSpec>,
    /// admission cost model (None = admissions are free and instant, the
    /// historical scheduler-only behaviour)
    prefill_model: Option<SimPrefill>,
    /// paged block-pool capacity model (DESIGN.md §2f): admissions plan
    /// real [`PagedKv`] block tables, share resident prefixes, and are
    /// gated on pool headroom instead of row count
    paged: Option<PagedKv>,
    /// per mid-admission row: (window tokens still to process, total
    /// planned) — the planned total makes the trace's `PrefillWindow`
    /// `start` offsets reconstructible from `planned - remaining`
    pending: Vec<Option<(usize, usize)>>,
    pstats: PrefillStats,
    /// (prompt, cfg, adapter) in admission order, for test assertions
    pub admissions: Vec<(String, SampleCfg, Option<AdapterId>)>,
    /// degradation lever (§2j): while false, drafter mode is bypassed
    /// and every row decodes one token per tick (the scheduler flips
    /// this through [`DecodeEngine::set_spec_enabled`] on Degrade /
    /// Recover)
    spec_enabled: bool,
}

/// Admission cost model for the [`SimEngine`] (ISSUE 5 satellite: charge
/// prefill ⌈len/C⌉-style work instead of admitting instantly, so the
/// scheduler benches actually exhibit — and measure the removal of — the
/// full-grid admission stall). A prompt of `len` tokens plans
/// `chunk_plan(ladder, len)` windows, and `prefill_tick` drains the
/// planned tokens at the scheduler's budget:
///
/// * monolithic baseline: a one-bucket ladder `[S]` (every admission pays
///   the padded grid) with `stall = true` — decode emits nothing while
///   any admission is in flight, the synchronous pad-to-S prefill;
/// * chunked: the real bucket ladder with `stall = false` — prefill
///   windows interleave with decode (the Sarathi-style budget loop).
pub struct SimPrefill {
    ladder: Vec<usize>,
    stall: bool,
}

/// Simulated drafter: every draft is accepted independently with
/// probability `accept_prob`, so a round emits `accepted-prefix + 1`
/// tokens — the scheduler sees exactly the multi-token event bursts (and,
/// at 0%, the rejection storm) a real [`SpecDecoder`] produces, without
/// artifacts.
struct SimSpec {
    k: usize,
    accept_prob: f64,
    rng: Rng,
    stats: SpecStats,
}

struct SimRow {
    cfg: SampleCfg,
    adapter: Option<AdapterId>,
    emitted: Vec<i32>,
    budget: usize,
}

impl SimEngine {
    pub fn new(batch: usize) -> SimEngine {
        SimEngine {
            batch,
            rows: (0..batch).map(|_| None).collect(),
            tk: Tokenizer::new(),
            spec: None,
            prefill_model: None,
            paged: None,
            pending: (0..batch).map(|_| None).collect(),
            pstats: PrefillStats::default(),
            admissions: vec![],
            spec_enabled: true,
        }
    }

    /// A [`SimEngine`] whose admissions cost prefill work (see
    /// [`SimPrefill`]): `ladder` holds the chunk buckets — a single
    /// `[grid]` bucket is the monolithic pad-to-S baseline — and `stall`
    /// freezes decode while admissions are in flight.
    pub fn with_prefill(batch: usize, ladder: Vec<usize>, stall: bool) -> SimEngine {
        assert!(!ladder.is_empty() && ladder.iter().zip(ladder.iter().skip(1)).all(|(a, b)| a < b));
        let mut e = SimEngine::new(batch);
        e.prefill_model = Some(SimPrefill { ladder, stall });
        e
    }

    /// A [`SimEngine`] over the paged block-pool capacity model
    /// (DESIGN.md §2f): `batch_rows` row slots — deliberately plentiful,
    /// decoupled from any dense grid — with admission capacity carried by
    /// a pool of `pool_blocks` × `block`-slot blocks, driven by the real
    /// [`PagedKv`] bookkeeping. Admission plans a block table through the
    /// shared-prefix index, so a prompt whose prefix is already resident
    /// charges prefill cost only for its non-resident suffix, and
    /// [`DecodeEngine::can_admit`] keeps requests queued while the pool
    /// lacks headroom. `ladder` prices the prefill windows as in
    /// [`SimEngine::with_prefill`] (its last bucket is the grid), never
    /// stalling — paged serving exists to kill the stall.
    pub fn with_paged(
        pool_blocks: usize,
        block: usize,
        batch_rows: usize,
        ladder: Vec<usize>,
    ) -> Result<SimEngine> {
        let Some(&grid) = ladder.last() else {
            bail!("with_paged: empty prefill ladder")
        };
        ensure!(
            ladder.iter().zip(ladder.iter().skip(1)).all(|(a, b)| a < b),
            "with_paged: ladder must be strictly increasing"
        );
        let mut e = SimEngine::new(batch_rows);
        e.prefill_model = Some(SimPrefill { ladder, stall: false });
        e.paged = Some(PagedKv::new(pool_blocks, block, batch_rows, grid)?);
        Ok(e)
    }

    /// A [`SimEngine`] in drafter mode: draft length `k`, per-draft
    /// acceptance probability `accept_prob` in [0, 1].
    pub fn with_spec(batch: usize, k: usize, accept_prob: f64, seed: u64) -> SimEngine {
        let mut e = SimEngine::new(batch);
        e.spec = Some(SimSpec {
            k: k.max(1),
            accept_prob: accept_prob.clamp(0.0, 1.0),
            rng: Rng::new(seed),
            stats: SpecStats::default(),
        });
        e
    }

    /// The token every step of an adapter-less request emits: its top-p as
    /// a printable byte (e.g. `top_p = 0.9` → 90 → `'Z'`).
    pub fn marker(cfg: &SampleCfg) -> i32 {
        (cfg.top_p * 100.0).round() as i32 % 256
    }

    /// The token an adapter-routed request emits: the adapter id as a
    /// capital letter (`a0` → `'A'`, `a1` → `'B'`, ...), so the emitted
    /// text *is* the routing record.
    pub fn adapter_marker(adapter: Option<AdapterId>, cfg: &SampleCfg) -> i32 {
        match adapter {
            Some(id) => b'A' as i32 + (id.ix() as i32 % 26),
            None => Self::marker(cfg),
        }
    }
}

impl DecodeEngine for SimEngine {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn free_rows(&self) -> usize {
        self.rows.iter().filter(|r| r.is_none()).count()
    }

    fn prefill(
        &mut self,
        prompt: &str,
        cfg: SampleCfg,
        adapter: Option<AdapterId>,
    ) -> Result<usize> {
        let row = self
            .rows
            .iter()
            .position(|r| r.is_none())
            .context("sim prefill: no free row")?;
        self.admissions.push((prompt.to_string(), cfg, adapter));
        self.rows[row] = Some(SimRow {
            cfg,
            adapter,
            emitted: vec![],
            budget: cfg.max_new.max(1),
        });
        Ok(row)
    }

    fn prefill_begin(
        &mut self,
        prompt: &str,
        cfg: SampleCfg,
        adapter: Option<AdapterId>,
        defer: bool,
    ) -> Result<(usize, bool)> {
        let row = self.prefill(prompt, cfg, adapter)?;
        // paged capacity model: plan the row's block table before any
        // prefill cost is charged — the resident shared-prefix tokens
        // (always a whole number of blocks below the frontier) are
        // skipped by the cost model below, and registration makes this
        // prompt's full blocks resident for the admissions behind it
        let mut resident = 0;
        if let Some(kv) = self.paged.as_mut() {
            let ids = {
                let mut ids = self.tk.encode(prompt);
                ids.truncate(kv.seq_len());
                if ids.is_empty() {
                    ids.push(1);
                }
                ids
            };
            let need = (ids.len() + cfg.max_new.max(1)).min(kv.seq_len());
            let planned = kv
                .plan_admit(row, &ids, need, true)
                .and_then(|r| kv.register(row, &ids).map(|_| r));
            match planned {
                Ok(r) => resident = r,
                Err(e) => {
                    let _ = kv.evict_row(row);
                    self.rows[row] = None;
                    self.admissions.pop();
                    return Err(e);
                }
            }
        }
        if let Some(pm) = &self.prefill_model {
            // constructors validate the ladder; an empty one degrades to
            // single-token windows rather than taking the batch down
            let grid = pm.ladder.last().copied().unwrap_or(1);
            let len = self.tk.encode(prompt).len().clamp(1, grid);
            let len = len.saturating_sub(resident).max(1);
            let plan = chunk_plan(&pm.ladder, len);
            let planned: usize = plan.iter().map(|(_, _, b)| *b).sum();
            self.pstats.prefill_tokens += planned;
            self.pstats.padded_prefill_tokens += planned - len;
            self.pstats.chunks += plan.len();
            // per the trait contract an un-deferred admission completes
            // in-call: the cost is charged either way, but only deferred
            // ones pend for prefill_tick pacing
            if defer {
                self.pending[row] = Some((planned, planned));
                return Ok((row, false));
            }
        }
        Ok((row, true))
    }

    fn prefill_tick(&mut self, budget: usize) -> Result<PrefillTickOut> {
        let mut out = PrefillTickOut::default();
        if self.prefill_model.is_none() {
            return Ok(out);
        }
        let mut left = budget;
        for row in 0..self.pending.len() {
            let Some((remaining, planned)) = self.pending[row].as_mut() else { continue };
            // drain the planned window tokens at the tick budget — bucket
            // granularity (padding included) is already charged in the
            // plan — with at least one token of progress per tick, the
            // same guarantee Generator::prefill_tick gives per window
            let cap = if left > 0 {
                left
            } else if out.spent == 0 {
                1
            } else {
                break;
            };
            let take = (*remaining).min(cap);
            let start = *planned - *remaining;
            *remaining -= take;
            trace::emit(|| Event::PrefillWindow { row, start, bucket: take });
            out.spent += take;
            left = left.saturating_sub(take);
            if *remaining == 0 {
                self.pending[row] = None;
                out.completed.push(row);
            }
        }
        Ok(out)
    }

    fn prefill_stats(&self) -> PrefillStats {
        self.pstats
    }

    fn decode_step(&mut self, _rng: &mut Rng) -> Result<Vec<StepOut>> {
        if let Some(pm) = &self.prefill_model {
            if pm.stall && self.pending.iter().any(|p| p.is_some()) {
                // the monolithic synchronous prefill freezes the batch
                return Ok(vec![]);
            }
        }
        let mut events = vec![];
        for (i, slot) in self.rows.iter_mut().enumerate() {
            let Some(r) = slot.as_mut() else { continue };
            if self.pending[i].is_some() {
                continue; // admission still being paced in
            }
            if r.emitted.len() >= r.budget {
                continue; // finished, awaiting take
            }
            let token = Self::adapter_marker(r.adapter, &r.cfg);
            let spec = if self.spec_enabled { self.spec.as_mut() } else { None };
            match spec {
                None => {
                    r.emitted.push(token);
                    events.push(StepOut {
                        row: i,
                        token,
                        finished: r.emitted.len() >= r.budget,
                        accepted: false,
                    });
                }
                Some(sp) => {
                    // one draft/verify round: k_eff drafts, accept the
                    // prefix that survives the coin flips, +1 correction
                    // the +1 correction token must fit the row's budget
                    let k_eff = sp.k.min(r.budget - r.emitted.len() - 1);
                    let mut accepted = 0;
                    while accepted < k_eff && sp.rng.f64() < sp.accept_prob {
                        accepted += 1;
                    }
                    sp.stats.rounds += 1;
                    sp.stats.draft_steps += if k_eff > 0 { k_eff + 1 } else { 0 };
                    sp.stats.verify_steps += 1;
                    sp.stats.drafted_tokens += k_eff;
                    sp.stats.accepted_tokens += accepted;
                    sp.stats.emitted_tokens += accepted + 1;
                    trace::emit(|| Event::VerifyRound { row: i, k: k_eff, accepted });
                    for j in 0..accepted + 1 {
                        r.emitted.push(token);
                        events.push(StepOut {
                            row: i,
                            token,
                            finished: r.emitted.len() >= r.budget,
                            accepted: j < accepted,
                        });
                    }
                }
            }
        }
        Ok(events)
    }

    fn take(&mut self, row: usize) -> Option<Vec<i32>> {
        self.pending.get_mut(row)?.take();
        if let Some(kv) = self.paged.as_mut() {
            let _ = kv.evict_row(row);
        }
        let out = self.rows.get_mut(row)?.take().map(|r| r.emitted);
        if out.is_some() {
            trace::emit(|| Event::Evict { row });
        }
        out
    }

    fn decode_text(&self, ids: &[i32]) -> String {
        self.tk.decode(ids)
    }

    fn spec_stats(&self) -> Option<SpecStats> {
        self.spec.as_ref().map(|s| s.stats)
    }

    fn can_admit(&mut self, prompt: &str, cfg: &SampleCfg) -> bool {
        let Some(kv) = self.paged.as_mut() else { return true };
        let mut ids = self.tk.encode(prompt);
        ids.truncate(kv.seq_len());
        if ids.is_empty() {
            ids.push(1);
        }
        let need = ids.len() + cfg.max_new.max(1);
        kv.probe(&ids, need) <= kv.free_blocks()
    }

    fn paged_stats(&self) -> Option<PagedStats> {
        self.paged.as_ref().map(|kv| kv.stats())
    }

    fn set_spec_enabled(&mut self, on: bool) {
        self.spec_enabled = on;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(top_p: f64, max_new: usize) -> SampleCfg {
        SampleCfg { temperature: 1.0, top_p, max_new }
    }

    /// Regression for the old `Server::step` grouping bug: requests were
    /// batched by (temperature, max_new) only, so a request with a
    /// different top_p was silently served under the head request's
    /// config. With per-row SampleCfg both decode together, each under its
    /// own config.
    #[test]
    fn two_requests_with_different_top_p_sample_under_their_own_cfg() {
        let mut srv = Server::new(SimEngine::new(2), 0);
        let a = srv.enqueue("alpha", cfg(0.90, 3)); // marker 90 = 'Z'
        let b = srv.enqueue("beta", cfg(0.50, 3)); // marker 50 = '2'
        let rs = srv.drain().unwrap();
        assert_eq!(rs.len(), 2);
        let ra = rs.iter().find(|r| r.id == a).unwrap();
        let rb = rs.iter().find(|r| r.id == b).unwrap();
        assert_eq!(ra.text, "ZZZ", "request a must sample under top_p=0.90");
        assert_eq!(rb.text, "222", "request b must sample under top_p=0.50");
        // and they shared the batch: 3 decode steps total, not 3 + 3
        assert_eq!(srv.stats.decode_steps, 3);
        assert_eq!(srv.engine.admissions.len(), 2);
        assert_eq!(srv.engine.admissions[0].1.top_p, 0.90);
        assert_eq!(srv.engine.admissions[1].1.top_p, 0.50);
    }

    /// A newly enqueued request is admitted into a freed row while an
    /// earlier request is still mid-decode (continuous batching), and a
    /// short request behind a long one is never head-of-line blocked.
    #[test]
    fn admits_mid_decode_and_short_requests_overtake_long_ones() {
        let mut srv = Server::new(SimEngine::new(2), 0);
        let r1 = srv.enqueue("one", cfg(0.9, 1));
        let r2 = srv.enqueue("two", cfg(0.9, 5));
        let r3 = srv.enqueue("three", cfg(0.9, 1));
        // tick 1: rows full with r1+r2, r3 queued; r1 completes
        let done1 = srv.step().unwrap();
        assert_eq!(done1.iter().map(|r| r.id).collect::<Vec<_>>(), vec![r1]);
        assert_eq!(srv.in_flight(), 1, "r2 still decoding");
        assert_eq!(srv.pending(), 1, "r3 still queued");
        // tick 2: r3 admitted into r1's freed row *while r2 decodes*
        let done2 = srv.step().unwrap();
        assert_eq!(srv.engine.admissions.len(), 3, "r3 admitted mid-decode");
        assert_eq!(done2.iter().map(|r| r.id).collect::<Vec<_>>(), vec![r3]);
        assert_eq!(srv.in_flight(), 1, "r2 still in flight after r3 finished");
        // r2 finishes last: completion order r1, r3, r2
        let rest = srv.drain().unwrap();
        assert_eq!(rest.iter().map(|r| r.id).collect::<Vec<_>>(), vec![r2]);
        assert_eq!(srv.stats.served, 3);
    }

    #[test]
    fn stats_track_ttft_throughput_and_occupancy() {
        let mut srv = Server::new(SimEngine::new(2), 0);
        for i in 0..4 {
            srv.enqueue(format!("req{i}"), cfg(0.95, 2 + i));
        }
        let rs = srv.drain().unwrap();
        assert_eq!(rs.len(), 4);
        let st = &srv.stats;
        assert_eq!(st.served, 4);
        assert_eq!(st.total_tokens, 2 + 3 + 4 + 5);
        assert!(st.tokens_per_sec() > 0.0 && st.tokens_per_sec().is_finite());
        assert!(st.mean_ttft_ms() >= 0.0 && st.mean_ttft_ms() <= st.mean_latency_ms());
        assert!(st.mean_occupancy() > 0.0 && st.mean_occupancy() <= 1.0);
        for r in &rs {
            assert!(r.ttft_ms <= r.latency_ms);
            assert!(r.tokens > 0);
        }
    }

    #[test]
    fn queue_pressure_stats_track_wait_and_peak_depth() {
        let mut srv = Server::new(SimEngine::new(2), 0);
        for i in 0..5 {
            srv.enqueue(format!("req{i}"), cfg(0.9, 2));
        }
        // nothing admitted yet: all five are waiting at once
        assert_eq!(srv.stats.peak_queue_depth, 5);
        assert_eq!(srv.stats.total_queue_wait_ms, 0.0);
        let rs = srv.drain().unwrap();
        assert_eq!(rs.len(), 5);
        assert_eq!(srv.stats.admitted, 5);
        // every admission recorded a (non-negative) wait; the peak is a
        // high-water mark, not reset by the drain
        assert!(srv.stats.mean_queue_wait_ms() >= 0.0);
        assert!(srv.stats.total_queue_wait_ms >= 0.0);
        assert_eq!(srv.stats.peak_queue_depth, 5);
        // an unloaded server records no queue pressure
        let mut idle = Server::new(SimEngine::new(2), 0);
        idle.enqueue("solo", cfg(0.9, 1));
        assert_eq!(idle.stats.peak_queue_depth, 1);
        idle.drain().unwrap();
        assert_eq!(idle.stats.admitted, 1);
    }

    /// The tentpole's scheduler contract: a mixed batch with >= 3 distinct
    /// adapters decodes *simultaneously* (no adapter partitions the batch)
    /// and every request's emitted stream proves it was routed through the
    /// adapter it named.
    #[test]
    fn mixed_adapter_batch_routes_each_request_through_its_own_adapter() {
        let mut srv = Server::new(SimEngine::new(4), 0);
        let a = srv.enqueue_adapter("alpha", cfg(0.9, 3), Some(AdapterId::for_slot(0)));
        let b = srv.enqueue_adapter("beta", cfg(0.9, 3), Some(AdapterId::for_slot(1)));
        let c = srv.enqueue_adapter("gamma", cfg(0.9, 3), Some(AdapterId::for_slot(2)));
        let d = srv.enqueue("delta", cfg(0.5, 3)); // adapter-less, marker '2'
        // all four decode in one batch: 3 steps total, not 4 x 3
        let rs = srv.drain().unwrap();
        assert_eq!(rs.len(), 4);
        assert_eq!(srv.stats.decode_steps, 3);
        let text = |id| rs.iter().find(|r| r.id == id).unwrap().text.clone();
        assert_eq!(text(a), "AAA", "request a must decode under adapter a0");
        assert_eq!(text(b), "BBB", "request b must decode under adapter a1");
        assert_eq!(text(c), "CCC", "request c must decode under adapter a2");
        assert_eq!(text(d), "222", "adapter-less request keeps its cfg marker");
        // the engine saw the adapters the requests named, in order
        let routed: Vec<_> = srv.engine.admissions.iter().map(|(_, _, ad)| *ad).collect();
        assert_eq!(
            routed,
            vec![Some(AdapterId::for_slot(0)), Some(AdapterId::for_slot(1)), Some(AdapterId::for_slot(2)), None]
        );
        // responses carry their adapter
        assert_eq!(rs.iter().find(|r| r.id == a).unwrap().adapter, Some(AdapterId::for_slot(0)));
    }

    /// Mixed-adapter queues keep free-row admission: an adapter never
    /// waits for same-adapter rows to free up.
    #[test]
    fn adapters_do_not_head_of_line_block_each_other() {
        let mut srv = Server::new(SimEngine::new(2), 0);
        let long = srv.enqueue_adapter("long", cfg(0.9, 5), Some(AdapterId::for_slot(0)));
        let _long2 = srv.enqueue_adapter("long2", cfg(0.9, 1), Some(AdapterId::for_slot(0)));
        let late = srv.enqueue_adapter("late", cfg(0.9, 1), Some(AdapterId::for_slot(1)));
        // tick 1: rows hold long+long2; late (different adapter) queued
        let done1 = srv.step().unwrap();
        assert_eq!(done1.len(), 1);
        // tick 2: late admitted into the freed row while long decodes
        let done2 = srv.step().unwrap();
        assert_eq!(done2.iter().map(|r| r.id).collect::<Vec<_>>(), vec![late]);
        assert!(srv.stats.served >= 2);
        let rest = srv.drain().unwrap();
        assert_eq!(rest.iter().map(|r| r.id).collect::<Vec<_>>(), vec![long]);
    }

    #[test]
    fn per_adapter_stats_break_down_requests_tokens_and_ttft() {
        let mut srv = Server::new(SimEngine::new(4), 0);
        for _ in 0..2 {
            srv.enqueue_adapter("x", cfg(0.9, 4), Some(AdapterId::for_slot(0)));
        }
        srv.enqueue_adapter("y", cfg(0.9, 2), Some(AdapterId::for_slot(1)));
        srv.enqueue("z", cfg(0.9, 3));
        srv.drain().unwrap();
        let st = &srv.stats;
        assert_eq!(st.per_adapter.len(), 3);
        let a0 = &st.per_adapter[&Some(AdapterId::for_slot(0))];
        let a1 = &st.per_adapter[&Some(AdapterId::for_slot(1))];
        let base = &st.per_adapter[&None];
        assert_eq!((a0.requests, a0.served, a0.tokens), (2, 2, 8));
        assert_eq!((a1.requests, a1.served, a1.tokens), (1, 1, 2));
        assert_eq!((base.requests, base.served, base.tokens), (1, 1, 3));
        // lanes partition the aggregate token count and throughput
        let lane_tokens: usize = st.per_adapter.values().map(|l| l.tokens).sum();
        assert_eq!(lane_tokens, st.total_tokens);
        let lane_tps: f64 = st
            .per_adapter
            .values()
            .map(|l| l.tokens_per_sec(st.decode_ms))
            .sum();
        assert!((lane_tps - st.tokens_per_sec()).abs() / st.tokens_per_sec() < 1e-6);
        for lane in st.per_adapter.values() {
            assert!(lane.mean_ttft_ms() >= 0.0);
            assert!(lane.mean_ttft_ms() <= lane.mean_latency_ms());
        }
        assert_eq!(adapter_label(Some(AdapterId::for_slot(2))), "a2");
        assert_eq!(adapter_label(None), "base");
    }

    /// An engine that refuses admission for a marker prompt — stands in
    /// for "request names an unregistered adapter".
    struct PickyEngine(SimEngine);

    impl DecodeEngine for PickyEngine {
        fn batch_size(&self) -> usize {
            self.0.batch_size()
        }
        fn free_rows(&self) -> usize {
            self.0.free_rows()
        }
        fn prefill(
            &mut self,
            prompt: &str,
            cfg: SampleCfg,
            adapter: Option<AdapterId>,
        ) -> Result<usize> {
            anyhow::ensure!(prompt != "bad", "adapter not registered");
            self.0.prefill(prompt, cfg, adapter)
        }
        fn decode_step(&mut self, rng: &mut Rng) -> Result<Vec<StepOut>> {
            self.0.decode_step(rng)
        }
        fn take(&mut self, row: usize) -> Option<Vec<i32>> {
            self.0.take(row)
        }
        fn decode_text(&self, ids: &[i32]) -> String {
            self.0.decode_text(ids)
        }
    }

    #[test]
    fn bad_request_is_rejected_without_taking_the_server_down() {
        let mut srv = Server::new(PickyEngine(SimEngine::new(2)), 0);
        let ok1 = srv.enqueue_adapter("fine", cfg(0.9, 2), Some(AdapterId::for_slot(0)));
        srv.enqueue("bad", cfg(0.9, 2));
        let ok2 = srv.enqueue_adapter("also fine", cfg(0.9, 2), Some(AdapterId::for_slot(1)));
        let rs = srv.drain().unwrap();
        let mut served: Vec<u64> = rs.iter().map(|r| r.id).collect();
        served.sort_unstable();
        assert_eq!(served, vec![ok1, ok2], "good requests survive the bad one");
        assert_eq!(srv.stats.rejected, 1);
        assert_eq!(srv.stats.served, 2);
        assert_eq!(srv.stats.admitted, 2);
    }

    #[test]
    fn engine_fault_with_no_progress_propagates() {
        // nothing in flight and every admission failing = the server
        // cannot make progress; that must surface, not drain into stats
        let mut srv = Server::new(PickyEngine(SimEngine::new(2)), 0);
        srv.enqueue("bad", cfg(0.9, 2));
        let err = srv.drain().unwrap_err().to_string();
        assert!(err.contains("no requests in flight"), "{err}");
        assert_eq!(srv.stats.rejected, 1);
        assert_eq!(srv.stats.served, 0);
    }

    /// The rejection-storm acceptance scenario: a drafter whose every
    /// draft is rejected degenerates to per-token decode. The scheduler
    /// must survive it — every request served, every row reclaimed, no
    /// token double-counted — with an acceptance rate of exactly 0.
    #[test]
    fn zero_acceptance_storm_leaks_no_rows() {
        let mut srv = Server::new(SimEngine::with_spec(2, 4, 0.0, 7), 0);
        for i in 0..6 {
            srv.enqueue(format!("req{i}"), cfg(0.9, 3 + i % 3));
        }
        let rs = srv.drain().unwrap();
        assert_eq!(rs.len(), 6);
        assert_eq!(srv.stats.served, 6);
        assert_eq!(srv.engine.free_rows(), 2, "rows leaked after the storm");
        assert_eq!(srv.in_flight(), 0);
        // 0% acceptance: every round emitted exactly the correction token
        let spec = srv.stats.spec.expect("spec engine reports counters");
        assert_eq!(spec.accepted_tokens, 0);
        assert_eq!(spec.emitted_tokens, srv.stats.total_tokens);
        assert_eq!(spec.verify_steps, srv.stats.total_tokens);
        assert_eq!(srv.stats.acceptance_rate(), Some(0.0));
        assert_eq!(srv.stats.accepted_tokens, 0);
        assert_eq!(srv.stats.draft_accept_share(), 0.0);
        // drafts were genuinely proposed (and all rejected)
        assert!(spec.drafted_tokens > 0);
    }

    /// Full acceptance: whole windows land per step; the scheduler must
    /// credit multiple tokens per row per tick and finish requests early.
    #[test]
    fn full_acceptance_emits_whole_windows_per_step() {
        let k = 3;
        let mut srv = Server::new(SimEngine::with_spec(2, k, 1.0, 7), 0);
        let a = srv.enqueue("a", cfg(0.9, 8)); // 8 tokens = 2 rounds of k+1
        let b = srv.enqueue("b", cfg(0.5, 8));
        let rs = srv.drain().unwrap();
        assert_eq!(rs.len(), 2);
        let text = |id| rs.iter().find(|r| r.id == id).unwrap().text.clone();
        assert_eq!(text(a), "Z".repeat(8), "burst tokens kept their row cfg");
        assert_eq!(text(b), "2".repeat(8));
        assert_eq!(srv.stats.decode_steps, 2, "k+1 tokens per row per step");
        assert_eq!(srv.stats.total_tokens, 16);
        let spec = srv.stats.spec.unwrap();
        assert_eq!(spec.accepted_tokens, spec.drafted_tokens);
        assert!((srv.stats.acceptance_rate().unwrap() - 1.0).abs() < 1e-12);
        // per-lane accepted tokens: k of every k+1 emitted
        let lane = &srv.stats.per_adapter[&None];
        assert_eq!(lane.tokens, 16);
        assert_eq!(lane.accepted_tokens, 12);
        assert!((lane.draft_accept_share() - 0.75).abs() < 1e-12);
    }

    /// Mid-acceptance drafter mixed with continuous batching: stats stay
    /// consistent (accepted <= drafted, emitted == served tokens) and
    /// rows keep recycling mid-decode.
    #[test]
    fn partial_acceptance_keeps_stats_consistent_under_churn() {
        let mut srv = Server::new(SimEngine::with_spec(2, 4, 0.6, 11), 3);
        for i in 0..8 {
            srv.enqueue(format!("req{i}"), cfg(0.9, 2 + i % 5));
        }
        let rs = srv.drain().unwrap();
        assert_eq!(rs.len(), 8);
        let spec = srv.stats.spec.unwrap();
        assert!(spec.accepted_tokens <= spec.drafted_tokens);
        assert_eq!(spec.emitted_tokens, srv.stats.total_tokens);
        assert_eq!(srv.stats.accepted_tokens, spec.accepted_tokens);
        let rate = srv.stats.acceptance_rate().unwrap();
        assert!((0.0..=1.0).contains(&rate));
        assert!(spec.tokens_per_verify() >= 1.0);
        // lanes still partition the totals under multi-token events
        let lane_tokens: usize =
            srv.stats.per_adapter.values().map(|l| l.tokens).sum();
        assert_eq!(lane_tokens, srv.stats.total_tokens);
        let lane_accepted: usize =
            srv.stats.per_adapter.values().map(|l| l.accepted_tokens).sum();
        assert_eq!(lane_accepted, srv.stats.accepted_tokens);
        assert_eq!(srv.engine.free_rows(), 2);
    }

    /// ISSUE 5 acceptance: under a bursty mixed-length load with the same
    /// per-tick token capacity, the chunked token-budget scheduler beats
    /// the monolithic pad-to-S admission on sim TTFT p95, keeps ITL
    /// bounded, and wastes fewer padded prefill tokens.
    #[test]
    fn token_budget_chunked_prefill_beats_monolithic_stall_on_bursty_load() {
        let grid = 64;
        let run = |ladder: Vec<usize>, stall: bool| {
            let mut srv = Server::new(SimEngine::with_prefill(4, ladder, stall), 0);
            srv.set_prefill_budget(Some(16));
            let mut sent = 0;
            let mut rs = vec![];
            for _burst in 0..4 {
                for _ in 0..6 {
                    // every third prompt is near-grid-long, the rest short
                    let prompt = if sent % 3 == 0 {
                        "L".repeat(60)
                    } else {
                        "hi".to_string()
                    };
                    srv.enqueue(prompt, cfg(0.9, 4));
                    sent += 1;
                }
                for _ in 0..6 {
                    rs.extend(srv.step().unwrap()); // next burst lands mid-decode
                }
            }
            rs.extend(srv.drain().unwrap());
            assert_eq!(rs.len(), sent);
            assert_eq!(srv.engine.free_rows(), 4, "rows leaked");
            srv.stats
        };
        let mono = run(vec![grid], true);
        let chunk = run(vec![16, grid], false);
        assert_eq!(mono.served, chunk.served);
        assert!(
            chunk.ttft_tick_p(95.0) < mono.ttft_tick_p(95.0),
            "chunked ttft p95 {} !< monolithic {}",
            chunk.ttft_tick_p(95.0),
            mono.ttft_tick_p(95.0)
        );
        assert!(
            chunk.itl_tick_p(95.0) <= mono.itl_tick_p(95.0),
            "chunked itl p95 {} > monolithic {}",
            chunk.itl_tick_p(95.0),
            mono.itl_tick_p(95.0)
        );
        assert!(chunk.itl_tick_p(95.0) <= 3.0, "chunked ITL unbounded");
        // the waste counter shows why: right-sized buckets, not pad-to-S
        assert!(chunk.prefill.padded_prefill_tokens < mono.prefill.padded_prefill_tokens);
        assert!(chunk.prefill.prefill_tokens < mono.prefill.prefill_tokens);
        // the baseline genuinely stalled (ticks where nothing decoded),
        // or the comparison is vacuous
        assert!(mono.ticks > mono.decode_steps, "monolithic baseline never stalled");
    }

    /// Budget pacing changes *when* admissions land, never what the rows
    /// emit: paced and instant admissions serve identical streams.
    #[test]
    fn paced_admission_emits_the_same_streams_as_instant_admission() {
        let run = |pace: bool| {
            let mut srv = if pace {
                let mut s = Server::new(SimEngine::with_prefill(2, vec![8, 32], false), 0);
                s.set_prefill_budget(Some(8));
                s
            } else {
                Server::new(SimEngine::new(2), 0)
            };
            for i in 0..5 {
                srv.enqueue(format!("req number {i}"), cfg(0.90, 3));
            }
            let mut rs = srv.drain().unwrap();
            rs.sort_by_key(|r| r.id);
            rs.into_iter().map(|r| r.text).collect::<Vec<_>>()
        };
        assert_eq!(run(true), run(false));
    }

    #[test]
    fn tick_stats_track_ttft_and_itl_distributions() {
        let mut srv = Server::new(SimEngine::new(2), 0);
        for i in 0..4 {
            srv.enqueue(format!("r{i}"), cfg(0.9, 3));
        }
        srv.drain().unwrap();
        assert_eq!(srv.stats.ttft_ticks.len(), 4);
        // 3 tokens per request: 2 inter-token gaps each
        assert_eq!(srv.stats.itl_ticks.len(), 8);
        assert!(srv.stats.ttft_tick_p(50.0) >= 1.0);
        assert!(srv.stats.itl_tick_p(95.0) >= 1.0);
        assert!(srv.stats.ticks >= srv.stats.decode_steps);
        // instant admissions report no prefill work at all
        assert_eq!(srv.stats.prefill, PrefillStats::default());
    }

    /// Engine whose chunked admission fails mid-window for a marker
    /// prompt — stands in for "adapter evicted between chunks" (the
    /// Scheduler::step admission-failure satellite).
    struct MidChunkFailEngine {
        inner: SimEngine,
        poison_rows: Vec<usize>,
    }

    impl DecodeEngine for MidChunkFailEngine {
        fn batch_size(&self) -> usize {
            self.inner.batch_size()
        }
        fn free_rows(&self) -> usize {
            self.inner.free_rows()
        }
        fn prefill(
            &mut self,
            prompt: &str,
            cfg: SampleCfg,
            adapter: Option<AdapterId>,
        ) -> Result<usize> {
            self.inner.prefill(prompt, cfg, adapter)
        }
        fn prefill_begin(
            &mut self,
            prompt: &str,
            cfg: SampleCfg,
            adapter: Option<AdapterId>,
            defer: bool,
        ) -> Result<(usize, bool)> {
            let (row, done) = self.inner.prefill_begin(prompt, cfg, adapter, defer)?;
            if prompt == "poison" {
                self.poison_rows.push(row);
                return Ok((row, false));
            }
            Ok((row, done))
        }
        fn prefill_tick(&mut self, budget: usize) -> Result<PrefillTickOut> {
            let mut out = self.inner.prefill_tick(budget)?;
            for row in self.poison_rows.drain(..) {
                // the engine releases the row itself, like the real
                // Generator::prefill_tick, then reports the failure
                self.inner.take(row);
                out.completed.retain(|&r| r != row);
                out.failed.push(row);
            }
            Ok(out)
        }
        fn decode_step(&mut self, rng: &mut Rng) -> Result<Vec<StepOut>> {
            self.inner.decode_step(rng)
        }
        fn take(&mut self, row: usize) -> Option<Vec<i32>> {
            self.inner.take(row)
        }
        fn decode_text(&self, ids: &[i32]) -> String {
            self.inner.decode_text(ids)
        }
    }

    /// A request rejected mid-chunk releases its row for the next request
    /// and never leaks into the admitted/queue-wait accounting.
    #[test]
    fn mid_chunk_rejection_releases_row_and_skips_queue_accounting() {
        let mut srv = Server::new(
            MidChunkFailEngine { inner: SimEngine::new(2), poison_rows: vec![] },
            0,
        );
        srv.set_prefill_budget(Some(8));
        let ok1 = srv.enqueue("fine", cfg(0.9, 2));
        srv.enqueue("poison", cfg(0.9, 2));
        let ok2 = srv.enqueue("also fine", cfg(0.9, 2));
        let rs = srv.drain().unwrap();
        let mut served: Vec<u64> = rs.iter().map(|r| r.id).collect();
        served.sort_unstable();
        assert_eq!(served, vec![ok1, ok2], "good requests survive the poisoned one");
        assert_eq!(srv.stats.rejected, 1);
        // the rejected request's partial admission never reached the
        // admitted / queue-wait ledgers, and the peak depth is the real
        // high-water mark of the queue, not inflated by the rejection
        assert_eq!(srv.stats.admitted, 2);
        assert_eq!(srv.stats.served, 2);
        assert_eq!(srv.stats.peak_queue_depth, 3);
        assert!(srv.stats.mean_queue_wait_ms() >= 0.0);
        // its row was released and is reusable
        assert_eq!(srv.engine.free_rows(), 2);
        assert_eq!(srv.in_flight(), 0);
    }

    /// §2f acceptance: at identical pool bytes (dense 4 rows × 64 slots
    /// == paged 32 blocks × 8 slots), a shared-system-prompt workload on
    /// the paged engine beats the dense grid on sim TTFT p95 and holds
    /// strictly more concurrent rows — with zero copy-on-write forks
    /// (the share-only-full-blocks invariant) and less prefill work
    /// (resident prefixes skip their windows).
    #[test]
    fn paged_shared_prefix_beats_dense_on_ttft_and_capacity() {
        let sys = "system: you are a terse helpful assistant. ";
        let run = |paged: bool| {
            let mut srv = if paged {
                Server::new(SimEngine::with_paged(32, 8, 32, vec![16, 64]).unwrap(), 0)
            } else {
                Server::new(SimEngine::with_prefill(4, vec![16, 64], false), 0)
            };
            srv.set_prefill_budget(Some(16));
            let mut sent = 0;
            let mut rs = vec![];
            for _burst in 0..4 {
                for u in 0..8 {
                    // N users share the system prompt; suffixes differ
                    srv.enqueue(format!("{sys}user {u}"), cfg(0.9, 4));
                    sent += 1;
                }
                for _ in 0..6 {
                    rs.extend(srv.step().unwrap()); // next burst lands mid-decode
                }
            }
            rs.extend(srv.drain().unwrap());
            assert_eq!(rs.len(), sent, "paged={paged}: requests lost");
            srv.stats
        };
        let dense = run(false);
        let paged = run(true);
        assert_eq!(dense.served, paged.served);
        assert!(
            paged.ttft_tick_p(95.0) < dense.ttft_tick_p(95.0),
            "paged ttft p95 {} !< dense {}",
            paged.ttft_tick_p(95.0),
            dense.ttft_tick_p(95.0)
        );
        // capacity decoupling: the dense grid pins concurrency at its 4
        // rows; the paged pool holds strictly more at the same bytes
        assert_eq!(dense.peak_in_flight, 4, "dense capacity is the grid");
        assert!(
            paged.peak_in_flight > dense.peak_in_flight,
            "paged peak in-flight {} !> dense {}",
            paged.peak_in_flight,
            dense.peak_in_flight
        );
        let ps = paged.paged.expect("paged engine reports pool counters");
        assert!(ps.prefix_hits > 0, "shared system prompt never hit");
        assert!(ps.prefix_hit_rate() > 0.0);
        assert_eq!(ps.cow_copies, 0, "the serving flow never forks a block");
        assert!(dense.paged.is_none(), "dense engine reports no pool");
        // resident prefixes skipped their windows: strictly less
        // admission work for the same served set
        assert!(paged.prefill.prefill_tokens < dense.prefill.prefill_tokens);
    }

    /// Pool-pressure scheduling: when the block pool lacks headroom,
    /// requests wait in the queue (never rejected) and admit as
    /// completions free blocks — every request is eventually served.
    #[test]
    fn paged_pool_pressure_queues_instead_of_rejecting() {
        // 8 blocks of 4 slots; long distinct prompts (~5 blocks each with
        // decode room) mean only one fits at a time
        let mut srv = Server::new(SimEngine::with_paged(8, 4, 8, vec![4, 16]).unwrap(), 0);
        srv.set_prefill_budget(Some(16));
        for i in 0..4 {
            srv.enqueue(format!("request number {i} padded out"), cfg(0.9, 3));
        }
        let rs = srv.drain().unwrap();
        assert_eq!(rs.len(), 4, "pool pressure must delay, not drop");
        assert_eq!(srv.stats.rejected, 0);
        assert_eq!(srv.stats.served, 4);
        assert!(srv.stats.peak_in_flight < 4, "pool cannot hold all four");
        // all blocks released once drained (index-resident blocks aside)
        let ps = srv.stats.paged.expect("paged stats");
        assert!(ps.blocks_in_use <= ps.pool_blocks);
        assert_eq!(srv.engine.free_rows(), 8);
    }

    #[test]
    fn step_with_nothing_to_do_is_a_noop() {
        let mut srv = Server::new(SimEngine::new(2), 0);
        assert!(srv.step().unwrap().is_empty());
        assert_eq!(srv.stats.decode_steps, 0);
        assert!(srv.drain().unwrap().is_empty());
    }

    #[test]
    fn zero_token_budget_is_clamped_so_requests_complete() {
        let mut srv = Server::new(SimEngine::new(1), 0);
        srv.enqueue("empty", cfg(0.9, 0));
        let rs = srv.drain().unwrap();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].tokens, 1);
    }

    // `trace` and `Event` arrive via `super::*` (the serving imports)
    use crate::obs::audit::{audit, AuditReport};
    use crate::obs::export;

    /// Percentiles reconstructed from raw trace ticks, via the same
    /// [`crate::util::stats::percentiles_of`] the ServerStats helpers use.
    fn trace_pcts(ticks: &[usize], ps: &[f64]) -> Vec<f64> {
        let v: Vec<f64> = ticks.iter().map(|&t| t as f64).collect();
        crate::util::stats::percentiles_of(&v, ps)
    }

    /// The trace is the ground truth the stats must agree with: replaying
    /// the raw events reconstructs the *exact* TTFT/ITL tick vectors the
    /// scheduler accumulated, so the percentiles match bit-for-bit.
    fn assert_trace_matches_stats(a: &AuditReport, st: &ServerStats) {
        assert!(a.ok(), "conservation violations: {:#?}", a.violations);
        assert_eq!(a.finished, st.served);
        assert_eq!(a.tokens, st.total_tokens);
        assert_eq!(a.ttft_ticks, st.ttft_ticks, "ttft vectors diverge");
        assert_eq!(a.itl_ticks, st.itl_ticks, "itl vectors diverge");
        let ps = [50.0, 95.0];
        assert_eq!(trace_pcts(&a.ttft_ticks, &ps), st.ttft_tick_pcts(&ps));
        assert_eq!(trace_pcts(&a.itl_ticks, &ps), st.itl_tick_pcts(&ps));
    }

    /// ISSUE 7 scenario 1: bursty mixed-length load through the chunked
    /// token-budget scheduler — the trace audit passes and reproduces the
    /// scheduler's latency distributions exactly.
    #[test]
    fn trace_audit_bursty_chunked_load_matches_server_stats() {
        trace::install(trace::DEFAULT_CAP, false);
        let mut srv = Server::new(SimEngine::with_prefill(4, vec![16, 64], false), 0);
        srv.set_prefill_budget(Some(16));
        let mut sent = 0;
        for _burst in 0..3 {
            for i in 0..6 {
                let prompt =
                    if i % 3 == 0 { "L".repeat(60) } else { "hi".to_string() };
                srv.enqueue(prompt, cfg(0.9, 4));
                sent += 1;
            }
            for _ in 0..6 {
                srv.step().unwrap(); // next burst lands mid-decode
            }
        }
        srv.drain().unwrap();
        let sink = trace::take().expect("sink installed");
        assert_eq!(sink.dropped(), 0, "ring too small for the scenario");
        let evs = sink.into_events();
        let a = audit(&evs);
        assert_eq!(a.enqueued, sent);
        assert_trace_matches_stats(&a, &srv.stats);
        // the paced admissions left PrefillWindow breadcrumbs whose token
        // sum is the planned prefill work the stats charged
        let windowed: usize = evs
            .iter()
            .filter_map(|s| match s.ev {
                Event::PrefillWindow { bucket, .. } => Some(bucket),
                _ => None,
            })
            .sum();
        assert_eq!(windowed, srv.stats.prefill.prefill_tokens);
    }

    /// ISSUE 7 scenario 2: the 0%-acceptance speculative storm — every
    /// VerifyRound in the trace shows `accepted == 0`, one round per
    /// emitted token, and the audit still balances.
    #[test]
    fn trace_audit_zero_acceptance_spec_storm() {
        trace::install(trace::DEFAULT_CAP, false);
        let mut srv = Server::new(SimEngine::with_spec(2, 4, 0.0, 7), 0);
        for i in 0..6 {
            srv.enqueue(format!("req{i}"), cfg(0.9, 3 + i % 3));
        }
        srv.drain().unwrap();
        let evs = trace::take().expect("sink installed").into_events();
        let a = audit(&evs);
        assert_trace_matches_stats(&a, &srv.stats);
        let spec = srv.stats.spec.expect("spec engine reports counters");
        assert_eq!(a.verify_rounds, spec.rounds);
        assert!(a.verify_rounds > 0);
        for s in &evs {
            if let Event::VerifyRound { accepted, .. } = s.ev {
                assert_eq!(accepted, 0, "storm rounds must accept nothing");
            }
        }
    }

    /// ISSUE 7 scenario 3: paged serving with a shared system prompt —
    /// the trace carries the block ledger (alloc/free pairing audited,
    /// end-of-trace residency == the pool's `blocks_in_use`), prefix hits,
    /// and zero copy-on-write forks.
    #[test]
    fn trace_audit_paged_prefix_reuse_balances_the_block_ledger() {
        trace::install(trace::DEFAULT_CAP, false);
        let sys = "system: you are a terse helpful assistant. ";
        let mut srv =
            Server::new(SimEngine::with_paged(32, 8, 32, vec![16, 64]).unwrap(), 0);
        srv.set_prefill_budget(Some(16));
        for u in 0..8 {
            srv.enqueue(format!("{sys}user {u}"), cfg(0.9, 4));
        }
        srv.drain().unwrap();
        let a = audit(&trace::take().expect("sink installed").into_events());
        assert_trace_matches_stats(&a, &srv.stats);
        assert!(a.prefix_hits > 0, "shared system prompt never hit");
        assert_eq!(a.cow_copies, 0, "the serving flow never forks a block");
        // blocks still live in the trace are exactly the pool's current
        // residency (the prefix index legitimately retains them)
        let ps = srv.engine.paged_stats().expect("paged stats");
        assert_eq!(a.live_blocks, ps.blocks_in_use);
    }

    /// ISSUE 7 determinism: two identical sim runs produce byte-identical
    /// exported traces — the tick clock carries no wall time.
    #[test]
    fn identical_sim_runs_export_identical_trace_bytes() {
        let run = || {
            trace::install(trace::DEFAULT_CAP, false);
            let mut srv = Server::new(SimEngine::with_spec(2, 3, 0.5, 13), 5);
            for i in 0..5 {
                srv.enqueue(format!("req{i}"), cfg(0.9, 4 + i % 2));
            }
            srv.drain().unwrap();
            let sink = trace::take().expect("sink installed");
            assert!(!sink.wall_clock());
            export::trace_json(&sink, vec![]).to_string()
        };
        let (a, b) = (run(), run());
        assert!(!a.is_empty());
        assert_eq!(a, b, "sim traces must be byte-deterministic");
    }

    /// ISSUE 7 acceptance: with no sink installed, serving records no
    /// events at all — the closures passed to `trace::emit` never run.
    #[test]
    fn disabled_tracing_records_no_events() {
        assert!(!trace::active());
        let before = trace::recorded();
        let mut srv = Server::new(SimEngine::with_prefill(2, vec![8, 32], false), 0);
        srv.set_prefill_budget(Some(8));
        for i in 0..4 {
            srv.enqueue(format!("req{i}"), cfg(0.9, 3));
        }
        let rs = srv.drain().unwrap();
        assert_eq!(rs.len(), 4);
        assert_eq!(
            trace::recorded(),
            before,
            "disabled tracing must not construct events"
        );
    }

    // ---- ISSUE 9: SLO-aware scheduling scenario suite -----------------

    /// Per-request TTFT ticks reconstructed from the raw trace (row →
    /// request mapping replayed from Admit/Finish/Preempt lifetimes) —
    /// what the per-class A/B assertions below measure.
    fn per_req_ttft_ticks(evs: &[trace::Stamped]) -> BTreeMap<u64, u64> {
        let mut rows: BTreeMap<usize, u64> = BTreeMap::new();
        let mut enq: BTreeMap<u64, u64> = BTreeMap::new();
        let mut ttft: BTreeMap<u64, u64> = BTreeMap::new();
        for s in evs {
            match s.ev {
                Event::Enqueue { req } => {
                    enq.insert(req, s.tick);
                }
                Event::Admit { req, row } => {
                    rows.insert(row, req);
                }
                Event::DecodeStep { row } => {
                    if let Some(&req) = rows.get(&row) {
                        ttft.entry(req).or_insert(s.tick - enq[&req]);
                    }
                }
                Event::Finish { row, .. } | Event::Preempt { row, .. } => {
                    rows.remove(&row);
                }
                _ => {}
            }
        }
        ttft
    }

    /// Peak number of rows simultaneously held by requests in `ids`.
    fn peak_concurrent_rows(evs: &[trace::Stamped], ids: &[u64]) -> usize {
        let mut occ: BTreeMap<usize, u64> = BTreeMap::new();
        let mut peak = 0;
        for s in evs {
            match s.ev {
                Event::Admit { req, row } => {
                    occ.insert(row, req);
                }
                Event::Finish { row, .. } | Event::Preempt { row, .. } => {
                    occ.remove(&row);
                }
                Event::Reject { req } => {
                    occ.retain(|_, r| *r != req);
                }
                _ => {}
            }
            peak = peak.max(occ.values().filter(|r| ids.contains(r)).count());
        }
        peak
    }

    /// Tentpole scenario 1: preempt-and-requeue yields a byte-identical
    /// stream to an unpreempted run of the same request; the discarded
    /// tokens are conserved by audit law 6 and TTFT is recorded once.
    #[test]
    fn preempted_request_streams_byte_identical_to_unpreempted_run() {
        // unpreempted reference: the same request on an idle server
        let mut alone = Server::new(SimEngine::new(1), 0);
        alone.enqueue_slo("victim", cfg(0.9, 6), None, Priority::Low, None);
        let reference = alone.drain().unwrap().remove(0);

        trace::install(trace::DEFAULT_CAP, false);
        let mut srv = Server::new(SimEngine::new(1), 0);
        srv.set_slo(true);
        let victim = srv.enqueue_slo("victim", cfg(0.9, 6), None, Priority::Low, None);
        srv.step().unwrap(); // victim admitted, token 1
        srv.step().unwrap(); // token 2
        let vip = srv.enqueue_slo("vip", cfg(0.5, 2), None, Priority::High, None);
        let mut rs = srv.drain().unwrap();
        rs.sort_by_key(|r| r.id);
        assert_eq!(rs.iter().map(|r| r.id).collect::<Vec<_>>(), vec![victim, vip]);
        assert_eq!(rs[1].text, "22", "vip overtook the victim wholesale");
        assert_eq!(rs[0].text, reference.text, "re-run stream must be byte-identical");
        assert_eq!(rs[0].text, "ZZZZZZ");
        assert_eq!(srv.stats.preempted, 1);
        // every sampled token is accounted: 2 discarded + 6 re-run + 2 vip
        assert_eq!(srv.stats.total_tokens, 2 + 6 + 2);
        let a = audit(&trace::take().expect("sink installed").into_events());
        assert_trace_matches_stats(&a, &srv.stats);
        assert_eq!(a.preempted, 1);
        assert_eq!(a.preempted_tokens, 2);
        // TTFT recorded once per request, never re-recorded by the re-run
        assert_eq!(srv.stats.ttft_ticks.len(), 2);
    }

    /// Tentpole scenario 2: a deadline storm cancels exactly the expired
    /// queued requests — never in-flight ones — with no row leaks, and a
    /// cancelled request never admits or decodes (audit law 7).
    #[test]
    fn deadline_storm_cancels_only_expired_requests_without_row_leaks() {
        trace::install(trace::DEFAULT_CAP, false);
        let mut srv = Server::new(SimEngine::new(2), 0);
        srv.set_slo(true);
        let long_a = srv.enqueue_slo("a", cfg(0.9, 10), None, Priority::Normal, None);
        let long_b = srv.enqueue_slo("b", cfg(0.9, 10), None, Priority::Normal, None);
        let doomed: Vec<u64> = (0..4)
            .map(|i| {
                srv.enqueue_slo(format!("d{i}"), cfg(0.9, 2), None, Priority::Normal, Some(1))
            })
            .collect();
        let patient_a = srv.enqueue_slo("p0", cfg(0.9, 2), None, Priority::Normal, Some(100));
        let patient_b = srv.enqueue_slo("p1", cfg(0.9, 2), None, Priority::Normal, Some(100));
        let rs = srv.drain().unwrap();
        let mut served: Vec<u64> = rs.iter().map(|r| r.id).collect();
        served.sort_unstable();
        assert_eq!(served, vec![long_a, long_b, patient_a, patient_b]);
        assert_eq!(srv.stats.cancelled, 4);
        assert_eq!(srv.stats.served, 4);
        assert_eq!(srv.stats.deadline_misses, 0, "survivors finished in time");
        assert_eq!(srv.stats.rejected, 0, "cancel is not reject");
        assert_eq!(srv.engine.free_rows(), 2, "rows leaked");
        assert_eq!(srv.in_flight(), 0);
        // goodput: 4 good finishes out of 4 served + 4 cancelled
        assert!((srv.stats.goodput() - 0.5).abs() < 1e-12);
        let evs = trace::take().expect("sink installed").into_events();
        let a = audit(&evs);
        assert_trace_matches_stats(&a, &srv.stats);
        assert_eq!(a.cancelled, 4);
        for s in &evs {
            if let Event::Admit { req, .. } = s.ev {
                assert!(!doomed.contains(&req), "cancelled req {req} was admitted");
            }
        }
    }

    /// Tentpole A/B: under a backlog of long Low requests with High
    /// arrivals landing mid-flight, the SLO scheduler's high-priority
    /// TTFT p95 beats FIFO's — the priority-inversion bound.
    #[test]
    fn high_priority_ttft_p95_beats_fifo_under_mixed_load() {
        let run = |slo: bool| -> (Vec<f64>, usize) {
            trace::install(trace::DEFAULT_CAP, false);
            let mut srv = Server::new(SimEngine::new(2), 0);
            srv.set_slo(slo);
            for i in 0..8 {
                srv.enqueue_slo(format!("low{i}"), cfg(0.9, 6), None, Priority::Low, None);
            }
            let mut vips = vec![];
            for burst in 0..4 {
                for _ in 0..4 {
                    srv.step().unwrap();
                }
                vips.push(srv.enqueue_slo(
                    format!("hi{burst}"),
                    cfg(0.5, 2),
                    None,
                    Priority::High,
                    None,
                ));
            }
            srv.drain().unwrap();
            let evs = trace::take().expect("sink installed").into_events();
            let a = audit(&evs);
            assert_trace_matches_stats(&a, &srv.stats);
            let ttft = per_req_ttft_ticks(&evs);
            (vips.iter().map(|id| ttft[id] as f64).collect(), srv.stats.preempted)
        };
        let (fifo, fifo_preempts) = run(false);
        let (slo, slo_preempts) = run(true);
        assert_eq!(fifo_preempts, 0, "FIFO must never preempt");
        assert!(slo_preempts > 0, "SLO arm must have preempted for its VIPs");
        let p95 = |xs: &[f64]| crate::util::stats::percentiles_of(xs, &[95.0])[0];
        assert!(
            p95(&slo) < p95(&fifo),
            "slo high-prio ttft p95 {} !< fifo {}",
            p95(&slo),
            p95(&fifo)
        );
    }

    /// Tentpole scenario: the adapter-fairness cap holds under 10:1 skew —
    /// the hot lane never exceeds its row cap, the cold lane's requests
    /// stop waiting behind the hot backlog, and everything is served.
    #[test]
    fn adapter_fairness_cap_holds_under_ten_to_one_skew() {
        let hot = Some(AdapterId::for_slot(0));
        let cold = Some(AdapterId::for_slot(1));
        let run = |cap: Option<usize>| -> (usize, u64) {
            trace::install(trace::DEFAULT_CAP, false);
            let mut srv = Server::new(SimEngine::new(4), 0);
            srv.set_slo(true);
            srv.set_adapter_fair_cap(cap);
            let mut hot_ids = vec![];
            let mut cold_ids = vec![];
            for burst in 0..2 {
                for i in 0..10 {
                    hot_ids.push(srv.enqueue_adapter(format!("hot{burst}-{i}"), cfg(0.9, 4), hot));
                }
                cold_ids.push(srv.enqueue_adapter(format!("cold{burst}"), cfg(0.9, 2), cold));
            }
            let rs = srv.drain().unwrap();
            assert_eq!(rs.len(), 22, "10:1 skew must not drop anything");
            let evs = trace::take().expect("sink installed").into_events();
            let a = audit(&evs);
            assert_trace_matches_stats(&a, &srv.stats);
            let ttft = per_req_ttft_ticks(&evs);
            let worst_cold = cold_ids.iter().map(|id| ttft[id]).max().unwrap();
            (peak_concurrent_rows(&evs, &hot_ids), worst_cold)
        };
        let (hot_capped, cold_capped) = run(Some(2));
        let (hot_free, cold_free) = run(None);
        assert!(hot_capped <= 2, "hot lane exceeded its cap: {hot_capped} rows");
        assert_eq!(hot_free, 4, "uncapped hot lane should saturate the grid");
        assert!(
            cold_capped < cold_free,
            "capped cold ttft {cold_capped} !< uncapped {cold_free}"
        );
    }

    /// Tentpole scenario: preemption mid-speculative-decode — the victim
    /// is evicted between verify rounds with its multi-token bursts
    /// conserved (`Preempt.tokens` counts every DecodeStep of the life),
    /// and the re-run still emits the identical stream.
    #[test]
    fn preemption_under_speculative_rounds_conserves_burst_tokens() {
        let mut alone = Server::new(SimEngine::with_spec(1, 3, 1.0, 7), 0);
        alone.enqueue_slo("victim", cfg(0.9, 8), None, Priority::Low, None);
        let reference = alone.drain().unwrap().remove(0);

        trace::install(trace::DEFAULT_CAP, false);
        let mut srv = Server::new(SimEngine::with_spec(1, 3, 1.0, 7), 0);
        srv.set_slo(true);
        let victim = srv.enqueue_slo("victim", cfg(0.9, 8), None, Priority::Low, None);
        srv.step().unwrap(); // one verify round: a k+1 = 4 token burst
        let vip = srv.enqueue_slo("vip", cfg(0.5, 2), None, Priority::High, None);
        let mut rs = srv.drain().unwrap();
        rs.sort_by_key(|r| r.id);
        assert_eq!(rs.iter().map(|r| r.id).collect::<Vec<_>>(), vec![victim, vip]);
        assert_eq!(rs[0].text, reference.text, "re-run stream must be byte-identical");
        assert_eq!(rs[0].text, "Z".repeat(8));
        let a = audit(&trace::take().expect("sink installed").into_events());
        assert_trace_matches_stats(&a, &srv.stats);
        assert_eq!(a.preempted, 1);
        assert_eq!(a.preempted_tokens, 4, "one full k+1 burst discarded");
        assert_eq!(srv.stats.total_tokens, 8 + 2 + 4);
    }

    /// Engine standing in for pool pressure racing a forced admission:
    /// `can_admit` refuses "pressed" while the poison is armed, the
    /// forced attempt reserves a row anyway (idle engine), and the
    /// admission then fails mid-chunk — exactly once.
    struct PoolPressureEngine {
        inner: SimEngine,
        armed: bool,
        pressed_row: Option<usize>,
    }

    impl DecodeEngine for PoolPressureEngine {
        fn batch_size(&self) -> usize {
            self.inner.batch_size()
        }
        fn free_rows(&self) -> usize {
            self.inner.free_rows()
        }
        fn prefill(
            &mut self,
            prompt: &str,
            cfg: SampleCfg,
            adapter: Option<AdapterId>,
        ) -> Result<usize> {
            self.inner.prefill(prompt, cfg, adapter)
        }
        fn prefill_begin(
            &mut self,
            prompt: &str,
            cfg: SampleCfg,
            adapter: Option<AdapterId>,
            defer: bool,
        ) -> Result<(usize, bool)> {
            let (row, done) = self.inner.prefill_begin(prompt, cfg, adapter, defer)?;
            if prompt == "pressed" && self.armed {
                self.pressed_row = Some(row);
                return Ok((row, false));
            }
            Ok((row, done))
        }
        fn prefill_tick(&mut self, budget: usize) -> Result<PrefillTickOut> {
            let mut out = self.inner.prefill_tick(budget)?;
            if let Some(row) = self.pressed_row.take() {
                // pool pressure strikes: the engine releases the row
                // itself, like the real Generator::prefill_tick, then
                // reports the failure — and the pressure clears with it
                self.inner.take(row);
                out.completed.retain(|&r| r != row);
                out.failed.push(row);
                self.armed = false;
            }
            Ok(out)
        }
        fn can_admit(&mut self, prompt: &str, _cfg: &SampleCfg) -> bool {
            !(prompt == "pressed" && self.armed)
        }
        fn decode_step(&mut self, rng: &mut Rng) -> Result<Vec<StepOut>> {
            self.inner.decode_step(rng)
        }
        fn take(&mut self, row: usize) -> Option<Vec<i32>> {
            self.inner.take(row)
        }
        fn decode_text(&self, ids: &[i32]) -> String {
            self.inner.decode_text(ids)
        }
    }

    /// ISSUE 9 satellite regression: a forced admission (attempted while
    /// nothing was in flight despite `can_admit` saying no) that fails
    /// mid-chunk while *other* rows were admitted since is pool pressure —
    /// the request must requeue with its original clocks and eventually
    /// serve. Before the fix it was dropped into `rejected`.
    #[test]
    fn forced_admit_that_fails_under_pressure_requeues_instead_of_rejecting() {
        trace::install(trace::DEFAULT_CAP, false);
        let mut srv = Server::new(
            PoolPressureEngine { inner: SimEngine::new(2), armed: true, pressed_row: None },
            0,
        );
        srv.set_prefill_budget(Some(8));
        let pressed = srv.enqueue("pressed", cfg(0.9, 2));
        let bystander = srv.enqueue("bystander", cfg(0.5, 3));
        let rs = srv.drain().unwrap();
        let mut served: Vec<u64> = rs.iter().map(|r| r.id).collect();
        served.sort_unstable();
        assert_eq!(served, vec![pressed, bystander], "pressed request must survive");
        assert_eq!(srv.stats.rejected, 0, "pool pressure is not a rejection");
        assert_eq!(srv.stats.preempted, 1, "the failed forced admit requeued");
        assert_eq!(srv.stats.served, 2);
        assert_eq!(srv.stats.admitted, 2, "the aborted life never reached the ledger");
        assert_eq!(srv.engine.free_rows(), 2);
        let a = audit(&trace::take().expect("sink installed").into_events());
        assert_trace_matches_stats(&a, &srv.stats);
        assert_eq!(a.preempted, 1);
        assert_eq!(a.preempted_tokens, 0);
    }

    /// Classes admit in priority order, FIFO within a class — and equal
    /// priorities never preempt each other (strict inequality only).
    #[test]
    fn priority_classes_admit_in_order_and_equals_never_preempt() {
        let mut srv = Server::new(SimEngine::new(1), 0);
        srv.set_slo(true);
        let low = srv.enqueue_slo("a", cfg(0.9, 2), None, Priority::Low, None);
        let mid1 = srv.enqueue_slo("b", cfg(0.9, 2), None, Priority::Normal, None);
        let high = srv.enqueue_slo("c", cfg(0.9, 2), None, Priority::High, None);
        let mid2 = srv.enqueue_slo("d", cfg(0.9, 2), None, Priority::Normal, None);
        let rs = srv.drain().unwrap();
        assert_eq!(
            rs.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![high, mid1, mid2, low],
            "admission must be High first, then FIFO Normals, then Low"
        );
        assert_eq!(srv.stats.preempted, 0, "equal classes never preempt");
        assert!(Priority::Low < Priority::Normal && Priority::Normal < Priority::High);
        assert_eq!(Priority::default(), Priority::Normal);
    }

    /// A request served past its deadline records exactly one
    /// `DeadlineMiss` (audit law 8: misses require a finish) and drops
    /// out of goodput while staying in `served` — an in-flight request
    /// is never cancelled, however late it runs.
    #[test]
    fn late_finish_records_deadline_miss_and_goodput_reflects_it() {
        trace::install(trace::DEFAULT_CAP, false);
        let mut srv = Server::new(SimEngine::new(1), 0);
        srv.set_slo(true);
        srv.enqueue_slo("fast", cfg(0.9, 2), None, Priority::Normal, Some(50));
        srv.drain().unwrap();
        let slow = srv.enqueue_slo("slow", cfg(0.9, 5), None, Priority::Normal, Some(2));
        srv.drain().unwrap();
        assert_eq!(srv.stats.served, 2);
        assert_eq!(srv.stats.deadline_misses, 1);
        assert_eq!(srv.stats.cancelled, 0, "in-flight requests are never cancelled");
        assert!((srv.stats.goodput() - 0.5).abs() < 1e-12);
        let evs = trace::take().expect("sink installed").into_events();
        let a = audit(&evs);
        assert_trace_matches_stats(&a, &srv.stats);
        assert_eq!(a.deadline_misses, 1);
        let misses: Vec<u64> = evs
            .iter()
            .filter_map(|s| match s.ev {
                Event::DeadlineMiss { req } => Some(req),
                _ => None,
            })
            .collect();
        assert_eq!(misses, vec![slow], "only the late finisher misses");
    }

    // ---- §2j chaos hardening: fault injection, retry, failure domains ----

    use crate::chaos::{ChaosEngine, PlannedFault};

    fn planned(tick: usize, kind_ix: usize, row: usize) -> PlannedFault {
        PlannedFault { tick, kind_ix, row }
    }

    /// Tentpole acceptance: a transient row fault no longer aborts the
    /// tick. The afflicted request is preempted, retried with backoff,
    /// and re-served byte-identically; the other row keeps decoding and
    /// the audit (retry ledger included) balances.
    #[test]
    fn row_fault_is_retried_and_isolated_from_the_batch() {
        trace::install(trace::DEFAULT_CAP, false);
        let chaos = ChaosEngine::from_plan(SimEngine::new(2), vec![planned(1, 0, 0)]);
        let mut srv = Server::new(chaos, 0);
        srv.set_retry_policy(Some(2), 1);
        let a = srv.enqueue("alpha", cfg(0.9, 4)); // row 0 at tick 1 — the target
        let b = srv.enqueue("beta", cfg(0.5, 4));
        let rs = srv.drain().unwrap();
        assert_eq!(rs.len(), 2, "both requests must resolve");
        let text = |id| rs.iter().find(|r| r.id == id).unwrap().text.clone();
        assert_eq!(text(a), "ZZZZ", "retried stream must be byte-identical");
        assert_eq!(text(b), "2222", "bystander row must be untouched");
        assert!(rs.iter().all(|r| r.outcome == Outcome::Ok));
        assert_eq!(srv.stats.served, 2);
        assert_eq!(srv.stats.retries, 1);
        assert_eq!(srv.stats.preempted, 1, "retry discards the partial life");
        assert_eq!(srv.stats.failed, 0);
        assert_eq!(srv.engine.injected, 1, "exactly the planned fault fired");
        assert_eq!(srv.health(), Health::Healthy, "row faults never degrade");
        let a = audit(&trace::take().expect("sink installed").into_events());
        assert_trace_matches_stats(&a, &srv.stats);
        assert_eq!((a.faults, a.retries, a.failed), (1, 1, 0));
        assert_eq!(a.preempted_tokens, 1, "the one pre-fault token was discarded");
    }

    /// Tentpole acceptance: past the retry budget the request terminates
    /// as a first-class `Outcome::Failed` response — never a silent
    /// drop, never a wedged row — and its tokens land in `failed_tokens`.
    #[test]
    fn retry_budget_exhaustion_fails_terminally_with_first_class_outcome() {
        trace::install(trace::DEFAULT_CAP, false);
        let chaos = ChaosEngine::from_plan(
            SimEngine::new(1),
            vec![planned(1, 0, 0), planned(4, 0, 0)],
        );
        let mut srv = Server::new(chaos, 0);
        srv.set_retry_policy(Some(1), 1);
        let victim = srv.enqueue("victim", cfg(0.9, 8));
        let rs = srv.drain().unwrap();
        assert_eq!(rs.len(), 1, "the failure is a response, not a drop");
        assert_eq!(rs[0].id, victim);
        assert_eq!(rs[0].outcome, Outcome::Failed);
        assert_eq!(rs[0].tokens, 0, "a failed request delivers no text");
        assert_eq!(srv.stats.served, 0);
        assert_eq!(srv.stats.failed, 1);
        assert_eq!(srv.stats.retries, 1, "the budget allowed one retry");
        assert_eq!(srv.stats.preempted, 1);
        assert_eq!(srv.stats.goodput(), 0.0, "failures drain goodput");
        assert_eq!(srv.in_flight(), 0, "the faulted row was reclaimed");
        assert_eq!(srv.engine.inner().free_rows(), 1);
        let a = audit(&trace::take().expect("sink installed").into_events());
        assert_trace_matches_stats(&a, &srv.stats);
        assert_eq!((a.faults, a.retries, a.failed), (2, 1, 1));
        assert_eq!(a.preempted_tokens, 1, "first life's token");
        assert_eq!(a.failed_tokens, 1, "second life's token");
    }

    /// Acceptance self-A/B: with chaos off (an empty plan) the retry
    /// policy is pure machinery — responses AND trace events are
    /// byte-identical to a plain PR 9 server on the same workload.
    #[test]
    fn chaos_off_retry_policy_is_byte_identical_to_plain_serving() {
        fn drive<E: DecodeEngine>(srv: &mut Server<E>) -> Vec<(u64, String, usize, Outcome)> {
            for i in 0..6 {
                srv.enqueue(format!("req{i}"), cfg(0.9, 2 + i % 3));
                if i % 2 == 0 {
                    srv.step().unwrap();
                }
            }
            let rs = srv.drain().unwrap();
            rs.iter().map(|r| (r.id, r.text.clone(), r.tokens, r.outcome)).collect()
        }
        fn ticked() -> Vec<(u64, Event)> {
            let evs = trace::take().expect("sink installed").into_events();
            evs.into_iter().map(|s| (s.tick, s.ev)).collect()
        }

        trace::install(trace::DEFAULT_CAP, false);
        let mut plain = Server::new(SimEngine::new(2), 0);
        let plain_rs = drive(&mut plain);
        let plain_evs = ticked();

        trace::install(trace::DEFAULT_CAP, false);
        let mut hard = Server::new(ChaosEngine::from_plan(SimEngine::new(2), vec![]), 0);
        hard.set_retry_policy(Some(3), 2);
        let hard_rs = drive(&mut hard);
        let hard_evs = ticked();

        assert_eq!(hard.engine.injected, 0, "an empty plan injects nothing");
        assert_eq!(plain_rs, hard_rs, "responses must be byte-identical");
        assert_eq!(plain_evs, hard_evs, "trace streams must be byte-identical");
    }

    /// Device loss is permanent: every survivor — in-flight and queued —
    /// fails loudly as a response, the server enters `Failing`, and late
    /// arrivals keep failing instead of wedging in the queue.
    #[test]
    fn device_loss_fails_everything_loudly_and_terminally() {
        trace::install(trace::DEFAULT_CAP, false);
        let chaos = ChaosEngine::from_plan(SimEngine::new(2), vec![planned(2, 4, 0)]);
        let mut srv = Server::new(chaos, 0);
        srv.set_retry_policy(Some(2), 1);
        srv.enqueue("a", cfg(0.9, 6));
        srv.enqueue("b", cfg(0.9, 6));
        let queued = srv.enqueue("c", cfg(0.9, 6)); // waits behind the full batch
        let rs = srv.drain().unwrap();
        assert_eq!(rs.len(), 3, "all three resolve, none silently dropped");
        assert!(rs.iter().all(|r| r.outcome == Outcome::Failed));
        assert!(rs.iter().any(|r| r.id == queued), "queued survivor fails too");
        assert_eq!(srv.health(), Health::Failing);
        assert_eq!(srv.stats.failed, 3);
        assert_eq!(srv.stats.served, 0);
        // failing is terminal: a late arrival fails loudly on the next step
        let late = srv.enqueue("late", cfg(0.9, 2));
        let rs2 = srv.step().unwrap();
        assert_eq!(rs2.len(), 1);
        assert_eq!((rs2[0].id, rs2[0].outcome), (late, Outcome::Failed));
        assert_eq!(srv.stats.failed, 4);
        let a = audit(&trace::take().expect("sink installed").into_events());
        assert!(a.ok(), "violations: {:#?}", a.violations);
        assert_eq!(a.failed, 4);
        assert_eq!(a.degrades, 1, "one Degrade(failing), no recovery");
    }

    /// An engine-level stall degrades the server (speculation off,
    /// admission shrunk) and three clean decode ticks recover it — the
    /// Degrade/Recover bracket the audit's law 11 enforces.
    #[test]
    fn stuck_tick_degrades_and_clean_ticks_recover() {
        trace::install(trace::DEFAULT_CAP, false);
        let chaos = ChaosEngine::from_plan(SimEngine::new(2), vec![planned(1, 3, 0)]);
        let mut srv = Server::new(chaos, 0);
        srv.set_retry_policy(Some(2), 1);
        srv.enqueue("a", cfg(0.9, 6));
        srv.enqueue("b", cfg(0.5, 6));
        let rs = srv.drain().unwrap();
        assert_eq!(rs.len(), 2, "a stall costs a tick, not the batch");
        assert!(rs.iter().all(|r| r.outcome == Outcome::Ok));
        assert_eq!(srv.health(), Health::Healthy, "three clean ticks recovered");
        assert_eq!(srv.stats.degraded_ticks, 3);
        assert_eq!(srv.stats.failed, 0);
        assert_eq!(srv.stats.retries, 0, "engine faults retry nothing row-level");
        let evs = trace::take().expect("sink installed").into_events();
        let a = audit(&evs);
        assert_trace_matches_stats(&a, &srv.stats);
        assert_eq!(a.degrades, 1);
        let brackets: Vec<&str> = evs
            .iter()
            .filter_map(|s| match s.ev {
                Event::Degrade { level } => Some(level),
                Event::Recover {} => Some("recover"),
                _ => None,
            })
            .collect();
        assert_eq!(brackets, vec!["degraded", "recover"]);
    }

    /// Three consecutive engine faults escalate Degraded → Failing: the
    /// engine is not coming back, so survivors fail loudly instead of
    /// losing a tick forever.
    #[test]
    fn three_consecutive_engine_faults_escalate_to_failing() {
        trace::install(trace::DEFAULT_CAP, false);
        let chaos = ChaosEngine::from_plan(
            SimEngine::new(1),
            vec![planned(1, 3, 0), planned(2, 3, 0), planned(3, 3, 0)],
        );
        let mut srv = Server::new(chaos, 0);
        srv.set_retry_policy(Some(2), 1);
        let only = srv.enqueue("only", cfg(0.9, 8));
        let rs = srv.drain().unwrap();
        assert_eq!(rs.len(), 1);
        assert_eq!((rs[0].id, rs[0].outcome), (only, Outcome::Failed));
        assert_eq!(srv.health(), Health::Failing);
        let a = audit(&trace::take().expect("sink installed").into_events());
        assert!(a.ok(), "violations: {:#?}", a.violations);
        assert_eq!(a.degrades, 2, "degraded first, then failing");
        assert_eq!(a.failed, 1);
    }

    /// The fault-storm acceptance gate: under the named storm scenario
    /// with retry + isolation, zero requests are lost silently — every
    /// enqueue resolves as exactly one of served / failed / rejected and
    /// the extended admission ledger (audit laws 8–11) balances.
    #[test]
    fn fault_storm_with_retry_isolation_loses_nothing_silently() {
        trace::install(trace::DEFAULT_CAP, false);
        let chaos = ChaosEngine::new(SimEngine::new(4), "fault-storm", 64, 9).unwrap();
        let mut srv = Server::new(chaos, 0);
        srv.set_retry_policy(Some(2), 1);
        let n = 12;
        for i in 0..n {
            srv.enqueue(format!("req{i}"), cfg(0.9, 3 + i % 4));
        }
        let rs = srv.drain().unwrap();
        assert_eq!(
            rs.len() + srv.stats.rejected,
            n,
            "every enqueue must resolve: {} responses + {} rejects",
            rs.len(),
            srv.stats.rejected
        );
        let served = rs.iter().filter(|r| r.outcome == Outcome::Ok).count();
        let failed = rs.iter().filter(|r| r.outcome == Outcome::Failed).count();
        assert_eq!(served, srv.stats.served);
        assert_eq!(failed, srv.stats.failed);
        assert!(served > 0, "the storm must be survivable");
        assert!(srv.engine.injected > 0, "the storm must actually storm");
        let a = audit(&trace::take().expect("sink installed").into_events());
        assert_trace_matches_stats(&a, &srv.stats);
        assert_eq!(a.enqueued, n);
        assert_eq!(a.retries, srv.stats.retries);
        assert_eq!(a.failed, srv.stats.failed);
    }

    /// The A/B the bench publishes, in miniature: the same storm without
    /// a retry policy aborts the whole batch at the first decode fault
    /// (the pre-§2j contract, still the default).
    #[test]
    fn same_storm_without_retry_policy_aborts_on_first_fault() {
        let chaos = ChaosEngine::new(SimEngine::new(4), "fault-storm", 64, 9).unwrap();
        let mut srv = Server::new(chaos, 0);
        for i in 0..12 {
            srv.enqueue(format!("req{i}"), cfg(0.9, 3 + i % 4));
        }
        let err = srv.drain().unwrap_err().to_string();
        assert!(err.contains("chaos:"), "the injected fault surfaces: {err}");
        assert_eq!(srv.stats.failed, 0, "abort-on-error fails no one gracefully");
    }

    /// Unclassified engine errors stay fatal even under a retry policy:
    /// the §2j machinery only absorbs faults the engine classifies.
    #[test]
    fn unclassified_decode_error_is_fatal_even_with_retry_policy() {
        struct BlowsUp(SimEngine);
        impl DecodeEngine for BlowsUp {
            fn batch_size(&self) -> usize {
                self.0.batch_size()
            }
            fn free_rows(&self) -> usize {
                self.0.free_rows()
            }
            fn prefill(
                &mut self,
                prompt: &str,
                cfg: SampleCfg,
                adapter: Option<AdapterId>,
            ) -> Result<usize> {
                self.0.prefill(prompt, cfg, adapter)
            }
            fn decode_step(&mut self, _rng: &mut Rng) -> Result<Vec<StepOut>> {
                bail!("segfault adjacent")
            }
            fn take(&mut self, row: usize) -> Option<Vec<i32>> {
                self.0.take(row)
            }
            fn decode_text(&self, ids: &[i32]) -> String {
                self.0.decode_text(ids)
            }
        }
        let mut srv = Server::new(BlowsUp(SimEngine::new(1)), 0);
        srv.set_retry_policy(Some(3), 1);
        srv.enqueue("x", cfg(0.9, 2));
        let err = srv.drain().unwrap_err().to_string();
        assert!(err.contains("segfault adjacent"), "{err}");
    }

    /// Satellite: a wedged row can no longer spin `drain` forever — the
    /// guard trips with an error naming the stuck rows.
    #[test]
    fn never_finishing_engine_trips_the_drain_guard_naming_stuck_rows() {
        struct NeverDone {
            occupied: bool,
        }
        impl DecodeEngine for NeverDone {
            fn batch_size(&self) -> usize {
                1
            }
            fn free_rows(&self) -> usize {
                usize::from(!self.occupied)
            }
            fn prefill(
                &mut self,
                _prompt: &str,
                _cfg: SampleCfg,
                _adapter: Option<AdapterId>,
            ) -> Result<usize> {
                self.occupied = true;
                Ok(0)
            }
            fn decode_step(&mut self, _rng: &mut Rng) -> Result<Vec<StepOut>> {
                ensure!(self.occupied, "decode on empty batch");
                // a token every tick, finished never
                Ok(vec![StepOut { row: 0, token: 7, finished: false, accepted: false }])
            }
            fn take(&mut self, _row: usize) -> Option<Vec<i32>> {
                self.occupied.then(|| {
                    self.occupied = false;
                    vec![]
                })
            }
            fn decode_text(&self, _ids: &[i32]) -> String {
                String::new()
            }
        }
        let mut srv = Server::new(NeverDone { occupied: false }, 0);
        let id = srv.enqueue("stuck", cfg(0.9, 2));
        let err = srv.drain().unwrap_err().to_string();
        assert!(err.contains("drain stuck after"), "{err}");
        assert!(err.contains(&format!("0:req {id}")), "names the stuck row: {err}");
    }

    /// Satellite: the chaos lifecycle counters flatten into the unified
    /// metrics registry like every other ServerStats field.
    #[test]
    fn chaos_counters_flatten_into_metrics() {
        let chaos = ChaosEngine::from_plan(
            SimEngine::new(1),
            vec![planned(1, 0, 0), planned(4, 0, 0)],
        );
        let mut srv = Server::new(chaos, 0);
        srv.set_retry_policy(Some(1), 1);
        srv.enqueue("victim", cfg(0.9, 8));
        srv.drain().unwrap();
        let m = srv.stats.to_metrics();
        assert_eq!(m.counter("serve.failed"), 1.0);
        assert_eq!(m.counter("serve.retries"), 1.0);
        assert!(m.has_counter("serve.degraded_ticks"));
    }
}

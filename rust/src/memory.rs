//! Analytic parameter / HBM accounting for the *real* LLaMA models —
//! reproduces the paper's Tables 4–6 exactly.
//!
//! The proxy models train on this machine; the memory story of the paper,
//! however, is pure arithmetic over the published architectures. This
//! module carries the LLaMA-2 / LLaMA-3.1 shape specs, the pruned-parameter
//! model, and the NF4 effective-parameter model (Table 6 reports pruned
//! params / 4, i.e. 4-bit vs 16-bit storage).
//!
//! Calibration: the paper's per-layer kept-unit counts come from
//! LLM-Pruner's coupled-structure rules. For LLaMA-2-13B @0.65 the uniform
//! round-to-nearest rule reproduces the published integer exactly; for the
//! 70B models we solved the per-layer (heads, kv, ff) kept counts from the
//! published totals (they are consistent across LLaMA-2-70B and
//! LLaMA-3.1-70B: kv heads unpruned, see `CALIBRATED_70B`).

/// Shape spec of a real (published) LLaMA model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LlamaSpec {
    pub name: &'static str,
    pub vocab: u64,
    pub d_model: u64,
    pub n_layers: u64,
    pub n_heads: u64,
    pub n_kv_heads: u64,
    pub d_ff: u64,
    pub head_dim: u64,
}

pub const LLAMA2_7B: LlamaSpec = LlamaSpec {
    name: "LLaMA-2-7B",
    vocab: 32000,
    d_model: 4096,
    n_layers: 32,
    n_heads: 32,
    n_kv_heads: 32,
    d_ff: 11008,
    head_dim: 128,
};

pub const LLAMA2_13B: LlamaSpec = LlamaSpec {
    name: "LLaMA-2-13B",
    vocab: 32000,
    d_model: 5120,
    n_layers: 40,
    n_heads: 40,
    n_kv_heads: 40,
    d_ff: 13824,
    head_dim: 128,
};

pub const LLAMA2_70B: LlamaSpec = LlamaSpec {
    name: "LLaMA-2-70B",
    vocab: 32000,
    d_model: 8192,
    n_layers: 80,
    n_heads: 64,
    n_kv_heads: 8,
    d_ff: 28672,
    head_dim: 128,
};

pub const LLAMA31_8B: LlamaSpec = LlamaSpec {
    name: "LLaMA-3.1-8B",
    vocab: 128256,
    d_model: 4096,
    n_layers: 32,
    n_heads: 32,
    n_kv_heads: 8,
    d_ff: 14336,
    head_dim: 128,
};

pub const LLAMA31_70B: LlamaSpec = LlamaSpec {
    name: "LLaMA-3.1-70B",
    vocab: 128256,
    d_model: 8192,
    n_layers: 80,
    n_heads: 64,
    n_kv_heads: 8,
    d_ff: 28672,
    head_dim: 128,
};

impl LlamaSpec {
    /// Attention + MLP + norm parameters of one (possibly pruned) layer.
    pub fn layer_params(&self, heads: u64, kv_heads: u64, ff: u64) -> u64 {
        let d = self.d_model;
        let hd = self.head_dim;
        d * heads * hd          // wq
            + 2 * d * kv_heads * hd // wk, wv
            + heads * hd * d        // wo
            + 3 * d * ff            // gate, up, down
            + 2 * d                 // rmsnorm scales
    }

    /// Total parameter count (matches the published model cards).
    pub fn total_params(&self) -> u64 {
        self.vocab * self.d_model
            + self.n_layers * self.layer_params(self.n_heads, self.n_kv_heads, self.d_ff)
            + self.d_model
            + self.d_model * self.vocab
    }

    /// LoRA parameter count at rank r over q,k,v,o,gate,up,down (+ lm_head
    /// unless `lora_lm_head` is false — the LLaMA-3 setting, paper §B).
    pub fn lora_params(&self, rank: u64, lora_lm_head: bool) -> u64 {
        let d = self.d_model;
        let hd = self.head_dim;
        let per_layer = (d + self.n_heads * hd) * rank        // wq
            + 2 * (d + self.n_kv_heads * hd) * rank           // wk, wv
            + (self.n_heads * hd + d) * rank                  // wo
            + 2 * (d + self.d_ff) * rank                      // gate, up
            + (self.d_ff + d) * rank; // down
        let head = if lora_lm_head { (d + self.vocab) * rank } else { 0 };
        self.n_layers * per_layer + head
    }
}

/// How many layers LLM-Pruner protects (paper §B: first 4 and last 2).
pub const PROTECT_FIRST: u64 = 4;
pub const PROTECT_LAST: u64 = 2;

/// Per-layer kept (heads, kv_heads, ff) solved from the paper's published
/// pruned-parameter totals for the 70B models (Tables 5–6). kv heads stay
/// unpruned; identical counts reproduce both LLaMA-2-70B and LLaMA-3.1-70B
/// rows bit-exactly.
pub const CALIBRATED_70B: [(f64, u64, u64, u64); 4] = [
    (0.65, 16, 8, 10291),
    (0.75, 10, 8, 7168),
    (0.85, 4, 8, 4812),
    (0.95, 1, 8, 1433),
];

/// Structured-pruned parameter count. Uses the calibrated per-layer counts
/// for the 70B specs when available, else the uniform round-to-nearest rule
/// (which reproduces the 13B row exactly).
pub fn structured_pruned_params(spec: &LlamaSpec, prune_ratio: f64) -> u64 {
    let keep = 1.0 - prune_ratio;
    let (h_k, kv_k, ff_k) = if spec.n_kv_heads != spec.n_heads {
        CALIBRATED_70B
            .iter()
            .find(|(r, ..)| (*r - prune_ratio).abs() < 1e-9)
            .map(|&(_, h, kv, ff)| (h, kv, ff))
            .unwrap_or_else(|| uniform_kept(spec, keep))
    } else {
        uniform_kept(spec, keep)
    };
    let full_layer = spec.layer_params(spec.n_heads, spec.n_kv_heads, spec.d_ff);
    let pruned_layer = spec.layer_params(h_k, kv_k, ff_k);
    let protected = PROTECT_FIRST + PROTECT_LAST;
    spec.vocab * spec.d_model
        + protected * full_layer
        + (spec.n_layers - protected) * pruned_layer
        + spec.d_model
        + spec.d_model * spec.vocab
}

fn uniform_kept(spec: &LlamaSpec, keep: f64) -> (u64, u64, u64) {
    let h = ((spec.n_heads as f64 * keep).round() as u64).max(1);
    let kv = if spec.n_kv_heads == spec.n_heads {
        h
    } else {
        ((spec.n_kv_heads as f64 * keep).round() as u64).max(1)
    };
    let ff = ((spec.d_ff as f64 * keep).round() as u64).max(1);
    (h, kv, ff)
}

/// Non-structured pruning: the paper's ▲ rows — *theoretical* reduction
/// over the layer projection weights only (embeddings/norms/lm_head are
/// untouched by SparseGPT); actual training memory is NOT reduced (zeros
/// are stored), which Table 1 footnotes.
pub fn nonstructured_pruned_params(spec: &LlamaSpec, prune_ratio: f64) -> u64 {
    let linear =
        spec.n_layers * (spec.layer_params(spec.n_heads, spec.n_kv_heads, spec.d_ff)
            - 2 * spec.d_model);
    let kept_linear = ((linear as f64) * (1.0 - prune_ratio)).round() as u64;
    spec.total_params() - linear + kept_linear
}

/// A row of Tables 4/5/6.
#[derive(Debug, Clone)]
pub struct ReductionRow {
    pub method: String,
    pub orig_params: u64,
    pub prune_ratio: f64,
    pub pruned_params: u64,
    pub reduction: f64,
    pub hbm_gb: f64,
}

/// 16-bit HBM footprint of a parameter count (paper: params × 2 bytes).
pub fn hbm_gb_bf16(params: u64) -> f64 {
    params as f64 * 2.0 / (1u64 << 30) as f64
}

/// LoRAM row (Tables 4–5): bf16 storage of the pruned model.
pub fn loram_row(spec: &LlamaSpec, method: &str, ratio: f64) -> ReductionRow {
    let pruned = if method.contains("Semi") || method.contains("Unst") {
        nonstructured_pruned_params(spec, ratio)
    } else {
        structured_pruned_params(spec, ratio)
    };
    ReductionRow {
        method: method.to_string(),
        orig_params: spec.total_params(),
        prune_ratio: ratio,
        pruned_params: pruned,
        reduction: spec.total_params() as f64 / pruned as f64,
        hbm_gb: hbm_gb_bf16(pruned),
    }
}

/// QLoRAM row (Table 6): NF4 quantisation packs 4 params/16-bit slot, so
/// the paper reports pruned_params / 4 as the effective parameter count.
pub fn qloram_row(spec: &LlamaSpec, method: &str, ratio: f64) -> ReductionRow {
    let pruned = structured_pruned_params(spec, ratio) / 4;
    ReductionRow {
        method: method.to_string(),
        orig_params: spec.total_params(),
        prune_ratio: ratio,
        pruned_params: pruned,
        reduction: spec.total_params() as f64 / pruned as f64,
        hbm_gb: hbm_gb_bf16(pruned),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn published_total_params_exact() {
        assert_eq!(LLAMA2_7B.total_params(), 6_738_415_616);
        assert_eq!(LLAMA2_13B.total_params(), 13_015_864_320);
        assert_eq!(LLAMA2_70B.total_params(), 68_976_648_192);
        assert_eq!(LLAMA31_70B.total_params(), 70_553_706_496);
        assert_eq!(LLAMA31_8B.total_params(), 8_030_261_248);
    }

    #[test]
    fn table4_13b_structured_exact() {
        // paper Table 4: LoRAM-Rand & Stru, ratio 0.65 -> 6005662720 (2.17x)
        let p = structured_pruned_params(&LLAMA2_13B, 0.65);
        assert_eq!(p, 6_005_662_720);
        let row = loram_row(&LLAMA2_13B, "LoRAM-Stru", 0.65);
        assert!((row.reduction - 2.17).abs() < 0.01);
        assert!((row.hbm_gb - 11.19).abs() < 0.01);
    }

    #[test]
    fn table5_70b_rows_exact() {
        // paper Table 5 (LLaMA-2-70B)
        for (ratio, want, red) in [
            (0.65, 28_099_436_544u64, 2.45),
            (0.75, 21_488_738_304, 3.21),
            (0.85, 16_272_924_672, 4.24),
            (0.95, 9_662_226_432, 7.14),
        ] {
            let p = structured_pruned_params(&LLAMA2_70B, ratio);
            assert_eq!(p, want, "ratio {ratio}");
            let row = loram_row(&LLAMA2_70B, "LoRAM-Stru", ratio);
            assert!((row.reduction - red).abs() < 0.01, "ratio {ratio}");
        }
        // LLaMA-3.1-70B @ 0.85 -> 17849982976 (3.95x)
        assert_eq!(structured_pruned_params(&LLAMA31_70B, 0.85), 17_849_982_976);
    }

    #[test]
    fn table6_qloram_rows_exact() {
        for (ratio, want, red, hbm) in [
            (0.65, 7_024_859_136u64, 9.82, 13.08),
            (0.75, 5_372_184_576, 12.84, 10.01),
            (0.85, 4_068_231_168, 16.95, 7.58),
            (0.95, 2_415_556_608, 28.56, 4.50),
        ] {
            let row = qloram_row(&LLAMA2_70B, "QLoRAM-Stru", ratio);
            assert_eq!(row.pruned_params, want, "ratio {ratio}");
            assert!((row.reduction - red).abs() < 0.01, "ratio {ratio}");
            assert!((row.hbm_gb - hbm).abs() < 0.01, "ratio {ratio}");
        }
        // LLaMA-3.1-70B: 4462495744 (15.81x, 8.31 GB)
        let row = qloram_row(&LLAMA31_70B, "QLoRAM-Stru", 0.85);
        assert_eq!(row.pruned_params, 4_462_495_744);
        assert!((row.reduction - 15.81).abs() < 0.01);
        assert!((row.hbm_gb - 8.31).abs() < 0.01);
    }

    #[test]
    fn table1_reduction_ratios() {
        // 7B LoRA vs 13B: 1.93x; 13B LoRA vs 70B: 5.30x; 8B vs 3.1-70B: 8.79x
        let r1 = LLAMA2_13B.total_params() as f64 / LLAMA2_7B.total_params() as f64;
        assert!((r1 - 1.93).abs() < 0.01);
        let r2 = LLAMA2_70B.total_params() as f64 / LLAMA2_13B.total_params() as f64;
        assert!((r2 - 5.30).abs() < 0.01);
        let r3 = LLAMA31_70B.total_params() as f64 / LLAMA31_8B.total_params() as f64;
        assert!((r3 - 8.79).abs() < 0.01);
    }

    #[test]
    fn nonstructured_ratios_close_to_paper() {
        // paper: semi (0.5) -> 1.93-1.95x, unst (0.55) -> 2.16x (theoretical)
        let semi = nonstructured_pruned_params(&LLAMA2_13B, 0.5);
        let r_semi = LLAMA2_13B.total_params() as f64 / semi as f64;
        assert!((r_semi - 1.95).abs() < 0.02, "semi {r_semi}");
        let unst = nonstructured_pruned_params(&LLAMA2_13B, 0.55);
        let r_unst = LLAMA2_13B.total_params() as f64 / unst as f64;
        assert!((r_unst - 2.16).abs() < 0.02, "unst {r_unst}");
    }

    #[test]
    fn lora_params_13b_about_32m() {
        // paper §2.2: rank 8 over q,k,v,o,up,gate,down,lm_head ≈ 32M,
        // 406x fewer than full params
        let l = LLAMA2_13B.lora_params(8, true);
        assert!((l as f64 / 1e6 - 32.0).abs() < 2.0, "lora {l}");
        let ratio = LLAMA2_13B.total_params() as f64 / l as f64;
        assert!((ratio - 406.0).abs() < 10.0, "ratio {ratio}");
    }

    #[test]
    fn intro_70b_gpu_claim() {
        // intro: QLoRAM puts a 70B within a 20 GB GPU
        let row = qloram_row(&LLAMA2_70B, "QLoRAM-Stru", 0.85);
        assert!(row.hbm_gb < 20.0);
    }
}

//! L3 runtime: load AOT artifacts (HLO text + meta JSON) and execute them
//! on the PJRT CPU client via the `xla` crate.
//!
//! The interchange is HLO *text* (`HloModuleProto::from_text_file`): jax
//! >= 0.5 serialises protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see aot.py / the working
//! reference at /opt/xla-example).
//!
//! Every artifact is described entirely by its `.meta.json` — input/output
//! names, shapes and dtypes in *exact* positional order — so the runtime is
//! generic: callers build a `TensorStore` and the runtime packs/unpacks by
//! the meta's order. Stateful execution (training steps, decode loops) goes
//! through the backend-polymorphic [`Session`] in [`session`]; `Runtime::run`
//! stays as the one-shot stateless convenience.

use crate::tensor::{Data, Dtype, Tensor, TensorStore};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

pub mod meta;
pub mod session;

pub use meta::{ArtifactMeta, IoSpec, ModelCfg, SlotGroup};
pub use session::{host_path_forced, BackendKind, Session, SlotValue};

/// The PJRT client plus a compile cache over loaded artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<Artifact>>>,
    /// cumulative counters for perf reporting (see DESIGN.md §Perf)
    pub metrics: RefCell<RuntimeMetrics>,
}

#[derive(Default, Debug, Clone)]
pub struct RuntimeMetrics {
    pub compiles: usize,
    pub compile_ms: f64,
    pub executions: usize,
    pub execute_ms: f64,
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
}

pub struct Artifact {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Raw buffer-in/buffer-out execution (device-resident hot path).
    pub fn execute_buffers(
        &self,
        args: &[&xla::PjRtBuffer],
    ) -> xla::Result<Vec<Vec<xla::PjRtBuffer>>> {
        self.exe.execute_b(args)
    }
}

impl Runtime {
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime {
            client,
            dir: artifact_dir.as_ref().to_path_buf(),
            cache: RefCell::new(HashMap::new()),
            metrics: RefCell::new(RuntimeMetrics::default()),
        })
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Names listed in the suite manifest (if present).
    pub fn manifest(&self) -> Result<Vec<String>> {
        let p = self.dir.join("manifest.json");
        let txt = std::fs::read_to_string(&p)
            .with_context(|| format!("read {}", p.display()))?;
        let j = Json::parse(&txt).map_err(anyhow::Error::msg)?;
        Ok(j.get("artifacts")
            .and_then(|a| a.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_str().map(String::from)).collect())
            .unwrap_or_default())
    }

    /// Load + compile an artifact by name (cached).
    pub fn load(&self, name: &str) -> Result<Rc<Artifact>> {
        if let Some(a) = self.cache.borrow().get(name) {
            return Ok(a.clone());
        }
        let hlo = self.dir.join(format!("{name}.hlo.txt"));
        let meta_path = self.dir.join(format!("{name}.meta.json"));
        let meta = ArtifactMeta::load(&meta_path)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            hlo.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parse HLO text {}", hlo.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA compile of {name}"))?;
        {
            let mut m = self.metrics.borrow_mut();
            m.compiles += 1;
            m.compile_ms += t0.elapsed().as_secs_f64() * 1e3;
        }
        let a = Rc::new(Artifact { meta, exe });
        self.cache.borrow_mut().insert(name.to_string(), a.clone());
        Ok(a)
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.cache.borrow().contains_key(name)
    }

    /// One-shot stateless execution: host tensors gathered from `store` by
    /// the meta's input order, outputs returned keyed by meta output names.
    /// Anything stateful (training steps, decode loops) goes through
    /// [`Session`], which owns the state threading.
    pub fn run(&self, art: &Artifact, store: &TensorStore) -> Result<TensorStore> {
        let lits = self.pack_inputs(art, store)?;
        let outs = self.execute_literals(art, &lits)?;
        unpack_outputs(&art.meta, outs)
    }

    /// Pack inputs in artifact order as XLA literals, validating shapes.
    fn pack_inputs(&self, art: &Artifact, store: &TensorStore) -> Result<Vec<xla::Literal>> {
        let mut lits = Vec::with_capacity(art.meta.inputs.len());
        let mut bytes = 0u64;
        for spec in &art.meta.inputs {
            let t = store
                .get(&spec.name)
                .with_context(|| format!("artifact {} input", art.meta.name))?;
            if t.shape != spec.shape {
                bail!(
                    "artifact {} input '{}': shape {:?} != expected {:?}",
                    art.meta.name, spec.name, t.shape, spec.shape
                );
            }
            if t.dtype() != spec.dtype {
                bail!(
                    "artifact {} input '{}': dtype {:?} != expected {:?}",
                    art.meta.name, spec.name, t.dtype(), spec.dtype
                );
            }
            bytes += (t.len() * 4) as u64;
            lits.push(tensor_to_literal(t)?);
        }
        self.metrics.borrow_mut().h2d_bytes += bytes;
        Ok(lits)
    }

    /// Execute packed literals and fetch every output back as literals
    /// (shared by [`Runtime::run`] and the host [`Session`] backend).
    pub(crate) fn execute_literals(
        &self,
        art: &Artifact,
        lits: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let t0 = Instant::now();
        let bufs = art
            .exe
            .execute::<xla::Literal>(lits)
            .with_context(|| format!("execute {}", art.meta.name))?;
        // With the vendored untuple_result patch outputs arrive one buffer
        // per leaf; fall back to tuple decomposition for unpatched builds.
        let outs = if bufs[0].len() == art.meta.outputs.len() {
            bufs[0]
                .iter()
                .map(|b| b.to_literal_sync())
                .collect::<xla::Result<Vec<_>>>()
                .context("fetch result literals")?
        } else {
            let root = bufs[0][0]
                .to_literal_sync()
                .context("fetch result literal")?;
            root.to_tuple().context("decompose result tuple")?
        };
        {
            let mut m = self.metrics.borrow_mut();
            m.executions += 1;
            m.execute_ms += t0.elapsed().as_secs_f64() * 1e3;
            m.d2h_bytes += art
                .meta
                .outputs
                .iter()
                .map(|o| (o.shape.iter().product::<usize>() * 4) as u64)
                .sum::<u64>();
        }
        if outs.len() != art.meta.outputs.len() {
            bail!(
                "artifact {}: {} outputs, meta says {}",
                art.meta.name,
                outs.len(),
                art.meta.outputs.len()
            );
        }
        Ok(outs)
    }
}

fn unpack_outputs(meta: &ArtifactMeta, outs: Vec<xla::Literal>) -> Result<TensorStore> {
    let mut store = TensorStore::new();
    for (spec, lit) in meta.outputs.iter().zip(outs) {
        store.insert(spec.name.clone(), literal_to_tensor(&lit, spec)?);
    }
    Ok(store)
}

pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<usize> = t.shape.clone();
    let lit = match &t.data {
        Data::F32(v) => {
            let bytes: &[u8] =
                unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) };
            xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                &dims,
                bytes,
            )?
        }
        Data::I32(v) => {
            let bytes: &[u8] =
                unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) };
            xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::S32,
                &dims,
                bytes,
            )?
        }
    };
    Ok(lit)
}

pub fn literal_to_tensor(lit: &xla::Literal, spec: &IoSpec) -> Result<Tensor> {
    let t = match spec.dtype {
        Dtype::F32 => Tensor::from_f32(&spec.shape, lit.to_vec::<f32>()?),
        Dtype::I32 => Tensor::from_i32(&spec.shape, lit.to_vec::<i32>()?),
    };
    Ok(t)
}

//! Device-resident execution sessions (the L3 §Perf optimisation).
//!
//! The v1 path (`Runtime::run`) re-packs every input tensor into an XLA
//! literal on every call — for a training step that means copying the full
//! parameter + optimiser state twice per step (h2d then d2h). This module
//! keeps state as PJRT buffers instead: weights upload once, each step
//! uploads only the few KB of (step, lr, tokens, loss_mask), executes via
//! `execute_b`, and re-binds the returned state buffers (`new.*`) onto
//! their input slots without touching the host.
//!
//! Requires the vendored xla patch (`ExecuteOptions::untuple_result=true`,
//! see vendor/xla/xla_rs/xla_rs.cc) so outputs arrive as per-leaf buffers.

use super::{Artifact, Runtime};
use crate::tensor::{Data, Dtype, Tensor, TensorStore};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::rc::Rc;

pub struct DeviceSession {
    pub art: Rc<Artifact>,
    slots: Vec<Option<xla::PjRtBuffer>>,
    name_to_slot: HashMap<String, usize>,
    /// output index -> input slot it replaces (state threading), if any
    out_to_in: Vec<Option<usize>>,
}

impl DeviceSession {
    /// Upload every tensor in `stores` that the artifact wants; remaining
    /// inputs (tokens, scalars, ...) must be `set` before `run`.
    pub fn new(rt: &Runtime, art: Rc<Artifact>, stores: &[&TensorStore]) -> Result<DeviceSession> {
        let mut name_to_slot = HashMap::new();
        for (i, spec) in art.meta.inputs.iter().enumerate() {
            name_to_slot.insert(spec.name.clone(), i);
        }
        // map outputs onto the input slots they replace:
        //   new.X / new_m.X / new_v.X  ->  X / adam_m.X / adam_v.X
        let out_to_in = art
            .meta
            .outputs
            .iter()
            .map(|o| {
                let target = if let Some(p) = o.name.strip_prefix("new_m.") {
                    Some(format!("adam_m.{p}"))
                } else if let Some(p) = o.name.strip_prefix("new_v.") {
                    Some(format!("adam_v.{p}"))
                } else {
                    o.name.strip_prefix("new.").map(|p| p.to_string())
                };
                target.and_then(|t| name_to_slot.get(&t).copied())
            })
            .collect();
        let mut sess = DeviceSession {
            slots: (0..art.meta.inputs.len()).map(|_| None).collect(),
            name_to_slot,
            out_to_in,
            art,
        };
        for store in stores {
            for (name, t) in &store.map {
                if sess.name_to_slot.contains_key(name) {
                    sess.set(rt, name, t)?;
                }
            }
        }
        // zero any adam moment slots not supplied
        let missing: Vec<(String, Vec<usize>)> = sess
            .art
            .meta
            .inputs
            .iter()
            .filter(|s| {
                (s.name.starts_with("adam_m.") || s.name.starts_with("adam_v."))
                    && sess.slots[sess.name_to_slot[&s.name]].is_none()
            })
            .map(|s| (s.name.clone(), s.shape.clone()))
            .collect();
        for (name, shape) in missing {
            sess.set(rt, &name, &Tensor::zeros(&shape))?;
        }
        Ok(sess)
    }

    /// Upload one tensor into its input slot (validates shape/dtype).
    pub fn set(&mut self, rt: &Runtime, name: &str, t: &Tensor) -> Result<()> {
        let slot = *self
            .name_to_slot
            .get(name)
            .with_context(|| format!("artifact {} has no input '{name}'", self.art.meta.name))?;
        let spec = &self.art.meta.inputs[slot];
        if t.shape != spec.shape || t.dtype() != spec.dtype {
            bail!(
                "input '{name}': got {:?}/{:?}, want {:?}/{:?}",
                t.shape, t.dtype(), spec.shape, spec.dtype
            );
        }
        let buf = match &t.data {
            Data::F32(v) => rt.client().buffer_from_host_buffer::<f32>(v, &t.shape, None)?,
            Data::I32(v) => rt.client().buffer_from_host_buffer::<i32>(v, &t.shape, None)?,
        };
        rt.metrics.borrow_mut().h2d_bytes += (t.len() * 4) as u64;
        self.slots[slot] = Some(buf);
        Ok(())
    }

    /// Execute; state outputs re-bind to their input slots on device, all
    /// other outputs are fetched to the host and returned.
    pub fn run(&mut self, rt: &Runtime) -> Result<TensorStore> {
        let t0 = std::time::Instant::now();
        let refs: Vec<&xla::PjRtBuffer> = self
            .slots
            .iter()
            .enumerate()
            .map(|(i, s)| {
                s.as_ref().ok_or_else(|| {
                    anyhow::anyhow!(
                        "input '{}' not set",
                        self.art.meta.inputs[i].name
                    )
                })
            })
            .collect::<Result<_>>()?;
        let mut bufs = self
            .art
            .execute_buffers(&refs)
            .with_context(|| format!("execute_b {}", self.art.meta.name))?;
        let outs = std::mem::take(&mut bufs[0]);
        if outs.len() != self.art.meta.outputs.len() {
            bail!(
                "artifact {}: got {} output buffers, expected {} (is the \
                 untuple_result patch active?)",
                self.art.meta.name,
                outs.len(),
                self.art.meta.outputs.len()
            );
        }
        let mut host = TensorStore::new();
        for (j, buf) in outs.into_iter().enumerate() {
            match self.out_to_in[j] {
                Some(slot) => {
                    self.slots[slot] = Some(buf);
                }
                None => {
                    let spec = &self.art.meta.outputs[j];
                    let lit = buf.to_literal_sync()?;
                    rt.metrics.borrow_mut().d2h_bytes +=
                        (spec.shape.iter().product::<usize>() * 4) as u64;
                    host.insert(spec.name.clone(), super::literal_to_tensor(&lit, spec)?);
                }
            }
        }
        let mut m = rt.metrics.borrow_mut();
        m.executions += 1;
        m.execute_ms += t0.elapsed().as_secs_f64() * 1e3;
        Ok(host)
    }

    /// Download a device-resident input slot back to the host (e.g. the
    /// trained LoRA factors after the last step).
    pub fn fetch(&self, rt: &Runtime, name: &str) -> Result<Tensor> {
        let slot = *self
            .name_to_slot
            .get(name)
            .with_context(|| format!("no input '{name}'"))?;
        let spec = &self.art.meta.inputs[slot];
        let buf = self.slots[slot]
            .as_ref()
            .with_context(|| format!("input '{name}' not set"))?;
        let lit = buf.to_literal_sync()?;
        rt.metrics.borrow_mut().d2h_bytes += (spec.shape.iter().product::<usize>() * 4) as u64;
        let t = match spec.dtype {
            Dtype::F32 => Tensor::from_f32(&spec.shape, lit.to_vec::<f32>()?),
            Dtype::I32 => Tensor::from_i32(&spec.shape, lit.to_vec::<i32>()?),
        };
        Ok(t)
    }

    pub fn fetch_all(&self, rt: &Runtime, names: &[String]) -> Result<TensorStore> {
        let mut out = TensorStore::new();
        for n in names {
            out.insert(n.clone(), self.fetch(rt, n)?);
        }
        Ok(out)
    }
}

//! The unified execution session: one state-threading implementation for
//! every artifact, behind two interchangeable backends.
//!
//! A [`Session`] owns one *named slot* per artifact input. Callers upload
//! tensors into slots (`set`), execute (`run`), and download slots back
//! (`fetch`). After each run the artifact's **declared** output→input state
//! bindings (`ArtifactMeta::state_bindings`, emitted by aot.py; the
//! `new.X → X` naming convention is only a fallback for old metas) donate
//! each state output back onto its input slot, so optimiser state never
//! leaves the execution path. All remaining outputs are returned to the
//! caller as a `TensorStore`.
//!
//! Backends (DESIGN.md §Perf):
//! * [`BackendKind::Device`] (default): slots are PJRT buffers. Weights
//!   upload once, each step uploads only the few KB of changed inputs,
//!   executes via `execute_b`, and bound outputs re-attach on device —
//!   requires the vendored `untuple_result` patch.
//! * [`BackendKind::Host`] (`LORAM_HOST_PATH=1`): slots are host tensors
//!   round-tripped through XLA literals every run — the §Perf baseline and
//!   the fallback for unpatched builds. Identical threading semantics,
//!   verified equivalent by the integration tests.
//!
//! Both backends account uniformly into [`super::RuntimeMetrics`]:
//! executions, execute time, and the h2d/d2h bytes they actually move.


// The static mirror of this policy is `tools/loramlint` (panic-surface
// pass, ratcheted in baseline.json); `warn` until the remaining sites
// burn down, then promote to `deny` as serve.rs/kvcache.rs already did.
#![cfg_attr(
    not(test),
    warn(clippy::unwrap_used, clippy::expect_used, clippy::panic, clippy::unreachable)
)]
#![cfg_attr(not(test), warn(clippy::indexing_slicing))]

use super::{literal_to_tensor, tensor_to_literal, Artifact, Runtime};
use crate::obs::trace::{self, Event};
use crate::tensor::{Data, Dtype, Tensor, TensorStore};
use anyhow::{bail, ensure, Context, Result};
use std::collections::{BTreeSet, HashMap};
use std::rc::Rc;
use std::time::Instant;

/// Which backend a [`Session`] keeps its state on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Host tensors, literal round-trip per run (the v1 baseline path).
    Host,
    /// Device-resident PJRT buffers (the hot path).
    Device,
}

/// `LORAM_HOST_PATH=1` forces the host backend for every new session.
pub fn host_path_forced() -> bool {
    std::env::var("LORAM_HOST_PATH").map(|v| v == "1").unwrap_or(false)
}

impl BackendKind {
    pub fn from_env() -> BackendKind {
        if host_path_forced() {
            BackendKind::Host
        } else {
            BackendKind::Device
        }
    }
}

enum Slots {
    Host(Vec<Option<Tensor>>),
    Device(Vec<Option<xla::PjRtBuffer>>),
}

/// A value taken out of a session slot for donation into another session:
/// a host tensor or a device-resident PJRT buffer. Moving a `SlotValue`
/// between sessions moves the handle only — on the device backend no bytes
/// leave the device (the KV-cache handoff between the decode prefill and
/// step sessions rides on this).
pub enum SlotValue {
    Host(Tensor),
    Device(xla::PjRtBuffer),
}

/// A resolved slot group: member input slots whose stacked leading axis
/// holds `size` interchangeable rows (see `ArtifactMeta::slot_groups`).
pub(crate) struct GroupState {
    pub(crate) size: usize,
    pub(crate) member_slots: Vec<usize>,
}

pub struct Session {
    pub art: Rc<Artifact>,
    name_to_slot: HashMap<String, usize>,
    /// output index -> input slot it donates back into (state threading)
    out_bind: Vec<Option<usize>>,
    slots: Slots,
    /// declared slot groups (e.g. the adapter group), by name
    groups: HashMap<String, GroupState>,
    /// every slot that belongs to some group (staging sync in `set`)
    group_member_slots: BTreeSet<usize>,
    /// host staging for group member slots: `put_group` writes rows here,
    /// `run` re-uploads only the members something actually changed in
    stage: HashMap<usize, Tensor>,
    dirty: BTreeSet<usize>,
}

/// Resolve the meta's declared output→input bindings to positional form,
/// validating that sources are outputs, targets are inputs, shapes/dtypes
/// agree, and that no state-style output is left unbound.
pub(crate) fn resolve_bindings(
    meta: &super::ArtifactMeta,
    name_to_slot: &HashMap<String, usize>,
) -> Result<Vec<Option<usize>>> {
    let mut out_bind: Vec<Option<usize>> = vec![None; meta.outputs.len()];
    for (out_name, in_name) in meta.state_bindings() {
        let j = meta
            .outputs
            .iter()
            .position(|o| o.name == out_name)
            .with_context(|| {
                format!("artifact {}: state binding source '{out_name}' is not an output", meta.name)
            })?;
        let slot = *name_to_slot.get(&in_name).with_context(|| {
            format!("artifact {}: state binding target '{in_name}' is not an input", meta.name)
        })?;
        let (o, i) = (&meta.outputs[j], &meta.inputs[slot]);
        if o.shape != i.shape || o.dtype != i.dtype {
            bail!(
                "artifact {}: binding {out_name} -> {in_name}: {:?}/{:?} vs {:?}/{:?}",
                meta.name, o.shape, o.dtype, i.shape, i.dtype
            );
        }
        out_bind[j] = Some(slot);
    }
    // guard against misdeclared metas: a state-style output that resolves
    // to nothing would silently round-trip through the host every step
    for (j, o) in meta.outputs.iter().enumerate() {
        let state_style = o.name.starts_with("new.")
            || o.name.starts_with("new_m.")
            || o.name.starts_with("new_v.");
        if state_style && out_bind[j].is_none() {
            bail!("artifact {}: state output '{}' has no input binding", meta.name, o.name);
        }
    }
    Ok(out_bind)
}

/// Resolve the meta's declared slot groups: the gather input must exist
/// (int32), every member must be an input whose leading dim equals the
/// group size. Mirrored in python by `compile.meta_check`.
pub(crate) fn resolve_groups(
    meta: &super::ArtifactMeta,
    name_to_slot: &HashMap<String, usize>,
) -> Result<HashMap<String, GroupState>> {
    let mut out = HashMap::new();
    let mut seen_members: HashMap<usize, String> = HashMap::new();
    for g in meta.slot_groups()? {
        ensure!(g.size >= 1, "artifact {}: slot group '{}' has size 0", meta.name, g.name);
        let gather = name_to_slot.get(&g.input).with_context(|| {
            format!(
                "artifact {}: slot group '{}' gather input '{}' is not an input",
                meta.name, g.name, g.input
            )
        })?;
        ensure!(
            meta.inputs[*gather].dtype == Dtype::I32,
            "artifact {}: slot group '{}' gather input '{}' must be int32",
            meta.name,
            g.name,
            g.input
        );
        let mut member_slots = Vec::with_capacity(g.members.len());
        for m in &g.members {
            let slot = *name_to_slot.get(m).with_context(|| {
                format!(
                    "artifact {}: slot group '{}' member '{m}' is not an input",
                    meta.name, g.name
                )
            })?;
            let shape = &meta.inputs[slot].shape;
            ensure!(
                shape.first() == Some(&g.size),
                "artifact {}: slot group '{}' member '{m}' shape {shape:?} \
                 does not stack {} slots",
                meta.name,
                g.name,
                g.size
            );
            // a member shared across groups would let one group's flush
            // clobber rows the other staged (the python mirror rejects
            // the same meta)
            if let Some(other) = seen_members.insert(slot, g.name.clone()) {
                bail!(
                    "artifact {}: slot group member '{m}' repeats across \
                     groups '{other}' and '{}'",
                    meta.name,
                    g.name
                );
            }
            member_slots.push(slot);
        }
        ensure!(
            !member_slots.is_empty(),
            "artifact {}: slot group '{}' has no members",
            meta.name,
            g.name
        );
        out.insert(g.name.clone(), GroupState { size: g.size, member_slots });
    }
    Ok(out)
}

/// Copy one slot's worth of data (`row`) into position `ix` of a stacked
/// staging tensor. Pure so the row math is unit-testable.
pub(crate) fn write_group_row(staged: &mut Tensor, ix: usize, row: &Tensor) -> Result<()> {
    ensure!(
        staged.shape.len() == row.shape.len() + 1 && staged.shape[1..] == row.shape[..],
        "group row shape {:?} does not fit stacked {:?}",
        row.shape,
        staged.shape
    );
    ensure!(ix < staged.shape[0], "group row {ix} out of {} slots", staged.shape[0]);
    let n = row.len();
    match (&mut staged.data, &row.data) {
        (Data::F32(dst), Data::F32(src)) => dst[ix * n..(ix + 1) * n].copy_from_slice(src),
        (Data::I32(dst), Data::I32(src)) => dst[ix * n..(ix + 1) * n].copy_from_slice(src),
        _ => bail!("group row dtype mismatch"),
    }
    Ok(())
}

impl Session {
    /// Backend from `LORAM_HOST_PATH`; uploads every tensor in `stores`
    /// that the artifact wants. Remaining inputs (tokens, scalars, ...)
    /// must be `set` before `run`; declared zero-init inputs (optimiser
    /// moments) are zero-filled if absent.
    pub fn new(rt: &Runtime, art: Rc<Artifact>, stores: &[&TensorStore]) -> Result<Session> {
        Session::with_backend(rt, art, stores, BackendKind::from_env())
    }

    pub fn with_backend(
        rt: &Runtime,
        art: Rc<Artifact>,
        stores: &[&TensorStore],
        kind: BackendKind,
    ) -> Result<Session> {
        let mut name_to_slot = HashMap::new();
        for (i, spec) in art.meta.inputs.iter().enumerate() {
            name_to_slot.insert(spec.name.clone(), i);
        }
        let out_bind = resolve_bindings(&art.meta, &name_to_slot)?;
        let groups = resolve_groups(&art.meta, &name_to_slot)?;
        let n = art.meta.inputs.len();
        let slots = match kind {
            BackendKind::Host => Slots::Host((0..n).map(|_| None).collect()),
            BackendKind::Device => Slots::Device((0..n).map(|_| None).collect()),
        };
        let group_member_slots = groups
            .values()
            .flat_map(|g| g.member_slots.iter().copied())
            .collect();
        let mut sess = Session {
            art,
            name_to_slot,
            out_bind,
            slots,
            groups,
            group_member_slots,
            stage: HashMap::new(),
            dirty: BTreeSet::new(),
        };
        for store in stores {
            for (name, t) in &store.map {
                if sess.name_to_slot.contains_key(name) {
                    sess.set(rt, name, t)?;
                }
            }
        }
        let missing: Vec<(String, Vec<usize>)> = sess
            .art
            .meta
            .zero_init_names()
            .into_iter()
            .filter_map(|name| {
                let slot = *sess.name_to_slot.get(&name)?;
                if sess.slot_is_set(slot) {
                    None
                } else {
                    Some((name, sess.art.meta.inputs[slot].shape.clone()))
                }
            })
            .collect();
        for (name, shape) in missing {
            sess.set(rt, &name, &Tensor::zeros(&shape))?;
        }
        Ok(sess)
    }

    pub fn backend(&self) -> BackendKind {
        match self.slots {
            Slots::Host(_) => BackendKind::Host,
            Slots::Device(_) => BackendKind::Device,
        }
    }

    fn slot_is_set(&self, slot: usize) -> bool {
        match &self.slots {
            Slots::Host(s) => s[slot].is_some(),
            Slots::Device(s) => s[slot].is_some(),
        }
    }

    /// Upload one tensor into its input slot (validates shape/dtype).
    pub fn set(&mut self, rt: &Runtime, name: &str, t: &Tensor) -> Result<()> {
        let slot = *self
            .name_to_slot
            .get(name)
            .with_context(|| format!("artifact {} has no input '{name}'", self.art.meta.name))?;
        self.upload_slot(rt, slot, t)?;
        // a group member set whole keeps its staging copy in sync, so a
        // later put_group row-write starts from the uploaded stack, never
        // from zeros (which would wipe the other slots at the next flush).
        // Sync strictly after the upload succeeded: a failed set must not
        // mark a stale member clean.
        if self.group_member_slots.contains(&slot) {
            self.stage.insert(slot, t.clone());
            self.dirty.remove(&slot);
        }
        Ok(())
    }

    /// Validate and upload into a slot, with no group-staging bookkeeping
    /// (shared by `set` and `flush_groups`).
    fn upload_slot(&mut self, rt: &Runtime, slot: usize, t: &Tensor) -> Result<()> {
        let spec = &self.art.meta.inputs[slot];
        if t.shape != spec.shape || t.dtype() != spec.dtype {
            bail!(
                "input '{}': got {:?}/{:?}, want {:?}/{:?}",
                spec.name, t.shape, t.dtype(), spec.shape, spec.dtype
            );
        }
        match &mut self.slots {
            Slots::Host(slots) => {
                slots[slot] = Some(t.clone());
            }
            Slots::Device(slots) => {
                let buf = match &t.data {
                    Data::F32(v) => rt.client().buffer_from_host_buffer::<f32>(v, &t.shape, None)?,
                    Data::I32(v) => rt.client().buffer_from_host_buffer::<i32>(v, &t.shape, None)?,
                };
                rt.metrics.borrow_mut().h2d_bytes += (t.len() * 4) as u64;
                slots[slot] = Some(buf);
            }
        }
        Ok(())
    }

    /// Stage one slot of a named group: write `store`'s member tensors
    /// (keyed by their *un-stacked* member names) into row `ix` of the
    /// stacked staging copies and mark those members dirty. The device
    /// upload is deferred to the next `run`, so swapping several slots
    /// back-to-back re-uploads each member tensor once, not once per slot
    /// — and a run with no group churn uploads nothing.
    pub fn put_group(&mut self, group: &str, ix: usize, store: &TensorStore) -> Result<()> {
        let (size, member_slots) = {
            let g = self.groups.get(group).with_context(|| {
                format!("artifact {} declares no slot group '{group}'", self.art.meta.name)
            })?;
            (g.size, g.member_slots.clone())
        };
        ensure!(
            ix < size,
            "slot group '{group}': slot {ix} out of {size} slots"
        );
        for slot in member_slots {
            let spec = &self.art.meta.inputs[slot];
            let row = store.get(&spec.name).with_context(|| {
                format!("put_group '{group}' slot {ix}: missing member")
            })?;
            let staged = self.stage.entry(slot).or_insert_with(|| match spec.dtype {
                Dtype::F32 => Tensor::zeros(&spec.shape),
                Dtype::I32 => Tensor::from_i32(
                    &spec.shape,
                    vec![0; spec.shape.iter().product()],
                ),
            });
            write_group_row(staged, ix, row)
                .with_context(|| format!("put_group '{group}' member '{}'", spec.name))?;
            self.dirty.insert(slot);
        }
        Ok(())
    }

    /// Size of a declared slot group (e.g. adapter capacity).
    pub fn group_size(&self, group: &str) -> Option<usize> {
        self.groups.get(group).map(|g| g.size)
    }

    /// Upload every dirty group member's staged stack into its slot. A
    /// member's dirty flag clears only after its upload succeeds, so a
    /// transient failure leaves the remaining members (and the failed one)
    /// queued for the next attempt — a retried run can never silently
    /// serve a stale member.
    fn flush_groups(&mut self, rt: &Runtime) -> Result<()> {
        while let Some(&slot) = self.dirty.iter().next() {
            let t = self.stage.remove(&slot).expect("dirty slot has staging");
            // raw upload: staging already holds the truth, and `set`'s
            // group sync would both clone redundantly and clear the dirty
            // flag before the upload is known to have succeeded
            let res = self.upload_slot(rt, slot, &t);
            self.stage.insert(slot, t);
            res?;
            self.dirty.remove(&slot);
        }
        Ok(())
    }

    /// Execute once. Bound state outputs donate back onto their input
    /// slots; every other output is fetched to the host and returned.
    ///
    /// When a trace sink is active (`obs::trace`), every run emits one
    /// `SessionRun` event with its h2d / execute / d2h wall-ms split —
    /// the timing hook DESIGN.md §2g's per-tick attribution rides on.
    /// The `Instant` reads cost nanoseconds next to a PJRT execution and
    /// the event itself is only built while tracing.
    pub fn run(&mut self, rt: &Runtime) -> Result<TensorStore> {
        let t_flush = Instant::now();
        self.flush_groups(rt)?;
        let mut h2d_ms = t_flush.elapsed().as_secs_f64() * 1e3;
        let mut exec_ms = 0.0;
        let mut d2h_ms = 0.0;
        let art = self.art.clone();
        let mut host = TensorStore::new();
        match &mut self.slots {
            Slots::Host(slots) => {
                let t_h2d = Instant::now();
                let mut lits = Vec::with_capacity(slots.len());
                let mut h2d = 0u64;
                for (i, s) in slots.iter().enumerate() {
                    let t = s.as_ref().with_context(|| {
                        format!("input '{}' not set", art.meta.inputs[i].name)
                    })?;
                    h2d += (t.len() * 4) as u64;
                    lits.push(tensor_to_literal(t)?);
                }
                rt.metrics.borrow_mut().h2d_bytes += h2d;
                h2d_ms += t_h2d.elapsed().as_secs_f64() * 1e3;
                let t_exec = Instant::now();
                let outs = rt.execute_literals(&art, &lits)?;
                exec_ms = t_exec.elapsed().as_secs_f64() * 1e3;
                let t_d2h = Instant::now();
                for (j, lit) in outs.into_iter().enumerate() {
                    let spec = &art.meta.outputs[j];
                    let t = literal_to_tensor(&lit, spec)?;
                    match self.out_bind[j] {
                        Some(slot) => slots[slot] = Some(t),
                        None => host.insert(spec.name.clone(), t),
                    }
                }
                d2h_ms = t_d2h.elapsed().as_secs_f64() * 1e3;
            }
            Slots::Device(slots) => {
                let t0 = Instant::now();
                let refs: Vec<&xla::PjRtBuffer> = slots
                    .iter()
                    .enumerate()
                    .map(|(i, s)| {
                        s.as_ref().ok_or_else(|| {
                            anyhow::anyhow!("input '{}' not set", art.meta.inputs[i].name)
                        })
                    })
                    .collect::<Result<_>>()?;
                let t_exec = Instant::now();
                let mut bufs = art
                    .execute_buffers(&refs)
                    .with_context(|| format!("execute_b {}", art.meta.name))?;
                exec_ms = t_exec.elapsed().as_secs_f64() * 1e3;
                let outs = std::mem::take(&mut bufs[0]);
                if outs.len() != art.meta.outputs.len() {
                    bail!(
                        "artifact {}: got {} output buffers, expected {} (is the \
                         untuple_result patch active?)",
                        art.meta.name,
                        outs.len(),
                        art.meta.outputs.len()
                    );
                }
                let t_d2h = Instant::now();
                for (j, buf) in outs.into_iter().enumerate() {
                    match self.out_bind[j] {
                        Some(slot) => {
                            slots[slot] = Some(buf);
                        }
                        None => {
                            let spec = &art.meta.outputs[j];
                            let lit = buf.to_literal_sync()?;
                            rt.metrics.borrow_mut().d2h_bytes +=
                                (spec.shape.iter().product::<usize>() * 4) as u64;
                            host.insert(spec.name.clone(), literal_to_tensor(&lit, spec)?);
                        }
                    }
                }
                d2h_ms = t_d2h.elapsed().as_secs_f64() * 1e3;
                let mut m = rt.metrics.borrow_mut();
                m.executions += 1;
                m.execute_ms += t0.elapsed().as_secs_f64() * 1e3;
            }
        }
        trace::emit(|| Event::SessionRun {
            artifact: art.meta.name.clone(),
            h2d_ms,
            exec_ms,
            d2h_ms,
        });
        Ok(host)
    }

    /// Download an input slot back to the host (e.g. the trained LoRA
    /// factors after the last step — the *stepped* state, not the initial
    /// upload, thanks to the output bindings).
    pub fn fetch(&self, rt: &Runtime, name: &str) -> Result<Tensor> {
        let slot = *self
            .name_to_slot
            .get(name)
            .with_context(|| format!("artifact {} has no input '{name}'", self.art.meta.name))?;
        let spec = &self.art.meta.inputs[slot];
        match &self.slots {
            Slots::Host(slots) => slots[slot]
                .clone()
                .with_context(|| format!("input '{name}' not set")),
            Slots::Device(slots) => {
                let buf = slots[slot]
                    .as_ref()
                    .with_context(|| format!("input '{name}' not set"))?;
                let lit = buf.to_literal_sync()?;
                rt.metrics.borrow_mut().d2h_bytes +=
                    (spec.shape.iter().product::<usize>() * 4) as u64;
                literal_to_tensor(&lit, spec)
            }
        }
    }

    pub fn fetch_all(&self, rt: &Runtime, names: &[String]) -> Result<TensorStore> {
        let mut out = TensorStore::new();
        for n in names {
            out.insert(n.clone(), self.fetch(rt, n)?);
        }
        Ok(out)
    }

    /// Take a slot's current value out of the session; the slot becomes
    /// unset and must be re-`set`/`put_slot` before the next `run`.
    /// Zero-copy on the device backend (the buffer handle moves).
    pub fn take_slot(&mut self, name: &str) -> Result<SlotValue> {
        let slot = *self
            .name_to_slot
            .get(name)
            .with_context(|| format!("artifact {} has no input '{name}'", self.art.meta.name))?;
        match &mut self.slots {
            Slots::Host(s) => s[slot].take().map(SlotValue::Host),
            Slots::Device(s) => s[slot].take().map(SlotValue::Device),
        }
        .with_context(|| format!("input '{name}' not set"))
    }

    /// Install a value taken from another session. Backends must match,
    /// and (on the host backend, where the value carries its shape) the
    /// receiving input must declare the same shape/dtype; device handoffs
    /// are validated by the caller against the two artifacts' metas.
    pub fn put_slot(&mut self, name: &str, v: SlotValue) -> Result<()> {
        let slot = *self
            .name_to_slot
            .get(name)
            .with_context(|| format!("artifact {} has no input '{name}'", self.art.meta.name))?;
        let spec = &self.art.meta.inputs[slot];
        match (&mut self.slots, v) {
            (Slots::Host(s), SlotValue::Host(t)) => {
                if t.shape != spec.shape || t.dtype() != spec.dtype {
                    bail!(
                        "put_slot '{name}': got {:?}/{:?}, want {:?}/{:?}",
                        t.shape, t.dtype(), spec.shape, spec.dtype
                    );
                }
                s[slot] = Some(t);
            }
            (Slots::Device(s), SlotValue::Device(b)) => {
                s[slot] = Some(b);
            }
            _ => bail!("put_slot '{name}': host/device backend mismatch"),
        }
        Ok(())
    }

    /// Donate named slots into `dst` — the state handoff between the two
    /// artifacts of one subsystem (e.g. decode prefill -> decode step
    /// caches). No transfer metrics accrue: nothing crosses the host
    /// boundary on the device backend.
    pub fn donate_slots(&mut self, dst: &mut Session, names: &[String]) -> Result<()> {
        for n in names {
            let v = self.take_slot(n)?;
            dst.put_slot(n, v)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ArtifactMeta;
    use crate::util::json::Json;

    fn meta(extra: &str) -> ArtifactMeta {
        let src = format!(
            r#"{{
              "name": "t", "config": {{"name":"tiny","vocab_size":512,"d_model":64,
                "n_layers":1,"n_heads":2,"n_kv_heads":2,"d_ff":160,"max_seq":64,
                "lora_rank":8,"lora_alpha":16.0,"lora_lm_head":true}},
              "inputs": [
                {{"name":"step","shape":[],"dtype":"float32"}},
                {{"name":"tokens","shape":[2,33],"dtype":"int32"}},
                {{"name":"w","shape":[4,4],"dtype":"float32"}},
                {{"name":"adam_m.w","shape":[4,4],"dtype":"float32"}},
                {{"name":"adam_v.w","shape":[4,4],"dtype":"float32"}}
              ],
              "outputs": [
                {{"name":"loss","shape":[],"dtype":"float32"}},
                {{"name":"new.w","shape":[4,4],"dtype":"float32"}},
                {{"name":"new_m.w","shape":[4,4],"dtype":"float32"}},
                {{"name":"new_v.w","shape":[4,4],"dtype":"float32"}}
              ]{extra}
            }}"#
        );
        ArtifactMeta::from_json(&Json::parse(&src).unwrap()).unwrap()
    }

    fn slots(m: &ArtifactMeta) -> HashMap<String, usize> {
        m.inputs
            .iter()
            .enumerate()
            .map(|(i, s)| (s.name.clone(), i))
            .collect()
    }

    #[test]
    fn every_state_output_binds_to_its_input_slot() {
        let m = meta("");
        let binds = resolve_bindings(&m, &slots(&m)).unwrap();
        // loss stays host-bound; new/new_m/new_v donate onto w/adam_m/adam_v
        assert_eq!(binds, vec![None, Some(2), Some(3), Some(4)]);
    }

    #[test]
    fn declared_bindings_resolve_positionally() {
        let m = meta(
            r#", "extra": {"state_bindings":
                 {"new.w": "w", "new_m.w": "adam_m.w", "new_v.w": "adam_v.w"}}"#,
        );
        let binds = resolve_bindings(&m, &slots(&m)).unwrap();
        assert_eq!(binds, vec![None, Some(2), Some(3), Some(4)]);
    }

    #[test]
    fn unbound_state_output_is_rejected() {
        // declaration covers only new.w: new_m.w / new_v.w left dangling
        let m = meta(r#", "extra": {"state_bindings": {"new.w": "w"}}"#);
        let err = resolve_bindings(&m, &slots(&m)).unwrap_err().to_string();
        assert!(err.contains("no input binding"), "{err}");
    }

    #[test]
    fn binding_to_unknown_input_is_rejected() {
        let m = meta(
            r#", "extra": {"state_bindings":
                 {"new.w": "nope", "new_m.w": "adam_m.w", "new_v.w": "adam_v.w"}}"#,
        );
        assert!(resolve_bindings(&m, &slots(&m)).is_err());
    }

    #[test]
    fn binding_shape_mismatch_is_rejected() {
        let m = meta(
            r#", "extra": {"state_bindings":
                 {"new.w": "tokens", "new_m.w": "adam_m.w", "new_v.w": "adam_v.w"}}"#,
        );
        assert!(resolve_bindings(&m, &slots(&m)).is_err());
    }

    const ADAPTER_META: &str = r#"{
      "name": "t", "config": {"name":"tiny","vocab_size":512,"d_model":64,
        "n_layers":1,"n_heads":2,"n_kv_heads":2,"d_ff":160,"max_seq":64,
        "lora_rank":8,"lora_alpha":16.0,"lora_lm_head":true},
      "inputs": [
        {"name":"tokens","shape":[2,8],"dtype":"int32"},
        {"name":"adapter_ix","shape":[2],"dtype":"int32"},
        {"name":"l0.wq.lora_a","shape":[3,4,2],"dtype":"float32"},
        {"name":"l0.wq.lora_b","shape":[3,2,4],"dtype":"float32"}
      ],
      "outputs": [{"name":"logits","shape":[2,8],"dtype":"float32"}],
      "extra": {"slot_groups": {"adapter": {
        "input": "adapter_ix", "size": 3,
        "members": ["l0.wq.lora_a", "l0.wq.lora_b"]}}}
    }"#;

    fn adapter_meta() -> ArtifactMeta {
        ArtifactMeta::from_json(&Json::parse(ADAPTER_META).unwrap()).unwrap()
    }

    #[test]
    fn groups_resolve_members_and_validate_stacking() {
        let m = adapter_meta();
        let gs = resolve_groups(&m, &slots(&m)).unwrap();
        let g = &gs["adapter"];
        assert_eq!(g.size, 3);
        assert_eq!(g.member_slots, vec![2, 3]);
    }

    #[test]
    fn group_with_unstacked_member_is_rejected() {
        // size 5 no longer matches the members' leading dim of 3
        let mut m = adapter_meta();
        m.extra = Json::parse(
            r#"{"slot_groups": {"adapter": {"input": "adapter_ix",
                "size": 5, "members": ["l0.wq.lora_a"]}}}"#,
        )
        .unwrap();
        let err = resolve_groups(&m, &slots(&m)).unwrap_err().to_string();
        assert!(err.contains("does not stack"), "{err}");
    }

    #[test]
    fn group_gather_input_must_exist_and_be_i32() {
        let mut m = adapter_meta();
        m.extra = Json::parse(
            r#"{"slot_groups": {"adapter": {"input": "missing",
                "size": 3, "members": ["l0.wq.lora_a"]}}}"#,
        )
        .unwrap();
        assert!(resolve_groups(&m, &slots(&m)).is_err());
        let mut m = adapter_meta();
        m.extra = Json::parse(
            r#"{"slot_groups": {"adapter": {"input": "l0.wq.lora_a",
                "size": 3, "members": ["l0.wq.lora_b"]}}}"#,
        )
        .unwrap();
        let err = resolve_groups(&m, &slots(&m)).unwrap_err().to_string();
        assert!(err.contains("int32"), "{err}");
    }

    #[test]
    fn write_group_row_lands_in_the_selected_slot_only() {
        let mut staged = Tensor::zeros(&[3, 2, 2]);
        let row = Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        write_group_row(&mut staged, 1, &row).unwrap();
        assert_eq!(staged.f32s()[0..4], [0.0; 4]);
        assert_eq!(staged.f32s()[4..8], [1.0, 2.0, 3.0, 4.0]);
        assert_eq!(staged.f32s()[8..12], [0.0; 4]);
        // overwrite the same slot: no accumulation
        let row2 = Tensor::from_f32(&[2, 2], vec![9.0; 4]);
        write_group_row(&mut staged, 1, &row2).unwrap();
        assert_eq!(staged.f32s()[4..8], [9.0; 4]);
        // out-of-range slot and wrong row shape are rejected
        assert!(write_group_row(&mut staged, 3, &row).is_err());
        assert!(write_group_row(&mut staged, 0, &Tensor::zeros(&[2, 3])).is_err());
    }
}

//! Artifact metadata: the `.meta.json` emitted by aot.py next to every HLO
//! artifact, plus the model config it embeds.

use crate::tensor::Dtype;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;

#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

/// Mirror of python/compile/configs.py::ModelConfig.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelCfg {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub lora_rank: usize,
    pub lora_alpha: f64,
    pub lora_lm_head: bool,
    /// per-layer (heads, kv_heads, d_ff) under structured pruning
    pub layer_plan: Option<Vec<(usize, usize, usize)>>,
}

impl ModelCfg {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn layer_shapes(&self, i: usize) -> (usize, usize, usize) {
        match &self.layer_plan {
            Some(plan) => plan[i],
            None => (self.n_heads, self.n_kv_heads, self.d_ff),
        }
    }

    /// Projection shapes for layer i, mirroring model.layer_proj_shapes.
    pub fn layer_proj_shapes(&self, i: usize) -> Vec<(&'static str, (usize, usize))> {
        let (h, kv, ff) = self.layer_shapes(i);
        let hd = self.head_dim();
        let d = self.d_model;
        vec![
            ("wq", (d, h * hd)),
            ("wk", (d, kv * hd)),
            ("wv", (d, kv * hd)),
            ("wo", (h * hd, d)),
            ("w_gate", (d, ff)),
            ("w_up", (d, ff)),
            ("w_down", (ff, d)),
        ]
    }

    /// Canonical base-parameter (name, shape) order — mirror of
    /// model.param_shapes. The artifact meta is the source of truth; this
    /// exists so Rust can initialise / manipulate weights without one.
    pub fn param_shapes(&self) -> Vec<(String, Vec<usize>)> {
        let mut out = vec![(
            "embed".to_string(),
            vec![self.vocab_size, self.d_model],
        )];
        for i in 0..self.n_layers {
            out.push((format!("l{i}.attn_norm"), vec![self.d_model]));
            for (k, (m, n)) in self.layer_proj_shapes(i) {
                out.push((format!("l{i}.{k}"), vec![m, n]));
            }
            out.push((format!("l{i}.mlp_norm"), vec![self.d_model]));
        }
        out.push(("final_norm".to_string(), vec![self.d_model]));
        out.push((
            "lm_head".to_string(),
            vec![self.d_model, self.vocab_size],
        ));
        out
    }

    /// Canonical LoRA (name, shape) order — mirror of model.lora_shapes.
    pub fn lora_shapes(&self) -> Vec<(String, Vec<usize>)> {
        let r = self.lora_rank;
        let mut out = vec![];
        for i in 0..self.n_layers {
            for (k, (m, n)) in self.layer_proj_shapes(i) {
                out.push((format!("l{i}.{k}.lora_a"), vec![m, r]));
                out.push((format!("l{i}.{k}.lora_b"), vec![r, n]));
            }
        }
        if self.lora_lm_head {
            out.push((
                "lm_head.lora_a".to_string(),
                vec![self.d_model, r],
            ));
            out.push((
                "lm_head.lora_b".to_string(),
                vec![r, self.vocab_size],
            ));
        }
        out
    }

    pub fn param_count(&self) -> usize {
        self.param_shapes()
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum()
    }

    pub fn lora_param_count(&self) -> usize {
        self.lora_shapes()
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum()
    }

    pub fn from_json(j: &Json) -> Result<ModelCfg> {
        let g = |k: &str| -> Result<f64> {
            j.get(k)
                .and_then(|v| v.as_f64())
                .with_context(|| format!("config field {k}"))
        };
        let layer_plan = match j.get("layer_plan") {
            Some(Json::Arr(rows)) => Some(
                rows.iter()
                    .map(|r| {
                        let a = r.as_arr().context("layer_plan row")?;
                        Ok((
                            a[0].as_usize().unwrap(),
                            a[1].as_usize().unwrap(),
                            a[2].as_usize().unwrap(),
                        ))
                    })
                    .collect::<Result<Vec<_>>>()?,
            ),
            _ => None,
        };
        Ok(ModelCfg {
            name: j
                .get("name")
                .and_then(|v| v.as_str())
                .unwrap_or("unnamed")
                .to_string(),
            vocab_size: g("vocab_size")? as usize,
            d_model: g("d_model")? as usize,
            n_layers: g("n_layers")? as usize,
            n_heads: g("n_heads")? as usize,
            n_kv_heads: g("n_kv_heads")? as usize,
            d_ff: g("d_ff")? as usize,
            max_seq: g("max_seq")? as usize,
            lora_rank: g("lora_rank")? as usize,
            lora_alpha: g("lora_alpha")?,
            lora_lm_head: j
                .get("lora_lm_head")
                .and_then(|v| v.as_bool())
                .unwrap_or(true),
            layer_plan,
        })
    }
}

/// A named *slot group*: a family of stacked inputs whose leading axis
/// holds `size` interchangeable slots, gathered per batch row by the
/// `input` tensor (e.g. the adapter group: every LoRA factor stacked
/// `(n_adapters, ...)`, selected by `adapter_ix`). Declared by aot.py in
/// `extra.slot_groups`; `Session::put_group` uploads one slot's worth of
/// member rows and re-uploads only dirty members.
#[derive(Debug, Clone, PartialEq)]
pub struct SlotGroup {
    pub name: String,
    /// the int32 input that selects a slot per row (e.g. `adapter_ix`)
    pub input: String,
    pub size: usize,
    pub members: Vec<String>,
}

/// Pool geometry of a paged decode artifact (`extra.paged`): caches are
/// `(n_blocks, block_size, ...)` tensors addressed through a per-row block
/// table instead of dense `(B, S, ...)` rows (DESIGN.md §2f).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PagedSpec {
    pub block_size: usize,
    pub n_blocks: usize,
}

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub config: ModelCfg,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub extra: Json,
}

impl ArtifactMeta {
    pub fn load(path: &Path) -> Result<ArtifactMeta> {
        let txt = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        let j = Json::parse(&txt).map_err(anyhow::Error::msg)?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<ArtifactMeta> {
        let parse_io = |key: &str| -> Result<Vec<IoSpec>> {
            let arr = j
                .get(key)
                .and_then(|v| v.as_arr())
                .with_context(|| format!("meta field {key}"))?;
            arr.iter()
                .map(|e| {
                    let name = e
                        .get("name")
                        .and_then(|v| v.as_str())
                        .context("io name")?
                        .to_string();
                    let shape = e
                        .get("shape")
                        .and_then(|v| v.as_arr())
                        .context("io shape")?
                        .iter()
                        .map(|d| d.as_usize().unwrap())
                        .collect();
                    let dtype =
                        Dtype::from_str(e.get("dtype").and_then(|v| v.as_str()).unwrap_or("float32"))?;
                    Ok(IoSpec { name, shape, dtype })
                })
                .collect()
        };
        let config = ModelCfg::from_json(j.get("config").context("meta config")?)?;
        Ok(ArtifactMeta {
            name: j
                .get("name")
                .and_then(|v| v.as_str())
                .context("meta name")?
                .to_string(),
            config,
            inputs: parse_io("inputs")?,
            outputs: parse_io("outputs")?,
            extra: j.get("extra").cloned().unwrap_or(Json::Null),
        })
    }

    pub fn kind(&self) -> &str {
        self.extra
            .get("kind")
            .and_then(|v| v.as_str())
            .unwrap_or("unknown")
    }

    pub fn batch(&self) -> usize {
        self.extra.get("batch").and_then(|v| v.as_usize()).unwrap_or(1)
    }

    pub fn seq(&self) -> usize {
        self.extra.get("seq").and_then(|v| v.as_usize()).unwrap_or(1)
    }

    /// Draft window size of a `decode_verify` artifact: the tokens input is
    /// a (B, draft_k + 1) window (frontier + K draft candidates). `None`
    /// for every other artifact kind.
    pub fn draft_k(&self) -> Option<usize> {
        self.extra.get("draft_k").and_then(|v| v.as_usize())
    }

    /// Prompt-window length of a `decode_prefill_chunk` artifact: the
    /// tokens input is a (1, chunk) window forwarded at `start_pos` and
    /// scattered into the `row_onehot`-selected cache row (the chunked
    /// admission contract, DESIGN.md §2e; mirrored by
    /// `compile.meta_check`). `None` for every other artifact kind.
    pub fn chunk(&self) -> Option<usize> {
        self.extra.get("chunk").and_then(|v| v.as_usize())
    }

    /// Paged-decode geometry of a `decode_*_paged` artifact: its caches
    /// are pooled `(n_blocks, block_size, ...)` tensors and every forward
    /// takes an int32 `block_table` input mapping logical block slots to
    /// physical pool blocks (the paged contract, DESIGN.md §2f; mirrored
    /// by `compile.meta_check`). `None` on dense artifacts; a declaration
    /// missing either field is treated as absent, which `KvDecoder`
    /// rejects loudly when probing the paged family.
    pub fn paged(&self) -> Option<PagedSpec> {
        let p = self.extra.get("paged")?;
        Some(PagedSpec {
            block_size: p.get("block_size").and_then(|v| v.as_usize())?,
            n_blocks: p.get("n_blocks").and_then(|v| v.as_usize())?,
        })
    }

    /// Ordered name list from extra (param_names / lora_names / ...).
    pub fn name_list(&self, key: &str) -> Vec<String> {
        self.extra
            .get(key)
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_str().map(String::from)).collect())
            .unwrap_or_default()
    }

    pub fn input_spec(&self, name: &str) -> Result<&IoSpec> {
        self.inputs
            .iter()
            .find(|s| s.name == name)
            .with_context(|| format!("artifact {}: no input '{name}'", self.name))
    }

    /// Output→input state bindings: which input slot each state output
    /// donates back into after a step (the `Session` threading contract).
    /// The preferred source is the meta itself (`extra.state_bindings`,
    /// emitted by aot.py); artifacts predating the declaration fall back to
    /// the canonical naming convention `new.X -> X`, `new_m.X -> adam_m.X`,
    /// `new_v.X -> adam_v.X`.
    pub fn state_bindings(&self) -> Vec<(String, String)> {
        if let Some(Json::Obj(m)) = self.extra.get("state_bindings") {
            return m
                .iter()
                .filter_map(|(out, v)| v.as_str().map(|inp| (out.clone(), inp.to_string())))
                .collect();
        }
        self.outputs
            .iter()
            .filter_map(|o| {
                let target = if let Some(p) = o.name.strip_prefix("new_m.") {
                    format!("adam_m.{p}")
                } else if let Some(p) = o.name.strip_prefix("new_v.") {
                    format!("adam_v.{p}")
                } else if let Some(p) = o.name.strip_prefix("new.") {
                    p.to_string()
                } else {
                    return None;
                };
                Some((o.name.clone(), target))
            })
            .collect()
    }

    /// Declared slot groups (`extra.slot_groups`), e.g. the adapter group
    /// of the multi-adapter serving artifacts. A malformed declaration is
    /// an error, never silently an adapter-less artifact — the python
    /// mirror (`compile.meta_check`) rejects the same shapes.
    pub fn slot_groups(&self) -> Result<Vec<SlotGroup>> {
        let m = match self.extra.get("slot_groups") {
            None => return Ok(vec![]),
            Some(Json::Obj(m)) => m,
            Some(_) => bail!(
                "artifact {}: extra.slot_groups must be an object",
                self.name
            ),
        };
        m.iter()
            .map(|(name, g)| {
                let err = |what: &str| {
                    format!("artifact {}: slot group '{name}' {what}", self.name)
                };
                let input = g
                    .get("input")
                    .and_then(|v| v.as_str())
                    .with_context(|| err("has no gather input"))?
                    .to_string();
                let size = g
                    .get("size")
                    .and_then(|v| v.as_usize())
                    .with_context(|| err("has no integer size"))?;
                let members = g
                    .get("members")
                    .and_then(|v| v.as_arr())
                    .with_context(|| err("has no member list"))?
                    .iter()
                    .map(|x| {
                        x.as_str()
                            .map(String::from)
                            .with_context(|| err("has a non-string member"))
                    })
                    .collect::<Result<Vec<_>>>()?;
                Ok(SlotGroup { name: name.clone(), input, size, members })
            })
            .collect()
    }

    /// The adapter slot group, when this artifact serves stacked adapters.
    pub fn adapter_group(&self) -> Result<Option<SlotGroup>> {
        Ok(self.slot_groups()?.into_iter().find(|g| g.name == "adapter"))
    }

    /// Inputs a `Session` may zero-initialise when the caller does not
    /// supply them (optimiser moments). Declared via
    /// `extra.state_zero_init`; the adam-prefix convention is the fallback
    /// for artifacts without the declaration.
    pub fn zero_init_names(&self) -> Vec<String> {
        let declared = self.name_list("state_zero_init");
        if !declared.is_empty() {
            return declared;
        }
        self.inputs
            .iter()
            .filter(|s| s.name.starts_with("adam_m.") || s.name.starts_with("adam_v."))
            .map(|s| s.name.clone())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ModelCfg {
        ModelCfg {
            name: "tiny".into(),
            vocab_size: 512,
            d_model: 64,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 2,
            d_ff: 160,
            max_seq: 64,
            lora_rank: 8,
            lora_alpha: 16.0,
            lora_lm_head: true,
            layer_plan: None,
        }
    }

    #[test]
    fn param_order_matches_python_convention() {
        let cfg = tiny_cfg();
        let names: Vec<String> = cfg.param_shapes().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names[0], "embed");
        assert_eq!(names[1], "l0.attn_norm");
        assert_eq!(names[2], "l0.wq");
        assert_eq!(*names.last().unwrap(), "lm_head");
    }

    #[test]
    fn param_count_formula() {
        let cfg = tiny_cfg();
        // embed + lm_head + final_norm + per-layer
        let per_layer = 64 * 128 * 2 /*wq?*/;
        let _ = per_layer;
        // cross-check against a straightforward sum
        let total: usize = cfg
            .param_shapes()
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum();
        assert_eq!(cfg.param_count(), total);
        assert!(total > 512 * 64 * 2);
    }

    #[test]
    fn lora_excludes_lm_head_when_disabled() {
        let mut cfg = tiny_cfg();
        cfg.lora_lm_head = false;
        assert!(cfg
            .lora_shapes()
            .iter()
            .all(|(n, _)| !n.starts_with("lm_head")));
    }

    #[test]
    fn parses_meta_json() {
        let src = r#"{
          "name": "t", "config": {"name":"tiny","vocab_size":512,"d_model":64,
            "n_layers":2,"n_heads":2,"n_kv_heads":2,"d_ff":160,"max_seq":64,
            "rope_theta":10000.0,"rms_eps":1e-5,"lora_rank":8,
            "lora_alpha":16.0,"lora_lm_head":true,"layer_plan":[[2,2,160],[1,1,80]]},
          "inputs": [{"name":"tokens","shape":[2,33],"dtype":"int32"}],
          "outputs": [{"name":"loss","shape":[],"dtype":"float32"}],
          "extra": {"kind":"sft","batch":2,"seq":32,
                    "lora_names":["l0.wq.lora_a"]}
        }"#;
        let m = ArtifactMeta::from_json(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(m.kind(), "sft");
        assert_eq!(m.batch(), 2);
        assert_eq!(m.config.layer_shapes(1), (1, 1, 80));
        assert_eq!(m.inputs[0].dtype, Dtype::I32);
        assert_eq!(m.name_list("lora_names"), vec!["l0.wq.lora_a"]);
    }

    const TRAIN_META: &str = r#"{
      "name": "t", "config": {"name":"tiny","vocab_size":512,"d_model":64,
        "n_layers":1,"n_heads":2,"n_kv_heads":2,"d_ff":160,"max_seq":64,
        "lora_rank":8,"lora_alpha":16.0,"lora_lm_head":true},
      "inputs": [
        {"name":"step","shape":[],"dtype":"float32"},
        {"name":"tokens","shape":[2,33],"dtype":"int32"},
        {"name":"w","shape":[4,4],"dtype":"float32"},
        {"name":"adam_m.w","shape":[4,4],"dtype":"float32"},
        {"name":"adam_v.w","shape":[4,4],"dtype":"float32"}
      ],
      "outputs": [
        {"name":"loss","shape":[],"dtype":"float32"},
        {"name":"new.w","shape":[4,4],"dtype":"float32"},
        {"name":"new_m.w","shape":[4,4],"dtype":"float32"},
        {"name":"new_v.w","shape":[4,4],"dtype":"float32"}
      ]EXTRA
    }"#;

    fn train_meta(extra: &str) -> ArtifactMeta {
        let src = TRAIN_META.replace("EXTRA", extra);
        ArtifactMeta::from_json(&Json::parse(&src).unwrap()).unwrap()
    }

    #[test]
    fn state_bindings_derive_from_naming_convention() {
        let m = train_meta("");
        let binds = m.state_bindings();
        assert_eq!(binds.len(), 3); // every new.* / new_m.* / new_v.* output
        assert!(binds.contains(&("new.w".into(), "w".into())));
        assert!(binds.contains(&("new_m.w".into(), "adam_m.w".into())));
        assert!(binds.contains(&("new_v.w".into(), "adam_v.w".into())));
        assert!(!binds.iter().any(|(o, _)| o == "loss"));
        assert_eq!(m.zero_init_names(), vec!["adam_m.w", "adam_v.w"]);
    }

    #[test]
    fn slot_groups_parse_from_extra() {
        let m = train_meta(
            r#", "extra": {"slot_groups": {"adapter": {
                "input": "adapter_ix", "size": 3,
                "members": ["l0.wq.lora_a", "l0.wq.lora_b"]}}}"#,
        );
        let gs = m.slot_groups().unwrap();
        assert_eq!(gs.len(), 1);
        let g = m.adapter_group().unwrap().unwrap();
        assert_eq!(g.input, "adapter_ix");
        assert_eq!(g.size, 3);
        assert_eq!(g.members, vec!["l0.wq.lora_a", "l0.wq.lora_b"]);
        // artifacts without the declaration have no groups
        assert!(train_meta("").adapter_group().unwrap().is_none());
        // a malformed declaration is an error, not an adapter-less meta
        let bad = train_meta(
            r#", "extra": {"slot_groups": {"adapter": {"input": "x",
                 "members": ["l0.wq.lora_a"]}}}"#,
        );
        let err = bad.slot_groups().unwrap_err().to_string();
        assert!(err.contains("integer size"), "{err}");
        assert!(bad.adapter_group().is_err());
        // non-object slot_groups is malformed too, never adapter-less
        let arr = train_meta(r#", "extra": {"slot_groups": []}"#);
        let err = arr.slot_groups().unwrap_err().to_string();
        assert!(err.contains("must be an object"), "{err}");
    }

    #[test]
    fn chunk_window_parses_from_extra() {
        // the chunked-admission contract: extra.chunk names the (1, C)
        // window length; absent on every other artifact kind
        let m = train_meta(r#", "extra": {"kind": "decode_prefill_chunk", "chunk": 16}"#);
        assert_eq!(m.chunk(), Some(16));
        assert_eq!(m.kind(), "decode_prefill_chunk");
        assert_eq!(train_meta("").chunk(), None);
        // a non-integer chunk is absent, which KvDecoder rejects loudly
        // when probing the ladder (the python mirror rejects it in CI)
        let bad = train_meta(r#", "extra": {"chunk": "sixteen"}"#);
        assert_eq!(bad.chunk(), None);
    }

    #[test]
    fn paged_geometry_parses_from_extra() {
        // the paged-decode contract: extra.paged carries the pool geometry
        // of a pooled (n_blocks, block_size, ...) cache family
        let m = train_meta(
            r#", "extra": {"kind": "decode_step",
                           "paged": {"block_size": 8, "n_blocks": 64}}"#,
        );
        assert_eq!(m.paged(), Some(PagedSpec { block_size: 8, n_blocks: 64 }));
        // dense artifacts carry no extra.paged
        assert_eq!(train_meta("").paged(), None);
        // a declaration missing either field (or non-integer) is treated
        // as absent, which KvDecoder rejects loudly when probing the
        // paged family (the python mirror rejects it in CI)
        let half = train_meta(r#", "extra": {"paged": {"block_size": 8}}"#);
        assert_eq!(half.paged(), None);
        let bad = train_meta(r#", "extra": {"paged": {"block_size": "eight", "n_blocks": 64}}"#);
        assert_eq!(bad.paged(), None);
    }

    #[test]
    fn declared_state_bindings_take_precedence() {
        let m = train_meta(
            r#", "extra": {
                "state_bindings": {"new.w": "w", "new_m.w": "adam_m.w",
                                   "new_v.w": "adam_v.w"},
                "state_zero_init": ["adam_m.w", "adam_v.w"]
            }"#,
        );
        let binds = m.state_bindings();
        assert_eq!(binds.len(), 3);
        assert!(binds.contains(&("new.w".into(), "w".into())));
        assert_eq!(m.zero_init_names(), vec!["adam_m.w", "adam_v.w"]);
    }
}

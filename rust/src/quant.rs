//! Blockwise NF4 quantisation (QLoRA / QLoRAM, paper Eq. 9).
//!
//! The host-side quantiser that produces the `(codes, absmax)` pairs the
//! `sft_*_q` artifacts consume. Codes are carried as i32 tensors across the
//! PJRT literal bridge (no u4 path in xla 0.1.6) — *storage accounting*
//! (`nf4_storage_bytes`) reflects the real packed layout: 4 bits/param plus
//! one f32 absmax per block, matching the paper's Tables 4–6 / QLoRA.

use crate::tensor::{Tensor, TensorStore};
use anyhow::Result;

/// The 16-entry NF4 codebook (QLoRA, Dettmers et al. 2023) — must match
/// python/compile/kernels/ref.py::NF4_CODEBOOK bit-for-bit.
pub const NF4_CODEBOOK: [f32; 16] = [
    -1.0,
    -0.696_192_8,
    -0.525_073_05,
    -0.394_917_5,
    -0.284_441_38,
    -0.184_773_43,
    -0.091_050_036,
    0.0,
    0.079_580_3,
    0.160_930_2,
    0.246_112_3,
    0.337_915_24,
    0.440_709_83,
    0.562_617,
    0.722_956_84,
    1.0,
];

/// Block size along the last axis. 16 divides every projection dim across
/// the proxy family (aot.NF4_BLOCK); the paper/QLoRA default of 64 is used
/// by the analytic storage model where noted.
pub const NF4_BLOCK: usize = 16;

pub struct QuantizedMatrix {
    /// i32 codes in [0, 16), shape (m, n)
    pub codes: Tensor,
    /// per-block scales, shape (m, n / block)
    pub absmax: Tensor,
    pub block: usize,
}

/// Nearest-codebook-entry blockwise quantisation of a rank-2 matrix.
pub fn quantize(w: &Tensor, block: usize) -> QuantizedMatrix {
    let (m, n) = w.dims2();
    assert_eq!(n % block, 0, "block {block} must divide cols {n}");
    let src = w.f32s();
    let nb = n / block;
    let mut codes = vec![0i32; m * n];
    let mut absmax = vec![0f32; m * nb];
    for i in 0..m {
        for b in 0..nb {
            let off = i * n + b * block;
            let blk = &src[off..off + block];
            let amax = blk.iter().fold(0f32, |acc, &x| acc.max(x.abs()));
            absmax[i * nb + b] = amax;
            let scale = if amax == 0.0 { 1.0 } else { amax };
            for (j, &x) in blk.iter().enumerate() {
                codes[off + j] = nearest_code(x / scale);
            }
        }
    }
    QuantizedMatrix {
        codes: Tensor::from_i32(&[m, n], codes),
        absmax: Tensor::from_f32(&[m, nb], absmax),
        block,
    }
}

pub fn dequantize(q: &QuantizedMatrix) -> Tensor {
    let (m, n) = q.codes.dims2();
    let nb = n / q.block;
    let codes = q.codes.i32s();
    let absmax = q.absmax.f32s();
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let s = absmax[i * nb + j / q.block];
            out[i * n + j] = NF4_CODEBOOK[codes[i * n + j] as usize] * s;
        }
    }
    Tensor::from_f32(&[m, n], out)
}

/// Nearest codebook index (codebook is sorted; binary search + neighbour).
pub fn nearest_code(x: f32) -> i32 {
    let mut lo = 0usize;
    let mut hi = NF4_CODEBOOK.len() - 1;
    while hi - lo > 1 {
        let mid = (lo + hi) / 2;
        if NF4_CODEBOOK[mid] <= x {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    if (x - NF4_CODEBOOK[lo]).abs() <= (NF4_CODEBOOK[hi] - x).abs() {
        lo as i32
    } else {
        hi as i32
    }
}

/// Quantise every projection matrix the `_q` artifacts expect, producing
/// `<proj>.codes` / `<proj>.absmax` entries (see model.quant_names).
pub fn quantize_projections(
    params: &TensorStore,
    proj_names: &[String],
    block: usize,
) -> Result<TensorStore> {
    let mut out = TensorStore::new();
    for name in proj_names {
        let base = name.trim_end_matches(".codes").trim_end_matches(".absmax");
        if out.contains(&format!("{base}.codes")) {
            continue;
        }
        let w = params.get(base)?;
        let q = quantize(w, block);
        out.insert(format!("{base}.codes"), q.codes);
        out.insert(format!("{base}.absmax"), q.absmax);
    }
    Ok(out)
}

/// True packed storage cost in bytes: 4 bits/element + one f32 per block.
/// (QLoRA's double quantisation of the absmax values would shave a further
/// ~0.37 bits/param; not modelled.)
pub fn nf4_storage_bytes(n_params: u64, block: u64) -> u64 {
    n_params / 2 + (n_params / block) * 4
}

/// Effective bits per parameter for a given block size.
pub fn nf4_bits_per_param(block: u64) -> f64 {
    4.0 + 32.0 / block as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_mat(m: usize, n: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        Tensor::from_f32(&[m, n], rng.normal_vec(m * n, 1.0))
    }

    #[test]
    fn nearest_code_is_argmin() {
        let mut rng = Rng::new(1);
        for _ in 0..500 {
            let x = rng.normal() * 1.2;
            let got = nearest_code(x.clamp(-1.0, 1.0));
            let want = NF4_CODEBOOK
                .iter()
                .enumerate()
                .min_by(|a, b| {
                    (a.1 - x.clamp(-1.0, 1.0))
                        .abs()
                        .partial_cmp(&(b.1 - x.clamp(-1.0, 1.0)).abs())
                        .unwrap()
                })
                .unwrap()
                .0 as i32;
            assert_eq!(got, want, "x={x}");
        }
    }

    #[test]
    fn roundtrip_error_bounded_by_halved_gap() {
        let w = rand_mat(8, 64, 2);
        let q = quantize(&w, NF4_BLOCK);
        let wd = dequantize(&q);
        let max_gap = NF4_CODEBOOK
            .windows(2)
            .map(|p| p[1] - p[0])
            .fold(0f32, f32::max);
        let absmax = q.absmax.f32s();
        let nb = 64 / NF4_BLOCK;
        for i in 0..8 {
            for j in 0..64 {
                let bound = absmax[i * nb + j / NF4_BLOCK] * (max_gap / 2.0) + 1e-6;
                let err = (w.f32s()[i * 64 + j] - wd.f32s()[i * 64 + j]).abs();
                assert!(err <= bound, "err {err} > bound {bound}");
            }
        }
    }

    #[test]
    fn zero_block_roundtrips_to_zero() {
        let w = Tensor::zeros(&[2, 32]);
        let q = quantize(&w, 16);
        let wd = dequantize(&q);
        assert!(wd.f32s().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn extremes_are_exact() {
        let mut v = vec![0.125f32; 32];
        v[0] = 2.0;
        v[16] = -3.0;
        let w = Tensor::from_f32(&[1, 32], v);
        let q = quantize(&w, 16);
        let wd = dequantize(&q);
        assert!((wd.f32s()[0] - 2.0).abs() < 1e-6);
        assert!((wd.f32s()[16] + 3.0).abs() < 1e-6);
    }

    /// Property sweep (mirrored by the hypothesis test over
    /// `kernels/nf4.py`): the QLoRAM quantiser is pinned by laws, not
    /// only golden values. Randomized shapes/scales, 200 trials.
    #[test]
    fn roundtrip_invariants_hold_over_random_matrices() {
        let mut rng = Rng::new(42);
        for trial in 0..200 {
            let m = 1 + rng.below(8);
            let nb = 1 + rng.below(6);
            let block = [8, 16, 32][rng.below(3)];
            let scale = 10f32.powf(rng.f32() * 4.0 - 3.0); // 1e-3 .. 10
            let n = nb * block;
            let mut w = rand_mat(m, n, 1000 + trial);
            for x in w.f32s_mut() {
                *x *= scale;
            }
            if trial % 3 == 0 {
                // all-zero blocks must round-trip too
                w.f32s_mut()[..block].fill(0.0);
            }
            let q = quantize(&w, block);
            // codes always index the 16-entry codebook
            assert!(q.codes.i32s().iter().all(|&c| (0..16).contains(&c)));
            // absmax is exactly the blockwise max |w|
            let src = w.f32s();
            for i in 0..m {
                for b in 0..nb {
                    let blk = &src[i * n + b * block..i * n + (b + 1) * block];
                    let want = blk.iter().fold(0f32, |a, &x| a.max(x.abs()));
                    assert_eq!(q.absmax.f32s()[i * nb + b], want, "trial {trial}");
                }
            }
            // quantize∘dequantize is idempotent: requantising the
            // dequantised matrix reproduces codes and absmax exactly
            let wd = dequantize(&q);
            let q2 = quantize(&wd, block);
            assert_eq!(q.codes.i32s(), q2.codes.i32s(), "trial {trial}");
            assert_eq!(q.absmax.f32s(), q2.absmax.f32s(), "trial {trial}");
        }
    }

    #[test]
    fn storage_accounting() {
        // 13B params at block 64: 6.5 GB codes + 0.81 GB absmax
        let bytes = nf4_storage_bytes(13_015_864_320, 64);
        let gb = bytes as f64 / (1u64 << 30) as f64;
        assert!((gb - 6.8).abs() < 0.3, "gb={gb}");
        assert!((nf4_bits_per_param(64) - 4.5).abs() < 1e-9);
        assert!((nf4_bits_per_param(16) - 6.0).abs() < 1e-9);
    }

    #[test]
    fn quantize_projections_covers_pairs() {
        let mut params = TensorStore::new();
        params.insert("l0.wq", rand_mat(8, 32, 3));
        let names = vec!["l0.wq.codes".to_string(), "l0.wq.absmax".to_string()];
        let q = quantize_projections(&params, &names, 16).unwrap();
        assert!(q.contains("l0.wq.codes"));
        assert!(q.contains("l0.wq.absmax"));
        assert_eq!(q.get("l0.wq.absmax").unwrap().shape, vec![8, 2]);
    }
}

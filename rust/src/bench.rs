//! Hand-rolled bench harness (no criterion in the vendor set).
//!
//! `cargo bench` drives `rust/benches/bench_main.rs`, which uses this
//! module: warmup, timed iterations, mean/p50/p99 reporting, and a simple
//! `--filter` facility.

use crate::util::stats;
use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub throughput: Option<(f64, &'static str)>,
}

impl BenchResult {
    pub fn report(&self) {
        let tp = self
            .throughput
            .map(|(v, unit)| format!("  {v:>10.2} {unit}"))
            .unwrap_or_default();
        println!(
            "{:<44} {:>6} it  mean {:>9.3} ms  p50 {:>9.3} ms  p99 {:>9.3} ms{}",
            self.name, self.iters, self.mean_ms, self.p50_ms, self.p99_ms, tp
        );
    }
}

/// Run `f` with warmup, then time `iters` iterations.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    // one sort for both percentiles (stats::percentile re-sorts per call)
    let pcts = stats::percentiles_of(&samples, &[50.0, 99.0]);
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ms: stats::mean(&samples),
        p50_ms: pcts[0],
        p99_ms: pcts[1],
        throughput: None,
    }
}

/// Like `bench`, attaching an items/sec throughput derived from the mean.
pub fn bench_throughput(
    name: &str,
    warmup: usize,
    iters: usize,
    items_per_iter: f64,
    unit: &'static str,
    f: impl FnMut(),
) -> BenchResult {
    let mut r = bench(name, warmup, iters, f);
    r.throughput = Some((items_per_iter / (r.mean_ms / 1e3), unit));
    r
}

/// Peak RSS of this process in MiB (Linux), for Table 8's memory column.
pub fn peak_rss_mib() -> f64 {
    if let Ok(status) = std::fs::read_to_string("/proc/self/status") {
        for line in status.lines() {
            if let Some(rest) = line.strip_prefix("VmHWM:") {
                let kb: f64 = rest
                    .trim()
                    .trim_end_matches("kB")
                    .trim()
                    .parse()
                    .unwrap_or(0.0);
                return kb / 1024.0;
            }
        }
    }
    0.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 1, 5, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert_eq!(r.iters, 5);
        assert!(r.mean_ms >= 0.0);
        assert!(r.p99_ms >= r.p50_ms);
    }

    #[test]
    fn rss_is_positive() {
        assert!(peak_rss_mib() > 1.0);
    }
}

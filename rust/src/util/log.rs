//! Lightweight logging + CSV result writers.

use std::io::Write;
use std::path::Path;
use std::time::{SystemTime, UNIX_EPOCH};

static VERBOSE: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(true);

pub fn set_verbose(v: bool) {
    VERBOSE.store(v, std::sync::atomic::Ordering::Relaxed);
}

pub fn info(msg: impl AsRef<str>) {
    if VERBOSE.load(std::sync::atomic::Ordering::Relaxed) {
        let t = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .unwrap_or_default()
            .as_secs_f64();
        eprintln!("[{:>12.3}] {}", t % 100_000.0, msg.as_ref());
    }
}

/// Warnings print even under `--quiet`: they flag silent-degradation
/// hazards (e.g. a decode artifact pair with one half missing).
pub fn warn(msg: impl AsRef<str>) {
    let t = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default()
        .as_secs_f64();
    eprintln!("[{:>12.3}] WARN {}", t % 100_000.0, msg.as_ref());
}

/// Incrementally written CSV file (header + rows), used by every experiment
/// to emit the data behind a paper table/figure.
pub struct Csv {
    w: std::io::BufWriter<std::fs::File>,
    cols: usize,
}

impl Csv {
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> std::io::Result<Csv> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let f = std::fs::File::create(path)?;
        let mut w = std::io::BufWriter::new(f);
        writeln!(w, "{}", header.join(","))?;
        Ok(Csv { w, cols: header.len() })
    }

    pub fn row(&mut self, values: &[String]) -> std::io::Result<()> {
        assert_eq!(values.len(), self.cols, "csv row arity mismatch");
        writeln!(self.w, "{}", values.join(","))?;
        self.w.flush()
    }

    pub fn rowf(&mut self, values: &[f64]) -> std::io::Result<()> {
        self.row(&values.iter().map(|v| format!("{v}")).collect::<Vec<_>>())
    }
}

/// `fmt_row!` helper: stringify heterogenous cells.
#[macro_export]
macro_rules! csv_row {
    ($($v:expr),* $(,)?) => {
        vec![$(format!("{}", $v)),*]
    };
}

//! Lightweight logging + CSV result writers.
//!
//! Level filtering: the `LORAM_LOG` env var (`error|warn|info|debug`)
//! sets the threshold once at first use; `--quiet` / [`set_verbose`]
//! lower it to `warn` when no env override is present. While a trace
//! sink is installed (`obs::trace`), log lines are stamped with the
//! current scheduler tick instead of wall time, so a log line lands next
//! to its trace events on the same deterministic clock.

use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    /// Parse a `LORAM_LOG` value; unknown strings get `None` (caller
    /// keeps its default rather than silently going quiet).
    pub fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }
}

/// Current threshold; `UNSET` defers to `LORAM_LOG` (or `Info`) on first
/// use so env filtering needs no init call.
const UNSET: u8 = u8::MAX;
static LEVEL: AtomicU8 = AtomicU8::new(UNSET);

fn threshold() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw != UNSET {
        return match raw {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            _ => Level::Debug,
        };
    }
    let lvl = std::env::var("LORAM_LOG")
        .ok()
        .as_deref()
        .and_then(Level::parse)
        .unwrap_or(Level::Info);
    LEVEL.store(lvl as u8, Ordering::Relaxed);
    lvl
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Legacy verbosity toggle (`--quiet`): drops the threshold to `Warn`
/// (or back to `Info`) unless a `LORAM_LOG` env override is set — the
/// env var is the operator's explicit word and wins.
pub fn set_verbose(v: bool) {
    if std::env::var("LORAM_LOG").ok().as_deref().and_then(Level::parse).is_some() {
        let _ = threshold(); // make sure the env value is latched
        return;
    }
    set_level(if v { Level::Info } else { Level::Warn });
}

pub fn enabled(l: Level) -> bool {
    l <= threshold()
}

/// Timestamp prefix: the scheduler tick while a trace sink is active
/// (deterministic, correlates with trace events), wall seconds otherwise.
fn stamp() -> String {
    if crate::obs::trace::active() {
        format!("[tick {:>7}]", crate::obs::trace::tick())
    } else {
        let t = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .unwrap_or_default()
            .as_secs_f64();
        format!("[{:>12.3}]", t % 100_000.0)
    }
}

fn line(tag: &str, msg: &str) {
    eprintln!("{} {}{}", stamp(), tag, msg);
}

pub fn error(msg: impl AsRef<str>) {
    if enabled(Level::Error) {
        line("ERROR ", msg.as_ref());
    }
}

/// Warnings print even under `--quiet`: they flag silent-degradation
/// hazards (e.g. a decode artifact pair with one half missing). Only an
/// explicit `LORAM_LOG=error` silences them.
pub fn warn(msg: impl AsRef<str>) {
    if enabled(Level::Warn) {
        line("WARN ", msg.as_ref());
    }
}

pub fn info(msg: impl AsRef<str>) {
    if enabled(Level::Info) {
        line("", msg.as_ref());
    }
}

pub fn debug(msg: impl AsRef<str>) {
    if enabled(Level::Debug) {
        line("DEBUG ", msg.as_ref());
    }
}

/// Incrementally written CSV file (header + rows), used by every experiment
/// to emit the data behind a paper table/figure.
pub struct Csv {
    w: std::io::BufWriter<std::fs::File>,
    cols: usize,
}

impl Csv {
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> std::io::Result<Csv> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let f = std::fs::File::create(path)?;
        let mut w = std::io::BufWriter::new(f);
        writeln!(w, "{}", header.join(","))?;
        Ok(Csv { w, cols: header.len() })
    }

    pub fn row(&mut self, values: &[String]) -> std::io::Result<()> {
        assert_eq!(values.len(), self.cols, "csv row arity mismatch");
        writeln!(self.w, "{}", values.join(","))?;
        self.w.flush()
    }

    pub fn rowf(&mut self, values: &[f64]) -> std::io::Result<()> {
        self.row(&values.iter().map(|v| format!("{v}")).collect::<Vec<_>>())
    }
}

/// `fmt_row!` helper: stringify heterogenous cells.
#[macro_export]
macro_rules! csv_row {
    ($($v:expr),* $(,)?) => {
        vec![$(format!("{}", $v)),*]
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing_accepts_the_documented_values() {
        assert_eq!(Level::parse("error"), Some(Level::Error));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse(" warning "), Some(Level::Warn));
        assert_eq!(Level::parse("Info"), Some(Level::Info));
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("trace"), None);
        assert_eq!(Level::parse(""), None);
    }

    #[test]
    fn levels_order_from_error_to_debug() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn threshold_gates_enabled() {
        // process-global: restore when done so parallel log output from
        // other tests is unaffected (enabled() is the only reader)
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Debug);
        assert!(enabled(Level::Debug));
        set_level(Level::Info);
    }

    #[test]
    fn stamp_uses_tick_clock_while_tracing() {
        crate::obs::trace::install(8, false);
        crate::obs::trace::set_tick(42);
        let s = stamp();
        assert!(s.contains("tick"), "{s}");
        assert!(s.contains("42"), "{s}");
        crate::obs::trace::take();
        assert!(!stamp().contains("tick"));
    }
}

//! Small statistics helpers shared by benches, evaluators and experiments.

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile via linear interpolation on a sorted copy; p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Unbiased pass@k estimator (Chen et al. 2021): 1 - C(n-c, k)/C(n, k).
pub fn pass_at_k(n: usize, c: usize, k: usize) -> f64 {
    if n < k || c == 0 {
        return if c > 0 { 1.0 } else { 0.0 };
    }
    if n - c < k {
        return 1.0;
    }
    // product form avoids overflow
    let mut prod = 1.0f64;
    for i in 0..k {
        prod *= (n - c - i) as f64 / (n - i) as f64;
    }
    1.0 - prod
}

/// Standard error of a proportion (used for Table 2's mean ± std columns).
pub fn proportion_se(p: f64, n: usize) -> f64 {
    if n == 0 {
        return 0.0;
    }
    (p * (1.0 - p) / n as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - 1.2909944487358056).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn pass_at_k_edges() {
        assert_eq!(pass_at_k(10, 0, 1), 0.0);
        assert_eq!(pass_at_k(10, 10, 1), 1.0);
        // n=2, c=1, k=1 -> 0.5
        assert!((pass_at_k(2, 1, 1) - 0.5).abs() < 1e-12);
        // n=4, c=2, k=2 -> 1 - C(2,2)/C(4,2) = 1 - 1/6
        assert!((pass_at_k(4, 2, 2) - (1.0 - 1.0 / 6.0)).abs() < 1e-12);
    }
}

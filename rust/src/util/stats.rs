//! Small statistics helpers shared by benches, evaluators and experiments.

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile via linear interpolation on a sorted copy; p in [0, 100].
///
/// NaN-safe: ordering is `f64::total_cmp` (NaNs sort above every number
/// instead of panicking mid-sort), so a poisoned sample degrades a high
/// percentile rather than aborting a bench run. Callers holding
/// already-sorted data should use [`percentile_sorted`]; callers needing
/// several percentiles of one sample should use [`percentiles_of`] —
/// both skip the per-call copy + sort this function pays.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    percentile_sorted(&v, p)
}

/// Sorted-input fast path of [`percentile`]: no copy, no sort. `xs` must
/// be ascending (debug-asserted); the interpolation is bit-identical to
/// [`percentile`] — `rank = (p/100)·(n-1)`, lerp between the straddling
/// samples — which is what lets `tools/trace_report.py` reproduce the
/// exported percentiles exactly.
pub fn percentile_sorted(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    debug_assert!(
        xs.windows(2).all(|w| w[0].total_cmp(&w[1]) != std::cmp::Ordering::Greater),
        "percentile_sorted needs ascending input"
    );
    let rank = (p / 100.0) * (xs.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        xs[lo]
    } else {
        xs[lo] + (rank - lo as f64) * (xs[hi] - xs[lo])
    }
}

/// Batch percentiles: one sort amortized over every requested `p` (the
/// stats-export paths all want p50+p95 or p50+p99 of the same sample).
pub fn percentiles_of(xs: &[f64], ps: &[f64]) -> Vec<f64> {
    if xs.is_empty() {
        return vec![0.0; ps.len()];
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    ps.iter().map(|&p| percentile_sorted(&v, p)).collect()
}

/// Batch percentiles of a tick-count distribution — the serving TTFT/ITL
/// helper (one f64 conversion + one sort for all `ps`). Empty input pins
/// every percentile to 0.0; the interpolation is [`percentile_sorted`]'s
/// `rank = (p/100)·(n-1)` lerp, bit-identical to what
/// `tools/trace_report.py` recomputes from exported traces.
pub fn tick_percentiles(xs: &[usize], ps: &[f64]) -> Vec<f64> {
    if xs.is_empty() {
        return vec![0.0; ps.len()];
    }
    let v: Vec<f64> = xs.iter().map(|&x| x as f64).collect();
    percentiles_of(&v, ps)
}

/// Unbiased pass@k estimator (Chen et al. 2021): 1 - C(n-c, k)/C(n, k).
pub fn pass_at_k(n: usize, c: usize, k: usize) -> f64 {
    if n < k || c == 0 {
        return if c > 0 { 1.0 } else { 0.0 };
    }
    if n - c < k {
        return 1.0;
    }
    // product form avoids overflow
    let mut prod = 1.0f64;
    for i in 0..k {
        prod *= (n - c - i) as f64 / (n - i) as f64;
    }
    1.0 - prod
}

/// Standard error of a proportion (used for Table 2's mean ± std columns).
pub fn proportion_se(p: f64, n: usize) -> f64 {
    if n == 0 {
        return 0.0;
    }
    (p * (1.0 - p) / n as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - 1.2909944487358056).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn sorted_fast_path_matches_general_percentile() {
        let xs = [9.0, 1.0, 5.0, 3.0, 7.0, 2.0];
        let mut sorted = xs.to_vec();
        sorted.sort_by(f64::total_cmp);
        for p in [0.0, 10.0, 25.0, 50.0, 90.0, 95.0, 100.0] {
            assert_eq!(percentile(&xs, p), percentile_sorted(&sorted, p));
        }
        assert_eq!(
            percentiles_of(&xs, &[50.0, 95.0]),
            vec![percentile(&xs, 50.0), percentile(&xs, 95.0)]
        );
    }

    #[test]
    fn nan_ordering_degrades_instead_of_panicking() {
        let xs = [2.0, f64::NAN, 1.0, 3.0];
        // total_cmp sorts the NaN last: low percentiles stay numeric
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
        assert!(percentile(&xs, 100.0).is_nan());
        let ps = percentiles_of(&xs, &[0.0, 100.0]);
        assert_eq!(ps[0], 1.0);
        assert!(ps[1].is_nan());
    }

    #[test]
    fn empty_batch_percentiles_are_zero() {
        assert_eq!(percentiles_of(&[], &[50.0, 95.0]), vec![0.0, 0.0]);
        assert_eq!(percentile_sorted(&[], 50.0), 0.0);
    }

    /// Spot values pinning the tick-percentile lerp to the exact numbers
    /// `python/tests/test_trace_report.py` parametrizes over — the two
    /// implementations must stay bit-identical (ISSUE 9 satellite).
    #[test]
    fn tick_percentiles_spot_values_match_trace_report() {
        assert_eq!(
            tick_percentiles(&[1, 2, 3, 4, 5], &[0.0, 25.0, 50.0, 100.0]),
            vec![1.0, 2.0, 3.0, 5.0]
        );
        assert_eq!(tick_percentiles(&[1, 2], &[50.0]), vec![1.5]);
        // unsorted input: the helper sorts, rank (50/100)·3 = 1.5 → 2.5
        assert_eq!(tick_percentiles(&[4, 3, 2, 1], &[50.0]), vec![2.5]);
        assert_eq!(tick_percentiles(&[10], &[0.0, 95.0]), vec![10.0, 10.0]);
        assert_eq!(tick_percentiles(&[], &[50.0, 95.0]), vec![0.0, 0.0]);
    }

    #[test]
    fn pass_at_k_edges() {
        assert_eq!(pass_at_k(10, 0, 1), 0.0);
        assert_eq!(pass_at_k(10, 10, 1), 1.0);
        // n=2, c=1, k=1 -> 0.5
        assert!((pass_at_k(2, 1, 1) - 0.5).abs() < 1e-12);
        // n=4, c=2, k=2 -> 1 - C(2,2)/C(4,2) = 1 - 1/6
        assert!((pass_at_k(4, 2, 2) - (1.0 - 1.0 / 6.0)).abs() < 1e-12);
    }
}

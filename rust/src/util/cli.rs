//! Hand-rolled CLI argument parser (no clap in the vendor set).
//!
//! Grammar: `loram <subcommand> [--flag] [--key value] [positional...]`.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                out.subcommand = it.next().unwrap().clone();
            }
        }
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                // --key=value or --key value or bare flag
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.options
                        .insert(key.to_string(), it.next().unwrap().clone());
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
        }
        out
    }

    pub fn from_env() -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{key} expects an integer")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{key} expects a number")))
            .unwrap_or(default)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(&s.split_whitespace().map(String::from).collect::<Vec<_>>())
    }

    #[test]
    fn subcommand_and_options() {
        // note: a bare word after `--flag` binds as its value (the grammar
        // has no flag registry) — positionals go before flags.
        let a = parse("train data.bin --steps 100 --lr 1e-3 --quiet");
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.get_usize("steps", 0), 100);
        assert_eq!(a.get_f64("lr", 0.0), 1e-3);
        assert!(a.has_flag("quiet"));
        assert_eq!(a.positional, vec!["data.bin"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("repro --exp=fig7 --scale=smoke");
        assert_eq!(a.get("exp"), Some("fig7"));
        assert_eq!(a.get("scale"), Some("smoke"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse("bench --verbose");
        assert!(a.has_flag("verbose"));
        assert!(a.get("verbose").is_none());
    }
}

//! Hand-rolled substrates (the vendor set has no serde/clap/rand/criterion).

pub mod cli;
pub mod json;
pub mod log;
pub mod rng;
pub mod stats;

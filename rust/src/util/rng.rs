//! Deterministic PRNG (PCG64-DXSM family) — the vendor set has no `rand`.
//!
//! Used for parameter init, synthetic data generation, pruning decisions
//! and sampling. Everything in the repo is seeded, so experiments are
//! bit-reproducible.

#[derive(Clone, Debug)]
pub struct Rng {
    state: u128,
    inc: u128,
}

const MUL: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut r = Rng {
            state: 0,
            inc: ((seed as u128) << 1) | 1,
        };
        r.next_u64();
        r.state = r.state.wrapping_add(0x9e3779b97f4a7c15u64 as u128 ^ (seed as u128));
        r.next_u64();
        r
    }

    /// Derive an independent stream (e.g. per data split / per worker).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xd1342543de82ef95))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MUL).wrapping_add(self.inc);
        // DXSM output permutation
        let mut hi = (self.state >> 64) as u64;
        let lo = (self.state as u64) | 1;
        hi ^= hi >> 32;
        hi = hi.wrapping_mul(0xda942042e4dd58b5);
        hi ^= hi >> 48;
        hi.wrapping_mul(lo)
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // Lemire's nearly-divisionless method on 64 bits.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.below((hi - lo) as usize) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = (self.f64().max(1e-300)) as f64;
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Sample an index from unnormalised weights.
    pub fn weighted(&mut self, ws: &[f32]) -> usize {
        let total: f32 = ws.iter().sum();
        let mut t = self.f32() * total;
        for (i, w) in ws.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        ws.len() - 1
    }

    /// Sample k distinct indices from [0, n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    /// Cross-language contract: `tools/workload_gen.py::Rng` pins these
    /// exact values (python/tests/test_slo_sched.py), so the adversarial
    /// workload streams are bit-identical on both sides.
    #[test]
    fn matches_the_python_mirror_golden_values() {
        let mut r = Rng::new(7);
        assert_eq!(r.next_u64(), 11819415725983595385);
        assert_eq!(r.next_u64(), 5343028139622295922);
        assert_eq!(r.next_u64(), 12185485406386585458);
        assert_eq!(r.next_u64(), 10788631124621038257);
        let mut r = Rng::new(0);
        assert_eq!(r.next_u64(), 546717224284700557);
        assert_eq!(r.next_u64(), 9027004767291937668);
        let mut r = Rng::new(9);
        let draws: Vec<usize> = (0..6).map(|_| r.below(8)).collect();
        assert_eq!(draws, vec![1, 0, 6, 7, 1, 1]);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
            sum += x as f64;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let xs: Vec<f32> = (0..20_000).map(|_| r.normal()).collect();
        let mean: f64 = xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64;
        let var: f64 =
            xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(8);
        let ws = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..5000 {
            counts[r.weighted(&ws)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }
}

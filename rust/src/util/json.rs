//! Minimal JSON parser/writer.
//!
//! The vendor set has no serde, so this hand-rolled implementation is the
//! substrate for artifact metadata (`*.meta.json`), experiment configs and
//! result files. It supports the full JSON grammar minus exotic number
//! forms; numbers are held as f64 (sufficient: metadata integers are tensor
//! dims well under 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- builders ----------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if pretty {
                            out.push(' ');
                        }
                    }
                    v.write(out, indent, pretty);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        for _ in 0..indent + 1 {
                            out.push(' ');
                        }
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    for _ in 0..indent {
                        out.push(' ');
                    }
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {}", start))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5]).unwrap();
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            // BMP only (sufficient for metadata); surrogate
                            // pairs map to replacement char.
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 sequence
                    let s = &self.b[self.i..];
                    let len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..len.min(s.len())])
                        .map_err(|_| "bad utf8".to_string())?;
                    out.push_str(chunk);
                    self.i += chunk.len();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = vec![];
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b < 0xe0 {
        2
    } else if b < 0xf0 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "hi\nthere", "c": null, "d": true}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(v.get("b").unwrap().as_str(), Some("hi\nthere"));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""café""#).unwrap();
        assert_eq!(v.as_str(), Some("café"));
    }

    #[test]
    fn nested_roundtrip() {
        let v = Json::obj(vec![
            ("xs", Json::arr_f64(&[1.0, 2.0])),
            ("o", Json::obj(vec![("k", Json::str("v"))])),
        ]);
        let re = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(2.5).to_string(), "2.5");
    }
}

//! Pruning P(·) and recovery R(·) — the core LoRAM mechanics (paper §2.2).
//!
//! Four strategies, mirroring the paper's variants:
//! * `rand` — randomly structured (LoRAM-Rand)
//! * `stru` — gradient-importance structured, LLM-Pruner-style (LoRAM-Stru)
//! * `semi` — 4:8 semi-structured magnitude (LoRAM-Semi / SparseGPT stand-in)
//! * `unst` — unstructured magnitude (LoRAM-Unst / SparseGPT stand-in)
//!
//! Structured pruning physically slices head/FF-channel groups out of the
//! weight matrices (deployment note C1); non-structured pruning keeps shapes
//! and produces {0,1} masks (C1/C2). `recover_lora` implements R(·):
//! scattering the trained pruned-shape LoRA factors back into full-shape
//! zeros, so the recovered update `a_R @ b_R` has support exactly on the
//! coordinates that were retained during training (Eq. 5/6 — note the
//! paper's mask algebra in Eq. 5 is notationally inverted w.r.t. Eq. 3; we
//! implement the operative semantics described in §1 and App. C: "recovers
//! the shape ... by filling zeros at pruned positions").

use crate::runtime::ModelCfg;
use crate::tensor::{Tensor, TensorStore};
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};

/// Per-layer kept indices (sorted ascending) for structured pruning.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerKept {
    pub heads: Vec<usize>,
    pub kv_heads: Vec<usize>,
    pub ff: Vec<usize>,
}

/// A structured pruning plan: which heads / kv-heads / FF channels survive
/// in every layer. Counts must match the pruned config's `layer_plan`.
#[derive(Debug, Clone, PartialEq)]
pub struct StructuredPlan {
    pub layers: Vec<LayerKept>,
}

impl StructuredPlan {
    /// LoRAM-Rand: random kept sets with the counts demanded by `pruned`.
    pub fn random(full: &ModelCfg, pruned: &ModelCfg, seed: u64) -> Result<StructuredPlan> {
        Self::build(full, pruned, |rng, n, k, _scores| {
            let mut idx = rng.sample_indices(n, k);
            idx.sort_unstable();
            idx
        }, None, seed)
    }

    /// LoRAM-Stru: keep the *most important* units per layer, importance
    /// from the `gradimp` artifact (Σ|w·∂w| per head / channel).
    pub fn from_importance(
        full: &ModelCfg,
        pruned: &ModelCfg,
        head_imp: &Tensor, // (L, n_heads)
        ff_imp: &Tensor,   // (L, d_ff)
    ) -> Result<StructuredPlan> {
        let scores = Some((head_imp, ff_imp));
        Self::build(full, pruned, |_rng, n, k, scores| top_k_sorted(scores.unwrap(), n, k),
                    scores, 0)
    }

    fn build(
        full: &ModelCfg,
        pruned: &ModelCfg,
        pick: impl Fn(&mut Rng, usize, usize, Option<&[f32]>) -> Vec<usize>,
        scores: Option<(&Tensor, &Tensor)>,
        seed: u64,
    ) -> Result<StructuredPlan> {
        if full.n_layers != pruned.n_layers {
            bail!("layer count mismatch");
        }
        let mut rng = Rng::new(seed);
        let rep = full.n_heads / full.n_kv_heads;
        let mut layers = Vec::with_capacity(full.n_layers);
        for i in 0..full.n_layers {
            let (h_k, kv_k, ff_k) = pruned.layer_shapes(i);
            let (h_f, kv_f, ff_f) = full.layer_shapes(i);
            if h_k == h_f && kv_k == kv_f && ff_k == ff_f {
                layers.push(LayerKept {
                    heads: (0..h_f).collect(),
                    kv_heads: (0..kv_f).collect(),
                    ff: (0..ff_f).collect(),
                });
                continue;
            }
            let (hs, fs) = match scores {
                Some((hi, fi)) => {
                    let hrow = &hi.f32s()[i * h_f..(i + 1) * h_f];
                    let frow = &fi.f32s()[i * ff_f..(i + 1) * ff_f];
                    (Some(hrow.to_vec()), Some(frow.to_vec()))
                }
                None => (None, None),
            };
            let heads = pick(&mut rng, h_f, h_k, hs.as_deref());
            // kv heads: keep the groups that own the most kept q-heads
            // (grouped-query attention); for MHA (kv == heads) reuse the set.
            let kv_heads = if kv_f == h_f {
                heads.clone()
            } else {
                let mut votes = vec![0f32; kv_f];
                for &h in &heads {
                    votes[h / rep] += 1.0;
                }
                top_k_sorted(&votes, kv_f, kv_k)
            };
            let ff = pick(&mut rng, ff_f, ff_k, fs.as_deref());
            layers.push(LayerKept { heads, kv_heads, ff });
        }
        Ok(StructuredPlan { layers })
    }

    /// Serialise as a TensorStore (saved as a `.lmck` sidecar).
    pub fn to_store(&self) -> TensorStore {
        let mut s = TensorStore::new();
        for (i, l) in self.layers.iter().enumerate() {
            s.insert(
                format!("l{i}.heads"),
                Tensor::from_i32(&[l.heads.len()], l.heads.iter().map(|&x| x as i32).collect()),
            );
            s.insert(
                format!("l{i}.kv_heads"),
                Tensor::from_i32(
                    &[l.kv_heads.len()],
                    l.kv_heads.iter().map(|&x| x as i32).collect(),
                ),
            );
            s.insert(
                format!("l{i}.ff"),
                Tensor::from_i32(&[l.ff.len()], l.ff.iter().map(|&x| x as i32).collect()),
            );
        }
        s
    }

    pub fn from_store(s: &TensorStore, n_layers: usize) -> Result<StructuredPlan> {
        let mut layers = Vec::with_capacity(n_layers);
        for i in 0..n_layers {
            let g = |k: &str| -> Result<Vec<usize>> {
                Ok(s.get(&format!("l{i}.{k}"))?
                    .i32s()
                    .iter()
                    .map(|&x| x as usize)
                    .collect())
            };
            layers.push(LayerKept {
                heads: g("heads")?,
                kv_heads: g("kv_heads")?,
                ff: g("ff")?,
            });
        }
        Ok(StructuredPlan { layers })
    }
}

fn top_k_sorted(scores: &[f32], n: usize, k: usize) -> Vec<usize> {
    assert!(scores.len() >= n);
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    let mut kept: Vec<usize> = idx.into_iter().take(k).collect();
    kept.sort_unstable();
    kept
}

fn expand_groups(idx: &[usize], group: usize) -> Vec<usize> {
    let mut out = Vec::with_capacity(idx.len() * group);
    for &i in idx {
        out.extend(i * group..(i + 1) * group);
    }
    out
}

// ---------------------------------------------------------------------------
// Structured pruning: weight slicing + LoRA recovery
// ---------------------------------------------------------------------------

/// P(·) for structured pruning: slice full-model weights down to the pruned
/// config's shapes following `plan` (deployment note C1: compact & dense).
pub fn slice_params(
    full_params: &TensorStore,
    full: &ModelCfg,
    plan: &StructuredPlan,
) -> Result<TensorStore> {
    let hd = full.head_dim();
    let mut out = TensorStore::new();
    for (name, t) in &full_params.map {
        let parts: Vec<&str> = name.splitn(2, '.').collect();
        let sliced = if parts.len() == 2 && parts[0].starts_with('l') {
            let li: usize = parts[0][1..].parse().unwrap_or(usize::MAX);
            if li == usize::MAX {
                t.clone()
            } else {
                let kept = &plan.layers[li];
                match parts[1] {
                    "wq" => t.select_cols(&expand_groups(&kept.heads, hd)),
                    "wk" | "wv" => t.select_cols(&expand_groups(&kept.kv_heads, hd)),
                    "wo" => t.select_rows(&expand_groups(&kept.heads, hd)),
                    "w_gate" | "w_up" => t.select_cols(&kept.ff),
                    "w_down" => t.select_rows(&kept.ff),
                    _ => t.clone(), // norms
                }
            }
        } else {
            t.clone() // embed, final_norm, lm_head
        };
        out.insert(name.clone(), sliced);
    }
    Ok(out)
}

/// R(·): scatter pruned-shape LoRA factors into full shapes (Eq. 5/6).
/// The recovered update `a_R @ b_R` is zero at pruned coordinates and
/// exactly the trained update at retained coordinates.
pub fn recover_lora(
    pruned_lora: &TensorStore,
    full: &ModelCfg,
    plan: &StructuredPlan,
) -> Result<TensorStore> {
    let hd = full.head_dim();
    let mut out = TensorStore::new();
    for (name, t) in &pruned_lora.map {
        // names look like "l{i}.{proj}.lora_a" or "lm_head.lora_a"
        let parts: Vec<&str> = name.split('.').collect();
        let recovered = if parts.len() == 3 && parts[0].starts_with('l') {
            let li: usize = parts[0][1..]
                .parse()
                .with_context(|| format!("bad lora name {name}"))?;
            let kept = &plan.layers[li];
            let d = full.d_model;
            let (h_f, _kv_f, ff_f) = full.layer_shapes(li);
            match (parts[1], parts[2]) {
                ("wq", "lora_b") => {
                    t.scatter_cols(&expand_groups(&kept.heads, hd), h_f * hd)
                }
                ("wk", "lora_b") | ("wv", "lora_b") => t.scatter_cols(
                    &expand_groups(&kept.kv_heads, hd),
                    full.layer_shapes(li).1 * hd,
                ),
                ("wo", "lora_a") => {
                    t.scatter_rows(&expand_groups(&kept.heads, hd), h_f * hd)
                }
                ("w_gate", "lora_b") | ("w_up", "lora_b") => t.scatter_cols(&kept.ff, ff_f),
                ("w_down", "lora_a") => t.scatter_rows(&kept.ff, ff_f),
                // input side of d_model-input projections, output side of
                // d_model-output projections: d_model is never pruned
                _ => {
                    debug_assert!(t.shape.contains(&d) || t.shape.contains(&full.lora_rank));
                    t.clone()
                }
            }
        } else {
            t.clone() // lm_head.lora_{a,b}
        };
        out.insert(name.clone(), recovered);
    }
    // validate against full-config lora shapes
    for (name, shape) in full.lora_shapes() {
        let t = out
            .get(&name)
            .with_context(|| format!("recovered lora missing {name}"))?;
        if t.shape != shape {
            bail!("recovered {name}: shape {:?} != {:?}", t.shape, shape);
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Non-structured pruning: masks
// ---------------------------------------------------------------------------

/// 4:8 semi-structured mask: in every group of 8 consecutive entries along
/// the *input* (reduction) axis of a column, keep the 4 largest |w|.
pub fn semi_mask_4of8(w: &Tensor) -> Tensor {
    let (m, n) = w.dims2();
    let src = w.f32s();
    let mut mask = vec![0f32; m * n];
    for j in 0..n {
        let mut g = 0;
        while g < m {
            let hi = (g + 8).min(m);
            let mut idx: Vec<usize> = (g..hi).collect();
            idx.sort_by(|&a, &b| {
                src[b * n + j]
                    .abs()
                    .partial_cmp(&src[a * n + j].abs())
                    .unwrap()
            });
            for &i in idx.iter().take((hi - g + 1) / 2) {
                mask[i * n + j] = 1.0;
            }
            g = hi;
        }
    }
    Tensor::from_f32(&[m, n], mask)
}

/// Unstructured magnitude mask keeping the (1 - ratio) largest |w| entries
/// of the matrix (per-matrix threshold, uniform across layers — the paper's
/// LoRAM-Unst setup).
pub fn unstructured_mask(w: &Tensor, prune_ratio: f64) -> Tensor {
    let (m, n) = w.dims2();
    let src = w.f32s();
    let mut mags: Vec<f32> = src.iter().map(|x| x.abs()).collect();
    let keep = ((m * n) as f64 * (1.0 - prune_ratio)).round() as usize;
    let mask = if keep == 0 {
        vec![0f32; m * n]
    } else if keep >= m * n {
        vec![1f32; m * n]
    } else {
        let k = m * n - keep; // threshold = k-th smallest magnitude
        mags.select_nth_unstable_by(k - 1, |a, b| a.partial_cmp(b).unwrap());
        let thr = mags[k - 1];
        // strictly-greater survives; ties beyond the quota are dropped l->r
        let mut out = vec![0f32; m * n];
        let mut quota = keep;
        for (i, &x) in src.iter().enumerate() {
            if x.abs() > thr && quota > 0 {
                out[i] = 1.0;
                quota -= 1;
            }
        }
        // fill remaining quota with ties at the threshold
        if quota > 0 {
            for (i, &x) in src.iter().enumerate() {
                if quota == 0 {
                    break;
                }
                if out[i] == 0.0 && x.abs() >= thr {
                    out[i] = 1.0;
                    quota -= 1;
                }
            }
        }
        out
    };
    Tensor::from_f32(&[m, n], mask)
}

/// Build `<proj>.mask` entries for every layer projection, plus the masked
/// (zeros-at-pruned) weights. `strategy` is "semi" or "unst".
pub fn build_masks(
    params: &TensorStore,
    cfg: &ModelCfg,
    strategy: &str,
    prune_ratio: f64,
) -> Result<(TensorStore, TensorStore)> {
    let mut masks = TensorStore::new();
    let mut masked = params.clone();
    for i in 0..cfg.n_layers {
        for (k, _) in cfg.layer_proj_shapes(i) {
            let name = format!("l{i}.{k}");
            let w = params.get(&name)?;
            let mask = match strategy {
                "semi" => semi_mask_4of8(w),
                "unst" => unstructured_mask(w, prune_ratio),
                other => bail!("unknown mask strategy {other}"),
            };
            let mut wm = w.clone();
            for (x, m) in wm.f32s_mut().iter_mut().zip(mask.f32s()) {
                *x *= m;
            }
            masked.insert(name.clone(), wm);
            masks.insert(format!("{name}.mask"), mask);
        }
    }
    Ok((masks, masked))
}

/// Fraction of surviving weights in a mask set (for reduction-ratio rows).
pub fn mask_density(masks: &TensorStore) -> f64 {
    let (mut ones, mut total) = (0f64, 0f64);
    for t in masks.map.values() {
        ones += t.f32s().iter().map(|&x| x as f64).sum::<f64>();
        total += t.len() as f64;
    }
    if total == 0.0 {
        0.0
    } else {
        ones / total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::init_params;

    fn full_cfg() -> ModelCfg {
        ModelCfg {
            name: "full".into(),
            vocab_size: 64,
            d_model: 32,
            n_layers: 3,
            n_heads: 4,
            n_kv_heads: 2,
            d_ff: 48,
            max_seq: 32,
            lora_rank: 4,
            lora_alpha: 8.0,
            lora_lm_head: true,
            layer_plan: None,
        }
    }

    fn pruned_cfg() -> ModelCfg {
        let mut c = full_cfg();
        c.name = "pruned".into();
        // protect first and last layer, prune the middle one
        c.layer_plan = Some(vec![(4, 2, 48), (2, 1, 32), (4, 2, 48)]);
        c
    }

    #[test]
    fn random_plan_counts_match() {
        let plan = StructuredPlan::random(&full_cfg(), &pruned_cfg(), 1).unwrap();
        assert_eq!(plan.layers[0].heads.len(), 4);
        assert_eq!(plan.layers[1].heads.len(), 2);
        assert_eq!(plan.layers[1].kv_heads.len(), 1);
        assert_eq!(plan.layers[1].ff.len(), 32);
        // sorted & unique
        let h = &plan.layers[1].heads;
        assert!(h.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn importance_plan_keeps_top_units() {
        let full = full_cfg();
        let pruned = pruned_cfg();
        // layer 1 head importances: heads 1 and 3 dominate
        let mut hi = vec![0f32; 3 * 4];
        hi[4 + 1] = 10.0;
        hi[4 + 3] = 9.0;
        let mut fi = vec![0f32; 3 * 48];
        for c in 0..32 {
            fi[48 + c + 16] = (c + 1) as f32; // channels 16..48 important
        }
        let plan = StructuredPlan::from_importance(
            &full,
            &pruned,
            &Tensor::from_f32(&[3, 4], hi),
            &Tensor::from_f32(&[3, 48], fi),
        )
        .unwrap();
        assert_eq!(plan.layers[1].heads, vec![1, 3]);
        assert_eq!(plan.layers[1].ff, (16..48).collect::<Vec<_>>());
    }

    #[test]
    fn plan_store_roundtrip() {
        let plan = StructuredPlan::random(&full_cfg(), &pruned_cfg(), 2).unwrap();
        let s = plan.to_store();
        let back = StructuredPlan::from_store(&s, 3).unwrap();
        assert_eq!(plan, back);
    }

    #[test]
    fn slice_params_shapes_match_pruned_cfg() {
        let full = full_cfg();
        let pruned = pruned_cfg();
        let params = init_params(&full, 0);
        let plan = StructuredPlan::random(&full, &pruned, 3).unwrap();
        let sliced = slice_params(&params, &full, &plan).unwrap();
        for (name, shape) in pruned.param_shapes() {
            assert_eq!(sliced.get(&name).unwrap().shape, shape, "{name}");
        }
        // protected layer identical
        assert_eq!(sliced.get("l0.wq").unwrap(), params.get("l0.wq").unwrap());
    }

    #[test]
    fn recover_lora_scatter_roundtrip() {
        let full = full_cfg();
        let pruned = pruned_cfg();
        let plan = StructuredPlan::random(&full, &pruned, 4).unwrap();
        // trained pruned lora with recognisable values
        let mut lora = TensorStore::new();
        for (name, shape) in pruned.lora_shapes() {
            let n: usize = shape.iter().product();
            lora.insert(name, Tensor::from_f32(&shape, (0..n).map(|x| x as f32 + 1.0).collect()));
        }
        let rec = recover_lora(&lora, &full, &plan).unwrap();
        // wq.lora_b of the pruned middle layer scattered into full width
        let rb = rec.get("l1.wq.lora_b").unwrap();
        assert_eq!(rb.shape, vec![4, 4 * 8]);
        let hd = 8;
        let kept = &plan.layers[1].heads;
        let cols = expand_groups(kept, hd);
        // kept columns carry the trained values, others zero
        let src = lora.get("l1.wq.lora_b").unwrap();
        for r in 0..4 {
            for (sj, &fj) in cols.iter().enumerate() {
                assert_eq!(rb.f32s()[r * 32 + fj], src.f32s()[r * 16 + sj]);
            }
            let zero_cols: Vec<usize> = (0..32).filter(|c| !cols.contains(c)).collect();
            for &c in &zero_cols {
                assert_eq!(rb.f32s()[r * 32 + c], 0.0);
            }
        }
        // unpruned-side factors unchanged
        assert_eq!(rec.get("l1.wq.lora_a").unwrap(), lora.get("l1.wq.lora_a").unwrap());
        assert_eq!(rec.get("lm_head.lora_a").unwrap(), lora.get("lm_head.lora_a").unwrap());
    }

    #[test]
    fn semi_mask_is_exactly_half() {
        let mut rng = crate::util::rng::Rng::new(5);
        let w = Tensor::from_f32(&[16, 8], rng.normal_vec(128, 1.0));
        let m = semi_mask_4of8(&w);
        // every column: 8 of 16 survive, 4 per group of 8
        for j in 0..8 {
            for g in (0..16).step_by(8) {
                let cnt: f32 = (g..g + 8).map(|i| m.f32s()[i * 8 + j]).sum();
                assert_eq!(cnt, 4.0);
            }
        }
        // surviving entries are the largest in their group
        for j in 0..8 {
            let kept_min = (0..8)
                .filter(|&i| m.f32s()[i * 8 + j] == 1.0)
                .map(|i| w.f32s()[i * 8 + j].abs())
                .fold(f32::MAX, f32::min);
            let dropped_max = (0..8)
                .filter(|&i| m.f32s()[i * 8 + j] == 0.0)
                .map(|i| w.f32s()[i * 8 + j].abs())
                .fold(0.0, f32::max);
            assert!(kept_min >= dropped_max);
        }
    }

    #[test]
    fn unstructured_mask_ratio_exact() {
        let mut rng = crate::util::rng::Rng::new(6);
        let w = Tensor::from_f32(&[20, 50], rng.normal_vec(1000, 1.0));
        for ratio in [0.0, 0.25, 0.55, 0.9, 1.0] {
            let m = unstructured_mask(&w, ratio);
            let kept: f32 = m.f32s().iter().sum();
            let want = (1000.0 * (1.0 - ratio)).round();
            assert_eq!(kept as f64, want, "ratio {ratio}");
        }
    }

    #[test]
    fn build_masks_zeroes_weights() {
        let cfg = full_cfg();
        let params = init_params(&cfg, 1);
        let (masks, masked) = build_masks(&params, &cfg, "unst", 0.5).unwrap();
        let w = masked.get("l0.wq").unwrap();
        let m = masks.get("l0.wq.mask").unwrap();
        for (x, mk) in w.f32s().iter().zip(m.f32s()) {
            if *mk == 0.0 {
                assert_eq!(*x, 0.0);
            }
        }
        let d = mask_density(&masks);
        assert!((d - 0.5).abs() < 0.01, "density {d}");
    }
}

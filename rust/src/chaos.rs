//! Deterministic fault injection for the serving stack (DESIGN.md §2j).
//!
//! [`ChaosEngine`] wraps any [`DecodeEngine`] and injects faults from a
//! *pregenerated plan* — a pure function of `(scenario, ticks, seed)`
//! over the repo PCG64-DXSM [`Rng`] using integer draws only, exactly
//! like `workload::generate`. Mirroring the plan rather than the live
//! engine keeps the cross-language contract small: `tools/chaos_gen.py`
//! reproduces every schedule bit-for-bit, the golden-plan test below
//! pins the first draws of every scenario on both sides, and the
//! loramlint contract-mirror pins [`FAULT_KINDS`] and
//! [`CHAOS_SCENARIOS`] (names AND order) against the Python consts.
//!
//! The scheduler drives the plan through the [`DecodeEngine::begin_tick`]
//! hook: each tick, the wrapper arms at most one planned fault and fires
//! it at the matching surface —
//!
//! * `decode-transient` — `decode_step` errors once, classified
//!   [`FaultDomain::Row`]; the scheduler retries just that request
//! * `admit-fail` — the next `prefill_begin` this tick errors (the
//!   existing admission-rejection isolation absorbs it)
//! * `pool-exhaust` — `can_admit` refuses once (the request stays queued)
//! * `stuck-tick` — `decode_step` errors, classified
//!   [`FaultDomain::Engine`] (drives the health state machine)
//! * `device-lost` — latched permanently; every subsequent call fails,
//!   classified [`FaultDomain::Lost`] (drives `Failing`)
//!
//! A fault aimed at a tick the scheduler never decodes on, or at an
//! unoccupied row, is a harmless miss by design — the plan stays pure.

// Same hot-path policy as serve.rs (loramlint panic-surface mirror).
#![cfg_attr(
    not(test),
    deny(clippy::unwrap_used, clippy::expect_used, clippy::panic, clippy::unreachable)
)]
#![cfg_attr(not(test), warn(clippy::indexing_slicing))]

use crate::coordinator::adapters::AdapterId;
use crate::coordinator::generate::{PrefillTickOut, SampleCfg, StepOut};
use crate::coordinator::kvcache::{PagedStats, PrefillStats};
use crate::coordinator::speculative::SpecStats;
use crate::serve::{DecodeEngine, FaultDomain, FaultInfo};
use crate::util::rng::Rng;
use anyhow::{bail, ensure, Result};

/// Fault taxonomy — mirrored verbatim by `tools/chaos_gen.py` (the
/// loramlint `fault-kinds` contract pair). A plan entry's `kind_ix`
/// indexes this table.
pub const FAULT_KINDS: &[&str] = &[
    "decode-transient",
    "admit-fail",
    "pool-exhaust",
    "stuck-tick",
    "device-lost",
];

/// Scenario catalog — mirrored verbatim by `tools/chaos_gen.py` (the
/// loramlint `chaos-scenarios` contract pair).
pub const CHAOS_SCENARIOS: &[&str] = &[
    "fault-storm",
    "decode-flaky",
    "admit-flaky",
    "pool-squeeze",
    "stuck-stall",
    "device-loss",
];

/// One scheduled fault: the scheduler tick it arms on (pre-increment
/// clock, the value [`DecodeEngine::begin_tick`] receives), the
/// [`FAULT_KINDS`] index, and the target row for row-scoped kinds.
/// Rows are drawn in `[0, 8)` regardless of the wrapped engine's batch
/// size — an out-of-range or unoccupied target is a harmless miss.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlannedFault {
    pub tick: usize,
    pub kind_ix: usize,
    pub row: usize,
}

/// Generate the named scenario's fault schedule. Pure in
/// `(scenario, ticks, seed)`; entries are tick-ascending. Draw order per
/// arm is part of the cross-language contract (documented again in
/// `tools/chaos_gen.py`). Unknown names error listing the catalog.
pub fn generate(scenario: &str, ticks: usize, seed: u64) -> Result<Vec<PlannedFault>> {
    ensure!(ticks >= 1, "chaos plan needs ticks >= 1");
    let mut rng = Rng::new(seed);
    let mut plan = vec![];
    match scenario {
        // the A/B headline: ~1/3 of ticks fault, any transient kind
        // (device-lost excluded — the storm must be survivable).
        // Draws per tick: below(3) coin; on 0: below(4) kind, below(8) row.
        "fault-storm" => {
            for t in 0..ticks {
                if rng.below(3) == 0 {
                    let kind_ix = rng.below(4);
                    plan.push(PlannedFault { tick: t, kind_ix, row: rng.below(8) });
                }
            }
        }
        // Draws per tick: below(4) coin; on 0: below(8) row.
        "decode-flaky" => {
            for t in 0..ticks {
                if rng.below(4) == 0 {
                    plan.push(PlannedFault { tick: t, kind_ix: 0, row: rng.below(8) });
                }
            }
        }
        // Draws per tick: below(3) coin.
        "admit-flaky" => {
            for t in 0..ticks {
                if rng.below(3) == 0 {
                    plan.push(PlannedFault { tick: t, kind_ix: 1, row: 0 });
                }
            }
        }
        // Draws per tick: below(3) coin.
        "pool-squeeze" => {
            for t in 0..ticks {
                if rng.below(3) == 0 {
                    plan.push(PlannedFault { tick: t, kind_ix: 2, row: 0 });
                }
            }
        }
        // Draws per tick: below(6) coin.
        "stuck-stall" => {
            for t in 0..ticks {
                if rng.below(6) == 0 {
                    plan.push(PlannedFault { tick: t, kind_ix: 3, row: 0 });
                }
            }
        }
        // Single draw: below(ticks) loss tick.
        "device-loss" => {
            plan.push(PlannedFault { tick: rng.below(ticks), kind_ix: 4, row: 0 });
        }
        other => {
            bail!("unknown chaos scenario {other:?} (expected one of {CHAOS_SCENARIOS:?})")
        }
    }
    Ok(plan)
}

/// Fault-injecting wrapper engine. Deterministic: the same plan against
/// the same inner engine and workload produces the same fault sequence,
/// so chaos tests golden-pin their outcomes.
pub struct ChaosEngine<E> {
    inner: E,
    plan: Vec<PlannedFault>,
    /// next plan entry to consider (entries are tick-ascending)
    cursor: usize,
    /// the fault armed for the current tick, if any (at most one per
    /// tick by construction of every scenario)
    armed: Option<PlannedFault>,
    /// `device-lost` latched: permanent, survives every tick
    lost: bool,
    last: Option<FaultInfo>,
    /// faults actually fired at an engine surface (misses excluded)
    pub injected: usize,
}

impl<E: DecodeEngine> ChaosEngine<E> {
    /// Wrap `inner` with the named scenario's schedule.
    pub fn new(inner: E, scenario: &str, ticks: usize, seed: u64) -> Result<ChaosEngine<E>> {
        Ok(Self::from_plan(inner, generate(scenario, ticks, seed)?))
    }

    /// Wrap `inner` with an explicit schedule (tests pin exact faults).
    pub fn from_plan(inner: E, plan: Vec<PlannedFault>) -> ChaosEngine<E> {
        ChaosEngine { inner, plan, cursor: 0, armed: None, lost: false, last: None, injected: 0 }
    }

    pub fn inner(&self) -> &E {
        &self.inner
    }

    /// Plan entries not yet armed (diagnostics; misses stay consumed).
    pub fn remaining(&self) -> usize {
        self.plan.len().saturating_sub(self.cursor)
    }

    fn armed_kind(&self, kind_ix: usize) -> Option<PlannedFault> {
        self.armed.filter(|f| f.kind_ix == kind_ix)
    }
}

impl<E: DecodeEngine> DecodeEngine for ChaosEngine<E> {
    fn batch_size(&self) -> usize {
        self.inner.batch_size()
    }

    fn free_rows(&self) -> usize {
        self.inner.free_rows()
    }

    fn begin_tick(&mut self, tick: u64) {
        self.inner.begin_tick(tick);
        // a fault armed for an earlier tick that never hit its surface is
        // a miss — drop it so it cannot fire on the wrong tick
        if self.armed.map_or(false, |f| (f.tick as u64) < tick) {
            self.armed = None;
        }
        while let Some(&f) = self.plan.get(self.cursor) {
            if (f.tick as u64) > tick {
                break;
            }
            self.cursor += 1;
            if f.kind_ix == 4 {
                // device loss latches even when its exact tick was never
                // decoded on — the device does not come back
                self.lost = true;
            } else if (f.tick as u64) == tick {
                self.armed = Some(f);
            }
        }
    }

    fn prefill(
        &mut self,
        prompt: &str,
        cfg: SampleCfg,
        adapter: Option<AdapterId>,
    ) -> Result<usize> {
        if self.lost {
            self.last = Some(FaultInfo { domain: FaultDomain::Lost, kind: "device-lost" });
            bail!("chaos: device lost");
        }
        if self.armed_kind(1).is_some() {
            self.armed = None;
            self.injected += 1;
            bail!("chaos: admission fault");
        }
        self.inner.prefill(prompt, cfg, adapter)
    }

    fn prefill_begin(
        &mut self,
        prompt: &str,
        cfg: SampleCfg,
        adapter: Option<AdapterId>,
        defer: bool,
    ) -> Result<(usize, bool)> {
        if self.lost {
            self.last = Some(FaultInfo { domain: FaultDomain::Lost, kind: "device-lost" });
            bail!("chaos: device lost");
        }
        if self.armed_kind(1).is_some() {
            self.armed = None;
            self.injected += 1;
            bail!("chaos: admission fault");
        }
        self.inner.prefill_begin(prompt, cfg, adapter, defer)
    }

    fn prefill_tick(&mut self, budget: usize) -> Result<PrefillTickOut> {
        self.inner.prefill_tick(budget)
    }

    fn prefill_stats(&self) -> PrefillStats {
        self.inner.prefill_stats()
    }

    fn decode_step(&mut self, rng: &mut Rng) -> Result<Vec<StepOut>> {
        if self.lost {
            self.last = Some(FaultInfo { domain: FaultDomain::Lost, kind: "device-lost" });
            bail!("chaos: device lost");
        }
        if let Some(f) = self.armed_kind(0) {
            self.armed = None;
            self.injected += 1;
            self.last =
                Some(FaultInfo { domain: FaultDomain::Row(f.row), kind: "decode-transient" });
            bail!("chaos: transient decode fault on row {}", f.row);
        }
        if self.armed_kind(3).is_some() {
            self.armed = None;
            self.injected += 1;
            self.last = Some(FaultInfo { domain: FaultDomain::Engine, kind: "stuck-tick" });
            bail!("chaos: stuck tick (watchdog timeout)");
        }
        self.last = None;
        self.inner.decode_step(rng)
    }

    fn last_fault(&self) -> Option<FaultInfo> {
        self.last
    }

    fn take(&mut self, row: usize) -> Option<Vec<i32>> {
        self.inner.take(row)
    }

    fn decode_text(&self, ids: &[i32]) -> String {
        self.inner.decode_text(ids)
    }

    fn spec_stats(&self) -> Option<SpecStats> {
        self.inner.spec_stats()
    }

    fn set_spec_enabled(&mut self, on: bool) {
        self.inner.set_spec_enabled(on);
    }

    fn can_admit(&mut self, prompt: &str, cfg: &SampleCfg) -> bool {
        if self.lost {
            return false;
        }
        if self.armed_kind(2).is_some() {
            self.armed = None;
            self.injected += 1;
            return false;
        }
        self.inner.can_admit(prompt, cfg)
    }

    fn paged_stats(&self) -> Option<PagedStats> {
        self.inner.paged_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::SimEngine;

    #[test]
    fn plans_are_deterministic_and_tick_ascending() {
        for &s in CHAOS_SCENARIOS {
            let a = generate(s, 256, 9).unwrap();
            let b = generate(s, 256, 9).unwrap();
            assert_eq!(a, b, "{s} must be a pure function of (ticks, seed)");
            assert!(!a.is_empty(), "{s} generated no faults in 256 ticks");
            let mut last = 0;
            for f in &a {
                assert!(f.tick >= last, "{s} plan must be tick-ascending");
                last = f.tick;
                assert!(f.kind_ix < FAULT_KINDS.len());
                assert!(f.row < 8);
            }
            assert_ne!(generate(s, 256, 10).unwrap(), a, "{s} must consume the seed");
        }
    }

    /// Cross-language contract: every scenario's plan at `(ticks=32,
    /// seed=9)`, exactly as `tools/chaos_gen.py` produces it
    /// (python/tests/test_chaos_sched.py pins the same tuples).
    #[test]
    fn plans_match_the_python_mirror_goldens() {
        let gold = |s: &str| {
            generate(s, 32, 9)
                .unwrap()
                .iter()
                .map(|f| (f.tick, f.kind_ix, f.row))
                .collect::<Vec<_>>()
        };
        let first4 = |s: &str| gold(s).into_iter().take(4).collect::<Vec<_>>();
        assert_eq!(gold("fault-storm").len(), 14);
        assert_eq!(first4("fault-storm"), vec![(0, 0, 6), (2, 0, 2), (3, 2, 5), (4, 0, 5)]);
        assert_eq!(gold("decode-flaky").len(), 9);
        assert_eq!(first4("decode-flaky"), vec![(0, 0, 0), (3, 0, 1), (5, 0, 4), (8, 0, 5)]);
        assert_eq!(gold("admit-flaky").len(), 12);
        assert_eq!(first4("admit-flaky"), vec![(0, 1, 0), (1, 1, 0), (4, 1, 0), (5, 1, 0)]);
        assert_eq!(gold("pool-squeeze").len(), 12);
        assert_eq!(first4("pool-squeeze"), vec![(0, 2, 0), (1, 2, 0), (4, 2, 0), (5, 2, 0)]);
        assert_eq!(
            gold("stuck-stall"),
            vec![(1, 3, 0), (7, 3, 0), (17, 3, 0), (27, 3, 0)]
        );
        assert_eq!(gold("device-loss"), vec![(5, 4, 0)]);
    }

    #[test]
    fn unknown_scenario_errors_with_the_catalog() {
        let err = generate("nope", 8, 0).unwrap_err().to_string();
        assert!(err.contains("fault-storm"), "error must list the catalog: {err}");
    }

    #[test]
    fn armed_decode_fault_fires_once_and_classifies_the_row() {
        let mut e = ChaosEngine::from_plan(
            SimEngine::new(2),
            vec![PlannedFault { tick: 1, kind_ix: 0, row: 1 }],
        );
        let mut rng = Rng::new(0);
        e.prefill("hi", SampleCfg { max_new: 3, ..SampleCfg::default() }, None).unwrap();
        e.begin_tick(0);
        assert!(e.decode_step(&mut rng).is_ok(), "tick 0 is clean");
        assert!(e.last_fault().is_none());
        e.begin_tick(1);
        let err = e.decode_step(&mut rng).unwrap_err().to_string();
        assert!(err.contains("transient decode fault on row 1"), "{err}");
        let info = e.last_fault().expect("fault must be classified");
        assert_eq!(info.domain, FaultDomain::Row(1));
        assert_eq!(info.kind, "decode-transient");
        // one-shot: the same tick's next step is clean again
        assert!(e.decode_step(&mut rng).is_ok());
        assert!(e.last_fault().is_none(), "clean step clears the classification");
        assert_eq!(e.injected, 1);
    }

    #[test]
    fn unfired_fault_is_dropped_when_the_tick_passes() {
        let mut e = ChaosEngine::from_plan(
            SimEngine::new(2),
            vec![PlannedFault { tick: 0, kind_ix: 0, row: 0 }],
        );
        let mut rng = Rng::new(0);
        e.prefill("hi", SampleCfg::default(), None).unwrap();
        e.begin_tick(0); // armed, but no decode happens this tick
        e.begin_tick(1);
        assert!(e.decode_step(&mut rng).is_ok(), "stale fault must not fire late");
        assert_eq!(e.injected, 0);
    }

    #[test]
    fn admit_and_pool_faults_hit_their_surfaces() {
        let mut e = ChaosEngine::from_plan(
            SimEngine::new(2),
            vec![
                PlannedFault { tick: 0, kind_ix: 1, row: 0 },
                PlannedFault { tick: 1, kind_ix: 2, row: 0 },
            ],
        );
        e.begin_tick(0);
        let err = e
            .prefill_begin("hi", SampleCfg::default(), None, false)
            .unwrap_err()
            .to_string();
        assert!(err.contains("admission fault"), "{err}");
        // consumed: the next admission this tick succeeds
        assert!(e.prefill_begin("hi", SampleCfg::default(), None, false).is_ok());
        e.begin_tick(1);
        assert!(!e.can_admit("hi", &SampleCfg::default()), "pool-exhaust spike");
        assert!(e.can_admit("hi", &SampleCfg::default()), "spike is one-shot");
        assert_eq!(e.injected, 2);
    }

    #[test]
    fn device_loss_latches_even_across_skipped_ticks() {
        let mut e = ChaosEngine::from_plan(
            SimEngine::new(2),
            vec![PlannedFault { tick: 3, kind_ix: 4, row: 0 }],
        );
        let mut rng = Rng::new(0);
        e.prefill("hi", SampleCfg::default(), None).unwrap();
        e.begin_tick(0);
        assert!(e.decode_step(&mut rng).is_ok());
        // the scheduler clock jumps straight past the loss tick
        e.begin_tick(7);
        let err = e.decode_step(&mut rng).unwrap_err().to_string();
        assert!(err.contains("device lost"), "{err}");
        assert_eq!(e.last_fault().map(|f| f.domain), Some(FaultDomain::Lost));
        assert!(!e.can_admit("hi", &SampleCfg::default()));
        assert!(e.prefill_begin("x", SampleCfg::default(), None, false).is_err());
        // permanent: it never recovers
        e.begin_tick(8);
        assert!(e.decode_step(&mut rng).is_err());
    }

    #[test]
    fn chaos_off_plan_is_fully_transparent() {
        let mut plain = SimEngine::new(2);
        let mut wrapped = ChaosEngine::from_plan(SimEngine::new(2), vec![]);
        let mut r1 = Rng::new(0);
        let mut r2 = Rng::new(0);
        let cfg = SampleCfg { max_new: 2, ..SampleCfg::default() };
        plain.prefill("hi", cfg, None).unwrap();
        wrapped.prefill("hi", cfg, None).unwrap();
        for t in 0..3 {
            wrapped.begin_tick(t);
            let a = plain.decode_step(&mut r1).unwrap();
            let b = wrapped.decode_step(&mut r2).unwrap();
            assert_eq!(a.len(), b.len(), "empty plan must not perturb decode");
        }
        assert_eq!(plain.take(0), wrapped.take(0));
    }
}

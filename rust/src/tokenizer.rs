//! Byte-level tokenizer with special tokens.
//!
//! The proxy models use vocab 512: ids 0..=255 are raw bytes, 256.. are
//! specials, the rest is reserved headroom (kept so the vocab matches the
//! artifact shapes). Synthetic corpora are ASCII, so byte-level tokenization
//! is lossless and reversible.

pub const VOCAB_SIZE: usize = 512;
pub const BOS: i32 = 256;
pub const EOS: i32 = 257;
pub const PAD: i32 = 258;
/// Separates instruction from response in SFT examples; the loss mask
/// covers only tokens after SEP (the "answer tokens", paper §2.1 L_SFT).
pub const SEP: i32 = 259;

#[derive(Debug, Clone, Default)]
pub struct Tokenizer;

impl Tokenizer {
    pub fn new() -> Tokenizer {
        Tokenizer
    }

    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.bytes().map(|b| b as i32).collect()
    }

    /// BOS + instruction + SEP + response + EOS.
    pub fn encode_pair(&self, instruction: &str, response: &str) -> Vec<i32> {
        let mut out = vec![BOS];
        out.extend(self.encode(instruction));
        out.push(SEP);
        out.extend(self.encode(response));
        out.push(EOS);
        out
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        let bytes: Vec<u8> = ids
            .iter()
            .filter(|&&t| (0..256).contains(&t))
            .map(|&t| t as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Decode only the response part (after the last SEP, before EOS/PAD).
    pub fn decode_response(&self, ids: &[i32]) -> String {
        let start = ids.iter().rposition(|&t| t == SEP).map(|i| i + 1).unwrap_or(0);
        let tail = &ids[start..];
        let end = tail
            .iter()
            .position(|&t| t == EOS || t == PAD)
            .unwrap_or(tail.len());
        self.decode(&tail[..end])
    }
}

/// Right-pad / truncate to a fixed length.
pub fn pad_to(ids: &[i32], len: usize) -> Vec<i32> {
    let mut out: Vec<i32> = ids.iter().take(len).copied().collect();
    while out.len() < len {
        out.push(PAD);
    }
    out
}

/// Loss mask for next-token prediction on a (len+1)-token sequence: mask[t]
/// covers the prediction of token t+1. `answer_only` restricts loss to the
/// response segment (after SEP) — the SFT objective; otherwise all non-PAD
/// transitions count — the LM/alignment objective (Eq. 8).
pub fn loss_mask(tokens: &[i32], answer_only: bool) -> Vec<f32> {
    let n = tokens.len() - 1;
    let sep = tokens.iter().position(|&t| t == SEP);
    (0..n)
        .map(|t| {
            let next = tokens[t + 1];
            if next == PAD || tokens[t] == PAD {
                return 0.0;
            }
            if answer_only {
                match sep {
                    Some(s) if t >= s => 1.0, // predicts tokens after SEP
                    _ => 0.0,
                }
            } else {
                1.0
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip() {
        let tk = Tokenizer::new();
        let s = "12 + 7 = 19";
        assert_eq!(tk.decode(&tk.encode(s)), s);
    }

    #[test]
    fn pair_structure() {
        let tk = Tokenizer::new();
        let ids = tk.encode_pair("2+2=", "4");
        assert_eq!(ids[0], BOS);
        assert_eq!(*ids.last().unwrap(), EOS);
        assert!(ids.contains(&SEP));
        assert_eq!(tk.decode_response(&ids), "4");
    }

    #[test]
    fn pad_and_truncate() {
        assert_eq!(pad_to(&[1, 2], 4), vec![1, 2, PAD, PAD]);
        assert_eq!(pad_to(&[1, 2, 3, 4, 5], 3), vec![1, 2, 3]);
    }

    #[test]
    fn answer_only_mask_covers_response() {
        let tk = Tokenizer::new();
        let ids = tk.encode_pair("ab", "xy"); // BOS a b SEP x y EOS
        let m = loss_mask(&ids, true);
        // positions: 0:BOS 1:a 2:b 3:SEP 4:x 5:y 6:EOS
        // mask[t] predicts ids[t+1]; response starts at SEP (t=3 predicts x)
        assert_eq!(m, vec![0., 0., 0., 1., 1., 1.]);
        let full = loss_mask(&ids, false);
        assert!(full.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn mask_zeroes_padding() {
        let ids = pad_to(&[BOS, 65, SEP, 66, EOS], 8);
        let m = loss_mask(&ids, true);
        assert_eq!(m.len(), 7);
        assert_eq!(&m[4..], &[0., 0., 0.]); // transitions into/from PAD
    }
}

#!/usr/bin/env bash
# CI gate for the workspace: tier-1 verify + static analysis + python
# tests + fmt + lints, as independent *lanes*.
#
#   ./ci.sh          # every lane the installed toolchains can run
#   ./ci.sh fast     # tier-1 only (build + test)
#
# A single preflight probes the toolchains (cargo / rustfmt / clippy /
# miri / python3 / pytest / jax) once; each lane either runs or prints a
# standardized `SKIP(<lane>: <reason>)` marker. The outcome of every
# lane — pass, skip (with reason), or fail — is written to
# `ci_lanes.json` so automation can tell "passed" from "never ran"
# without scraping the log. The loramlint lane is pure stdlib python and
# runs even on a box with no cargo and no jax.
set -euo pipefail
cd "$(dirname "$0")"

run() { echo "+ $*"; "$@"; }

# ---- toolchain preflight (probe once, decide everywhere) -------------------
have() { command -v "$1" >/dev/null 2>&1; }
HAVE_CARGO=0; have cargo && HAVE_CARGO=1
HAVE_FMT=0; [ "$HAVE_CARGO" = 1 ] && cargo fmt --version >/dev/null 2>&1 && HAVE_FMT=1
HAVE_CLIPPY=0; [ "$HAVE_CARGO" = 1 ] && cargo clippy --version >/dev/null 2>&1 && HAVE_CLIPPY=1
HAVE_MIRI=0; [ "$HAVE_CARGO" = 1 ] && cargo miri --version >/dev/null 2>&1 && HAVE_MIRI=1
HAVE_PY=0; have python3 && python3 -c "import sys" >/dev/null 2>&1 && HAVE_PY=1
HAVE_PYTEST=0; [ "$HAVE_PY" = 1 ] && python3 -c "import pytest" >/dev/null 2>&1 && HAVE_PYTEST=1
HAVE_JAX=0; [ "$HAVE_PYTEST" = 1 ] && python3 -c "import jax" >/dev/null 2>&1 && HAVE_JAX=1
HAVE_HYPOTHESIS=0; [ "$HAVE_PY" = 1 ] && python3 -c "import hypothesis" >/dev/null 2>&1 && HAVE_HYPOTHESIS=1
echo "preflight: cargo=$HAVE_CARGO fmt=$HAVE_FMT clippy=$HAVE_CLIPPY miri=$HAVE_MIRI" \
     "python3=$HAVE_PY pytest=$HAVE_PYTEST jax=$HAVE_JAX hypothesis=$HAVE_HYPOTHESIS"

# ---- lane ledger -> ci_lanes.json ------------------------------------------
LANE_NAMES=(); LANE_STATUS=(); LANE_DETAIL=(); CUR_LANE=""
lane()  { CUR_LANE="$1"; echo "== lane: $1"; }
pass()  { LANE_NAMES+=("$CUR_LANE"); LANE_STATUS+=(pass); LANE_DETAIL+=("${1:-}"); CUR_LANE=""; }
skip()  { CUR_LANE="$1"; echo "SKIP($1: $2)";
          LANE_NAMES+=("$1"); LANE_STATUS+=(skip); LANE_DETAIL+=("$2"); CUR_LANE=""; }
write_lanes() {
    local code=$?
    if [ -n "$CUR_LANE" ]; then
        LANE_NAMES+=("$CUR_LANE"); LANE_STATUS+=(fail); LANE_DETAIL+=("exit $code")
    fi
    {
        echo "{"
        echo " \"version\": 1,"
        echo " \"lanes\": ["
        local i sep=""
        for i in "${!LANE_NAMES[@]}"; do
            printf '%s  {"lane": "%s", "status": "%s", "detail": "%s"}' \
                "$sep" "${LANE_NAMES[$i]}" "${LANE_STATUS[$i]}" "${LANE_DETAIL[$i]}"
            sep=",
"
        done
        echo ""
        echo " ]"
        echo "}"
    } > ci_lanes.json
    echo "lane summary written to ci_lanes.json (${#LANE_NAMES[@]} lanes)"
}
trap write_lanes EXIT

# ---- tier-1: build + test ---------------------------------------------------
if [ "$HAVE_CARGO" = 1 ]; then
    lane rust-build
    run cargo build --release
    pass
    lane rust-test
    run cargo test -q
    pass
else
    skip rust-build "no toolchain"
    skip rust-test "no toolchain"
fi

if [ "${1:-}" = "fast" ]; then
    exit 0
fi

# ---- loramlint: stdlib static analysis (panic surface, contract mirror,
# trace coverage, lock discipline, result hygiene) against the committed
# ratchet baseline. Needs only python3 — this is the lane that still
# proves the Rust invariants when cargo itself is absent.
if [ "$HAVE_PY" = 1 ]; then
    lane loramlint
    run python3 tools/loramlint/__main__.py rust/src
    pass "ratchet vs tools/loramlint/baseline.json"
else
    skip loramlint "no python3"
fi

# ---- test-inventory audit: the skip-clean integration tests print a
# standardized "skipping: artifact '<name>' unavailable" line; when the
# artifacts directory exists, none of those skips may name an artifact
# that IS on disk (a silently-hollowed test is a CI bug, not a skip).
if [ "$HAVE_CARGO" = 1 ] && [ -d artifacts ] && [ "$HAVE_PY" = 1 ]; then
    lane skip-audit
    echo "+ cargo test --test integration -- --nocapture | skip_audit"
    INTEG_LOG=$(cargo test --test integration -- --nocapture 2>&1) || {
        echo "$INTEG_LOG"
        exit 1
    }
    echo "$INTEG_LOG" | python3 tools/skip_audit.py artifacts
    pass
elif [ ! -d artifacts ]; then
    skip skip-audit "no artifacts dir"
else
    skip skip-audit "no toolchain"
fi

# ---- §2g observability lanes: (a) Rust/Python event-schema sync (now the
# loramlint contract-mirror `event-kinds` pair, still exposed through the
# event_sync_check shim); (b) a sim serve run must emit a Perfetto trace
# whose offline replay conserves requests/tokens/blocks and whose
# TTFT/ITL percentiles match the exported serverStats bit-for-bit.
if [ "$HAVE_PY" = 1 ]; then
    lane event-sync
    run python3 tools/event_sync_check.py
    pass "shim over loramlint contract-mirror"
else
    skip event-sync "no python3"
fi
if [ "$HAVE_PY" = 1 ] && [ "$HAVE_CARGO" = 1 ]; then
    lane trace-audit
    TRACE_OUT=$(mktemp /tmp/loram_trace_XXXXXX.json)
    run cargo run --release -q -p loram -- serve --engine sim \
        --requests 24 --sim-mode spec --trace "$TRACE_OUT"
    run python3 tools/trace_report.py --check "$TRACE_OUT"
    rm -f "$TRACE_OUT" "${TRACE_OUT%.json}.jsonl"
    pass
else
    skip trace-audit "no toolchain"
fi
# ---- §2i SLO-scheduler lane: the Python tick model (the exact mirror of
# Server<SimEngine>) must (a) beat FIFO on goodput-under-SLO for the
# headline bursty-heavytail workload — the same A/B the Rust bench
# publishes into BENCH_serve.json — and (b) emit, for every scenario in
# the catalog, a stream that passes the full trace_report conservation
# audit bit-for-bit. Pure stdlib: this lane proves the scheduler laws
# even on a box with no cargo and no jax.
if [ "$HAVE_PY" = 1 ]; then
    lane slo-sim
    run python3 tools/slo_sim.py --ab bursty-heavytail -n 48 --seed 9
    SLO_OUT=$(mktemp -d /tmp/loram_slo_XXXXXX)
    for s in $(python3 tools/workload_gen.py --list); do
        run python3 tools/slo_sim.py "$s" -n 32 --seed 3 --slo --out "$SLO_OUT/$s.json"
        run python3 tools/trace_report.py --check "$SLO_OUT/$s.json"
    done
    rm -rf "$SLO_OUT"
    pass "A/B goodput gate + per-scenario conservation audit"
else
    skip slo-sim "no python3"
fi
# ---- §2j chaos lane: the fault-storm A/B gate — retry+isolation must
# resolve every request (nothing lost silently) and beat abort-on-error
# on offered-load goodput, the same A/B the Rust bench publishes into
# BENCH_serve.json — plus, for every scenario in the chaos catalog, a
# faulted sim run whose trace passes the full conservation audit (retry
# ledger, failure terminality, degradation bracketing). Pure stdlib.
if [ "$HAVE_PY" = 1 ]; then
    lane chaos-sim
    run python3 tools/slo_sim.py --chaos-ab faults -n 24 --seed 9 --batch 4
    CHAOS_OUT=$(mktemp -d /tmp/loram_chaos_XXXXXX)
    for c in $(python3 tools/chaos_gen.py --list); do
        run python3 tools/slo_sim.py faults -n 16 --seed 3 --chaos "$c" \
            --retry-budget 2 --out "$CHAOS_OUT/$c.json"
        run python3 tools/trace_report.py --check "$CHAOS_OUT/$c.json"
    done
    rm -rf "$CHAOS_OUT"
    pass "fault-storm A/B gate + per-scenario chaos conservation audit"
else
    skip chaos-sim "no python3"
fi
# the auditor's own unit tests are stdlib-only — run them even when the
# jax-gated pytest lane below is skipped
if [ "$HAVE_PYTEST" = 1 ]; then
    lane pytest-stdlib
    (cd python && run python3 -m pytest -q tests/test_trace_report.py tests/test_loramlint.py tests/test_slo_sched.py tests/test_chaos_sched.py)
    pass
else
    skip pytest-stdlib "no pytest"
fi

# ---- L1/L2 python tests (model + AOT emitter contract) under a JAX env -----
if [ "$HAVE_JAX" = 1 ]; then
    lane pytest-jax
    PYTEST_ARGS=(-q tests)
    if [ "$HAVE_HYPOTHESIS" != 1 ]; then
        echo "WARN: hypothesis not installed; skipping python/tests/test_kernels.py" >&2
        PYTEST_ARGS+=(--ignore=tests/test_kernels.py)
    fi
    # pytest must run from python/ so `compile` is importable
    (cd python && run python3 -m pytest "${PYTEST_ARGS[@]}")
    pass
    # §2f paged-equivalence lane, named explicitly so a collection change
    # (rename, accidental deselection) that hollows the dense-vs-paged
    # byte-identity contract out of the suite fails CI instead of
    # passing quietly; `-k paged` must select a non-empty set
    lane pytest-paged
    (cd python && run python3 -m pytest -q -k paged tests/test_model.py tests/test_aot.py)
    pass
    # meta-schema validation: every suite meta (and any emitted artifact
    # metas) must parse under runtime::meta's python mirror — adapter slot
    # groups and the decode_prefill_chunk window rule included, so a
    # misdeclared chunk artifact on disk fails CI here
    lane meta-check
    META_ARGS=()
    if [ -d artifacts ]; then
        META_ARGS=(--dir ../artifacts)
    fi
    # ${arr[@]+...} keeps `set -u` happy on bash < 4.4 when the array is empty
    (cd python && run python3 -m compile.meta_check ${META_ARGS[@]+"${META_ARGS[@]}"})
    pass
else
    skip pytest-jax "no jax"
    skip pytest-paged "no jax"
    skip meta-check "no jax"
fi

# ---- toolchain-side lint lanes (the dynamic mirror of loramlint) -----------
if [ "$HAVE_FMT" = 1 ]; then
    lane fmt
    run cargo fmt --all --check
    pass
else
    skip fmt "no toolchain"
fi
if [ "$HAVE_CLIPPY" = 1 ]; then
    lane clippy
    # the hot-path modules carry #![cfg_attr(not(test), deny/warn(...))]
    # panic-policy attributes; clippy.toml exempts test code
    run cargo clippy --workspace --all-targets -- -D warnings
    pass
else
    skip clippy "no toolchain"
fi
if [ "$HAVE_MIRI" = 1 ]; then
    lane miri
    # UB check on the pure-logic core (no PJRT FFI under miri)
    run cargo miri test -p loram --lib -q
    pass
else
    skip miri "no toolchain"
fi

echo "ci.sh: all runnable lanes passed"

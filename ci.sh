#!/usr/bin/env bash
# CI gate for the rust workspace: tier-1 verify + formatting + lints.
#
#   ./ci.sh          # build, test, fmt --check, clippy -D warnings
#   ./ci.sh fast     # tier-1 only (build + test)
#
# Needs a Rust toolchain (cargo); fmt/clippy steps are skipped with a
# warning when the corresponding component is missing.
set -euo pipefail
cd "$(dirname "$0")"

run() { echo "+ $*"; "$@"; }

run cargo build --release
run cargo test -q

if [ "${1:-}" = "fast" ]; then
    exit 0
fi

if cargo fmt --version >/dev/null 2>&1; then
    run cargo fmt --all --check
else
    echo "WARN: rustfmt not installed; skipping cargo fmt --check" >&2
fi

if cargo clippy --version >/dev/null 2>&1; then
    run cargo clippy --workspace --all-targets -- -D warnings
else
    echo "WARN: clippy not installed; skipping cargo clippy" >&2
fi

echo "ci.sh: all checks passed"
